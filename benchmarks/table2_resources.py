"""Paper Table 2: 1D FFT engine resource counts (N/2 vs N/2·log2 N)."""

from __future__ import annotations

from benchmarks.common import emit
from repro.core.fft1d import butterfly_counts


def run():
    print("# Table 2: 1D FFT resources")
    for n in (8, 64, 256, 1024, 4096):
        p = butterfly_counts(n, proposed=True)
        t = butterfly_counts(n, proposed=False)
        emit(
            f"table2_1dfft_N{n}",
            0.0,
            f"BU {p['butterfly_units']} vs {t['butterfly_units']}; "
            f"add {p['adders_subtractors']} vs {t['adders_subtractors']}; "
            f"stages reused {p['stages']}x",
        )


if __name__ == "__main__":
    run()
