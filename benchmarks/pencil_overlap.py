"""Distributed 2D FFT: plain pencil vs chunked corner-turn overlap.

The ping-pong insight applied to the collective itself (DESIGN.md §2):
slab i's all_to_all is independent of slab i−1's column FFT, so the
scheduler can overlap them. Runs in a subprocess with 8 fake devices;
reports wall-clock plus the compiled collective schedule structure.
"""

from __future__ import annotations

import os
import subprocess
import sys

from benchmarks.common import emit

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import time
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh
from repro.core.distributed import fft2_pencil, fft2_pencil_overlapped, pencil_sharding

mesh = make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
x = rng.standard_normal((1024, 1024)).astype(np.float32)
xs = jax.device_put(jnp.asarray(x), pencil_sharding(mesh, "data", "rows"))

plain = jax.jit(lambda v: fft2_pencil(v, mesh, variant="stockham"))
over = jax.jit(lambda v: fft2_pencil_overlapped(v, mesh, variant="stockham", chunks=4))

for name, fn in (("plain", plain), ("overlapped", over)):
    jax.block_until_ready(fn(xs))
    ts = []
    for _ in range(5):
        t0 = time.perf_counter(); jax.block_until_ready(fn(xs))
        ts.append(time.perf_counter() - t0)
    hlo = fn.lower(xs).compile().as_text()
    n_a2a = sum(1 for l in hlo.splitlines() if "all-to-all" in l and "=" in l)
    print(f"{name},{sorted(ts)[2]*1e6:.1f},a2a_ops={n_a2a}")
ref = np.fft.fft2(x)
got = np.asarray(over(xs))
print(f"overlap_rel_err,{np.max(np.abs(got-ref))/np.max(np.abs(ref)):.2e},")
"""


def run():
    print("# Distributed pencil FFT: corner-turn overlap (8 fake devices)")
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True, env=env,
        timeout=900,
    )
    if out.returncode != 0:
        emit("pencil_overlap_FAILED", 0.0, out.stderr.strip()[-120:])
        return
    for line in out.stdout.strip().splitlines():
        parts = line.split(",")
        emit(f"pencil_{parts[0]}", float(parts[1]) if parts[1] else 0.0,
             parts[2] if len(parts) > 2 else "")


if __name__ == "__main__":
    run()
