"""Serving throughput + tail latency: BENCH_serve.json.

Makes "heavy traffic" a gated number (ROADMAP), two gates:

1. **Continuous batching >= call-scoped batching.** The same backlog of
   mixed real/complex frames is served two ways under an EQUAL batch
   budget (at most ``--max-batch`` requests per admitted unit):

   * *call-scoped* — the pre-loop model: ``SpectrumService.serve`` on
     arrival-order chunks of ``max_batch``. A mixed chunk splits into one
     sub-batch per problem key, so interleaved traffic pays ~2 engine
     dispatches per chunk.
   * *loop* — the same requests stream through ``svc.loop.submit`` and
     the continuous-batching scheduler coalesces each LANE up to
     ``max_batch``: full-occupancy batches, half the dispatches.

   Gate: loop requests/sec >= call-scoped requests/sec (median of
   interleaved reps). p50/p95/p99 per-request latency reported for both,
   computed from the production-path ``LatencyHistogram`` (bounded log
   buckets), with the raw-sample p99 as a cross-check: the histogram p99
   must land within one bucket of it or the bench fails.

2. **Warm-started process re-tunes nothing.** A fresh ``PlanCache`` is
   warm-started from the packaged wisdom artifact
   (``repro.serve.wisdom``) and a MEASURE-mode service serves an
   artifact-covered shape. Gate, proven from the event stream: zero
   ``plan.measure`` spans and every ``plan.resolve`` outcome ``"hit"``.

  PYTHONPATH=src python benchmarks/serve_bench.py --out BENCH_serve.json
  PYTHONPATH=src python -m benchmarks.run serve
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np

from repro import obs
from repro.obs.hist import LatencyHistogram
from repro.plan import PlanCache
from repro.serve import BatchPolicy, SpectrumRequest, SpectrumService, wisdom

try:  # python -m benchmarks.serve_bench (repo root on sys.path)
    from benchmarks.common import emit
except ImportError:  # python benchmarks/serve_bench.py
    from common import emit


def _traffic(n_requests: int, size: int, seed: int = 0):
    """Interleaved real/complex frames: two lanes, worst case for
    call-scoped chunking (every chunk splits), best case for lane
    coalescing — the structural difference under test."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        if i % 2 == 0:
            frame = rng.standard_normal((size, size)).astype(np.float32)
        else:
            frame = (
                rng.standard_normal((size, size))
                + 1j * rng.standard_normal((size, size))
            ).astype(np.complex64)
        reqs.append(SpectrumRequest(frame=frame))
    return reqs


def _quantiles(lat_us: list) -> dict:
    """Tail stats the way the serve loop reports them in production: a
    bounded log-bucket :class:`LatencyHistogram`, not a raw-sample sort.
    The raw p99 rides along as a cross-check — the histogram's p99 must
    land within one bucket (~19% at the default geometry) of it, which
    is the accuracy the histogram promises by construction."""
    h = LatencyHistogram()
    for us in lat_us:
        h.record(us)
    raw_p99 = float(np.percentile(np.asarray(lat_us), 99))
    hist_p99 = h.percentile(99)
    return {
        "p50_us": round(h.percentile(50), 1),
        "p95_us": round(h.percentile(95), 1),
        "p99_us": round(hist_p99, 1),
        "raw_p99_us": round(raw_p99, 1),
        "hist_p99_within_one_bucket": (
            abs(h.bucket_index(hist_p99) - h.bucket_index(raw_p99)) <= 1
        ),
    }


def _serve_call_scoped(svc, reqs, max_batch) -> list:
    """Chunked serve; per-request latency = chunk completion - t0 (the
    whole backlog is present at t0 — a drained queue, both modes)."""
    t0 = time.perf_counter()
    lat = []
    for i in range(0, len(reqs), max_batch):
        chunk = reqs[i:i + max_batch]
        svc.serve(chunk)
        done_at = (time.perf_counter() - t0) * 1e6
        lat.extend([done_at] * len(chunk))
    return lat


def _serve_loop(svc, reqs) -> list:
    t0 = time.perf_counter()
    tickets = [svc.loop.submit(r) for r in reqs]
    lat = {}
    while svc.loop.tick(drain=True, raise_errors=True):
        done_at = (time.perf_counter() - t0) * 1e6
        for i, t in enumerate(tickets):
            if t.done and i not in lat:
                lat[i] = done_at
    assert len(lat) == len(reqs), "loop left requests unserved"
    return [lat[i] for i in range(len(reqs))]


def bench_throughput(n_requests: int, size: int, max_batch: int, reps: int) -> dict:
    call_svc = SpectrumService()
    loop_svc = SpectrumService(batch=BatchPolicy(max_batch=max_batch))
    # Warm both modes' jit shapes before timing: chunked sub-batches
    # (~max_batch/2 per lane) and full lane batches (max_batch) compile
    # to different batched kernels.
    warm = _traffic(n_requests, size, seed=99)
    _serve_call_scoped(call_svc, warm, max_batch)
    _serve_loop(loop_svc, _traffic(n_requests, size, seed=98))

    call_runs, loop_runs = [], []
    for rep in range(reps):
        reqs = _traffic(n_requests, size, seed=rep)
        order = (  # interleave which mode goes first: kill drift bias
            [("call", call_svc), ("loop", loop_svc)]
            if rep % 2 == 0
            else [("loop", loop_svc), ("call", call_svc)]
        )
        for mode, svc in order:
            t0 = time.perf_counter()
            if mode == "call":
                lat = _serve_call_scoped(svc, _traffic(n_requests, size, seed=rep),
                                         max_batch)
                call_runs.append((time.perf_counter() - t0, lat))
            else:
                lat = _serve_loop(svc, reqs)
                loop_runs.append((time.perf_counter() - t0, lat))

    def median_run(runs):
        runs = sorted(runs, key=lambda r: r[0])
        return runs[len(runs) // 2]

    call_s, call_lat = median_run(call_runs)
    loop_s, loop_lat = median_run(loop_runs)
    call_rps = n_requests / call_s
    loop_rps = n_requests / loop_s
    with obs.capture() as trace:
        _serve_loop(loop_svc, _traffic(n_requests, size, seed=1234))
    dispatches = len(trace.select("serve.batch"))
    call_q = _quantiles(call_lat)
    loop_q = _quantiles(loop_lat)
    return {
        "requests": n_requests,
        "size": size,
        "max_batch": max_batch,
        "reps": reps,
        "call_scoped": {
            "rps": round(call_rps, 1),
            "total_s": round(call_s, 4),
            **call_q,
        },
        "loop": {
            "rps": round(loop_rps, 1),
            "total_s": round(loop_s, 4),
            "dispatches": dispatches,
            **loop_q,
        },
        "speedup": round(loop_rps / call_rps, 3),
        "ok": (
            loop_rps >= call_rps
            and call_q["hist_p99_within_one_bucket"]
            and loop_q["hist_p99_within_one_bucket"]
        ),
    }


def bench_warm_start(size: int, n_requests: int) -> dict:
    """A fresh process, warm-started: zero MEASURE sweeps, all hits."""
    cache = PlanCache()
    artifact = wisdom.artifact_path()
    if artifact is None:
        # no packaged artifact for this backend: generate one (this IS
        # the measure cost the artifact saves everyone else)
        cache = wisdom.pretune([size], kinds=("rfft2d",), measure_iters=1)
        report = {"kept": len(cache), "file_error": "generated in-process"}
    else:
        report = wisdom.warm_start(artifact, cache=cache).to_dict()
    covered = sorted(
        p.key.shape for _, p in cache.entries() if p.key.kind == "rfft2d"
    )
    shape = covered[0] if covered else (size, size)
    svc = SpectrumService(plan_mode="measure", cache=cache)
    rng = np.random.default_rng(0)
    reqs = [
        SpectrumRequest(frame=rng.standard_normal(shape).astype(np.float32))
        for _ in range(n_requests)
    ]
    with obs.capture() as trace:
        svc.serve(reqs)
    measure_spans = len(trace.select("plan.measure"))
    outcomes = [e["outcome"] for e in trace.select("plan.resolve")]
    ok = (
        all(r.done for r in reqs)
        and report["kept"] > 0
        and measure_spans == 0
        and outcomes == ["hit"]
    )
    return {
        "artifact": artifact,
        "load": report,
        "served_shape": list(shape),
        "measure_spans": measure_spans,
        "resolve_outcomes": outcomes,
        "ok": ok,
    }


def run() -> None:
    """benchmarks.run entry point: default sweep, BENCH_serve.json."""
    main(["--out", "/tmp/BENCH_serve.json"])


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=96,
                    help="backlog size per rep")
    ap.add_argument("--size", type=int, default=64,
                    help="frame size N (NxN)")
    ap.add_argument("--max-batch", type=int, default=16,
                    help="batch budget for BOTH serving modes")
    ap.add_argument("--reps", type=int, default=5,
                    help="interleaved reps (median)")
    ap.add_argument("--out", default=None, help="write the JSON report here")
    args = ap.parse_args(argv)

    throughput = bench_throughput(
        args.requests, args.size, args.max_batch, args.reps
    )
    warm = bench_warm_start(args.size, n_requests=8)
    report = {
        "backend": jax.default_backend(),
        "throughput": throughput,
        "warm_start": warm,
        "ok": throughput["ok"] and warm["ok"],
    }
    emit(
        f"serve_bench/loop/{args.size}",
        round(throughput["loop"]["total_s"] * 1e6 / args.requests, 2),
        f"rps={throughput['loop']['rps']} p99={throughput['loop']['p99_us']}",
    )
    emit(
        f"serve_bench/call_scoped/{args.size}",
        round(throughput["call_scoped"]["total_s"] * 1e6 / args.requests, 2),
        f"rps={throughput['call_scoped']['rps']}",
    )
    text = json.dumps(report, indent=2)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
