"""Paper Table 6: delay comparison. On the Virtex-6 the proposed design was
1.1% slower (32.487 vs 32.129 ns). Claim under test: butterfly reuse costs
(almost) no time. We measure wall-clock of the looped vs unrolled engines
(jit'd, CPU) for the paper's 8×8 frame and larger sizes."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

import repro.xfft as xfft
from benchmarks.common import emit, time_fn


def run():
    print("# Table 6 analogue: 2D FFT delay, looped (proposed) vs unrolled (traditional)")
    rng = np.random.default_rng(0)
    for hw, batch in (((8, 8), 64), ((64, 64), 16), ((256, 256), 2)):
        x = jnp.asarray(rng.standard_normal((batch, *hw)), jnp.float32)
        def _fft2_with(variant):
            def run(v):
                with xfft.config(variant=variant):
                    return xfft.fft2(v)
            return jax.jit(run)

        f_loop = _fft2_with("looped")
        f_unroll = _fft2_with("unrolled")
        us_l = time_fn(f_loop, x)
        us_u = time_fn(f_unroll, x)
        ratio = us_l / us_u
        emit(
            f"table6_delay_{hw[0]}x{hw[1]}",
            us_l,
            f"looped {us_l:.1f}us vs unrolled {us_u:.1f}us; ratio={ratio:.3f} "
            f"(paper: 1.011)",
        )


if __name__ == "__main__":
    run()
