"""Streaming throughput: the ping-pong double-buffered 2D FFT pipeline
(paper fig. 3/4) vs a frame-at-a-time loop, plus the fused Pallas 2D kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

import repro.xfft as xfft
from benchmarks.common import emit, time_fn
from repro.core.fft2d import fft2_stream
from repro.kernels.ops import fft2_kernel


def run():
    print("# Streaming 2D FFT throughput (frames/s)")
    rng = np.random.default_rng(0)
    frames = jnp.asarray(rng.standard_normal((16, 128, 128)), jnp.float32)

    def _seq(f):
        with xfft.config(variant="stockham"):
            return xfft.fft2(f)

    stream = jax.jit(lambda f: fft2_stream(f, variant="stockham"))
    seq = jax.jit(_seq)

    us_stream = time_fn(stream, frames)
    us_seq = time_fn(seq, frames)
    fps_stream = 16 / (us_stream * 1e-6)
    fps_seq = 16 / (us_seq * 1e-6)
    emit("throughput_pingpong_stream", us_stream, f"{fps_stream:.0f} frames/s")
    emit("throughput_sequential", us_seq, f"{fps_seq:.0f} frames/s")

    kern = jax.jit(lambda f: fft2_kernel(f, interpret=True))
    us_k = time_fn(kern, frames[:2], iters=3)
    emit("throughput_fused_kernel_interp", us_k,
         "interpret mode (CPU) — per-frame HBM traffic 1 round trip")


if __name__ == "__main__":
    run()
