"""Paper Tables 4/5 analogue: device-utilization comparison.

FPGA slice/LUT/DSP counts have no TPU meaning; the resources that play
"area"'s role here (DESIGN.md §2) are:

  * compiled code size + HLO instruction count  (spatial footprint)
  * peak temp bytes (memory_analysis)           (register/RAM footprint)
  * modeled HBM traffic of the Pallas kernels   (fused = 1 round trip vs
    staged = log2 N) — the paper's α reappears as the traffic ratio.

Proposed (looped / fused-kernel) vs traditional (unrolled / staged-kernel).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.fft1d import fft_impl
from repro.kernels.ops import hbm_traffic_model


def _compiled_stats(variant: str, n: int, batch: int = 64):
    fn = jax.jit(lambda x: fft_impl(x, variant=variant))
    x = jax.ShapeDtypeStruct((batch, n), jnp.complex64)
    compiled = fn.lower(x).compile()
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    n_instr = sum(
        1 for l in hlo.splitlines() if "=" in l and not l.strip().startswith("//")
    )
    return {
        "code_bytes": mem.generated_code_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "hlo_instructions": n_instr,
    }


def run():
    print("# Table 5 analogue: compiled-artifact utilization, looped vs unrolled")
    for n in (64, 1024, 4096):
        loop = _compiled_stats("looped", n)
        unroll = _compiled_stats("unrolled", n)
        emit(
            f"table5_codesize_N{n}",
            0.0,
            f"hlo_instr {loop['hlo_instructions']} vs {unroll['hlo_instructions']}; "
            f"temp {loop['temp_bytes']} vs {unroll['temp_bytes']} B",
        )
    print("# Pallas-kernel HBM traffic (fused 'reuse' kernel vs staged baseline)")
    for n in (256, 1024, 4096):
        fused = hbm_traffic_model(128, n, fused=True)
        staged = hbm_traffic_model(128, n, fused=False)
        emit(
            f"table5_hbm_traffic_N{n}",
            0.0,
            f"fused {fused} B vs staged {staged} B; ratio={fused/staged:.4f} "
            f"(paper alpha={1/jnp.log2(n):.4f})",
        )


if __name__ == "__main__":
    run()
