"""Observability overhead + event-stream acceptance: BENCH_obs.json.

Four gates, all about trusting the ``repro.obs`` layer:

1. **Overhead, recorder ON** — the fully instrumented cached hot path
   (``xfft.fft2`` at NxN, plan already in cache, the always-on flight
   recorder at its default capacity AND an active ``obs.capture()``
   scope) must stay within ``--gate-pct`` (default 3%) of the identical
   loop gone fully dark (``xfft.config(flight_recorder=False)``, no
   scope). Baseline and instrumented reps are interleaved so clock
   drift hits both equally.

2. **"Second run re-tunes nothing", proven by events** — under a
   file-backed MEASURE-mode scope, the cold call must emit exactly one
   ``plan.measure`` sweep; the warm call and a fresh-cache "second
   process" (a new ``PlanCache`` loading the same wisdom file) must emit
   zero, with their ``plan.resolve`` events reading ``outcome="hit"``.

3. **Flight dump fidelity** — an injected engine failure drives a real
   ``resilience.failover``, which must auto-dump a JSONL snapshot whose
   trailing events are exactly the live trace up to and including the
   trigger: the black box replays what the caller saw.

4. **Calibration coverage** — after warm loops over three transform
   kinds, the planner calibration ledger must hold >= 3 (engine, kind)
   rows with observed dispatch samples and an observed/predicted ratio.

Also writes CI-artifact snapshots: a Chrome-trace/Perfetto JSON of the
flight recorder's window (``--trace-out``) and a Prometheus text
exposition of counters + latency histograms (``--prom-out``).

  PYTHONPATH=src python benchmarks/obs_bench.py --size 256
  PYTHONPATH=src python -m benchmarks.run obs
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.xfft as xfft
from repro import obs, resilience
from repro.obs import telemetry
from repro.obs.export import write_chrome_trace, write_prometheus
from repro.plan import PlanCache, reset_default_cache
from repro.plan.api import resolve_call
from repro.resilience import FaultPlan, FaultSpec

try:  # python -m benchmarks.obs_bench (repo root on sys.path)
    from benchmarks.common import emit
except ImportError:  # python benchmarks/obs_bench.py (script dir on sys.path)
    from common import emit


def _hot_loop_us(x, iters: int) -> float:
    """Wall time per cached fft2 call (µs), one rep."""
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(xfft.fft2(x))
    return (time.perf_counter() - t0) * 1e6 / iters


def bench_overhead(n: int, iters: int, reps: int) -> dict:
    """Median per-call time of the cached hot loop, fully dark vs fully
    instrumented (default flight recorder + capture scope)."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(
        (rng.standard_normal((n, n)) + 1j * rng.standard_normal((n, n)))
        .astype(np.complex64)
    )
    # Warm: plan resolved into the cache, kernels compiled.
    jax.block_until_ready(xfft.fft2(x))

    def dark() -> float:
        with xfft.config(flight_recorder=False):
            return _hot_loop_us(x, iters)

    def lit() -> float:
        with obs.capture():  # default recorder stays installed
            return _hot_loop_us(x, iters)

    baseline, instrumented = [], []
    for rep in range(reps):
        # Interleave AND alternate order per rep: running second in a pair
        # is measurably slower on shared CPUs, so a fixed order would book
        # that position bias as instrumentation overhead.
        if rep % 2:
            instrumented.append(lit())
            baseline.append(dark())
        else:
            baseline.append(dark())
            instrumented.append(lit())
    baseline.sort()
    instrumented.sort()
    base_us = baseline[len(baseline) // 2]
    instr_us = instrumented[len(instrumented) // 2]
    rec = obs.flight_recorder()
    return {
        "size": n,
        "iters": iters,
        "reps": reps,
        "recorder_capacity": rec.capacity if rec else 0,
        "baseline_us": round(base_us, 2),
        "instrumented_us": round(instr_us, 2),
        "overhead_pct": round((instr_us - base_us) / base_us * 100.0, 3),
    }


def bench_events(n: int) -> dict:
    """Cold MEASURE sweep then two warm paths, judged by the event stream."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(
        (rng.standard_normal((n, n)) + 1j * rng.standard_normal((n, n)))
        .astype(np.complex64)
    )
    with tempfile.TemporaryDirectory() as d:
        with xfft.config(cache_dir=d, mode="measure"):
            with obs.capture() as cold:
                jax.block_until_ready(xfft.fft2(x))
            with obs.capture() as warm:
                jax.block_until_ready(xfft.fft2(x))
        # "Second process": a fresh cache object loads the wisdom file the
        # sweep persisted; resolution must hit with zero MEASURE work.
        fresh = PlanCache(path=os.path.join(d, "xfft_plans.json"))
        with obs.capture() as second:
            resolve_call("fft2d", (n, n), cache=fresh, mode="measure")
        return {
            "size": n,
            "cold_outcome": cold.first("plan.resolve")["outcome"],
            "cold_measure_events": len(cold.select("plan.measure")),
            "cold_candidates": cold.first("plan.measure").get("candidates"),
            "warm_outcome": warm.first("plan.resolve")["outcome"],
            "warm_measure_events": len(warm.select("plan.measure")),
            "second_process_outcome": second.first("plan.resolve")["outcome"],
            "second_process_measure_events": len(second.select("plan.measure")),
            "wisdom_load": fresh.load_report.to_dict(),
        }


def bench_flight_dump(n: int, dump_dir: str) -> dict:
    """Inject one engine failure; the failover must auto-dump a JSONL
    snapshot whose tail is exactly the live trace up to the trigger."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(
        (rng.standard_normal((n, n)) + 1j * rng.standard_normal((n, n)))
        .astype(np.complex64)
    )
    jax.block_until_ready(xfft.fft2(x))  # warm: plan + kernels ready
    resilience.reset()
    rec = telemetry.FlightRecorder(capacity=1024, dump_dir=dump_dir)
    fp = FaultPlan(FaultSpec("engine.apply", mode="error", times=1))
    with xfft.config(flight_recorder=rec, faults=fp):
        with obs.capture() as trace:
            jax.block_until_ready(xfft.fft2(x))
    resilience.reset()  # do not leave the benched engine quarantined

    live = [e.name for e in trace]
    upto = live[: live.index("resilience.failover") + 1]
    # the breaker-open dump fires first (record_failure precedes the
    # failover emit); the gate is on the failover snapshot
    dump = next(
        (d for d in rec.stats()["dumps"]
         if d["trigger"] == "resilience.failover"),
        None,
    )
    tail_matches = False
    if dump is not None:
        dumped = [json.loads(line)["name"] for line in open(dump["path"])]
        tail_matches = dumped[-len(upto):] == upto
    return {
        "size": n,
        "dumps": [
            {"trigger": d["trigger"], "events": d["events"]}
            for d in rec.stats()["dumps"]
        ],
        "live_events_to_trigger": len(upto),
        "dump_tail_matches_live_trace": tail_matches,
        "ok": dump is not None and tail_matches,
    }


def bench_calibration(n: int, iters: int) -> dict:
    """Warm loops over three transform kinds; the ledger must join
    observed dispatch durations against planner predictions for >= 3
    (engine, kind) rows."""
    ledger = obs.calibration_ledger()
    ledger.reset()
    rng = np.random.default_rng(3)
    cx = jnp.asarray(
        (rng.standard_normal((n, n)) + 1j * rng.standard_normal((n, n)))
        .astype(np.complex64)
    )
    re = jnp.asarray(rng.standard_normal((n, n)).astype(np.float32))
    v = jnp.asarray(
        (rng.standard_normal(n * 4) + 1j * rng.standard_normal(n * 4))
        .astype(np.complex64)
    )
    for _ in range(iters):
        jax.block_until_ready(xfft.fft2(cx))
        jax.block_until_ready(xfft.rfft2(re))
        jax.block_until_ready(xfft.fft(v))
    rows = [r for r in ledger.table() if r["observed_n"] > 0]
    covered = sorted({(r["engine"], r["kind"]) for r in rows})
    return {
        "size": n,
        "iters": iters,
        "observed_rows": len(rows),
        "engine_kind_pairs": [list(p) for p in covered],
        "all_have_ratio": all(r["ratio"] is not None for r in rows),
        "table": ledger.table()[:10],
        "ok": len(covered) >= 3 and all(r["ratio"] is not None for r in rows),
    }


def export_snapshots(trace_out: str, prom_out: str) -> dict:
    """Write the CI-artifact views: Chrome trace of the flight recorder's
    retained window, Prometheus exposition of counters + histograms."""
    rec = obs.flight_recorder()
    events = rec.events() if rec is not None else []
    names = rec.thread_names() if rec is not None else {}
    write_chrome_trace(events, trace_out, thread_names=names)
    gauges = {}
    if rec is not None:
        stats = rec.stats()
        gauges = {
            "flight_recorder_retained": stats["retained"],
            "flight_recorder_recorded_total": stats["recorded_total"],
        }
    write_prometheus(
        prom_out,
        counters=obs.counters(),
        gauges=gauges,
        histograms=obs.histograms(),
    )
    return {
        "chrome_trace": trace_out,
        "chrome_trace_events": len(events),
        "prometheus": prom_out,
        "histograms_exported": len(obs.histograms()),
    }


def run() -> None:
    """benchmarks.run entry point: default sweep, report to BENCH_obs.json."""
    main(["--out", "/tmp/BENCH_obs.json"])


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--size", type=int, default=256,
                    help="frame size N for the cached overhead loop (NxN)")
    ap.add_argument("--measure-size", type=int, default=64,
                    help="frame size for the MEASURE event-stream proof")
    ap.add_argument("--iters", type=int, default=30,
                    help="hot-loop calls per rep")
    ap.add_argument("--reps", type=int, default=7,
                    help="interleaved baseline/instrumented reps (median)")
    ap.add_argument("--gate-pct", type=float, default=3.0,
                    help="max tolerated instrumentation overhead, percent")
    ap.add_argument("--out", default=None, help="write the JSON report here")
    ap.add_argument("--trace-out", default="/tmp/obs_trace.json",
                    help="write the Chrome-trace snapshot here")
    ap.add_argument("--prom-out", default="/tmp/obs_metrics.prom",
                    help="write the Prometheus exposition here")
    args = ap.parse_args(argv)

    reset_default_cache()
    overhead = bench_overhead(args.size, args.iters, args.reps)
    events = bench_events(args.measure_size)
    events_ok = (
        events["cold_outcome"] == "measured"
        and events["cold_measure_events"] == 1
        and events["warm_outcome"] == "hit"
        and events["warm_measure_events"] == 0
        and events["second_process_outcome"] == "hit"
        and events["second_process_measure_events"] == 0
        and events["wisdom_load"]["kept"] >= 1
    )
    overhead_ok = overhead["overhead_pct"] < args.gate_pct
    with tempfile.TemporaryDirectory() as dump_dir:
        flight = bench_flight_dump(args.measure_size, dump_dir)
    calibration = bench_calibration(args.measure_size, iters=5)
    snapshots = export_snapshots(args.trace_out, args.prom_out)
    report = {
        "backend": jax.default_backend(),
        "gate_pct": args.gate_pct,
        "overhead": overhead,
        "overhead_ok": overhead_ok,
        "events": events,
        "events_ok": events_ok,
        "flight_dump": flight,
        "calibration": calibration,
        "snapshots": snapshots,
        "counters": obs.counters(),
        "ok": overhead_ok and events_ok and flight["ok"] and calibration["ok"],
    }
    emit(f"obs_bench/hot_loop/{args.size}", overhead["instrumented_us"],
         f"overhead_pct={overhead['overhead_pct']}")
    text = json.dumps(report, indent=2)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
