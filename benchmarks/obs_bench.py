"""Observability overhead + event-stream acceptance: BENCH_obs.json.

Two gates, both about trusting the new ``repro.obs`` layer:

1. **Overhead** — the instrumented cached hot path (``xfft.fft2`` at
   NxN, plan already in cache, events collected by an active
   ``obs.capture()`` scope) must stay within ``--gate-pct`` (default 3%)
   of the identical loop with no capture scope. Baseline and
   instrumented reps are interleaved so clock drift hits both equally.

2. **"Second run re-tunes nothing", proven by events** — under a
   file-backed MEASURE-mode scope, the cold call must emit exactly one
   ``plan.measure`` sweep; the warm call and a fresh-cache "second
   process" (a new ``PlanCache`` loading the same wisdom file) must emit
   zero, with their ``plan.resolve`` events reading ``outcome="hit"``.
   This replaces the ad-hoc hit/miss counter asserts older benches used:
   the event stream *is* the evidence.

  PYTHONPATH=src python benchmarks/obs_bench.py --size 256
  PYTHONPATH=src python -m benchmarks.run obs
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.xfft as xfft
from repro import obs
from repro.plan import PlanCache, reset_default_cache
from repro.plan.api import resolve_call

try:  # python -m benchmarks.obs_bench (repo root on sys.path)
    from benchmarks.common import emit
except ImportError:  # python benchmarks/obs_bench.py (script dir on sys.path)
    from common import emit


def _hot_loop_us(x, iters: int) -> float:
    """Wall time per cached fft2 call (µs), one rep."""
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(xfft.fft2(x))
    return (time.perf_counter() - t0) * 1e6 / iters


def bench_overhead(n: int, iters: int, reps: int) -> dict:
    """Median per-call time of the cached hot loop, capture off vs on."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(
        (rng.standard_normal((n, n)) + 1j * rng.standard_normal((n, n)))
        .astype(np.complex64)
    )
    # Warm: plan resolved into the cache, kernels compiled.
    jax.block_until_ready(xfft.fft2(x))
    baseline, instrumented = [], []
    for rep in range(reps):
        # Interleave AND alternate order per rep: running second in a pair
        # is measurably slower on shared CPUs, so a fixed order would book
        # that position bias as instrumentation overhead.
        first_on = bool(rep % 2)
        if first_on:
            with obs.capture():
                instrumented.append(_hot_loop_us(x, iters))
            baseline.append(_hot_loop_us(x, iters))
        else:
            baseline.append(_hot_loop_us(x, iters))
            with obs.capture():
                instrumented.append(_hot_loop_us(x, iters))
    baseline.sort()
    instrumented.sort()
    base_us = baseline[len(baseline) // 2]
    instr_us = instrumented[len(instrumented) // 2]
    return {
        "size": n,
        "iters": iters,
        "reps": reps,
        "baseline_us": round(base_us, 2),
        "instrumented_us": round(instr_us, 2),
        "overhead_pct": round((instr_us - base_us) / base_us * 100.0, 3),
    }


def bench_events(n: int) -> dict:
    """Cold MEASURE sweep then two warm paths, judged by the event stream."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(
        (rng.standard_normal((n, n)) + 1j * rng.standard_normal((n, n)))
        .astype(np.complex64)
    )
    with tempfile.TemporaryDirectory() as d:
        with xfft.config(cache_dir=d, mode="measure"):
            with obs.capture() as cold:
                jax.block_until_ready(xfft.fft2(x))
            with obs.capture() as warm:
                jax.block_until_ready(xfft.fft2(x))
        # "Second process": a fresh cache object loads the wisdom file the
        # sweep persisted; resolution must hit with zero MEASURE work.
        fresh = PlanCache(path=os.path.join(d, "xfft_plans.json"))
        with obs.capture() as second:
            resolve_call("fft2d", (n, n), cache=fresh, mode="measure")
        return {
            "size": n,
            "cold_outcome": cold.first("plan.resolve")["outcome"],
            "cold_measure_events": len(cold.select("plan.measure")),
            "cold_candidates": cold.first("plan.measure").get("candidates"),
            "warm_outcome": warm.first("plan.resolve")["outcome"],
            "warm_measure_events": len(warm.select("plan.measure")),
            "second_process_outcome": second.first("plan.resolve")["outcome"],
            "second_process_measure_events": len(second.select("plan.measure")),
            "wisdom_load": fresh.load_report.to_dict(),
        }


def run() -> None:
    """benchmarks.run entry point: default sweep, report to BENCH_obs.json."""
    main(["--out", "/tmp/BENCH_obs.json"])


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--size", type=int, default=256,
                    help="frame size N for the cached overhead loop (NxN)")
    ap.add_argument("--measure-size", type=int, default=64,
                    help="frame size for the MEASURE event-stream proof")
    ap.add_argument("--iters", type=int, default=30,
                    help="hot-loop calls per rep")
    ap.add_argument("--reps", type=int, default=7,
                    help="interleaved baseline/instrumented reps (median)")
    ap.add_argument("--gate-pct", type=float, default=3.0,
                    help="max tolerated instrumentation overhead, percent")
    ap.add_argument("--out", default=None, help="write the JSON report here")
    args = ap.parse_args(argv)

    reset_default_cache()
    overhead = bench_overhead(args.size, args.iters, args.reps)
    events = bench_events(args.measure_size)
    events_ok = (
        events["cold_outcome"] == "measured"
        and events["cold_measure_events"] == 1
        and events["warm_outcome"] == "hit"
        and events["warm_measure_events"] == 0
        and events["second_process_outcome"] == "hit"
        and events["second_process_measure_events"] == 0
        and events["wisdom_load"]["kept"] >= 1
    )
    overhead_ok = overhead["overhead_pct"] < args.gate_pct
    report = {
        "backend": jax.default_backend(),
        "gate_pct": args.gate_pct,
        "overhead": overhead,
        "overhead_ok": overhead_ok,
        "events": events,
        "events_ok": events_ok,
        "counters": obs.counters(),
        "ok": overhead_ok and events_ok,
    }
    emit(f"obs_bench/hot_loop/{args.size}", overhead["instrumented_us"],
         f"overhead_pct={overhead['overhead_pct']}")
    text = json.dumps(report, indent=2)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
