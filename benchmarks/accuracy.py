"""Numerical accuracy of every registered engine vs float64 references.

Two sections, one JSON report (``BENCH_precision.json`` in CI):

* ``single`` — each single-precision engine in the ``repro.engines``
  registry, 1D forward transform vs a float64 DFT oracle, across sizes
  (the registry is the sweep source: a new engine registration is a new
  report row, no edits here).
* ``double`` — the ``precision="double"`` path (the ``reference_x64``
  engine) for all eight xfft transforms vs ``numpy.fft`` computed in
  double; the gate is max error ≤ 1e-10, the ISSUE-5 acceptance bound.

  PYTHONPATH=src python benchmarks/accuracy.py --out /tmp/BENCH_precision.json
  PYTHONPATH=src python -m benchmarks.run accuracy
"""

from __future__ import annotations

import argparse
import json
import sys

import jax.numpy as jnp
import numpy as np

import repro.xfft as xfft
from repro.engines import iter_engines
from repro.plan import problem_key

try:  # python -m benchmarks.accuracy (repo root on sys.path)
    from benchmarks.common import emit
except ImportError:  # python benchmarks/accuracy.py (script dir on sys.path)
    from common import emit

DOUBLE_TOL = 1e-10


def single_precision_errors(sizes=(64, 1024, 4096)) -> dict:
    """Max relative error of each single-precision engine's 1D forward
    transform vs the float64 DFT oracle."""
    rng = np.random.default_rng(0)
    out: dict = {}
    for n in sizes:
        x = (rng.standard_normal((8, n)) + 1j * rng.standard_normal((8, n))).astype(
            np.complex64
        )
        ref = np.fft.fft(x.astype(np.complex128))
        scale = np.max(np.abs(ref))
        key = problem_key("fft1d", (8, n))
        for spec in iter_engines(kind="fft1d", precision="single"):
            if not spec.supports(key):
                continue
            with xfft.config(variant=spec.name):
                got = np.asarray(xfft.fft(jnp.asarray(x)))
            err = float(np.max(np.abs(got - ref)) / scale)
            out.setdefault(spec.name, {})[str(n)] = err
            emit(f"accuracy_{spec.name}_N{n}", 0.0, f"max_rel_err={err:.2e}")
    return out


def double_precision_errors() -> dict:
    """Max scaled error of all eight transforms under precision="double"
    vs numpy.fft in double — the registered x64 engine end to end."""
    rng = np.random.default_rng(1)
    z1 = (rng.standard_normal((3, 64)) + 1j * rng.standard_normal((3, 64))).astype(
        np.complex64
    )
    z2 = (rng.standard_normal((2, 32, 32))
          + 1j * rng.standard_normal((2, 32, 32))).astype(np.complex64)
    x1 = rng.standard_normal((3, 64)).astype(np.float32)
    x2 = rng.standard_normal((2, 32, 32)).astype(np.float32)
    h1 = np.fft.rfft(x1).astype(np.complex64)
    h2 = np.fft.rfft2(x2).astype(np.complex64)

    def err(got, ref):
        got, ref = np.asarray(got), np.asarray(ref)
        return float(np.max(np.abs(got - ref)) / max(1.0, np.max(np.abs(ref))))

    with xfft.config(precision="double"):
        errors = {
            "fft": err(xfft.fft(z1), np.fft.fft(z1.astype(np.complex128))),
            "ifft": err(xfft.ifft(z1), np.fft.ifft(z1.astype(np.complex128))),
            "fft2": err(xfft.fft2(z2), np.fft.fft2(z2.astype(np.complex128))),
            "ifft2": err(xfft.ifft2(z2), np.fft.ifft2(z2.astype(np.complex128))),
            "rfft": err(xfft.rfft(x1), np.fft.rfft(x1.astype(np.float64))),
            "irfft": err(xfft.irfft(h1), np.fft.irfft(h1.astype(np.complex128))),
            "rfft2": err(xfft.rfft2(x2), np.fft.rfft2(x2.astype(np.float64))),
            "irfft2": err(xfft.irfft2(h2),
                          np.fft.irfft2(h2.astype(np.complex128))),
        }
    for name, e in errors.items():
        emit(f"accuracy_double_{name}", 0.0, f"max_err={e:.2e}")
    return errors


def build_report(sizes=(64, 1024, 4096)) -> dict:
    import jax

    single = single_precision_errors(sizes)
    double = double_precision_errors()
    return {
        "backend": jax.default_backend(),
        "sizes": list(sizes),
        "single": single,
        "double": double,
        "double_tol": DOUBLE_TOL,
        "ok": all(e <= DOUBLE_TOL for e in double.values()),
    }


def run():
    """benchmarks.run entry point: print the report (small size sweep)."""
    print("# Engine accuracy vs float64 references")
    report = build_report(sizes=(64, 1024))
    print(json.dumps(report, indent=2))


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sizes", default="64,1024,4096",
                    help="comma-separated 1D sizes for the single sweep")
    ap.add_argument("--out", default=None, help="write the JSON report here")
    args = ap.parse_args(argv)
    sizes = tuple(int(s) for s in args.sizes.split(",") if s)
    report = build_report(sizes=sizes)
    text = json.dumps(report, indent=2)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
