"""Numerical accuracy of the engine vs a float64 DFT oracle (all variants +
the Pallas kernels), across transform sizes."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

import repro.xfft as xfft
from benchmarks.common import emit
from repro.kernels.ops import fft_kernel, fft_staged


def run():
    print("# Engine accuracy vs float64 DFT (max relative error)")
    rng = np.random.default_rng(0)
    for n in (64, 1024, 4096):
        x = (rng.standard_normal((8, n)) + 1j * rng.standard_normal((8, n))).astype(
            np.complex64
        )
        ref = np.fft.fft(x.astype(np.complex128))
        scale = np.max(np.abs(ref))
        for variant in ("looped", "unrolled", "stockham"):
            with xfft.config(variant=variant):
                got = np.asarray(xfft.fft(jnp.asarray(x)))
            err = float(np.max(np.abs(got - ref)) / scale)
            emit(f"accuracy_{variant}_N{n}", 0.0, f"max_rel_err={err:.2e}")
        for name, fn in (
            ("kernel_fused", lambda v: fft_kernel(v, interpret=True)),
            ("kernel_staged", lambda v: fft_staged(v, interpret=True)),
        ):
            got = np.asarray(fn(jnp.asarray(x)))
            err = float(np.max(np.abs(got - ref)) / scale)
            emit(f"accuracy_{name}_N{n}", 0.0, f"max_rel_err={err:.2e}")


if __name__ == "__main__":
    run()
