"""MRI reconstruction benchmark: CG-SENSE latency + the PR-10 gates.

Three panels in one JSON report:

  * recon      — CG-SENSE wall time per iteration (the solve is a host
                 loop by design: each iteration resolves through
                 ``repro.plan`` and emits its residual), plus the NRMSE
                 of CG vs zero-filled at R=2 and R=4 — CG must beat the
                 baseline by the gated margin at both accelerations;
  * moco       — the motion-compensated model: NRMSE of motion-blind
                 CG-SENSE vs Batchelor moco CG on two-shot corrupted
                 data (the gate: modelling motion must help);
  * plan_cache — under ``xfft.config(mode="measure")`` the FIRST recon
                 of a problem key tunes (MEASURE sweeps run); the second
                 recon of the same key must perform ZERO sweeps and
                 resolve every transform as a cache hit — the event
                 stream is the proof.

  PYTHONPATH=src python benchmarks/mri_bench.py --size 64
  PYTHONPATH=src python -m benchmarks.run mri
"""

from __future__ import annotations

import argparse
import json
import sys
import tempfile
import time

import jax
import numpy as np

import repro.xfft as xfft
from repro import mri, obs

try:  # python -m benchmarks.mri_bench (repo root on sys.path)
    from benchmarks.common import emit
except ImportError:  # python benchmarks/mri_bench.py (script dir on path)
    from common import emit

COILS = 4
ITERS = 10


def _median_us(fn, warmup: int = 1, repeats: int = 5) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn())
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def _problem(n: int, accel: int, calib: int = 16):
    x = np.asarray(mri.shepp_logan(n))
    smaps = np.asarray(mri.birdcage_maps(COILS, n))
    mask = np.asarray(mri.uniform_mask((n, n), accel, calib=calib))
    k = np.asarray(mri.sense_forward(x, smaps, mask))
    return x, smaps, mask, k


def bench_recon(n: int) -> dict:
    out = {"coils": COILS, "iters": ITERS}
    for accel, margin in ((2, 0.5), (4, 0.7)):
        x, smaps, mask, k = _problem(n, accel)
        zf = mri.nrmse(mri.recon_zero_filled(k, smaps, mask), x)
        cg = mri.nrmse(
            mri.recon_cg_sense(k, smaps, mask, iters=ITERS), x
        )
        us = _median_us(
            lambda: mri.recon_cg_sense(k, smaps, mask, iters=ITERS)
        )
        emit(f"mri/recon/{n}R{accel}", us / ITERS, f"nrmse={cg:.4f} zf={zf:.4f}")
        out[f"R{accel}"] = {
            "accel_realised": round(mri.acceleration(mask), 2),
            "us_per_iter": round(us / ITERS, 2),
            "nrmse_zero_filled": round(zf, 5),
            "nrmse_cg": round(cg, 5),
            "gate_margin": margin,
            "cg_beats_zf": bool(cg < margin * zf),
        }
    return out


def bench_moco(n: int) -> dict:
    x, smaps, mask, _ = _problem(n, 2)
    masks = mri.shot_masks(mask, 2)
    shifts = np.array([[0.0, 0.0], [3.0, -2.0]], np.float32)
    k = np.asarray(mri.moco_forward(x, smaps, masks, shifts))
    blind = mri.nrmse(mri.recon_cg_sense(k, smaps, mask, iters=8), x)
    moco = mri.nrmse(mri.recon_cg_moco(k, smaps, masks, shifts, iters=8), x)
    us = _median_us(
        lambda: mri.recon_cg_moco(k, smaps, masks, shifts, iters=8),
        repeats=3,
    )
    emit(f"mri/moco/{n}", us / 8, f"moco={moco:.4f} blind={blind:.4f}")
    return {
        "shots": 2,
        "us_per_iter": round(us / 8, 2),
        "nrmse_motion_blind": round(blind, 5),
        "nrmse_moco": round(moco, 5),
        "moco_beats_blind": bool(moco < 0.5 * blind),
    }


def bench_plan_cache(n: int) -> dict:
    """MEASURE-mode warm-up accounting: recon #1 tunes, recon #2 rides
    the plan cache — zero sweeps, 100% resolve hits."""
    x, smaps, mask, k = _problem(n, 2)
    # a scratch cache_dir isolates this panel's wisdom from the process
    # default, so the warm-up really does tune from cold
    with tempfile.TemporaryDirectory() as scratch:
        with xfft.config(mode="measure", cache_dir=scratch):
            with obs.capture() as first:
                mri.recon_cg_sense(k, smaps, mask, iters=ITERS)
            with obs.capture() as second:
                mri.recon_cg_sense(k, smaps, mask, iters=ITERS)
    warm_sweeps = len(first.select("plan.measure"))
    second_sweeps = len(second.select("plan.measure"))
    outcomes = [e["outcome"] for e in second.select("plan.resolve")]
    hits = outcomes.count("hit")
    emit(f"mri/plan_cache/{n}", 0.0,
         f"warm_sweeps={warm_sweeps} second_sweeps={second_sweeps}")
    return {
        "warmup_measure_sweeps": warm_sweeps,
        "second_recon_measure_sweeps": second_sweeps,
        "second_recon_resolutions": len(outcomes),
        "second_recon_hits": hits,
        "hit_rate": round(hits / max(len(outcomes), 1), 3),
    }


def run() -> None:
    """benchmarks.run entry point: small sweep, report to BENCH_mri.json."""
    main(["--size", "64", "--out", "/tmp/BENCH_mri.json"])


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--size", type=int, default=64,
                    help="frame size N (pow2; problems are NxN)")
    ap.add_argument("--out", default=None, help="write the JSON report here")
    args = ap.parse_args(argv)
    n = args.size
    report = {
        "backend": jax.default_backend(),
        "size": n,
        "recon": bench_recon(n),
        "moco": bench_moco(n),
        "plan_cache": bench_plan_cache(n),
    }
    # The gates that make "ok" meaningful: CG beats zero-filled at both
    # accelerations, motion modelling beats motion blindness, and the
    # second recon of a warm key re-decides nothing.
    report["ok"] = bool(
        report["recon"]["R2"]["cg_beats_zf"]
        and report["recon"]["R4"]["cg_beats_zf"]
        and report["moco"]["moco_beats_blind"]
        and report["plan_cache"]["warmup_measure_sweeps"] > 0
        and report["plan_cache"]["second_recon_measure_sweeps"] == 0
        and report["plan_cache"]["hit_rate"] == 1.0
    )
    text = json.dumps(report, indent=2)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
