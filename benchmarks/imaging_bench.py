"""Imaging-subsystem benchmark: the PR-4 operator set as one JSON report.

Three panels, all numbers median wall time on the current backend:

  * psd        — ``fft2_psd`` vs plain ``fft2``: the cost of simultaneous
                 edge-artifact removal (should be a small constant factor:
                 two extra 1D border FFTs), plus the measured cross-energy
                 suppression on a ramp+texture frame;
  * register   — whole-pixel and subpixel phase correlation per frame
                 pair (batched leading axes amortise the transforms);
  * oaconv     — overlap-save ``oaconvolve2`` (planner-picked tile) vs
                 the single-transform ``fftconv2`` on a frame + kernel
                 whose padded one-shot transform is much larger than any
                 VMEM-sized tile, with the numeric max-error between the
                 two paths (gate: fp32 agreement).

  PYTHONPATH=src python benchmarks/imaging_bench.py --size 512
  PYTHONPATH=src python -m benchmarks.run imaging
"""

from __future__ import annotations

import argparse
import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.imaging import (
    apply_shift,
    band_limited_frame,
    fft2_psd,
    fftconv2,
    oaconvolve2,
    register_phase_correlation,
)
from repro.kernels.ops import fft2_working_set, vmem_budget_bytes
from repro.plan.api import resolve_call
import repro.xfft as xfft

try:  # python -m benchmarks.imaging_bench (repo root on sys.path)
    from benchmarks.common import emit, time_fn
except ImportError:  # python benchmarks/imaging_bench.py (script dir on path)
    from common import emit, time_fn


def _ramp_texture(n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    i, j = np.mgrid[0:n, 0:n]
    return (0.05 * i + 0.03 * j + 0.2 * rng.standard_normal((n, n))).astype(
        np.float32
    )


def _cross_energy(spectrum: np.ndarray) -> float:
    power = np.abs(spectrum) ** 2
    total = power.sum() - power[0, 0]
    return float((power[0, 1:].sum() + power[1:, 0].sum()) / total)


def bench_psd(n: int) -> dict:
    x = jnp.asarray(_ramp_texture(n))
    us_plain = time_fn(jax.jit(xfft.fft2), x.astype(jnp.complex64))
    us_psd = time_fn(jax.jit(fft2_psd), x)
    plain = _cross_energy(np.fft.fft2(np.asarray(x)))
    psd = _cross_energy(np.asarray(fft2_psd(x)))
    emit(f"imaging/psd/{n}", us_psd, f"plain_fft2={us_plain:.2f}us")
    return {
        "us_fft2": round(us_plain, 2),
        "us_fft2_psd": round(us_psd, 2),
        "overhead": round(us_psd / max(us_plain, 1e-9), 3),
        "cross_energy_plain": plain,
        "cross_energy_psd": psd,
        "cross_suppression": round(plain / max(psd, 1e-12), 1),
    }


def bench_register(n: int, batch: int = 4) -> dict:
    ref = band_limited_frame(n, seed=1)
    refs = jnp.asarray(np.broadcast_to(ref, (batch, n, n)))
    movs = apply_shift(refs, jnp.asarray([[3.0, -2.0]] * batch))
    whole = time_fn(jax.jit(register_phase_correlation), refs, movs)
    fine = time_fn(
        jax.jit(lambda a, b: register_phase_correlation(a, b, upsample_factor=10)),
        refs, movs,
    )
    emit(f"imaging/register/{n}x{batch}", whole, f"subpixel={fine:.2f}us")
    return {
        "batch": batch,
        "us_whole_pixel": round(whole, 2),
        "us_subpixel_x10": round(fine, 2),
    }


def bench_oaconv(n: int, k: int = 17) -> dict:
    rng = np.random.default_rng(2)
    image = jnp.asarray(rng.standard_normal((n, n)).astype(np.float32))
    kernel = jnp.asarray(rng.standard_normal((k, k)).astype(np.float32))
    plan = resolve_call("oaconv2d", (n, n, k, k), dtype="float32")
    tiled = time_fn(jax.jit(lambda a, b: oaconvolve2(a, b, tile=plan.tile)),
                    image, kernel)
    oneshot = time_fn(jax.jit(lambda a, b: fftconv2(a, b, mode="same")),
                      image, kernel)
    err = float(
        jnp.max(jnp.abs(oaconvolve2(image, kernel, tile=plan.tile)
                        - fftconv2(image, kernel, mode="same")))
    )
    scale = float(jnp.max(jnp.abs(fftconv2(image, kernel, mode="same"))))
    emit(f"imaging/oaconv/{n}k{k}", tiled,
         f"oneshot={oneshot:.2f}us tile={plan.tile}")
    return {
        "kernel": k,
        "tile": list(plan.tile),
        # the planner's tile must sit inside the fused kernels' census
        "tile_working_set_bytes": fft2_working_set(*plan.tile, real=True),
        "vmem_budget_bytes": vmem_budget_bytes(),
        "us_oaconvolve2": round(tiled, 2),
        "us_fftconv2": round(oneshot, 2),
        "max_abs_err": err,
        "rel_err": err / max(scale, 1e-9),
    }


def run() -> None:
    """benchmarks.run entry point: small sweep, report to BENCH_imaging.json."""
    main(["--size", "256", "--out", "/tmp/BENCH_imaging.json"])


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--size", type=int, default=512,
                    help="frame size N (frames are NxN)")
    ap.add_argument("--out", default=None, help="write the JSON report here")
    args = ap.parse_args(argv)
    n = args.size
    report = {
        "backend": jax.default_backend(),
        "size": n,
        "psd": bench_psd(n),
        "register": bench_register(min(n, 256)),
        "oaconv": bench_oaconv(n),
    }
    # The gates that make "ok" meaningful: edge artifact actually removed,
    # and the tiled path numerically agrees with the one-shot transform.
    report["ok"] = bool(
        report["psd"]["cross_suppression"] >= 20.0
        and report["oaconv"]["rel_err"] <= 1e-3
        and report["oaconv"]["tile_working_set_bytes"]
        <= report["oaconv"]["vmem_budget_bytes"]
    )
    text = json.dumps(report, indent=2)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
