"""Paper Table 1 + Fig. 2: 2D FFT hardware-resource counts, proposed vs
traditional, and the area-reduction factor α2D = 1/log2 N (eq. 5).

Counts are *verified against the implementation*: the looped engine's
routing tables instantiate exactly N/2 butterfly positions per stage, and
each butterfly consumes 1 complex multiplier + 2 complex adders."""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core.fft1d import butterfly_counts, fft_routing_tables


def run():
    print("# Table 1: 2D FFT resources (proposed uses 2 x 1D engines)")
    print("# N, BU_prop, BU_trad, mult_prop, mult_trad, add_prop, add_trad, alpha2D")
    for n in (8, 16, 32, 64, 128, 256, 512, 1024):
        prop = butterfly_counts(n, proposed=True)
        trad = butterfly_counts(n, proposed=False)
        # two 1D engines per the 2D processor (paper eq. 3-4)
        bu_p, bu_t = 2 * prop["butterfly_units"], 2 * trad["butterfly_units"]
        alpha = bu_p / bu_t
        assert abs(alpha - 1 / np.log2(n)) < 1e-12  # eq. 5
        # verify against the actual routing tables
        idx_a, _, tw, _ = fft_routing_tables(n)
        assert idx_a.shape == (int(np.log2(n)), n // 2)
        emit(
            f"table1_2dfft_N{n}",
            0.0,
            f"BU {bu_p} vs {bu_t}; mult {bu_p} vs {bu_t}; "
            f"add {2*bu_p} vs {2*bu_t}; alpha2D={alpha:.4f}",
        )
    # the paper's 8x8 headline: proposed N=8 -> 16 BUs vs 48
    prop8 = 2 * butterfly_counts(8, True)["butterfly_units"]
    trad8 = 2 * butterfly_counts(8, False)["butterfly_units"]
    emit("table1_paper_8x8", 0.0, f"proposed {prop8} BU vs traditional {trad8} BU (1/3)")


if __name__ == "__main__":
    run()
