"""Resilience overhead + failover acceptance: BENCH_resilience.json.

Two gates, both about trusting the new ``repro.resilience`` layer:

1. **Overhead, chaos disabled** — the resilience seams (fault-hook
   contextvar reads, breaker fast paths, the ladder wrapper) ride on
   every transform call. The cached hot path with NO FaultPlan in scope
   (the production default) must stay within ``--gate-pct`` (default 3%)
   of the same loop under an armed-but-never-matching FaultPlan — i.e.
   the fully-exercised consultation path. Reps are interleaved with
   alternating order so clock drift and position bias hit both equally.

2. **Failover flow, proven by events** — with a FaultPlan injecting a
   deterministic failure into the first-choice engine, ``xfft.fft2``
   must return numpy-parity output, emit ``resilience.failover`` naming
   the quarantined engine, serve the next call from the fallback
   (``plan.resolve`` outcome ``"quarantined"``, no second injection),
   and close the breaker after cooldown via a successful half-open
   probe — all asserted from the obs event stream. The timed failover
   call reports how much a one-rung degrade costs over the healthy path.

  PYTHONPATH=src python benchmarks/resilience_bench.py --size 256
  PYTHONPATH=src python -m benchmarks.run resilience
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.xfft as xfft
from repro import obs
from repro.plan import resolve_call
from repro.resilience import FaultPlan, FaultSpec, configure, reset

try:  # python -m benchmarks.resilience_bench (repo root on sys.path)
    from benchmarks.common import emit
except ImportError:  # python benchmarks/resilience_bench.py
    from common import emit


def _frame(n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        (rng.standard_normal((n, n)) + 1j * rng.standard_normal((n, n)))
        .astype(np.complex64)
    )


def _hot_loop_us(x, iters: int) -> float:
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(xfft.fft2(x))
    return (time.perf_counter() - t0) * 1e6 / iters


def bench_overhead(n: int, iters: int, reps: int) -> dict:
    """Cached hot loop: chaos off (production) vs armed-no-match FaultPlan."""
    x = _frame(n)
    jax.block_until_ready(xfft.fft2(x))  # plan cached, kernels compiled
    # Armed plan whose match can never hit: every seam consultation walks
    # the full spec-matching path and rejects — the worst in-scope cost
    # short of actually firing.
    armed = FaultPlan(
        FaultSpec("engine.apply", match={"engine": "no_such_engine"}),
    )
    disabled, in_scope = [], []
    for rep in range(reps):
        first_armed = bool(rep % 2)
        if first_armed:
            with xfft.config(faults=armed):
                in_scope.append(_hot_loop_us(x, iters))
            disabled.append(_hot_loop_us(x, iters))
        else:
            disabled.append(_hot_loop_us(x, iters))
            with xfft.config(faults=armed):
                in_scope.append(_hot_loop_us(x, iters))
    disabled.sort()
    in_scope.sort()
    base_us = disabled[len(disabled) // 2]
    armed_us = in_scope[len(in_scope) // 2]
    return {
        "size": n,
        "iters": iters,
        "reps": reps,
        "disabled_us": round(base_us, 2),
        "armed_no_match_us": round(armed_us, 2),
        "overhead_pct": round((armed_us - base_us) / base_us * 100.0, 3),
    }


def bench_failover(n: int) -> dict:
    """The PR's acceptance flow, judged by the event stream, with timing."""
    clock = [0.0]
    configure(cooldown_s=30.0, clock=lambda: clock[0])
    try:
        x = _frame(n, seed=1)
        first = resolve_call("fft2d", (n, n)).variant
        reset()
        want = np.fft.fft2(np.asarray(x))
        jax.block_until_ready(xfft.fft2(x))  # compile the healthy path
        healthy_us = _hot_loop_us(x, 5)
        # times=2: one unmeasured failover to compile the fallback rung,
        # then one timed failover on warm code.
        plan = FaultPlan(
            FaultSpec(
                "engine.apply", mode="error", match={"engine": first}, times=2
            )
        )
        with obs.capture() as trace, xfft.config(faults=plan):
            y = xfft.fft2(x)                       # failover 1 (compiles)
            np.testing.assert_allclose(np.asarray(y), want, rtol=1e-3, atol=1e-3)
            reset()                                # re-admit the engine...
            t0 = time.perf_counter()
            y = jax.block_until_ready(xfft.fft2(x))  # failover 2 (timed)
            failover_us = (time.perf_counter() - t0) * 1e6
            np.testing.assert_allclose(np.asarray(y), want, rtol=1e-3, atol=1e-3)
            xfft.fft2(x)                           # served from fallback
            clock[0] += 31.0                       # cooldown passes
            xfft.fft2(x)                           # half-open probe: closes
        failovers = trace.select("resilience.failover")
        outcomes = [e["outcome"] for e in trace.select("plan.resolve")]
        states = [e["state"] for e in trace.select("resilience.breaker")]
        ok = (
            len(trace.select("resilience.fault")) == 2
            and len(failovers) == 2
            and all(e["engine"] == first and e["quarantined"] for e in failovers)
            and outcomes.count("quarantined") >= 1
            and states.count("open") == 2
            and states[-2:] == ["half_open", "closed"]
        )
        return {
            "size": n,
            "first_choice": first,
            "fallback": failovers[0]["next"] if failovers else None,
            "healthy_us": round(healthy_us, 2),
            "failover_us": round(failover_us, 2),
            "failover_overhead_pct": round(
                (failover_us - healthy_us) / healthy_us * 100.0, 1
            ),
            "resolve_outcomes": outcomes,
            "breaker_states": states,
            "ok": ok,
        }
    finally:
        reset()
        configure(clock=time.monotonic)


def run() -> None:
    """benchmarks.run entry point: default sweep, BENCH_resilience.json."""
    main(["--out", "/tmp/BENCH_resilience.json"])


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--size", type=int, default=256,
                    help="frame size N for the overhead loop (NxN)")
    ap.add_argument("--failover-size", type=int, default=64,
                    help="frame size for the failover acceptance flow")
    ap.add_argument("--iters", type=int, default=30,
                    help="hot-loop calls per rep")
    ap.add_argument("--reps", type=int, default=7,
                    help="interleaved disabled/armed reps (median)")
    ap.add_argument("--gate-pct", type=float, default=3.0,
                    help="max tolerated seam overhead (chaos off), percent")
    ap.add_argument("--out", default=None, help="write the JSON report here")
    args = ap.parse_args(argv)

    overhead = bench_overhead(args.size, args.iters, args.reps)
    failover = bench_failover(args.failover_size)
    overhead_ok = overhead["overhead_pct"] < args.gate_pct
    report = {
        "backend": jax.default_backend(),
        "gate_pct": args.gate_pct,
        "overhead": overhead,
        "overhead_ok": overhead_ok,
        "failover": failover,
        "failover_ok": failover["ok"],
        "ok": overhead_ok and failover["ok"],
    }
    emit(
        f"resilience_bench/hot_loop/{args.size}", overhead["disabled_us"],
        f"overhead_pct={overhead['overhead_pct']}",
    )
    emit(
        f"resilience_bench/failover/{args.failover_size}",
        failover["failover_us"],
        f"healthy_us={failover['healthy_us']}",
    )
    text = json.dumps(report, indent=2)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
