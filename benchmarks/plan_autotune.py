"""Planner benchmark: variant="auto" vs every fixed schedule, plus cache reuse.

For each frame size N the script times ``fft2`` under each fixed variant,
MEASURE-tunes a plan for the same problem through a file-backed cache, and
times ``variant="auto"`` (which resolves through that cache). The JSON
report records the chosen plans, per-variant timings, speedups, and the
cache hit/miss counters — on a second run with the same ``--cache`` file
every plan is a hit and nothing re-tunes.

  PYTHONPATH=src python benchmarks/plan_autotune.py --sizes 64,128
  PYTHONPATH=src python benchmarks/plan_autotune.py \
      --sizes 64,128,256,512,1024,2048,4096 --out /tmp/plan_report.json
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

import repro.xfft as xfft
from repro.plan import plan_fft, problem_key, variant_candidates

try:  # python -m benchmarks.plan_autotune (repo root on sys.path)
    from benchmarks.common import time_fn
except ImportError:  # python benchmarks/plan_autotune.py (script dir on sys.path)
    from common import time_fn


def _iters_for(n: int) -> int:
    """Fewer timing reps for big frames so the 4096 sweep stays minutes."""
    return max(3, 12 - int(np.log2(n)))


def bench_size(n: int, cache, mode: str) -> dict:
    rng = np.random.default_rng(0)
    x = jnp.asarray(
        (rng.standard_normal((n, n)) + 1j * rng.standard_normal((n, n))).astype(
            np.complex64
        )
    )
    iters = _iters_for(n)

    fixed_us = {}
    # The candidate set comes from the engine registry, capability-filtered
    # for this very problem — new registrations join the sweep automatically.
    for v in variant_candidates(problem_key("fft2d", (n, n))):
        # A scoped config override pins the engine (applied at trace time).
        def run(arr, _v=v):
            with xfft.config(variant=_v):
                return xfft.fft2(arr)

        fixed_us[v] = time_fn(jax.jit(run), x, warmup=1, iters=iters)

    timings = {}
    plan = plan_fft("fft2d", (n, n), mode=mode, cache=cache,
                    measure_iters=iters, timings_out=timings)

    # a bare xfft call resolves through the (now warm) cache inside the trace.
    auto_fn = jax.jit(lambda v: xfft.fft2(v))
    auto_us = time_fn(auto_fn, x, warmup=1, iters=iters)

    worst = max(fixed_us.values())
    best = min(fixed_us.values())
    entry = {
        "size": n,
        "plan": plan.to_dict(),
        "fixed_us": {k: round(us, 2) for k, us in fixed_us.items()},
        "auto_us": round(auto_us, 2),
        "tune_timings_us": {k: round(us, 2) for k, us in timings.items()},
        "speedup_vs_worst_fixed": round(worst / auto_us, 3),
        "speedup_vs_best_fixed": round(best / auto_us, 3),
        "auto_not_slower_than_worst": bool(auto_us <= worst),
        "auto_matches_best_variant": plan.variant == min(fixed_us, key=fixed_us.get),
    }
    return entry


def run() -> None:
    """benchmarks.run entry point: a small sweep with the shared cache file."""
    main(["--sizes", "64,128,256"])


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sizes", default="64,128,256,512,1024",
                    help="comma-separated frame sizes N (frames are NxN); "
                         "the full paper sweep is 64..4096")
    ap.add_argument("--mode", choices=["estimate", "measure"], default="measure")
    ap.add_argument("--cache", default="/tmp/repro_fft_plans.json",
                    help="plan cache file; rerun with the same file to see "
                         "pure cache hits (no re-tune)")
    ap.add_argument("--out", default=None, help="also write the report here")
    args = ap.parse_args(argv)

    sizes = [int(s) for s in args.sizes.split(",") if s]
    # Point the process-wide default cache at the same file so the
    # variant="auto" resolution inside fft2's trace sees the MEASURE plans
    # tuned below (resolve() consults default_cache()).
    from repro.plan.cache import CACHE_ENV_VAR, reset_default_cache

    os.environ[CACHE_ENV_VAR] = args.cache
    reset_default_cache()
    from repro.plan import default_cache

    cache = default_cache()
    assert cache.path == args.cache
    preloaded = len(cache)

    entries = [bench_size(n, cache, args.mode) for n in sizes]

    report = {
        "backend": jax.default_backend(),
        "mode": args.mode,
        "sizes": sizes,
        "cache_path": args.cache,
        "cache_entries_preloaded": preloaded,
        "cache_hits": cache.hits,
        "cache_misses": cache.misses,
        "retuned": cache.misses,  # 0 on a warm second run
        "entries": entries,
        "ok": all(e["auto_not_slower_than_worst"] for e in entries),
    }
    text = json.dumps(report, indent=2)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
