"""Radix + realness benchmark: the PR-2 hot-path matrix as one JSON report.

For each frame size N the script times 2D transforms along two axes of the
optimization space:

  * radix   — radix-2 Stockham vs radix-4 Stockham (half the stages and
              twiddle transcendentals);
  * realness — complex ``fft2`` vs two-for-one real ``rfft2`` (half the
              arithmetic and HBM bytes on the real frames every paper
              workload feeds the engine).

Each cell reports median wall time plus the *modeled* HBM traffic of the
equivalent fused kernel (``repro.kernels.ops.hbm_traffic_model``), so the
report tracks both what we measure today (CPU/interpret in CI) and what
the memory system will see on TPU. The acceptance gate of ISSUE 2 —
``rfft2`` ≥ 1.5× faster than complex ``fft2`` in the same variant class —
is computed per size in ``speedup_real_vs_complex``.

  PYTHONPATH=src python benchmarks/fft_bench.py --sizes 256,512,1024
  PYTHONPATH=src python -m benchmarks.run fft
"""

from __future__ import annotations

import argparse
import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

import repro.xfft as xfft
from repro.kernels.ops import hbm_traffic_model

try:  # python -m benchmarks.fft_bench (repo root on sys.path)
    from benchmarks.common import emit, time_fn
except ImportError:  # python benchmarks/fft_bench.py (script dir on sys.path)
    from common import emit, time_fn


def _cell(transform, variant):
    """One benchmark cell: the xfft entry point under a scoped config
    override (the post-ISSUE-3 way to pin an engine — no variant kwargs)."""

    def run(x):
        with xfft.config(variant=variant):
            return transform(x)

    return run


#: (label, transform, radix, real) — the 2×2 radix×realness matrix.
_CELLS = (
    ("fft2/radix2", _cell(xfft.fft2, "stockham"), 2, False),
    ("fft2/radix4", _cell(xfft.fft2, "radix4"), 4, False),
    ("rfft2/radix2", _cell(xfft.rfft2, "stockham"), 2, True),
    ("rfft2/radix4", _cell(xfft.rfft2, "radix4"), 4, True),
)


def _iters_for(n: int) -> int:
    """Fewer timing reps for big frames so the 2048 sweep stays minutes —
    but never so few that one scheduler hiccup owns the median."""
    return max(5, 12 - int(np.log2(n)))


def bench_size(n: int) -> dict:
    rng = np.random.default_rng(0)
    xr = jnp.asarray(rng.standard_normal((n, n)).astype(np.float32))
    xc = jnp.asarray(
        (rng.standard_normal((n, n)) + 1j * rng.standard_normal((n, n))).astype(
            np.complex64
        )
    )
    iters = _iters_for(n)
    cells = {}
    for label, transform, radix, real in _CELLS:
        fn = jax.jit(transform)
        us = time_fn(fn, xr if real else xc, warmup=1, iters=iters)
        # Modeled HBM bytes of the equivalent fused kernel: row pass (n rows
        # of length n) + column pass, one fused round trip each.
        bytes_fused = 2 * hbm_traffic_model(n, n, True, radix=radix, real=real)
        bytes_staged = 2 * hbm_traffic_model(n, n, False, radix=radix, real=real)
        cells[label] = {
            "us_per_call": round(us, 2),
            "modeled_hbm_bytes_fused": bytes_fused,
            "modeled_hbm_bytes_staged": bytes_staged,
        }
        emit(f"fft_bench/{label}/{n}", us, f"fused_bytes={bytes_fused}")
    r2 = cells["fft2/radix2"]["us_per_call"] / cells["rfft2/radix2"]["us_per_call"]
    r4 = cells["fft2/radix4"]["us_per_call"] / cells["rfft2/radix4"]["us_per_call"]
    return {
        "size": n,
        "cells": cells,
        # real-vs-complex within the same variant class (the ISSUE 2 gate)
        "speedup_real_vs_complex": {"radix2": round(r2, 3), "radix4": round(r4, 3)},
        "speedup_radix4_vs_radix2": round(
            cells["fft2/radix2"]["us_per_call"] / cells["fft2/radix4"]["us_per_call"], 3
        ),
        "hbm_bytes_real_over_complex": round(
            cells["rfft2/radix2"]["modeled_hbm_bytes_fused"]
            / cells["fft2/radix2"]["modeled_hbm_bytes_fused"],
            3,
        ),
    }


def run() -> None:
    """benchmarks.run entry point: small sweep, report to BENCH_fft.json."""
    main(["--sizes", "256,512", "--out", "/tmp/BENCH_fft.json"])


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sizes", default="256,512,1024,2048",
                    help="comma-separated frame sizes N (frames are NxN)")
    ap.add_argument("--out", default=None, help="write the JSON report here")
    args = ap.parse_args(argv)

    sizes = [int(s) for s in args.sizes.split(",") if s]
    entries = [bench_size(n) for n in sizes]
    # Gate on every size >= 1024 (the ISSUE 2 criterion); a small sweep
    # gates on its largest size so "ok" is never vacuously true.
    gated = [e for e in entries if e["size"] >= 1024] or \
        [max(entries, key=lambda e: e["size"])]
    report = {
        "backend": jax.default_backend(),
        "sizes": sizes,
        "entries": entries,
        "gated_sizes": [e["size"] for e in gated],
        "ok": all(e["speedup_real_vs_complex"]["radix2"] >= 1.5 for e in gated),
    }
    text = json.dumps(report, indent=2)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
