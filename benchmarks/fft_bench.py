"""Engine × realness benchmark: the hot-path matrix as one JSON report.

For each frame size N the script times 2D transforms for EVERY engine in
the ``repro.engines`` registry that can serve the problem — no hardcoded
variant list: a newly registered engine (a plugin, a new radix, a new
backend) shows up in ``BENCH_fft.json`` automatically. Each engine gets a
complex ``fft2`` cell and (when it serves ``rfft2d``) a two-for-one real
``rfft2`` cell, timed under a scoped ``xfft.config(variant=..., precision
=...)`` override; the ``reference_x64`` engine is swept at double
precision.

Each cell reports median wall time plus the *modeled* HBM traffic of the
equivalent fused kernel (``repro.kernels.ops.hbm_traffic_model``), so the
report tracks both what we measure today (CPU/interpret in CI) and what
the memory system will see on TPU. The acceptance gate of ISSUE 2 —
two-for-one real input ≥ 1.5× faster than the complex transform in the
bandwidth-lean radix-2 engine class (selected from the registry by
capability metadata, not by name) — is ``gate_speedup`` per size.

  PYTHONPATH=src python benchmarks/fft_bench.py --sizes 256,512,1024
  PYTHONPATH=src python -m benchmarks.run fft
"""

from __future__ import annotations

import argparse
import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

import repro.xfft as xfft
from repro.engines import iter_engines
from repro.kernels.ops import hbm_traffic_model
from repro.plan import problem_key

try:  # python -m benchmarks.fft_bench (repo root on sys.path)
    from benchmarks.common import emit, time_fn
except ImportError:  # python benchmarks/fft_bench.py (script dir on sys.path)
    from common import emit, time_fn


def _cell(transform, variant, precision):
    """One benchmark cell: the xfft entry point under a scoped config
    override (the post-ISSUE-3 way to pin an engine — no variant kwargs)."""

    def run(x):
        with xfft.config(variant=variant, precision=precision):
            return transform(x)

    return run


def _engine_cells(n: int):
    """(label, runner, spec, real, precision) cells from the live registry:
    every engine that can serve an (n, n) frame, complex and (when it can)
    real, at EVERY precision it declares — an engine spanning both tiers
    gets a row per tier (the double row tagged ``@f64``; a single-tier
    engine keeps the bare name)."""
    cells = []
    for spec in iter_engines():
        for precision in spec.precisions:
            tag = "@f64" if precision == "double" and len(spec.precisions) > 1 \
                else ""
            if "fft2d" in spec.kinds and spec.supports(
                problem_key("fft2d", (n, n), precision=precision)
            ):
                cells.append((f"fft2/{spec.name}{tag}",
                              _cell(xfft.fft2, spec.name, precision),
                              spec, False, precision))
            if "rfft2d" in spec.kinds and spec.supports(
                problem_key("rfft2d", (n, n), dtype="float32",
                            precision=precision)
            ):
                cells.append((f"rfft2/{spec.name}{tag}",
                              _cell(xfft.rfft2, spec.name, precision),
                              spec, True, precision))
    return cells


def _gate_engine():
    """The ISSUE-2 gate engine: the bandwidth-lean radix-2 schedule —
    chosen by capability metadata (lowest traffic factor among non-fused
    single-precision radix-2 engines serving both 2D kinds), never by a
    hardcoded name. With the seed registry this resolves to ``stockham``,
    exactly the class the pre-registry gate pinned, so the criterion did
    not weaken when the sweep generalized."""
    cands = [
        s for s in iter_engines(precision="single")
        if not s.fused and s.radix == 2
        and "fft2d" in s.kinds and "rfft2d" in s.kinds
    ]
    return min(cands, key=lambda s: s.cost.traffic_factor) if cands else None


def _iters_for(n: int) -> int:
    """Fewer timing reps for big frames so the 2048 sweep stays minutes —
    but never so few that one scheduler hiccup owns the median."""
    return max(5, 12 - int(np.log2(n)))


def bench_size(n: int) -> dict:
    from jax.experimental import enable_x64

    rng = np.random.default_rng(0)
    real64 = rng.standard_normal((n, n))
    cplx64 = rng.standard_normal((n, n)) + 1j * rng.standard_normal((n, n))
    xr = jnp.asarray(real64.astype(np.float32))
    xc = jnp.asarray(cplx64.astype(np.complex64))
    iters = _iters_for(n)
    cells = {}
    for label, runner, spec, real, precision in _engine_cells(n):
        fn = jax.jit(runner)
        if precision == "double":
            # Double cells must trace and move TRUE 64-bit inputs — and
            # that only survives the jit boundary inside enable_x64.
            with enable_x64():
                arg = jnp.asarray(real64 if real else cplx64)
                us = time_fn(fn, arg, warmup=1, iters=iters)
        else:
            us = time_fn(fn, xr if real else xc, warmup=1, iters=iters)
        # Modeled HBM bytes of the equivalent fused kernel: row pass (n rows
        # of length n) + column pass, one fused round trip each; double
        # precision moves twice the bytes per element.
        width = 2 if precision == "double" else 1
        bytes_fused = (
            2 * width * hbm_traffic_model(n, n, True, radix=spec.radix, real=real)
        )
        bytes_staged = (
            2 * width * hbm_traffic_model(n, n, False, radix=spec.radix, real=real)
        )
        cells[label] = {
            "us_per_call": round(us, 2),
            "engine": spec.name,
            "backend": spec.backend,
            "radix": spec.radix,
            "precision": precision,
            "modeled_hbm_bytes_fused": bytes_fused,
            "modeled_hbm_bytes_staged": bytes_staged,
        }
        emit(f"fft_bench/{label}/{n}", us, f"fused_bytes={bytes_fused}")
    # Real-vs-complex speedup per (engine, precision) row with both cells.
    speedups = {}
    for base in sorted({label.split("/", 1)[1] for label in cells}):
        c, r = cells.get(f"fft2/{base}"), cells.get(f"rfft2/{base}")
        if c and r:
            speedups[base] = round(c["us_per_call"] / r["us_per_call"], 3)
    gate_spec = _gate_engine()
    gate = speedups.get(gate_spec.name, 0.0) if gate_spec else 0.0
    real_cell = gate_spec and cells.get(f"rfft2/{gate_spec.name}")
    complex_cell = gate_spec and cells.get(f"fft2/{gate_spec.name}")
    hbm_ratio = (
        round(real_cell["modeled_hbm_bytes_fused"]
              / complex_cell["modeled_hbm_bytes_fused"], 3)
        if real_cell and complex_cell else None
    )
    return {
        "size": n,
        "cells": cells,
        "speedup_real_vs_complex": speedups,
        "gate_engine": gate_spec.name if gate_spec else None,
        "gate_speedup": gate,
        "hbm_bytes_real_over_complex": hbm_ratio,
    }


def run() -> None:
    """benchmarks.run entry point: small sweep, report to BENCH_fft.json."""
    main(["--sizes", "256,512", "--out", "/tmp/BENCH_fft.json"])


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sizes", default="256,512,1024,2048",
                    help="comma-separated frame sizes N (frames are NxN)")
    ap.add_argument("--out", default=None, help="write the JSON report here")
    args = ap.parse_args(argv)

    sizes = [int(s) for s in args.sizes.split(",") if s]
    entries = [bench_size(n) for n in sizes]
    # Gate on every size >= 1024 (the ISSUE 2 criterion); a small sweep
    # gates on its largest size so "ok" is never vacuously true.
    gated = [e for e in entries if e["size"] >= 1024] or \
        [max(entries, key=lambda e: e["size"])]
    report = {
        "backend": jax.default_backend(),
        "sizes": sizes,
        "engines_swept": [s.name for s in iter_engines()],
        "entries": entries,
        "gated_sizes": [e["size"] for e in gated],
        "ok": all(e["gate_speedup"] >= 1.5 for e in gated),
    }
    text = json.dumps(report, indent=2)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
