"""Benchmark harness — one module per paper table (+ throughput, accuracy).

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run table6     # one table

CSV rows: ``name,us_per_call,derived``.
"""

from __future__ import annotations

import sys

from benchmarks import accuracy, fft_bench, imaging_bench, mri_bench
from benchmarks import obs_bench, pencil_overlap, plan_autotune
from benchmarks import resilience_bench, serve_bench, table1_resources
from benchmarks import table2_resources, table5_utilization, table6_delay
from benchmarks import throughput

ALL = {
    "table1": table1_resources.run,
    "table2": table2_resources.run,
    "table5": table5_utilization.run,
    "table6": table6_delay.run,
    "throughput": throughput.run,
    "accuracy": accuracy.run,
    "pencil_overlap": pencil_overlap.run,
    "plan_autotune": plan_autotune.run,
    "fft": fft_bench.run,
    "imaging": imaging_bench.run,
    "mri": mri_bench.run,
    "obs": obs_bench.run,
    "resilience": resilience_bench.run,
    "serve": serve_bench.run,
}


def main() -> None:
    picks = sys.argv[1:] or list(ALL)
    print("name,us_per_call,derived")
    for name in picks:
        ALL[name]()


if __name__ == "__main__":
    main()
