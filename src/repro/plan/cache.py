"""Plan cache: in-memory map with versioned JSON on-disk persistence.

FFTW's wisdom files are the precedent: tuning is expensive (MEASURE jits
and times every candidate), so the result is remembered per problem key.
Keys embed :data:`repro.plan.plan.PLAN_SCHEMA_VERSION`, so bumping the
schema orphans stale entries instead of mis-deserialising them.

Every load is *accounted for*: :meth:`PlanCache.load` returns a
:class:`LoadReport` saying how many entries were kept and how many were
dropped per reason (stale schema prefix, malformed plan dict, key/value
mismatch), emits a ``plan.cache.load`` event, and bumps the matching
``repro.obs`` counters — a fleet process that warm-starts from shipped
wisdom can confirm through :func:`repro.xfft.report` that the file
actually loaded instead of silently tuning from scratch.
"""

from __future__ import annotations

import dataclasses
import json
import logging
import os
import tempfile
from typing import Dict, Optional, Tuple

from repro import obs
from repro.plan.plan import PLAN_SCHEMA_VERSION, FFTPlan, ProblemKey
from repro.resilience import faults as _faults
from repro.resilience.faults import InjectedFault

__all__ = ["LoadReport", "PlanCache", "default_cache", "reset_default_cache"]

#: Environment variable naming the on-disk cache file for the process-wide
#: default cache. Unset -> the default cache is memory-only.
CACHE_ENV_VAR = "REPRO_PLAN_CACHE"

_FILE_FORMAT = 1

_log = logging.getLogger("repro.plan.cache")


@dataclasses.dataclass(frozen=True)
class LoadReport:
    """Accounting for one :meth:`PlanCache.load`: kept vs dropped-by-reason.

    kept         — entries merged into the cache.
    stale_schema — dropped: cache-key version prefix != current schema.
    malformed    — dropped: plan dict failed to deserialise.
    key_mismatch — dropped: stored key and plan's own key disagree.
    file_error   — the whole file was unreadable (missing / not JSON);
                   ``None`` when the file parsed.
    """

    kept: int = 0
    stale_schema: int = 0
    malformed: int = 0
    key_mismatch: int = 0
    file_error: Optional[str] = None

    @property
    def dropped(self) -> int:
        return self.stale_schema + self.malformed + self.key_mismatch

    def __add__(self, other: "LoadReport") -> "LoadReport":
        return LoadReport(
            kept=self.kept + other.kept,
            stale_schema=self.stale_schema + other.stale_schema,
            malformed=self.malformed + other.malformed,
            key_mismatch=self.key_mismatch + other.key_mismatch,
            file_error=other.file_error or self.file_error,
        )

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class PlanCache:
    """Maps ``ProblemKey.cache_key()`` strings to :class:`FFTPlan`.

    ``path`` (optional) backs the cache with a JSON file: it is loaded at
    construction and rewritten atomically by :meth:`save`. Aggregate
    hit/miss counters plus per-key hit counts let benchmarks assert
    "second run re-tunes nothing" and let ``repro.xfft.report`` show
    which wisdom entries actually serve traffic; :attr:`load_report`
    accumulates the accounting of every :meth:`load`.
    """

    def __init__(self, path: Optional[str] = None, autoload: bool = True):
        self._plans: Dict[str, FFTPlan] = {}
        self.path = path
        self.hits = 0
        self.misses = 0
        self.key_hits: Dict[str, int] = {}
        self.load_report: Optional[LoadReport] = None
        #: Set when a save hit an unwritable path and the cache degraded
        #: to memory-only; holds the path that refused the write.
        self.readonly_path: Optional[str] = None
        #: Wisdom staleness accounting: the engine each loaded (artifact)
        #: entry arrived with, and how many consecutive times a live
        #: MEASURE re-tune disagreed with it. ``repro.serve.wisdom.export``
        #: drops entries whose losses pass its threshold — stale wisdom
        #: ages out of the artifact instead of shipping forever.
        self._artifact_variants: Dict[str, str] = {}
        self.stale_losses: Dict[str, int] = {}
        if path and autoload and os.path.exists(path):
            self.load(path)

    def __len__(self) -> int:
        return len(self._plans)

    def __contains__(self, key: ProblemKey) -> bool:
        return key.cache_key() in self._plans

    def get(self, key: ProblemKey) -> Optional[FFTPlan]:
        ck = key.cache_key()
        plan = self._plans.get(ck)
        if plan is None:
            self.misses += 1
        else:
            self.hits += 1
            self.key_hits[ck] = self.key_hits.get(ck, 0) + 1
        return plan

    def put(self, plan: FFTPlan) -> FFTPlan:
        ck = plan.key.cache_key()
        loaded = self._artifact_variants.get(ck)
        if loaded is not None and plan.mode == "measure":
            if plan.variant != loaded:
                # A live MEASURE re-tune beat the warm-started artifact
                # plan: one staleness loss against the entry.
                losses = self.stale_losses.get(ck, 0) + 1
                self.stale_losses[ck] = losses
                obs.emit(
                    "serve.wisdom.stale",
                    key=ck,
                    artifact_variant=loaded,
                    measured_variant=plan.variant,
                    losses=losses,
                )
                obs.count("serve.wisdom.stale")
            elif ck in self.stale_losses:
                # The artifact's choice was re-confirmed by a live sweep:
                # losses count CONSECUTIVE disagreements, so reset.
                del self.stale_losses[ck]
        self._plans[ck] = plan
        return plan

    def clear(self) -> None:
        self._plans.clear()
        self.key_hits.clear()
        self.hits = 0
        self.misses = 0
        self.load_report = None
        self._artifact_variants.clear()
        self.stale_losses.clear()

    def entries(self) -> Tuple[Tuple[str, FFTPlan], ...]:
        """(cache_key, plan) pairs, sorted by key — the introspection
        surface ``repro.xfft.report`` renders."""
        return tuple(sorted(self._plans.items()))

    def hit_count(self, cache_key: str) -> int:
        """How many :meth:`get` hits this entry has served."""
        return self.key_hits.get(cache_key, 0)

    # ------------------------------ persistence ------------------------------

    def save(
        self,
        path: Optional[str] = None,
        *,
        measured_only: bool = False,
        exclude: Tuple[str, ...] = (),
    ) -> Optional[str]:
        """Atomically write all plans to ``path`` (default: ``self.path``).

        ``measured_only=True`` writes only MEASURE-mode plans — the form a
        wisdom *artifact* ships in (``repro.serve.wisdom``): ESTIMATE
        entries cost nothing to recreate and would pin a heuristic guess
        over the receiving process's own estimator, so an exported
        artifact carries only the plans that were actually timed.
        ``exclude`` drops specific cache keys from the write — the
        staleness-aging hook ``repro.serve.wisdom.export`` uses to keep
        repeatedly-outvoted artifact entries out of the next artifact.

        The write goes to a temp file in the SAME directory (same
        filesystem, so the rename is atomic), is fsynced, then
        ``os.replace``d over the target — a killed process can leave a
        stray ``.tmp`` but never a truncated wisdom file, and concurrent
        writers each land a complete file (last writer wins).

        An unwritable path (read-only wisdom directory, permission loss
        at runtime) does NOT raise: the cache degrades to memory-only —
        ``self.path`` is cleared so no further saves are attempted, the
        original path is kept on :attr:`readonly_path`, and a
        ``plan.cache.readonly`` obs event records the degrade. Plans keep
        serving from memory; only persistence is lost. Returns the path
        written, or ``None`` after a degrade.
        """
        path = path or self.path
        if not path:
            raise ValueError("PlanCache.save needs a path (none configured)")
        plans = self._plans
        if measured_only:
            plans = {k: p for k, p in plans.items() if p.mode == "measure"}
        if exclude:
            dropped = frozenset(exclude)
            plans = {k: p for k, p in plans.items() if k not in dropped}
        payload = {
            "file_format": _FILE_FORMAT,
            "plan_schema_version": PLAN_SCHEMA_VERSION,
            "plans": {k: p.to_dict() for k, p in plans.items()},
        }
        try:
            _faults.maybe_fail("plan.cache.save", path=path)
            d = os.path.dirname(os.path.abspath(path))
            os.makedirs(d, exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
            try:
                with os.fdopen(fd, "w") as f:
                    json.dump(payload, f, indent=1, sort_keys=True)
                    f.flush()
                    os.fsync(f.fileno())
                os.replace(tmp, path)
            finally:
                if os.path.exists(tmp):
                    os.unlink(tmp)
        except (OSError, InjectedFault) as e:
            self.readonly_path = path
            if self.path == path:
                self.path = None  # memory-only from here on
            obs.emit(
                "plan.cache.readonly", path=path, error=str(e),
                entries=len(self._plans),
            )
            obs.count("plan.cache.readonly")
            _log.warning(
                "plan cache path %s is unwritable (%s); degrading to "
                "in-memory caching", path, e,
            )
            return None
        obs.emit("plan.cache.save", path=path, entries=len(plans))
        return path

    def load(self, path: Optional[str] = None) -> LoadReport:
        """Merge plans from ``path``; returns the kept/dropped accounting.

        Entries from other schema versions (key prefix mismatch),
        malformed entries and key/value disagreements are dropped — but
        *counted*, not silent: the :class:`LoadReport` is returned,
        accumulated on :attr:`load_report`, emitted as a
        ``plan.cache.load`` event and surfaced through the
        ``plan.cache.load.*`` obs counters.
        """
        path = path or self.path
        if not path:
            raise ValueError("PlanCache.load needs a path (none configured)")
        try:
            _faults.maybe_fail("plan.cache.load", path=path)
            with open(path) as f:
                payload = json.load(f)
        except (OSError, json.JSONDecodeError, InjectedFault) as e:
            return self._account_load(path, LoadReport(file_error=str(e)))
        prefix = f"v{PLAN_SCHEMA_VERSION}|"
        kept = stale = malformed = mismatch = 0
        for key, plan_dict in payload.get("plans", {}).items():
            if not key.startswith(prefix):
                stale += 1
                continue
            try:
                plan = FFTPlan.from_dict(plan_dict)
            except (KeyError, TypeError, ValueError):
                malformed += 1
                continue
            if plan.key.cache_key() != key:
                mismatch += 1
                continue  # key/value disagree — do not trust the entry
            self._plans[key] = plan
            self._artifact_variants[key] = plan.variant
            kept += 1
        report = LoadReport(
            kept=kept,
            stale_schema=stale,
            malformed=malformed,
            key_mismatch=mismatch,
        )
        return self._account_load(path, report)

    def _account_load(self, path: str, report: LoadReport) -> LoadReport:
        self.load_report = (
            report if self.load_report is None else self.load_report + report
        )
        obs.emit(
            "plan.cache.load",
            path=path,
            kept=report.kept,
            stale_schema=report.stale_schema,
            malformed=report.malformed,
            key_mismatch=report.key_mismatch,
            file_error=report.file_error,
        )
        obs.count("plan.cache.load.kept", report.kept)
        obs.count("plan.cache.load.stale_schema", report.stale_schema)
        obs.count("plan.cache.load.malformed", report.malformed)
        obs.count("plan.cache.load.key_mismatch", report.key_mismatch)
        if report.file_error is not None:
            obs.count("plan.cache.load.file_error")
        return report


_DEFAULT: Optional[PlanCache] = None


def default_cache() -> PlanCache:
    """Process-wide cache used by ``variant="auto"`` resolution.

    Backed by the file named in ``$REPRO_PLAN_CACHE`` when set, else
    memory-only. The first touch emits a ``plan.cache.attached`` event
    (path + entries kept from the wisdom file) and logs it, so a fleet
    process can confirm its shipped wisdom actually loaded — the env var
    is read exactly once per process, and this is the record of what it
    resolved to.
    """
    global _DEFAULT
    if _DEFAULT is None:
        path = os.environ.get(CACHE_ENV_VAR) or None
        _DEFAULT = PlanCache(path=path)
        obs.emit(
            "plan.cache.attached",
            path=path,
            entries=len(_DEFAULT),
            source=CACHE_ENV_VAR if path else "memory",
        )
        _log.info(
            "default plan cache attached: path=%s entries=%d", path, len(_DEFAULT)
        )
    return _DEFAULT


def reset_default_cache() -> None:
    """Drop the process-wide cache (tests; or after changing the env var)."""
    global _DEFAULT
    _DEFAULT = None
