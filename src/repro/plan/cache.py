"""Plan cache: in-memory map with versioned JSON on-disk persistence.

FFTW's wisdom files are the precedent: tuning is expensive (MEASURE jits
and times every candidate), so the result is remembered per problem key.
Keys embed :data:`repro.plan.plan.PLAN_SCHEMA_VERSION`, so bumping the
schema orphans stale entries instead of mis-deserialising them — load
simply drops keys whose version prefix doesn't match.
"""

from __future__ import annotations

import json
import os
import tempfile
from typing import Dict, Optional

from repro.plan.plan import PLAN_SCHEMA_VERSION, FFTPlan, ProblemKey

__all__ = ["PlanCache", "default_cache", "reset_default_cache"]

#: Environment variable naming the on-disk cache file for the process-wide
#: default cache. Unset -> the default cache is memory-only.
CACHE_ENV_VAR = "REPRO_PLAN_CACHE"

_FILE_FORMAT = 1


class PlanCache:
    """Maps ``ProblemKey.cache_key()`` strings to :class:`FFTPlan`.

    ``path`` (optional) backs the cache with a JSON file: it is loaded at
    construction and rewritten atomically by :meth:`save`. Hit/miss
    counters let benchmarks assert "second run re-tunes nothing".
    """

    def __init__(self, path: Optional[str] = None, autoload: bool = True):
        self._plans: Dict[str, FFTPlan] = {}
        self.path = path
        self.hits = 0
        self.misses = 0
        if path and autoload and os.path.exists(path):
            self.load(path)

    def __len__(self) -> int:
        return len(self._plans)

    def __contains__(self, key: ProblemKey) -> bool:
        return key.cache_key() in self._plans

    def get(self, key: ProblemKey) -> Optional[FFTPlan]:
        plan = self._plans.get(key.cache_key())
        if plan is None:
            self.misses += 1
        else:
            self.hits += 1
        return plan

    def put(self, plan: FFTPlan) -> FFTPlan:
        self._plans[plan.key.cache_key()] = plan
        return plan

    def clear(self) -> None:
        self._plans.clear()
        self.hits = 0
        self.misses = 0

    # ------------------------------ persistence ------------------------------

    def save(self, path: Optional[str] = None) -> str:
        """Atomically write all plans to ``path`` (default: ``self.path``)."""
        path = path or self.path
        if not path:
            raise ValueError("PlanCache.save needs a path (none configured)")
        payload = {
            "file_format": _FILE_FORMAT,
            "plan_schema_version": PLAN_SCHEMA_VERSION,
            "plans": {k: p.to_dict() for k, p in self._plans.items()},
        }
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        return path

    def load(self, path: Optional[str] = None) -> int:
        """Merge plans from ``path``; returns how many entries were kept.

        Entries from other schema versions (key prefix mismatch) and
        malformed entries are silently dropped — a cache is a cache.
        """
        path = path or self.path
        if not path:
            raise ValueError("PlanCache.load needs a path (none configured)")
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, json.JSONDecodeError):
            return 0
        prefix = f"v{PLAN_SCHEMA_VERSION}|"
        kept = 0
        for key, plan_dict in payload.get("plans", {}).items():
            if not key.startswith(prefix):
                continue
            try:
                plan = FFTPlan.from_dict(plan_dict)
            except (KeyError, TypeError, ValueError):
                continue
            if plan.key.cache_key() != key:
                continue  # key/value disagree — do not trust the entry
            self._plans[key] = plan
            kept += 1
        return kept


_DEFAULT: Optional[PlanCache] = None


def default_cache() -> PlanCache:
    """Process-wide cache used by ``variant="auto"`` resolution.

    Backed by the file named in ``$REPRO_PLAN_CACHE`` when set, else
    memory-only.
    """
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = PlanCache(path=os.environ.get(CACHE_ENV_VAR) or None)
    return _DEFAULT


def reset_default_cache() -> None:
    """Drop the process-wide cache (tests; or after changing the env var)."""
    global _DEFAULT
    _DEFAULT = None
