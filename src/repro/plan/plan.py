"""Plan objects: the software rendition of the paper's control unit.

The paper's 2D processor owes its area savings to a *control unit* that
schedules a small pool of butterfly units across stages, and a *RAM
controller* that sequences the two 1D engines through the ping-pong
buffers. In software the analogous decisions — which 1D schedule
(``looped`` / ``unrolled`` / ``stockham``), how far to unroll the
streaming scan, how many slabs to chunk the pencil corner-turn into —
are made *per problem*, keyed by backend, device kind, shape, dtype and
device count. An :class:`FFTPlan` freezes one such decision set; the
autotuner (``repro.plan.autotune``) produces plans and the cache
(``repro.plan.cache``) remembers them across calls and processes.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

#: Bumped whenever plan semantics change; embedded in every cache key so a
#: stale on-disk cache can never hand an old-format plan to new code.
#: v2: radix-4 + fused kernel variants, real-input (rfft) problem kinds and
#: the transform-direction key field — v1 wisdom tuned without these
#: candidates is stale by construction, so bumping forces a re-tune.
#: v3: norm and axes join the key (the ``repro.xfft`` front door plans whole
#: calls, scaling convention and transform axes included, through
#: ``resolve_call``) — v2 wisdom carries neither field, so it is orphaned.
#: v4: norm LEAVES the key again — the scaling convention is applied outside
#: the engine (``repro.xfft._scale``), so backward/ortho/forward share one
#: tuned entry and a service tuned under one convention serves all three.
#: v4 also adds the ``oaconv2d`` problem kind (overlap-save tiled 2D
#: convolution) and the plan ``tile`` field it resolves; v3 wisdom keyed
#: norm-per-entry is orphaned by the version prefix.
#: v5: engines became a registry (``repro.engines``) and the key gained the
#: capability constraints resolution runs under — the numeric ``precision``
#: ("single"/"double") and the scoped engine-``backends`` restriction — so
#: wisdom tuned for one engine population can never be served to an
#: incompatible one; v4 wisdom carries neither field and is orphaned.
PLAN_SCHEMA_VERSION = 5

#: Problem kinds the planner understands (r* = real-input two-for-one;
#: oaconv2d = overlap-save tiled 2D convolution, whose shape convention is
#: (H, W, KH, KW) — image dims then kernel dims — and whose plan carries
#: the FFT tile in ``FFTPlan.tile``).
KINDS = (
    "fft1d", "fft2d", "fft2d_stream", "fft2d_pencil", "rfft1d", "rfft2d",
    "oaconv2d",
)

#: Numeric precisions a ProblemKey may carry ("single" = the paper's
#: complex64 datapath, "double" = complex128 via an x64-capable engine).
#: ONE source of truth: ``repro.engines.registry.PRECISIONS`` — re-exported
#: here lazily (module ``__getattr__`` below) so key validation and engine
#: registration can never disagree on the domain.

#: Transform directions a ProblemKey may carry. Inverse transforms tune
#: separately: their conjugation wrapper and 1/N scaling shift the optimum.
DIRECTIONS = ("fwd", "inv")

#: Normalization conventions (scipy.fft names): where the 1/N lives. The
#: convention is NOT part of the plan key: every entry point applies the
#: norm as a scale outside the engine, so the schedule optimum cannot
#: depend on it and all three conventions share one tuned entry.
NORMS = ("backward", "ortho", "forward")

#: Single-precision dtype labels and their double-precision widenings —
#: ``ProblemKey.__post_init__`` maps a key's dtype through this whenever
#: ``precision == "double"``.
_WIDE_DTYPES = {"complex64": "complex128", "float32": "float64"}

#: Canonical transform axes per kind — the axes every entry point moves the
#: transform onto before keying (1D kinds transform the last axis, 2D kinds
#: the trailing two). A ProblemKey built without explicit axes gets these,
#: so pre-xfft call sites and the xfft front door share cache entries.
_CANONICAL_AXES = {
    "fft1d": (-1,),
    "rfft1d": (-1,),
    "fft2d": (-2, -1),
    "rfft2d": (-2, -1),
    "fft2d_stream": (-2, -1),
    "fft2d_pencil": (-2, -1),
    "oaconv2d": (-2, -1),
}


@dataclasses.dataclass(frozen=True)
class ProblemKey:
    """Identity of one FFT problem: what the control unit dispatches on.

    ``shape`` is the concrete array shape seen by the entry point (for
    ``fft1d`` the transform axis is last; for 2D kinds the trailing two
    axes are H, W; for ``fft2d_stream`` the leading axis is time).
    """

    kind: str                  # one of KINDS
    backend: str               # jax.default_backend(): "cpu" | "gpu" | "tpu"
    device_kind: str           # e.g. "TPU v5e", "cpu"
    shape: Tuple[int, ...]
    dtype: str                 # canonical dtype name, e.g. "complex64"
    n_devices: int = 1
    direction: str = "fwd"     # "fwd" | "inv" — inverse transforms tune apart
    axes: Tuple[int, ...] = () # transform axes; () -> canonical for the kind
    precision: str = "single"  # "single" | "double" — engine-capability filter
    backends: Tuple[str, ...] = ()  # engine-backend scope; () = unrestricted

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown problem kind {self.kind!r}; want one of {KINDS}")
        if self.direction not in DIRECTIONS:
            raise ValueError(
                f"unknown direction {self.direction!r}; want one of {DIRECTIONS}"
            )
        from repro.engines.registry import PRECISIONS  # lazy: one domain

        if self.precision not in PRECISIONS:
            raise ValueError(
                f"unknown precision {self.precision!r}; want one of {PRECISIONS}"
            )
        if self.precision == "double":
            # Normalize the dtype label to the width a double-precision
            # engine actually moves. Done HERE — the one place every key is
            # born (resolve_call, plan_fft, direct construction) — so double
            # wisdom can never split across callers that spelled the dtype
            # at different widths.
            object.__setattr__(
                self, "dtype", _WIDE_DTYPES.get(str(self.dtype), str(self.dtype))
            )
        object.__setattr__(self, "shape", tuple(int(s) for s in self.shape))
        axes = tuple(int(a) for a in self.axes) or _CANONICAL_AXES[self.kind]
        object.__setattr__(self, "axes", axes)
        # Canonicalize the engine-backend scope (sorted, deduplicated) so
        # config(backend=("pallas", "jnp")) and ("jnp", "pallas") share keys.
        object.__setattr__(self, "backends", tuple(sorted(set(self.backends))))

    def cache_key(self) -> str:
        """Stable, versioned string key for the plan cache.

        The engine-capability constraints — precision and any scoped
        backend restriction — are part of the key: a plan tuned for one
        engine population (say complex64 jnp+pallas) is never wisdom for
        an incompatible one (complex128 x64, or a pallas-only scope).
        """
        shape = "x".join(str(s) for s in self.shape)
        axes = ",".join(str(a) for a in self.axes)
        engines = ",".join(self.backends) if self.backends else "*"
        return (
            f"v{PLAN_SCHEMA_VERSION}|{self.kind}|{self.direction}|{self.backend}"
            f"|{self.device_kind}|{shape}|{self.dtype}|d{self.n_devices}"
            f"|ax{axes}|{self.precision}|be{engines}"
        )

    def to_dict(self) -> dict:
        return {
            "kind": self.kind,
            "backend": self.backend,
            "device_kind": self.device_kind,
            "shape": list(self.shape),
            "dtype": self.dtype,
            "n_devices": self.n_devices,
            "direction": self.direction,
            "axes": list(self.axes),
            "precision": self.precision,
            "backends": list(self.backends),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "ProblemKey":
        return cls(
            kind=d["kind"],
            backend=d["backend"],
            device_kind=d["device_kind"],
            shape=tuple(d["shape"]),
            dtype=d["dtype"],
            n_devices=int(d["n_devices"]),
            direction=d.get("direction", "fwd"),
            axes=tuple(d.get("axes", ())),
            precision=d.get("precision", "single"),
            backends=tuple(d.get("backends", ())),
        )


@dataclasses.dataclass(frozen=True)
class FFTPlan:
    """One frozen scheduling decision for a :class:`ProblemKey`.

    Fields beyond ``variant`` exist so later PRs (sharding, batching,
    multi-backend) plug into the same decision point instead of growing
    new keyword arguments on every entry point:

      axis_order  — pass order for separable 2D transforms; ``(-1, -2)``
                    is rows-then-columns (paper fig. 1).
      precision   — numeric precision the plan resolves under ("single"
                    = the paper's complex64 datapath, "double" = the x64
                    engine family); mirrors ``key.precision``.
      unroll      — ``lax.scan`` unroll for the streaming pipeline.
      chunks      — corner-turn slab count for the overlapped pencil path.
      tile        — (TH, TW) FFT tile for ``oaconv2d`` plans: the largest
                    tile whose fused-kernel working set stays inside VMEM
                    with the best compute-per-output ratio; ``None`` for
                    every other kind.
      degrade_reason — why a MEASURE request produced this ESTIMATE plan
                    (``"estimate_only_kind"`` for pencil/oaconv problems,
                    ``"trace_not_clean"`` when resolution happened inside
                    a jit trace, ``"forced_variant"`` under a scoped
                    variant pin); ``None`` when nothing degraded. Persists
                    into wisdom files, so a shipped cache says *why* an
                    entry never tuned.
    """

    key: ProblemKey
    variant: str                       # name of a registered engine
    axis_order: Tuple[int, ...] = (-1, -2)
    precision: str = "single"
    unroll: int = 1
    chunks: int = 1
    mode: str = "estimate"             # "estimate" | "measure"
    est_time_s: float = 0.0            # roofline-model time (ESTIMATE)
    measured_us: Optional[float] = None  # winning candidate time (MEASURE)
    tile: Optional[Tuple[int, int]] = None  # oaconv2d FFT tile (TH, TW)
    degrade_reason: Optional[str] = None  # why measure degraded to estimate

    def __post_init__(self):
        from repro.engines import has_engine, registered_variants  # lazy

        if not has_engine(self.variant):
            # Name what IS registered, live — never a stale hardcoded tuple.
            raise ValueError(
                f"plan variant must be a concrete registered engine, got "
                f"{self.variant!r} (registered engines: {registered_variants()})"
            )
        # precision is DERIVED state: always the key's, so no construction
        # site can ever produce a double-keyed plan labeled "single".
        object.__setattr__(self, "precision", self.key.precision)
        if self.unroll < 1 or self.chunks < 1:
            raise ValueError("unroll and chunks must be >= 1")

    def to_dict(self) -> dict:
        return {
            "key": self.key.to_dict(),
            "variant": self.variant,
            "axis_order": list(self.axis_order),
            "precision": self.precision,
            "unroll": self.unroll,
            "chunks": self.chunks,
            "mode": self.mode,
            "est_time_s": self.est_time_s,
            "measured_us": self.measured_us,
            "tile": None if self.tile is None else list(self.tile),
            "degrade_reason": self.degrade_reason,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "FFTPlan":
        tile = d.get("tile")
        return cls(
            key=ProblemKey.from_dict(d["key"]),
            variant=d["variant"],
            axis_order=tuple(d["axis_order"]),
            precision=d["precision"],
            unroll=int(d["unroll"]),
            chunks=int(d["chunks"]),
            mode=d["mode"],
            est_time_s=float(d["est_time_s"]),
            measured_us=None if d.get("measured_us") is None else float(d["measured_us"]),
            tile=None if tile is None else (int(tile[0]), int(tile[1])),
            degrade_reason=d.get("degrade_reason"),
        )


def problem_key(
    kind: str,
    shape: Tuple[int, ...],
    dtype: str = "complex64",
    n_devices: int = 1,
    direction: str = "fwd",
    axes: Optional[Tuple[int, ...]] = None,
    precision: str = "single",
    backends: Tuple[str, ...] = (),
) -> ProblemKey:
    """Build a :class:`ProblemKey` for the *current* JAX backend/device.

    ``axes=None`` keys on the kind's canonical axes (transform axes moved
    last), which is what every entry point does before dispatching. The
    ``norm`` convention is deliberately absent: it is a post-engine scale,
    so all three conventions resolve to the same key (schema v4).
    ``precision`` and ``backends`` are the engine-capability constraints
    resolution runs under (schema v5); both come from the scoped
    ``repro.xfft.config`` when resolution goes through ``resolve_call``.
    """
    import jax

    devices = jax.devices()
    return ProblemKey(
        kind=kind,
        backend=jax.default_backend(),
        device_kind=devices[0].device_kind if devices else "unknown",
        shape=tuple(shape),
        dtype=str(dtype),
        n_devices=int(n_devices),
        direction=direction,
        axes=tuple(axes) if axes else (),
        precision=precision,
        backends=tuple(backends),
    )


def __getattr__(name: str):
    # Deprecation alias: the hardcoded engine tuple became the registry
    # (``repro.engines``). Derived live so third-party registrations show
    # up; restricted to single precision so pre-registry callers see
    # exactly the engine population the old tuple named.
    if name == "PLAN_VARIANTS":
        from repro.engines import registered_variants

        return registered_variants(precision="single")
    # Lazy re-export: the precision domain lives on the engine registry
    # (the leaf module) so registration and key validation share it.
    if name == "PRECISIONS":
        from repro.engines.registry import PRECISIONS

        return PRECISIONS
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
