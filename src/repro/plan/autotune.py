"""FFTW-style planning modes: analytic ESTIMATE and timed MEASURE.

ESTIMATE builds a roofline model per candidate schedule from the paper's
analytic resource counts (``butterfly_counts``: (N/2)·log2 N butterfly
passes) plus per-variant memory-traffic factors, and adds small
per-stage dispatch overheads that differentiate the schedules where the
roofline terms tie:

  * ``looped``   — fori_loop stages run strictly sequentially and each
                   stage is a gather/concat/gather round-trip.
  * ``unrolled`` — same traffic, but XLA sees all stages at once and can
                   fuse across them; lowest per-stage overhead.
  * ``stockham`` — autosort: no bit-reversal gather and contiguous
                   reshapes only, so ~2/3 of the per-stage traffic.

The crossover this produces — ``unrolled`` for overhead-dominated small
transforms, ``stockham`` once bandwidth dominates — matches what MEASURE
finds on CPU and TPU for this repo's engines.

MEASURE jits every candidate, times it (median of several runs, first
call discarded so compile time never pollutes the comparison) and keeps
the argmin, exactly like FFTW's planner running real candidates.
"""

from __future__ import annotations

import math
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.fft1d import butterfly_counts
from repro.launch.roofline import Roofline
from repro.plan.plan import PLAN_VARIANTS, FFTPlan, ProblemKey

__all__ = ["estimate_plan", "measure_plan", "chunk_candidates"]

# Real FLOPs per butterfly pass: one complex multiply (6) + two complex
# add/sub (4) — the multiplier + 2 adders of the paper's butterfly unit.
_FLOPS_PER_BUTTERFLY = 10.0

# Bytes of HBM traffic per element per stage (complex64 = 8 B), per variant.
# looped/unrolled: gather a, gather b, write top/bot concat, gather unperm
# write-back -> ~6 element-touches; stockham: read + twiddle-mul + two
# contiguous writes -> ~4.
_TRAFFIC_FACTOR = {"looped": 6.0, "unrolled": 6.0, "stockham": 4.0}

# Per-stage dispatch overhead (seconds): sequential fori_loop iterations
# cannot fuse; unrolled fuses best; stockham pays for reshape/concat.
_STAGE_OVERHEAD_S = {"looped": 3.0e-6, "unrolled": 0.5e-6, "stockham": 0.8e-6}

# Fixed cost of entering a fori_loop with carried state (the register array).
_LOOP_ENTRY_S = 5.0e-6

# CPU backends sit far off the TPU roofline constants; only the *ranking*
# matters for planning, but scaling keeps est_time_s roughly honest.
_BACKEND_SLOWDOWN = {"cpu": 40.0}


def _transform_geometry(key: ProblemKey) -> Tuple[int, int, int]:
    """(n, rows_per_frame, n_transforms_total) for the 1D passes of ``key``.

    2D kinds do a length-W pass over H rows and a length-H pass over W
    columns; we model the dominant cost with the last-axis length and
    total 1D transforms across both passes.
    """
    shape = key.shape
    if key.kind == "fft1d":
        n = shape[-1]
        batch = int(np.prod(shape[:-1], dtype=np.int64)) if len(shape) > 1 else 1
        return n, 1, max(batch, 1)
    h, w = shape[-2], shape[-1]
    lead = int(np.prod(shape[:-2], dtype=np.int64)) if len(shape) > 2 else 1
    # rows pass: lead*h transforms of length w; cols pass: lead*w of length h.
    # Use the geometric-mean length so non-square frames aren't mismodelled.
    n = int(2 ** round((math.log2(w) + math.log2(h)) / 2))
    return n, h, max(lead, 1) * (h + w)


def estimate_variant_time(key: ProblemKey, variant: str) -> float:
    """Roofline-model execution time (seconds) of one call under ``variant``."""
    n, _, n_transforms = _transform_geometry(key)
    counts = butterfly_counts(n, proposed=True)
    stages = counts["stages"]
    # (N/2)·log2 N butterfly passes per transform (paper Tables 1 & 2).
    flops = _FLOPS_PER_BUTTERFLY * counts["butterfly_units"] * stages * n_transforms
    traffic = _TRAFFIC_FACTOR[variant] * 8.0 * n * stages * n_transforms
    # Pencil kind: the corner-turn moves each element once across the mesh.
    collective = 0.0
    if key.kind == "fft2d_pencil" and key.n_devices > 1:
        collective = 8.0 * float(np.prod(key.shape, dtype=np.int64)) / key.n_devices
    rl = Roofline(
        flops_per_device=flops / key.n_devices,
        bytes_per_device=traffic / key.n_devices,
        collective_bytes_per_device=collective,
        n_devices=key.n_devices,
        model_flops_global=flops,
    )
    t = rl.step_time_s * _BACKEND_SLOWDOWN.get(key.backend, 1.0)
    t += stages * _STAGE_OVERHEAD_S[variant]
    if variant == "looped":
        t += _LOOP_ENTRY_S
    return t


def chunk_candidates(w: int, n_devices: int, limit: int = 16) -> List[int]:
    """Legal corner-turn slab counts: c | W and d | (W/c)."""
    out = [c for c in range(1, limit + 1)
           if w % c == 0 and (w // c) % max(n_devices, 1) == 0]
    return out or [1]


def _estimate_chunks(key: ProblemKey) -> int:
    """Pick the slab count that best overlaps all_to_all with column FFTs.

    Ideal chunking splits the collective into enough slabs that slab i's
    exchange hides behind slab i-1's butterflies; past that, smaller
    slabs just pay more launch latency. We size c ~ collective/compute
    and clamp to the legal divisors.
    """
    w = key.shape[-1]
    cands = chunk_candidates(w, key.n_devices)
    if len(cands) == 1:
        return cands[0]
    compute_s = estimate_variant_time(
        ProblemKey(
            kind="fft2d",
            backend=key.backend,
            device_kind=key.device_kind,
            shape=key.shape,
            dtype=key.dtype,
            n_devices=key.n_devices,
        ),
        "stockham",
    )
    from repro.launch.roofline import ICI_LINK_BW

    collective_s = 8.0 * float(np.prod(key.shape, dtype=np.int64)) / (
        key.n_devices * ICI_LINK_BW
    )
    ideal = max(1.0, collective_s / max(compute_s, 1e-12))
    # Closest legal slab count to the overlap ideal; ties favour more slabs.
    return min(cands, key=lambda c: (abs(c - ideal), -c))


def _estimate_unroll(key: ProblemKey) -> int:
    """Streaming scan unroll: unroll short pipelines over small frames so
    XLA can interleave frame k's rows with frame k-1's columns across scan
    iterations too; long streams / big frames keep the compact loop."""
    if key.kind != "fft2d_stream" or len(key.shape) < 3:
        return 1
    t = key.shape[0]
    frame_elems = key.shape[-2] * key.shape[-1]
    if t >= 2 and frame_elems <= 128 * 128:
        return 2
    return 1


def estimate_plan(key: ProblemKey) -> FFTPlan:
    """Analytic (FFTW ``ESTIMATE``) plan: no device work, microseconds."""
    times = {v: estimate_variant_time(key, v) for v in PLAN_VARIANTS}
    variant = min(times, key=times.get)
    return FFTPlan(
        key=key,
        variant=variant,
        unroll=_estimate_unroll(key),
        chunks=_estimate_chunks(key) if key.kind == "fft2d_pencil" else 1,
        mode="estimate",
        est_time_s=times[variant],
    )


# ------------------------------- MEASURE ---------------------------------


def _time_us(fn: Callable, x, warmup: int = 1, iters: int = 5) -> float:
    """Median wall time per call in microseconds (first call = compile)."""
    import jax

    for _ in range(max(warmup, 1)):
        jax.block_until_ready(fn(x))
    samples = []
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(x))
        samples.append(time.perf_counter() - t0)
    samples.sort()
    return samples[len(samples) // 2] * 1e6


def _measure_input(key: ProblemKey, seed: int = 0):
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    x = (
        rng.standard_normal(key.shape) + 1j * rng.standard_normal(key.shape)
    ).astype(np.complex64)
    return jnp.asarray(x)


def _candidate_runners(key: ProblemKey) -> Dict[Tuple[str, int], Callable]:
    """(variant, unroll) -> jitted callable for this problem kind."""
    import functools

    import jax

    from repro.core.fft1d import fft
    from repro.core.fft2d import fft2, fft2_stream

    runners: Dict[Tuple[str, int], Callable] = {}
    for v in PLAN_VARIANTS:
        if key.kind == "fft1d":
            runners[(v, 1)] = jax.jit(functools.partial(fft, variant=v))
        elif key.kind == "fft2d":
            runners[(v, 1)] = jax.jit(functools.partial(fft2, variant=v))
        elif key.kind == "fft2d_stream":
            for u in (1, 2):
                runners[(v, u)] = jax.jit(
                    functools.partial(fft2_stream, variant=v, unroll=u)
                )
        else:
            raise ValueError(
                f"MEASURE planning for kind {key.kind!r} needs a device mesh; "
                "use mode='estimate' (the pencil chunk model) instead"
            )
    return runners


def measure_plan(
    key: ProblemKey,
    warmup: int = 1,
    iters: int = 5,
    timings_out: Optional[Dict[str, float]] = None,
) -> FFTPlan:
    """Timed candidate sweep (FFTW ``MEASURE``): jit + run every schedule.

    ``timings_out`` (optional dict) receives per-candidate medians in µs,
    keyed ``"variant"`` or ``"variant/unroll=k"`` — benchmarks report it.
    """
    x = _measure_input(key)
    best: Optional[Tuple[Tuple[str, int], float]] = None
    for (variant, unroll), fn in _candidate_runners(key).items():
        us = _time_us(fn, x, warmup=warmup, iters=iters)
        label = variant if unroll == 1 else f"{variant}/unroll={unroll}"
        if timings_out is not None:
            timings_out[label] = us
        if best is None or us < best[1]:
            best = ((variant, unroll), us)
    (variant, unroll), us = best
    return FFTPlan(
        key=key,
        variant=variant,
        unroll=unroll,
        chunks=1,
        mode="measure",
        est_time_s=estimate_variant_time(key, variant),
        measured_us=us,
    )
