"""FFTW-style planning modes: analytic ESTIMATE and timed MEASURE.

Candidates come from the ``repro.engines`` registry (capability-filtered
per problem key); ESTIMATE builds a roofline model per candidate from the
paper's analytic resource counts (``butterfly_counts``: (N/2)·log2 N
butterfly passes) plus each engine's registered cost hints
(``repro.engines.CostHints``: memory-traffic factor, per-stage dispatch
overhead, FLOP scale, fixed entry cost), which differentiate the
schedules where the roofline terms tie:

  * ``looped``   — fori_loop stages run strictly sequentially and each
                   stage is a gather/concat/gather round-trip.
  * ``unrolled`` — same traffic, but XLA sees all stages at once and can
                   fuse across them; lowest per-stage overhead.
  * ``stockham`` — autosort: no bit-reversal gather and contiguous
                   reshapes only, so ~2/3 of the per-stage traffic.
  * ``radix4``   — Stockham with 4-point butterflies: half the stage
                   passes (≈ half the traffic) and ~15% fewer FLOPs
                   (3 twiddle multiplies produce 4 outputs).
  * ``fused``/``fused_r4`` — the Pallas whole-transform kernels: ONE HBM
                   round trip on TPU. On other backends they execute in
                   interpret mode (plain XLA ops), so they are modeled
                   like their in-VMEM schedule plus launch overhead —
                   which keeps ESTIMATE honest on CPU while letting the
                   fused path dominate where it really does.

Real-input kinds (``rfft1d``/``rfft2d``) halve both the butterfly count
and the traffic: the two-for-one Hermitian pack runs ONE half-size
complex FFT and touches half the bytes.

The crossover this produces — ``unrolled`` for overhead-dominated small
transforms, the bandwidth-lean Stockham family once traffic dominates —
matches what MEASURE finds on CPU and TPU for this repo's engines.

MEASURE jits every candidate, times it (median of several runs, first
call discarded so compile time never pollutes the comparison) and keeps
the argmin, exactly like FFTW's planner running real candidates.
"""

from __future__ import annotations

import math
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.fft1d import butterfly_counts
from repro.core.spectral import _next_pow2
from repro.launch.roofline import Roofline
from repro.plan.plan import FFTPlan, ProblemKey
from repro.resilience import faults as _faults
from repro.resilience.breaker import quarantine

__all__ = [
    "estimate_plan",
    "measure_plan",
    "chunk_candidates",
    "oaconv_tile_candidates",
    "variant_candidates",
]

# Real FLOPs per butterfly pass: one complex multiply (6) + two complex
# add/sub (4) — the multiplier + 2 adders of the paper's butterfly unit.
_FLOPS_PER_BUTTERFLY = 10.0

# Fixed cost of a Pallas kernel launch; in interpret mode (non-TPU) the
# kernel body is traced into XLA, costing grid bookkeeping on top.
_KERNEL_LAUNCH_S = 2.0e-6
_INTERPRET_OVERHEAD_S = 20.0e-6

# CPU backends sit far off the TPU roofline constants; only the *ranking*
# matters for planning, but scaling keeps est_time_s roughly honest.
_BACKEND_SLOWDOWN = {"cpu": 40.0}

#: Real-input (two-for-one) kinds.
_REAL_KINDS = ("rfft1d", "rfft2d")

#: Per-candidate wall-clock budget (seconds) for a MEASURE sweep. A
#: candidate whose warmup+timing loop exceeds it is skipped and recorded
#: in the ``plan.measure`` span; a sweep where EVERY candidate blows the
#: budget degrades to ESTIMATE with reason ``measure_timeout``. The check
#: runs between calls (a single hung jit cannot be preempted from Python),
#: so the guard bounds sweeps that are slow, not ones that never return.
MEASURE_CANDIDATE_BUDGET_S = 30.0


def variant_candidates(key: ProblemKey) -> Tuple[str, ...]:
    """Engines the planner may legally consider for ``key``.

    An enumeration of the ``repro.engines`` registry filtered by
    capability: problem kind × precision × scoped backend restriction ×
    device count × VMEM working-set fit (each engine's own
    ``EngineSpec.supports``). Per-engine cost tables, fused-kind lists and
    pow2/VMEM gates all live on the specs now — registering an engine is
    enough to enter every sweep.

    Engines quarantined for this problem key (``repro.resilience``
    circuit breaker open after a failure) are excluded, so the planner
    routes around a benched engine until its cooldown admits a probe.
    When quarantine would empty the list, the ``reliable``-marked rungs
    (``stockham``/``reference_x64``) come back regardless — the ladder
    must always have a bottom.
    """
    from repro.engines import iter_engines  # lazy: engines is the leaf layer

    specs = tuple(s for s in iter_engines() if s.supports(key))
    if not specs:
        scope = f" under backend scope {key.backends}" if key.backends else ""
        raise ValueError(
            f"no registered engine supports kind {key.kind!r} at precision "
            f"{key.precision!r}{scope}; registered engines: "
            f"{tuple(s.name for s in iter_engines())}"
        )
    breaker = quarantine()
    healthy = tuple(
        s.name for s in specs if not breaker.excluded(s.name, key)
    )
    if healthy:
        return healthy
    reliable = tuple(s.name for s in specs if s.reliable)
    return reliable or tuple(s.name for s in specs)


def _transform_geometry(key: ProblemKey) -> Tuple[int, int, int]:
    """(n, rows_per_frame, n_transforms_total) for the 1D passes of ``key``.

    2D kinds do a length-W pass over H rows and a length-H pass over W
    columns; we model the dominant cost with the last-axis length and
    total 1D transforms across both passes.
    """
    shape = key.shape
    if key.kind in ("fft1d", "rfft1d"):
        n = shape[-1]
        batch = int(np.prod(shape[:-1], dtype=np.int64)) if len(shape) > 1 else 1
        return n, 1, max(batch, 1)
    h, w = shape[-2], shape[-1]
    lead = int(np.prod(shape[:-2], dtype=np.int64)) if len(shape) > 2 else 1
    # rows pass: lead*h transforms of length w; cols pass: lead*w of length h.
    # Use the geometric-mean length so non-square frames aren't mismodelled.
    n = int(2 ** round((math.log2(w) + math.log2(h)) / 2))
    return n, h, max(lead, 1) * (h + w)


def _stage_passes(stages: int, radix: int) -> int:
    """Butterfly passes over the data at the engine's ``radix``."""
    if radix <= 2:
        return stages
    return max(1, math.ceil(stages / math.log2(radix)))


def estimate_variant_time(key: ProblemKey, variant: str) -> float:
    """Roofline-model execution time (seconds) of one call under ``variant``.

    All per-engine coefficients — traffic factor, per-stage overhead, FLOP
    scale, fixed entry cost, radix, fusion — come from the engine's
    registered :class:`repro.engines.CostHints`, so a new registration is
    rankable by ESTIMATE without touching this function.
    """
    from repro.engines import get_engine  # lazy: engines is the leaf layer

    spec = get_engine(variant)
    n, _, n_transforms = _transform_geometry(key)
    counts = butterfly_counts(n, proposed=True)
    stages = counts["stages"]
    passes = _stage_passes(stages, spec.radix)
    # (N/2)·log2 N butterfly passes per transform (paper Tables 1 & 2).
    flops = _FLOPS_PER_BUTTERFLY * counts["butterfly_units"] * stages * n_transforms
    flops *= spec.cost.flop_scale
    # Bytes per element: re+im at the key's precision (f32 pairs = 8 B,
    # f64 pairs = 16 B — the double path moves twice the traffic).
    elem_bytes = 16.0 if key.precision == "double" else 8.0
    on_tpu = key.backend == "tpu"
    if spec.fused and on_tpu:
        # Whole transform on one VMEM residency: one HBM read + one write.
        # Frames over the VMEM budget take the unfused row/turn/column
        # failover instead — three round trips, not one.
        trips = 1
        if key.kind in ("fft2d", "rfft2d") and len(key.shape) >= 2:
            from repro.kernels.fft_radix2 import fft2_fits_vmem  # lazy

            arrays = 6 if key.kind == "rfft2d" else 8
            if not fft2_fits_vmem(key.shape[-2], key.shape[-1], arrays=arrays):
                trips = 3
        traffic = spec.cost.traffic_factor * elem_bytes * n * trips * n_transforms
    else:
        # jnp engines — and fused kernels in interpret mode, which execute
        # as plain XLA ops and get no HBM fusion win.
        traffic = spec.cost.traffic_factor * elem_bytes * n * passes * n_transforms
    if key.kind in _REAL_KINDS:
        # Two-for-one Hermitian pack: one half-size transform, half the bytes.
        flops *= 0.5
        traffic *= 0.5
    # Pencil kind: the corner-turn moves each element once across the mesh.
    collective = 0.0
    if key.kind == "fft2d_pencil" and key.n_devices > 1:
        collective = (
            elem_bytes * float(np.prod(key.shape, dtype=np.int64)) / key.n_devices
        )
    rl = Roofline(
        flops_per_device=flops / key.n_devices,
        bytes_per_device=traffic / key.n_devices,
        collective_bytes_per_device=collective,
        n_devices=key.n_devices,
        model_flops_global=flops,
    )
    t = rl.step_time_s * _BACKEND_SLOWDOWN.get(key.backend, 1.0)
    if spec.fused:
        t += _KERNEL_LAUNCH_S
        if not on_tpu:
            t += _INTERPRET_OVERHEAD_S + passes * spec.cost.stage_overhead_s
    else:
        t += passes * spec.cost.stage_overhead_s
    return t + spec.cost.entry_overhead_s


def chunk_candidates(w: int, n_devices: int, limit: int = 16) -> List[int]:
    """Legal corner-turn slab counts: c | W and d | (W/c)."""
    out = [c for c in range(1, limit + 1)
           if w % c == 0 and (w // c) % max(n_devices, 1) == 0]
    return out or [1]


def _estimate_chunks(key: ProblemKey) -> int:
    """Pick the slab count that best overlaps all_to_all with column FFTs.

    Ideal chunking splits the collective into enough slabs that slab i's
    exchange hides behind slab i-1's butterflies; past that, smaller
    slabs just pay more launch latency. We size c ~ collective/compute
    and clamp to the legal divisors.
    """
    w = key.shape[-1]
    cands = chunk_candidates(w, key.n_devices)
    if len(cands) == 1:
        return cands[0]
    compute_s = estimate_variant_time(
        ProblemKey(
            kind="fft2d",
            backend=key.backend,
            device_kind=key.device_kind,
            shape=key.shape,
            dtype=key.dtype,
            n_devices=key.n_devices,
            precision=key.precision,
        ),
        "stockham",
    )
    from repro.launch.roofline import ICI_LINK_BW

    collective_s = 8.0 * float(np.prod(key.shape, dtype=np.int64)) / (
        key.n_devices * ICI_LINK_BW
    )
    ideal = max(1.0, collective_s / max(compute_s, 1e-12))
    # Closest legal slab count to the overlap ideal; ties favour more slabs.
    return min(cands, key=lambda c: (abs(c - ideal), -c))


def _estimate_unroll(key: ProblemKey) -> int:
    """Streaming scan unroll: unroll short pipelines over small frames so
    XLA can interleave frame k's rows with frame k-1's columns across scan
    iterations too; long streams / big frames keep the compact loop."""
    if key.kind != "fft2d_stream" or len(key.shape) < 3:
        return 1
    t = key.shape[0]
    frame_elems = key.shape[-2] * key.shape[-1]
    if t >= 2 and frame_elems <= 128 * 128:
        return 2
    return 1


def oaconv_tile_candidates(key: ProblemKey) -> List[Tuple[int, int]]:
    """Legal FFT tiles for an overlap-save ``oaconv2d`` problem.

    ``key.shape`` ends ``(H, W, KH, KW)`` — image dims then kernel dims.
    Per axis, a tile must be a power of two at least the kernel extent
    (otherwise the overlap-save step ``T - K + 1`` vanishes) and at most
    the padded full-frame transform; jointly, the pair must keep the fused
    kernel's true working set (``repro.kernels.ops.fft2_working_set``)
    inside the VMEM budget. When even the smallest legal tile busts the
    budget (enormous kernels), the single padded full-frame transform is
    the fallback — the engines' unfused failover handles it.
    """
    if len(key.shape) < 4:
        raise ValueError(
            f"oaconv2d keys on (..., H, W, KH, KW); got shape {key.shape}"
        )
    h, w, kh, kw = key.shape[-4:]
    real = not key.dtype.startswith("complex")
    from repro.kernels.ops import fft2_fits_budget  # lazy: pallas import

    def axis_cands(dim: int, k: int) -> List[int]:
        lo, hi = _next_pow2(k), _next_pow2(dim + k - 1)
        return [t for t in (1 << p for p in range(lo.bit_length() - 1,
                                                  hi.bit_length()))
                if lo <= t <= hi]

    pairs = [
        (th, tw)
        for th in axis_cands(h, kh)
        for tw in axis_cands(w, kw)
        if fft2_fits_budget(th, tw, real=real)
    ]
    return pairs or [(_next_pow2(h + kh - 1), _next_pow2(w + kw - 1))]


def _estimate_oaconv_plan(key: ProblemKey) -> FFTPlan:
    """Pick the overlap-save FFT tile with the best modeled time.

    Modeled cost of a tile = (tiles needed to cover the full-size output)
    × (forward + inverse transform of one tile under that tile's best
    schedule). Small tiles waste work on the K−1 overlap; big tiles waste
    it on zero padding and fall off the fused kernel's VMEM cliff — the
    sweet spot is exactly what the census-constrained sweep finds.
    """
    h, w, kh, kw = key.shape[-4:]
    sub_kind = "rfft2d" if not key.dtype.startswith("complex") else "fft2d"
    best: Optional[Tuple[float, str, Tuple[int, int]]] = None
    for th, tw in oaconv_tile_candidates(key):
        sub = ProblemKey(
            kind=sub_kind,
            backend=key.backend,
            device_kind=key.device_kind,
            shape=(th, tw),
            dtype=key.dtype,
            n_devices=key.n_devices,
            precision=key.precision,
            backends=key.backends,
        )
        times = {v: estimate_variant_time(sub, v) for v in variant_candidates(sub)}
        variant = min(times, key=times.get)
        n_tiles = math.ceil((h + kh - 1) / max(th - kh + 1, 1)) * math.ceil(
            (w + kw - 1) / max(tw - kw + 1, 1)
        )
        total = 2.0 * times[variant] * n_tiles  # forward + inverse per tile
        if best is None or total < best[0]:
            best = (total, variant, (th, tw))
    total, variant, tile = best
    return FFTPlan(
        key=key, variant=variant, mode="estimate", est_time_s=total, tile=tile
    )


def estimate_plan(key: ProblemKey) -> FFTPlan:
    """Analytic (FFTW ``ESTIMATE``) plan: no device work, microseconds."""
    if key.kind == "oaconv2d":
        return _estimate_oaconv_plan(key)
    times = {v: estimate_variant_time(key, v) for v in variant_candidates(key)}
    variant = min(times, key=times.get)
    return FFTPlan(
        key=key,
        variant=variant,
        unroll=_estimate_unroll(key),
        chunks=_estimate_chunks(key) if key.kind == "fft2d_pencil" else 1,
        mode="estimate",
        est_time_s=times[variant],
    )


# ------------------------------- MEASURE ---------------------------------


class MeasureTimeout(Exception):
    """A MEASURE candidate exceeded its wall-clock budget (sweep guard)."""


def _time_us(
    fn: Callable,
    x,
    warmup: int = 1,
    iters: int = 5,
    budget_s: Optional[float] = None,
) -> float:
    """Median wall time per call in microseconds (first call = compile).

    ``budget_s`` bounds the candidate's TOTAL wall clock (warmup included):
    past it, :class:`MeasureTimeout` aborts the candidate between calls so
    one pathologically slow schedule cannot hang the whole sweep.
    """
    import jax

    start = time.perf_counter()

    def checkpoint():
        if budget_s is not None and time.perf_counter() - start > budget_s:
            raise MeasureTimeout(
                f"candidate exceeded its {budget_s:.1f}s measure budget"
            )

    for _ in range(max(warmup, 1)):
        jax.block_until_ready(fn(x))
        checkpoint()
    samples = []
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(x))
        samples.append(time.perf_counter() - t0)
        checkpoint()
    samples.sort()
    return samples[len(samples) // 2] * 1e6


def _measure_input(key: ProblemKey, seed: int = 0):
    """A representative input for ``key``: real for rfft kinds, complex
    else, at the key's precision (a double-precision sweep must move
    double-width bytes or its timings misrepresent the workload); inverse
    real kinds get the half spectrum their runner consumes."""
    import jax.numpy as jnp

    double = key.precision == "double"
    rdt = np.float64 if double else np.float32
    cdt = np.complex128 if double else np.complex64
    rng = np.random.default_rng(seed)
    if key.kind in _REAL_KINDS:
        x = rng.standard_normal(key.shape).astype(rdt)
        if key.direction == "inv":
            x = np.fft.rfft2(x).astype(cdt) if key.kind == "rfft2d" \
                else np.fft.rfft(x).astype(cdt)
    else:
        x = (
            rng.standard_normal(key.shape) + 1j * rng.standard_normal(key.shape)
        ).astype(cdt)
    # measure_plan wraps double sweeps in enable_x64, so this asarray
    # keeps the 64-bit width instead of canonicalizing it away.
    return jnp.asarray(x)


def _candidate_runners(key: ProblemKey) -> Dict[Tuple[str, int], Callable]:
    """(variant, unroll) -> jitted callable for this problem kind."""
    import functools

    import jax

    from repro.core.fft1d import fft_impl, ifft_impl
    from repro.core.fft2d import fft2_impl, fft2_stream, ifft2_impl
    from repro.core.rfft import irfft2_impl, irfft_impl, rfft2_impl, rfft_impl

    inv = key.direction == "inv"
    entry = {
        "fft1d": ifft_impl if inv else fft_impl,
        "fft2d": ifft2_impl if inv else fft2_impl,
        "rfft1d": irfft_impl if inv else rfft_impl,
        "rfft2d": irfft2_impl if inv else rfft2_impl,
    }
    from repro.core.fft1d import BUILTIN_VARIANTS

    runners: Dict[Tuple[str, int], Callable] = {}
    for v in variant_candidates(key):
        if key.kind in entry:
            runners[(v, 1)] = jax.jit(functools.partial(entry[key.kind], variant=v))
        elif key.kind == "fft2d_stream":
            # The scan-unroll knob only exists on the builtin jnp stream;
            # registry engines run their own stream op and would time the
            # identical computation twice under two labels.
            for u in (1, 2) if v in BUILTIN_VARIANTS else (1,):
                runners[(v, u)] = jax.jit(
                    functools.partial(fft2_stream, variant=v, unroll=u)
                )
        else:
            raise ValueError(
                f"MEASURE planning is unavailable for kind {key.kind!r} "
                "(pencil problems need a live mesh; oaconv2d tile choice is "
                "analytic); use mode='estimate' instead"
            )
    return runners


def measure_plan(
    key: ProblemKey,
    warmup: int = 1,
    iters: int = 5,
    timings_out: Optional[Dict[str, float]] = None,
    budget_s: Optional[float] = None,
) -> FFTPlan:
    """Timed candidate sweep (FFTW ``MEASURE``): jit + run every schedule.

    ``timings_out`` (optional dict) receives per-candidate medians in µs,
    keyed ``"variant"`` or ``"variant/unroll=k"`` — benchmarks report it.
    Double-precision keys sweep under ``jax.enable_x64`` so the timed
    calls really trace and move 64-bit data.

    Each candidate gets ``budget_s`` of wall clock (default
    :data:`MEASURE_CANDIDATE_BUDGET_S`); candidates that exceed it — or
    raise — are skipped and recorded in the ``plan.measure`` span rather
    than hanging or killing the sweep. A sweep with no surviving
    candidate returns the ESTIMATE plan with ``degrade_reason``
    ``"measure_timeout"`` (all timed out) or ``"measure_failed"``.
    """
    if budget_s is None:
        budget_s = MEASURE_CANDIDATE_BUDGET_S
    if key.precision == "double":
        from jax.experimental import enable_x64  # lazy

        with enable_x64():
            return _measure_plan_impl(key, warmup, iters, timings_out, budget_s)
    return _measure_plan_impl(key, warmup, iters, timings_out, budget_s)


def _measure_plan_impl(
    key: ProblemKey,
    warmup: int,
    iters: int,
    timings_out: Optional[Dict[str, float]],
    budget_s: float,
) -> FFTPlan:
    import dataclasses

    from repro import obs  # lazy: keep autotune importable without obs users

    x = _measure_input(key)
    best: Optional[Tuple[Tuple[str, int], float]] = None
    timings: Dict[str, float] = {}
    skipped: Dict[str, str] = {}
    # One span for the whole sweep (it is the expensive planner action —
    # under xfft.config(observe=True) it lands in XLA profiles too), with
    # every candidate's median attached to the emitted event.
    with obs.span(
        "plan.measure",
        kind=key.kind,
        shape=key.shape,
        dtype=key.dtype,
        direction=key.direction,
        precision=key.precision,
    ) as out:
        for (variant, unroll), fn in _candidate_runners(key).items():
            label = variant if unroll == 1 else f"{variant}/unroll={unroll}"

            def run(arr, _fn=fn, _variant=variant):
                # plan.measure fault seam fires per timed call, so an
                # injected latency accrues against the candidate budget
                # exactly like a genuinely slow schedule would.
                _faults.maybe_fail(
                    "plan.measure", engine=_variant, kind=key.kind
                )
                return _fn(arr)

            try:
                us = _time_us(run, x, warmup=warmup, iters=iters,
                              budget_s=budget_s)
            except MeasureTimeout:
                skipped[label] = "timeout"
                continue
            except Exception as e:  # noqa: BLE001 — one bad candidate
                skipped[label] = f"error: {e!r}"
                continue
            timings[label] = us
            # Per-candidate event: the calibration ledger's measured
            # prediction for engines the sweep timed but did NOT choose
            # (the chosen one also rides plan.resolve's measured_us).
            obs.emit(
                "plan.measure.candidate",
                engine=variant,
                unroll=unroll,
                label=label,
                kind=key.kind,
                shape=key.shape,
                precision=key.precision,
                median_us=us,
            )
            if timings_out is not None:
                timings_out[label] = us
            if best is None or us < best[1]:
                best = ((variant, unroll), us)
        out["candidates"] = len(timings) + len(skipped)
        out["timings"] = dict(timings)
        if skipped:
            out["skipped"] = dict(skipped)
        if best is None:
            # Nothing survived: fall back to the analytic plan, with the
            # reason recorded on the plan AND in the degrade vocabulary.
            reason = (
                "measure_timeout"
                if any(r == "timeout" for r in skipped.values())
                else "measure_failed"
            )
            out["chosen"] = None
            out["degrade_reason"] = reason
            obs.emit(
                "plan.degrade", kind=key.kind, shape=key.shape,
                direction=key.direction, reason=reason,
            )
            obs.count(f"plan.degrade.{reason}")
            return dataclasses.replace(
                estimate_plan(key), degrade_reason=reason
            )
        (variant, unroll), us = best
        out["chosen"] = variant
        out["chosen_us"] = us
    return FFTPlan(
        key=key,
        variant=variant,
        unroll=unroll,
        chunks=1,
        mode="measure",
        est_time_s=estimate_variant_time(key, variant),
        measured_us=us,
    )
