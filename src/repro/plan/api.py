"""Planner entry points: ``plan_fft`` / ``execute`` / ``resolve``.

``plan_fft`` is the explicit front door (pick a mode, get a plan, it is
cached — and persisted when the cache is file-backed). ``resolve`` is
the implicit one: every ``variant="auto"`` call site in ``repro.core``
funnels through it, so a warm cache (e.g. MEASURE plans produced at
service startup or by ``benchmarks/plan_autotune.py``) steers the hot
path while a cold cache falls back to the analytic ESTIMATE model —
never a timed sweep, because ``resolve`` may run inside a jit trace.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

from repro.plan.autotune import estimate_plan, measure_plan
from repro.plan.cache import PlanCache, default_cache
from repro.plan.plan import FFTPlan, ProblemKey, problem_key

__all__ = ["plan_fft", "execute", "resolve"]


def plan_fft(
    kind: str,
    shape: Tuple[int, ...],
    dtype: str = "complex64",
    mode: str = "estimate",
    n_devices: int = 1,
    cache: Optional[PlanCache] = None,
    force: bool = False,
    measure_iters: int = 5,
    timings_out: Optional[Dict[str, float]] = None,
    direction: str = "fwd",
) -> FFTPlan:
    """Plan one FFT problem; consult the cache first unless ``force``.

    ``mode="estimate"`` is analytic and instant; ``mode="measure"`` jits
    and times every candidate schedule (pencil problems stay analytic —
    timing them needs a live mesh). A MEASURE result replaces a cached
    ESTIMATE plan for the same key. File-backed caches are saved after
    every new plan so a second process re-tunes nothing.

    ``direction="inv"`` plans the inverse transform, which tunes under its
    own cache key (forward wisdom never cross-contaminates it).
    """
    if mode not in ("estimate", "measure"):
        raise ValueError(f"mode must be 'estimate' or 'measure', got {mode!r}")
    cache = cache if cache is not None else default_cache()
    key = problem_key(kind, shape, dtype, n_devices, direction)
    # Pencil problems can't be timed without a live mesh: the best we can do
    # is the analytic model, so a cached ESTIMATE plan already is the answer.
    effective_mode = "estimate" if kind == "fft2d_pencil" else mode
    if not force:
        hit = cache.get(key)
        if hit is not None and (effective_mode == "estimate" or hit.mode == "measure"):
            return hit
    if effective_mode == "measure":
        plan = measure_plan(key, iters=measure_iters, timings_out=timings_out)
    else:
        plan = estimate_plan(key)
    cache.put(plan)
    if cache.path:
        cache.save()
    return plan


def resolve(
    kind: str,
    shape: Tuple[int, ...],
    dtype: str = "complex64",
    n_devices: int = 1,
    cache: Optional[PlanCache] = None,
    direction: str = "fwd",
) -> FFTPlan:
    """Cheap plan lookup for ``variant="auto"`` call sites (trace-safe).

    Cache hit -> the cached (possibly MEASURE) plan; miss -> ESTIMATE,
    which is pure Python on analytic counts and therefore safe to run
    while JAX is tracing the surrounding computation.
    """
    cache = cache if cache is not None else default_cache()
    key = problem_key(kind, shape, dtype, n_devices, direction)
    hit = cache.get(key)
    if hit is not None:
        return hit
    return cache.put(estimate_plan(key))


def execute(plan: FFTPlan, x, mesh=None, axis: str = "data"):
    """Run ``x`` through the transform ``plan`` was made for.

    Pencil plans need the ``mesh`` (and device-axis name) the plan's
    ``n_devices`` refers to.
    """
    kind = plan.key.kind
    inv = plan.key.direction == "inv"
    if kind == "fft1d":
        from repro.core.fft1d import fft, ifft

        return (ifft if inv else fft)(x, variant=plan.variant)
    if kind == "fft2d":
        from repro.core.fft2d import fft2, ifft2

        return (ifft2 if inv else fft2)(x, variant=plan.variant)
    if kind == "rfft1d":
        from repro.core.rfft import irfft, rfft

        return (irfft if inv else rfft)(x, variant=plan.variant)
    if kind == "rfft2d":
        from repro.core.rfft import irfft2, rfft2

        return (irfft2 if inv else rfft2)(x, variant=plan.variant)
    if kind == "fft2d_stream":
        from repro.core.fft2d import fft2_stream

        return fft2_stream(x, variant=plan.variant, unroll=plan.unroll)
    if kind == "fft2d_pencil":
        if mesh is None:
            raise ValueError("execute() needs mesh=... for a pencil plan")
        from repro.core.distributed import fft2_pencil_overlapped

        return fft2_pencil_overlapped(
            x, mesh, axis=axis, variant=plan.variant, chunks=plan.chunks
        )
    raise ValueError(f"plan has unknown kind {kind!r}")
