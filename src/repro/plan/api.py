"""Planner entry points: ``plan_fft`` / ``execute`` / ``resolve_call``.

``plan_fft`` is the explicit front door (pick a mode, get a plan, it is
cached — and persisted when the cache is file-backed). ``resolve_call``
is the implicit one: every ``repro.xfft`` transform and every
``variant="auto"`` call site in ``repro.core`` funnels through it, so a
warm cache (e.g. MEASURE plans produced at service startup or by
``benchmarks/plan_autotune.py``) steers the hot path while a cold cache
falls back to the analytic ESTIMATE model. ``resolve_call`` is also
where the scoped ``repro.xfft.config`` overrides land: a forced variant,
a measure-on-miss mode, or a wisdom directory apply to every call inside
the scope without any signature changing. ``resolve`` is the pre-xfft
spelling of the same lookup, kept for callers that plan bare problems.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Dict, Optional, Tuple

from repro import obs
from repro.plan.autotune import estimate_plan, measure_plan
from repro.plan.cache import PlanCache, default_cache
from repro.plan.plan import FFTPlan, ProblemKey, problem_key
from repro.resilience.breaker import quarantine
from repro.resilience.ladder import run_plan

__all__ = ["plan_fft", "execute", "resolve", "resolve_call"]

#: Kinds whose MEASURE mode degrades to ESTIMATE: pencil problems need a
#: live mesh to time; oaconv2d tile choice is analytic by construction.
_ESTIMATE_ONLY_KINDS = ("fft2d_pencil", "oaconv2d")


def plan_fft(
    kind: str,
    shape: Tuple[int, ...],
    dtype: str = "complex64",
    mode: str = "estimate",
    n_devices: int = 1,
    cache: Optional[PlanCache] = None,
    force: bool = False,
    measure_iters: int = 5,
    timings_out: Optional[Dict[str, float]] = None,
    direction: str = "fwd",
    axes: Optional[Tuple[int, ...]] = None,
    precision: str = "single",
    backends: Tuple[str, ...] = (),
) -> FFTPlan:
    """Plan one FFT problem; consult the cache first unless ``force``.

    ``mode="estimate"`` is analytic and instant; ``mode="measure"`` jits
    and times every candidate schedule (pencil problems stay analytic —
    timing them needs a live mesh; ``oaconv2d`` tile selection is analytic
    too). A MEASURE result replaces a cached ESTIMATE plan for the same
    key. File-backed caches are saved after every new plan so a second
    process re-tunes nothing.

    ``direction="inv"`` plans the inverse transform, which tunes under its
    own cache key (forward wisdom never cross-contaminates it). ``axes``
    is part of the key too; the ``norm`` convention is not — it is applied
    as a scale outside the engine, so all conventions share one entry.
    ``precision`` and ``backends`` restrict which registered engines the
    planner may consider (``repro.engines``) and are part of the key.
    """
    if mode not in ("estimate", "measure"):
        raise ValueError(f"mode must be 'estimate' or 'measure', got {mode!r}")
    cache = cache if cache is not None else default_cache()
    key = problem_key(kind, shape, dtype, n_devices, direction, axes,
                      precision, backends)
    # Pencil problems can't be timed without a live mesh, and oaconv2d tile
    # selection is a closed-form working-set/efficiency trade-off: the best
    # we can do is the analytic model, so a cached ESTIMATE plan already is
    # the answer for both kinds.
    effective_mode = "estimate" if kind in _ESTIMATE_ONLY_KINDS else mode
    degrade = _degrade_event(key, mode, effective_mode, "estimate_only_kind")
    if not force:
        hit = cache.get(key)
        if hit is not None and (effective_mode == "estimate" or hit.mode == "measure"):
            _resolve_event("plan_fft", key, mode, "hit", hit, cache)
            return hit
    if effective_mode == "measure":
        plan = measure_plan(key, iters=measure_iters, timings_out=timings_out)
        outcome = "measured"
    else:
        plan = estimate_plan(key)
        outcome = "miss"
        if degrade is not None:
            plan = dataclasses.replace(plan, degrade_reason=degrade)
    cache.put(plan)
    if cache.path:
        cache.save()
    _resolve_event("plan_fft", key, mode, outcome, plan, cache)
    return plan


def _degrade_event(
    key: ProblemKey, requested_mode: str, effective_mode: str, reason: str
) -> Optional[str]:
    """Emit+count a MEASURE->ESTIMATE degrade; returns the reason or None.

    The record the ROADMAP's wisdom-shipping story needs: a fleet whose
    plans never tune should be able to read *why* (pencil/oaconv kinds
    are analytic by construction, a jit trace forbids timing, a forced
    variant makes timing pointless) instead of inferring it from silence.
    """
    if requested_mode != "measure" or effective_mode == "measure":
        return None
    obs.emit(
        "plan.degrade",
        kind=key.kind,
        shape=key.shape,
        direction=key.direction,
        reason=reason,
    )
    obs.count(f"plan.degrade.{reason}")
    return reason


def _resolve_event(
    entry: str,
    key: ProblemKey,
    mode: str,
    outcome: str,
    plan: FFTPlan,
    cache: Optional[PlanCache],
) -> None:
    """One ``plan.resolve`` event per planner decision (+ outcome counter).

    ``outcome`` is the cache verdict: ``"hit"`` (cached plan served),
    ``"miss"`` (fresh ESTIMATE), ``"measured"`` (a timed sweep ran),
    ``"forced"`` (a scoped variant pin replaced the planned engine).
    """
    obs.count(f"plan.resolve.{outcome}")
    obs.emit(
        "plan.resolve",
        entry=entry,
        kind=key.kind,
        shape=key.shape,
        dtype=key.dtype,
        direction=key.direction,
        precision=key.precision,
        backend=key.backend,
        mode=mode,
        outcome=outcome,
        variant=plan.variant,
        plan_mode=plan.mode,
        est_time_s=plan.est_time_s,
        measured_us=plan.measured_us,
        degrade_reason=plan.degrade_reason,
        cache_path=getattr(cache, "path", None),
        key=key.cache_key(),
    )


def _active_config():
    """The scoped ``repro.xfft.config`` state (lazy import: xfft uses plan)."""
    from repro.xfft._config import get_config

    return get_config()


#: PlanCache instances memoized per config ``cache_dir`` so repeated calls
#: under the same scope accumulate hits in ONE cache (and one wisdom file).
_DIR_CACHES: Dict[str, PlanCache] = {}


def _cache_for_dir(cache_dir: str) -> PlanCache:
    path = os.path.join(cache_dir, "xfft_plans.json")
    cache = _DIR_CACHES.get(path)
    if cache is None:
        cache = _DIR_CACHES.setdefault(path, PlanCache(path=path))
    return cache


_WARNED_NO_TRACE_INTROSPECTION = False


def _trace_safe() -> bool:
    """True when no JAX trace is in flight (MEASURE may jit and time).

    Unavailable introspection degrades to False — a measure-mode config
    then falls back to ESTIMATE rather than risking a jit inside a trace
    — and says so once, so autotuning never stops working silently after
    a jax upgrade.
    """
    import warnings

    with warnings.catch_warnings():
        # newer jax deprecates the jax.core re-export; stay silent so
        # callers running with -W error::DeprecationWarning never trip
        warnings.simplefilter("ignore", DeprecationWarning)
        try:
            from jax.core import trace_state_clean
        except Exception:  # pragma: no cover - public re-export removed
            try:
                from jax._src.core import trace_state_clean
            except Exception:
                global _WARNED_NO_TRACE_INTROSPECTION
                if not _WARNED_NO_TRACE_INTROSPECTION:
                    _WARNED_NO_TRACE_INTROSPECTION = True
                    warnings.warn(
                        "jax trace-state introspection unavailable on this "
                        "jax version; mode='measure' resolution degrades to "
                        "ESTIMATE (use plan_fft(mode='measure') to tune "
                        "explicitly)",
                        RuntimeWarning,
                        stacklevel=3,
                    )
                return False
        try:
            return bool(trace_state_clean())
        except Exception:  # pragma: no cover - conservative inside traces
            return False


def resolve_call(
    kind: str,
    shape: Tuple[int, ...],
    dtype: str = "complex64",
    n_devices: int = 1,
    cache: Optional[PlanCache] = None,
    direction: str = "fwd",
    axes: Optional[Tuple[int, ...]] = None,
    mode: Optional[str] = None,
) -> FFTPlan:
    """Resolve one transform *call* to a concrete plan, config applied.

    The dispatch pipeline of every ``repro.xfft`` entry point (and of the
    legacy ``variant="auto"`` call sites):

    1. The active :func:`repro.xfft.config` scope supplies defaults: its
       ``cache_dir`` selects the wisdom cache (else the process-wide
       default cache), its ``mode`` decides what a cache miss costs, and
       its ``precision``/``backend`` constraints become part of the
       problem key — the planner then only considers registered engines
       (``repro.engines``) capable of that precision on those backends,
       and wisdom tuned under one constraint set never serves another.
    2. Cache hit -> the cached (possibly MEASURE) plan. Miss -> ESTIMATE,
       which is pure Python on analytic counts and therefore safe while
       JAX is tracing the surrounding computation. ``mode="measure"``
       upgrades misses (and cached ESTIMATE plans) to a timed sweep, but
       only outside a trace — inside one it degrades to ESTIMATE rather
       than jitting mid-trace.
    3. A scoped ``variant=...`` override replaces the planned schedule
       (the returned plan is marked ``mode="forced"`` and never cached:
       forced choices are opinions, not wisdom).

    Resilience: a cached plan whose engine is quarantined for this key
    (``repro.resilience`` circuit breaker open after a failure) is NOT
    served — the call re-resolves with quarantined engines excluded from
    the candidate sweep (outcome ``"quarantined"``), and the fallback
    plan is never written into the cache: wisdom must outlive the bench,
    the workaround must not.
    """
    cfg = _active_config()
    if cache is None:
        cache = _cache_for_dir(cfg.cache_dir) if cfg.cache_dir else default_cache()
    key = problem_key(kind, shape, dtype, n_devices, direction, axes,
                      cfg.precision, cfg.backends)
    mode = mode if mode is not None else cfg.mode
    breaker = quarantine()
    plan = cache.get(key)
    hit = plan is not None
    quarantined = hit and breaker.excluded(plan.variant, key)
    if quarantined:
        plan = None  # re-resolve around the benched engine
    affected = quarantined or breaker.affects(key)
    # A forced variant discards the planner's pick, so never pay a timed
    # sweep inside the scope — the pin exists to skip planning costs.
    # Either degrade (a variant pin, an analytic-only kind, a dirty trace)
    # is recorded as a plan.degrade event AND — for fresh plans — on the
    # plan's own degrade_reason, so wisdom files say why they are ESTIMATE.
    degrade = None
    if mode == "measure" and (plan is None or plan.mode != "measure"):
        if cfg.variant is not None:
            degrade = "forced_variant"
        elif kind in _ESTIMATE_ONLY_KINDS:
            degrade = "estimate_only_kind"
        elif affected:
            # Sweeping while an engine is benched would tune (and persist)
            # wisdom over a temporarily reduced engine population.
            degrade = "engine_quarantined"
    want_measure = (
        mode == "measure"
        and degrade is None
        and (plan is None or plan.mode != "measure")
        # A measure_timeout plan means the sweep already hung once for
        # this key; don't re-hang every call — plan_fft(force=True) is
        # the explicit re-tune path.
        and (plan is None or plan.degrade_reason != "measure_timeout")
    )
    measured = False
    if want_measure and not _trace_safe():
        degrade = "trace_not_clean"
        want_measure = False
    if degrade is not None:
        _degrade_event(key, "measure", "estimate", degrade)
    if want_measure:
        plan = cache.put(measure_plan(key))
        measured = True
        if cache.path:
            cache.save()
    elif plan is None:
        # ESTIMATE results stay in memory only: they are free to recompute,
        # and a whole-file save here could clobber wisdom another process
        # measured into the same file after we loaded it (it would also put
        # file I/O inside jit traces). Only MEASURE results earn a write.
        fresh = estimate_plan(key)
        if degrade is not None:
            fresh = dataclasses.replace(fresh, degrade_reason=degrade)
        # Plans resolved under an active quarantine are workarounds, not
        # wisdom: keep them out of the cache so the planned engine comes
        # back the moment its breaker closes.
        plan = fresh if affected else cache.put(fresh)
    if cfg.variant is not None and cfg.variant != plan.variant:
        # The key (and therefore plan.precision) already carries the scoped
        # precision; only the engine choice itself can be forced.
        plan = dataclasses.replace(
            plan, variant=cfg.variant, mode="forced", measured_us=None,
            degrade_reason=degrade,
        )
        _resolve_event("resolve_call", key, mode, "forced", plan, cache)
        return plan
    outcome = (
        "quarantined" if quarantined
        else "measured" if measured
        else "hit" if hit
        else "miss"
    )
    _resolve_event("resolve_call", key, mode, outcome, plan, cache)
    return plan


def resolve(
    kind: str,
    shape: Tuple[int, ...],
    dtype: str = "complex64",
    n_devices: int = 1,
    cache: Optional[PlanCache] = None,
    direction: str = "fwd",
) -> FFTPlan:
    """Cheap plan lookup for ``variant="auto"`` call sites (trace-safe).

    Pre-xfft spelling of :func:`resolve_call` under the kind's canonical
    axes; kept so bare-problem callers read naturally.
    """
    return resolve_call(kind, shape, dtype, n_devices, cache, direction)


def execute(plan: FFTPlan, x, mesh=None, axis: str = "data"):
    """Run ``x`` through the transform ``plan`` was made for.

    Pencil plans need the ``mesh`` (and device-axis name) the plan's
    ``n_devices`` refers to.

    Single-device kinds run through the resilience degradation ladder
    (:func:`repro.resilience.run_plan`): an engine failure is quarantined
    and the call retries the next-best healthy rung instead of raising.
    The pencil and oaconv2d composites dispatch directly — their variants
    compose per-pass engines that each ladder on their own.
    """
    kind = plan.key.kind
    inv = plan.key.direction == "inv"
    if kind == "fft1d":
        from repro.core.fft1d import fft_impl, ifft_impl

        impl = ifft_impl if inv else fft_impl
        return run_plan(plan, lambda v: impl(x, variant=v))
    if kind == "fft2d":
        from repro.core.fft2d import fft2_impl, ifft2_impl

        impl = ifft2_impl if inv else fft2_impl
        return run_plan(plan, lambda v: impl(x, variant=v))
    if kind == "rfft1d":
        from repro.core.rfft import irfft_impl, rfft_impl

        impl = irfft_impl if inv else rfft_impl
        return run_plan(plan, lambda v: impl(x, variant=v))
    if kind == "rfft2d":
        from repro.core.rfft import irfft2_impl, rfft2_impl

        impl = irfft2_impl if inv else rfft2_impl
        return run_plan(plan, lambda v: impl(x, variant=v))
    if kind == "fft2d_stream":
        from repro.core.fft2d import fft2_stream

        return run_plan(
            plan, lambda v: fft2_stream(x, variant=v, unroll=plan.unroll)
        )
    if kind == "fft2d_pencil":
        if mesh is None:
            raise ValueError("execute() needs mesh=... for a pencil plan")
        from repro.core.distributed import fft2_pencil_overlapped

        return fft2_pencil_overlapped(
            x, mesh, axis=axis, variant=plan.variant, chunks=plan.chunks
        )
    if kind == "oaconv2d":
        from repro.imaging.tiled import oaconvolve2

        if not (isinstance(x, (tuple, list)) and len(x) == 2):
            raise ValueError(
                "execute() needs x=(image, kernel) for an oaconv2d plan"
            )
        image, kernel = x
        return oaconvolve2(image, kernel, tile=plan.tile)
    raise ValueError(f"plan has unknown kind {kind!r}")
