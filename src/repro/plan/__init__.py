"""repro.plan — FFT execution planner, autotuner, and plan cache.

The software control unit: picks the 1D schedule, streaming unroll and
pencil chunking per ``(backend, device_kind, shape, dtype, n_devices)``
problem key, FFTW-style (ESTIMATE analytically, MEASURE by timing), and
remembers the decision in a versioned JSON-backed cache.
"""

from repro.plan.api import execute, plan_fft, resolve, resolve_call
from repro.plan.autotune import (
    chunk_candidates,
    estimate_plan,
    measure_plan,
    oaconv_tile_candidates,
    variant_candidates,
)
from repro.plan.cache import PlanCache, default_cache, reset_default_cache
from repro.plan.plan import (
    DIRECTIONS,
    KINDS,
    NORMS,
    PLAN_SCHEMA_VERSION,
    PRECISIONS,
    FFTPlan,
    ProblemKey,
    problem_key,
)


def __getattr__(name: str):
    # Deprecation alias (see repro.plan.plan.__getattr__): the engine list
    # lives in the repro.engines registry now; this stays importable for
    # pre-registry callers and always reflects the live registry.
    if name == "PLAN_VARIANTS":
        from repro.plan.plan import PLAN_VARIANTS

        return PLAN_VARIANTS
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "FFTPlan",
    "ProblemKey",
    "PlanCache",
    "DIRECTIONS",
    "KINDS",
    "NORMS",
    "PLAN_SCHEMA_VERSION",
    "PLAN_VARIANTS",
    "PRECISIONS",
    "chunk_candidates",
    "default_cache",
    "estimate_plan",
    "execute",
    "measure_plan",
    "oaconv_tile_candidates",
    "plan_fft",
    "problem_key",
    "reset_default_cache",
    "resolve",
    "resolve_call",
    "variant_candidates",
]
