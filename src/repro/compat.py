"""JAX version shims.

The codebase targets the modern ``jax.shard_map`` / ``jax.set_mesh`` /
``jax.sharding.AxisType`` API; this module backfills those spellings on
older jaxlibs (0.4.x) so the distributed paths and their tests run
everywhere the container does.

On 0.4.x the mapping is:

  jax.shard_map(..., axis_names=M)  -> experimental shard_map(auto=mesh-M)
  jax.set_mesh(mesh)                -> ``with mesh:`` resource-env context
                                       (bare-PartitionSpec wsc works there)
  jax.sharding.get_abstract_mesh()  -> the ambient physical mesh
  jax.lax.pcast(x, axes, to=...)    -> identity (no varying-axis tracking)
"""

from __future__ import annotations

import contextlib

import jax

_MODERN = hasattr(jax, "shard_map")

if _MODERN:
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _old_shard_map

    def shard_map(f=None, *, mesh, in_specs, out_specs, axis_names=None, **kw):
        """Translate the ``axis_names`` (manual axes) kwarg to 0.4.x's
        complementary ``auto`` (non-manual axes) kwarg."""
        if axis_names is not None:
            auto = frozenset(mesh.axis_names) - frozenset(axis_names)
            if auto:
                kw.setdefault("auto", auto)
                # 0.4.x partial-manual mode cannot do replication checking
                kw.setdefault("check_rep", False)
        if f is None:
            return lambda g: shard_map(
                g, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw
            )
        return _old_shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw
        )


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    try:
        from jax.sharding import AxisType

        return jax.make_mesh(
            tuple(axis_shapes),
            tuple(axis_names),
            axis_types=(AxisType.Auto,) * len(tuple(axis_names)),
        )
    except (ImportError, TypeError):
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))


def set_mesh(mesh):
    """Ambient-mesh context: ``jax.set_mesh`` on modern jax, the classic
    ``with mesh:`` resource environment on 0.4.x."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return _mesh_resource_env(mesh)


@contextlib.contextmanager
def _mesh_resource_env(mesh):
    with mesh:
        yield mesh


def get_abstract_mesh():
    """The mesh sharding decisions should be made against: the abstract mesh
    on modern jax, the ambient physical mesh (possibly empty) on 0.4.x."""
    if hasattr(jax.sharding, "get_abstract_mesh"):
        return jax.sharding.get_abstract_mesh()
    from jax._src.mesh import thread_resources

    return thread_resources.env.physical_mesh


def axis_size(axis_name):
    """``jax.lax.axis_size`` on modern jax; the psum-of-ones identity on
    0.4.x (inside shard_map/pmap the sum of 1 over the axis is its size)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def pcast(x, axes, *, to):
    """Varying-axis cast: real on modern jax, identity on 0.4.x (which has
    no manual-varying tracking to satisfy)."""
    if hasattr(jax.lax, "pcast"):
        return jax.lax.pcast(x, axes, to=to)
    return x


__all__ = ["shard_map", "make_mesh", "set_mesh", "get_abstract_mesh", "axis_size", "pcast"]
