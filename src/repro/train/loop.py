"""Fault-tolerant training loop: grad accumulation, checkpoint/restart,
straggler monitoring, optional int8-compressed gradient averaging.

The loop is deliberately boring: all failure handling is explicit and
testable (tests/train/test_resilience.py kills it mid-run and restarts)."""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.checkpoint import AsyncCheckpointer, latest_step, restore
from repro.optim import adamw_init, adamw_update, clip_by_global_norm
from repro.optim.compression import compressed_mean, init_error_state


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["params", "opt", "error_fb"],
    meta_fields=[],
)
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Any
    error_fb: Any = None  # compression error-feedback state

    def tree(self):
        t = {"params": self.params, "opt": self.opt}
        if self.error_fb is not None:
            t["error_fb"] = self.error_fb
        return t

    @classmethod
    def from_tree(cls, t):
        return cls(t["params"], t["opt"], t.get("error_fb"))


def make_train_step(
    loss_fn: Callable,
    *,
    accum: int = 1,
    max_norm: float = 1.0,
    peak_lr: float = 3e-4,
    warmup: int = 20,
    total: int = 10_000,
    compress: bool = False,
    cast_params=None,
):
    """(state, batches) -> (state, metrics). ``batches`` is a pytree whose
    leaves carry a leading [accum] dim when accum > 1.

    ``cast_params=jnp.bfloat16`` differentiates at a bf16 view of the f32
    master weights: FSDP weight gathers AND gradient reductions then move
    bf16 instead of f32 (2× collective cut, §Perf mixtral iteration)."""

    def grad_one(params, batch):
        if cast_params is not None:
            view = jax.tree.map(
                lambda p: p.astype(cast_params)
                if p.dtype == jnp.float32
                else p,
                params,
            )
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                view, batch
            )
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
        return loss, metrics, grads

    def step(state: TrainState, batches) -> tuple[TrainState, dict]:
        params = state.params
        if accum == 1:
            loss, metrics, grads = grad_one(params, batches)
        else:
            def body(carry, micro):
                g_sum, l_sum = carry
                loss, _, grads = grad_one(params, micro)
                return (
                    jax.tree.map(jnp.add, g_sum, grads),
                    l_sum + loss,
                ), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (g_sum, l_sum), _ = jax.lax.scan(body, (zeros, jnp.zeros(())), batches)
            grads = jax.tree.map(lambda g: g / accum, g_sum)
            loss = l_sum / accum
            metrics = {"loss": loss}

        error_fb = state.error_fb
        if compress:
            if error_fb is None:
                error_fb = init_error_state(grads)
            grads, error_fb = compressed_mean(grads, error_fb)

        grads, gnorm = clip_by_global_norm(grads, max_norm)
        new_params, new_opt = adamw_update(
            params, grads, state.opt, peak_lr=peak_lr, warmup=warmup, total=total
        )
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        return TrainState(new_params, new_opt, error_fb), metrics

    return step


class StragglerMonitor:
    """EWMA step-time monitor. In a multi-host deployment the flag triggers
    re-balancing / hot-spare swap; here it records and reports."""

    def __init__(self, alpha=0.2, threshold=2.0):
        self.alpha, self.threshold = alpha, threshold
        self.ewma = None
        self.flags: list[tuple[int, float]] = []

    def record(self, step: int, dt: float) -> bool:
        if self.ewma is None:
            self.ewma = dt
            return False
        slow = dt > self.threshold * self.ewma
        if slow:
            self.flags.append((step, dt))
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return slow


class TrainLoop:
    """Checkpointed, restartable loop around a jitted train step."""

    def __init__(
        self,
        model,
        *,
        ckpt_dir: str,
        batch_fn: Callable[[int], Any],
        step_fn=None,
        save_every: int = 50,
        accum: int = 1,
        peak_lr: float = 3e-4,
        compress: bool = False,
        jit: bool = True,
    ):
        self.model = model
        self.ckpt_dir = ckpt_dir
        self.batch_fn = batch_fn
        self.save_every = save_every
        self.ckpt = AsyncCheckpointer(ckpt_dir)
        self.monitor = StragglerMonitor()
        raw = step_fn or make_train_step(
            model.loss_fn, accum=accum, peak_lr=peak_lr, compress=compress
        )
        self.step_fn = jax.jit(raw) if jit else raw

    def init_or_restore(self, key) -> tuple[TrainState, int]:
        start = latest_step(self.ckpt_dir)
        params = self.model.init(key)
        state = TrainState(params, adamw_init(params))
        if start is not None:
            state = TrainState.from_tree(
                restore(self.ckpt_dir, start, state.tree())
            )
            return state, start
        return state, 0

    def run(self, key, n_steps: int, *, fail_at: int | None = None) -> dict:
        """Runs to ``n_steps`` global steps (resuming if checkpoints exist).
        ``fail_at`` raises mid-run to simulate preemption (tests)."""
        state, start = self.init_or_restore(key)
        losses = {}
        for step in range(start, n_steps):
            if fail_at is not None and step == fail_at:
                self.ckpt.wait()
                raise RuntimeError(f"simulated preemption at step {step}")
            t0 = time.perf_counter()
            batch = self.batch_fn(step)
            state, metrics = self.step_fn(state, batch)
            losses[step] = float(metrics["loss"])
            self.monitor.record(step, time.perf_counter() - t0)
            if (step + 1) % self.save_every == 0 or step + 1 == n_steps:
                self.ckpt.save_async(step + 1, state.tree())
        self.ckpt.wait()
        return losses
