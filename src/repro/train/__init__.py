from repro.train.loop import TrainLoop, TrainState, make_train_step

__all__ = ["TrainLoop", "TrainState", "make_train_step"]
