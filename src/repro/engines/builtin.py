"""The six seed engines, registered with their true capability envelopes.

The engine *bodies* keep living where they grew — the jnp schedules in
``repro.core`` and the fused Pallas kernels in ``repro.kernels`` — but
the planner no longer knows their names: everything it used to hardcode
(the ``PLAN_VARIANTS`` tuple, the fused-kind/device/VMEM gating in
``variant_candidates``, the per-variant cost tables in ``autotune``) now
reads off these specs.

Capability parity with the pre-registry planner is deliberate and tested:

* the four jnp engines serve every problem kind at any device count;
* the fused kernels serve the 1D/2D complex+real kinds only, single
  device, power-of-two dims, and only while a 1D row tile fits the VMEM
  budget (``working_set``) — the exact gate ``variant_candidates`` used
  to open-code.
"""

from __future__ import annotations

import functools

from repro.engines.registry import CostHints, EngineSpec, register_engine

#: Every planner kind: the jnp schedules are the universal fallback (the
#: stream/pencil/oaconv paths compose them per 1D pass).
_JNP_KINDS = (
    "fft1d", "fft2d", "fft2d_stream", "fft2d_pencil", "rfft1d", "rfft2d",
    "oaconv2d",
)

#: Kinds whose entry points dispatch to the fused Pallas kernels.
_FUSED_KINDS = ("fft1d", "fft2d", "rfft1d", "rfft2d")


def _core_ops(name: str):
    """Op factory shared by all builtin engines: the ``repro.core`` engine
    entries under a concrete variant (their dispatch chains terminate on
    builtin names, so this never re-enters the registry)."""

    def factory(kind: str, direction: str):
        inv = direction == "inv"
        if kind == "fft1d":
            from repro.core.fft1d import fft_impl, ifft_impl

            return functools.partial(ifft_impl if inv else fft_impl, variant=name)
        if kind == "fft2d":
            from repro.core.fft2d import fft2_impl, ifft2_impl

            return functools.partial(ifft2_impl if inv else fft2_impl, variant=name)
        if kind == "rfft1d":
            from repro.core.rfft import irfft_impl, rfft_impl

            return functools.partial(irfft_impl if inv else rfft_impl, variant=name)
        if kind == "rfft2d":
            from repro.core.rfft import irfft2_impl, rfft2_impl

            return functools.partial(irfft2_impl if inv else rfft2_impl, variant=name)
        if kind == "fft2d_stream" and not inv:
            from repro.core.fft2d import fft2_stream

            return functools.partial(fft2_stream, variant=name)
        # fft2d_pencil needs a mesh and oaconv2d a (image, kernel) pair;
        # both execute at the plan level (repro.plan.execute), not here.
        return None

    return factory


def _fused_predicate(key) -> bool:
    """Fused kernels need power-of-two transform dims (and a real 2D frame
    to actually be 2D)."""
    if key.kind in ("fft2d", "rfft2d"):
        if len(key.shape) < 2:
            return False
        dims = key.shape[-2:]
    else:
        dims = key.shape[-1:]
    return all(d >= 2 and (d & (d - 1)) == 0 for d in dims)


def _fused_working_set(key):
    """Smallest VMEM residency the fused path needs: one 1D row tile of the
    longest transform dim (the 2D kernels' unfused failover still runs the
    1D kernel per pass, so a row tile must fit for ANY fused plan)."""
    if key.kind in ("fft2d", "rfft2d"):
        if len(key.shape) < 2:
            return None
        dims = key.shape[-2:]
    else:
        dims = key.shape[-1:]
    from repro.kernels.fft_radix2 import _FFT1_WORKING_ARRAYS  # lazy: pallas

    return max(dims) * 4 * _FFT1_WORKING_ARRAYS


def _register_builtin_engines() -> None:
    # The four jnp schedules: per-variant memory-traffic factors and
    # dispatch overheads exactly as the pre-registry cost tables had them.
    jnp_engines = (
        ("looped", CostHints(traffic_factor=6.0, stage_overhead_s=3.0e-6,
                             entry_overhead_s=5.0e-6), 2),
        ("unrolled", CostHints(traffic_factor=6.0, stage_overhead_s=0.5e-6), 2),
        ("stockham", CostHints(traffic_factor=4.0, stage_overhead_s=0.8e-6), 2),
        ("radix4", CostHints(traffic_factor=4.0, stage_overhead_s=0.8e-6,
                             flop_scale=0.85), 4),
    )
    for name, cost, radix in jnp_engines:
        register_engine(EngineSpec(
            name=name,
            backend="jnp",
            kinds=_JNP_KINDS,
            radix=radix,
            cost=cost,
            ops=_core_ops(name),
            # stockham is the canonical always-works rung: pure jnp ops,
            # every kind, no VMEM cliff — the degradation ladder's bottom.
            reliable=(name == "stockham"),
        ), _protect=True)
    for name, radix, flop_scale in (("fused", 2, 1.0), ("fused_r4", 4, 0.85)):
        register_engine(EngineSpec(
            name=name,
            backend="pallas",
            kinds=_FUSED_KINDS,
            radix=radix,
            fused=True,
            single_device_only=True,
            working_set=_fused_working_set,
            predicate=_fused_predicate,
            cost=CostHints(traffic_factor=4.0, stage_overhead_s=0.8e-6,
                           flop_scale=flop_scale),
            ops=_core_ops(name),
        ), _protect=True)


_register_builtin_engines()
