"""``reference_x64`` — the first post-registry backend: real double precision.

The paper's butterfly datapath is complex64 end to end, and so are the six
seed engines. Scientific workloads (k-space reconstruction, long
correlation chains) sometimes need a float64 reference path, and ROADMAP
has carried "a real ``precision='double'`` path" since the xfft PR. This
engine is that path: ``jnp.fft`` executed under ``jax.enable_x64`` so the
whole transform — input cast, twiddles, accumulation, output — is
complex128, regardless of the process-wide x64 flag. It registers with
``precisions=("double",)`` only, so the planner proposes it exactly when a
scope asks for ``xfft.config(precision="double")`` (or builds a
double-precision :class:`~repro.plan.plan.ProblemKey` directly) and never
lets it leak into single-precision sweeps.

It is a *reference* engine: correctness first (≤1e-10 vs ``numpy.fft`` in
the conformance suite), speed second — the cost hints model it like a
bandwidth-lean library transform at double the bytes per element.
"""

from __future__ import annotations

from repro.engines.registry import CostHints, engine

_KINDS = ("fft1d", "fft2d", "fft2d_stream", "rfft1d", "rfft2d")


@engine(
    "reference_x64",
    backend="x64",
    kinds=_KINDS,
    precisions=("double",),
    dtypes=("complex128", "float64"),
    requires_x64=True,
    # The double ladder's always-works rung: jnp.fft under enable_x64,
    # immune to quarantine exhaustion like stockham is for single.
    reliable=True,
    cost=CostHints(traffic_factor=4.0, stage_overhead_s=0.8e-6),
)
def _reference_x64_ops(kind: str, direction: str):
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    inv = direction == "inv"

    def under_x64(fn, real_in: bool = False):
        # The cast MUST happen inside the enable_x64 scope: outside it,
        # jax canonicalizes explicit 64-bit dtypes back down to 32.
        def run(x):
            with enable_x64():
                x = jnp.asarray(x)
                x = x.astype(jnp.float64 if real_in else jnp.complex128)
                return fn(x)

        return run

    if kind == "fft1d":
        return under_x64(jnp.fft.ifft if inv else jnp.fft.fft)
    if kind == "fft2d":
        return under_x64(jnp.fft.ifft2 if inv else jnp.fft.fft2)
    if kind == "rfft1d":
        if inv:
            return under_x64(jnp.fft.irfft)
        return under_x64(jnp.fft.rfft, real_in=True)
    if kind == "rfft2d":
        if inv:
            return under_x64(jnp.fft.irfft2)
        return under_x64(jnp.fft.rfft2, real_in=True)
    if kind == "fft2d_stream" and not inv:
        # Same ping-pong dataflow as repro.core.fft2d.fft2_stream (rows of
        # frame t and columns of frame t-1 in one scan step, a drain frame
        # to flush the pipe), self-contained so the whole scan — carried
        # RAM state included — lives inside enable_x64 at complex128.
        def stream(frames):
            import jax

            with enable_x64():
                frames = jnp.asarray(frames).astype(jnp.complex128)
                if frames.ndim < 3:
                    raise ValueError(
                        "fft2_stream expects (T, H, W) or (T, ..., H, W)"
                    )

                def step(ram, frame):
                    return (jnp.fft.fft(frame, axis=-1),
                            jnp.fft.fft(ram, axis=-2))

                seq = jnp.concatenate([frames, jnp.zeros_like(frames[:1])], 0)
                _, outs = jax.lax.scan(step, jnp.zeros_like(frames[0]), seq)
                return outs[1:]  # drop the pipeline-fill output

        return stream
    return None
