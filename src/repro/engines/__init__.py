"""repro.engines — pluggable FFT engine registry (the planner's codelets).

The paper's processor reuses one butterfly array under a control unit;
the software control unit is ``repro.plan``, and this package is the pool
of engines it schedules. Every engine — the four jnp schedules, the two
fused Pallas kernels, the double-precision ``reference_x64`` backend, and
any third-party registration — is an :class:`EngineSpec` describing what
it can do (kinds × precisions × backend × VMEM needs) and how to run it.
The planner enumerates the registry by capability; adding a backend or a
precision is a registration, not a planner edit.

    from repro.engines import iter_engines, get_engine, engine, CostHints

    for spec in iter_engines(kind="fft2d", precision="single"):
        print(spec.name, spec.backend, spec.radix)

Importing this package registers the built-in engines.
"""

from repro.engines.registry import (
    PRECISIONS,
    CostHints,
    EngineSpec,
    engine,
    get_engine,
    has_engine,
    iter_engines,
    register_engine,
    registered_backends,
    registered_variants,
    unregister_engine,
)

# Importing these modules registers the built-in engines as a side effect.
from repro.engines import builtin as _builtin  # noqa: F401
from repro.engines import x64 as _x64  # noqa: F401

__all__ = [
    "PRECISIONS",
    "CostHints",
    "EngineSpec",
    "apply_engine",
    "engine",
    "get_engine",
    "has_engine",
    "iter_engines",
    "register_engine",
    "registered_backends",
    "registered_variants",
    "unregister_engine",
]


def apply_engine(name: str, kind: str, x, *, direction: str = "fwd",
                 axis: int | None = None):
    """Run ``x`` through engine ``name``'s executor for ``(kind, direction)``.

    This is the fallback the ``repro.core`` engine entries take for any
    variant their builtin dispatch chains do not recognise — which is how
    a registered engine (e.g. ``reference_x64``) serves every existing
    call path (``repro.xfft``, ``repro.plan.execute``, MEASURE sweeps,
    the serve layer) without those layers learning its name.

    ``x`` must be the caller's ORIGINAL array: every jnp touch (asarray,
    moveaxis, ...) happens in here, inside ``jax.enable_x64`` for engines
    that require it — outside that scope jax re-canonicalizes 64-bit
    dtypes down to 32 and a double input would be silently truncated
    before the engine ever saw it. ``axis`` (1D kinds only) names the
    transform axis; the executor itself always sees axes-last layout.

    The ``engine.apply`` dispatch span is NOT emitted here: it lives in
    :func:`repro.resilience.ladder.run_plan`, which wraps every planned
    and forced dispatch for *all* engines (builtin chains included) and
    feeds the calibration ledger observed durations. Emitting here too
    would double-count registry engines — and MEASURE sweeps, which call
    executors directly, must stay out of the observed population anyway.
    """
    spec = get_engine(name)
    fn = spec.op(kind, direction)

    def run():
        import jax.numpy as jnp

        arr = jnp.asarray(x)
        if axis is not None and kind in ("fft1d", "rfft1d"):
            ax = axis % arr.ndim
            if ax != arr.ndim - 1:
                return jnp.moveaxis(fn(jnp.moveaxis(arr, ax, -1)), -1, ax)
        return fn(arr)

    if spec.requires_x64:
        from jax.experimental import enable_x64

        with enable_x64():
            return run()
    return run()
