"""The engine registry: capability-described FFT engines, FFTW-style.

FFTW3 owes its longevity to the planner/codelet split: codelets declare
what they can do, the planner enumerates whatever is registered, and new
codelets are registrations rather than planner edits. This module is that
split for the repo. An :class:`EngineSpec` is the codelet descriptor — a
name (the ``plan.variant`` value), an execution *backend* family, the
problem kinds and precisions it can serve, its radix/fusion geometry, a
VMEM working-set callback the planner sizes against, and the cost-model
hints ESTIMATE ranks with. ``repro.plan`` enumerates the registry by
capability (kind × precision × backend × device count × VMEM fit)
instead of a hardcoded variant tuple, so a new backend, precision or
kernel lands as::

    from repro.engines import CostHints, engine

    @engine("my_split_radix", backend="jnp", kinds=("fft1d", "fft2d"),
            cost=CostHints(traffic_factor=4.0, flop_scale=0.8))
    def my_ops(kind, direction):
        ...  # return the transform callable for (kind, direction)

and is immediately a planner candidate, a MEASURE sweep entrant, a
``benchmarks/fft_bench.py`` row and a ``tests/engines`` conformance case.

This module imports nothing from the rest of the repo at module scope —
``repro.plan``, ``repro.core`` and ``repro.xfft`` all build on it.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

__all__ = [
    "PRECISIONS",
    "CostHints",
    "EngineSpec",
    "engine",
    "get_engine",
    "has_engine",
    "iter_engines",
    "register_engine",
    "registered_backends",
    "registered_variants",
    "unregister_engine",
]

#: Numeric precisions an engine may declare: "single" is the paper's
#: complex64/float32 datapath, "double" is complex128/float64.
PRECISIONS = ("single", "double")


@dataclasses.dataclass(frozen=True)
class CostHints:
    """ESTIMATE-model coefficients for one engine (see ``plan.autotune``).

    traffic_factor   — HBM element-touches per butterfly pass (gather-heavy
                       schedules pay ~6, contiguous Stockham-style ~4).
    stage_overhead_s — per-stage dispatch overhead (seconds).
    flop_scale       — multiplier on the radix-2 butterfly FLOP count
                       (radix-4 merges twiddles: ~0.85).
    entry_overhead_s — fixed per-call cost (e.g. entering a ``fori_loop``
                       with carried state).
    """

    traffic_factor: float = 4.0
    stage_overhead_s: float = 0.8e-6
    flop_scale: float = 1.0
    entry_overhead_s: float = 0.0


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """One registered FFT engine: identity, capabilities, cost, executors.

    name               — registry key; the value ``FFTPlan.variant`` holds.
    backend            — execution-backend family ("jnp" = plain XLA ops,
                         "pallas" = the fused TPU kernels, "x64" = the
                         double-precision reference). ``xfft.config(
                         backend=...)`` scopes planning to a subset.
    kinds              — ``repro.plan`` problem kinds the engine serves.
    precisions         — subset of :data:`PRECISIONS`.
    dtypes             — canonical I/O dtype names, documentation-grade.
    radix              — butterfly radix (stage count = log_radix N).
    fused              — True for whole-transform-in-VMEM Pallas kernels.
    reliable           — True marks an always-works degradation rung (plain
                         XLA ops, no lowering cliffs): when the resilience
                         quarantine would exclude every candidate for a
                         problem, reliable engines come back regardless so
                         the ladder always has a bottom.
    single_device_only — engine cannot take part in multi-device plans.
    requires_x64       — engine computes under ``jax.enable_x64``.
    working_set        — optional callback ``(ProblemKey) -> bytes|None``:
                         the smallest VMEM residency the engine needs for
                         that problem; the planner drops the engine when it
                         exceeds ``repro.kernels.ops.vmem_budget_bytes()``.
    predicate          — optional extra capability check ``(ProblemKey) ->
                         bool`` for constraints the generic fields cannot
                         express (e.g. power-of-two transform dims).
    cost               — :class:`CostHints` for the analytic ESTIMATE mode.
    ops                — op factory ``(kind, direction) -> callable|None``;
                         the callable takes one array in the kind's
                         canonical layout (transform axes last) and returns
                         the transform under the engine's native backward
                         convention.
    """

    name: str
    backend: str
    kinds: Tuple[str, ...]
    precisions: Tuple[str, ...] = ("single",)
    dtypes: Tuple[str, ...] = ("complex64", "float32")
    radix: int = 2
    fused: bool = False
    reliable: bool = False
    single_device_only: bool = False
    requires_x64: bool = False
    working_set: Optional[Callable] = None
    predicate: Optional[Callable] = None
    cost: CostHints = dataclasses.field(default_factory=CostHints)
    ops: Optional[Callable] = None

    def supports(self, key) -> bool:
        """True when this engine may serve ``key`` (the planner's filter:
        kind × precision × backend scope × device count × VMEM fit)."""
        if key.kind not in self.kinds:
            return False
        if getattr(key, "precision", "single") not in self.precisions:
            return False
        backends = getattr(key, "backends", ())
        if backends and self.backend not in backends:
            return False
        if self.single_device_only and key.n_devices != 1:
            return False
        if self.predicate is not None and not self.predicate(key):
            return False
        if self.working_set is not None:
            ws = self.working_set(key)
            if ws is not None:
                from repro.kernels.ops import vmem_budget_bytes  # lazy

                if ws > vmem_budget_bytes():
                    return False
        return True

    def op(self, kind: str, direction: str = "fwd") -> Callable:
        """The executor for ``(kind, direction)``; raises when unserved."""
        fn = None
        if kind in self.kinds and self.ops is not None:
            fn = self.ops(kind, direction)
        if fn is None:
            raise ValueError(
                f"engine {self.name!r} has no executor for kind {kind!r} "
                f"direction {direction!r} (declared kinds: {self.kinds})"
            )
        return fn


_REGISTRY: Dict[str, EngineSpec] = {}

#: Names whose execution is fused into the ``repro.core`` dispatch chains
#: for speed (the six seed engines). Replacing or removing one would leave
#: dispatch running the ORIGINAL body while the registry advertised the
#: replacement — a silent lie — so registration refuses instead.
_PROTECTED: set = set()


def register_engine(
    spec: EngineSpec, *, replace: bool = False, _protect: bool = False
) -> EngineSpec:
    """Add ``spec`` to the registry (the non-decorator spelling).

    Validates the declaration eagerly — a typo'd kind or precision should
    fail at registration, not at the first planning call — and rejects
    duplicate names unless ``replace=True``. The six seed engines cannot
    be replaced at all: their bodies are fused into the core dispatch
    chains, so an override would never execute — register under a new
    name instead.
    """
    if not spec.name or not isinstance(spec.name, str):
        raise ValueError(f"engine name must be a non-empty string, got {spec.name!r}")
    if not spec.kinds:
        raise ValueError(f"engine {spec.name!r} declares no problem kinds")
    from repro.plan.plan import KINDS  # lazy: plan builds on this module

    for kind in spec.kinds:
        if kind not in KINDS:
            raise ValueError(
                f"engine {spec.name!r} declares unknown kind {kind!r}; "
                f"want members of {KINDS}"
            )
    for precision in spec.precisions:
        if precision not in PRECISIONS:
            raise ValueError(
                f"engine {spec.name!r} declares unknown precision "
                f"{precision!r}; want members of {PRECISIONS}"
            )
    if spec.name in _REGISTRY:
        if spec.name in _PROTECTED:
            raise ValueError(
                f"engine {spec.name!r} is a builtin fused into the core "
                "dispatch chains and cannot be replaced; register your "
                "engine under a new name"
            )
        if not replace:
            raise ValueError(
                f"engine {spec.name!r} is already registered "
                "(pass replace=True to override)"
            )
    _REGISTRY[spec.name] = spec
    if _protect:
        _PROTECTED.add(spec.name)
    return spec


def unregister_engine(name: str) -> None:
    """Remove an engine (plugin teardown / tests); unknown names are a
    no-op. Builtin engines cannot be removed — core dispatch would keep
    executing them while the planner denied they exist."""
    if name in _PROTECTED:
        raise ValueError(f"builtin engine {name!r} cannot be unregistered")
    _REGISTRY.pop(name, None)


def engine(name: str, **fields):
    """Decorator-based registration: decorate the op factory.

    The decorated function is the spec's ``ops`` field — it receives
    ``(kind, direction)`` and returns the transform callable (or ``None``
    for combinations it cannot serve). Returns the registered
    :class:`EngineSpec`.
    """

    def deco(ops_factory: Callable) -> EngineSpec:
        return register_engine(EngineSpec(name=name, ops=ops_factory, **fields))

    return deco


def get_engine(name: str) -> EngineSpec:
    """Look an engine up by name; the error names what IS registered."""
    spec = _REGISTRY.get(name)
    if spec is None:
        raise ValueError(
            f"unknown engine {name!r}; registered engines: {tuple(_REGISTRY)}"
        )
    return spec


def has_engine(name: str) -> bool:
    return name in _REGISTRY


def iter_engines(
    kind: Optional[str] = None,
    precision: Optional[str] = None,
    backend: Optional[str] = None,
) -> Tuple[EngineSpec, ...]:
    """Registered engines in registration order, optionally filtered."""
    out = []
    for spec in _REGISTRY.values():
        if kind is not None and kind not in spec.kinds:
            continue
        if precision is not None and precision not in spec.precisions:
            continue
        if backend is not None and spec.backend != backend:
            continue
        out.append(spec)
    return tuple(out)


def registered_variants(precision: Optional[str] = None) -> Tuple[str, ...]:
    """Engine names, optionally restricted to one precision (the
    ``PLAN_VARIANTS`` deprecation alias derives from this)."""
    return tuple(s.name for s in iter_engines(precision=precision))


def registered_backends() -> Tuple[str, ...]:
    """Distinct backend families currently registered (sorted)."""
    return tuple(sorted({s.backend for s in _REGISTRY.values()}))
