"""whisper-medium [audio]: 24L enc + 24L dec, d_model=1024, 16H (GQA kv=16),
d_ff=4096, vocab=51865 — enc-dec, conv frontend STUB (input_specs provides
precomputed frame embeddings). [arXiv:2212.04356; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,
    n_enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=51865,
    enc_frames=1500,
    act="gelu",
    rope_theta=10000.0,
    subquadratic=False,   # full attention -> long_500k skipped
)
