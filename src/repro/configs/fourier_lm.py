"""fourier_lm [spectral] — the PAPER'S OWN architecture in the framework:
an FNet-style masked LM whose token-mixing sublayer is the paper's
area-efficient 2D FFT engine (Re(FFT2) over (seq, d_model)). Bidirectional
mixing => encoder-style MLM; no decode shapes."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="fourier_lm",
    family="spectral",
    n_layers=12,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=32768,
    act="gelu",
    seq_pad_to_pow2=True,
    fft_variant="auto",
    subquadratic=True,     # O(L log L) mixing
)
