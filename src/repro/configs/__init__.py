"""Assigned architecture registry (``--arch <id>``)."""

from repro.configs.registry import (
    ARCH_IDS,
    SHAPES,
    get_config,
    input_specs,
    shape_skips,
    smoke_config,
)

__all__ = [
    "ARCH_IDS",
    "SHAPES",
    "get_config",
    "input_specs",
    "shape_skips",
    "smoke_config",
]
