"""internvl2-76b [vlm]: 80L, d_model=8192, 64H (GQA kv=8), d_ff=28672,
vocab=128256 — InternViT + InternLM2. [arXiv:2404.16821; unverified]
Backbone only; the ViT patch frontend is a STUB (input_specs provides
precomputed patch embeddings prepended to the token sequence)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    n_patches=256,
    act="swiglu",
    rope_theta=1000000.0,
    subquadratic=False,
)
