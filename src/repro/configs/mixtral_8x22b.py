"""mixtral-8x22b [moe]: 56L, d_model=6144, 48H (GQA kv=8), MoE 8 experts
top-2 (expert d_ff=16384), vocab=32768, SWA window 4096.
[arXiv:2401.04088; hf]. SWA -> sub-quadratic -> long_500k runs with a
ring KV cache of window size."""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=32768,
    sliding_window=4096,
    moe=MoEConfig(
        n_experts=8,
        top_k=2,
        d_ff_expert=16384,
        router_norm="softmax",
        capacity_factor=1.25,
        impl="grouped_local",
    ),
    subquadratic=True,
)
