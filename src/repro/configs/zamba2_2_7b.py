"""zamba2-2.7b [hybrid]: 54L Mamba2, d_model=2560, shared attn block 32H
(GQA kv=32) every 6 layers, d_ff=10240, vocab=32000, ssm_state=64.
[arXiv:2411.15242; hf]. Sub-quadratic (SSM + a few shared-attention
invocations) -> long_500k runs."""

from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    shared_attn_every=6,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=256),
    subquadratic=True,
)
