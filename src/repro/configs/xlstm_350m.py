"""xlstm-350m [ssm]: 24L (alternating mLSTM/sLSTM), d_model=1024, 4H
(GQA kv=4), d_ff=0 (blocks carry their own projections), vocab=50304.
[arXiv:2405.04517; unverified]. O(1) state -> long_500k runs."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    slstm_every=2,
    subquadratic=True,
)
