"""deepseek-v3-671b [moe]: 61L, d_model=7168, 128H MLA, MoE 1 shared + 256
routed top-8 (expert d_ff=2048), vocab=129280, MTP. First 3 layers dense
(d_ff=18432). [arXiv:2412.19437; hf]"""

from repro.models.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,            # dense-layer FFN width
    vocab=129280,
    attention="mla",
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        n_experts=256,
        top_k=8,
        d_ff_expert=2048,
        n_shared_experts=1,
        n_dense_layers=3,
        router_norm="sigmoid",
        capacity_factor=1.25,
        impl="grouped_local",   # ep_a2a variant benchmarked in §Perf
    ),
    mtp=True,
    subquadratic=False,
)
