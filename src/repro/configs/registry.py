"""Architecture registry + assigned input shapes + dry-run input specs.

Shapes (assignment):
  train_4k      seq_len=4096   global_batch=256   (training)
  prefill_32k   seq_len=32768  global_batch=32    (inference-prefill)
  decode_32k    seq_len=32768  global_batch=128   (one token, KV=seq_len)
  long_500k     seq_len=524288 global_batch=1     (one token; sub-quadratic only)
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import MLAConfig, ModelConfig, SSMConfig

_MODULES = {
    "whisper-medium": "whisper_medium",
    "starcoder2-3b": "starcoder2_3b",
    "llama3.2-3b": "llama3_2_3b",
    "glm4-9b": "glm4_9b",
    "stablelm-12b": "stablelm_12b",
    "zamba2-2.7b": "zamba2_2_7b",
    "xlstm-350m": "xlstm_350m",
    "internvl2-76b": "internvl2_76b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "mixtral-8x22b": "mixtral_8x22b",
    "fourier_lm": "fourier_lm",
}

ARCH_IDS = [k for k in _MODULES if k != "fourier_lm"]  # the 10 assigned
ALL_IDS = list(_MODULES)

SHAPES: dict[str, dict[str, Any]] = {
    "train_4k": {"seq": 4096, "batch": 256, "kind": "train"},
    "prefill_32k": {"seq": 32768, "batch": 32, "kind": "prefill"},
    "decode_32k": {"seq": 32768, "batch": 128, "kind": "decode"},
    "long_500k": {"seq": 524288, "batch": 1, "kind": "decode"},
}


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def shape_skips(cfg: ModelConfig, shape: str) -> str | None:
    """Returns a skip reason or None (assignment skip policy)."""
    if shape == "long_500k" and not cfg.subquadratic:
        return "pure full attention — long_500k needs sub-quadratic mixing (DESIGN.md §6)"
    if shape in ("decode_32k", "long_500k") and cfg.family == "spectral":
        return "encoder-style MLM (bidirectional FNet mixing) — no causal decode step"
    return None


def smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests (one step, no NaNs)."""
    cfg = get_config(arch)
    common = dict(
        vocab=512,
        rope_theta=10000.0,
        attn_block_q=16,
        attn_block_k=16,
        remat=False,
        compute_dtype="float32",
    )
    if cfg.family == "audio":
        return cfg.scaled(
            n_layers=2, n_enc_layers=2, d_model=32, n_heads=4, n_kv_heads=4,
            d_ff=64, enc_frames=8, **common,
        )
    if cfg.family == "vlm":
        return cfg.scaled(
            n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
            n_patches=4, **common,
        )
    if cfg.family == "hybrid":
        return cfg.scaled(
            n_layers=4, d_model=32, n_heads=4, n_kv_heads=4, d_ff=64,
            shared_attn_every=2,
            ssm=SSMConfig(d_state=8, d_conv=4, expand=2, head_dim=8, chunk=8),
            **common,
        )
    if cfg.family == "ssm":
        return cfg.scaled(n_layers=4, d_model=32, n_heads=4, n_kv_heads=4, d_ff=0, **common)
    if cfg.family == "moe":
        moe = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=2, d_ff_expert=32,
            n_dense_layers=min(cfg.moe.n_dense_layers, 1), capacity_factor=2.0,
        )
        extra: dict[str, Any] = {"moe": moe}
        if cfg.attention == "mla":
            extra["mla"] = MLAConfig(
                q_lora_rank=16, kv_lora_rank=8, qk_nope_head_dim=8,
                qk_rope_head_dim=4, v_head_dim=8,
            )
        if cfg.sliding_window:
            extra["sliding_window"] = 8
        return cfg.scaled(
            n_layers=3, d_model=32, n_heads=4, n_kv_heads=4, d_ff=64, **extra, **common,
        )
    if cfg.family == "spectral":
        return cfg.scaled(n_layers=2, d_model=32, n_heads=4, n_kv_heads=4, d_ff=64, **common)
    # dense
    return cfg.scaled(
        n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
        head_dim=8, **common,
    )


def input_specs(
    cfg: ModelConfig,
    shape: str,
    *,
    seq: int | None = None,
    batch: int | None = None,
) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of a (arch × shape)
    cell — weak-type-correct, shardable, zero allocation.

    For train/prefill: the batch dict. For decode: {"token", "pos"} (caches
    are built separately via ``jax.eval_shape`` on the cache initialiser).
    """
    info = SHAPES[shape]
    s = seq if seq is not None else info["seq"]
    b = batch if batch is not None else info["batch"]
    kind = info["kind"]
    i32 = jnp.int32
    f32 = jnp.float32

    if kind == "decode":
        return {
            "token": jax.ShapeDtypeStruct((b, 1), i32),
            "pos": jax.ShapeDtypeStruct((), i32),
        }

    specs: dict[str, Any] = {}
    if cfg.family == "audio":
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        specs["frames"] = jax.ShapeDtypeStruct((b, cfg.enc_frames, cfg.d_model), f32)
    elif cfg.family == "vlm":
        specs["tokens"] = jax.ShapeDtypeStruct((b, s - cfg.n_patches), i32)
        specs["patches"] = jax.ShapeDtypeStruct((b, cfg.n_patches, cfg.d_model), f32)
    elif cfg.family == "spectral":
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        specs["targets"] = jax.ShapeDtypeStruct((b, s), i32)
        specs["mlm_mask"] = jax.ShapeDtypeStruct((b, s), f32)
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
    return specs
