"""Pure-jnp oracles for the Pallas FFT kernels.

Two independent references:
  * ``dft_matmul`` — O(N²) DFT-matrix product in float64 (ground truth).
  * ``fft_jnp`` / ``fft2_jnp`` — jnp.fft (XLA's FFT), used for larger sizes.
Both operate on (re, im) float planes, matching the kernel ABI (TPU Pallas
has no complex dtype).
"""

from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np


@functools.lru_cache(maxsize=32)
def _dft_matrix(n: int) -> np.ndarray:
    k = np.arange(n)
    return np.exp(-2j * np.pi * np.outer(k, k) / n)  # complex128


def dft_matmul(re: jnp.ndarray, im: jnp.ndarray):
    """Ground-truth DFT along the last axis via explicit matrix product."""
    w = _dft_matrix(re.shape[-1])
    x = np.asarray(re, dtype=np.float64) + 1j * np.asarray(im, dtype=np.float64)
    y = x @ w.T
    return jnp.asarray(y.real, jnp.float32), jnp.asarray(y.imag, jnp.float32)


def fft_jnp(re: jnp.ndarray, im: jnp.ndarray):
    """XLA FFT oracle along the last axis."""
    y = jnp.fft.fft(re.astype(jnp.complex64) + 1j * im.astype(jnp.complex64))
    return jnp.real(y), jnp.imag(y)


def fft2_jnp(re: jnp.ndarray, im: jnp.ndarray):
    """XLA 2D FFT oracle over the last two axes."""
    y = jnp.fft.fft2(re.astype(jnp.complex64) + 1j * im.astype(jnp.complex64))
    return jnp.real(y), jnp.imag(y)
