"""Pallas sLSTM scan kernel — VMEM-resident recurrent state.

The dry-run shows xlstm-350m × prefill_32k as the worst cell in the
roofline table (memory term 260 s): the XLA while-loop writes the (c, n,
h, m) carry and reads per-step slices from HBM on every one of 32768
timesteps. This kernel runs the recurrence in chunks with the state held
in VMEM scratch across the whole sequence — HBM traffic collapses to one
read of the gate pre-activations and one write of the hidden outputs
(≈10× less), the butterfly-reuse insight applied to a recurrence.

ABI: xg (B, L, 4D) f32 gate pre-activations (x @ Wx, computed outside —
that part is a dense matmul XLA already does well), wr (H, hd, 4hd)
block-diagonal recurrent weights, bias (4D,), initial state (B, D) × 4.
Returns hs (B, L, D) and the final state.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_SLSTM_HEADS = 4


def _kernel(xg_ref, wr_ref, bias_ref, c0_ref, n0_ref, h0_ref, m0_ref,
            hs_ref, cf_ref, nf_ref, hf_ref, mf_ref,
            c_sc, n_sc, h_sc, m_sc, *, chunk: int, d: int):
    j = pl.program_id(1)
    nl = pl.num_programs(1)
    hd = d // _SLSTM_HEADS

    @pl.when(j == 0)
    def _load():
        c_sc[...] = c0_ref[...]
        n_sc[...] = n0_ref[...]
        h_sc[...] = h0_ref[...]
        m_sc[...] = m0_ref[...]

    def step(t, _):
        x_t = xg_ref[:, t, :]                         # (TB, 4D)
        hprev = h_sc[...]                             # (TB, D)
        # block-diagonal recurrence: per-head (TB, hd) @ (hd, 4hd)
        recs = []
        for h in range(_SLSTM_HEADS):
            hh = hprev[:, h * hd:(h + 1) * hd]
            recs.append(
                jnp.dot(hh, wr_ref[h], preferred_element_type=jnp.float32)
            )
        # reference wiring (models/xlstm.py::_slstm_step): head-major concat —
        # (B, H, 4hd).reshape(B, 4D) with 4hd == D
        rec = jnp.concatenate(recs, axis=-1)          # (TB, 4D)
        gates = x_t + rec + bias_ref[...]
        it = gates[:, :d]
        ft = gates[:, d:2 * d]
        zt = gates[:, 2 * d:3 * d]
        ot = gates[:, 3 * d:]
        log_f = -jnp.logaddexp(0.0, -ft)              # log sigmoid
        m_new = jnp.maximum(log_f + m_sc[...], it)
        i_sc = jnp.exp(it - m_new)
        f_sc = jnp.exp(log_f + m_sc[...] - m_new)
        c = f_sc * c_sc[...] + i_sc * jnp.tanh(zt)
        n = f_sc * n_sc[...] + i_sc
        h_new = jax.nn.sigmoid(ot) * c / jnp.maximum(n, 1e-6)
        c_sc[...] = c
        n_sc[...] = n
        m_sc[...] = m_new
        h_sc[...] = h_new
        hs_ref[:, t, :] = h_new
        return 0

    jax.lax.fori_loop(0, chunk, step, 0)

    @pl.when(j == nl - 1)
    def _final():
        cf_ref[...] = c_sc[...]
        nf_ref[...] = n_sc[...]
        hf_ref[...] = h_sc[...]
        mf_ref[...] = m_sc[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def slstm_scan(xg, wr, bias, c0, n0, h0, m0, *, chunk: int = 256,
               interpret: bool = False):
    """xg: (B, L, 4D) f32. Returns (hs (B, L, D), (c, n, h, m) final)."""
    b, l, d4 = xg.shape
    d = d4 // 4
    chunk = min(chunk, l)
    if l % chunk:
        raise ValueError(f"L={l} not divisible by chunk={chunk}")
    nl = l // chunk
    hd = d // _SLSTM_HEADS

    state_spec = pl.BlockSpec((b, d), lambda i, j: (0, 0))
    outs = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, d=d),
        grid=(1, nl),
        in_specs=[
            pl.BlockSpec((b, chunk, d4), lambda i, j: (0, j, 0)),
            pl.BlockSpec((_SLSTM_HEADS, hd, 4 * hd), lambda i, j: (0, 0, 0)),
            pl.BlockSpec((d4,), lambda i, j: (0,)),
            state_spec, state_spec, state_spec, state_spec,
        ],
        out_specs=[
            pl.BlockSpec((b, chunk, d), lambda i, j: (0, j, 0)),
            state_spec, state_spec, state_spec, state_spec,
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, l, d), jnp.float32),
            jax.ShapeDtypeStruct((b, d), jnp.float32),
            jax.ShapeDtypeStruct((b, d), jnp.float32),
            jax.ShapeDtypeStruct((b, d), jnp.float32),
            jax.ShapeDtypeStruct((b, d), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((b, d), jnp.float32),
            pltpu.VMEM((b, d), jnp.float32),
            pltpu.VMEM((b, d), jnp.float32),
            pltpu.VMEM((b, d), jnp.float32),
        ],
        interpret=interpret,
    )(
        xg.astype(jnp.float32), wr.astype(jnp.float32), bias.astype(jnp.float32),
        c0.astype(jnp.float32), n0.astype(jnp.float32),
        h0.astype(jnp.float32), m0.astype(jnp.float32),
    )
    hs, c, n, h, m = outs
    return hs, (c, n, h, m)


def hbm_traffic_estimate(b: int, l: int, d: int, kernel: bool) -> int:
    """Kernel: read xg + write hs once. XLA loop: + per-step carry r/w."""
    base = b * l * 4 * d * 4 + b * l * d * 4
    if kernel:
        return base
    per_step_carry = 4 * b * d * 4 * 2  # (c,n,h,m) written+read per step
    return base + l * per_step_carry
