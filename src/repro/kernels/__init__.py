"""Pallas TPU kernels (validated on CPU via interpret mode)."""

from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.ops import (
    fft2_kernel,
    fft_kernel,
    fft_staged,
    hbm_traffic_model,
    irfft2_kernel,
    irfft_kernel,
    rfft2_kernel,
    rfft_kernel,
)
from repro.kernels.slstm_scan import slstm_scan

__all__ = [
    "fft2_kernel",
    "fft_kernel",
    "fft_staged",
    "flash_attention_fwd",
    "hbm_traffic_model",
    "irfft2_kernel",
    "irfft_kernel",
    "rfft2_kernel",
    "rfft_kernel",
    "slstm_scan",
]
