"""Public jit'd entry points for the FFT kernels.

Complex in/out convenience wrappers around the (re, im) kernel ABI, with
platform dispatch: real TPUs run the compiled kernels, CPU runs them in
interpret mode (the kernel body executes in Python — bit-identical logic).

  fft_kernel(x)    — fused 1D FFT (one HBM round trip)       [proposed]
  fft_staged(x)    — stage-at-a-time via the BU-array kernel [column-arch baseline]
  fft2_kernel(x)   — fused 2D FFT (row+turn+column in VMEM)  [beyond-paper fusion]
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.core.fft1d import bit_reversal_permutation
from repro.kernels.butterfly import butterfly_stage
from repro.kernels.fft_radix2 import fft2_fused, fft_fused

__all__ = ["fft_kernel", "fft_staged", "fft2_kernel", "hbm_traffic_model"]


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _split(x: jax.Array):
    x = jnp.asarray(x)
    if jnp.issubdtype(x.dtype, jnp.complexfloating):
        return jnp.real(x).astype(jnp.float32), jnp.imag(x).astype(jnp.float32)
    return x.astype(jnp.float32), jnp.zeros_like(x, dtype=jnp.float32)


def _flatten_rows(x: jax.Array):
    lead = x.shape[:-1]
    n = x.shape[-1]
    flat = int(jnp.prod(jnp.asarray(lead))) if lead else 1
    return x.reshape(flat, n), lead


def fft_kernel(x: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    """Fused-kernel FFT along the last axis (any leading batch dims)."""
    interpret = _interpret_default() if interpret is None else interpret
    re, im = _split(x)
    re2, lead = _flatten_rows(re)
    im2, _ = _flatten_rows(im)
    yr, yi = fft_fused(re2, im2, interpret=interpret)
    y = yr + 1j * yi
    return y.reshape(*lead, x.shape[-1])


def fft_staged(x: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    """Stage-at-a-time FFT: log2(N) kernel launches, log2(N) HBM round trips."""
    interpret = _interpret_default() if interpret is None else interpret
    re, im = _split(x)
    re2, lead = _flatten_rows(re)
    im2, _ = _flatten_rows(im)
    n = re2.shape[-1]
    rev = jnp.asarray(bit_reversal_permutation(n))
    re2 = jnp.take(re2, rev, axis=-1)
    im2 = jnp.take(im2, rev, axis=-1)
    for s in range(int(math.log2(n))):  # the control unit's stage counter
        re2, im2 = butterfly_stage(re2, im2, stage=s, interpret=interpret)
    y = re2 + 1j * im2
    return y.reshape(*lead, n)


def fft2_kernel(x: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    """Fused-kernel 2D FFT of (..., H, W)."""
    interpret = _interpret_default() if interpret is None else interpret
    re, im = _split(x)
    h, w = x.shape[-2], x.shape[-1]
    lead = x.shape[:-2]
    f = 1
    for d in lead:
        f *= d
    yr, yi = fft2_fused(re.reshape(f, h, w), im.reshape(f, h, w), interpret=interpret)
    return (yr + 1j * yi).reshape(*lead, h, w)


def hbm_traffic_model(batch: int, n: int, fused: bool) -> int:
    """Bytes moved between HBM and VMEM (re+im f32, read+write per pass).

    fused: one round trip. staged: one per stage — the paper's α = 1/log2 N
    shows up as traffic(fused)/traffic(staged).
    """
    passes = 1 if fused else int(math.log2(n))
    return passes * batch * n * 4 * 2 * 2
