"""Public jit'd entry points for the FFT kernels.

Complex in/out convenience wrappers around the (re, im) kernel ABI, with
platform dispatch: real TPUs run the compiled kernels, CPU runs them in
interpret mode (the kernel body executes in Python — bit-identical logic).

  fft_kernel(x)    — fused 1D FFT (one HBM round trip)       [proposed]
  fft_staged(x)    — stage-at-a-time via the BU-array kernel [column-arch baseline]
  fft2_kernel(x)   — fused 2D FFT (row+turn+column in VMEM)  [beyond-paper fusion]
  rfft_kernel(x)   — real-input 1D FFT, two-for-one packing  [half traffic]
  rfft2_kernel(x)  — real-input fused 2D FFT                 [half traffic]

All fused entry points take ``radix`` (2 or 4): radix-4 halves the in-VMEM
stage count and the twiddle transcendentals. 2D entry points fail over to an
unfused row/column composition when the frame's true working set exceeds the
VMEM budget (``fft2_fits_vmem``) instead of overflowing it.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.fft1d import bit_reversal_permutation
from repro.resilience import faults as _faults
from repro.kernels.butterfly import butterfly_stage
from repro.kernels.fft_radix2 import (
    _FFT2_WORKING_ARRAYS,
    _VMEM_BUDGET_BYTES,
    fft2_fits_vmem,
    fft2_fused,
    fft_fits_vmem,
    fft_fused,
    irfft2_fused,
    irfft_fused,
    rfft2_fused,
    rfft_fused,
)

__all__ = [
    "fft_kernel",
    "fft_staged",
    "fft2_kernel",
    "rfft_kernel",
    "irfft_kernel",
    "rfft2_kernel",
    "irfft2_kernel",
    "hbm_traffic_model",
    "fft2_working_set",
    "fft2_fits_budget",
    "vmem_budget_bytes",
]

#: f32 frame-sized arrays live at the real-input fused 2D kernels' peak —
#: fewer than the complex census (``_FFT2_WORKING_ARRAYS``) because the
#: input is one f32 pane, not re+im, and the packed panel is half-width.
#: This is the same count the rfft2/irfft2 failover guards below pass to
#: ``fft2_fits_vmem(..., arrays=6)``.
_REAL2D_ARRAYS = 6


def vmem_budget_bytes() -> int:
    """The VMEM byte budget the fused kernels tile against (one number for
    the whole repo: kernels, planner and imaging all size against it)."""
    return _VMEM_BUDGET_BYTES


def fft2_working_set(h: int, w: int, *, real: bool = False) -> int:
    """True VMEM working set (bytes) of one fused 2D transform of (H, W).

    The public spelling of the kernel census: input/output/working panes
    plus corner-turn temporaries, all f32 frame-sized. Pair it with
    :func:`vmem_budget_bytes` to report or reason about tile headroom
    (``benchmarks/imaging_bench.py`` does); callers that only need the
    yes/no answer use :func:`fft2_fits_budget`, the exact predicate the
    kernel entry points and the ``oaconv2d`` tile planner dispatch on.
    """
    return h * w * 4 * (_REAL2D_ARRAYS if real else _FFT2_WORKING_ARRAYS)


def fft2_fits_budget(h: int, w: int, *, real: bool = False) -> bool:
    """True when a fused 2D transform of (H, W) stays inside the budget —
    the same predicate the kernel entry points fail over on."""
    return fft2_fits_vmem(
        h, w, arrays=_REAL2D_ARRAYS if real else _FFT2_WORKING_ARRAYS
    )


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _failover_event(kind: str, h: int, w: int, frames: int, *, real: bool) -> None:
    """Record one fused->unfused VMEM failover (the decision was silent
    before: a frame over budget quietly paid three HBM round trips instead
    of one). Emitted at trace time — once per compiled shape, which is
    exactly the granularity the decision is made at."""
    obs.emit(
        "kernel.failover",
        kind=kind,
        shape=(h, w),
        frames=frames,
        working_set=fft2_working_set(h, w, real=real),
        budget=vmem_budget_bytes(),
    )


def _split(x: jax.Array):
    x = jnp.asarray(x)
    if jnp.issubdtype(x.dtype, jnp.complexfloating):
        return jnp.real(x).astype(jnp.float32), jnp.imag(x).astype(jnp.float32)
    return x.astype(jnp.float32), jnp.zeros_like(x, dtype=jnp.float32)


def _flatten_rows(x: jax.Array):
    lead = x.shape[:-1]
    n = x.shape[-1]
    flat = math.prod(lead) if lead else 1  # static shapes: stays trace-safe
    return x.reshape(flat, n), lead


def fft_kernel(x: jax.Array, *, radix: int = 2, interpret: bool | None = None) -> jax.Array:
    """Fused-kernel FFT along the last axis (any leading batch dims)."""
    interpret = _interpret_default() if interpret is None else interpret
    re, im = _split(x)
    re2, lead = _flatten_rows(re)
    im2, _ = _flatten_rows(im)
    yr, yi = fft_fused(re2, im2, radix=radix, interpret=interpret)
    y = yr + 1j * yi
    return y.reshape(*lead, x.shape[-1])


def fft_staged(x: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    """Stage-at-a-time FFT: log2(N) kernel launches, log2(N) HBM round trips."""
    interpret = _interpret_default() if interpret is None else interpret
    re, im = _split(x)
    re2, lead = _flatten_rows(re)
    im2, _ = _flatten_rows(im)
    n = re2.shape[-1]
    rev = jnp.asarray(bit_reversal_permutation(n))
    re2 = jnp.take(re2, rev, axis=-1)
    im2 = jnp.take(im2, rev, axis=-1)
    for s in range(int(math.log2(n))):  # the control unit's stage counter
        re2, im2 = butterfly_stage(re2, im2, stage=s, interpret=interpret)
    y = re2 + 1j * im2
    return y.reshape(*lead, n)


def _frames(x: jax.Array):
    h, w = x.shape[-2], x.shape[-1]
    lead = x.shape[:-2]
    f = 1
    for d in lead:
        f *= d
    return f, h, w, lead


def _jnp_variant(radix: int) -> str:
    return "radix4" if radix == 4 else "stockham"


def _fft_rows(re: jax.Array, im: jax.Array, *, radix: int, interpret: bool):
    """Last-axis complex FFT for the 2D failover paths: the fused kernel
    when a row tile fits VMEM, the jnp engine otherwise — the failover
    never overflows, whatever the frame geometry."""
    if fft_fits_vmem(re.shape[-1]):
        return fft_fused(re, im, radix=radix, interpret=interpret)
    from repro.core.fft1d import fft_impl  # lazy: core imports kernels

    z = fft_impl(re + 1j * im, variant=_jnp_variant(radix))
    return jnp.real(z).astype(jnp.float32), jnp.imag(z).astype(jnp.float32)


def fft2_kernel(x: jax.Array, *, radix: int = 2, interpret: bool | None = None) -> jax.Array:
    """Fused-kernel 2D FFT of (..., H, W); unfused failover for big frames."""
    interpret = _interpret_default() if interpret is None else interpret
    re, im = _split(x)
    f, h, w, lead = _frames(x)
    re, im = re.reshape(f, h, w), im.reshape(f, h, w)
    if fft2_fits_vmem(h, w) and not _faults.vmem_exhausted(
        "kernel.fused", kind="fft2d", h=h, w=w
    ):
        yr, yi = fft2_fused(re, im, radix=radix, interpret=interpret)
    else:
        # Frame working set exceeds VMEM: row pass, materialised corner
        # turn, column pass — more HBM trips, but never an overflow.
        _failover_event("fft2d", h, w, f, real=False)
        yr, yi = _fft_rows(re.reshape(f * h, w), im.reshape(f * h, w),
                           radix=radix, interpret=interpret)
        yr = yr.reshape(f, h, w).swapaxes(-1, -2).reshape(f * w, h)
        yi = yi.reshape(f, h, w).swapaxes(-1, -2).reshape(f * w, h)
        yr, yi = _fft_rows(yr, yi, radix=radix, interpret=interpret)
        yr = yr.reshape(f, w, h).swapaxes(-1, -2)
        yi = yi.reshape(f, w, h).swapaxes(-1, -2)
    return (yr + 1j * yi).reshape(*lead, h, w)


def rfft_kernel(x: jax.Array, *, radix: int = 2, interpret: bool | None = None) -> jax.Array:
    """Real-input fused FFT along the last axis -> (..., N/2+1) complex."""
    interpret = _interpret_default() if interpret is None else interpret
    x = jnp.asarray(x)
    re, lead = _flatten_rows(x.astype(jnp.float32))
    yr, yi = rfft_fused(re, radix=radix, interpret=interpret)
    return (yr + 1j * yi).reshape(*lead, x.shape[-1] // 2 + 1)


def irfft_kernel(y: jax.Array, *, radix: int = 2, interpret: bool | None = None) -> jax.Array:
    """Inverse of :func:`rfft_kernel`: (..., N/2+1) complex -> real (..., N)."""
    interpret = _interpret_default() if interpret is None else interpret
    re, im = _split(y)
    re2, lead = _flatten_rows(re)
    im2, _ = _flatten_rows(im)
    out = irfft_fused(re2, im2, radix=radix, interpret=interpret)
    return out.reshape(*lead, out.shape[-1])


def rfft2_kernel(x: jax.Array, *, radix: int = 2, interpret: bool | None = None) -> jax.Array:
    """Real-input fused 2D FFT of (..., H, W) -> (..., H, W/2+1) complex."""
    interpret = _interpret_default() if interpret is None else interpret
    x = jnp.asarray(x).astype(jnp.float32)
    f, h, w, lead = _frames(x)
    xf = x.reshape(f, h, w)
    if fft2_fits_vmem(h, w, arrays=_REAL2D_ARRAYS) and not _faults.vmem_exhausted(
        "kernel.fused", kind="rfft2d", h=h, w=w
    ):
        yr, yi = rfft2_fused(xf, radix=radix, interpret=interpret)
    else:
        # Unfused failover: row rfft kernel, corner turn in HBM, column FFT.
        # The column batch (f·(W/2+1) rows) is odd, which would force the
        # fused kernel to a degenerate 1-row tile — the jnp engine handles
        # that pass instead.
        _failover_event("rfft2d", h, w, f, real=True)
        from repro.core.fft1d import fft_impl  # lazy: core imports kernels

        half = w // 2 + 1
        if fft_fits_vmem(w):
            yr, yi = rfft_fused(xf.reshape(f * h, w), radix=radix, interpret=interpret)
            z = (yr + 1j * yi).reshape(f, h, half)
        else:
            from repro.core.rfft import rfft_impl  # rows too long for any tile

            z = rfft_impl(xf.reshape(f * h, w), variant=_jnp_variant(radix))
            z = z.reshape(f, h, half)
        z = fft_impl(z.swapaxes(-1, -2), variant=_jnp_variant(radix))
        z = z.swapaxes(-1, -2)
        return z.reshape(*lead, h, half)
    return (yr + 1j * yi).reshape(*lead, h, w // 2 + 1)


def irfft2_kernel(y: jax.Array, *, radix: int = 2, interpret: bool | None = None) -> jax.Array:
    """Inverse of :func:`rfft2_kernel`: (..., H, W/2+1) -> real (..., H, W)."""
    interpret = _interpret_default() if interpret is None else interpret
    re, im = _split(y)
    f, h, half, lead = _frames(y)
    w = 2 * (half - 1)
    re, im = re.reshape(f, h, half), im.reshape(f, h, half)
    if fft2_fits_vmem(h, w, arrays=_REAL2D_ARRAYS) and not _faults.vmem_exhausted(
        "kernel.fused", kind="irfft2d", h=h, w=w
    ):
        out = irfft2_fused(re, im, radix=radix, interpret=interpret)
    else:
        # Column IFFT via the jnp engine (the odd f·(W/2+1) column batch
        # defeats the fused kernel's row tiling), then the fused row irfft.
        _failover_event("irfft2d", h, w, f, real=True)
        from repro.core.fft1d import ifft_impl  # lazy: core imports kernels

        z = ifft_impl((re + 1j * im).swapaxes(-1, -2), variant=_jnp_variant(radix))
        z = z.swapaxes(-1, -2)
        if fft_fits_vmem(w):
            fr = jnp.real(z).astype(jnp.float32).reshape(f * h, half)
            fi = jnp.imag(z).astype(jnp.float32).reshape(f * h, half)
            out = irfft_fused(fr, fi, radix=radix, interpret=interpret)
        else:
            from repro.core.rfft import irfft_impl  # rows too long for any tile

            out = irfft_impl(z.reshape(f * h, half), variant=_jnp_variant(radix))
        out = out.reshape(f, h, w)
    return out.reshape(*lead, h, w)


def hbm_traffic_model(
    batch: int, n: int, fused: bool, *, radix: int = 2, real: bool = False
) -> int:
    """Bytes moved between HBM and VMEM (re+im f32, read+write per pass).

    fused: one round trip. staged: one per stage — the paper's α = 1/log2 N
    shows up as traffic(fused)/traffic(staged). ``radix=4`` halves the pass
    count of the staged path (4-point butterflies); ``real`` halves every
    pass (N real samples in, N/2+1 complex bins out — the two-for-one pack).
    """
    stages = int(math.log2(n))
    passes = 1 if fused else math.ceil(stages / math.log2(radix))
    per_pass = batch * n * 4 * 2 * 2
    if real:
        per_pass //= 2
    return passes * per_pass
