"""Single-stage butterfly kernel — the paper's N/2-BU array, stage at a time.

One `pallas_call` executes exactly one FFT stage (one pass through the N/2
butterfly units). The paper's *routing network* — the stage-dependent
shuffle between the register array and the BUs — is expressed with ZERO
gathers: at stage s (half-span h = 2^s, block m = 2h) the natural-order
array viewed as (B, N/m, 2, h) puts every butterfly's two inputs in
adjacent sub-rows, so the BlockSpec/reshape IS the routing network.

Running all log2(N) stages through this kernel (``fft_staged`` in ops.py)
is the *column architecture* baseline: the data round-trips HBM log2(N)
times. Compare `fft_radix2.fft_fused` (one round trip) — the measured HBM
traffic ratio reproduces the paper's area ratio α = 1/log2(N).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["butterfly_stage_kernel", "butterfly_stage", "pick_block_tile"]


def butterfly_stage_kernel(re_ref, im_ref, out_re_ref, out_im_ref, *, stage: int):
    """One pass through the BU array for a (TB, G, 2, h) tile.

    A (top) and B (bottom) samples per fig. 6a:  top' = A + W·B, bot' = A − W·B,
    with W = W_{2h}^p generated in-register from an iota over p (twiddle ROM).
    """
    h = re_ref.shape[-1]
    ar, br = re_ref[..., 0, :], re_ref[..., 1, :]
    ai, bi = im_ref[..., 0, :], im_ref[..., 1, :]
    p = jax.lax.broadcasted_iota(jnp.float32, (1, 1, h), 2)
    ang = (-math.pi / h) * p  # -2π p / m, m = 2h
    wr, wi = jnp.cos(ang), jnp.sin(ang)
    tr = br * wr - bi * wi
    ti = br * wi + bi * wr
    out_re_ref[..., 0, :] = ar + tr
    out_re_ref[..., 1, :] = ar - tr
    out_im_ref[..., 0, :] = ai + ti
    out_im_ref[..., 1, :] = ai - ti


def pick_block_tile(nblk: int, h: int, rows: int) -> tuple[int, int]:
    """(row_tile, group_tile): keep tiles lane-friendly and VMEM-bounded."""
    group = 1
    while group < nblk and group * 2 * h < 1024:
        group *= 2
    while nblk % group:
        group //= 2
    per_row = nblk // max(group, 1) * group * 2 * h * 4 * 4
    row_tile = max(1, min(rows, (4 * 1024 * 1024) // max(per_row, 1)))
    row_tile = 1 << (row_tile.bit_length() - 1)
    while rows % row_tile:
        row_tile //= 2
    return max(row_tile, 1), max(group, 1)


@functools.partial(jax.jit, static_argnames=("stage", "interpret"))
def butterfly_stage(
    re: jax.Array,
    im: jax.Array,
    *,
    stage: int,
    interpret: bool = False,
):
    """Apply DIT stage ``stage`` to (B, N) re/im planes in natural order.

    Input must already be bit-reversed (stage 0) — i.e. this is the engine
    the control unit re-invokes with SB = stage.
    """
    b, n = re.shape
    h = 1 << stage
    m = 2 * h
    nblk = n // m
    re4 = re.reshape(b, nblk, 2, h)
    im4 = im.reshape(b, nblk, 2, h)
    row_tile, group = pick_block_tile(nblk, h, b)
    grid = (b // row_tile, nblk // group)
    spec = pl.BlockSpec((row_tile, group, 2, h), lambda i, j: (i, j, 0, 0))
    out_re, out_im = pl.pallas_call(
        functools.partial(butterfly_stage_kernel, stage=stage),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct(re4.shape, jnp.float32),
            jax.ShapeDtypeStruct(im4.shape, jnp.float32),
        ],
        interpret=interpret,
    )(re4.astype(jnp.float32), im4.astype(jnp.float32))
    return out_re.reshape(b, n), out_im.reshape(b, n)
