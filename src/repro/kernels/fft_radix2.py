"""Fused radix-2 FFT Pallas kernels — the paper's reuse insight, TPU-native.

The paper keeps ONE stage of butterfly hardware and streams all log2(N)
stages through it. The TPU translation (DESIGN.md §2): keep the data panel
resident in VMEM and stream all log2(N) stages over it inside one kernel —
one HBM read + one HBM write for the whole transform, instead of the
log2(N) round trips of the stage-at-a-time baseline (`kernels/butterfly.py`).
The paper's area reduction factor (1/log2 N, eq. 5) reappears as the HBM
traffic ratio between the two kernels.

The in-VMEM schedule is Stockham autosort: every stage is a contiguous
reshape + one butterfly pass — no bit-reversal gather, so nothing here needs
dynamic indexing (TPU vector units hate gathers). Twiddles are generated
in-register from an iota (the twiddle "ROM" costs no VMEM).

ABI: separate float32 re/im planes (TPU Pallas has no complex dtype).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["fft_panel_kernel", "fft_fused", "fft2_fused", "pick_row_tile"]

_VMEM_BUDGET_BYTES = 8 * 1024 * 1024  # conservative half of a v5e core's VMEM


def pick_row_tile(batch: int, n: int, arrays: int = 4) -> int:
    """Largest power-of-two row tile whose working set fits the VMEM budget."""
    per_row = n * 4 * arrays  # f32 re+im, in+out
    tile = max(1, _VMEM_BUDGET_BYTES // max(per_row, 1))
    tile = 1 << (tile.bit_length() - 1)
    while batch % tile != 0:
        tile //= 2
    return max(tile, 1)


def _stockham_panel(re: jax.Array, im: jax.Array, n: int):
    """All log2(N) stages over a (tile, N) panel, entirely in registers/VMEM."""
    stages = int(math.log2(n))
    tb = re.shape[0]
    yr = re.reshape(tb, n, 1)
    yi = im.reshape(tb, n, 1)
    for s in range(stages):
        l = 1 << s
        r = n >> (s + 1)
        yr = yr.reshape(tb, 2, r, l)
        yi = yi.reshape(tb, 2, r, l)
        # Twiddle "ROM" generated in-register: W_{2l}^k, k = 0..l-1.
        k = jax.lax.broadcasted_iota(jnp.float32, (1, 1, l), 2)
        ang = (-math.pi / l) * k
        wr, wi = jnp.cos(ang), jnp.sin(ang)
        ar, ai = yr[:, 0], yi[:, 0]
        br, bi = yr[:, 1], yi[:, 1]
        tr = br * wr - bi * wi
        ti = br * wi + bi * wr
        yr = jnp.concatenate([ar + tr, ar - tr], axis=-1)
        yi = jnp.concatenate([ai + ti, ai - ti], axis=-1)
    return yr.reshape(tb, n), yi.reshape(tb, n)


def fft_panel_kernel(re_ref, im_ref, out_re_ref, out_im_ref):
    """Kernel body: one VMEM-resident panel, all stages fused."""
    n = re_ref.shape[-1]
    yr, yi = _stockham_panel(re_ref[...], im_ref[...], n)
    out_re_ref[...] = yr
    out_im_ref[...] = yi


@functools.partial(jax.jit, static_argnames=("interpret", "row_tile"))
def fft_fused(
    re: jax.Array,
    im: jax.Array,
    *,
    row_tile: int | None = None,
    interpret: bool = False,
):
    """FFT along the last axis of (B, N) re/im planes; one HBM round trip."""
    b, n = re.shape
    if n & (n - 1):
        raise ValueError(f"power-of-two length required, got {n}")
    tile = row_tile or pick_row_tile(b, n)
    grid = (b // tile,)
    spec = pl.BlockSpec((tile, n), lambda i: (i, 0))
    return pl.pallas_call(
        fft_panel_kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((b, n), jnp.float32),
            jax.ShapeDtypeStruct((b, n), jnp.float32),
        ],
        interpret=interpret,
    )(re.astype(jnp.float32), im.astype(jnp.float32))


def _fft2_kernel(re_ref, im_ref, out_re_ref, out_im_ref):
    """Fused 2D FFT: row pass, in-VMEM corner turn, column pass, turn back.

    Beyond-paper fusion: the hardware needs RAM1/RAM2 + a second engine for
    the column pass; with the whole (H, W) frame VMEM-resident both passes
    and the transpose happen on one residency — a single HBM round trip for
    the full 2D transform (vs 2 passes + materialised transpose ≈ 3-4 trips).
    """
    h = re_ref.shape[-2]
    w = re_ref.shape[-1]
    yr, yi = _stockham_panel(re_ref[0], im_ref[0], w)            # row pass
    yr, yi = yr.swapaxes(-1, -2), yi.swapaxes(-1, -2)            # corner turn
    yr, yi = _stockham_panel(yr, yi, h)                          # column pass
    out_re_ref[0] = yr.swapaxes(-1, -2)
    out_im_ref[0] = yi.swapaxes(-1, -2)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fft2_fused(re: jax.Array, im: jax.Array, *, interpret: bool = False):
    """2D FFT of (F, H, W) frames, one frame per grid step, fully fused."""
    f, h, w = re.shape
    if (h & (h - 1)) or (w & (w - 1)):
        raise ValueError(f"power-of-two frame dims required, got {(h, w)}")
    if h * w * 4 * 4 > _VMEM_BUDGET_BYTES:
        raise ValueError(f"frame {(h, w)} exceeds the fused-kernel VMEM budget")
    spec = pl.BlockSpec((1, h, w), lambda i: (i, 0, 0))
    return pl.pallas_call(
        _fft2_kernel,
        grid=(f,),
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((f, h, w), jnp.float32),
            jax.ShapeDtypeStruct((f, h, w), jnp.float32),
        ],
        interpret=interpret,
    )(re.astype(jnp.float32), im.astype(jnp.float32))
