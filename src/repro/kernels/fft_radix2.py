"""Fused radix-2/radix-4 FFT Pallas kernels — the paper's reuse insight, TPU-native.

The paper keeps ONE stage of butterfly hardware and streams all log2(N)
stages through it. The TPU translation (DESIGN.md §2): keep the data panel
resident in VMEM and stream all stages over it inside one kernel — one HBM
read + one HBM write for the whole transform, instead of the log2(N) round
trips of the stage-at-a-time baseline (`kernels/butterfly.py`). The paper's
area reduction factor (1/log2 N, eq. 5) reappears as the HBM traffic ratio
between the two kernels.

Two in-VMEM schedules, both Stockham autosort (contiguous reshapes, no
bit-reversal gather — TPU vector units hate gathers):

  * radix-2 (``_stockham_panel``)    — log2(N) stages of 2-point butterflies.
  * radix-4 (``_stockham_panel_r4``) — log4(N) stages of 4-point butterflies
    (one leading radix-2 stage when log2(N) is odd): half the stage count,
    half the ``concatenate`` shuffles, and the three twiddle factors per
    butterfly are derived from ONE ``cos/sin`` table by complex
    multiplication, so the transcendental count is halved too.

The twiddle "ROM" is hoisted: the largest stage's ``cos/sin`` table is
generated once per panel (from an iota, costing no HBM) and every smaller
stage reads a strided slice of it instead of recomputing ``jnp.cos/jnp.sin``.

Real-input kernels (two-for-one Hermitian packing): ``rfft_fused`` packs N
reals as N/2 complex, runs the half-size panel, and untangles the spectrum
with the conjugate-symmetry recombination — inside the same kernel, so the
whole real transform is still one HBM round trip at half the traffic of the
complex path. ``rfft2_fused``/``irfft2_fused`` fuse the row rfft, the
in-VMEM corner turn and the column FFT the same way.

ABI: separate float32 re/im planes (TPU Pallas has no complex dtype).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "fft_panel_kernel",
    "fft_fused",
    "fft2_fused",
    "fft_fits_vmem",
    "fft2_fits_vmem",
    "pick_row_tile",
    "rfft_fused",
    "irfft_fused",
    "rfft2_fused",
    "irfft2_fused",
]

_VMEM_BUDGET_BYTES = 8 * 1024 * 1024  # conservative half of a v5e core's VMEM

#: f32 arrays of frame size live at the 2D kernel's peak: input re/im panes,
#: output re/im panes, the working panel re/im, and the corner-turn's
#: transposed temporaries re/im. The old guard counted only 4 and let large
#: frames overflow VMEM silently.
_FFT2_WORKING_ARRAYS = 8

#: Same census for the 1D panel: input re/im, output re/im, working re/im.
_FFT1_WORKING_ARRAYS = 6


def pick_row_tile(batch: int, n: int, arrays: int = _FFT1_WORKING_ARRAYS) -> int:
    """Largest power-of-two row tile whose working set fits the VMEM budget.

    ``arrays`` is the number of f32 row-sized arrays simultaneously live in
    the kernel (inputs + outputs + working copies), not just the I/O count.
    """
    per_row = n * 4 * arrays
    tile = max(1, _VMEM_BUDGET_BYTES // max(per_row, 1))
    tile = 1 << (tile.bit_length() - 1)
    while batch % tile != 0:
        tile //= 2
    return max(tile, 1)


def fft_fits_vmem(n: int, arrays: int = _FFT1_WORKING_ARRAYS) -> bool:
    """True when even a single length-N row's working set fits the budget
    (below this, ``pick_row_tile`` would degrade to a 1-row tile that still
    overflows VMEM)."""
    return n * 4 * arrays <= _VMEM_BUDGET_BYTES


def fft2_fits_vmem(h: int, w: int, arrays: int = _FFT2_WORKING_ARRAYS) -> bool:
    """True when a fused 2D kernel's real working set fits the VMEM budget."""
    return h * w * 4 * arrays <= _VMEM_BUDGET_BYTES


# --------------------------- in-VMEM panels -------------------------------


def _stockham_panel(re: jax.Array, im: jax.Array, n: int):
    """All log2(N) radix-2 stages over a (tile, N) panel, in registers/VMEM."""
    stages = int(math.log2(n)) if n > 1 else 0
    tb = re.shape[0]
    yr = re.reshape(tb, n, 1)
    yi = im.reshape(tb, n, 1)
    if stages == 0:
        return yr.reshape(tb, n), yi.reshape(tb, n)
    # Twiddle ROM hoisted out of the stage loop: one cos/sin evaluation for
    # the largest stage; smaller stages are strided slices of it
    # (ang_l(k) = -pi*k/l = ang_lmax(k * lmax/l)).
    l_max = n // 2
    j = jax.lax.broadcasted_iota(jnp.float32, (1, 1, l_max), 2)
    ang = (-math.pi / l_max) * j
    rom_r, rom_i = jnp.cos(ang), jnp.sin(ang)
    for s in range(stages):
        l = 1 << s
        r = n >> (s + 1)
        yr = yr.reshape(tb, 2, r, l)
        yi = yi.reshape(tb, 2, r, l)
        stride = l_max // l
        wr = rom_r[..., ::stride]
        wi = rom_i[..., ::stride]
        ar, ai = yr[:, 0], yi[:, 0]
        br, bi = yr[:, 1], yi[:, 1]
        tr = br * wr - bi * wi
        ti = br * wi + bi * wr
        yr = jnp.concatenate([ar + tr, ar - tr], axis=-1)
        yi = jnp.concatenate([ai + ti, ai - ti], axis=-1)
    return yr.reshape(tb, n), yi.reshape(tb, n)


def _stockham_panel_r4(re: jax.Array, im: jax.Array, n: int):
    """Radix-4 Stockham panel: log4(N) stages of 4-point butterflies.

    Odd log2(N) runs one twiddle-free radix-2 stage first, then radix-4 the
    rest of the way. Per stage the three twiddles W, W^2, W^3 come from one
    hoisted cos/sin table (W^2, W^3 by complex multiplication — no extra
    transcendentals), and the ±i rotations of the 4-point butterfly are
    free sign/plane swaps.
    """
    stages = int(math.log2(n)) if n > 1 else 0
    tb = re.shape[0]
    yr = re.reshape(tb, n, 1)
    yi = im.reshape(tb, n, 1)
    if stages == 0:
        return yr.reshape(tb, n), yi.reshape(tb, n)
    l = 1
    if stages % 2:
        # One radix-2 stage (l=1 -> twiddle-free) to make the rest radix-4.
        r = n >> 1
        yr = yr.reshape(tb, 2, r, 1)
        yi = yi.reshape(tb, 2, r, 1)
        ar, ai = yr[:, 0], yi[:, 0]
        br, bi = yr[:, 1], yi[:, 1]
        yr = jnp.concatenate([ar + br, ar - br], axis=-1)
        yi = jnp.concatenate([ai + bi, ai - bi], axis=-1)
        l = 2
    if l < n:
        # Hoisted twiddle ROM for the largest radix-4 stage (l = n/4):
        # W_{4l}^k = exp(-2i*pi*k/n); smaller stages stride into it.
        l_max = n // 4
        j = jax.lax.broadcasted_iota(jnp.float32, (1, 1, l_max), 2)
        ang = (-2.0 * math.pi / n) * j
        rom_r, rom_i = jnp.cos(ang), jnp.sin(ang)
    while l < n:
        r = n // (4 * l)
        yr = yr.reshape(tb, 4, r, l)
        yi = yi.reshape(tb, 4, r, l)
        stride = (n // 4) // l
        w1r = rom_r[..., ::stride]
        w1i = rom_i[..., ::stride]
        w2r = w1r * w1r - w1i * w1i
        w2i = 2.0 * w1r * w1i
        w3r = w2r * w1r - w2i * w1i
        w3i = w2r * w1i + w2i * w1r
        a0r, a0i = yr[:, 0], yi[:, 0]
        a1r = yr[:, 1] * w1r - yi[:, 1] * w1i
        a1i = yr[:, 1] * w1i + yi[:, 1] * w1r
        a2r = yr[:, 2] * w2r - yi[:, 2] * w2i
        a2i = yr[:, 2] * w2i + yi[:, 2] * w2r
        a3r = yr[:, 3] * w3r - yi[:, 3] * w3i
        a3i = yr[:, 3] * w3i + yi[:, 3] * w3r
        s02r, s02i = a0r + a2r, a0i + a2i
        d02r, d02i = a0r - a2r, a0i - a2i
        s13r, s13i = a1r + a3r, a1i + a3i
        d13r, d13i = a1r - a3r, a1i - a3i
        # X[k+c'l] = sum_j (-i)^(j c') a_j: the ±i factors are plane swaps.
        yr = jnp.concatenate(
            [s02r + s13r, d02r + d13i, s02r - s13r, d02r - d13i], axis=-1
        )
        yi = jnp.concatenate(
            [s02i + s13i, d02i - d13r, s02i - s13i, d02i + d13r], axis=-1
        )
        l *= 4
    return yr.reshape(tb, n), yi.reshape(tb, n)


def _panel(radix: int):
    if radix not in (2, 4):
        raise ValueError(f"radix must be 2 or 4, got {radix}")
    return _stockham_panel_r4 if radix == 4 else _stockham_panel


# ----------------------- real-input (two-for-one) panels -------------------


def _rfft_panel(x: jax.Array, n: int, radix: int):
    """Real (tile, N) panel -> half spectrum (tile, N/2+1) re/im.

    Classic two-for-one: pack even/odd samples as N/2 complex, run the
    half-size panel, untangle with the Hermitian-symmetry recombination
    Y[k] = Xe[k] + W_N^k Xo[k].
    """
    m = n // 2
    zr = x[:, 0::2]
    zi = x[:, 1::2]
    zr, zi = _panel(radix)(zr, zi, m)
    # Z[k] for k = 0..M (Z[M] = Z[0]) and conj(Z[(M-k) mod M]).
    zkr = jnp.concatenate([zr, zr[:, :1]], axis=-1)
    zki = jnp.concatenate([zi, zi[:, :1]], axis=-1)
    zmkr = jnp.concatenate([zr[:, :1], jnp.flip(zr[:, 1:], axis=-1), zr[:, :1]], axis=-1)
    zmki = -jnp.concatenate([zi[:, :1], jnp.flip(zi[:, 1:], axis=-1), zi[:, :1]], axis=-1)
    xer = 0.5 * (zkr + zmkr)
    xei = 0.5 * (zki + zmki)
    dr = zkr - zmkr
    di = zki - zmki
    xor_ = 0.5 * di          # Xo = -i/2 (Zk - conj(Zmk))
    xoi = -0.5 * dr
    k = jax.lax.broadcasted_iota(jnp.float32, (1, m + 1), 1)
    ang = (-2.0 * math.pi / n) * k
    wr, wi = jnp.cos(ang), jnp.sin(ang)
    yr = xer + wr * xor_ - wi * xoi
    yi = xei + wr * xoi + wi * xor_
    return yr, yi


def _irfft_panel(yr: jax.Array, yi: jax.Array, n: int, radix: int):
    """Half spectrum (tile, N/2+1) re/im -> real (tile, N) panel (inverse)."""
    tb = yr.shape[0]
    m = n // 2
    # np.fft.irfft semantics: the DC and Nyquist bins of a Hermitian
    # spectrum are real — discard any imaginary part instead of folding
    # it into the output.
    edge = jax.lax.broadcasted_iota(jnp.int32, (1, m + 1), 1)
    yi = jnp.where((edge == 0) | (edge == m), 0.0, yi)
    ykr, yki = yr[:, :m], yi[:, :m]
    # conj(Y[M-k]) for k = 0..M-1 is the reversed tail of the half spectrum.
    ymkr = jnp.flip(yr[:, 1:], axis=-1)
    ymki = -jnp.flip(yi[:, 1:], axis=-1)
    xer = 0.5 * (ykr + ymkr)
    xei = 0.5 * (yki + ymki)
    txr = 0.5 * (ykr - ymkr)   # W^k Xo[k]
    txi = 0.5 * (yki - ymki)
    k = jax.lax.broadcasted_iota(jnp.float32, (1, m), 1)
    ang = (2.0 * math.pi / n) * k   # W^{-k} undoes the forward phase
    wr, wi = jnp.cos(ang), jnp.sin(ang)
    xor_ = txr * wr - txi * wi
    xoi = txr * wi + txi * wr
    zr = xer - xoi             # Z = Xe + i·Xo
    zi = xei + xor_
    # IFFT_M via the conjugation identity on the shared forward panel.
    fr, fi = _panel(radix)(zr, -zi, m)
    inv = 1.0 / m
    zr, zi = fr * inv, -fi * inv
    # Interleave: x[2j] = Re(z[j]), x[2j+1] = Im(z[j]).
    return jnp.stack([zr, zi], axis=-1).reshape(tb, n)


# ------------------------------ 1D kernels --------------------------------


def fft_panel_kernel(re_ref, im_ref, out_re_ref, out_im_ref, *, radix: int = 2):
    """Kernel body: one VMEM-resident panel, all stages fused."""
    n = re_ref.shape[-1]
    yr, yi = _panel(radix)(re_ref[...], im_ref[...], n)
    out_re_ref[...] = yr
    out_im_ref[...] = yi


@functools.partial(jax.jit, static_argnames=("interpret", "row_tile", "radix"))
def fft_fused(
    re: jax.Array,
    im: jax.Array,
    *,
    row_tile: int | None = None,
    radix: int = 2,
    interpret: bool = False,
):
    """FFT along the last axis of (B, N) re/im planes; one HBM round trip."""
    b, n = re.shape
    if n & (n - 1):
        raise ValueError(f"power-of-two length required, got {n}")
    if not fft_fits_vmem(n):
        raise ValueError(
            f"length-{n} rows exceed the fused-kernel VMEM budget even at "
            "a 1-row tile; use an unfused variant"
        )
    tile = row_tile or pick_row_tile(b, n)
    grid = (b // tile,)
    spec = pl.BlockSpec((tile, n), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(fft_panel_kernel, radix=radix),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((b, n), jnp.float32),
            jax.ShapeDtypeStruct((b, n), jnp.float32),
        ],
        interpret=interpret,
    )(re.astype(jnp.float32), im.astype(jnp.float32))


def _rfft_kernel_body(x_ref, out_re_ref, out_im_ref, *, radix: int):
    yr, yi = _rfft_panel(x_ref[...], x_ref.shape[-1], radix)
    out_re_ref[...] = yr
    out_im_ref[...] = yi


@functools.partial(jax.jit, static_argnames=("interpret", "row_tile", "radix"))
def rfft_fused(
    x: jax.Array,
    *,
    row_tile: int | None = None,
    radix: int = 2,
    interpret: bool = False,
):
    """Real-input FFT of (B, N) -> (B, N/2+1) re/im; one HBM round trip at
    roughly half the complex path's traffic and arithmetic."""
    b, n = x.shape
    if n < 2 or n & (n - 1):
        raise ValueError(f"power-of-two length >= 2 required, got {n}")
    if not fft_fits_vmem(n):
        raise ValueError(
            f"length-{n} rows exceed the fused-kernel VMEM budget even at "
            "a 1-row tile; use an unfused variant"
        )
    m = n // 2
    tile = row_tile or pick_row_tile(b, n)
    in_spec = pl.BlockSpec((tile, n), lambda i: (i, 0))
    out_spec = pl.BlockSpec((tile, m + 1), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_rfft_kernel_body, radix=radix),
        grid=(b // tile,),
        in_specs=[in_spec],
        out_specs=[out_spec, out_spec],
        out_shape=[
            jax.ShapeDtypeStruct((b, m + 1), jnp.float32),
            jax.ShapeDtypeStruct((b, m + 1), jnp.float32),
        ],
        interpret=interpret,
    )(x.astype(jnp.float32))


def _irfft_kernel_body(re_ref, im_ref, out_ref, *, n: int, radix: int):
    out_ref[...] = _irfft_panel(re_ref[...], im_ref[...], n, radix)


@functools.partial(jax.jit, static_argnames=("interpret", "row_tile", "radix"))
def irfft_fused(
    re: jax.Array,
    im: jax.Array,
    *,
    row_tile: int | None = None,
    radix: int = 2,
    interpret: bool = False,
):
    """Inverse of :func:`rfft_fused`: (B, N/2+1) re/im -> real (B, N)."""
    b, half = re.shape
    n = 2 * (half - 1)
    if n < 2 or n & (n - 1):
        raise ValueError(f"half-spectrum width must be N/2+1 with N a power of two, got {half}")
    if not fft_fits_vmem(n):
        raise ValueError(
            f"length-{n} rows exceed the fused-kernel VMEM budget even at "
            "a 1-row tile; use an unfused variant"
        )
    tile = row_tile or pick_row_tile(b, n)
    in_spec = pl.BlockSpec((tile, half), lambda i: (i, 0))
    out_spec = pl.BlockSpec((tile, n), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_irfft_kernel_body, n=n, radix=radix),
        grid=(b // tile,),
        in_specs=[in_spec, in_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=interpret,
    )(re.astype(jnp.float32), im.astype(jnp.float32))


# ------------------------------ 2D kernels --------------------------------


def _fft2_kernel(re_ref, im_ref, out_re_ref, out_im_ref, *, radix: int):
    """Fused 2D FFT: row pass, in-VMEM corner turn, column pass, turn back.

    Beyond-paper fusion: the hardware needs RAM1/RAM2 + a second engine for
    the column pass; with the whole (H, W) frame VMEM-resident both passes
    and the transpose happen on one residency — a single HBM round trip for
    the full 2D transform (vs 2 passes + materialised transpose ≈ 3-4 trips).
    """
    h = re_ref.shape[-2]
    w = re_ref.shape[-1]
    panel = _panel(radix)
    yr, yi = panel(re_ref[0], im_ref[0], w)                      # row pass
    yr, yi = yr.swapaxes(-1, -2), yi.swapaxes(-1, -2)            # corner turn
    yr, yi = panel(yr, yi, h)                                    # column pass
    out_re_ref[0] = yr.swapaxes(-1, -2)
    out_im_ref[0] = yi.swapaxes(-1, -2)


@functools.partial(jax.jit, static_argnames=("interpret", "radix"))
def fft2_fused(
    re: jax.Array, im: jax.Array, *, radix: int = 2, interpret: bool = False
):
    """2D FFT of (F, H, W) frames, one frame per grid step, fully fused."""
    f, h, w = re.shape
    if (h & (h - 1)) or (w & (w - 1)):
        raise ValueError(f"power-of-two frame dims required, got {(h, w)}")
    if not fft2_fits_vmem(h, w):
        # The corner turn materialises transposed temporaries on top of the
        # in/out/working panes; callers should check fft2_fits_vmem() and
        # fail over to the unfused path rather than overflow VMEM.
        raise ValueError(
            f"frame {(h, w)} exceeds the fused-kernel VMEM budget "
            f"({_FFT2_WORKING_ARRAYS} frame-sized arrays live at the corner turn)"
        )
    spec = pl.BlockSpec((1, h, w), lambda i: (i, 0, 0))
    return pl.pallas_call(
        functools.partial(_fft2_kernel, radix=radix),
        grid=(f,),
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((f, h, w), jnp.float32),
            jax.ShapeDtypeStruct((f, h, w), jnp.float32),
        ],
        interpret=interpret,
    )(re.astype(jnp.float32), im.astype(jnp.float32))


def _rfft2_kernel(x_ref, out_re_ref, out_im_ref, *, radix: int):
    """Fused real-input 2D FFT: row rfft, corner turn, column FFT, turn back."""
    h = x_ref.shape[-2]
    w = x_ref.shape[-1]
    yr, yi = _rfft_panel(x_ref[0], w, radix)                     # (H, W/2+1)
    yr, yi = yr.swapaxes(-1, -2), yi.swapaxes(-1, -2)            # corner turn
    yr, yi = _panel(radix)(yr, yi, h)                            # column pass
    out_re_ref[0] = yr.swapaxes(-1, -2)
    out_im_ref[0] = yi.swapaxes(-1, -2)


@functools.partial(jax.jit, static_argnames=("interpret", "radix"))
def rfft2_fused(x: jax.Array, *, radix: int = 2, interpret: bool = False):
    """2D real-input FFT of (F, H, W) -> (F, H, W/2+1) re/im, fully fused."""
    f, h, w = x.shape
    if (h & (h - 1)) or (w & (w - 1)) or w < 2:
        raise ValueError(f"power-of-two frame dims required, got {(h, w)}")
    if not fft2_fits_vmem(h, w, arrays=6):
        raise ValueError(f"frame {(h, w)} exceeds the fused-kernel VMEM budget")
    in_spec = pl.BlockSpec((1, h, w), lambda i: (i, 0, 0))
    out_spec = pl.BlockSpec((1, h, w // 2 + 1), lambda i: (i, 0, 0))
    return pl.pallas_call(
        functools.partial(_rfft2_kernel, radix=radix),
        grid=(f,),
        in_specs=[in_spec],
        out_specs=[out_spec, out_spec],
        out_shape=[
            jax.ShapeDtypeStruct((f, h, w // 2 + 1), jnp.float32),
            jax.ShapeDtypeStruct((f, h, w // 2 + 1), jnp.float32),
        ],
        interpret=interpret,
    )(x.astype(jnp.float32))


def _irfft2_kernel(re_ref, im_ref, out_ref, *, n: int, radix: int):
    """Inverse fused 2D: column IFFT (conj trick), turn, row irfft."""
    h = re_ref.shape[-2]
    yr, yi = re_ref[0].swapaxes(-1, -2), im_ref[0].swapaxes(-1, -2)
    fr, fi = _panel(radix)(yr, -yi, h)                           # column IFFT
    inv = 1.0 / h
    yr, yi = fr * inv, -fi * inv
    yr, yi = yr.swapaxes(-1, -2), yi.swapaxes(-1, -2)            # (H, W/2+1)
    out_ref[0] = _irfft_panel(yr, yi, n, radix)                  # row irfft


@functools.partial(jax.jit, static_argnames=("interpret", "radix"))
def irfft2_fused(re: jax.Array, im: jax.Array, *, radix: int = 2, interpret: bool = False):
    """Inverse of :func:`rfft2_fused`: (F, H, W/2+1) re/im -> real (F, H, W)."""
    f, h, half = re.shape
    w = 2 * (half - 1)
    if (h & (h - 1)) or w < 2 or (w & (w - 1)):
        raise ValueError(f"bad half-spectrum frame dims {(h, half)}")
    if not fft2_fits_vmem(h, w, arrays=6):
        raise ValueError(f"frame {(h, w)} exceeds the fused-kernel VMEM budget")
    in_spec = pl.BlockSpec((1, h, half), lambda i: (i, 0, 0))
    out_spec = pl.BlockSpec((1, h, w), lambda i: (i, 0, 0))
    return pl.pallas_call(
        functools.partial(_irfft2_kernel, n=w, radix=radix),
        grid=(f,),
        in_specs=[in_spec, in_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((f, h, w), jnp.float32),
        interpret=interpret,
    )(re.astype(jnp.float32), im.astype(jnp.float32))
