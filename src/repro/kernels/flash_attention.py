"""Pallas TPU flash-attention kernel (beyond-paper optimization).

Motivation (EXPERIMENTS.md §Perf): the dry-run shows attention-heavy cells
memory-dominated by materialised (block_q × S) score traffic — XLA cannot
fuse dot→softmax→dot. This kernel keeps scores in VMEM: per (batch·head,
q-block) the online-softmax accumulator persists across the kv-block grid
dimension, so HBM traffic drops from O(S²·H) to O(S·H·D) per layer —
the same VMEM-residency insight the paper's butterfly reuse embodies,
applied to attention.

Layout: q (BH, Sq, D), k/v (BH, Sk, D) float32 (complex-free ABI like the
FFT kernels; GQA callers pre-map heads). Grid: (BH, nq, nk) with the kv
dimension innermost ("arbitrary" semantics) and VMEM scratch carrying
(acc, m, l) across kv steps.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30

__all__ = ["flash_attention_fwd", "mha_reference"]


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            causal: bool, window: int | None, block_q: int, block_k: int,
            sq: int, sk: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]  # (bq, d)
    k = k_ref[0]  # (bk, d)
    v = v_ref[0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
    s = s * (1.0 / math.sqrt(q.shape[-1]))

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = kpos < sk
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(kj == nk - 1)
    def _finalize():
        o_ref[0] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-20)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention_fwd(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 256,
    block_k: int = 512,
    interpret: bool = False,
):
    """q: (BH, Sq, D); k, v: (BH, Sk, D) — float32. Returns (BH, Sq, D)."""
    bh, sq, d = q.shape
    _, sk, dv = v.shape
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    pad_q = (-sq) % block_q
    pad_k = (-sk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))
    nq = (sq + pad_q) // block_q
    nk = (sk + pad_k) // block_k

    out = pl.pallas_call(
        functools.partial(
            _kernel, causal=causal, window=window,
            block_q=block_q, block_k=block_k, sq=sq, sk=sk,
        ),
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, dv), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq + pad_q, dv), jnp.float32),
        scratch_shapes=[
            # (acc, m, l) persist across the kv grid dimension in VMEM
            pltpu.VMEM((block_q, dv), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32))
    return out[:, :sq]


def mha_reference(q, k, v, *, causal=True, window=None):
    """Naive oracle: (BH, Sq, D) × (BH, Sk, D)."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q, k) / math.sqrt(d)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w, v)
