"""Multi-coil Cartesian MRI operators on the planned transform stack.

The encoding model of parallel (SENSE) MRI: an array of ``C`` receive
coils sees the object ``x`` through per-coil sensitivity profiles
``S_c``, and the scanner samples each coil's centered k-space on a
Cartesian grid masked by the undersampling pattern ``M``:

    y_c = M · F(S_c · x)                (forward, per coil)
    x̃  = Σ_c S_c* · F⁻¹(M · y_c)        (adjoint)

``F`` here is the MRI community's centered, ortho-normalised 2D
transform — exactly :func:`repro.imaging.kspace.image_to_kspace` (the
moco-workshop ``Image2K`` convention) — so ``F`` is unitary and the
forward/adjoint pair above is a true adjoint pair: ``<A x, y> ==
<x, Aᴴ y>``. That identity is what every iterative reconstruction
(:mod:`repro.mri.recon`) leans on.

Every transform resolves through ``repro.xfft`` → ``repro.plan``: coil
and frame axes ride the batched leading axes of ONE planned ``fft2``
per call, so planning, MEASURE wisdom, precision scopes, the resilience
ladder and obs spans all apply to reconstruction for free — no private
engine calls anywhere in this package.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.imaging.kspace import image_to_kspace, kspace_to_image

__all__ = ["apply_mask", "sense_forward", "sense_adjoint", "rss_combine"]


def _as_mask(mask, like: jax.Array) -> jax.Array:
    """Sampling mask as a real multiplicand broadcastable over ``like``.

    Bool masks become floats (complex·bool promotion is surprising);
    real dtypes pass through — multiplying complex k-space by a real
    mask stays in the k-space dtype.
    """
    m = jnp.asarray(mask)
    if m.dtype == jnp.bool_:
        m = m.astype(jnp.float32)
    return m


def apply_mask(kspace: jax.Array, mask) -> jax.Array:
    """Zero the unsampled k-space locations: ``M · y``.

    ``mask`` broadcasts against the trailing axes of ``kspace`` — a
    ``(H, W)`` mask masks every coil/frame of a ``(..., C, H, W)``
    array; a per-shot ``(S, 1, H, W)`` mask masks per shot.
    """
    return jnp.asarray(kspace) * _as_mask(mask, kspace)


def sense_forward(
    image: jax.Array, smaps: jax.Array, mask: Optional[jax.Array] = None
) -> jax.Array:
    """SENSE forward model: image ``(..., H, W)`` -> k-space ``(..., C, H, W)``.

    ``smaps`` is ``(..., C, H, W)`` (leading axes broadcast against the
    image's). The coil axis rides the batched leading axes of one
    planned centered ``fft2``; ``mask=None`` means fully sampled.
    """
    image = jnp.asarray(image)
    smaps = jnp.asarray(smaps)
    if image.ndim < 2:
        raise ValueError(f"image must be (..., H, W), got shape {image.shape}")
    if smaps.ndim < 3:
        raise ValueError(f"smaps must be (..., C, H, W), got shape {smaps.shape}")
    if smaps.shape[-2:] != image.shape[-2:]:
        raise ValueError(
            f"smaps frame {smaps.shape[-2:]} does not match "
            f"image frame {image.shape[-2:]}"
        )
    coil_images = smaps * image[..., None, :, :]
    kspace = image_to_kspace(coil_images)
    return kspace if mask is None else apply_mask(kspace, mask)


def sense_adjoint(
    kspace: jax.Array, smaps: jax.Array, mask: Optional[jax.Array] = None
) -> jax.Array:
    """SENSE adjoint: k-space ``(..., C, H, W)`` -> image ``(..., H, W)``.

    The exact adjoint of :func:`sense_forward` under the ortho-normalised
    centered transform: mask, inverse-transform every coil (one planned
    ``ifft2``), weight by conjugate sensitivities, sum over coils.
    """
    kspace = jnp.asarray(kspace)
    smaps = jnp.asarray(smaps)
    if kspace.ndim < 3:
        raise ValueError(f"kspace must be (..., C, H, W), got shape {kspace.shape}")
    if smaps.shape[-3:] != kspace.shape[-3:]:
        raise ValueError(
            f"smaps coil block {smaps.shape[-3:]} does not match "
            f"kspace coil block {kspace.shape[-3:]}"
        )
    if mask is not None:
        kspace = apply_mask(kspace, mask)
    coil_images = kspace_to_image(kspace)
    return jnp.sum(jnp.conj(smaps) * coil_images, axis=-3)


def rss_combine(coil_images: jax.Array, axis: int = -3) -> jax.Array:
    """Root-sum-of-squares coil combination: ``sqrt(Σ_c |x_c|²)``.

    The sensitivity-free magnitude combine — the standard display/
    reference image when no maps are available, and the normaliser the
    ESPIRiT-lite map estimate divides by.
    """
    coil_images = jnp.asarray(coil_images)
    return jnp.sqrt(jnp.sum(jnp.abs(coil_images) ** 2, axis=axis))
