"""repro.mri — multi-coil MRI reconstruction on the planned FFT stack.

The source paper's headline application for area-efficient 2D FFT
hardware is medical image processing; this package is that workload,
end to end: the SENSE encoding operators, reproducible Cartesian
undersampling, ESPIRiT-lite sensitivity estimation, iterative CG-SENSE
reconstruction, and Batchelor's motion-compensated forward model built
from the PR-4 registration machinery.

Everything transforms through ``repro.xfft`` → ``repro.plan`` — a CG
recon's inner loop is tens of planned centered transforms over two
problem keys, which makes reconstruction the hardest plan-cache,
calibration-ledger and serve-lane stress test in the repo (serving
lives in :class:`repro.serve.ImagingService`'s ``recon`` lane family).

* :mod:`repro.mri.operators` — ``sense_forward`` / ``sense_adjoint``
  (a true adjoint pair under the ortho centered transform),
  ``apply_mask``, root-sum-of-squares ``rss_combine``; coil/frame axes
  batch through one planned transform.
* :mod:`repro.mri.masks` — seeded ``uniform_mask`` /
  ``variable_density_mask`` (fully-sampled calibration block),
  realised ``acceleration``, and ``estimate_sensitivities``
  (ESPIRiT-lite: windowed calibration ifft + RSS normalisation).
* :mod:`repro.mri.recon` — ``recon_cg_sense`` (CG on the normal
  equations, optional Tikhonov ``lam``, per-iteration ``mri.cg.iter``
  residual events), the ``recon_zero_filled`` baseline, the shared
  ``cg_normal`` driver, and the ``nrmse`` gate metric.
* :mod:`repro.mri.moco` — ``moco_forward`` / ``moco_adjoint``
  (per-shot masks × per-shot ``apply_shift``), ``recon_cg_moco``, shot
  partitioning and registration-based ``estimate_shot_shifts``.
* :mod:`repro.mri.phantom` — the deterministic Shepp-Logan +
  birdcage-coil fixture shared by tests, benchmarks and examples.
"""

from repro.mri.masks import (
    acceleration,
    estimate_sensitivities,
    uniform_mask,
    variable_density_mask,
)
from repro.mri.moco import (
    estimate_shot_shifts,
    moco_adjoint,
    moco_forward,
    recon_cg_moco,
    shot_masks,
)
from repro.mri.operators import (
    apply_mask,
    rss_combine,
    sense_adjoint,
    sense_forward,
)
from repro.mri.phantom import birdcage_maps, shepp_logan
from repro.mri.recon import (
    cg_normal,
    nrmse,
    recon_cg_sense,
    recon_zero_filled,
)

__all__ = [
    "acceleration",
    "apply_mask",
    "birdcage_maps",
    "cg_normal",
    "estimate_sensitivities",
    "estimate_shot_shifts",
    "moco_adjoint",
    "moco_forward",
    "nrmse",
    "recon_cg_moco",
    "recon_cg_sense",
    "recon_zero_filled",
    "rss_combine",
    "sense_adjoint",
    "sense_forward",
    "shepp_logan",
    "shot_masks",
    "uniform_mask",
    "variable_density_mask",
]
