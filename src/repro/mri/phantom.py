"""Synthetic multi-coil acquisition fixture: phantom + birdcage coils.

The deterministic ground truth the recon tests, benchmark gates and
examples all share (the same one-definition rule as
``repro.imaging.synthetic``): a Shepp-Logan head phantom and a smooth
birdcage-style coil-sensitivity model. Pure numpy — generating the
fixture must not exercise the transform engines under test.
"""

from __future__ import annotations

import numpy as np

__all__ = ["shepp_logan", "birdcage_maps"]

# (intensity, a, b, x0, y0, phi_deg) — the modified (Toft) Shepp-Logan
# table, whose soft-tissue contrasts are visible without windowing.
_ELLIPSES = (
    (1.00, 0.6900, 0.9200, 0.00, 0.0000, 0.0),
    (-0.80, 0.6624, 0.8740, 0.00, -0.0184, 0.0),
    (-0.20, 0.1100, 0.3100, 0.22, 0.0000, -18.0),
    (-0.20, 0.1600, 0.4100, -0.22, 0.0000, 18.0),
    (0.10, 0.2100, 0.2500, 0.00, 0.3500, 0.0),
    (0.10, 0.0460, 0.0460, 0.00, 0.1000, 0.0),
    (0.10, 0.0460, 0.0460, 0.00, -0.1000, 0.0),
    (0.10, 0.0460, 0.0230, -0.08, -0.6050, 0.0),
    (0.10, 0.0230, 0.0230, 0.00, -0.6060, 0.0),
    (0.10, 0.0230, 0.0460, 0.06, -0.6050, 0.0),
)


def shepp_logan(n: int) -> np.ndarray:
    """(n, n) float32 modified Shepp-Logan phantom on the [-1, 1]² grid."""
    if n < 8:
        raise ValueError(f"phantom size must be >= 8, got {n}")
    grid = np.linspace(-1.0, 1.0, n, endpoint=False) + 1.0 / n
    x = grid[None, :]
    y = -grid[:, None]                       # row 0 is the top of the head
    img = np.zeros((n, n), np.float64)
    for value, a, b, x0, y0, phi_deg in _ELLIPSES:
        phi = np.deg2rad(phi_deg)
        xr = (x - x0) * np.cos(phi) + (y - y0) * np.sin(phi)
        yr = -(x - x0) * np.sin(phi) + (y - y0) * np.cos(phi)
        img += value * ((xr / a) ** 2 + (yr / b) ** 2 <= 1.0)
    return img.astype(np.float32)


def birdcage_maps(n_coils: int, n: int, radius: float = 1.5) -> np.ndarray:
    """(n_coils, n, n) complex64 birdcage-style sensitivity maps, RSS ≈ 1.

    Each coil sits at angle ``2πc/C`` on a circle of ``radius`` (in
    half-FOV units) around the image: magnitude falls off with distance
    to the coil, phase ramps smoothly across the FOV with a per-coil
    offset. Normalised so the root-sum-of-squares is 1 everywhere — the
    convention ESPIRiT maps satisfy, and the one that keeps the CG-SENSE
    normal operator well conditioned.
    """
    if n_coils < 1:
        raise ValueError(f"need at least one coil, got {n_coils}")
    grid = np.linspace(-1.0, 1.0, n, endpoint=False) + 1.0 / n
    x = grid[None, :]
    y = grid[:, None]
    maps = np.empty((n_coils, n, n), np.complex128)
    for c in range(n_coils):
        ang = 2.0 * np.pi * c / n_coils
        cx, cy = radius * np.cos(ang), radius * np.sin(ang)
        d2 = (x - cx) ** 2 + (y - cy) ** 2
        mag = 1.0 / d2
        phase = np.exp(1j * (0.5 * np.pi * (x * cy - y * cx) + ang))
        maps[c] = mag * phase
    rss = np.sqrt((np.abs(maps) ** 2).sum(axis=0))
    return (maps / rss).astype(np.complex64)
