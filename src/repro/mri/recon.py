"""Iterative reconstruction: CG-SENSE and the zero-filled baseline.

CG-SENSE (Pruessmann et al.) solves the regularised normal equations of
the SENSE forward model with conjugate gradients:

    (AᴴA + λI) x = Aᴴ y,      A = M · F · S

Every CG iteration applies ``A`` and ``Aᴴ`` once — two planned centered
2D transforms over the full coil stack — so a ten-iteration recon is
~twenty planned ``fft2`` resolutions of TWO problem keys (forward and
inverse of the same batched coil shape). That makes reconstruction the
plan-cache stress test the ROADMAP asked for: the first recon of a
problem key tunes, every later iteration and every later recon of that
key is a pure cache hit.

The loop is a host-side driver on purpose (like FFTW's planner, the
decision layer stays out of the traced computation): each iteration
resolves through ``repro.plan``, runs under the resilience ladder, and
emits one ``mri.cg.iter`` obs event carrying the residual trace — the
convergence evidence the tests and ``BENCH_mri.json`` gate on. Leading
batch axes are first-class: a ``(B, C, H, W)`` k-space stack runs ONE
batched CG with per-item step sizes, which is exactly how the
``ImagingService`` recon lane coalesces concurrent requests.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro import obs
from repro.mri.operators import sense_adjoint, sense_forward

__all__ = ["recon_zero_filled", "recon_cg_sense", "cg_normal", "nrmse"]

_TINY = 1e-30


def _dot(a: jax.Array, b: jax.Array) -> jax.Array:
    """Per-item real inner product ``Re<a, b>`` over the frame axes."""
    return jnp.real(jnp.sum(jnp.conj(a) * b, axis=(-2, -1)))


def recon_zero_filled(
    kspace: jax.Array, smaps: jax.Array, mask=None
) -> jax.Array:
    """The non-iterative baseline: ``Aᴴ y`` (coil-combined zero-filled).

    With RSS-normalised maps this is the sensitivity-weighted zero-filled
    image — the thing CG-SENSE must beat, and its own first iterate.
    """
    return sense_adjoint(kspace, smaps, mask)


def cg_normal(
    normal_op: Callable[[jax.Array], jax.Array],
    b: jax.Array,
    iters: int = 10,
    tol: float = 0.0,
    event: str = "mri.cg.iter",
    **event_fields,
) -> jax.Array:
    """Conjugate gradients on ``normal_op(x) = b`` from ``x = 0``.

    ``normal_op`` must be self-adjoint positive (semi-)definite — any
    ``AᴴA + λI`` qualifies; :func:`recon_cg_sense` and the
    motion-compensated model in :mod:`repro.mri.moco` both drive their
    solves through here. ``b`` may carry leading batch axes: inner
    products reduce over the trailing frame axes only, so every batch
    item takes its own step sizes.

    Emits one ``event`` obs event per iteration with the worst-case
    relative residual ``max_B ||r|| / ||b||`` (a host sync per iteration
    — the residual trace is the point of the loop, not a by-product).
    ``tol > 0`` stops early once that residual falls below it.
    """
    if iters < 1:
        raise ValueError(f"iters must be >= 1, got {iters}")
    x = jnp.zeros_like(b)
    r = b
    p = r
    rs = _dot(r, r)
    bnorm = jnp.sqrt(jnp.maximum(rs, _TINY))
    for i in range(iters):
        q = normal_op(p)
        alpha = rs / jnp.maximum(_dot(p, q), _TINY)
        x = x + alpha[..., None, None] * p
        r = r - alpha[..., None, None] * q
        rs_new = _dot(r, r)
        residual = float(jnp.max(jnp.sqrt(jnp.maximum(rs_new, 0.0)) / bnorm))
        # emit bumps the event's counter itself — one count per iteration
        obs.emit(event, iter=i, residual=residual, **event_fields)
        if tol > 0.0 and residual <= tol:
            break
        beta = rs_new / jnp.maximum(rs, _TINY)
        p = r + beta[..., None, None] * p
        rs = rs_new
    return x


def recon_cg_sense(
    kspace: jax.Array,
    smaps: jax.Array,
    mask=None,
    iters: int = 10,
    lam: float = 0.0,
    tol: float = 0.0,
) -> jax.Array:
    """CG-SENSE: solve ``(AᴴA + λI) x = Aᴴ y`` for the image.

    ``kspace``/``smaps``: ``(..., C, H, W)``; ``mask`` broadcasts over
    the coil axis (``None`` = fully sampled). ``lam`` is the Tikhonov
    weight (0 is plain SENSE; a small ``lam`` tames the nullspace of
    heavily undersampled problems). Returns the ``(..., H, W)`` image.
    """
    if lam < 0.0:
        raise ValueError(f"lam must be >= 0, got {lam}")
    kspace = jnp.asarray(kspace)
    smaps = jnp.asarray(smaps)
    b = sense_adjoint(kspace, smaps, mask)

    def normal_op(x: jax.Array) -> jax.Array:
        ax = sense_adjoint(sense_forward(x, smaps, mask), smaps, mask)
        return ax + lam * x if lam else ax

    shape = (kspace.shape[-2], kspace.shape[-1])
    return cg_normal(
        normal_op, b, iters=iters, tol=tol,
        model="sense", shape=shape, coils=kspace.shape[-3],
    )


def nrmse(estimate, reference, magnitude: bool = True) -> float:
    """Normalised RMSE ``||est − ref|| / ||ref||`` (on magnitudes by
    default — MRI images carry coil/acquisition phase the phantom ground
    truth doesn't)."""
    est = jnp.asarray(estimate)
    ref = jnp.asarray(reference)
    if magnitude:
        est, ref = jnp.abs(est), jnp.abs(ref)
    denom = jnp.sqrt(jnp.sum(jnp.abs(ref) ** 2))
    return float(jnp.sqrt(jnp.sum(jnp.abs(est - ref) ** 2)) / jnp.maximum(denom, _TINY))
