"""Reproducible Cartesian undersampling masks + ESPIRiT-lite coil maps.

Cartesian MRI undersamples along the phase-encode axis (rows here):
a mask keeps whole k-space rows, and the acceleration factor ``R`` is
the ratio of total to kept rows. Two generators:

* :func:`uniform_mask` — every ``R``-th row (the classic SENSE pattern,
  coherent fold-over aliasing), plus a fully-sampled calibration block;
* :func:`variable_density_mask` — seeded random rows with a Gaussian
  density concentrated at the k-space centre (incoherent aliasing, the
  pattern iterative reconstruction prefers), plus the calibration block.

Both are plain numpy on purpose — mask generation is a *fixture*, it
must be bit-reproducible from its seed and must not exercise the
transform engines under test (the same rule as
``repro.imaging.synthetic``).

:func:`estimate_sensitivities` is the ESPIRiT-lite map estimate: window
the fully-sampled calibration region, one planned low-resolution inverse
transform per coil, normalise by the root-sum-of-squares image. Good
enough to close the CG-SENSE loop without carrying the full ESPIRiT
eigen-decomposition.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np

__all__ = [
    "uniform_mask",
    "variable_density_mask",
    "acceleration",
    "estimate_sensitivities",
]


def _check_mask_args(shape: Tuple[int, int], accel: int, calib: int) -> None:
    if len(shape) != 2:
        raise ValueError(f"mask shape must be (H, W), got {tuple(shape)}")
    if accel < 1:
        raise ValueError(f"acceleration must be >= 1, got {accel}")
    if not 0 <= calib <= shape[0]:
        raise ValueError(
            f"calibration rows must be in 0..{shape[0]}, got {calib}"
        )


def _calib_rows(h: int, calib: int) -> slice:
    start = (h - calib) // 2
    return slice(start, start + calib)


def uniform_mask(
    shape: Tuple[int, int], accel: int, calib: int = 16
) -> np.ndarray:
    """Every ``accel``-th phase-encode row + a centred ``calib``-row block.

    Returns a float32 ``(H, W)`` mask. Row 0 is always kept, so the
    pattern is deterministic without a seed.
    """
    _check_mask_args(shape, accel, calib)
    h, w = shape
    mask = np.zeros((h, w), np.float32)
    mask[::accel, :] = 1.0
    if calib:
        mask[_calib_rows(h, calib), :] = 1.0
    return mask


def variable_density_mask(
    shape: Tuple[int, int], accel: int, calib: int = 16, seed: int = 0
) -> np.ndarray:
    """Seeded random rows, Gaussian-dense at the centre, calib block kept.

    The per-row keep probability is a Gaussian in the distance from the
    k-space centre, scaled so the EXPECTED kept-row count is ``H/accel``
    (calibration rows count toward the budget; probabilities clip at 1).
    Same ``(shape, accel, calib, seed)`` -> bit-identical mask.
    """
    _check_mask_args(shape, accel, calib)
    h, w = shape
    rows = np.arange(h, dtype=np.float64)
    dist = np.abs(rows - h / 2.0) / (h / 2.0)            # 0 centre .. 1 edge
    density = np.exp(-(dist**2) / (2 * 0.35**2))
    target = h / accel
    density *= target / density.sum()
    # iterate the clip-renormalise once: clipped centre rows push their
    # excess budget outward instead of silently under-sampling
    excess = np.clip(density - 1.0, 0.0, None).sum()
    density = np.clip(density, 0.0, 1.0)
    tail = density < 1.0
    if excess > 0 and tail.any():
        density[tail] += excess * density[tail] / density[tail].sum()
        density = np.clip(density, 0.0, 1.0)
    keep = np.random.default_rng(seed).random(h) < density
    if calib:
        keep[_calib_rows(h, calib)] = True
    mask = np.zeros((h, w), np.float32)
    mask[keep, :] = 1.0
    return mask


def acceleration(mask) -> float:
    """The realised acceleration factor ``R = size / samples`` of a mask."""
    mask = np.asarray(mask)
    kept = float((mask != 0).sum())
    if kept == 0:
        raise ValueError("mask keeps no samples")
    return mask.size / kept


def estimate_sensitivities(
    kspace: jax.Array,
    calib: int = 16,
    eps: float = 1e-6,
    mask: Optional[np.ndarray] = None,
) -> jax.Array:
    """ESPIRiT-lite sensitivity maps from the calibration region.

    ``kspace``: centered ``(..., C, H, W)`` multi-coil data whose
    central ``calib`` rows (and columns) are fully sampled. A smooth
    (Hann) window over that block suppresses truncation ringing; one
    planned inverse transform gives low-resolution coil images, and the
    maps are those images normalised by their root-sum-of-squares:

        S_c = lowres_c / (RSS(lowres) + eps)

    so ``RSS(S) ≈ 1`` wherever the object has signal — which makes the
    CG-SENSE normal operator well conditioned. ``mask`` is accepted for
    convenience (it is ignored beyond a sanity check that the
    calibration block is actually sampled).
    """
    import jax.numpy as jnp

    from repro.mri.operators import rss_combine

    kspace = jnp.asarray(kspace)
    if kspace.ndim < 3:
        raise ValueError(f"kspace must be (..., C, H, W), got shape {kspace.shape}")
    h, w = kspace.shape[-2], kspace.shape[-1]
    if not 0 < calib <= min(h, w):
        raise ValueError(f"calib must be in 1..{min(h, w)}, got {calib}")
    if mask is not None:
        block = np.asarray(mask)[_calib_rows(h, calib), :]
        if not np.all(block != 0):
            raise ValueError(
                "mask does not fully sample the calibration block "
                f"(central {calib} rows)"
            )

    def axis_window(n: int, keep: int) -> np.ndarray:
        win = np.zeros(n, np.float32)
        start = (n - keep) // 2
        win[start:start + keep] = np.hanning(keep + 2)[1:-1].astype(np.float32)
        return win

    window = jnp.asarray(np.outer(axis_window(h, calib), axis_window(w, calib)))
    from repro.imaging.kspace import kspace_to_image

    lowres = kspace_to_image(kspace * window)
    rss = rss_combine(lowres)
    return lowres / (rss[..., None, :, :] + eps)
