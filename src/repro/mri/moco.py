"""Batchelor-style motion-compensated forward model and reconstruction.

Multi-shot MRI acquires k-space in interleaved *shots*; a patient who
moves between shots corrupts the data in a way zero-filling cannot undo
— but that motion can be modelled. Batchelor's general matrix model
(the moco-workshop's reconstruction ladder) composes a rigid motion
operator ``T_s`` per shot into the SENSE encoding:

    y = Σ_s M_s · F · S · T_s x,      x̂ = Σ_s T_s⁻¹ · Sᴴ · F⁻¹ · M_s y

where ``M_s`` are the disjoint per-shot sampling masks. For pure
translation ``T_s`` is :func:`repro.imaging.apply_shift` — the PR-4
Fourier-shift operator, unitary and circular, so its adjoint is the
shift by ``−d_s`` and the pair above is again a true adjoint pair. The
per-shot motion itself is estimable from the data with the PR-4
registration machinery (:func:`estimate_shot_shifts`), which is the
point of this module: the registration workload becomes a
reconstruction *building block*.

Reconstruction reuses the shared CG driver (:func:`repro.mri.recon.
cg_normal`) on this model's normal equations; every inner transform is
the same planned centered ``fft2`` the SENSE path uses, just batched
one axis deeper (shots × coils).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.imaging.registration import apply_shift, register_phase_correlation
from repro.mri.operators import apply_mask, sense_adjoint, sense_forward
from repro.mri.recon import cg_normal

__all__ = [
    "shot_masks",
    "moco_forward",
    "moco_adjoint",
    "recon_cg_moco",
    "estimate_shot_shifts",
]


def shot_masks(mask, n_shots: int) -> np.ndarray:
    """Partition a sampling mask into ``n_shots`` interleaved shot masks.

    Sampled phase-encode rows are dealt round-robin to shots (the
    standard interleaved multi-shot ordering), so the per-shot masks are
    disjoint and sum back to ``mask``. Returns float32
    ``(n_shots, H, W)``.
    """
    mask = np.asarray(mask)
    if mask.ndim != 2:
        raise ValueError(f"mask must be (H, W), got shape {mask.shape}")
    if n_shots < 1:
        raise ValueError(f"n_shots must be >= 1, got {n_shots}")
    sampled_rows = np.flatnonzero((mask != 0).any(axis=1))
    if len(sampled_rows) < n_shots:
        raise ValueError(
            f"mask has {len(sampled_rows)} sampled rows, too few for "
            f"{n_shots} shots"
        )
    shots = np.zeros((n_shots, *mask.shape), np.float32)
    for i, row in enumerate(sampled_rows):
        shots[i % n_shots, row, :] = mask[row, :]
    return shots


def _check_shots(masks: jax.Array, shifts: jax.Array) -> None:
    if masks.ndim != 3:
        raise ValueError(f"shot masks must be (S, H, W), got shape {masks.shape}")
    if shifts.shape != (masks.shape[0], 2):
        raise ValueError(
            f"shifts must be ({masks.shape[0]}, 2) to match the shot "
            f"masks, got shape {tuple(shifts.shape)}"
        )


def moco_forward(
    image: jax.Array, smaps: jax.Array, masks: jax.Array, shifts
) -> jax.Array:
    """Motion-compensated forward model: ``Σ_s M_s F S T_s x``.

    ``image``: ``(H, W)``; ``smaps``: ``(C, H, W)``; ``masks``:
    ``(S, H, W)`` disjoint shot masks; ``shifts``: ``(S, 2)`` per-shot
    ``(dy, dx)`` object translations. Returns ``(C, H, W)`` k-space —
    the shots' disjoint masks make the sum a k-space interleave. All
    shots ride the leading batch axis of ONE planned transform.
    """
    image = jnp.asarray(image)
    if image.ndim != 2:
        raise ValueError(f"image must be (H, W), got shape {image.shape}")
    masks = jnp.asarray(masks)
    shifts = jnp.asarray(shifts, dtype=jnp.float32)
    _check_shots(masks, shifts)
    if not jnp.issubdtype(image.dtype, jnp.complexfloating):
        image = image.astype(jnp.complex64)
    moved = apply_shift(image, shifts)                    # (S, H, W)
    kspace = sense_forward(moved, smaps, mask=None)       # (S, C, H, W)
    return jnp.sum(apply_mask(kspace, masks[:, None]), axis=0)


def moco_adjoint(
    kspace: jax.Array, smaps: jax.Array, masks: jax.Array, shifts
) -> jax.Array:
    """Adjoint of :func:`moco_forward`: ``Σ_s T_s⁻¹ Sᴴ F⁻¹ M_s y``.

    ``apply_shift`` is unitary, so its adjoint is the opposite shift —
    each shot's coil-combined image is shifted back before the sum.
    """
    kspace = jnp.asarray(kspace)
    masks = jnp.asarray(masks)
    shifts = jnp.asarray(shifts, dtype=jnp.float32)
    _check_shots(masks, shifts)
    per_shot = apply_mask(kspace[None], masks[:, None])   # (S, C, H, W)
    images = sense_adjoint(per_shot, smaps, mask=None)    # (S, H, W)
    return jnp.sum(apply_shift(images, -shifts), axis=0)


def recon_cg_moco(
    kspace: jax.Array,
    smaps: jax.Array,
    masks: jax.Array,
    shifts,
    iters: int = 10,
    lam: float = 0.0,
    tol: float = 0.0,
) -> jax.Array:
    """CG on the motion-compensated normal equations.

    The moco analogue of :func:`repro.mri.recon.recon_cg_sense`: with
    the true (or well-estimated) per-shot ``shifts``, inter-shot motion
    stops being an artifact and becomes part of the encoding — the gate
    test shows it beating motion-blind CG-SENSE on the same data.
    """
    if lam < 0.0:
        raise ValueError(f"lam must be >= 0, got {lam}")
    b = moco_adjoint(kspace, smaps, masks, shifts)

    def normal_op(x: jax.Array) -> jax.Array:
        ax = moco_adjoint(moco_forward(x, smaps, masks, shifts), smaps,
                          masks, shifts)
        return ax + lam * x if lam else ax

    return cg_normal(
        normal_op, b, iters=iters, tol=tol,
        model="moco", shape=(kspace.shape[-2], kspace.shape[-1]),
        coils=kspace.shape[-3], shots=int(jnp.asarray(masks).shape[0]),
    )


def estimate_shot_shifts(
    kspace: jax.Array,
    smaps: jax.Array,
    masks: jax.Array,
    ref_shot: int = 0,
    upsample_factor: int = 4,
) -> jax.Array:
    """Estimate per-shot object shifts by registering shot navigators.

    Each shot's zero-filled coil combine is a (heavily aliased) snapshot
    of the object at that shot's motion state; registering every shot's
    magnitude onto ``ref_shot``'s with
    :func:`repro.imaging.register_phase_correlation` recovers the
    relative translations. Returns ``(S, 2)`` shifts in the
    :func:`moco_forward` convention (``shifts[ref_shot] == 0``), ready
    to hand to :func:`recon_cg_moco`.
    """
    kspace = jnp.asarray(kspace)
    masks = jnp.asarray(masks)
    n_shots = masks.shape[0]
    if not 0 <= ref_shot < n_shots:
        raise ValueError(f"ref_shot must be in 0..{n_shots - 1}, got {ref_shot}")
    per_shot = apply_mask(kspace[None], masks[:, None])   # (S, C, H, W)
    navs = jnp.abs(sense_adjoint(per_shot, smaps, mask=None))  # (S, H, W)
    ref = jnp.broadcast_to(navs[ref_shot], navs.shape)
    # register returns the shift that maps each nav ONTO the reference;
    # the shot's own motion is the opposite of that correction
    correction = register_phase_correlation(
        ref, navs, upsample_factor=upsample_factor
    )
    return -correction
