"""Render results/dryrun/*.json into the EXPERIMENTS.md §Dry-run/§Roofline
markdown tables.

  PYTHONPATH=src python -m repro.launch.report [--dir results/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def fmt_s(x):
    if x == 0:
        return "0"
    return f"{x:.2e}"


def load(dirpath):
    rows = []
    for path in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def roofline_table(rows, mesh="pod1_16x16"):
    out = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "useful ratio | MFU@roofline | bytes/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("mesh") != mesh:
            continue
        if r["status"] == "skip":
            out.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | *skip* | — | — | — |"
            )
            continue
        if r["status"] != "ok":
            out.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | **ERROR** | — | — | — |"
            )
            continue
        rl = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(rl['compute_s'])} | "
            f"{fmt_s(rl['memory_s'])} | {fmt_s(rl['collective_s'])} | "
            f"{rl['dominant']} | {rl['useful_flops_ratio']:.3f} | "
            f"{rl['mfu_at_roofline']*100:.2f}% | "
            f"{fmt_bytes(r['memory']['total_bytes'])} |"
        )
    return "\n".join(out)


def dryrun_table(rows):
    out = [
        "| arch | shape | mesh | status | args/dev | temp/dev | flops/dev | "
        "coll traffic/dev | #coll |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["status"] == "skip":
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | skip ({r['reason'][:40]}…) "
                f"| — | — | — | — | — |"
            )
            continue
        if r["status"] != "ok":
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | **ERROR** | — | — | — | — | — |"
            )
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{fmt_bytes(r['memory']['argument_bytes'])} | "
            f"{fmt_bytes(r['memory']['temp_bytes'])} | "
            f"{r['cost']['flops']:.2e} | "
            f"{fmt_bytes(r['collective_traffic_bytes'])} | {r['collective_count']} |"
        )
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--section", choices=["roofline", "dryrun", "both"], default="both")
    args = ap.parse_args()
    rows = load(args.dir)
    if args.section in ("roofline", "both"):
        print("### Roofline (single-pod 16x16)\n")
        print(roofline_table(rows))
        print()
    if args.section in ("dryrun", "both"):
        print("### Dry-run (both meshes)\n")
        print(dryrun_table(rows))


if __name__ == "__main__":
    main()
