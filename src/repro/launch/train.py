"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch fourier_lm --steps 200 \
      --batch 8 --seq 256 --ckpt /tmp/run1

Single-host by default; on a real multi-host TPU deployment the same entry
point calls ``jax.distributed.initialize()`` (guarded below) and the mesh
spans all processes — nothing else changes (GSPMD + the sharding rules do
the rest)."""

from __future__ import annotations

import argparse
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="fourier_lm")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--peak-lr", type=float, default=3e-4)
    ap.add_argument("--compress", action="store_true",
                    help="int8 gradient compression with error feedback")
    ap.add_argument("--distributed", action="store_true",
                    help="multi-host: jax.distributed.initialize()")
    args = ap.parse_args()

    if args.distributed:
        import jax

        jax.distributed.initialize()

    import jax
    import numpy as np

    from repro.configs.registry import get_config, smoke_config
    from repro.data.pipeline import make_batch
    from repro.models.build import build
    from repro.train.loop import TrainLoop

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build(cfg)
    print(f"[train] arch={cfg.name} params={model.n_params/1e6:.1f}M "
          f"batch={args.batch} seq={args.seq}")

    def batch_fn(step: int):
        return make_batch(cfg, args.batch, args.seq, step)

    loop = TrainLoop(
        model,
        ckpt_dir=args.ckpt,
        batch_fn=batch_fn,
        save_every=args.save_every,
        accum=args.accum,
        peak_lr=args.peak_lr,
        compress=args.compress,
    )
    t0 = time.perf_counter()
    losses = loop.run(jax.random.PRNGKey(0), args.steps)
    dt = time.perf_counter() - t0
    steps = sorted(losses)
    if steps:
        first = np.mean([losses[s] for s in steps[: max(len(steps)//10, 1)]])
        last = np.mean([losses[s] for s in steps[-max(len(steps)//10, 1):]])
        print(f"[train] {len(steps)} steps in {dt:.1f}s "
              f"({dt/max(len(steps),1):.2f}s/step) loss {first:.3f} -> {last:.3f}")
    if loop.monitor.flags:
        print(f"[train] straggler flags: {loop.monitor.flags[:5]}")


if __name__ == "__main__":
    main()
