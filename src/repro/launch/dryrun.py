import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture × input-shape × mesh) cell against the production meshes,
with ShapeDtypeStruct stand-ins (zero allocation), and record
memory_analysis / cost_analysis / collective traffic for §Roofline.

  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --skip-existing
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
from repro import compat
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs.registry import (  # noqa: E402
    ALL_IDS,
    ARCH_IDS,
    SHAPES,
    get_config,
    input_specs,
    shape_skips,
)
from repro.launch.hlo_analysis import collective_schedule, collective_stats  # noqa: E402
from repro.launch.hlo_cost import loop_aware_cost, top_collectives  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.roofline import Roofline, model_flops  # noqa: E402
from repro.models.build import build  # noqa: E402
from repro.optim import adamw_init  # noqa: E402
from repro.sharding import batch_specs, cache_specs, param_rules  # noqa: E402
from repro.sharding.ctx import activation_sharding  # noqa: E402
from repro.train.loop import TrainState, make_train_step  # noqa: E402

# archs whose optimizer state must be bf16 to fit 512 v5e chips (noted in
# EXPERIMENTS.md §Dry-run)
_BF16_OPT = {"deepseek-v3-671b", "internvl2-76b", "mixtral-8x22b"}


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def build_cell(arch: str, shape: str, mesh, multi_pod: bool, overrides=None,
               bf16_params: bool = False):
    """Returns (jittable fn, arg SDS tuple, in_shardings tuple, meta)."""
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.scaled(**overrides)
    model = build(cfg)
    info = SHAPES[shape]
    kind = info["kind"]
    seq, batch = info["seq"], info["batch"]
    rules = param_rules(cfg, multi_pod=multi_pod)
    pspecs = model.specs(rules)
    specs = input_specs(cfg, shape)
    bspecs = batch_specs(cfg, kind, multi_pod=multi_pod, batch=batch)

    if kind == "train":
        params_sds = model.abstract(jnp.float32)
        opt_dtype = jnp.bfloat16 if arch in _BF16_OPT else jnp.float32
        opt_sds = jax.eval_shape(lambda p: adamw_init(p, opt_dtype), params_sds)
        state_sds = TrainState(params_sds, opt_sds, None)
        opt_specs = {
            "mu": pspecs,
            "nu": pspecs,
            "step": P(),
        }
        state_specs = TrainState(pspecs, opt_specs, None)
        step = make_train_step(
            model.loss_fn,
            cast_params=jnp.bfloat16 if bf16_params else None,
        )
        args = (state_sds, specs)
        shardings = (_named(mesh, state_specs), _named(mesh, bspecs))
        return step, args, shardings, {"cfg": cfg, "model": model, "kind": kind,
                                       "seq": seq, "batch": batch}

    params_sds = model.abstract(jnp.bfloat16)  # serving weights
    cache_len = seq
    cache_dtype = jnp.bfloat16
    if model.init_cache_fn is None:  # encoder-style arch: no KV cache
        caches_sds, cspecs = None, None
    else:
        caches_sds = jax.eval_shape(
            lambda: model.init_cache_fn(batch, cache_len, cache_dtype)
        )
        cspecs = cache_specs(cfg, caches_sds, batch, multi_pod=multi_pod)

    if kind == "prefill":
        def step(params, batch_in, caches):
            return model.prefill_fn(params, batch_in, caches)

        args = (params_sds, specs, caches_sds)
        shardings = (_named(mesh, pspecs), _named(mesh, bspecs), _named(mesh, cspecs))
        return step, args, shardings, {"cfg": cfg, "model": model, "kind": kind,
                                       "seq": seq, "batch": batch}

    # decode
    def step(params, token, pos, caches):
        return model.decode_fn(params, token, pos, caches)

    args = (params_sds, specs["token"], specs["pos"], caches_sds)
    shardings = (
        _named(mesh, pspecs),
        _named(mesh, bspecs["token"]),
        _named(mesh, bspecs["pos"]),
        _named(mesh, cspecs),
    )
    return step, args, shardings, {"cfg": cfg, "model": model, "kind": kind,
                                   "seq": seq, "batch": batch}


def run_cell(arch: str, shape: str, multi_pod: bool, overrides=None,
             hlo_path: str | None = None, bf16_params: bool = False) -> dict:
    cfg = get_config(arch)
    skip = shape_skips(cfg, shape)
    mesh_name = "pod2_2x16x16" if multi_pod else "pod1_16x16"
    if skip:
        return {"arch": arch, "shape": shape, "mesh": mesh_name, "status": "skip",
                "reason": skip}
    from repro.sharding.rules import use_tp

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    dp = ("pod", "data") if multi_pod else ("data",)
    dp_sizes = (2, 16) if multi_pod else (16,)
    cp = None
    if not use_tp(cfg):
        # pure 2-D batch FSDP: batch spreads over the model axis too; when
        # an INFERENCE batch can't fill it, attention falls back to context
        # parallelism over the same axis (ctx.cp_axis_for). Training keeps
        # plain 2-D batch: a global batch below mesh size is a configuration
        # smell at this scale, and CP-under-autodiff-under-remat explodes
        # host compile memory (documented in EXPERIMENTS.md §Dry-run).
        dp, dp_sizes = dp + ("model",), dp_sizes + (16,)
        tp = None
        info = SHAPES[shape]
        if info["kind"] != "train":
            cp = "model"
    else:
        tp = "model"
    t0 = time.perf_counter()
    step, args, shardings, meta = build_cell(
        arch, shape, mesh, multi_pod, overrides, bf16_params=bf16_params
    )
    with compat.set_mesh(mesh), activation_sharding(
        dp=dp, dp_sizes=dp_sizes, tp=tp, tp_size=16, cp=cp, cp_size=16,
    ):
        lowered = jax.jit(step, in_shardings=shardings).lower(*args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    if hlo_path:
        with open(hlo_path, "w") as f:
            f.write(hlo)
    colls = collective_stats(hlo)          # static (once-per-body) breakdown
    lac = loop_aware_cost(hlo)             # loop-multiplied totals (§Roofline)
    sched = collective_schedule(hlo, limit=20)
    mf = model_flops(meta["cfg"], meta["model"].skeleton, meta["kind"],
                     meta["seq"], meta["batch"])
    rl = Roofline(
        flops_per_device=float(lac["flops"]),
        bytes_per_device=float(lac["bytes"]),
        collective_bytes_per_device=float(lac["collective_traffic_bytes"]),
        n_devices=n_dev,
        model_flops_global=mf,
    )
    return {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_name,
        "status": "ok",
        "n_devices": n_dev,
        "kind": meta["kind"],
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
            "total_bytes": mem.argument_size_in_bytes + mem.temp_size_in_bytes
            + mem.output_size_in_bytes,
        },
        "cost_xla_once_per_body": {
            k: cost[k] for k in ("flops", "bytes accessed", "transcendentals")
            if k in cost
        },
        "cost": {"flops": lac["flops"], "bytes accessed": lac["bytes"]},
        "collectives": {k: v for k, v in colls.items() if isinstance(v, dict)},
        "collective_traffic_bytes": lac["collective_traffic_bytes"],
        "collective_count": lac["collective_count"],
        "schedule_head": sched,
        "top_collectives": top_collectives(hlo, 15),
        "roofline": rl.to_dict(),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--include-fourier", action="store_true",
                    help="also dry-run the paper's own fourier_lm arch")
    ap.add_argument("--moe-impl", default=None,
                    choices=["grouped_local", "ep_a2a", "dense_small"],
                    help="§Perf override: MoE dispatch path")
    ap.add_argument("--ep-axes", default="data,model",
                    help="mesh axes for expert parallelism (comma list)")
    ap.add_argument("--fft-variant", default=None,
                    choices=["looped", "unrolled", "stockham", "rfft"],
                    help="§Perf override: spectral mixing variant")
    ap.add_argument("--attn-block-q", type=int, default=None)
    ap.add_argument("--attn-block-k", type=int, default=None)
    ap.add_argument("--no-remat", action="store_true",
                    help="§Perf override: disable per-layer rematerialisation")
    ap.add_argument("--remat-policy", default=None, choices=["full", "dots"],
                    help="§Perf override: selective checkpoint policy")
    ap.add_argument("--save-hlo", action="store_true",
                    help="also dump the compiled HLO text next to the JSON")
    ap.add_argument("--bf16-params", action="store_true",
                    help="§Perf override: differentiate at a bf16 view of the "
                         "f32 master weights (bf16 gathers + grad reductions)")
    ap.add_argument("--tag", default="", help="suffix for the result file")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else (
        ALL_IDS if args.include_fourier else ARCH_IDS
    )
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.multi_pod]
    os.makedirs(args.out, exist_ok=True)

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                overrides = {}
                if args.moe_impl:
                    import dataclasses

                    base_moe = get_config(arch).moe
                    if base_moe is not None:
                        overrides["moe"] = dataclasses.replace(
                            base_moe,
                            impl=args.moe_impl,
                            ep_axes=tuple(args.ep_axes.split(",")),
                        )
                if args.fft_variant:
                    overrides["fft_variant"] = args.fft_variant
                if args.no_remat:
                    overrides["remat"] = False
                if args.remat_policy:
                    overrides["remat_policy"] = args.remat_policy
                if args.attn_block_q:
                    overrides["attn_block_q"] = args.attn_block_q
                if args.attn_block_k:
                    overrides["attn_block_k"] = args.attn_block_k
                tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
                if args.tag:
                    tag += f"__{args.tag}"
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip-existing] {tag}")
                    continue
                print(f"[dryrun] {tag} ...", flush=True)
                try:
                    res = run_cell(
                        arch, shape, mp, overrides or None,
                        hlo_path=path.replace(".json", ".hlo.txt")
                        if args.save_hlo else None,
                        bf16_params=args.bf16_params,
                    )
                except Exception as e:  # record the failure, keep sweeping
                    res = {"arch": arch, "shape": shape,
                           "mesh": "multi" if mp else "single",
                           "status": "error", "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-3000:]}
                    failures.append(tag)
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                if res["status"] == "ok":
                    r = res["roofline"]
                    print(
                        f"  ok: compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s "
                        f"collective={r['collective_s']:.3e}s dominant={r['dominant']} "
                        f"(lower {res['lower_s']}s compile {res['compile_s']}s)",
                        flush=True,
                    )
                elif res["status"] == "skip":
                    print(f"  skip: {res['reason']}")
                else:
                    print(f"  ERROR: {res['error']}")
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("dry-run sweep complete")


if __name__ == "__main__":
    main()
