"""While-loop-aware FLOP/byte costing of compiled HLO text.

``compiled.cost_analysis()`` visits every computation ONCE — a
scan-over-layers model (while loop) therefore under-reports FLOPs/bytes by
the trip count (verified experimentally; see EXPERIMENTS.md §Roofline
methodology). This module re-costs the compiled module with loop
multiplication:

  cost(computation) = Σ op_cost + Σ_while trips(while) × cost(body)

op costs:
  dot            2 × |result| × contracted_size   (contraction dims parsed)
  custom-call    2·m·k·n when the target mentions matmul/dot
  fusion         cost of the fused computation (dots inside counted)
  elementwise    |result| flops (minor term)
bytes: every op contributes |result| × (1 read + 1 write) — a deliberate,
documented approximation of HBM traffic (fusion internals stay in
registers on the real machine, so only fusion ROOT results are counted).

Trip counts come from the loop condition's comparison constant (scan
lowers to `compare(iv, constant(N)), direction=LT`).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "c64": 8, "s64": 8, "u64": 8, "f64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(pred|bf16|f8e4m3fn|f8e5m2|[suf]\d+|c64|c128)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_CALLED_RE = re.compile(r"(?:body|to_apply|calls)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_OPERANDS_RE = re.compile(r"\(([^)]*)\)")


def _shapes(text: str):
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out.append((n, _DTYPE_BYTES.get(dt, 4)))
    return out


def _result_part(rest: str) -> str:
    """Everything before the opcode = the result type(s)."""
    m = re.search(r"\b([a-z][a-z0-9\-]*)\(", rest)
    return rest[: m.start()] if m else rest


def _opcode(rest: str) -> str:
    m = re.search(r"\b([a-z][a-z0-9\-]*)\(", rest)
    return m.group(1) if m else ""


@dataclass
class _Op:
    name: str
    opcode: str
    rest: str
    result_elems: int
    result_bytes: int


@dataclass
class _Computation:
    name: str
    ops: list = field(default_factory=list)
    shapes: dict = field(default_factory=dict)  # op name -> full result text


def parse_computations(hlo: str) -> dict[str, _Computation]:
    comps: dict[str, _Computation] = {}
    cur: _Computation | None = None
    for line in hlo.splitlines():
        s = line.strip()
        header = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{", s)
        if header and not s.startswith("//"):
            cur = _Computation(header.group(1))
            comps[cur.name] = cur
            continue
        if cur is None or s.startswith("}"):
            continue
        m = _OP_RE.match(s)
        if not m:
            continue
        name, rest = m.groups()
        shapes = _shapes(_result_part(rest))
        elems = sum(n for n, _ in shapes)
        nbytes = sum(n * b for n, b in shapes)
        cur.ops.append(_Op(name, _opcode(rest), rest, elems, nbytes))
        cur.shapes[name] = _result_part(rest)
    return comps


def _dims_list(rest: str, key: str) -> list[int]:
    m = re.search(rf"{key}={{([\d,]*)}}", rest)
    if not m or not m.group(1):
        return []
    return [int(x) for x in m.group(1).split(",")]


def _operand_names(rest: str) -> list[str]:
    m = re.search(r"\b[a-z][a-z0-9\-]*\(([^)]*)\)", rest)
    if not m:
        return []
    out = []
    for part in m.group(1).split(","):
        part = part.strip()
        mm = re.match(r"(?:[\w\[\],\{\}]+\s+)?%([\w.\-]+)", part)
        if mm:
            out.append(mm.group(1))
    return out


def _operand_shape_dims(comp: _Computation, rest: str, idx: int) -> list[int]:
    """Dims of the idx-th operand (resolved via in-computation def or the
    inline type annotation)."""
    # inline annotation: opcode(f32[a,b] %x, ...)
    m = re.search(r"\b[a-z][a-z0-9\-]*\(([^)]*)\)", rest)
    if m:
        parts = [p.strip() for p in m.group(1).split(",")]
        # reassemble shapes that contain commas: fall back to name lookup
    names = _operand_names(rest)
    if idx < len(names) and names[idx] in comp.shapes:
        sh = _SHAPE_RE.search(comp.shapes[names[idx]])
        if sh:
            return [int(x) for x in sh.group(2).split(",") if x]
    return []


def _trip_count(cond: _Computation) -> int:
    """Largest comparison constant in the loop condition."""
    best = 1
    for op in cond.ops:
        if op.opcode == "constant":
            m = re.search(r"constant\((\d+)\)", op.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


_ELEMENTWISE_FLOPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "exponential",
    "tanh", "negate", "compare", "select", "rsqrt", "sqrt", "log", "power",
    "cosine", "sine", "and", "or",
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _group_size(rest: str) -> int:
    m = _GROUPS_RE.search(rest)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(rest)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 1


def _collective_kind(opcode: str) -> str | None:
    for k in _COLLECTIVES:
        if opcode == k or opcode == k + "-start":
            return k
    return None


def _collective_traffic(kind: str, result_bytes: int, g: int) -> int:
    """Per-device link-traffic model (documented in hlo_analysis.py)."""
    if kind == "all-reduce":
        return int(2 * result_bytes * (g - 1) / max(g, 1))
    if kind == "all-gather":
        return int(result_bytes * (g - 1) / max(g, 1))
    if kind == "reduce-scatter":
        return int(result_bytes * (g - 1))
    if kind == "all-to-all":
        return int(result_bytes * (g - 1) / max(g, 1))
    return result_bytes  # collective-permute: one hop


def cost_computation(
    comps: dict[str, _Computation], name: str, memo: dict | None = None
) -> tuple[float, float, float, float]:
    """(flops, bytes, collective_traffic, collective_count), loops multiplied."""
    if memo is None:
        memo = {}
    if name in memo:
        return memo[name]
    comp = comps.get(name)
    if comp is None:
        return (0.0, 0.0, 0.0, 0.0)
    memo[name] = (0.0, 0.0, 0.0, 0.0)  # cycle guard
    flops = nbytes = coll = ccount = 0.0
    for op in comp.ops:
        kind = _collective_kind(op.opcode)
        if op.opcode == "while":
            body = _CALLED_RE.search(op.rest)
            cond = _COND_RE.search(op.rest)
            trips = 1
            if cond and cond.group(1) in comps:
                trips = _trip_count(comps[cond.group(1)])
            if body:
                bf, bb, bc, bn = cost_computation(comps, body.group(1), memo)
                flops += trips * bf
                nbytes += trips * bb
                coll += trips * bc
                ccount += trips * bn
        elif kind is not None:
            g = _group_size(op.rest)
            rbytes = op.result_bytes
            if op.opcode.endswith("-start"):
                rbytes //= 2  # async start results alias (operand, dest)
            coll += _collective_traffic(kind, rbytes, g)
            ccount += 1
            nbytes += 2 * rbytes
        elif op.opcode == "fusion":
            called = _CALLED_RE.search(op.rest)
            if called:
                ff, _, _, _ = cost_computation(comps, called.group(1), memo)
                flops += ff
            nbytes += 2 * op.result_bytes  # fusion internals stay fused
        elif op.opcode in ("call", "conditional", "map"):
            called = _CALLED_RE.search(op.rest)
            if called:
                cf, cb, cc, cn = cost_computation(comps, called.group(1), memo)
                flops += cf
                nbytes += cb
                coll += cc
                ccount += cn
        elif op.opcode == "dot":
            contracting = _dims_list(op.rest, "lhs_contracting_dims")
            lhs_dims = _operand_shape_dims(comp, op.rest, 0)
            csize = 1
            for d in contracting:
                if d < len(lhs_dims):
                    csize *= lhs_dims[d]
            flops += 2.0 * op.result_elems * max(csize, 1)
            nbytes += 2 * op.result_bytes
        elif op.opcode == "custom-call":
            if re.search(r"matmul|dot|gemm", op.rest, re.I):
                lhs = _operand_shape_dims(comp, op.rest, 0)
                k = lhs[-1] if lhs else 1
                flops += 2.0 * op.result_elems * k
            nbytes += 2 * op.result_bytes
        elif op.opcode in ("parameter", "constant", "get-tuple-element", "tuple",
                           "bitcast", "iota"):
            pass
        else:
            if op.opcode in _ELEMENTWISE_FLOPS:
                flops += op.result_elems
            nbytes += 2 * op.result_bytes
    memo[name] = (flops, nbytes, coll, ccount)
    return memo[name]


def top_collectives(hlo: str, n: int = 15) -> list[dict]:
    """Per-collective traffic × loop-trip multiplier, sorted descending —
    the §Perf 'where is it going' view."""
    comps = parse_computations(hlo)
    # compute the trip multiplier of every computation (product of enclosing
    # while trip counts), by walking from the entry.
    mult: dict[str, int] = {}

    entry = None
    for line in hlo.splitlines():
        m = re.match(r"^ENTRY\s+%?([\w.\-]+)", line.strip())
        if m:
            entry = m.group(1)
            break

    def walk(name: str, factor: int):
        comp = comps.get(name)
        if comp is None:
            return
        if mult.get(name, 0) >= factor:
            return
        mult[name] = factor
        for op in comp.ops:
            called = _CALLED_RE.search(op.rest)
            if op.opcode == "while":
                cond = _COND_RE.search(op.rest)
                trips = 1
                if cond and cond.group(1) in comps:
                    trips = _trip_count(comps[cond.group(1)])
                if called:
                    walk(called.group(1), factor * trips)
            elif called:
                walk(called.group(1), factor)

    if entry:
        walk(entry, 1)

    rows = []
    for cname, comp in comps.items():
        f = mult.get(cname, 0)
        if f == 0:
            continue
        for op in comp.ops:
            kind = _collective_kind(op.opcode)
            if kind is None:
                continue
            g = _group_size(op.rest)
            rbytes = op.result_bytes
            if op.opcode.endswith("-start"):
                rbytes //= 2
            traffic = _collective_traffic(kind, rbytes, g)
            rows.append({
                "kind": kind,
                "result": _result_part(op.rest).strip()[:60],
                "trips": f,
                "traffic_total": traffic * f,
            })
    rows.sort(key=lambda r: -r["traffic_total"])
    return rows[:n]


def loop_aware_cost(hlo: str) -> dict:
    """Entry point: loop-multiplied flops/bytes/collective traffic."""
    comps = parse_computations(hlo)
    entry = None
    for line in hlo.splitlines():
        m = re.match(r"^ENTRY\s+%?([\w.\-]+)", line.strip())
        if m:
            entry = m.group(1)
            break
    if entry is None:
        entry = next((n for n in comps if "main" in n), next(iter(comps), None))
    flops, nbytes, coll, ccount = cost_computation(comps, entry)
    return {
        "flops": flops,
        "bytes": nbytes,
        "collective_traffic_bytes": coll,
        "collective_count": ccount,
        "entry": entry,
        "n_computations": len(comps),
    }
