"""Serving launcher: batched greedy decoding over the ServeEngine.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b --smoke \
      --batch 4 --prompt-len 16 --max-new 16
"""

from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.registry import get_config, smoke_config
    from repro.data.pipeline import frames_for, patches_for
    from repro.models.build import build
    from repro.serve.engine import Request, ServeEngine

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build(cfg)
    if model.decode_fn is None:
        raise SystemExit(f"{cfg.name} has no decode step (encoder-style arch)")
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(
        model, params, batch=args.batch, max_len=args.max_len, dtype=jnp.float32
    )
    extras = {}
    if cfg.family == "audio":
        extras["frames"] = frames_for(cfg, args.batch, 0)
    if cfg.family == "vlm":
        extras["patches"] = patches_for(cfg, args.batch, 0)

    rng = np.random.default_rng(0)
    queue = [
        Request(prompt=rng.integers(0, cfg.vocab, (args.prompt_len,)).astype(np.int32),
                max_new=args.max_new)
        for _ in range(args.requests)
    ]
    t0 = time.perf_counter()
    done = engine.serve_queue(queue, extras=extras or None)
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in done)
    print(f"[serve] {len(done)} requests, {toks} tokens in {dt:.1f}s "
          f"({toks/max(dt,1e-9):.1f} tok/s) arch={cfg.name}")
    print("[serve] sample output:", done[0].out[:8])


if __name__ == "__main__":
    main()
