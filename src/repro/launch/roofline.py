"""Roofline terms from the compiled dry-run artifact (TPU v5e targets).

  compute    = HLO_FLOPs_per_device / peak_FLOP/s
  memory     = HLO_bytes_per_device / HBM_bw
  collective = collective_traffic_per_device / link_bw

plus MODEL_FLOPS = 6·N·D (train) / 2·N_active·D (inference) and the
usefulness ratio MODEL_FLOPS / (HLO_FLOPs × n_devices)."""

from __future__ import annotations

import dataclasses

import numpy as np

PEAK_FLOPS_BF16 = 197e12     # per v5e chip
HBM_BW = 819e9               # B/s per chip
ICI_LINK_BW = 50e9           # B/s per link


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    n_devices: int
    model_flops_global: float

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_device / ICI_LINK_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops_per_device * self.n_devices
        return self.model_flops_global / total if total else 0.0

    @property
    def step_time_s(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilisation at the roofline step time."""
        t = self.step_time_s
        if t == 0:
            return 0.0
        return self.model_flops_global / (t * self.n_devices * PEAK_FLOPS_BF16)

    def to_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "n_devices": self.n_devices,
            "model_flops_global": self.model_flops_global,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_step_s": self.step_time_s,
            "mfu_at_roofline": self.mfu,
        }


def expert_param_count(skeleton) -> int:
    """Parameters living on an 'experts' logical axis."""
    import jax
    from repro.models.param import ParamDef

    total = 0
    for leaf in jax.tree.leaves(skeleton, is_leaf=lambda x: isinstance(x, ParamDef)):
        if "experts" in leaf.logical_axes:
            total += int(np.prod(leaf.shape))
    return total


def model_flops(cfg, skeleton, kind: str, seq: int, batch: int) -> float:
    """6·N·D (train) / 2·N_active·D (prefill) / 2·N_active·B (decode)."""
    from repro.models.param import param_count

    n = param_count(skeleton)
    if cfg.moe is not None:
        e_params = expert_param_count(skeleton)
        active_frac = cfg.moe.top_k / cfg.moe.n_experts
        n = n - e_params + e_params * active_frac
    if kind == "train":
        return 6.0 * n * seq * batch
    if kind == "prefill":
        return 2.0 * n * seq * batch
    return 2.0 * n * batch  # decode: one token per request
