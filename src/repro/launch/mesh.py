"""Production mesh builders (assignment-mandated shapes).

A FUNCTION, not a module constant — importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init)."""

from __future__ import annotations

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """(16,16) data×model single pod; (2,16,16) pod×data×model for 2 pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(*, multi_pod: bool = False):
    """Tiny analogue for CI subprocesses (8 fake devices)."""
    shape = (2, 2, 2) if multi_pod else (4, 2)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)
