"""Post-SPMD HLO analysis: collective-traffic accounting per device.

``cost_analysis()`` has no collective information, so we parse the compiled
module text and sum result-buffer sizes of every collective op, converted to
estimated per-device link traffic:

  all-reduce          2·S·(g−1)/g      (ring reduce + broadcast)
  all-gather          S·(g−1)/g        (S = gathered result size)
  reduce-scatter      S·(g−1)          (S = scattered result size; input = S·g)
  all-to-all          S·(g−1)/g
  collective-permute  S                (one hop)
"""

from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "c64": 8, "s64": 8, "u64": 8, "f64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(pred|[suf]\d+|bf16|c64|c128)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of all shapes appearing in the result part of an op."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)  # [num_groups, group_size]<=[N]
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 1


def collective_stats(hlo_text: str) -> dict:
    """Returns {op_kind: {count, result_bytes, traffic_bytes}} + totals."""
    stats: dict = defaultdict(lambda: {"count": 0, "result_bytes": 0, "traffic_bytes": 0})
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = (.*)", stripped)
        if not m:
            continue
        rest = m.group(1)
        kind = None
        for k in _COLLECTIVES:
            # match the op name, not fused computation names
            if re.search(rf"\)?\s{re.escape(k)}(-start|-done)?\(", " " + rest) or rest.startswith(k):
                kind = k
                break
        if kind is None:
            continue
        if f"{kind}-done" in rest:
            continue  # avoid double counting async pairs (count the -start)
        # result shapes = everything before the op name occurrence
        idx = rest.find(kind)
        result_part = rest[:idx]
        size = _shape_bytes(result_part)
        g = _group_size(rest)
        if kind == "all-reduce":
            traffic = int(2 * size * (g - 1) / max(g, 1))
        elif kind == "all-gather":
            traffic = int(size * (g - 1) / max(g, 1))
        elif kind == "reduce-scatter":
            traffic = int(size * (g - 1))
        elif kind == "all-to-all":
            traffic = int(size * (g - 1) / max(g, 1))
        else:  # collective-permute
            traffic = size
        s = stats[kind]
        s["count"] += 1
        s["result_bytes"] += size
        s["traffic_bytes"] += traffic
    out = dict(stats)
    out["total_traffic_bytes"] = sum(v["traffic_bytes"] for v in stats.values())
    out["total_count"] = sum(v["count"] for v in stats.values())
    return out


def collective_schedule(hlo_text: str, limit: int = 40) -> list[str]:
    """Ordered summary of collectives (for EXPERIMENTS.md §Dry-run)."""
    lines = []
    for line in hlo_text.splitlines():
        s = line.strip()
        if any(f"{k}(" in s or f"{k}-start(" in s for k in _COLLECTIVES):
            op = s.split(" = ", 1)[-1][:110]
            lines.append(op)
            if len(lines) >= limit:
                break
    return lines
