"""The eight xfft transforms + N-D helpers, all plan-backed.

Every function here follows the same dispatch pipeline:

1. validate axes/norm and (scipy-style) resize to ``n``/``s`` if given —
   errors name the offending axis and size;
2. move the transform axes last (the engines' canonical layout);
3. resolve the whole call through :func:`repro.plan.api.resolve_call`
   (plan cache -> scoped config overrides -> concrete engine from the
   ``repro.engines`` registry, capability-filtered by the scope's
   precision and backend restriction);
4. run the ``repro.core`` engine implementation under that variant,
   through the resilience degradation ladder
   (:func:`repro.resilience.run_plan`): an engine failure quarantines
   the engine for this problem key and retries the next-best rung;
5. apply the ``norm`` scaling on top of the engines' native convention
   (forward unscaled, inverse 1/N — i.e. ``"backward"``).

Precision handling: under ``xfft.config(precision="double")`` every
public entry point runs its whole body inside ``jax.enable_x64`` — that
is the only way jax lets 64-bit dtypes survive the plumbing (moveaxis,
pad, roll and friends re-canonicalize dtypes when x64 is off), and it
makes the double path work whether or not ``JAX_ENABLE_X64`` is set
process-wide. The planner then resolves to an engine registered with the
``"double"`` capability (``reference_x64``) and the call is complex128
end to end.
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64 as _enable_x64

from repro.core.fft1d import _check_pow2 as _core_check_pow2
from repro.core.fft1d import canonical_axis
from repro.core.fft1d import fft_impl as _fft_impl
from repro.core.fft1d import ifft_impl as _ifft_impl
from repro.core.fft2d import fft2_impl as _fft2_impl
from repro.core.fft2d import fftshift2 as _core_fftshift2
from repro.core.fft2d import ifft2_impl as _ifft2_impl
from repro.core.fft2d import ifftshift2 as _core_ifftshift2
from repro.core.rfft import _ensure_real  # one real-input contract
from repro.core.rfft import irfft2_impl as _irfft2_impl
from repro.core.rfft import irfft_impl as _irfft_impl
from repro.core.rfft import rfft2_impl as _rfft2_impl
from repro.core.rfft import rfft_impl as _rfft_impl
from repro.plan.api import resolve_call
from repro.plan.plan import NORMS
from repro.resilience.ladder import run_plan as _run_plan
from repro.xfft._config import get_config

__all__ = [
    "fft", "ifft", "fft2", "ifft2", "fftn", "ifftn",
    "rfft", "irfft", "rfft2", "irfft2", "rfftn", "irfftn",
    "fftshift", "ifftshift", "fftshift2", "ifftshift2",
    "fftfreq", "rfftfreq",
]


def _precision_scope(fn):
    """Run the wrapped entry point under ``jax.enable_x64`` when the scoped
    precision is double, so 64-bit dtypes survive every jnp op inside."""

    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        if get_config().precision == "double":
            with _enable_x64():
                return fn(*args, **kwargs)
        return fn(*args, **kwargs)

    return wrapper


def _cdtype():
    """The scope's complex dtype (what inverse entry points cast input to)."""
    return jnp.complex128 if get_config().precision == "double" else jnp.complex64


def _rdtype():
    """The scope's real dtype (what real-input entry points cast to)."""
    return jnp.float64 if get_config().precision == "double" else jnp.float32


def _real_input(x, name: str):
    """Validate real input and cast it to the scope's float width."""
    return _ensure_real(x, name).astype(_rdtype())


def _check_norm(norm: Optional[str]) -> str:
    if norm is None:
        return "backward"
    if norm not in NORMS:
        raise ValueError(
            f'norm must be one of {NORMS} (or None for "backward"), got {norm!r}'
        )
    return norm


# one bounds check for the whole stack (same helper the engines use)
_canon_axis = canonical_axis


def _canon_axes(
    axes: Sequence[int], ndim: int, name: str
) -> Tuple[int, ...]:
    canon = tuple(_canon_axis(a, ndim, name) for a in axes)
    if len(set(canon)) != len(canon):
        raise ValueError(f"{name}: axes {tuple(axes)} name an axis twice")
    return canon


def _check_pow2(n: int, axis: int, name: str) -> None:
    """The satellite error contract: name the offending axis AND size
    (one shared message — ``repro.core.fft1d._check_pow2`` — so the
    wording can't drift between the front door and the engines)."""
    del name  # entry point named by the traceback; the contract names axis+size
    _core_check_pow2(n, axis=axis)


def _resize_axis(x: jax.Array, n: int, axis: int) -> jax.Array:
    """scipy-style ``n``/``s`` handling: crop or zero-pad along ``axis``."""
    cur = x.shape[axis]
    if n == cur:
        return x
    if n < cur:
        idx = [slice(None)] * x.ndim
        idx[axis] = slice(0, n)
        return x[tuple(idx)]
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, n - cur)
    return jnp.pad(x, pad)


def _scale(y: jax.Array, norm: str, n: int, forward: bool) -> jax.Array:
    """Norm correction on top of the engines' backward convention."""
    if norm == "backward":
        return y
    if norm == "ortho":
        factor = 1.0 / math.sqrt(n) if forward else math.sqrt(n)
    else:  # "forward"
        factor = 1.0 / n if forward else float(n)
    # Match the factor's width to the data so a complex128 result is not
    # dragged down by f32 rounding of the scale (and a single-precision
    # result never pays an f64 promotion).
    wide = y.dtype in (jnp.complex128, jnp.float64)
    return y * jnp.asarray(factor, dtype=jnp.float64 if wide else jnp.float32)


def _moved_shape(shape: Tuple[int, ...], axis: int) -> Tuple[int, ...]:
    """The plan-key shape: ``axis`` moved last (the engines' layout)."""
    return shape[:axis] + shape[axis + 1:] + (shape[axis],)


# ------------------------------ 1D complex ------------------------------


@_precision_scope
def fft(x, n: Optional[int] = None, axis: int = -1, norm: Optional[str] = None):
    """1D FFT along ``axis``; scipy.fft-compatible, plan-backed dispatch."""
    norm = _check_norm(norm)
    x = jnp.asarray(x)
    ax = _canon_axis(axis, x.ndim, "fft")
    if n is not None:
        x = _resize_axis(x, int(n), ax)
    length = x.shape[ax]
    _check_pow2(length, ax, "fft")
    plan = resolve_call("fft1d", _moved_shape(x.shape, ax))
    y = _run_plan(plan, lambda v: _fft_impl(x, axis=ax, variant=v))
    return _scale(y, norm, length, forward=True)


@_precision_scope
def ifft(x, n: Optional[int] = None, axis: int = -1, norm: Optional[str] = None):
    """Inverse 1D FFT along ``axis`` (norm-aware, plan-backed)."""
    norm = _check_norm(norm)
    x = jnp.asarray(x)
    ax = _canon_axis(axis, x.ndim, "ifft")
    if n is not None:
        x = _resize_axis(x, int(n), ax)
    length = x.shape[ax]
    _check_pow2(length, ax, "ifft")
    plan = resolve_call("fft1d", _moved_shape(x.shape, ax), direction="inv")
    y = _run_plan(plan, lambda v: _ifft_impl(x, axis=ax, variant=v))
    return _scale(y, norm, length, forward=False)


# ------------------------------ 2D complex ------------------------------


def _prep_2d(x, s, axes, norm, name):
    """Shared 2D plumbing: validate, resize, move axes to (-2, -1)."""
    norm = _check_norm(norm)
    x = jnp.asarray(x)
    if x.ndim < 2:
        raise ValueError(f"{name} needs at least a 2D array, got shape {x.shape}")
    if len(axes) != 2:
        raise ValueError(f"{name} transforms exactly 2 axes, got {tuple(axes)}")
    canon = _canon_axes(axes, x.ndim, name)
    if s is not None:
        if len(s) != 2:
            raise ValueError(f"{name}: s must have 2 entries, got {tuple(s)}")
        for target, ax in zip(s, canon):
            x = _resize_axis(x, int(target), ax)
    for ax in canon:
        _check_pow2(x.shape[ax], ax, name)
    moved = canon != (x.ndim - 2, x.ndim - 1)
    if moved:
        x = jnp.moveaxis(x, canon, (-2, -1))
    return x, norm, canon, moved


def _unmove_2d(y, canon, moved):
    return jnp.moveaxis(y, (-2, -1), canon) if moved else y


@_precision_scope
def fft2(x, s=None, axes=(-2, -1), norm: Optional[str] = None):
    """2D FFT over ``axes``; scipy.fft-compatible, plan-backed dispatch."""
    x, norm, canon, moved = _prep_2d(x, s, axes, norm, "fft2")
    h, w = x.shape[-2], x.shape[-1]
    plan = resolve_call("fft2d", x.shape)
    y = _run_plan(plan, lambda v: _fft2_impl(x, variant=v))
    return _unmove_2d(_scale(y, norm, h * w, forward=True), canon, moved)


@_precision_scope
def ifft2(x, s=None, axes=(-2, -1), norm: Optional[str] = None):
    """Inverse 2D FFT over ``axes`` (norm-aware, plan-backed)."""
    x, norm, canon, moved = _prep_2d(x, s, axes, norm, "ifft2")
    h, w = x.shape[-2], x.shape[-1]
    plan = resolve_call("fft2d", x.shape, direction="inv")
    y = _run_plan(plan, lambda v: _ifft2_impl(x, variant=v))
    return _unmove_2d(_scale(y, norm, h * w, forward=False), canon, moved)


# ------------------------------ N-D complex ------------------------------


def _fftn_axes(x, s, axes, name):
    if axes is None:
        axes = tuple(range(x.ndim)) if s is None else \
            tuple(range(x.ndim - len(s), x.ndim))
    axes = tuple(int(a) for a in axes)
    if s is not None and len(s) != len(axes):
        raise ValueError(
            f"{name}: s has {len(s)} entries for {len(axes)} axes"
        )
    return axes


@_precision_scope
def fftn(x, s=None, axes=None, norm: Optional[str] = None):
    """N-D FFT: separable 1D passes (a plan per axis); 2-axis calls take
    the dedicated ``fft2d`` planning kind via :func:`fft2`."""
    x = jnp.asarray(x)
    axes = _fftn_axes(x, s, axes, "fftn")
    if len(axes) == 2:
        return fft2(x, s=s, axes=axes, norm=norm)
    norm = _check_norm(norm)
    _canon_axes(axes, x.ndim, "fftn")  # distinctness + bounds up front
    total = 1
    for i, ax in enumerate(axes):
        if s is not None:
            x = _resize_axis(x, int(s[i]), _canon_axis(ax, x.ndim, "fftn"))
        total *= x.shape[_canon_axis(ax, x.ndim, "fftn")]
        x = fft(x, axis=ax)
    return _scale(x, norm, total, forward=True)


@_precision_scope
def ifftn(x, s=None, axes=None, norm: Optional[str] = None):
    """Inverse N-D FFT (see :func:`fftn`)."""
    x = jnp.asarray(x)
    axes = _fftn_axes(x, s, axes, "ifftn")
    if len(axes) == 2:
        return ifft2(x, s=s, axes=axes, norm=norm)
    norm = _check_norm(norm)
    _canon_axes(axes, x.ndim, "ifftn")
    total = 1
    for i, ax in enumerate(axes):
        if s is not None:
            x = _resize_axis(x, int(s[i]), _canon_axis(ax, x.ndim, "ifftn"))
        total *= x.shape[_canon_axis(ax, x.ndim, "ifftn")]
        x = ifft(x, axis=ax)
    return _scale(x, norm, total, forward=False)


# ------------------------------- real input -------------------------------




@_precision_scope
def rfft(x, n: Optional[int] = None, axis: int = -1, norm: Optional[str] = None):
    """Real-input FFT -> non-redundant half spectrum (..., N/2+1)."""
    norm = _check_norm(norm)
    x = _real_input(x, "rfft")
    ax = _canon_axis(axis, x.ndim, "rfft")
    if n is not None:
        x = _resize_axis(x, int(n), ax)
    length = x.shape[ax]
    _check_pow2(length, ax, "rfft")
    plan = resolve_call("rfft1d", _moved_shape(x.shape, ax), dtype="float32")
    y = _run_plan(plan, lambda v: _rfft_impl(x, axis=ax, variant=v))
    return _scale(y, norm, length, forward=True)


@_precision_scope
def irfft(x, n: Optional[int] = None, axis: int = -1, norm: Optional[str] = None):
    """Inverse of :func:`rfft`: half spectrum -> real signal of length ``n``
    (default ``2*(width-1)``)."""
    norm = _check_norm(norm)
    x = jnp.asarray(x).astype(_cdtype())
    ax = _canon_axis(axis, x.ndim, "irfft")
    length = int(n) if n is not None else 2 * (x.shape[ax] - 1)
    _check_pow2(length, ax, "irfft")
    # numpy semantics: the spectrum is cropped/zero-padded to n//2+1 bins.
    x = _resize_axis(x, length // 2 + 1, ax)
    key_shape = _moved_shape(x.shape, ax)[:-1] + (length,)
    plan = resolve_call("rfft1d", key_shape, dtype="float32", direction="inv")
    y = _run_plan(plan, lambda v: _irfft_impl(x, axis=ax, variant=v))
    return _scale(y, norm, length, forward=False)


@_precision_scope
def rfft2(x, s=None, axes=(-2, -1), norm: Optional[str] = None):
    """2D real-input FFT -> (..., H, W/2+1) half spectrum, plan-backed."""
    x = _real_input(x, "rfft2")
    x, norm, canon, moved = _prep_2d(x, s, axes, norm, "rfft2")
    h, w = x.shape[-2], x.shape[-1]
    plan = resolve_call("rfft2d", x.shape, dtype="float32")
    y = _run_plan(plan, lambda v: _rfft2_impl(x, variant=v))
    return _unmove_2d(_scale(y, norm, h * w, forward=True), canon, moved)


@_precision_scope
def irfft2(x, s=None, axes=(-2, -1), norm: Optional[str] = None):
    """Inverse of :func:`rfft2`: (..., H, W/2+1) -> real (..., H, W)."""
    norm = _check_norm(norm)
    x = jnp.asarray(x).astype(_cdtype())
    if x.ndim < 2:
        raise ValueError(f"irfft2 needs at least a 2D array, got shape {x.shape}")
    if len(axes) != 2:
        raise ValueError(f"irfft2 transforms exactly 2 axes, got {tuple(axes)}")
    if s is not None and len(s) != 2:
        raise ValueError(f"irfft2: s must have 2 entries, got {tuple(s)}")
    canon = _canon_axes(axes, x.ndim, "irfft2")
    moved = canon != (x.ndim - 2, x.ndim - 1)
    if moved:
        x = jnp.moveaxis(x, canon, (-2, -1))
    h = int(s[0]) if s is not None else x.shape[-2]
    w = int(s[1]) if s is not None else 2 * (x.shape[-1] - 1)
    _check_pow2(h, canon[0], "irfft2")
    _check_pow2(w, canon[1], "irfft2")
    x = _resize_axis(_resize_axis(x, h, -2), w // 2 + 1, -1)
    plan = resolve_call(
        "rfft2d", x.shape[:-1] + (w,), dtype="float32", direction="inv"
    )
    y = _run_plan(plan, lambda v: _irfft2_impl(x, variant=v))
    return _unmove_2d(_scale(y, norm, h * w, forward=False), canon, moved)


# ------------------------------ N-D real ------------------------------


@_precision_scope
def rfftn(x, s=None, axes=None, norm: Optional[str] = None):
    """N-D real-input FFT: the two-for-one ``rfft`` along the LAST of
    ``axes``, complex passes over the rest — a real array never round-trips
    through a full complex ``fftn`` (half the arithmetic and traffic on the
    innermost, largest pass). 1- and 2-axis calls take the dedicated
    ``rfft1d``/``rfft2d`` planning kinds."""
    x = _real_input(x, "rfftn")
    axes = _fftn_axes(x, s, axes, "rfftn")
    if len(axes) == 1:
        return rfft(x, n=None if s is None else int(s[0]), axis=axes[0], norm=norm)
    if len(axes) == 2:
        return rfft2(x, s=s, axes=axes, norm=norm)
    norm = _check_norm(norm)
    canon = _canon_axes(axes, x.ndim, "rfftn")
    if s is not None:
        for target, ax in zip(s, canon):
            x = _resize_axis(x, int(target), ax)
    total = 1
    for ax in canon:
        total *= x.shape[ax]
    y = rfft(x, axis=canon[-1])
    for ax in canon[:-1]:
        y = fft(y, axis=ax)
    return _scale(y, norm, total, forward=True)


@_precision_scope
def irfftn(x, s=None, axes=None, norm: Optional[str] = None):
    """Inverse of :func:`rfftn`: complex inverse passes over the leading
    axes, then the half-spectrum ``irfft`` along the last -> real output."""
    axes_in = axes
    x = jnp.asarray(x).astype(_cdtype())
    axes = _fftn_axes(x, s, axes_in, "irfftn")
    if len(axes) == 1:
        return irfft(x, n=None if s is None else int(s[0]), axis=axes[0], norm=norm)
    if len(axes) == 2:
        return irfft2(x, s=s, axes=axes, norm=norm)
    norm = _check_norm(norm)
    canon = _canon_axes(axes, x.ndim, "irfftn")
    total = 1
    for i, ax in enumerate(canon[:-1]):
        if s is not None:
            x = _resize_axis(x, int(s[i]), ax)
        total *= x.shape[ax]
        x = ifft(x, axis=ax)
    last = canon[-1]
    n_last = int(s[-1]) if s is not None else 2 * (x.shape[last] - 1)
    total *= n_last
    y = irfft(x, n=n_last, axis=last)
    return _scale(y, norm, total, forward=False)


# ------------------------------- shifts -------------------------------


@_precision_scope
def fftshift(x, axes=None):
    """Move the zero-frequency bin to the centre (numpy-compatible)."""
    x = jnp.asarray(x)
    if axes is None:
        axes = tuple(range(x.ndim))
    elif isinstance(axes, int):
        axes = (axes,)
    axes = _canon_axes(axes, x.ndim, "fftshift")
    return jnp.roll(x, [x.shape[a] // 2 for a in axes], axes)


@_precision_scope
def ifftshift(x, axes=None):
    """Exact inverse of :func:`fftshift` (correct for odd lengths too)."""
    x = jnp.asarray(x)
    if axes is None:
        axes = tuple(range(x.ndim))
    elif isinstance(axes, int):
        axes = (axes,)
    axes = _canon_axes(axes, x.ndim, "ifftshift")
    return jnp.roll(x, [-(x.shape[a] // 2) for a in axes], axes)


@_precision_scope
def fftshift2(x):
    """Centre the zero-frequency bin of the trailing two axes."""
    return _core_fftshift2(jnp.asarray(x))


@_precision_scope
def ifftshift2(x):
    """Exact inverse of :func:`fftshift2` (sign-correct for odd lengths)."""
    return _core_ifftshift2(jnp.asarray(x))


# ---------------------------- sample frequencies ----------------------------


def _freq_width_ctx(dtype):
    """Context that lets an EXPLICIT 64-bit dtype pin survive: outside a
    double scope jax would silently canonicalize a float64 request down to
    float32, which is the one thing a pinned width must never do."""
    import contextlib

    import numpy as np

    if dtype is not None and np.dtype(dtype).itemsize == 8:
        return _enable_x64()
    return contextlib.nullcontext()


@_precision_scope
def fftfreq(n, d: float = 1.0, *, dtype=None):
    """Sample frequencies of an ``n``-point FFT (scipy.fft parity).

    Bin ``k`` of :func:`fft` oscillates at ``fftfreq(n, d)[k]`` cycles per
    unit of the sample spacing ``d``. Pure index arithmetic — no engine —
    but it lives here so frequency grids follow the same precision scope
    as the transforms they index (``dtype=`` pins a width explicitly,
    honored whatever the ambient scope).
    """
    n = int(n)
    if n <= 0:
        raise ValueError(f"fftfreq needs a positive sample count, got {n}")
    with _freq_width_ctx(dtype):
        dt = dtype if dtype is not None else _rdtype()
        k = jnp.concatenate([
            jnp.arange(0, (n - 1) // 2 + 1, dtype=dt),
            jnp.arange(-(n // 2), 0, dtype=dt),
        ])
        return k / jnp.asarray(n * d, dtype=dt)


@_precision_scope
def rfftfreq(n, d: float = 1.0, *, dtype=None):
    """Sample frequencies of the :func:`rfft` half spectrum (scipy parity):
    the ``n // 2 + 1`` non-negative bins of :func:`fftfreq`."""
    n = int(n)
    if n <= 0:
        raise ValueError(f"rfftfreq needs a positive sample count, got {n}")
    with _freq_width_ctx(dtype):
        dt = dtype if dtype is not None else _rdtype()
        return jnp.arange(0, n // 2 + 1, dtype=dt) / jnp.asarray(n * d, dtype=dt)
