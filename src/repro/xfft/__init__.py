"""repro.xfft — the unified, scipy.fft-style front door to the engine.

One namespace, eight transforms (`fft`/`ifft`, `fft2`/`ifft2`, `rfft`/
`irfft`, `rfft2`/`irfft2`), N-D helpers (`fftn`/`ifftn` and the real-input
`rfftn`/`irfftn`), shift utilities
(`fftshift`/`ifftshift`, plus the 2D conveniences `fftshift2`/
`ifftshift2`), sample-frequency grids (`fftfreq`/`rfftfreq`),
`norm="backward"|"ortho"|"forward"` conventions and
arbitrary `axes=` — all dispatched through ``repro.plan`` over the
pluggable engine registry (``repro.engines``). `config(precision=
"double")` routes every call through an x64-capable engine (complex128
end to end); `config(backend=...)` restricts which engine backends the
planner may consider.

**The unified default.** Before this namespace existed, every entry point
carried its own ``variant=`` kwarg with *inconsistent* defaults: ``fft``/
``fft2`` defaulted to ``"looped"`` (the paper-faithful schedule — also the
slowest one XLA can emit, kept as a baseline), while ``rfft*`` defaulted
to ``"stockham"`` (the variant that happened to be fastest when PR 2
landed). Both were accidents of history, and both pushed a scheduling
decision onto every caller. The one default now is: **no per-call variant
at all — dispatch resolves through the planner** (``repro.plan``: a cached
MEASURE plan when wisdom exists, the analytic ESTIMATE model otherwise).
That is the right default because the best schedule is a property of the
*problem* (backend, shape, dtype, direction), not of the call site; it is
also the prerequisite shape for multi-backend dispatch — later PRs change
what the planner may pick without changing any signature here.

Engine selection is scoped, not threaded::

    import repro.xfft as xfft

    y = xfft.rfft2(frames)                  # plan-backed, no kwargs
    with xfft.config(variant="fused_r4"):   # force the Pallas kernel...
        y = xfft.rfft2(frames)              # ...only inside this scope
    xfft.config(mode="measure")             # tune-on-miss, process-wide

The old ``repro.core`` entry points (``repro.core.fft`` etc.) remain as
deprecation shims that warn once and delegate here.
"""

from repro.xfft._config import XFFTConfig, config, get_config
from repro.xfft._report import report, report_data
from repro.xfft._transforms import (
    fft,
    fft2,
    fftfreq,
    fftn,
    fftshift,
    fftshift2,
    ifft,
    ifft2,
    ifftn,
    ifftshift,
    ifftshift2,
    irfft,
    irfft2,
    irfftn,
    rfft,
    rfft2,
    rfftfreq,
    rfftn,
)

__all__ = [
    "fft",
    "ifft",
    "fft2",
    "ifft2",
    "fftn",
    "ifftn",
    "rfft",
    "irfft",
    "rfft2",
    "irfft2",
    "rfftn",
    "irfftn",
    "fftshift",
    "ifftshift",
    "fftshift2",
    "ifftshift2",
    "fftfreq",
    "rfftfreq",
    "config",
    "get_config",
    "report",
    "report_data",
    "XFFTConfig",
]
