"""Live plan-cache + counter introspection: ``xfft.report()``.

FFTW answers "what did the planner learn?" with ``fftw_export_wisdom``;
this module is that answer for the repo. :func:`report_data` assembles a
structured snapshot of the wisdom cache the active scope resolves
against — per-key engine choice, planning mode, tuned times, hit counts,
the kept/dropped accounting of every wisdom-file load — plus every
process-wide ``repro.obs`` counter; :func:`report` renders it for
humans. Neither touches a device or mutates any state: reporting a
service must never replan it.
"""

from __future__ import annotations

from repro import obs

__all__ = ["report", "report_data"]


def report_data(cache=None) -> dict:
    """Structured snapshot of the active scope's plan cache + obs counters.

    ``cache`` (a :class:`repro.plan.PlanCache`) overrides the scope's
    cache — the active ``config(cache_dir=...)`` wisdom cache when set,
    the process-wide default cache otherwise.
    """
    # Lazy imports: report is a diagnostic surface; the obs/record layer
    # must stay importable without the planner.
    from repro.plan.api import _cache_for_dir
    from repro.plan.cache import default_cache
    from repro.resilience.breaker import quarantine
    from repro.serve.loop import services_for_key
    from repro.xfft._config import get_config

    cfg = get_config()
    if cache is None:
        cache = _cache_for_dir(cfg.cache_dir) if cfg.cache_dir else default_cache()
    entries = []
    for key_str, plan in cache.entries():
        k = plan.key
        entries.append({
            "key": key_str,
            "kind": k.kind,
            "direction": k.direction,
            "shape": list(k.shape),
            "dtype": k.dtype,
            "precision": k.precision,
            "backend": k.backend,
            "variant": plan.variant,
            "mode": plan.mode,
            "est_time_s": plan.est_time_s,
            "measured_us": plan.measured_us,
            "tile": None if plan.tile is None else list(plan.tile),
            "degrade_reason": plan.degrade_reason,
            "hits": cache.hit_count(key_str),
        })
    qrows = []
    by_service: dict = {}
    for row in quarantine().table():
        services = services_for_key(row["key"])
        row = dict(row, services=list(services))
        qrows.append(row)
        for svc in services or ("unassigned",):
            by_service.setdefault(svc, []).append(row)
    return {
        "config": {
            "variant": cfg.variant,
            "mode": cfg.mode,
            "precision": cfg.precision,
            "backends": list(cfg.backends),
            "cache_dir": cfg.cache_dir,
        },
        "cache": {
            "path": cache.path,
            "entries": entries,
            "hits": cache.hits,
            "misses": cache.misses,
            "load": (
                None if cache.load_report is None
                else cache.load_report.to_dict()
            ),
            "readonly_path": getattr(cache, "readonly_path", None),
        },
        # Live circuit-breaker state (repro.resilience): one row per
        # non-closed (engine, problem-key) breaker — which engines are
        # benched, for which problems, and how long until a half-open
        # probe is admitted. Empty when nothing has failed. Each row is
        # tagged with the serve lanes that plan under its key (the
        # serve-loop lane registry), and `quarantine_by_service` regroups
        # the table per service — "which of MY lanes are degraded" for an
        # operator of one service, not just engine × key.
        "resilience": {
            "quarantine": qrows,
            "quarantine_by_service": by_service,
        },
        # Always-on telemetry (repro.obs.telemetry): flight-recorder
        # retention + dump accounting, the planner calibration ledger's
        # mispricing table (observed engine.apply time vs the planner's
        # prediction), and every registered latency histogram (serve
        # lanes + engines).
        "telemetry": {
            "flight_recorder": (
                None if obs.flight_recorder() is None
                else obs.flight_recorder().stats()
            ),
            "calibration": obs.calibration_ledger().table(),
            "histograms": {
                name: h.to_dict() for name, h in obs.histograms().items()
            },
        },
        "counters": obs.counters(),
    }


def _fmt_time(entry: dict) -> str:
    if entry["measured_us"] is not None:
        return f"measured={entry['measured_us']:.1f}us"
    return f"est={entry['est_time_s'] * 1e6:.1f}us"


def report(cache=None) -> str:
    """Human-readable plan-cache + counter report for the active scope.

    One line per wisdom entry (problem identity -> chosen engine, planning
    mode, tuned time, hit count, degrade reason when a MEASURE request
    fell back to ESTIMATE), the load accounting of any wisdom file, and
    every live obs counter.
    """
    d = report_data(cache)
    cfg, c = d["config"], d["cache"]
    scope = f"mode={cfg['mode']} precision={cfg['precision']}"
    if cfg["variant"]:
        scope += f" variant={cfg['variant']}"
    if cfg["backends"]:
        scope += f" backends={','.join(cfg['backends'])}"
    lines = [
        f"repro.xfft report ({scope})",
        f"plan cache: path={c['path'] or 'memory'}  entries={len(c['entries'])}"
        f"  hits={c['hits']}  misses={c['misses']}",
    ]
    for e in c["entries"]:
        shape = "x".join(str(s) for s in e["shape"])
        problem = f"{e['kind']} {e['direction']} {shape} {e['dtype']}"
        line = (
            f"  {problem:<40} -> {e['variant']:<12} {e['mode']:<8} "
            f"{_fmt_time(e):<20} hits={e['hits']}"
        )
        if e["degrade_reason"]:
            line += f"  degraded[{e['degrade_reason']}]"
        if e["tile"]:
            line += f"  tile={e['tile'][0]}x{e['tile'][1]}"
        lines.append(line)
    if c["load"] is not None:
        ld = c["load"]
        lines.append(
            f"wisdom load: kept={ld['kept']} stale_schema={ld['stale_schema']}"
            f" malformed={ld['malformed']} key_mismatch={ld['key_mismatch']}"
            + (f" file_error={ld['file_error']}" if ld["file_error"] else "")
        )
    if c.get("readonly_path"):
        lines.append(
            f"wisdom save: path {c['readonly_path']} unwritable -> "
            "degraded to in-memory caching"
        )
    by_service = d["resilience"]["quarantine_by_service"]
    if by_service:
        lines.append("quarantine (by service lane):")
        for svc in sorted(by_service):
            for q in by_service[svc]:
                line = (
                    f"  {svc:<12} {q['engine']:<12} {q['state']:<9} "
                    f"failures={q['failures']}"
                )
                if q["state"] == "open":
                    line += f" cooldown={q['cooldown_remaining_s']:.1f}s"
                line += f"  {q['key']}"
                lines.append(line)
    tel = d["telemetry"]
    fr = tel["flight_recorder"]
    if fr is None:
        lines.append("flight recorder: off")
    else:
        lines.append(
            f"flight recorder: retained={fr['retained']}/{fr['capacity']}"
            f"  recorded={fr['recorded_total']}  dumps={len(fr['dumps'])}"
            + (f" (+{fr['dropped_dumps']} dropped)" if fr["dropped_dumps"] else "")
        )
        for dump in fr["dumps"]:
            lines.append(
                f"  dump[{dump['trigger']}] {dump['events']} events -> "
                f"{dump['path']}"
            )
    if tel["histograms"]:
        lines.append("latency histograms (us):")
        for name, h in tel["histograms"].items():
            lines.append(
                f"  {name:<40} n={h['count']:<7} p50={h['p50_us']:<9} "
                f"p95={h['p95_us']:<9} p99={h['p99_us']}"
            )
    if tel["calibration"]:
        lines.append("planner calibration (observed vs predicted, worst first):")
        for r in tel["calibration"]:
            shape = "x".join(str(s) for s in r["shape"])
            problem = f"{r['engine']} {r['kind']} {shape} {r['precision']}"
            ratio = f"{r['ratio']:.2f}x" if r["ratio"] is not None else "-"
            observed = (
                f"{r['observed_p50_us']}us" if r["observed_p50_us"] is not None
                else "-"
            )
            lines.append(
                f"  {problem:<44} predicted={r['predicted_us']}us"
                f"[{r['predicted_source']}] observed_p50={observed} "
                f"ratio={ratio} n={r['observed_n']}"
            )
    counters = d["counters"]
    if counters:
        lines.append("counters:")
        width = max(len(name) for name in counters)
        lines.extend(
            f"  {name:<{width}}  {value}" for name, value in counters.items()
        )
    return "\n".join(lines)
