"""Context-scoped configuration for the ``repro.xfft`` namespace.

One knob set, one scope rule: :func:`config` merges its keyword arguments
into the active configuration immediately (global-setter usage) and, when
used as a context manager, restores the previous configuration on exit
(scoped usage). Tests, benchmarks and the serve engine select engines by
*scope* instead of threading ``variant=`` kwargs through five layers:

    import repro.xfft as xfft

    xfft.config(mode="measure")                 # process-wide from here on
    with xfft.config(variant="fused_r4"):       # only inside this block
        y = xfft.rfft2(x)
    with xfft.config(precision="double"):       # complex128 end to end
        y = xfft.fft2(x)                        # via the reference_x64 engine
    with xfft.config(backend="jnp"):            # restrict planner candidates
        y = xfft.fft2(x)

Scoping is :mod:`contextvars`-based, so overrides nest, compose across
``async`` task boundaries, and never leak between threads. Engine names,
backends and precisions are validated against the live ``repro.engines``
registry — a registered plugin is immediately forceable and scopable.
"""

from __future__ import annotations

import contextvars
import dataclasses
from typing import Any, Optional, Sequence, Tuple, Union

from repro import obs
from repro.engines import (
    get_engine,
    has_engine,
    registered_backends,
    registered_variants,
)
from repro.resilience.faults import FaultPlan, pop_faults, push_faults

__all__ = ["XFFTConfig", "config", "get_config"]

#: Accepted spellings per canonical precision. "single" is the paper's
#: complex64 butterfly datapath; "double" resolves to engines registered
#: with the "double" capability (``reference_x64``), complex128 end to end.
_PRECISIONS = {
    "single": "single",
    "complex64": "single",
    "float32": "single",
    "double": "double",
    "complex128": "double",
    "float64": "double",
}


@dataclasses.dataclass(frozen=True)
class XFFTConfig:
    """One immutable configuration snapshot.

    variant   — force a concrete registered engine for every call in
                scope; ``None`` (the default) lets ``repro.plan`` decide.
                This is THE unified default: see the ``repro.xfft`` module
                docstring for why the old per-entry-point defaults died.
    mode      — what a plan-cache miss costs: ``"estimate"`` (analytic,
                instant, trace-safe) or ``"measure"`` (timed sweep when
                resolution happens outside a jit trace).
    precision — numeric precision policy: ``"single"`` (complex64, the
                paper datapath) or ``"double"`` (complex128 through an
                x64-capable engine). Part of the plan key: wisdom never
                crosses precisions.
    backends  — engine-backend families the planner may consider (e.g.
                ``("jnp",)`` to exclude the Pallas kernels); ``()`` means
                all registered backends. Part of the plan key too.
    cache_dir — directory holding the plan-wisdom file for calls in scope
                (``<cache_dir>/xfft_plans.json``); ``None`` uses the
                process-wide default cache (``$REPRO_PLAN_CACHE``). Pass
                ``""`` to :func:`config` to clear an inherited directory
                (``None`` means "inherit", like every other field).
    observe   — observability policy for calls in scope: a
                :class:`repro.obs.Trace` collects every event emitted in
                scope into that trace; ``True`` turns spans into
                ``jax.profiler.TraceAnnotation`` regions so planner/engine
                work lands in XLA profiles; ``False`` (the default)
                disables both. ``repro.obs.capture()`` is the usual
                spelling for getting a trace back; this field exists so a
                long-lived scope (a service process) can stream into one.
    faults    — chaos policy for calls in scope: a
                :class:`repro.resilience.FaultPlan` injects its seeded
                fault schedule into every named seam reached in scope;
                ``False`` (the default) injects nothing. The scoping
                machinery mirrors ``observe=`` exactly.
    check_health — opt-in output-health guard: ``"nan"`` makes the
                degradation ladder treat a non-finite transform output as
                an engine failure (retry one rung down); ``"off"`` (the
                default) trusts outputs.

    The ``flight_recorder=`` argument to :func:`config` is deliberately
    *not* a field here: the flight recorder is process-global state (the
    always-on black box of :mod:`repro.obs.telemetry`), not part of the
    hashable planning configuration — plan memoization keys on this
    dataclass and must not vary with telemetry plumbing. ``config`` swaps
    the recorder and restores the previous one on scope exit, exactly
    like the contextvars fields.
    """

    variant: Optional[str] = None
    mode: str = "estimate"
    precision: str = "single"
    cache_dir: Optional[str] = None
    backends: Tuple[str, ...] = ()
    observe: Any = False
    faults: Any = False
    check_health: str = "off"


_ACTIVE: contextvars.ContextVar[XFFTConfig] = contextvars.ContextVar(
    "repro_xfft_config", default=XFFTConfig()
)


def get_config() -> XFFTConfig:
    """The configuration currently in scope."""
    return _ACTIVE.get()


def _canon_backends(
    backend: Union[str, Sequence[str], None]
) -> Optional[Tuple[str, ...]]:
    """Validate a ``backend=`` argument against the live registry.

    Returns the canonical tuple, ``()`` for the explicit clear spellings
    (``"auto"`` / an empty sequence), or ``None`` for "inherit".
    """
    if backend is None:
        return None
    if backend == "auto":
        return ()
    names = (backend,) if isinstance(backend, str) else tuple(backend)
    known = registered_backends()
    for name in names:
        if name not in known:
            raise ValueError(
                f"unknown engine backend {name!r}; registered backends: "
                f"{known} ('auto' clears an outer restriction)"
            )
    return tuple(sorted(set(names)))


class config:
    """Set xfft configuration, globally or for a ``with`` scope.

    Calling applies the overrides immediately; entering the returned object
    as a context manager makes them scoped (previous configuration restored
    on exit). Unspecified fields inherit from the configuration active at
    call time, so scopes nest naturally. ``backend`` accepts one backend
    name or a sequence of them (``"auto"`` clears an outer restriction).
    """

    def __init__(
        self,
        variant: Optional[str] = None,
        mode: Optional[str] = None,
        precision: Optional[str] = None,
        cache_dir: Optional[str] = None,
        backend: Union[str, Sequence[str], None] = None,
        observe: Any = None,
        faults: Any = None,
        check_health: Optional[str] = None,
        flight_recorder: Any = None,
    ):
        prev = _ACTIVE.get()
        if flight_recorder is not None:
            from repro.obs import telemetry as _telemetry

            if isinstance(flight_recorder, bool):
                recorder = (
                    _telemetry.FlightRecorder() if flight_recorder else None
                )
            elif isinstance(flight_recorder, int):
                recorder = _telemetry.FlightRecorder(capacity=flight_recorder)
            elif isinstance(flight_recorder, _telemetry.FlightRecorder):
                recorder = flight_recorder
            else:
                raise ValueError(
                    f"flight_recorder must be a repro.obs.FlightRecorder, "
                    f"True (fresh default recorder), False (off), an int "
                    f"capacity, or None (inherit); got {flight_recorder!r}"
                )
            self._flight_prev = (True, _telemetry.set_flight_recorder(recorder))
        else:
            self._flight_prev = None
        if observe is not None and not isinstance(observe, (bool, obs.Trace)):
            raise ValueError(
                f"observe must be a repro.obs.Trace, True (profiler "
                f"annotations), False (off) or None (inherit); got {observe!r}"
            )
        if faults is not None and faults is not False and not isinstance(
            faults, FaultPlan
        ):
            raise ValueError(
                f"faults must be a repro.resilience.FaultPlan, False (off) "
                f"or None (inherit); got {faults!r}"
            )
        if check_health is not None and check_health not in ("nan", "off"):
            raise ValueError(
                f'check_health must be "nan", "off" or None (inherit); '
                f"got {check_health!r}"
            )
        clear_variant = variant == "auto"  # "auto" clears an outer override
        if clear_variant:
            variant = None
        elif variant is not None and not has_engine(variant):
            raise ValueError(
                f"unknown variant {variant!r}; registered engines: "
                f"{registered_variants()}, 'auto' to clear an outer "
                "override, or None to inherit"
            )
        if mode is not None and mode not in ("estimate", "measure"):
            raise ValueError(
                f"mode must be 'estimate' or 'measure', got {mode!r}"
            )
        if precision is not None:
            if precision not in _PRECISIONS:
                raise ValueError(
                    f"unsupported precision {precision!r}; want a spelling "
                    f"of one of {sorted(set(_PRECISIONS.values()))} "
                    f"(accepted: {sorted(_PRECISIONS)})"
                )
            precision = _PRECISIONS[precision]
        backends = _canon_backends(backend)
        merged = XFFTConfig(
            variant=None if clear_variant else (
                variant if variant is not None else prev.variant
            ),
            mode=mode if mode is not None else prev.mode,
            precision=precision if precision is not None else prev.precision,
            # "" clears an inherited directory (mirrors variant="auto"):
            # None always means "inherit" for every field.
            cache_dir=(
                None if cache_dir == "" else
                cache_dir if cache_dir is not None else prev.cache_dir
            ),
            backends=backends if backends is not None else prev.backends,
            observe=observe if observe is not None else prev.observe,
            faults=faults if faults is not None else prev.faults,
            check_health=(
                check_health if check_health is not None else prev.check_health
            ),
        )
        # A forced variant must be CAPABLE of the scope's constraints —
        # otherwise config(precision="double", variant="stockham") would
        # silently compute in complex64 against the documented contract.
        # Checked on the MERGED config so inherited fields are covered too.
        if merged.variant is not None:
            spec = get_engine(merged.variant)
            if merged.precision not in spec.precisions:
                raise ValueError(
                    f"engine {merged.variant!r} cannot serve precision "
                    f"{merged.precision!r} (it supports {spec.precisions}); "
                    "force a capable engine or change precision="
                )
            if merged.backends and spec.backend not in merged.backends:
                raise ValueError(
                    f"engine {merged.variant!r} is on backend "
                    f"{spec.backend!r}, outside the scoped backend "
                    f"restriction {merged.backends}; widen backend= or "
                    "force a different variant"
                )
        self._token = _ACTIVE.set(merged)
        # Only an EXPLICIT observe= pushes obs scope state: inheriting must
        # not re-push (a Trace pushed twice would record every event twice).
        self._obs_tokens = obs.push_observe(observe) if observe is not None else None
        # Same rule for faults=: an explicit FaultPlan arms a fresh seeded
        # FaultState for this scope; an explicit False pushes a cleared
        # scope; inheriting leaves the enclosing scope's firing state alone.
        self._faults_token = (
            push_faults(faults if isinstance(faults, FaultPlan) else None)
            if faults is not None else None
        )

    def __enter__(self) -> "config":
        return self

    def __exit__(self, *exc) -> None:
        self.restore()

    def restore(self) -> None:
        """Undo this call's overrides (automatic when used as a context)."""
        if self._flight_prev is not None:
            from repro.obs import telemetry as _telemetry

            _telemetry.set_flight_recorder(self._flight_prev[1])
            self._flight_prev = None
        if self._faults_token is not None:
            pop_faults(self._faults_token)
            self._faults_token = None
        if self._obs_tokens is not None:
            obs.pop_observe(self._obs_tokens)
            self._obs_tokens = None
        if self._token is not None:
            _ACTIVE.reset(self._token)
            self._token = None
