"""Context-scoped configuration for the ``repro.xfft`` namespace.

One knob set, one scope rule: :func:`config` merges its keyword arguments
into the active configuration immediately (global-setter usage) and, when
used as a context manager, restores the previous configuration on exit
(scoped usage). Tests, benchmarks and the serve engine select engines by
*scope* instead of threading ``variant=`` kwargs through five layers:

    import repro.xfft as xfft

    xfft.config(mode="measure")                 # process-wide from here on
    with xfft.config(variant="fused_r4"):       # only inside this block
        y = xfft.rfft2(x)

Scoping is :mod:`contextvars`-based, so overrides nest, compose across
``async`` task boundaries, and never leak between threads.
"""

from __future__ import annotations

import contextvars
import dataclasses
from typing import Optional

from repro.plan.plan import PLAN_VARIANTS

__all__ = ["XFFTConfig", "config", "get_config"]

#: Accepted spellings of the single-precision policy (the paper engine is
#: complex64 end to end; higher precisions are roadmap items).
_PRECISIONS = {"complex64": "complex64", "single": "complex64"}


@dataclasses.dataclass(frozen=True)
class XFFTConfig:
    """One immutable configuration snapshot.

    variant   — force a concrete engine schedule for every call in scope;
                ``None`` (the default) lets ``repro.plan`` decide. This is
                THE unified default: see the ``repro.xfft`` module
                docstring for why the old per-entry-point defaults died.
    mode      — what a plan-cache miss costs: ``"estimate"`` (analytic,
                instant, trace-safe) or ``"measure"`` (timed sweep when
                resolution happens outside a jit trace).
    precision — accumulation dtype policy; only single precision
                (``"complex64"``) exists today, matching the paper's c64
                butterfly datapath.
    cache_dir — directory holding the plan-wisdom file for calls in scope
                (``<cache_dir>/xfft_plans.json``); ``None`` uses the
                process-wide default cache (``$REPRO_PLAN_CACHE``). Pass
                ``""`` to :func:`config` to clear an inherited directory
                (``None`` means "inherit", like every other field).
    """

    variant: Optional[str] = None
    mode: str = "estimate"
    precision: str = "complex64"
    cache_dir: Optional[str] = None


_ACTIVE: contextvars.ContextVar[XFFTConfig] = contextvars.ContextVar(
    "repro_xfft_config", default=XFFTConfig()
)


def get_config() -> XFFTConfig:
    """The configuration currently in scope."""
    return _ACTIVE.get()


class config:
    """Set xfft configuration, globally or for a ``with`` scope.

    Calling applies the overrides immediately; entering the returned object
    as a context manager makes them scoped (previous configuration restored
    on exit). Unspecified fields inherit from the configuration active at
    call time, so scopes nest naturally.
    """

    def __init__(
        self,
        variant: Optional[str] = None,
        mode: Optional[str] = None,
        precision: Optional[str] = None,
        cache_dir: Optional[str] = None,
    ):
        prev = _ACTIVE.get()
        clear_variant = variant == "auto"  # "auto" clears an outer override
        if clear_variant:
            variant = None
        elif variant is not None and variant not in PLAN_VARIANTS:
            raise ValueError(
                f"unknown variant {variant!r}; want one of {PLAN_VARIANTS}, "
                "'auto' to clear an outer override, or None to inherit"
            )
        if mode is not None and mode not in ("estimate", "measure"):
            raise ValueError(
                f"mode must be 'estimate' or 'measure', got {mode!r}"
            )
        if precision is not None:
            if precision not in _PRECISIONS:
                raise ValueError(
                    f"unsupported precision {precision!r}; the engine is "
                    f"single-precision (want one of {sorted(_PRECISIONS)})"
                )
            precision = _PRECISIONS[precision]
        merged = XFFTConfig(
            variant=None if clear_variant else (
                variant if variant is not None else prev.variant
            ),
            mode=mode if mode is not None else prev.mode,
            precision=precision if precision is not None else prev.precision,
            # "" clears an inherited directory (mirrors variant="auto"):
            # None always means "inherit" for every field.
            cache_dir=(
                None if cache_dir == "" else
                cache_dir if cache_dir is not None else prev.cache_dir
            ),
        )
        self._token = _ACTIVE.set(merged)

    def __enter__(self) -> "config":
        return self

    def __exit__(self, *exc) -> None:
        self.restore()

    def restore(self) -> None:
        """Undo this call's overrides (automatic when used as a context)."""
        if self._token is not None:
            _ACTIVE.reset(self._token)
            self._token = None
