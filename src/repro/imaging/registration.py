"""Translation registration / motion correction via phase correlation.

The moco-workshop pipeline (PAPERS.md, ``/root/related``) corrects
inter-frame motion by estimating a rigid shift per frame and applying it
in k-space; the estimation workhorse is phase correlation — the
cross-power spectrum of two frames is a pure phase ramp whose inverse
transform is a delta at the displacement:

    R = F(ref) · conj(F(mov)) / |F(ref) · conj(F(mov))|
    corr = IFFT2(R)  →  peak at the shift

Whole-pixel estimation is one planned forward/inverse transform pair
(the two-for-one real path for camera/MRI magnitude frames). Subpixel
refinement is the Guizar-Sicairos upsampled-DFT trick: evaluate the
inverse transform on a tiny ``O(1.5·u)²`` grid around the coarse peak by
matrix-multiply DFT at ``u``× upsampling — no big zero-padded transform.

Conventions match ``skimage.registration.phase_cross_correlation``: the
returned ``(dy, dx)`` is the shift to APPLY to ``mov`` to register it
onto ``ref`` — ``apply_shift(mov, register_phase_correlation(ref, mov))
≈ ref``.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

import repro.xfft as xfft
from repro.core.spectral import _is_real

__all__ = [
    "register_phase_correlation",
    "register_logpolar",
    "apply_shift",
    "hermitian_full",
]


def hermitian_full(rh: jax.Array, w: int) -> jax.Array:
    """Full-width spectrum from its Hermitian (..., H, W/2+1) half.

    A real frame's spectrum satisfies ``R[q, r] = conj(R[−q mod H, W−r])``,
    so the missing columns are a conjugated, double-flipped copy of
    columns ``1 .. W/2−1`` — no second (complex) transform needed. Used
    here to rebuild the full cross-power spectrum for subpixel
    refinement, and by :func:`repro.imaging.psd.fft2_psd` to return the
    full PSD off the two-for-one real path.
    """
    tail = jnp.conj(rh[..., :, 1:w - w // 2])        # cols 1 .. W/2-1
    tail = jnp.flip(tail, axis=-1)                   # -> cols W-1 .. W/2+1 order
    tail = jnp.roll(jnp.flip(tail, axis=-2), 1, axis=-2)  # row q -> (-q) mod H
    return jnp.concatenate([rh, tail], axis=-1)


def _upsampled_peak(r_full: jax.Array, coarse: jax.Array, upsample: int):
    """Refine per-item peaks by evaluating IFFT2(R) on a ±(region/2u)
    window around ``coarse`` at ``u``× upsampling (matrix-multiply DFT)."""
    h, w = r_full.shape[-2], r_full.shape[-1]
    region = int(math.ceil(1.5 * upsample))
    centre = region // 2
    grid = (jnp.arange(region, dtype=jnp.float32) - centre) / upsample
    fy = xfft.fftfreq(h, dtype=jnp.float32)                   # cycles/sample
    fx = xfft.fftfreq(w, dtype=jnp.float32)
    # Per-item sample positions around the coarse peak (broadcast batch).
    ys = coarse[..., 0:1] + grid                              # (..., region)
    xs = coarse[..., 1:2] + grid
    ey = jnp.exp(2j * math.pi * ys[..., :, None] * fy)        # (..., region, H)
    ex = jnp.exp(2j * math.pi * xs[..., :, None] * fx)        # (..., region, W)
    cc = jnp.einsum("...ah,...hw,...bw->...ab", ey, r_full, ex)
    flat = jnp.abs(cc).reshape(*cc.shape[:-2], region * region)
    idx = jnp.argmax(flat, axis=-1)
    dy = (idx // region).astype(jnp.float32)
    dx = (idx % region).astype(jnp.float32)
    return jnp.stack(
        [coarse[..., 0] + (dy - centre) / upsample,
         coarse[..., 1] + (dx - centre) / upsample],
        axis=-1,
    )


def register_phase_correlation(
    ref: jax.Array,
    mov: jax.Array,
    upsample_factor: int = 1,
    eps: float = 1e-12,
) -> jax.Array:
    """Estimate the (dy, dx) translation registering ``mov`` onto ``ref``.

    ``ref``/``mov``: (..., H, W), real or complex, leading axes batched —
    one planned transform pair serves the whole batch. Returns float32
    ``(..., 2)``. ``upsample_factor > 1`` adds subpixel refinement to
    within ``1/upsample_factor`` px (Guizar-Sicairos upsampled DFT).
    """
    ref = jnp.asarray(ref)
    mov = jnp.asarray(mov)
    if ref.shape != mov.shape:
        raise ValueError(
            f"ref and mov must share a shape, got {ref.shape} vs {mov.shape}"
        )
    if ref.ndim < 2:
        raise ValueError(f"need (..., H, W) frames, got shape {ref.shape}")
    h, w = ref.shape[-2], ref.shape[-1]
    real = _is_real(ref) and _is_real(mov)
    if real:
        fr_ = xfft.rfft2(ref)
        fm = xfft.rfft2(mov)
    else:
        fr_ = xfft.fft2(ref.astype(jnp.complex64))
        fm = xfft.fft2(mov.astype(jnp.complex64))
    r = fr_ * jnp.conj(fm)
    r = r / jnp.maximum(jnp.abs(r), eps)              # pure phase ramp
    corr = xfft.irfft2(r) if real else jnp.real(xfft.ifft2(r))
    idx = jnp.argmax(corr.reshape(*corr.shape[:-2], h * w), axis=-1)
    py = idx // w
    px = idx % w
    coarse = jnp.stack(
        [jnp.where(py > h // 2, py - h, py).astype(jnp.float32),
         jnp.where(px > w // 2, px - w, px).astype(jnp.float32)],
        axis=-1,
    )
    if upsample_factor <= 1:
        return coarse
    r_full = hermitian_full(r, w) if real else r
    return _upsampled_peak(r_full, coarse, int(upsample_factor))


def _logpolar_resample(mag: jax.Array) -> jax.Array:
    """Resample a centred (H, W) magnitude spectrum onto a log-polar grid.

    Rows sweep θ over [0, π) (a real frame's magnitude spectrum is
    point-symmetric, so the half-turn carries all the information and
    the axis stays circular for phase correlation); columns sweep radius
    log-uniformly from 1 to ``min(H, W)/2 − 1``. The output keeps the
    (H, W) shape, so both axes stay pow2 for the planned transforms that
    phase correlation runs next.
    """
    from jax.scipy.ndimage import map_coordinates

    h, w = mag.shape[-2], mag.shape[-1]
    n_theta, n_r = h, w
    rmax = min(h, w) / 2.0 - 1.0
    theta = jnp.arange(n_theta, dtype=jnp.float32) * (math.pi / n_theta)
    logr = jnp.exp(
        jnp.arange(n_r, dtype=jnp.float32) * (math.log(rmax) / (n_r - 1))
    )
    rows = h / 2.0 + logr[None, :] * jnp.sin(theta)[:, None]
    cols = w / 2.0 + logr[None, :] * jnp.cos(theta)[:, None]
    return map_coordinates(mag, [rows, cols], order=1, mode="constant")


def register_logpolar(
    ref: jax.Array, mov: jax.Array, upsample_factor: int = 10
):
    """Estimate the rotation + scale of ``mov`` relative to ``ref``.

    The Fourier-Mellin trick on the existing machinery: a rotation of
    the frame rotates its spectrum magnitude, an isotropic scale by
    ``s`` scales it by ``1/s`` — and on a log-polar resampling of the
    magnitude both become pure *translations* (rotation along θ, log-
    scale along log-r), which :func:`register_phase_correlation`
    already recovers to subpixel precision. The magnitude comes from
    :func:`repro.imaging.psd.fft2_psd` so the border cross artifact
    (which would anchor a spurious zero-motion peak) never enters.

    Returns ``(angle, scale)`` floats: ``mov`` looks like ``ref``
    rotated by ``angle`` radians (counter-clockwise, y-up convention)
    and magnified by ``scale`` about the centre; apply the inverse warp
    ``(-angle, 1/scale)`` to register ``mov`` onto ``ref``. Translation
    does not bias the estimate (magnitude spectra are shift-invariant)
    — recover it afterwards with :func:`register_phase_correlation` on
    the de-rotated frame. 2D frames only; the angle is recovered modulo
    π (magnitude spectra cannot tell a half-turn apart).
    """
    # lazy import: psd imports hermitian_full from this module
    from repro.imaging.psd import fft2_psd

    ref = jnp.asarray(ref)
    mov = jnp.asarray(mov)
    if ref.ndim != 2 or mov.ndim != 2:
        raise ValueError(
            f"register_logpolar takes single (H, W) frames, got "
            f"{ref.shape} and {mov.shape}"
        )
    if ref.shape != mov.shape:
        raise ValueError(
            f"ref and mov must share a shape, got {ref.shape} vs {mov.shape}"
        )
    h, w = ref.shape
    lp_ref = _logpolar_resample(jnp.log1p(jnp.abs(xfft.fftshift2(fft2_psd(ref)))))
    lp_mov = _logpolar_resample(jnp.log1p(jnp.abs(xfft.fftshift2(fft2_psd(mov)))))
    d_theta, d_logr = register_phase_correlation(
        lp_ref, lp_mov, upsample_factor=upsample_factor
    )
    rmax = min(h, w) / 2.0 - 1.0
    angle = float(d_theta) * (math.pi / h)
    scale = math.exp(float(d_logr) * (math.log(rmax) / (w - 1)))
    return angle, scale


def apply_shift(x: jax.Array, shift) -> jax.Array:
    """Translate ``x`` by ``shift = (dy, dx)`` (fractional ok) via the
    Fourier shift theorem: ``y[i, j] = x[i − dy, j − dx]`` with circular
    boundary. ``shift`` broadcasts over leading axes (``(..., 2)``); real
    frames stay on the two-for-one half-spectrum path end to end."""
    x = jnp.asarray(x)
    if x.ndim < 2:
        raise ValueError(f"need (..., H, W) frames, got shape {x.shape}")
    shift = jnp.asarray(shift, dtype=jnp.float32)
    if shift.shape[-1] != 2:
        raise ValueError(f"shift must end in (dy, dx), got shape {shift.shape}")
    h, w = x.shape[-2], x.shape[-1]
    dy = shift[..., 0][..., None, None]
    dx = shift[..., 1][..., None, None]
    fy = xfft.fftfreq(h, dtype=jnp.float32)[:, None]
    if _is_real(x):
        fx = xfft.rfftfreq(w, dtype=jnp.float32)[None, :]
        ramp = jnp.exp(-2j * math.pi * (fy * dy + fx * dx))
        return xfft.irfft2(xfft.rfft2(x) * ramp).astype(x.dtype)
    fx = xfft.fftfreq(w, dtype=jnp.float32)[None, :]
    ramp = jnp.exp(-2j * math.pi * (fy * dy + fx * dx))
    return xfft.ifft2(xfft.fft2(x) * ramp)
