"""Overlap-save tiled FFT convolution: frames bigger than any transform.

The paper's engine (and its software twin here) is happiest on frames
whose whole working set sits in VMEM; ``repro.kernels`` refuses to grow
past that and fails over to slower unfused passes. But imaging inputs —
stitched microscopy, holography holograms, wide-area correlation scenes
— are routinely far larger than any single power-of-two transform worth
running. Overlap-save is the classical answer: slide a VMEM-sized tile
with ``K − 1`` overlap across the frame, circularly convolve each tile
in the spectrum, keep each tile's valid interior, and the seams vanish
by construction.

The tile is a *planning* decision: small tiles waste work on overlap,
big tiles on padding — and past the fused kernels' working-set census
(``repro.kernels.ops.fft2_working_set``) they fall off the VMEM cliff.
``oaconvolve2`` therefore asks ``repro.plan`` (problem kind
``oaconv2d``) for the tile, and the answer is cached wisdom like any
other plan. Every transform in here goes through ``repro.xfft``: real
inputs ride the two-for-one half-spectrum path end to end.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

import repro.xfft as xfft
from repro.core.spectral import _is_real, _next_pow2
from repro.plan.api import resolve_call

__all__ = ["oaconvolve2", "fftconv2", "matched_filter2"]


def _check_2d_pair(image: jax.Array, kernel: jax.Array, name: str):
    image = jnp.asarray(image)
    kernel = jnp.asarray(kernel)
    if image.ndim < 2 or kernel.ndim < 2:
        raise ValueError(
            f"{name} needs (..., H, W) image and (..., KH, KW) kernel, got "
            f"{image.shape} and {kernel.shape}"
        )
    return image, kernel


def _crop_mode(
    full: jax.Array, h: int, w: int, kh: int, kw: int, mode: str
) -> jax.Array:
    """Crop a full (H+KH−1, W+KW−1) convolution to ``mode`` (scipy names)."""
    if mode == "full":
        return full
    if mode == "same":
        top, left = (kh - 1) // 2, (kw - 1) // 2
        return full[..., top:top + h, left:left + w]
    if mode == "valid":
        if kh > h or kw > w:
            raise ValueError(
                f"valid-mode convolution needs kernel <= image, got "
                f"({kh}, {kw}) vs ({h}, {w})"
            )
        return full[..., kh - 1:h, kw - 1:w]
    raise ValueError(f'mode must be "full", "same" or "valid", got {mode!r}')


def _pad_tail(x: jax.Array, h: int, w: int) -> jax.Array:
    pad = [(0, 0)] * (x.ndim - 2)
    pad += [(0, h - x.shape[-2]), (0, w - x.shape[-1])]
    return jnp.pad(x, pad)


def _spectral_multiply(a: jax.Array, b: jax.Array, real: bool) -> jax.Array:
    """Circular convolution of equal-size frames through planned FFTs."""
    if real:
        return xfft.irfft2(xfft.rfft2(a) * xfft.rfft2(b))
    return xfft.ifft2(xfft.fft2(a) * xfft.fft2(b))


def fftconv2(
    image: jax.Array, kernel: jax.Array, mode: str = "full"
) -> jax.Array:
    """Linear 2D convolution via ONE padded transform pair (plan-backed).

    The reference and small-input path: both operands zero-pad to the
    power-of-two cover of (H+KH−1, W+KW−1) and multiply in the spectrum.
    Use :func:`oaconvolve2` when the padded frame outgrows a sensible
    single transform. Kernel leading axes broadcast against the image's.
    """
    image, kernel = _check_2d_pair(image, kernel, "fftconv2")
    h, w = image.shape[-2], image.shape[-1]
    kh, kw = kernel.shape[-2], kernel.shape[-1]
    fh, fw = h + kh - 1, w + kw - 1
    ph, pw = _next_pow2(fh), _next_pow2(fw)
    real = _is_real(image) and _is_real(kernel)
    if not real:
        image = image.astype(jnp.complex64)
        kernel = kernel.astype(jnp.complex64)
    full = _spectral_multiply(
        _pad_tail(image, ph, pw), _pad_tail(kernel, ph, pw), real
    )[..., :fh, :fw]
    return _crop_mode(full, h, w, kh, kw, mode)


def _gather_tiles(
    xp: jax.Array, th: int, tw: int, sh: int, sw: int, nbh: int, nbw: int
) -> jax.Array:
    """(..., PH, PW) -> (..., nbh, nbw, th, tw) overlapping tile stack."""
    hidx = jnp.arange(nbh)[:, None] * sh + jnp.arange(th)[None, :]
    widx = jnp.arange(nbw)[:, None] * sw + jnp.arange(tw)[None, :]
    tiles = xp[..., hidx, :]                 # (..., nbh, th, PW)
    tiles = tiles[..., widx]                 # (..., nbh, th, nbw, tw)
    return jnp.moveaxis(tiles, -2, -3)       # (..., nbh, nbw, th, tw)


def oaconvolve2(
    image: jax.Array,
    kernel: jax.Array,
    mode: str = "same",
    tile: Optional[Tuple[int, int]] = None,
) -> jax.Array:
    """Overlap-save tiled FFT convolution of (..., H, W) with (..., KH, KW).

    Handles images far larger than any single power-of-two transform:
    the frame streams through (TH, TW) tiles with (KH−1, KW−1) overlap,
    each tile one planned ``rfft2``/``irfft2`` (or complex) round trip,
    seams exact by construction. ``tile=None`` asks the planner (problem
    kind ``oaconv2d``) — the tile that best trades overlap waste against
    padding waste while the fused kernels' working set stays in VMEM.
    Kernel leading axes broadcast against the image's (one kernel, or
    one per batched frame). Matches :func:`fftconv2` to fp32 tolerance.
    """
    image, kernel = _check_2d_pair(image, kernel, "oaconvolve2")
    h, w = image.shape[-2], image.shape[-1]
    kh, kw = kernel.shape[-2], kernel.shape[-1]
    real = _is_real(image) and _is_real(kernel)
    if tile is None:
        plan = resolve_call(
            "oaconv2d",
            (h, w, kh, kw),
            dtype="float32" if real else "complex64",
        )
        tile = plan.tile
    th, tw = int(tile[0]), int(tile[1])
    if th < kh or tw < kw:
        raise ValueError(
            f"tile {(th, tw)} smaller than kernel {(kh, kw)}: the "
            "overlap-save step T-K+1 would be empty"
        )
    fh, fw = h + kh - 1, w + kw - 1
    sh, sw = th - kh + 1, tw - kw + 1
    nbh, nbw = math.ceil(fh / sh), math.ceil(fw / sw)
    if nbh * nbw == 1:
        # One tile covers the whole output: the single-transform path is
        # the same arithmetic without the gather.
        return fftconv2(image, kernel, mode=mode)
    if not real:
        image = image.astype(jnp.complex64)
        kernel = kernel.astype(jnp.complex64)
    ph = (kh - 1) + (nbh - 1) * sh + th - (kh - 1)   # = (nbh-1)*sh + th
    pw = (nbw - 1) * sw + tw
    pad = [(0, 0)] * (image.ndim - 2)
    pad += [(kh - 1, ph - (kh - 1) - h), (kw - 1, pw - (kw - 1) - w)]
    xp = jnp.pad(image, pad)
    tiles = _gather_tiles(xp, th, tw, sh, sw, nbh, nbw)
    kf = _pad_tail(kernel, th, tw)[..., None, None, :, :]  # broadcast tiles
    out = _spectral_multiply(tiles, kf, real)
    valid = out[..., kh - 1:, kw - 1:]                # (..., nbh, nbw, sh, sw)
    joined = jnp.moveaxis(valid, -3, -2)              # (..., nbh, sh, nbw, sw)
    full = joined.reshape(*joined.shape[:-4], nbh * sh, nbw * sw)
    return _crop_mode(full[..., :fh, :fw], h, w, kh, kw, mode)


def matched_filter2(
    scene: jax.Array,
    template: jax.Array,
    mode: str = "same",
    tile: Optional[Tuple[int, int]] = None,
) -> jax.Array:
    """Cross-correlate ``scene`` with ``template`` at any scene size —
    the paper's correlation-pattern-recognition workload, tiled.

    ``corr[i, j] = Σ scene[i+u, j+v]·conj(template[u, v])``, computed as
    an overlap-save convolution with the conjugate-flipped template, so
    scenes far beyond :func:`repro.core.correlate2`'s equal-size,
    single-transform contract still stream through VMEM-sized tiles.
    The peak of the result locates the template.
    """
    template = jnp.asarray(template)
    flipped = jnp.conj(jnp.flip(template, axis=(-2, -1)))
    return oaconvolve2(scene, flipped, mode=mode, tile=tile)
