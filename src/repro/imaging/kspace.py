"""Centered k-space transforms — the MRI community's convention.

MRI raw data ("k-space") puts the zero-frequency sample at the ARRAY
CENTRE, not at index 0, and uses the unitary (``ortho``) scaling so the
forward/adjoint pair used in iterative reconstruction is an isometry.
The moco-workshop operators (``/root/related``) spell this

    kspace = fftshift(fft2(ifftshift(image)))     # norm="ortho"
    image  = fftshift(ifft2(ifftshift(kspace)))

and every reconstruction/motion-correction step composes these two.
These are those operators on the planned engine: the inner transform
resolves through ``repro.plan`` like any other ``repro.xfft`` call, the
shifts are index rolls, and leading axes (coils, frames, slices) batch
through untouched.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

import repro.xfft as xfft

__all__ = ["image_to_kspace", "kspace_to_image"]


def image_to_kspace(
    image: jax.Array,
    axes: Tuple[int, int] = (-2, -1),
    norm: Optional[str] = "ortho",
) -> jax.Array:
    """Image -> centered k-space over ``axes`` (leading axes batched).

    ``fftshift(fft2(ifftshift(image)))`` with unitary scaling by default:
    ``kspace_to_image(image_to_kspace(x)) == x`` and energy is preserved
    (Parseval) — the contract iterative reconstruction relies on.
    """
    image = jnp.asarray(image)
    if not jnp.issubdtype(image.dtype, jnp.complexfloating):
        image = image.astype(jnp.complex64)
    shifted = xfft.ifftshift(image, axes=axes)
    spectrum = xfft.fft2(shifted, axes=axes, norm=norm)
    return xfft.fftshift(spectrum, axes=axes)


def kspace_to_image(
    kspace: jax.Array,
    axes: Tuple[int, int] = (-2, -1),
    norm: Optional[str] = "ortho",
) -> jax.Array:
    """Centered k-space -> image over ``axes`` (exact inverse of
    :func:`image_to_kspace` under the same ``norm``)."""
    kspace = jnp.asarray(kspace)
    if not jnp.issubdtype(kspace.dtype, jnp.complexfloating):
        # real input upcasts; complex128 (a double-precision scope under
        # enable_x64) must NOT be silently downcast to complex64
        kspace = kspace.astype(jnp.complex64)
    shifted = xfft.ifftshift(kspace, axes=axes)
    image = xfft.ifft2(shifted, axes=axes, norm=norm)
    return xfft.fftshift(image, axes=axes)
