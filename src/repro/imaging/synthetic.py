"""Deterministic synthetic frames for demos, benchmarks and tests.

One generator, one definition: the band-limited random frame that makes
subpixel registration well posed (a Gaussian-windowed white spectrum).
Tests, benchmarks and examples all import it from here so the fixture
can never drift between them. The spectral shaping runs in numpy on
purpose — generating inputs must not exercise the transform engines
under test — but the frequency grid comes from :func:`repro.xfft.fftfreq`
(pure index arithmetic, no engine), the one definition the rest of the
stack uses, with its dtype PINNED so the fixture stays bit-identical
whatever ``xfft.config(precision=...)`` scope happens to be active.
"""

from __future__ import annotations

import numpy as np

__all__ = ["band_limited_frame"]


def band_limited_frame(n: int, seed: int, bandwidth: float = 0.05) -> np.ndarray:
    """(n, n) float32 frame with a Gaussian-bounded spectrum, max-normed.

    ``bandwidth`` is the Gaussian's std in cycles/sample; 0.05 leaves
    enough low-frequency structure that phase correlation locks on and
    little enough high frequency that fractional shifts interpolate
    cleanly.
    """
    import jax.numpy as jnp

    from repro import xfft  # lazy: keep fixture generation import-light

    rng = np.random.default_rng(seed)
    spectrum = np.fft.fft2(rng.standard_normal((n, n)))
    # dtype pinned: an ambient precision="double" scope must not change
    # the grid (and therefore the fixture) between test environments.
    freqs = np.asarray(xfft.fftfreq(n, dtype=jnp.float32), dtype=np.float64)
    ky = freqs[:, None]
    kx = freqs[None, :]
    spectrum *= np.exp(-(ky**2 + kx**2) / (2 * bandwidth**2))
    frame = np.real(np.fft.ifft2(spectrum))
    return (frame / np.abs(frame).max()).astype(np.float32)
