"""Deterministic synthetic frames for demos, benchmarks and tests.

One generator, one definition: the band-limited random frame that makes
subpixel registration well posed (a Gaussian-windowed white spectrum).
Tests, benchmarks and examples all import it from here so the fixture
can never drift between them. Pure numpy on purpose — generating inputs
must not touch the engine under test.
"""

from __future__ import annotations

import numpy as np

__all__ = ["band_limited_frame"]


def band_limited_frame(n: int, seed: int, bandwidth: float = 0.05) -> np.ndarray:
    """(n, n) float32 frame with a Gaussian-bounded spectrum, max-normed.

    ``bandwidth`` is the Gaussian's std in cycles/sample; 0.05 leaves
    enough low-frequency structure that phase correlation locks on and
    little enough high frequency that fractional shifts interpolate
    cleanly.
    """
    rng = np.random.default_rng(seed)
    spectrum = np.fft.fft2(rng.standard_normal((n, n)))
    ky = np.fft.fftfreq(n)[:, None]
    kx = np.fft.fftfreq(n)[None, :]
    spectrum *= np.exp(-(ky**2 + kx**2) / (2 * bandwidth**2))
    frame = np.real(np.fft.ifft2(spectrum))
    return (frame / np.abs(frame).max()).astype(np.float32)
