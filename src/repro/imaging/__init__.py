"""repro.imaging — spectral image processing on the paper's 2D engine.

The source paper motivates its area-efficient 2D FFT with imaging
workloads — medical image processing, digital holography, correlation
pattern recognition — but a transform alone is not a workload. This
subsystem is the workload layer: the operator set an imaging user
actually calls, each one built ON the ``repro.xfft``/``repro.plan``
stack (every FFT in here resolves through the planner; none reaches
into the engines privately):

* :mod:`repro.imaging.psd` — periodic-plus-smooth decomposition
  (Moisan; Mahmood et al.'s simultaneous edge-artifact removal):
  ``psd_decompose`` / ``fft2_psd`` give spectra free of the cross-shaped
  boundary artifact that plain windowless ``fft2`` stamps on every
  natural image.
* :mod:`repro.imaging.registration` — translation registration /
  motion correction: ``register_phase_correlation`` (whole-pixel peak +
  subpixel upsampled-DFT refinement) and ``apply_shift`` (Fourier shift
  theorem).
* :mod:`repro.imaging.kspace` — the MRI community's centered-transform
  convention (``fftshift(fft2(ifftshift(·)))``, ortho-normalised):
  ``image_to_kspace`` / ``kspace_to_image`` with batched leading axes.
* :mod:`repro.imaging.tiled` — overlap-save tiled FFT convolution:
  ``oaconvolve2`` handles images far larger than any single transform
  by streaming VMEM-sized tiles (tile picked by the planner's
  ``oaconv2d`` kind against the fused kernels' working-set census);
  ``fftconv2`` is the single-transform reference and small-input path;
  ``matched_filter2`` is the paper's correlation-recognition application
  at arbitrary scene size.

Serving lives in :class:`repro.serve.ImagingService`, which batches
registration and convolution requests by problem key the same way
``SpectrumService`` batches bare transforms.
"""

from repro.imaging.kspace import image_to_kspace, kspace_to_image
from repro.imaging.psd import fft2_psd, psd_decompose
from repro.imaging.registration import apply_shift, register_phase_correlation
from repro.imaging.synthetic import band_limited_frame
from repro.imaging.tiled import fftconv2, matched_filter2, oaconvolve2

__all__ = [
    "band_limited_frame",
    "psd_decompose",
    "fft2_psd",
    "register_phase_correlation",
    "apply_shift",
    "image_to_kspace",
    "kspace_to_image",
    "oaconvolve2",
    "fftconv2",
    "matched_filter2",
]
