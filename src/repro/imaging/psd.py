"""Periodic-plus-smooth decomposition: edge-artifact-free spectra.

The DFT treats every frame as one period of a torus. A natural image's
opposite borders do not match, so the implicit wrap is a step edge, and
that step stamps a bright cross (energy smeared along both frequency
axes) over the whole spectrum — fatal for correlation recognition and
k-space analysis, the paper's own motivating workloads. Moisan's
periodic-plus-smooth decomposition splits the frame ``x = p + s`` where
``s`` (the *smooth* component) is the harmonic image carrying all the
border mismatch and ``p`` (the *periodic* component) tiles seamlessly.

Mahmood et al. ("2D DFT with Simultaneous Edge Artifact Removal",
PAPERS.md) make this real-time on tiled FFT hardware by solving the
smooth component *in the spectrum*: ``s`` solves a discrete Poisson
equation whose right-hand side is nonzero only on the frame border, so
its spectrum is a closed form over TWO 1D FFTs of the border-difference
vectors — no second 2D transform:

    v̂[q, r] = B̂1[r]·(1 − e^{2πiq/H}) + B̂2[q]·(1 − e^{2πir/W})
    ŝ[q, r] = v̂[q, r] / (2cos(2πq/H) + 2cos(2πr/W) − 4),   ŝ[0,0] = 0

where ``b1 = x[H−1,:] − x[0,:]`` and ``b2 = x[:,W−1] − x[:,0]``. That is
what :func:`fft2_psd` computes: one planned ``fft2`` plus two planned 1D
``fft`` calls, every transform resolved through ``repro.plan``.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

import repro.xfft as xfft
from repro.core.spectral import _is_real
from repro.imaging.registration import hermitian_full

# The ONE argument contract: axis canonicalization (bounds-checked, named
# errors), norm validation and post-engine scaling all come from the xfft
# front door, so the smooth term below can never drift out of sync with
# the fft2 term it is subtracted from.
from repro.xfft._transforms import _canon_axes, _check_norm, _scale

__all__ = ["psd_decompose", "fft2_psd", "smooth_spectrum"]


def _to_last_two(x: jax.Array, axes: Tuple[int, int], name: str):
    x = jnp.asarray(x)
    if x.ndim < 2:
        raise ValueError(f"{name} needs at least a 2D image, got shape {x.shape}")
    if len(axes) != 2:
        raise ValueError(f"{name} decomposes exactly 2 axes, got {tuple(axes)}")
    canon = _canon_axes(axes, x.ndim, name)
    moved = canon != (x.ndim - 2, x.ndim - 1)
    if moved:
        x = jnp.moveaxis(x, canon, (-2, -1))
    return x, canon, moved


def smooth_spectrum(x: jax.Array) -> jax.Array:
    """Spectrum (backward norm) of the smooth component of ``(..., H, W)``.

    The in-spectrum solve above: two planned 1D FFTs of the border
    differences, a closed-form Poisson division, no 2D transform.
    """
    x = jnp.asarray(x)
    h, w = x.shape[-2], x.shape[-1]
    cdt = x.dtype if jnp.issubdtype(x.dtype, jnp.complexfloating) else jnp.complex64
    b1 = (x[..., -1, :] - x[..., 0, :]).astype(cdt)   # (..., W)
    b2 = (x[..., :, -1] - x[..., :, 0]).astype(cdt)   # (..., H)
    bhat1 = xfft.fft(b1)                              # planned length-W pass
    bhat2 = xfft.fft(b2)                              # planned length-H pass
    q = jnp.arange(h, dtype=jnp.float32)
    r = jnp.arange(w, dtype=jnp.float32)
    fq = 1.0 - jnp.exp(2j * math.pi * q / h).astype(cdt)   # (H,)
    fr = 1.0 - jnp.exp(2j * math.pi * r / w).astype(cdt)   # (W,)
    vhat = bhat1[..., None, :] * fq[:, None] + bhat2[..., :, None] * fr[None, :]
    denom = (
        2.0 * jnp.cos(2.0 * math.pi * q / h)[:, None]
        + 2.0 * jnp.cos(2.0 * math.pi * r / w)[None, :]
        - 4.0
    )
    denom = denom.at[0, 0].set(1.0)                   # avoid 0/0 at DC
    shat = vhat / denom.astype(cdt)
    return shat.at[..., 0, 0].set(0.0)                # smooth has zero mean


def _smooth_spectrum_half(x: jax.Array) -> jax.Array:
    """Half-width smooth spectrum ``shat[..., :, :W/2+1]`` of a REAL frame.

    The two-for-one route of :func:`smooth_spectrum`: real border
    differences take ``rfft`` (half the border-pass arithmetic), the
    row-axis half is Hermitian-extended in-place (1D flip+conj, no
    transform), and the Poisson division only ever runs on the half the
    real 2D path actually consumes.
    """
    x = jnp.asarray(x)
    h, w = x.shape[-2], x.shape[-1]
    wh = w // 2 + 1
    b1 = x[..., -1, :] - x[..., 0, :]                 # (..., W) real
    b2 = x[..., :, -1] - x[..., :, 0]                 # (..., H) real
    bhat1 = xfft.rfft(b1)                             # (..., W/2+1)
    bhat2h = xfft.rfft(b2)                            # (..., H/2+1)
    # full-length row spectrum by Hermitian symmetry: B2[q] = conj(B2[H-q])
    tail = jnp.conj(jnp.flip(bhat2h[..., 1:h - h // 2], axis=-1))
    bhat2 = jnp.concatenate([bhat2h, tail], axis=-1)  # (..., H)
    cdt = bhat1.dtype
    q = jnp.arange(h, dtype=jnp.float32)
    r = jnp.arange(wh, dtype=jnp.float32)
    fq = 1.0 - jnp.exp(2j * math.pi * q / h).astype(cdt)
    fr = 1.0 - jnp.exp(2j * math.pi * r / w).astype(cdt)
    vhat = bhat1[..., None, :wh] * fq[:, None] + bhat2[..., :, None] * fr[None, :]
    denom = (
        2.0 * jnp.cos(2.0 * math.pi * q / h)[:, None]
        + 2.0 * jnp.cos(2.0 * math.pi * r / w)[None, :]
        - 4.0
    )
    denom = denom.at[0, 0].set(1.0)
    shat = vhat / denom.astype(cdt)
    return shat.at[..., 0, 0].set(0.0)


def psd_decompose(
    x: jax.Array, axes: Tuple[int, int] = (-2, -1)
) -> Tuple[jax.Array, jax.Array]:
    """Split ``x`` into ``(periodic, smooth)`` with ``periodic + smooth == x``.

    The periodic component tiles seamlessly (opposite borders match), so
    its spectrum carries no cross artifact; the smooth component is the
    harmonic border-mismatch image. Leading axes are batched.
    """
    x, canon, moved = _to_last_two(x, axes, "psd_decompose")
    if _is_real(x):
        # two-for-one: the smooth component of a real frame is real, so
        # its spectrum is Hermitian — one irfft2 of the half-spectrum
        # replaces the complex ifft2 + real projection
        smooth = xfft.irfft2(_smooth_spectrum_half(x)).astype(x.dtype)
    else:
        smooth = xfft.ifft2(smooth_spectrum(x))
    periodic = x - smooth
    if moved:
        periodic = jnp.moveaxis(periodic, (-2, -1), canon)
        smooth = jnp.moveaxis(smooth, (-2, -1), canon)
    return periodic, smooth


def fft2_psd(
    x: jax.Array,
    axes: Tuple[int, int] = (-2, -1),
    norm: Optional[str] = None,
) -> jax.Array:
    """2D spectrum of the *periodic* component of ``x`` — ``fft2`` minus
    the in-spectrum smooth solve, i.e. Mahmood et al.'s simultaneous
    edge-artifact removal. Same shape, layout and ``norm`` conventions as
    :func:`repro.xfft.fft2`; one extra pair of 1D border FFTs is the whole
    overhead. Real frames take the two-for-one route throughout —
    ``rfft2`` plus the half-width smooth solve — and the Hermitian
    half-spectrum is expanded to full width only here, where the full
    PSD is the return contract."""
    norm = _check_norm(norm)
    x, canon, moved = _to_last_two(x, axes, "fft2_psd")
    h, w = x.shape[-2], x.shape[-1]
    if _is_real(x):
        shat_h = _scale(_smooth_spectrum_half(x), norm, h * w, forward=True)
        phat = hermitian_full(xfft.rfft2(x, norm=norm) - shat_h, w)
    else:
        shat = _scale(smooth_spectrum(x), norm, h * w, forward=True)
        phat = xfft.fft2(x, norm=norm) - shat
    return jnp.moveaxis(phat, (-2, -1), canon) if moved else phat
