"""Activation-sharding context: explicit with_sharding_constraint annotations.

Why this exists (EXPERIMENTS.md §Perf, iteration 1): with ZeRO-3-style
weights (contraction dim sharded over "data") and batch-sharded activations,
the SPMD partitioner often picks contraction-splitting — producing
activation-sized all-reduces (observed: 17 GB per MLP layer on
whisper prefill) — instead of gathering the (much smaller) weights.
Constraining the big activations pins GSPMD to the intended pattern:
batch-parallel compute, per-layer weight gathering, TP on the annotated dim.

The context is a no-op unless enabled (CPU unit tests never see it).

Axis tokens used by ``shard(x, *tokens)``:
  "dp"   — batch sharded over the data(+pod) axes
  "tp"   — sharded over the model axis (skipped if the dim doesn't divide)
  "dp+tp"— batch sharded over data AND model axes (2-D batch parallelism for
           attention in archs whose head count doesn't divide the TP size);
           falls back to "dp" when the dim doesn't divide
  None   — unconstrained dim
"""

from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import PartitionSpec as P

_CTX = contextvars.ContextVar("repro_act_sharding", default=None)


@contextlib.contextmanager
def activation_sharding(
    *,
    dp: tuple[str, ...],
    dp_sizes: tuple[int, ...],
    tp: str | None,
    tp_size: int,
    cp: str | None = None,
    cp_size: int = 1,
):
    """``cp`` names a mesh axis available for context-parallel attention
    (sequence-sharded Q) when neither head-TP nor 2-D batch can use it."""
    token = _CTX.set(
        {
            "dp": tuple(dp),
            "dp_sizes": tuple(dp_sizes),
            "tp": tp,
            "tp_size": tp_size if tp else 1,
            "cp": cp,
            "cp_size": cp_size if cp else 1,
        }
    )
    try:
        yield
    finally:
        _CTX.reset(token)


def enabled() -> bool:
    return _CTX.get() is not None


def tp_size() -> int:
    """Model-axis size (1 when the context is disabled)."""
    c = _CTX.get()
    return c["tp_size"] if c else 1


def cp_axis_for(batch: int, seq: int) -> str | None:
    """Context-parallel axis to use for attention over (batch, seq) — only
    when the batch cannot spread over it and the sequence divides."""
    c = _CTX.get()
    if c is None or not c.get("cp"):
        return None
    total = 1  # dp product excluding the cp axis itself
    for a, s in zip(c["dp"], c["dp_sizes"]):
        if a != c["cp"]:
            total *= s
    if batch % (total * c["cp_size"]) == 0:
        return None  # 2-D batch already fills the axis
    if batch % total != 0 or seq % c["cp_size"] != 0:
        return None
    return c["cp"]


def _largest_prefix(dim: int, axes: tuple[str, ...], sizes: tuple[int, ...]):
    """Longest prefix of ``axes`` whose total size divides ``dim``."""
    best = None
    prod = 1
    for ax, sz in zip(axes, sizes):
        prod *= sz
        if dim % prod == 0:
            best = axes[: axes.index(ax) + 1]
        else:
            break
    return best


def shard(x, *tokens):
    """Apply a sharding constraint along ``tokens`` (one per dim of x).

    Axis products that don't divide a dim fall back to the largest usable
    prefix (e.g. a 128-batch decode under a 2-D (data, model) batch context
    shards over data only)."""
    c = _CTX.get()
    if c is None:
        return x
    if len(tokens) != x.ndim:
        raise ValueError(f"{len(tokens)} tokens for rank-{x.ndim} array")
    dp, dp_sizes = c["dp"], c["dp_sizes"]
    tp, tp_sz = c["tp"], c["tp_size"]
    spec = []
    for i, t in enumerate(tokens):
        dim = x.shape[i]
        if t is None:
            spec.append(None)
        elif t == "dp":
            spec.append(_largest_prefix(dim, dp, dp_sizes))
        elif t == "tp":
            spec.append(tp if (tp and dim % tp_sz == 0) else None)
        elif t == "dp+tp":
            axes = dp + ((tp,) if tp else ())
            sizes = dp_sizes + ((tp_sz,) if tp else ())
            spec.append(_largest_prefix(dim, axes, sizes))
        else:
            raise ValueError(f"unknown axis token {t!r}")
    return jax.lax.with_sharding_constraint(x, P(*spec))


def shard_weight(w, *tokens):
    """Compute-view of a weight: same token language; typically used to force
    an FSDP-stored weight to be gathered (None on the stored dim) while
    keeping its TP dim."""
    return shard(w, *tokens)
