from repro.sharding.rules import (
    batch_specs,
    cache_specs,
    dp_axes,
    param_rules,
)

__all__ = ["batch_specs", "cache_specs", "dp_axes", "param_rules"]
