"""Logical-axis sharding rules (MaxText-style), per config and mesh.

Strategy (DESIGN.md §4):
  * DP/FSDP over ("pod","data") — params' "embed" axis sharded over data,
    gathered per-layer inside the scan (ZeRO-3-style).
  * TP over "model" — MLP hidden, vocab, attention heads (only when the head
    count divides the model-axis size; otherwise attention weights stay
    FSDP-only and GSPMD batch-shards attention compute — "hybrid TP").
  * EP: experts' hidden is TP'd; expert weights are FSDP'd (the ep_a2a MoE
    path re-shards tokens instead — §Perf).
  * SP: long-context decode shards the KV/state sequence dim over "model"
    (and over every axis for the 500k single-request cell).
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig


def dp_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)


def use_tp(cfg: ModelConfig, model_size: int = 16) -> bool:
    """TP strategy selector: archs whose head count doesn't divide the model
    axis (llama/starcoder2 24H, xlstm 4H — all ≤3.2B params) run pure 2-D
    batch FSDP instead (weights gathered per layer, batch over data×model).
    Weight-gather traffic ≈ params/layer; activation-reshard traffic of
    hybrid TP measured ~3× higher (EXPERIMENTS.md §Perf iteration 2)."""
    return cfg.n_heads % model_size == 0


def param_rules(cfg: ModelConfig, *, multi_pod: bool, model_size: int = 16) -> dict:
    dp = dp_axes(multi_pod)
    tp = use_tp(cfg, model_size)
    ep = cfg.moe is not None and cfg.moe.impl == "ep_a2a"
    return {
        "embed": dp,                        # FSDP
        "vocab": "model" if tp and cfg.vocab % model_size == 0 else None,
        "mlp": "model" if tp else None,
        "heads": "model" if tp else None,
        "kv_heads": None,                   # KV heads replicated across TP
        "head_dim": None,
        # expert-parallel: experts sharded over cfg.moe.ep_axes (tokens travel
        # via all_to_all; expert FFN hidden deliberately NOT TP'd — the spec
        # dedup drops "model" from the hidden dim when it's used here, which
        # kills the dispatched-activation psum, §Perf cell A iteration 2);
        # otherwise unsharded (weights FSDP'd via "embed").
        "experts": tuple(cfg.moe.ep_axes) if ep else None,
        "q_lora": None,
        "kv_lora": None,
        "ssm_in": "model" if tp else None,
        "layers": None,                     # scan dim never sharded
    }


def batch_specs(
    cfg: ModelConfig, kind: str, *, multi_pod: bool, batch: int | None = None
) -> dict:
    """PartitionSpecs for the input batch of a train/prefill/decode step."""
    dp = dp_axes(multi_pod)
    n_dp = 32 if multi_pod else 16
    if batch is not None and batch % n_dp != 0:
        dp = None  # batch-1 long-context cell: replicate batch, SP the cache
    if kind == "decode":
        return {"token": P(dp, None), "pos": P()}
    specs: dict[str, Any] = {"tokens": P(dp, None)}
    if cfg.family == "audio":
        specs["frames"] = P(dp, None, None)
    if cfg.family == "vlm":
        specs["patches"] = P(dp, None, None)
    if cfg.family == "spectral":
        specs["targets"] = P(dp, None)
        specs["mlm_mask"] = P(dp, None)
    return specs


def _seq_axes(batch: int, multi_pod: bool, model_size: int):
    """How to shard a cache's sequence dim: across "model" normally; across
    EVERYTHING when the whole cell has batch 1 (long-context SP)."""
    if batch == 1:
        return ("pod", "data", "model") if multi_pod else ("data", "model")
    return ("model",)


def cache_specs(
    cfg: ModelConfig,
    cache_tree: Any,
    batch: int,
    *,
    multi_pod: bool,
    model_size: int = 16,
) -> Any:
    """Name-based PartitionSpecs for every cache leaf (KV, ring, MLA latent,
    SSM/xLSTM state). Leaves start with a leading stacked-layer dim."""
    dp = dp_axes(multi_pod)
    bspec = dp if batch > 1 else None
    seq_ax = _seq_axes(batch, multi_pod, model_size)
    heads_ok = cfg.n_heads % model_size == 0

    def spec_for(path, leaf) -> P:
        name = None
        keys = [getattr(k, "key", None) for k in path]
        for key in reversed(keys):
            if isinstance(key, str):
                name = key
                break
        nd = leaf.ndim
        if "slstm" in keys:
            # sequential recurrence distributes over batch only (see xlstm.py)
            return P(*([None, bspec] + [None] * (nd - 2)))
        if name in ("k", "v"):            # (L, B, S, KV, Dh)
            return P(None, bspec, seq_ax, None, None)
        if name in ("cross_k", "cross_v"):  # (L, B, T, H, Dh)
            return P(None, bspec, None, "model" if heads_ok else None, None)
        if name == "c_kv":                # (L, B, S, r)
            return P(None, bspec, seq_ax, None)
        if name == "k_rope":              # (L, B, S, dr)
            return P(None, bspec, seq_ax, None)
        if name == "slot_pos":            # (L, S) or (S,)
            return P(*([None] * (nd - 1)), seq_ax)
        if name == "ssd":                 # (L, B, H, P, N)
            h = leaf.shape[2]
            return P(None, bspec, "model" if h % model_size == 0 else None, None, None)
        if name == "c" and nd == 5:       # mLSTM matrix memory (L,B,H,dk,dv)
            return P(None, bspec, None, "model" if leaf.shape[3] % model_size == 0 else None, None)
        # generic recurrent-state fallback (conv, sLSTM vectors, mLSTM n/m):
        # batch dim -> dp, last dim -> model when divisible.
        last = "model" if leaf.shape[-1] % model_size == 0 and nd >= 3 else None
        mids = [None] * (nd - 3) if nd >= 3 else []
        if nd >= 3:
            return P(None, bspec, *mids, last)
        return P(*([None] * nd))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
    return jax.tree.unflatten(treedef, [spec_for(p, l) for p, l in flat])
