"""Real-input FFTs via two-for-one Hermitian packing — half the work.

Every workload the paper targets (medical imaging, holography, correlation
recognition) feeds the transform *real* data, whose spectrum is conjugate
symmetric: Y[k] = conj(Y[N-k]). Computing the full complex FFT therefore
does 2× the arithmetic and moves 2× the bytes actually required. The classic
remedy — pack the N real samples as N/2 complex numbers z[j] = x[2j] +
i·x[2j+1], run ONE half-size complex FFT, and untangle the two interleaved
spectra with the symmetry recombination

    Y[k] = Xe[k] + W_N^k · Xo[k],   k = 0..N/2

— is the software twin of the paper's area reuse: the same butterfly engine,
half the stages' worth of data.

The ``*_impl`` functions are the engine entries (any variant, including
``"fused"``/``"fused_r4"`` — the Pallas kernels that run the pack +
half-size panel + recombination in one VMEM residency — and ``"auto"``,
planned through ``repro.plan`` under the ``rfft1d``/``rfft2d`` problem
kinds). The public names are deprecated aliases of the ``repro.xfft``
front door, which adds ``norm=`` conventions and plan-backed dispatch.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core._deprecation import warn_deprecated
from repro.core.fft1d import (
    BUILTIN_VARIANTS,
    Variant,
    _check_pow2,
    fft_impl,
    ifft_impl,
)

__all__ = ["rfft", "irfft", "rfft2", "irfft2"]

_FUSED = ("fused", "fused_r4")


def _ensure_real(x: jax.Array, name: str) -> jax.Array:
    """Validate real input WITHOUT touching its dtype (the engine — or the
    precision-aware xfft front door — owns the float width)."""
    x = jnp.asarray(x)
    if jnp.issubdtype(x.dtype, jnp.complexfloating):
        raise TypeError(f"{name} expects real input; use fft/fft2 for complex")
    return x


def _resolve(kind: str, shape, variant: Variant, direction: str = "fwd") -> Variant:
    if variant != "auto":
        return variant
    from repro.plan.api import resolve  # lazy: plan imports core

    return resolve(kind, tuple(shape), dtype="float32", direction=direction).variant


def _radix(variant: Variant) -> int:
    return 4 if variant == "fused_r4" else 2


def _rfft_jnp(x: jax.Array, n: int, variant: Variant) -> jax.Array:
    """Pack N reals as N/2 complex, half-size FFT, symmetry recombination."""
    m = n // 2
    z = (x[..., 0::2] + 1j * x[..., 1::2]).astype(jnp.complex64)
    zf = fft_impl(z, variant=variant) if m > 1 else z
    k = jnp.arange(m + 1)
    zk = jnp.take(zf, k % m, axis=-1)               # Z[k], with Z[M] = Z[0]
    zmk = jnp.conj(jnp.take(zf, (-k) % m, axis=-1))  # conj(Z[(M-k) mod M])
    xe = 0.5 * (zk + zmk)                           # spectrum of even samples
    xo = -0.5j * (zk - zmk)                         # spectrum of odd samples
    w = jnp.exp(-2j * jnp.pi * k / n).astype(jnp.complex64)
    return xe + w * xo


def _irfft_jnp(y: jax.Array, n: int, variant: Variant) -> jax.Array:
    """Invert the recombination, one half-size IFFT, de-interleave."""
    m = n // 2
    # np.fft.irfft semantics: DC and Nyquist bins of a Hermitian spectrum
    # are real — discard any imaginary part there.
    edge = jnp.arange(m + 1)
    y = jnp.where((edge == 0) | (edge == m), jnp.real(y).astype(jnp.complex64), y)
    k = jnp.arange(m)
    yk = y[..., :m]
    ymk = jnp.conj(jnp.flip(y[..., 1:], axis=-1))   # conj(Y[M-k]), k = 0..M-1
    xe = 0.5 * (yk + ymk)
    xo = 0.5 * (yk - ymk) * jnp.exp(2j * jnp.pi * k / n).astype(jnp.complex64)
    z = xe + 1j * xo
    zi = ifft_impl(z, variant=variant) if m > 1 else z
    out = jnp.stack([jnp.real(zi), jnp.imag(zi)], axis=-1)
    return out.reshape(*zi.shape[:-1], n).astype(jnp.float32)


def rfft_impl(x: jax.Array, axis: int = -1, variant: Variant = "auto") -> jax.Array:
    """Real-input FFT along ``axis`` -> non-redundant half spectrum
    (..., N/2+1) complex. N must be a power of two >= 2."""
    orig = x
    x = _ensure_real(x, "rfft")
    user_axis = axis
    axis = axis % x.ndim
    n = x.shape[axis]
    _check_pow2(n, axis=user_axis)
    key_shape = x.shape[:axis] + x.shape[axis + 1:] + (n,)
    variant = _resolve("rfft1d", key_shape, variant)
    if variant not in BUILTIN_VARIANTS:
        # Registry fallback gets the caller's ORIGINAL array (an x64
        # engine must do its own asarray/moveaxis inside enable_x64).
        from repro.engines import apply_engine

        return apply_engine(variant, "rfft1d", orig, axis=axis)
    x = x.astype(jnp.float32)
    if axis != x.ndim - 1:
        x = jnp.moveaxis(x, axis, -1)
    if variant in _FUSED:
        from repro.kernels.ops import rfft_kernel  # lazy: kernels import core

        y = rfft_kernel(x, radix=_radix(variant))
    else:
        y = _rfft_jnp(x, n, variant)
    if axis != x.ndim - 1:
        y = jnp.moveaxis(y, -1, axis)
    return y


def irfft_impl(y: jax.Array, axis: int = -1, variant: Variant = "auto") -> jax.Array:
    """Inverse of :func:`rfft_impl`: (..., N/2+1) half spectrum -> real (..., N)."""
    orig = y
    y = jnp.asarray(y)
    user_axis = axis
    axis = axis % y.ndim
    n = 2 * (y.shape[axis] - 1)
    if n < 2 or n & (n - 1):
        raise ValueError(
            f"axis {user_axis} has a half spectrum of width {y.shape[axis]}; "
            "irfft requires width N/2+1 with N a power of two"
        )
    key_shape = y.shape[:axis] + y.shape[axis + 1:] + (n,)
    variant = _resolve("rfft1d", key_shape, variant, direction="inv")
    if variant not in BUILTIN_VARIANTS:
        from repro.engines import apply_engine  # lazy: registry fallback

        return apply_engine(variant, "rfft1d", orig, direction="inv", axis=axis)
    y = y.astype(jnp.complex64)
    if axis != y.ndim - 1:
        y = jnp.moveaxis(y, axis, -1)
    if variant in _FUSED:
        from repro.kernels.ops import irfft_kernel  # lazy: kernels import core

        out = irfft_kernel(y, radix=_radix(variant))
    else:
        out = _irfft_jnp(y, n, variant)
    if axis != y.ndim - 1:
        out = jnp.moveaxis(out, -1, axis)
    return out


def rfft2_impl(x: jax.Array, variant: Variant = "auto") -> jax.Array:
    """2D real-input FFT over the last two axes: row rfft then full column
    FFT -> (..., H, W/2+1) complex."""
    orig = x
    x = _ensure_real(x, "rfft2")
    variant = _resolve("rfft2d", x.shape, variant)
    if variant not in BUILTIN_VARIANTS:
        from repro.engines import apply_engine  # lazy: registry fallback

        return apply_engine(variant, "rfft2d", orig)
    x = x.astype(jnp.float32)
    if variant in _FUSED:
        from repro.kernels.ops import rfft2_kernel  # lazy: kernels import core

        return rfft2_kernel(x, radix=_radix(variant))
    y = rfft_impl(x, axis=-1, variant=variant)
    return fft_impl(y, axis=-2, variant=variant)


def irfft2_impl(y: jax.Array, variant: Variant = "auto") -> jax.Array:
    """Inverse of :func:`rfft2_impl`: (..., H, W/2+1) -> real (..., H, W)."""
    orig = y
    y = jnp.asarray(y)
    half = y.shape[-1]
    w = 2 * (half - 1)
    variant = _resolve("rfft2d", y.shape[:-1] + (w,), variant, direction="inv")
    if variant not in BUILTIN_VARIANTS:
        from repro.engines import apply_engine  # lazy: registry fallback

        return apply_engine(variant, "rfft2d", orig, direction="inv")
    y = y.astype(jnp.complex64)
    if variant in _FUSED:
        from repro.kernels.ops import irfft2_kernel  # lazy: kernels import core

        return irfft2_kernel(y, radix=_radix(variant))
    z = ifft_impl(y, axis=-2, variant=variant)
    return irfft_impl(z, axis=-1, variant=variant)


# --------------------- deprecated public entry points ---------------------


def rfft(
    x: jax.Array, axis: int = -1, variant: Optional[Variant] = None
) -> jax.Array:
    """Deprecated alias of :func:`repro.xfft.rfft` (kept for old call sites)."""
    warn_deprecated("repro.core.rfft.rfft", "repro.xfft.rfft")
    from repro import xfft  # lazy: xfft builds on this module

    if variant is None or variant == "auto":
        return xfft.rfft(x, axis=axis)
    with xfft.config(variant=variant):
        return xfft.rfft(x, axis=axis)


def irfft(
    y: jax.Array, axis: int = -1, variant: Optional[Variant] = None
) -> jax.Array:
    """Deprecated alias of :func:`repro.xfft.irfft` (kept for old call sites)."""
    warn_deprecated("repro.core.rfft.irfft", "repro.xfft.irfft")
    from repro import xfft  # lazy: xfft builds on this module

    if variant is None or variant == "auto":
        return xfft.irfft(y, axis=axis)
    with xfft.config(variant=variant):
        return xfft.irfft(y, axis=axis)


def rfft2(x: jax.Array, variant: Optional[Variant] = None) -> jax.Array:
    """Deprecated alias of :func:`repro.xfft.rfft2` (kept for old call sites)."""
    warn_deprecated("repro.core.rfft.rfft2", "repro.xfft.rfft2")
    from repro import xfft  # lazy: xfft builds on this module

    if variant is None or variant == "auto":
        return xfft.rfft2(x)
    with xfft.config(variant=variant):
        return xfft.rfft2(x)


def irfft2(y: jax.Array, variant: Optional[Variant] = None) -> jax.Array:
    """Deprecated alias of :func:`repro.xfft.irfft2` (kept for old call sites)."""
    warn_deprecated("repro.core.rfft.irfft2", "repro.xfft.irfft2")
    from repro import xfft  # lazy: xfft builds on this module

    if variant is None or variant == "auto":
        return xfft.irfft2(y)
    with xfft.config(variant=variant):
        return xfft.irfft2(y)
