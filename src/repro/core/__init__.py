"""Core: the paper's area-efficient FFT engine and its applications."""

from repro.core.fft1d import (
    bit_reversal_permutation,
    butterfly_counts,
    fft,
    fft_routing_tables,
    ifft,
)
from repro.core.fft2d import fft2, fft2_stream, fftshift2, ifft2, ifftshift2
from repro.core.rfft import irfft, irfft2, rfft, rfft2
from repro.core.spectral import correlate2, fftconv, fourier_mixing, log_mel, stft

__all__ = [
    "bit_reversal_permutation",
    "butterfly_counts",
    "fft",
    "fft_routing_tables",
    "ifft",
    "fft2",
    "fft2_stream",
    "fftshift2",
    "ifftshift2",
    "ifft2",
    "rfft",
    "irfft",
    "rfft2",
    "irfft2",
    "correlate2",
    "fftconv",
    "fourier_mixing",
    "log_mel",
    "stft",
]
