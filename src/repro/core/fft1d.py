"""Iterative radix-2 FFT with butterfly-unit reuse (the paper's 1D engine).

The paper's 1D FFT processor instantiates only N/2 butterfly units and reuses
them for log2(N) stages, steered by a control unit (Stage Bus), a routing
network (stage-dependent shuffle) and a register array (feedback path).

JAX mapping (see DESIGN.md §2):

  * ``variant="looped"``   — paper-faithful: one stage body inside
    ``lax.fori_loop``; the induction variable is the Stage Bus, per-stage
    routing/twiddle tables are the routing network + twiddle ROM, and the loop
    carry is the register array.
  * ``variant="unrolled"`` — the "array architecture" baseline the paper
    compares against: log2(N) stage bodies laid out in space (XLA sees
    log2(N) separate stage computations).
  * ``variant="stockham"`` — beyond-paper optimized variant: Stockham
    autosort (no bit-reversal gather, contiguous reshapes only) — the
    TPU-friendliest access pattern; used by the optimized kernels.
  * ``variant="radix4"``   — radix-4 Stockham: half the stage count and
    half the twiddle transcendentals (one radix-2 stage when log2(N) is
    odd) — the software analogue of the higher-radix butterfly papers.
  * ``variant="fused"`` / ``"fused_r4"`` — the Pallas kernels
    (``repro.kernels``): the whole transform in one VMEM residency, one
    HBM round trip; ``fused_r4`` runs the radix-4 panel inside.

All variants compute the same DFT and are tested against each other and a
float64 DFT oracle.

Public transform calls belong to ``repro.xfft`` (plan-backed dispatch, no
per-call variant kwargs); this module keeps the engines themselves
(``fft_impl``/``ifft_impl`` plus the per-variant bodies) and warn-once
deprecation shims under the old names.
"""

from __future__ import annotations

import functools
import math
from typing import Literal, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core._deprecation import warn_deprecated

Variant = Literal[
    "looped", "unrolled", "stockham", "radix4", "fused", "fused_r4", "auto"
]

#: Variants this module's dispatch chains terminate on. Any OTHER name is
#: looked up in the ``repro.engines`` registry and delegated wholesale to
#: that engine's executor (before any complex64 cast — a registered engine
#: owns its own dtype policy, e.g. ``reference_x64`` computes in c128).
BUILTIN_VARIANTS = ("looped", "unrolled", "stockham", "radix4", "fused", "fused_r4")

__all__ = [
    "fft",
    "ifft",
    "fft_routing_tables",
    "bit_reversal_permutation",
    "butterfly_counts",
]


def _check_pow2(n: int, axis: Optional[int] = None) -> int:
    """log2(n), or a ValueError that names the offending axis and size.

    The one pow2 error contract for the whole stack: ``repro.xfft`` and
    the engine entries both validate through here, so the message (the
    ISSUE-3 satellite wording) can never drift between layers.
    """
    if n < 2 or (n & (n - 1)) != 0:
        if axis is not None:
            raise ValueError(
                f"axis {axis} has length {n}; xfft requires a power of "
                "two >= 2"
            )
        raise ValueError(f"radix-2 FFT needs a power-of-two length, got {n}")
    return int(math.log2(n))


def canonical_axis(axis: int, ndim: int, name: str = "fft") -> int:
    """Normalize ``axis`` into [0, ndim), naming the axis in the error."""
    if not -ndim <= axis < ndim:
        raise ValueError(
            f"{name}: axis {axis} is out of bounds for an array of "
            f"dimension {ndim}"
        )
    return axis % ndim


@functools.lru_cache(maxsize=64)
def bit_reversal_permutation(n: int) -> np.ndarray:
    """Index permutation that bit-reverses ``n`` positions (DIT input order)."""
    bits = _check_pow2(n)
    idx = np.arange(n, dtype=np.int64)
    rev = np.zeros(n, dtype=np.int64)
    for b in range(bits):
        rev |= ((idx >> b) & 1) << (bits - 1 - b)
    return rev


@functools.lru_cache(maxsize=64)
def fft_routing_tables(n: int):
    """Per-stage routing network + twiddle ROM for the looped engine.

    Returns numpy arrays, all indexed by stage ``s`` (the Stage Bus value):
      idx_a   (L, N/2) int32 — "odd"/top input index of each butterfly unit
      idx_b   (L, N/2) int32 — "even"/bottom input index (= idx_a + half)
      twiddle (L, N/2) c64   — W_m^p per butterfly unit
      unperm  (L, N)   int32 — inverse shuffle: position i of the stage output
                               gathers from concat([top_out, bot_out])[unperm[i]]

    The paper's routing network shuffles register-array contents per stage as
    a function of SB; these tables are that shuffle, precomputed.
    """
    stages = _check_pow2(n)
    half_n = n // 2
    idx_a = np.zeros((stages, half_n), dtype=np.int32)
    idx_b = np.zeros((stages, half_n), dtype=np.int32)
    twiddle = np.zeros((stages, half_n), dtype=np.complex64)
    unperm = np.zeros((stages, n), dtype=np.int32)
    for s in range(stages):
        half = 1 << s          # butterfly span within a block
        m = half * 2           # block size at this stage
        j = 0
        pos_of = np.zeros(n, dtype=np.int32)
        for blk in range(0, n, m):
            for p in range(half):
                a = blk + p
                b = a + half
                idx_a[s, j] = a
                idx_b[s, j] = b
                twiddle[s, j] = np.exp(-2j * np.pi * p / m).astype(np.complex64)
                pos_of[a] = j           # top output j lives at position a
                pos_of[b] = half_n + j  # bottom output j lives at position b
                j += 1
        unperm[s] = pos_of
    return idx_a, idx_b, twiddle, unperm


def butterfly_counts(n: int, proposed: bool) -> dict:
    """Analytic resource counts from the paper (Tables 1 & 2), 1D engine."""
    stages = _check_pow2(n)
    bu = n // 2 if proposed else (n // 2) * stages
    return {
        "butterfly_units": bu,
        "multipliers": bu,
        "adders_subtractors": 2 * bu,
        "stages": stages,
    }


def _stage_tables_device(n: int):
    idx_a, idx_b, tw, unperm = fft_routing_tables(n)
    return (
        jnp.asarray(idx_a),
        jnp.asarray(idx_b),
        jnp.asarray(tw),
        jnp.asarray(unperm),
    )


def _butterfly_stage(x, idx_a, idx_b, tw):
    """One pass through the N/2 butterfly units (paper fig. 6a).

    top = A + W·B ; bot = A − W·B, computed for all N/2 units at once.
    """
    a = jnp.take(x, idx_a, axis=-1)
    b = jnp.take(x, idx_b, axis=-1) * tw
    return a + b, a - b


def _fft_looped(x: jax.Array, n: int) -> jax.Array:
    """Paper-faithful engine: N/2 butterflies reused log2(N) times.

    fori_loop induction variable == Stage Bus; carry == register array.
    """
    stages = _check_pow2(n)
    idx_a, idx_b, tw, unperm = _stage_tables_device(n)
    x = jnp.take(x, jnp.asarray(bit_reversal_permutation(n)), axis=-1)

    def stage_body(s, regs):
        top, bot = _butterfly_stage(regs, idx_a[s], idx_b[s], tw[s])
        merged = jnp.concatenate([top, bot], axis=-1)
        return jnp.take(merged, unperm[s], axis=-1)

    return jax.lax.fori_loop(0, stages, stage_body, x)


def _fft_unrolled(x: jax.Array, n: int) -> jax.Array:
    """Array-architecture baseline: stages laid out in space (Python loop)."""
    stages = _check_pow2(n)
    idx_a, idx_b, tw, unperm = _stage_tables_device(n)
    x = jnp.take(x, jnp.asarray(bit_reversal_permutation(n)), axis=-1)
    for s in range(stages):
        top, bot = _butterfly_stage(x, idx_a[s], idx_b[s], tw[s])
        merged = jnp.concatenate([top, bot], axis=-1)
        x = jnp.take(merged, unperm[s], axis=-1)
    return x


@functools.lru_cache(maxsize=64)
def _stockham_twiddles(n: int):
    """Per-stage twiddles for the Stockham autosort schedule."""
    stages = _check_pow2(n)
    out = []
    for s in range(stages):
        l = 1 << s  # current transform length of each sub-FFT
        k = np.arange(l, dtype=np.float64)
        out.append(np.exp(-2j * np.pi * k / (2 * l)).astype(np.complex64))
    return tuple(out)


def _fft_stockham(x: jax.Array, n: int) -> jax.Array:
    """Stockham autosort: no bit-reversal, contiguous strides (TPU-friendly)."""
    stages = _check_pow2(n)
    tws = _stockham_twiddles(n)
    batch = x.shape[:-1]
    # y has shape (..., r, l): r sub-FFTs each of length l = n/r.
    y = x.reshape(*batch, n, 1)
    for s in range(stages):
        l = 1 << s
        r = n >> (s + 1)  # half the current number of sub-sequences
        tw = jnp.asarray(tws[s])  # (l,)
        y = y.reshape(*batch, 2, r, l)
        a = y[..., 0, :, :]
        b = y[..., 1, :, :] * tw
        y = jnp.concatenate([a + b, a - b], axis=-1)  # (..., r, 2l)
    return y.reshape(*batch, n)


@functools.lru_cache(maxsize=64)
def _radix4_twiddles(n: int):
    """Per-radix-4-stage base twiddles W_{4l}^k (W^2, W^3 are derived)."""
    stages = _check_pow2(n)
    out = []
    l = 2 if stages % 2 else 1
    while l < n:
        k = np.arange(l, dtype=np.float64)
        out.append(np.exp(-2j * np.pi * k / (4 * l)).astype(np.complex64))
        l *= 4
    return tuple(out)


def _fft_radix4(x: jax.Array, n: int) -> jax.Array:
    """Radix-4 Stockham autosort: ceil(log2(N)/2) stages of 4-point
    butterflies — half the stage shuffles and half the twiddle tables of the
    radix-2 schedule (one twiddle-free radix-2 stage when log2(N) is odd)."""
    stages = _check_pow2(n)
    batch = x.shape[:-1]
    y = x.reshape(*batch, n, 1)
    l = 1
    if stages % 2:
        r = n >> 1
        y = y.reshape(*batch, 2, r, 1)
        a = y[..., 0, :, :]
        b = y[..., 1, :, :]
        y = jnp.concatenate([a + b, a - b], axis=-1)
        l = 2
    for w1_np in _radix4_twiddles(n):
        r = n // (4 * l)
        y = y.reshape(*batch, 4, r, l)
        w1 = jnp.asarray(w1_np)
        w2 = w1 * w1
        w3 = w2 * w1
        a0 = y[..., 0, :, :]
        a1 = y[..., 1, :, :] * w1
        a2 = y[..., 2, :, :] * w2
        a3 = y[..., 3, :, :] * w3
        s02, d02 = a0 + a2, a0 - a2
        s13, d13 = a1 + a3, a1 - a3
        # X[k+c'l] = sum_j (-i)^(j c') a_j W^(jk): the ±i are free rotations.
        y = jnp.concatenate(
            [s02 + s13, d02 - 1j * d13, s02 - s13, d02 + 1j * d13], axis=-1
        )
        l *= 4
    return y.reshape(*batch, n)


def fft_impl(x: jax.Array, axis: int = -1, variant: Variant = "auto") -> jax.Array:
    """Radix-2 FFT along ``axis``. Input real or complex; returns complex64.

    This is the engine entry the xfft front door and the planner dispatch
    to; ``variant="auto"`` resolves the schedule through ``repro.plan``
    (cached MEASURE plan if one was tuned for this shape, analytic
    ESTIMATE else, scoped ``repro.xfft.config`` overrides applied).
    """
    orig = x
    x = jnp.asarray(x)
    user_axis = axis
    axis = canonical_axis(axis, x.ndim)
    _check_pow2(x.shape[axis], axis=user_axis)
    if variant == "auto":
        from repro.plan.api import resolve  # lazy: plan imports core

        key_shape = x.shape[:axis] + x.shape[axis + 1:] + (x.shape[axis],)
        variant = resolve("fft1d", key_shape).variant
    if variant not in BUILTIN_VARIANTS:
        # Registry fallback gets the caller's ORIGINAL array: the engine
        # owns every jnp touch (an x64 engine must asarray/moveaxis inside
        # its enable_x64 scope or 64-bit input is truncated to 32).
        from repro.engines import apply_engine

        return apply_engine(variant, "fft1d", orig, axis=axis)
    if x.dtype != jnp.complex64:
        x = x.astype(jnp.complex64)
    if axis != x.ndim - 1:
        x = jnp.moveaxis(x, axis, -1)
    n = x.shape[-1]
    if variant == "looped":
        y = _fft_looped(x, n)
    elif variant == "unrolled":
        y = _fft_unrolled(x, n)
    elif variant == "stockham":
        y = _fft_stockham(x, n)
    elif variant == "radix4":
        y = _fft_radix4(x, n)
    else:  # fused / fused_r4
        from repro.kernels.ops import fft_kernel  # lazy: kernels import core

        y = fft_kernel(x, radix=4 if variant == "fused_r4" else 2)
    if axis != x.ndim - 1:
        y = jnp.moveaxis(y, -1, axis)
    return y


def ifft_impl(x: jax.Array, axis: int = -1, variant: Variant = "auto") -> jax.Array:
    """Inverse FFT via the conjugation identity (shares the forward engine)."""
    orig = x
    x = jnp.asarray(x)
    axis_n = canonical_axis(axis, x.ndim)
    n = x.shape[axis_n]
    if variant == "auto":
        from repro.plan.api import resolve  # lazy: plan imports core

        # Inverse transforms carry their own plan direction so forward
        # tuning never cross-contaminates them. Key on the axis-moved
        # shape (transform axis last), matching the forward convention.
        key_shape = x.shape[:axis_n] + x.shape[axis_n + 1:] + (n,)
        variant = resolve("fft1d", key_shape, direction="inv").variant
    if variant not in BUILTIN_VARIANTS:
        from repro.engines import apply_engine  # lazy: registry fallback

        return apply_engine(variant, "fft1d", orig, direction="inv", axis=axis_n)
    x = x.astype(jnp.complex64)
    return jnp.conj(fft_impl(jnp.conj(x), axis=axis, variant=variant)) / n


def fft(
    x: jax.Array, axis: int = -1, variant: Optional[Variant] = None
) -> jax.Array:
    """Deprecated alias of :func:`repro.xfft.fft` (kept for old call sites).

    The per-call ``variant=`` kwarg is superseded by plan-backed dispatch:
    ``None``/``"auto"`` lets ``repro.plan`` pick; a concrete variant is
    honoured by scoping a ``repro.xfft.config`` override around the call.
    """
    warn_deprecated("repro.core.fft1d.fft", "repro.xfft.fft")
    from repro import xfft  # lazy: xfft builds on this module

    if variant is None or variant == "auto":
        return xfft.fft(x, axis=axis)
    with xfft.config(variant=variant):
        return xfft.fft(x, axis=axis)


def ifft(
    x: jax.Array, axis: int = -1, variant: Optional[Variant] = None
) -> jax.Array:
    """Deprecated alias of :func:`repro.xfft.ifft` (kept for old call sites)."""
    warn_deprecated("repro.core.fft1d.ifft", "repro.xfft.ifft")
    from repro import xfft  # lazy: xfft builds on this module

    if variant is None or variant == "auto":
        return xfft.ifft(x, axis=axis)
    with xfft.config(variant=variant):
        return xfft.ifft(x, axis=axis)
