"""Separable 2D FFT + the paper's ping-pong-buffered streaming processor.

The paper's 2D processor (fig. 3) runs two 1D FFT engines simultaneously:
engine 1 performs row FFTs of frame k into RAM1 while engine 2 reads frame
k−1's row-FFT result from RAM2 and produces the final column-FFT output; a
RAM controller flips ``sel`` when both RAMs fill.

``fft2_stream`` is the JAX dataflow rendition: a ``lax.scan`` whose carry is
"the other RAM" (the previous frame's row-FFT result). Within one scan step
the row-pass of frame k and the column-pass of frame k−1 have no data
dependency, so the XLA scheduler may execute them concurrently — the same
concurrency the two hardware engines provide. The ``sel`` wire disappears:
buffer rotation is the scan carry.
"""

from __future__ import annotations

from typing import Literal, Optional, Union

import jax
import jax.numpy as jnp

from repro.core._deprecation import warn_deprecated
from repro.core.fft1d import BUILTIN_VARIANTS, Variant, fft_impl, ifft_impl

__all__ = ["fft2", "ifft2", "fft2_stream", "fftshift2", "ifftshift2"]


def _resolve_2d(kind: str, shape, variant: Variant, direction: str = "fwd") -> Variant:
    """Map ``variant="auto"`` to a concrete schedule for the whole 2D problem
    (one plan per frame shape, not one per 1D pass)."""
    if variant != "auto":
        return variant
    from repro.plan.api import resolve  # lazy: plan imports core

    return resolve(kind, tuple(shape), direction=direction).variant


def fft2_impl(x: jax.Array, variant: Variant = "auto") -> jax.Array:
    """2D FFT over the last two axes: row pass then column pass (paper fig. 1)."""
    variant = _resolve_2d("fft2d", jnp.shape(x), variant)
    if variant in ("fused", "fused_r4"):
        from repro.kernels.ops import fft2_kernel  # lazy: kernels import core

        # Whole-frame VMEM residency (with built-in failover to an unfused
        # row/turn/column composition when the frame exceeds the budget).
        return fft2_kernel(x, radix=4 if variant == "fused_r4" else 2)
    if variant not in BUILTIN_VARIANTS:
        # The engine owns every jnp touch (see repro.engines.apply_engine).
        from repro.engines import apply_engine

        return apply_engine(variant, "fft2d", x)
    y = fft_impl(x, axis=-1, variant=variant)   # first 1D FFT block (rows)
    return fft_impl(y, axis=-2, variant=variant)  # second 1D FFT block (columns)


def ifft2_impl(x: jax.Array, variant: Variant = "auto") -> jax.Array:
    # Inverse transforms plan under their own direction key ("inv") so
    # forward-tuned wisdom never cross-contaminates them.
    variant = _resolve_2d("fft2d", jnp.shape(x), variant, direction="inv")
    if variant in ("fused", "fused_r4"):
        x = jnp.asarray(x)
        h, w = x.shape[-2], x.shape[-1]
        return jnp.conj(fft2_impl(jnp.conj(x), variant=variant)) / (h * w)
    if variant not in BUILTIN_VARIANTS:
        from repro.engines import apply_engine  # lazy: registry fallback

        return apply_engine(variant, "fft2d", x, direction="inv")
    y = ifft_impl(x, axis=-1, variant=variant)
    return ifft_impl(y, axis=-2, variant=variant)


def fft2(x: jax.Array, variant: Optional[Variant] = None) -> jax.Array:
    """Deprecated alias of :func:`repro.xfft.fft2` (kept for old call sites)."""
    warn_deprecated("repro.core.fft2d.fft2", "repro.xfft.fft2")
    from repro import xfft  # lazy: xfft builds on this module

    if variant is None or variant == "auto":
        return xfft.fft2(x)
    with xfft.config(variant=variant):
        return xfft.fft2(x)


def ifft2(x: jax.Array, variant: Optional[Variant] = None) -> jax.Array:
    """Deprecated alias of :func:`repro.xfft.ifft2` (kept for old call sites)."""
    warn_deprecated("repro.core.fft2d.ifft2", "repro.xfft.ifft2")
    from repro import xfft  # lazy: xfft builds on this module

    if variant is None or variant == "auto":
        return xfft.ifft2(x)
    with xfft.config(variant=variant):
        return xfft.ifft2(x)


def fftshift2(x: jax.Array) -> jax.Array:
    """Centre the zero-frequency bin (for correlation/holography demos)."""
    return jnp.roll(x, shift=(x.shape[-2] // 2, x.shape[-1] // 2), axis=(-2, -1))


def ifftshift2(x: jax.Array) -> jax.Array:
    """Exact inverse of :func:`fftshift2`.

    Rolls by the *negated* half sizes: for even H/W that equals another
    ``fftshift2``, but for odd lengths (framing code pads to arbitrary
    sizes even though the engines are pow2-only) the half-size roll is not
    self-inverse and the sign matters.
    """
    return jnp.roll(
        x, shift=(-(x.shape[-2] // 2), -(x.shape[-1] // 2)), axis=(-2, -1)
    )


def fft2_stream(
    frames: jax.Array,
    variant: Variant = "auto",
    unroll: Union[int, Literal["auto"]] = 1,
) -> jax.Array:
    """Streaming 2D FFT over ``frames[t, H, W]`` with ping-pong double buffering.

    Frame t's row pass and frame t−1's column pass execute in the same scan
    step (two concurrent engines). Output t is the 2D FFT of frame t — the
    one-frame pipeline latency is internal: a zero frame is fed through to
    drain the pipe, matching the hardware's drain cycle.

    ``variant="auto"`` / ``unroll="auto"`` resolve through ``repro.plan``
    with the stream's own problem key (the scan unroll is part of the plan).
    """
    if frames.ndim < 3:
        raise ValueError("fft2_stream expects (T, H, W) or (T, ..., H, W)")
    if variant == "auto" or unroll == "auto":
        from repro.plan.api import resolve  # lazy: plan imports core

        plan = resolve("fft2d_stream", tuple(frames.shape))
        if variant == "auto":
            variant = plan.variant
        if unroll == "auto":
            unroll = plan.unroll
    if variant not in BUILTIN_VARIANTS:
        # A registered engine (e.g. reference_x64) runs its own stream op
        # — the scan carry must share the engine's compute dtype.
        from repro.engines import apply_engine

        return apply_engine(variant, "fft2d_stream", frames)
    if not jnp.issubdtype(frames.dtype, jnp.complexfloating):
        frames = frames.astype(jnp.complex64)

    def step(ram, frame):
        # Engine 1: row FFTs of the incoming frame -> the "write" RAM.
        row_done = fft_impl(frame, axis=-1, variant=variant)
        # Engine 2 (concurrent): column FFTs of the previous frame's rows.
        out = fft_impl(ram, axis=-2, variant=variant)
        return row_done, out

    drain = jnp.zeros_like(frames[:1])
    stream = jnp.concatenate([frames, drain], axis=0)
    init_ram = jnp.zeros_like(frames[0])
    _, outs = jax.lax.scan(step, init_ram, stream, unroll=unroll)
    return outs[1:]  # drop the pipeline-fill output
