"""Warn-once plumbing for the deprecated ``repro.core`` transform entry
points. The implementations live on (as the ``*_impl`` functions the xfft
front door and the planner dispatch to); only the public per-call
``variant=`` surface is deprecated in favour of ``repro.xfft``.
"""

from __future__ import annotations

import warnings
from typing import Set

_WARNED: Set[str] = set()


def warn_deprecated(old: str, new: str, *, stacklevel: int = 3) -> None:
    """Emit one DeprecationWarning per entry point per process."""
    if old in _WARNED:
        return
    _WARNED.add(old)
    warnings.warn(
        f"{old} is deprecated; call {new} instead (engine selection now "
        "lives in repro.plan / repro.xfft.config, not per-call kwargs)",
        DeprecationWarning,
        stacklevel=stacklevel,
    )


def reset_warnings() -> None:
    """Forget which warnings fired (tests)."""
    _WARNED.clear()
