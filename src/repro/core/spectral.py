"""LM-facing applications of the paper's FFT engine.

* ``fourier_mixing`` — FNet-style token mixing: Re(FFT2(x)) over (seq, d).
  FNet's mixing sublayer *is* a 2D Fourier transform, so the paper's
  area-efficient 2D engine drops in as the mixing layer of a trainable LM
  (``configs/fourier_lm.py``, the paper's technique as an LM architecture).
* ``fftconv`` — long convolution via the engine (Hyena-style), the opt-in
  spectral primitive offered to the SSM/hybrid archs.
* ``stft`` / ``log_mel`` — a real spectrogram frontend for the audio arch
  (the assignment mandates a stub frontend; this is the optional real one,
  and it is itself a direct application of the paper: a streamed bank of
  1D FFTs).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fft1d import Variant, fft_impl, ifft_impl
from repro.core.fft2d import fft2_impl, ifft2_impl
from repro.core.rfft import irfft2_impl, irfft_impl, rfft2_impl, rfft_impl

__all__ = ["fourier_mixing", "fftconv", "correlate2", "stft", "log_mel"]


def _is_real(x) -> bool:
    return not jnp.issubdtype(jnp.asarray(x).dtype, jnp.complexfloating)


def fourier_mixing(x: jax.Array, variant: str = "auto") -> jax.Array:
    """FNet mixing sublayer: real part of the 2D FFT over (seq, hidden).

    x: (..., seq, d) real. Both dims must be powers of two (pad upstream).
    variant="rfft" uses the real-input specialisation (beyond-paper
    optimization, §Perf cell C): ~2× fewer FLOPs/bytes via conjugate
    symmetry.
    """
    if variant == "rfft":
        return fourier_mixing_rfft(x)
    return jnp.real(fft2_impl(x.astype(jnp.complex64), variant=variant)).astype(x.dtype)


def rfft_last_axis(x: jax.Array, variant: Variant = "auto") -> jax.Array:
    """Real-input FFT along the last axis via the packed half-length trick:
    one complex FFT of length D/2 + O(D) untangling, instead of length D.
    Returns the non-redundant half spectrum (..., D//2 + 1).

    Thin alias of :func:`repro.core.rfft.rfft` (kept for back-compat)."""
    return rfft_impl(x, axis=-1, variant=variant)


def fourier_mixing_rfft(x: jax.Array, variant: Variant = "auto") -> jax.Array:
    """Re(FFT_seq(FFT_d(x))) for real x, computing only the non-redundant
    half of the d-spectrum and mirroring the real part back:

      Re(Y)[s, k] = Re(Y)[(S−s) mod S, D−k]   for k > D/2
    """
    s, d = x.shape[-2], x.shape[-1]
    xh = rfft_last_axis(x, variant=variant)        # (..., S, D/2+1)
    yh = fft_impl(xh, axis=-2, variant=variant)         # seq-axis complex FFT
    re = jnp.real(yh)
    s_mirror = (-jnp.arange(s)) % s
    tail_k = jnp.arange(d // 2 - 1, 0, -1)         # D−k for k = D/2+1 .. D−1
    tail = jnp.take(jnp.take(re, s_mirror, axis=-2), tail_k, axis=-1)
    return jnp.concatenate([re, tail], axis=-1).astype(x.dtype)


def _next_pow2(n: int) -> int:
    """Power-of-two cover of ``n``, floored at 2 (the engines' minimum
    transform length). Shared by fftconv, the imaging tiled-convolution
    padding and the planner's oaconv2d tile sweep."""
    return max(2, 1 << max(int(n) - 1, 0).bit_length())


def fftconv(x: jax.Array, kernel: jax.Array, variant: Variant = "auto") -> jax.Array:
    """Causal long convolution y[t] = sum_s k[s]·x[t−s] via the FFT engine.

    x: (..., seq, d); kernel: (seq_k, d) with seq_k <= seq. O(L log L) versus
    the O(L²) direct form — the spectral primitive for SSM/hybrid archs.
    Real inputs (the usual case) take the two-for-one ``rfft``/``irfft``
    path: half-size transforms over the non-redundant half spectrum.
    """
    seq = x.shape[-2]
    n = _next_pow2(2 * seq)  # zero-pad to avoid circular wrap
    xt = jnp.swapaxes(x, -1, -2)                      # (..., d, seq)
    kt = jnp.swapaxes(kernel, -1, -2)                 # (d, seq_k)
    xp = jnp.pad(xt, [(0, 0)] * (xt.ndim - 1) + [(0, n - seq)])
    kp = jnp.pad(kt, [(0, 0)] * (kt.ndim - 1) + [(0, n - kt.shape[-1])])
    if _is_real(x) and _is_real(kernel):
        y = irfft_impl(
            rfft_impl(xp, variant=variant) * rfft_impl(kp, variant=variant),
            variant=variant,
        )[..., :seq]
        return jnp.swapaxes(y, -1, -2).astype(x.dtype)
    y = ifft_impl(
        fft_impl(xp, variant=variant) * fft_impl(kp, variant=variant),
        variant=variant,
    )[..., :seq]
    return jnp.swapaxes(jnp.real(y), -1, -2).astype(x.dtype)


def correlate2(scene: jax.Array, template: jax.Array,
               variant: Variant = "auto") -> jax.Array:
    """Matched-filter cross-correlation entirely in the Fourier domain:

        corr = IFFT2( FFT2(scene) · conj(FFT2(template)) )

    — the paper's correlation-pattern-recognition application. Real inputs
    (camera frames, templates) take the two-for-one ``rfft2``/``irfft2``
    path: the conjugate-symmetric half spectrum carries all the
    information, so the whole pipeline runs at half the arithmetic and
    HBM traffic of the complex transform.
    """
    if _is_real(scene) and _is_real(template):
        fs = rfft2_impl(scene, variant=variant)
        ft = rfft2_impl(template, variant=variant)
        return irfft2_impl(fs * jnp.conj(ft), variant=variant)
    fs = fft2_impl(jnp.asarray(scene).astype(jnp.complex64), variant=variant)
    ft = fft2_impl(jnp.asarray(template).astype(jnp.complex64), variant=variant)
    return jnp.real(ifft2_impl(fs * jnp.conj(ft), variant=variant))


@functools.lru_cache(maxsize=8)
def _hann(n: int) -> np.ndarray:
    return (0.5 - 0.5 * np.cos(2 * np.pi * np.arange(n) / n)).astype(np.float32)


def stft(
    audio: jax.Array,
    frame: int = 512,
    hop: int = 256,
    variant: Variant = "auto",
) -> jax.Array:
    """Short-time Fourier transform: (..., T) -> (..., frames, frame//2+1)."""
    t = audio.shape[-1]
    n_frames = 1 + (t - frame) // hop
    idx = np.arange(frame)[None, :] + hop * np.arange(n_frames)[:, None]
    windows = audio[..., idx] * jnp.asarray(_hann(frame))
    spec = fft_impl(windows.astype(jnp.complex64), variant=variant)
    return spec[..., : frame // 2 + 1]


@functools.lru_cache(maxsize=8)
def _mel_filterbank(n_fft_bins: int, n_mels: int, sr: float = 16000.0) -> np.ndarray:
    """Triangular mel filterbank (slaney-style, simplified)."""
    def hz_to_mel(f):
        return 2595.0 * np.log10(1.0 + f / 700.0)

    def mel_to_hz(m):
        return 700.0 * (10.0 ** (m / 2595.0) - 1.0)

    mel_pts = np.linspace(hz_to_mel(0.0), hz_to_mel(sr / 2), n_mels + 2)
    hz_pts = mel_to_hz(mel_pts)
    bins = np.floor((n_fft_bins - 1) * 2 * hz_pts / sr).astype(int)
    bins = np.clip(bins, 0, n_fft_bins - 1)
    fb = np.zeros((n_mels, n_fft_bins), dtype=np.float32)
    for m in range(1, n_mels + 1):
        lo, c, hi = bins[m - 1], bins[m], bins[m + 1]
        if c > lo:
            fb[m - 1, lo:c] = (np.arange(lo, c) - lo) / (c - lo)
        if hi > c:
            fb[m - 1, c:hi] = (hi - np.arange(c, hi)) / (hi - c)
    return fb


def log_mel(
    audio: jax.Array,
    frame: int = 512,
    hop: int = 256,
    n_mels: int = 80,
    variant: Variant = "auto",
) -> jax.Array:
    """Whisper-style log-mel spectrogram built on the paper's engine."""
    spec = stft(audio, frame=frame, hop=hop, variant=variant)
    power = jnp.abs(spec) ** 2
    fb = jnp.asarray(_mel_filterbank(frame // 2 + 1, n_mels))
    mel = jnp.einsum("...tf,mf->...tm", power, fb)
    return jnp.log10(jnp.maximum(mel, 1e-10))
