"""Pencil-decomposed multi-device 2D FFT under ``shard_map``.

The paper's two 1D engines + ping-pong RAM become, on a TPU mesh:

  local row FFTs  →  all_to_all "corner-turn" transpose  →  local column FFTs

The all_to_all is the distributed analogue of the RAM1/RAM2 handoff: it is
the only inter-engine communication, and the chunked variant overlaps it with
butterfly compute the same way the hardware overlaps engine 1's writes with
engine 2's reads.

Layouts (for a 1D device axis of size d):
  input   x:  rows sharded    — global (H, W), per-device (H/d, W)
  output  y:  columns sharded — global (H, W), per-device (H, W/d)
"""

from __future__ import annotations

import functools
from typing import Literal, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core.fft1d import Variant, fft_impl

__all__ = ["fft2_pencil", "fft2_pencil_overlapped", "pencil_sharding"]


def pencil_sharding(mesh: Mesh, axis: str, stage: Literal["rows", "cols"]):
    """NamedSharding for the pencil layouts (batch dims replicated)."""
    if stage == "rows":
        return NamedSharding(mesh, P(axis, None))
    return NamedSharding(mesh, P(None, axis))


def _corner_turn(block: jax.Array, axis_name: str, d: int) -> jax.Array:
    """all_to_all transpose: (H/d, W) row-pencils -> (H, W/d) column-pencils."""
    h_loc, w = block.shape[-2], block.shape[-1]
    lead = block.shape[:-2]
    # Split the row-FFT result into d column chunks and exchange them.
    blk = block.reshape(*lead, h_loc, d, w // d)
    blk = jnp.moveaxis(blk, -2, 0)  # (d, ..., H/d, W/d)
    blk = jax.lax.all_to_all(blk, axis_name, split_axis=0, concat_axis=0, tiled=False)
    # (d, ..., H/d, W/d): leading dim now indexes the source device = row block.
    blk = jnp.moveaxis(blk, 0, -3)  # (..., d, H/d, W/d)
    return blk.reshape(*lead, h_loc * d, w // d)


def fft2_pencil(
    x: jax.Array,
    mesh: Mesh,
    axis: str = "data",
    variant: Variant = "looped",
) -> jax.Array:
    """Distributed 2D FFT. ``x`` global (..., H, W) sharded (axis, None)."""
    d = mesh.shape[axis]
    if variant == "auto":
        from repro.plan.api import resolve  # lazy: plan imports core

        variant = resolve("fft2d_pencil", tuple(x.shape), n_devices=d).variant
    ndim = jnp.ndim(x)
    lead = (None,) * (ndim - 2)

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=P(*lead, axis, None),
        out_specs=P(*lead, None, axis),
    )
    def _run(block):
        rows = fft_impl(block, axis=-1, variant=variant)       # engine 1 (local)
        turned = _corner_turn(rows, axis, d)              # RAM handoff
        return fft_impl(turned, axis=-2, variant=variant)      # engine 2 (local)

    return _run(x.astype(jnp.complex64))


def fft2_pencil_overlapped(
    x: jax.Array,
    mesh: Mesh,
    axis: str = "data",
    variant: Variant = "looped",
    chunks: Union[int, Literal["auto"]] = "auto",
) -> jax.Array:
    """Chunked pencil FFT overlapping the corner-turn with column compute.

    The W axis is split into ``chunks`` slabs; slab i's all_to_all has no
    data dependency on slab i−1's column FFT, so the scheduler can overlap
    collective i with compute i−1 — the ping-pong insight applied to the
    collective itself (beyond-paper optimization, see EXPERIMENTS.md §Perf).

    ``chunks="auto"`` (default) and ``variant="auto"`` take their values
    from the ``repro.plan`` plan for this ``(shape, n_devices)`` problem.
    """
    d = mesh.shape[axis]
    if variant == "auto" or chunks == "auto":
        from repro.plan.api import resolve  # lazy: plan imports core

        plan = resolve("fft2d_pencil", tuple(x.shape), n_devices=d)
        if variant == "auto":
            variant = plan.variant
        if chunks == "auto":
            chunks = plan.chunks
    ndim = jnp.ndim(x)
    lead = (None,) * (ndim - 2)
    h, w = x.shape[-2], x.shape[-1]
    slab_w = w // chunks

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=P(*lead, axis, None),
        # (..., H, chunks, slab_w/d): slab index is a real axis so each slab's
        # device-sharded columns stay contiguous in the global result.
        out_specs=P(*lead, None, None, axis),
    )
    def _run(block):
        rows = fft_impl(block, axis=-1, variant=variant)
        outs = []
        for c in range(chunks):
            slab = jax.lax.slice_in_dim(rows, c * slab_w, (c + 1) * slab_w, axis=-1)
            turned = _corner_turn(slab, axis, d)          # (..., H, slab_w/d)
            outs.append(fft_impl(turned, axis=-2, variant=variant))
        return jnp.stack(outs, axis=-2)                   # (..., H, chunks, slab_w/d)

    y = _run(x.astype(jnp.complex64))
    return y.reshape(*x.shape[:-2], h, chunks * slab_w)
