"""Wisdom artifacts: ship pre-tuned plan caches with the repo (FFTW model).

MEASURE tuning jits and times every candidate engine — seconds per
problem key. A fleet of servers must not pay that per process: FFTW
solved this with *wisdom files* exported once and imported everywhere,
and this module is that model for ``repro.plan``:

* :func:`export` writes the active plan cache's MEASURE entries to a
  wisdom artifact (atomic, via :meth:`PlanCache.save`);
* :func:`warm_start` merges an artifact into a fresh process's cache —
  with the full :class:`~repro.plan.cache.LoadReport` accounting, so
  "the artifact actually loaded" is a checkable fact, not hope;
* :func:`pretune` runs the MEASURE sweeps that *produce* wisdom for a
  list of frame sizes (the generation side of the artifact);
* :data:`WISDOM_DIR` holds artifacts packaged with the repo itself
  (``wisdom_files/<backend>.json``): a warm-started serve loop performs
  **zero** MEASURE sweeps from its first request — the serve benchmark
  proves this from the event stream.

Plan cache keys embed backend × device-kind × precision
(``PLAN_SCHEMA_VERSION``), so an artifact tuned on one engine population
can never poison another: foreign entries simply never match, and stale
schema versions are dropped (and counted) at load.

Regenerating the packaged artifact (from the repo root)::

    PYTHONPATH=src python -m repro.serve.wisdom --sizes 64,128,256

writes ``src/repro/serve/wisdom_files/<backend>.json`` for the machine's
default backend.
"""

from __future__ import annotations

import os
from typing import Optional, Sequence, Tuple

from repro import obs
from repro.plan.cache import LoadReport, PlanCache, default_cache

__all__ = [
    "WISDOM_DIR",
    "artifact_path",
    "export",
    "pretune",
    "warm_start",
]

#: Directory of wisdom artifacts packaged with the repo, one per backend
#: (named ``<backend>.json`` after ``jax.default_backend()``).
WISDOM_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)), "wisdom_files")


def _active_cache() -> PlanCache:
    """The cache the current scope plans against: a scoped ``cache_dir``'s
    file-backed cache when one is configured, else the process default."""
    from repro.plan.api import _cache_for_dir
    from repro.xfft import get_config

    cfg = get_config()
    if cfg.cache_dir:
        return _cache_for_dir(cfg.cache_dir)
    return default_cache()


def artifact_path(backend: Optional[str] = None) -> Optional[str]:
    """Path of the packaged artifact for ``backend`` (default: the live
    jax backend), or ``None`` when no artifact ships for it."""
    if backend is None:
        import jax

        backend = jax.default_backend()
    path = os.path.join(WISDOM_DIR, f"{backend}.json")
    return path if os.path.exists(path) else None


def export(
    path: str,
    cache: Optional[PlanCache] = None,
    *,
    measured_only: bool = True,
    stale_loss_threshold: Optional[int] = 3,
) -> str:
    """Write ``cache`` (default: the active scope's cache) to ``path``.

    Only MEASURE entries ship by default — ESTIMATE plans cost nothing to
    recreate and would pin one machine's heuristics on another. Raises
    ``RuntimeError`` when the path is unwritable (an *export* that lands
    nowhere is an error; the serve path's degrade-to-memory behaviour
    lives in :meth:`PlanCache.save` and still applies there).

    **Staleness aging**: warm-started artifact entries that lost to a
    live MEASURE re-tune ``stale_loss_threshold`` or more consecutive
    times (the ``serve.wisdom.stale`` accounting on
    :attr:`PlanCache.stale_losses`) are dropped from the written artifact
    — wisdom the fleet keeps outvoting stops shipping. ``None`` disables
    aging (export everything regardless of losses).
    """
    cache = cache if cache is not None else _active_cache()
    stale = (
        tuple(
            k for k, losses in cache.stale_losses.items()
            if losses >= stale_loss_threshold
        )
        if stale_loss_threshold is not None else ()
    )
    written = cache.save(path, measured_only=measured_only, exclude=stale)
    if written is None:
        raise RuntimeError(
            f"wisdom export to {path!r} failed: path is unwritable "
            f"(see the plan.cache.readonly event for the cause)"
        )
    obs.emit(
        "serve.wisdom.export",
        path=written,
        entries=len(cache),
        measured_only=measured_only,
        dropped_stale=len(stale),
    )
    return written


def warm_start(
    path: Optional[str] = None, cache: Optional[PlanCache] = None
) -> LoadReport:
    """Merge a wisdom artifact into ``cache`` (default: the active cache).

    ``path=None`` uses the packaged artifact for the live backend — the
    zero-config fleet case: call once at startup and every MEASURE-grade
    plan in the artifact serves without a single sweep. Returns the
    :class:`LoadReport`; a missing packaged artifact is not an error
    (``file_error`` says so) because a fresh process can always fall back
    to tuning itself.
    """
    cache = cache if cache is not None else _active_cache()
    if path is None:
        path = artifact_path()
    if path is None:
        report = LoadReport(file_error="no packaged wisdom artifact for backend")
    else:
        report = cache.load(path)
    obs.emit(
        "serve.wisdom.warm_start",
        path=path,
        kept=report.kept,
        dropped=report.dropped,
        file_error=report.file_error,
    )
    return report


def pretune(
    sizes: Sequence[int],
    kinds: Tuple[str, ...] = ("rfft2d", "fft2d"),
    directions: Tuple[str, ...] = ("fwd",),
    cache: Optional[PlanCache] = None,
    measure_iters: int = 3,
) -> PlanCache:
    """Run the MEASURE sweeps that produce wisdom for square frames.

    The generation side of an artifact: tunes ``kind × direction`` for
    every ``N × N`` size into ``cache`` (default: a fresh in-memory
    cache, so packaged artifacts contain exactly what was asked for).
    """
    from repro.plan import plan_fft

    cache = cache if cache is not None else PlanCache()
    for n in sizes:
        for kind in kinds:
            dtype = "float32" if kind.startswith("r") else "complex64"
            for direction in directions:
                plan_fft(
                    kind,
                    (int(n), int(n)),
                    dtype=dtype,
                    mode="measure",
                    cache=cache,
                    direction=direction,
                    measure_iters=measure_iters,
                )
    return cache


def _main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse

    import jax

    ap = argparse.ArgumentParser(
        description="Generate a packaged wisdom artifact (MEASURE sweeps)."
    )
    ap.add_argument("--sizes", default="64,128,256",
                    help="comma-separated square frame sizes")
    ap.add_argument("--kinds", default="rfft2d,fft2d")
    ap.add_argument("--out", default=None,
                    help="output path (default: wisdom_files/<backend>.json)")
    args = ap.parse_args(argv)
    sizes = [int(s) for s in args.sizes.split(",") if s]
    kinds = tuple(k for k in args.kinds.split(",") if k)
    out = args.out or os.path.join(WISDOM_DIR, f"{jax.default_backend()}.json")
    cache = pretune(sizes, kinds=kinds)
    written = export(out, cache)
    print(f"wrote {len(cache)} measured plans to {written}")
    return 0


if __name__ == "__main__":
    raise SystemExit(_main())
