"""Imaging request serving: registration + tiled convolution, batched.

:class:`ImagingService` extends :class:`SpectrumService` from bare
transforms to the ``repro.imaging`` operator set, with the same serving
policy: group requests by PROBLEM KEY, resolve one plan per group
through ``repro.plan``, and run each group as a single batched call.

* registration requests group by (frame shape, realness, upsample
  factor): one ``rfft2``/``irfft2`` round trip registers the whole
  group, one plan cache entry serves every future batch of that shape;
* convolution requests group by (image shape, kernel shape, mode,
  realness): the group shares one ``oaconv2d`` plan — i.e. one
  overlap-save tile — and the per-request kernels ride the batched
  leading axis of :func:`repro.imaging.tiled.oaconvolve2`;
* plain :class:`SpectrumRequest` frames still work; a mixed queue is
  partitioned and each family served by its own grouping.

Like the parent, the service honours scoped :func:`repro.xfft.config`
overrides unless the constructor pinned ``plan_mode``.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.resilience.policies import admit, execute_with_policy
from repro.serve.engine import SpectrumRequest, SpectrumService

__all__ = ["RegistrationRequest", "ConvolutionRequest", "ImagingService"]


@dataclasses.dataclass
class RegistrationRequest:
    """Estimate the translation registering ``mov`` onto ``ref``."""

    ref: np.ndarray                         # (H, W) real or complex
    mov: np.ndarray                         # (H, W), same shape/realness
    upsample: int = 1                       # >1 -> subpixel refinement
    shift: np.ndarray | None = None         # filled by serve: (2,) float32
    done: bool = False


@dataclasses.dataclass
class ConvolutionRequest:
    """Convolve ``image`` with ``kernel`` (overlap-save, plan-tiled)."""

    image: np.ndarray                       # (H, W) real or complex
    kernel: np.ndarray                      # (KH, KW)
    mode: str = "same"                      # "full" | "same" | "valid"
    out: np.ndarray | None = None           # filled by serve
    done: bool = False


class ImagingService(SpectrumService):
    """Plan-aware batched serving for spectra, registration and convolution."""

    def serve(self, requests: list) -> list:
        """Process a mixed request queue in-place; returns the same list.

        The whole queue is partitioned AND shape-validated before any
        group executes, so an invalid request fails the call without
        leaving the queue half-served — and admission control runs on the
        FULL mixed queue, so an overloaded service sheds before any
        family is touched.
        """
        admit(self.policy, len(requests), service="imaging")
        spectra, registrations, convolutions = [], [], []
        for i, r in enumerate(requests):
            if isinstance(r, SpectrumRequest):
                spectra.append(r)
            elif isinstance(r, RegistrationRequest):
                ref, mov = np.asarray(r.ref), np.asarray(r.mov)
                if ref.ndim != 2 or ref.shape != mov.shape:
                    raise ValueError(
                        f"request {i}: ref/mov must be matching (H, W) "
                        f"frames, got {ref.shape} vs {mov.shape}"
                    )
                registrations.append(r)
            elif isinstance(r, ConvolutionRequest):
                image, kernel = np.asarray(r.image), np.asarray(r.kernel)
                if image.ndim != 2 or kernel.ndim != 2:
                    raise ValueError(
                        f"request {i}: image and kernel must be 2D, got "
                        f"{image.shape} and {kernel.shape}"
                    )
                if r.mode not in ("full", "same", "valid"):
                    raise ValueError(
                        f'request {i}: mode must be "full", "same" or '
                        f'"valid", got {r.mode!r}'
                    )
                if r.mode == "valid" and (
                    kernel.shape[0] > image.shape[0]
                    or kernel.shape[1] > image.shape[1]
                ):
                    raise ValueError(
                        f"request {i}: valid-mode convolution needs "
                        f"kernel <= image, got {kernel.shape} vs {image.shape}"
                    )
                convolutions.append(r)
            else:
                raise TypeError(
                    f"request {i}: expected SpectrumRequest, "
                    f"RegistrationRequest or ConvolutionRequest, got {type(r)!r}"
                )
        obs.emit(
            "serve.queue",
            service="imaging",
            depth=len(requests),
            spectra=len(spectra),
            registrations=len(registrations),
            convolutions=len(convolutions),
        )
        if spectra:
            super().serve(spectra)
        if registrations:
            self._serve_registrations(registrations)
        if convolutions:
            self._serve_convolutions(convolutions)
        return requests

    # ------------------------------ groups ------------------------------

    def _serve_registrations(self, items) -> None:
        from repro.imaging import register_phase_correlation

        groups: dict = {}
        for r in items:
            ref = np.asarray(r.ref)
            real = not (
                np.iscomplexobj(ref) or np.iscomplexobj(np.asarray(r.mov))
            )
            groups.setdefault((ref.shape, real, int(r.upsample)), []).append(r)
        for (shape, real, upsample), members in groups.items():
            # Warm the plan for the BATCHED problem the group's transform
            # pair will actually run under ((B, H, W) — xfft keys on the
            # full shape), so a repeat batch of this shape and size is a
            # pure cache hit inside register_phase_correlation.
            self._plan_for(
                "rfft2d" if real else "fft2d",
                (len(members), *shape),
                "float32" if real else "complex64",
            )
            refs = jnp.asarray(np.stack([np.asarray(r.ref) for r in members]))
            movs = jnp.asarray(np.stack([np.asarray(r.mov) for r in members]))
            with obs.span(
                "serve.batch", service="registration", shape=shape,
                batch=len(members), upsample=upsample,
            ):
                shifts = np.asarray(execute_with_policy(
                    self.policy,
                    lambda: register_phase_correlation(
                        refs, movs, upsample_factor=upsample
                    ),
                    service="registration",
                ))
            for r, shift in zip(members, shifts):
                r.shift = shift
                r.done = True

    def _serve_convolutions(self, items) -> None:
        from repro.imaging import oaconvolve2

        groups: dict = {}
        for r in items:
            image = np.asarray(r.image)
            real = not (
                np.iscomplexobj(image) or np.iscomplexobj(np.asarray(r.kernel))
            )
            groups.setdefault(
                (image.shape, np.asarray(r.kernel).shape, r.mode, real), []
            ).append(r)
        for (ishape, kshape, mode, real), members in groups.items():
            # One oaconv2d plan per (image, kernel) geometry: every member
            # shares the tile, kernels ride the batched leading axis.
            plan = self._plan_for(
                "oaconv2d",
                (*ishape, *kshape),
                "float32" if real else "complex64",
            )
            images = jnp.asarray(np.stack([np.asarray(r.image) for r in members]))
            kernels = jnp.asarray(np.stack([np.asarray(r.kernel) for r in members]))
            with obs.span(
                "serve.batch", service="convolution", shape=ishape,
                kernel=kshape, batch=len(members), tile=plan.tile,
            ):
                out = np.asarray(execute_with_policy(
                    self.policy,
                    lambda: oaconvolve2(images, kernels, mode=mode, tile=plan.tile),
                    service="convolution",
                ))
            for r, res in zip(members, out):
                r.out = res
                r.done = True
