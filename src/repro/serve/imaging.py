"""Imaging request serving: registration + tiled convolution, batched.

:class:`ImagingService` extends :class:`SpectrumService` from bare
transforms to the ``repro.imaging`` operator set, with the same serving
policy: classify requests into PROBLEM-KEY lanes, resolve one plan per
lane through ``repro.plan``, and run each lane batch as a single call —
all on the shared :class:`repro.serve.loop.ServeLoop`.

* registration requests lane by (frame shape, realness, upsample
  factor): one ``rfft2``/``irfft2`` round trip registers the whole
  batch, one plan cache entry serves every future batch of that shape;
* convolution requests lane by (image shape, kernel shape, mode,
  realness): the lane shares one ``oaconv2d`` plan — i.e. one
  overlap-save tile — and the per-request kernels ride the batched
  leading axis of :func:`repro.imaging.tiled.oaconvolve2`;
* reconstruction requests (:class:`ReconRequest`) lane by (frame
  shape, coil count, acceleration, CG iterations, Tikhonov weight,
  precision): the lane stacks every member's k-space/maps/mask and
  runs ONE batched CG-SENSE solve — tens of planned centered
  transforms over two problem keys, all coalesced under one plan;
* plain :class:`SpectrumRequest` frames still work; a mixed queue is
  partitioned into lanes and each family served by its own executor —
  and under the streaming entry (``svc.loop.submit``) the four
  families coalesce and round-robin through ONE scheduler.

Like the parent, the service honours scoped :func:`repro.xfft.config`
overrides unless the constructor pinned ``plan_mode``.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.resilience.policies import execute_with_policy
from repro.serve.engine import SpectrumRequest, SpectrumService
from repro.serve.loop import LaneKey

__all__ = [
    "RegistrationRequest",
    "ConvolutionRequest",
    "ReconRequest",
    "ImagingService",
]


@dataclasses.dataclass
class RegistrationRequest:
    """Estimate the translation registering ``mov`` onto ``ref``."""

    ref: np.ndarray                         # (H, W) real or complex
    mov: np.ndarray                         # (H, W), same shape/realness
    upsample: int = 1                       # >1 -> subpixel refinement
    shift: np.ndarray | None = None         # filled by serve: (2,) float32
    done: bool = False


@dataclasses.dataclass
class ConvolutionRequest:
    """Convolve ``image`` with ``kernel`` (overlap-save, plan-tiled)."""

    image: np.ndarray                       # (H, W) real or complex
    kernel: np.ndarray                      # (KH, KW)
    mode: str = "same"                      # "full" | "same" | "valid"
    out: np.ndarray | None = None           # filled by serve
    done: bool = False


@dataclasses.dataclass
class ReconRequest:
    """CG-SENSE reconstruct undersampled multi-coil k-space to an image."""

    kspace: np.ndarray                      # (C, H, W) complex, centered
    smaps: np.ndarray                       # (C, H, W) coil sensitivities
    mask: np.ndarray                        # (H, W) sampling mask
    iters: int = 10                         # CG iterations
    lam: float = 0.0                        # Tikhonov weight
    image: np.ndarray | None = None         # filled by serve: (H, W) complex
    done: bool = False


class ImagingService(SpectrumService):
    """Plan-aware batched serving for spectra, registration, convolution
    and MRI reconstruction.

    One loop, four request families: classification is the only
    family-specific intake code, so validation stays all-or-nothing (a
    bad request anywhere in a call fails the call before any lane runs)
    and admission control sheds the FULL mixed queue before any family
    is touched.
    """

    name = "imaging"

    # --------------------------- lane machinery ---------------------------

    def _classify(self, r) -> LaneKey:
        if isinstance(r, SpectrumRequest):
            return super()._classify(r)
        if isinstance(r, RegistrationRequest):
            ref, mov = np.asarray(r.ref), np.asarray(r.mov)
            if ref.ndim != 2 or ref.shape != mov.shape:
                raise ValueError(
                    f"ref/mov must be matching (H, W) "
                    f"frames, got {ref.shape} vs {mov.shape}"
                )
            real = not (np.iscomplexobj(ref) or np.iscomplexobj(mov))
            return LaneKey("registration", (ref.shape, real, int(r.upsample)))
        if isinstance(r, ConvolutionRequest):
            image, kernel = np.asarray(r.image), np.asarray(r.kernel)
            if image.ndim != 2 or kernel.ndim != 2:
                raise ValueError(
                    f"image and kernel must be 2D, got "
                    f"{image.shape} and {kernel.shape}"
                )
            if r.mode not in ("full", "same", "valid"):
                raise ValueError(
                    f'mode must be "full", "same" or '
                    f'"valid", got {r.mode!r}'
                )
            if r.mode == "valid" and (
                kernel.shape[0] > image.shape[0]
                or kernel.shape[1] > image.shape[1]
            ):
                raise ValueError(
                    f"valid-mode convolution needs "
                    f"kernel <= image, got {kernel.shape} vs {image.shape}"
                )
            real = not (np.iscomplexobj(image) or np.iscomplexobj(kernel))
            return LaneKey(
                "convolution", (image.shape, kernel.shape, r.mode, real)
            )
        if isinstance(r, ReconRequest):
            from repro.mri import acceleration
            from repro.xfft import get_config

            kspace = np.asarray(r.kspace)
            smaps = np.asarray(r.smaps)
            mask = np.asarray(r.mask)
            if kspace.ndim != 3 or kspace.shape != smaps.shape:
                raise ValueError(
                    f"kspace and smaps must be matching (C, H, W) stacks, "
                    f"got {kspace.shape} vs {smaps.shape}"
                )
            if mask.shape != kspace.shape[-2:]:
                raise ValueError(
                    f"mask {mask.shape} does not match the "
                    f"k-space frame {kspace.shape[-2:]}"
                )
            if r.iters < 1:
                raise ValueError(f"iters must be >= 1, got {r.iters}")
            if r.lam < 0.0:
                raise ValueError(f"lam must be >= 0, got {r.lam}")
            # Lane on the CG problem geometry: requests that share it run
            # as ONE batched solve (per-item masks/maps ride the leading
            # axis; cg_normal takes per-item step sizes). Acceleration is
            # part of the key so lightly and heavily undersampled solves
            # don't share a convergence budget; precision is part of it
            # because a scoped config(precision="double") changes the
            # plan the lane must warm.
            accel = int(round(acceleration(mask)))
            return LaneKey(
                "recon",
                (kspace.shape[-2:], kspace.shape[0], accel,
                 int(r.iters), float(r.lam), get_config().precision),
            )
        raise TypeError(
            f"expected SpectrumRequest, RegistrationRequest, "
            f"ConvolutionRequest or ReconRequest, got {type(r)!r}"
        )

    def _queue_fields(self, requests, lanes) -> dict:
        families = [lane.family for lane in lanes]
        return {
            "spectra": families.count("spectrum"),
            "registrations": families.count("registration"),
            "convolutions": families.count("convolution"),
            "recons": families.count("recon"),
        }

    def _execute_lane(self, lane: LaneKey, members: list) -> None:
        if lane.family == "registration":
            self._execute_registrations(lane, members)
        elif lane.family == "convolution":
            self._execute_convolutions(lane, members)
        elif lane.family == "recon":
            self._execute_recons(lane, members)
        else:
            self._execute_spectra(lane, members)

    # ------------------------------ executors ------------------------------

    def _execute_registrations(self, lane: LaneKey, members: list) -> None:
        from repro.imaging import register_phase_correlation

        shape, real, upsample = lane.signature
        # Warm the plan for the BATCHED problem the lane's transform pair
        # will actually run under ((B, H, W) — xfft keys on the full
        # shape), so a repeat batch of this shape and size is a pure
        # cache hit inside register_phase_correlation.
        self._plan_for(
            "rfft2d" if real else "fft2d",
            (len(members), *shape),
            "float32" if real else "complex64",
        )
        refs = jnp.asarray(np.stack([np.asarray(r.ref) for r in members]))
        movs = jnp.asarray(np.stack([np.asarray(r.mov) for r in members]))
        with obs.span(
            "serve.batch", service="registration", shape=shape,
            batch=len(members), upsample=upsample,
        ):
            shifts = np.asarray(execute_with_policy(
                self.policy,
                lambda: register_phase_correlation(
                    refs, movs, upsample_factor=upsample
                ),
                service="registration",
            ))
        for r, shift in zip(members, shifts):
            r.shift = shift
            r.done = True

    def _execute_convolutions(self, lane: LaneKey, members: list) -> None:
        from repro.imaging import oaconvolve2

        ishape, kshape, mode, real = lane.signature
        # One oaconv2d plan per (image, kernel) geometry: every member
        # shares the tile, kernels ride the batched leading axis.
        plan = self._plan_for(
            "oaconv2d",
            (*ishape, *kshape),
            "float32" if real else "complex64",
        )
        images = jnp.asarray(np.stack([np.asarray(r.image) for r in members]))
        kernels = jnp.asarray(np.stack([np.asarray(r.kernel) for r in members]))
        with obs.span(
            "serve.batch", service="convolution", shape=ishape,
            kernel=kshape, batch=len(members), tile=plan.tile,
        ):
            out = np.asarray(execute_with_policy(
                self.policy,
                lambda: oaconvolve2(images, kernels, mode=mode, tile=plan.tile),
                service="convolution",
            ))
        for r, res in zip(members, out):
            r.out = res
            r.done = True

    def _execute_recons(self, lane: LaneKey, members: list) -> None:
        from repro.mri import recon_cg_sense

        shape, coils, accel, iters, lam, _precision = lane.signature
        # Warm the plan for the BATCHED coil stack every CG iteration
        # transforms ((B, C, H, W) forward + inverse — xfft keys on the
        # full shape), so the whole 2·iters-transform solve below runs on
        # plan-cache hits: one lane, one plan, tens of resolutions.
        self._plan_for("fft2d", (len(members), coils, *shape), "complex64")
        kspaces = jnp.asarray(np.stack([np.asarray(r.kspace) for r in members]))
        smapss = jnp.asarray(np.stack([np.asarray(r.smaps) for r in members]))
        masks = jnp.asarray(
            np.stack([np.asarray(r.mask) for r in members]).astype(np.float32)
        )[:, None]                           # (B, 1, H, W): broadcast coils
        with obs.span(
            "serve.batch", service="recon", shape=shape, coils=coils,
            accel=accel, batch=len(members), iters=iters,
        ):
            out = np.asarray(execute_with_policy(
                self.policy,
                lambda: recon_cg_sense(
                    kspaces, smapss, mask=masks, iters=iters, lam=lam
                ),
                service="recon",
            ))
        for r, img in zip(members, out):
            r.image = img
            r.done = True
