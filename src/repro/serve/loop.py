"""Continuous-batching serve loop: one long-lived scheduler for all services.

The paper's control unit time-multiplexes N/2 physical butterflies across
every stage of the transform; :class:`ServeLoop` is the same economy at
serving scale — one scheduler time-multiplexes the planner/engine
population across a continuous request stream instead of spinning up
call-scoped batching per ``serve()`` invocation.

A loop is built from two service-supplied functions:

* ``classify(request) -> LaneKey`` — validate one request and name its
  lane (problem key). Raising here rejects the request *before*
  admission; nothing is half-served.
* ``execute(lane, requests) -> None`` — run one coalesced batch for a
  lane, filling results in-place (the serve layer's convention).

Everything else — per-lane FIFO queues, round-robin fairness,
``max_batch``/``max_wait`` coalescing, ``Overloaded`` backpressure,
completion tickets, the background thread — is shared by
``SpectrumService``, ``ImagingService`` and the LM ``ServeEngine``.
There is exactly one batching implementation in the repo now.

Two entry styles over the same queue:

* **call-scoped** — :meth:`ServeLoop.serve` admits a whole request list,
  enqueues it, and drains: the pre-loop ``service.serve(requests)``
  contract, preserved verbatim for existing callers (same grouping, same
  events, same memoization).
* **streaming** — :meth:`ServeLoop.submit` returns a :class:`Ticket`;
  batches form across submitters as lanes fill or age past the
  coalescing window, driven by explicit :meth:`tick` calls or the
  :meth:`start`-ed background thread.

Quarantine awareness rides on the services' ``_plan_for`` (a lane whose
memoized engine gets benched by :mod:`repro.resilience.breaker`
re-resolves around the bench instead of stalling), so a mid-stream
engine failure costs one ``resilience.failover`` and the lane keeps
serving. The loop additionally keeps a lane → problem-key registry so
``xfft.report()`` can group the quarantine table by *service*, not just
engine × key.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

from repro import obs
from repro.obs.hist import LatencyHistogram, histogram
from repro.resilience.policies import ServicePolicy, admit
from repro.serve.queue import AdmissionQueue, BatchPolicy, LaneKey, Ticket

__all__ = [
    "ServeLoop",
    "record_lane_key",
    "reset_lane_keys",
    "services_for_key",
]


# --------------------- lane -> problem-key registry ---------------------
#
# Which services planned which problem keys. Deliberately process-wide
# (like obs counters): the quarantine table in xfft.report() is
# process-wide too, and grouping its rows by service needs the union of
# every live service's lanes, not one loop's view.

_LANE_KEYS: Dict[str, Set[str]] = {}
_LANE_LOCK = threading.Lock()


def record_lane_key(service: str, cache_key: str) -> None:
    """Record that ``service`` serves a lane planned under ``cache_key``."""
    with _LANE_LOCK:
        _LANE_KEYS.setdefault(service, set()).add(cache_key)


def services_for_key(cache_key: str) -> Tuple[str, ...]:
    """Services whose lanes plan under ``cache_key`` (sorted; may be empty)."""
    with _LANE_LOCK:
        return tuple(
            sorted(s for s, keys in _LANE_KEYS.items() if cache_key in keys)
        )


def reset_lane_keys() -> None:
    """Forget all lane -> key mappings (tests)."""
    with _LANE_LOCK:
        _LANE_KEYS.clear()


# ------------------------------ the loop ------------------------------


class ServeLoop:
    """Continuous-batching scheduler over an :class:`AdmissionQueue`.

    ``policy`` is the service's :class:`ServicePolicy` (its ``max_queue``
    is the admission backpressure); ``batch`` the coalescing
    :class:`BatchPolicy` (default: dispatch eagerly, whole lanes).
    ``queue_fields(requests, lanes)`` lets a service decorate the
    call-scoped ``serve.queue`` event with its own fields (group counts,
    slot counts) without owning the emission point.
    """

    def __init__(
        self,
        classify: Callable[[Any], LaneKey],
        execute: Callable[[LaneKey, List[Any]], None],
        *,
        service: str,
        policy: Optional[ServicePolicy] = None,
        batch: Optional[BatchPolicy] = None,
        clock: Callable[[], float] = time.monotonic,
        queue_fields: Optional[
            Callable[[Sequence[Any], Sequence[LaneKey]], Dict[str, Any]]
        ] = None,
    ):
        self.classify = classify
        self.execute = execute
        self.service = service
        self.policy = policy if policy is not None else ServicePolicy()
        self.batch = batch if batch is not None else BatchPolicy()
        self.clock = clock
        self.queue_fields = queue_fields
        self.queue = AdmissionQueue(self.policy, service=service, clock=clock)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # ------------------------------ intake ------------------------------

    def submit(self, request: Any) -> Ticket:
        """Classify + admit one streaming request; returns its ticket.

        Raises the classifier's error for an invalid request and
        ``Overloaded`` past the policy's ``max_queue`` — backpressure is
        an answer to the submitter, never a silent drop.
        """
        lane = self.classify(request)
        return self.queue.submit(request, lane)

    def serve(self, requests: List[Any]) -> List[Any]:
        """Call-scoped entry: admit, enqueue and drain a whole queue.

        Mirrors the pre-loop ``service.serve()`` contract exactly:
        validation is all-or-nothing (every request classifies before any
        is admitted, errors carry a ``request {i}:`` prefix), admission
        sheds the whole call with ``Overloaded`` before any batch runs,
        one ``serve.queue`` event describes the intake, and the same list
        comes back with results filled in-place.
        """
        lanes: List[LaneKey] = []
        for i, r in enumerate(requests):
            try:
                lanes.append(self.classify(r))
            except (TypeError, ValueError) as e:
                raise type(e)(f"request {i}: {e}") from e
        admit(
            self.policy,
            self.queue.depth() + len(requests),
            service=self.service,
        )
        fields = (
            self.queue_fields(requests, lanes) if self.queue_fields else {}
        )
        obs.emit(
            "serve.queue",
            service=self.service,
            depth=len(requests),
            **fields,
        )
        for r, lane in zip(requests, lanes):
            # already admitted above as one unit — per-submit shedding off,
            # or a half-admitted call could strand earlier requests
            self.queue.submit(r, lane, shed=False)
        self.drain(raise_errors=True)
        return requests

    # ------------------------------ dispatch ------------------------------

    def _lane_histogram(self, lane: LaneKey) -> LatencyHistogram:
        """The process-wide admission->completion latency histogram of one
        lane (``serve.lane.<service>.<label>`` in the registry): bounded,
        mergeable, and readable by ``xfft.report()`` and the Prometheus
        exporter without touching the loop."""
        return histogram(f"serve.lane.{self.service}.{lane.label()}")

    def tick(self, *, drain: bool = False, raise_errors: bool = False) -> int:
        """Dispatch at most one ready lane batch; returns tickets served.

        The scheduler heartbeat: takes the next ready batch in round-robin
        lane order, emits ``serve.loop.tick`` (with the queue-depth gauge),
        runs the service executor, and completes the tickets. A batch
        that raises marks every member ticket failed (streaming callers
        see the error from :meth:`Ticket.result`); ``raise_errors`` also
        re-raises for call-scoped serving.
        """
        taken = self.queue.take(self.batch, drain=drain)
        if taken is None:
            return 0
        lane, tickets = taken
        now = self.clock()
        hist = self._lane_histogram(lane)
        obs.emit(
            "serve.loop.tick",
            service=self.service,
            lane=lane.label(),
            batch=len(tickets),
            depth=self.queue.depth(),
            waited_s=now - tickets[0].submitted_at,
            # the lane's latency-tail gauges as of the PREVIOUS batches:
            # a monitoring scrape of the tick stream sees the live tail
            # without holding a capture scope open
            lane_n=hist.count,
            lane_p50_us=hist.percentile(50) if hist.count else None,
            lane_p99_us=hist.percentile(99) if hist.count else None,
        )
        try:
            self.execute(lane, [t.request for t in tickets])
        except BaseException as e:
            obs.emit(
                "serve.lane.error",
                service=self.service,
                lane=lane.label(),
                batch=len(tickets),
                error=repr(e),
            )
            for t in tickets:
                t.mark_done(error=e)
            if raise_errors:
                raise
            return len(tickets)
        done_at = self.clock()
        for t in tickets:
            t.mark_done()
            # admission -> completion, on the same injectable clock the
            # ticket was stamped with
            hist.record((done_at - t.submitted_at) * 1e6)
        return len(tickets)

    def drain(self, *, raise_errors: bool = False) -> int:
        """Tick until the queue is empty (every lane ready); returns total."""
        served = 0
        while True:
            n = self.tick(drain=True, raise_errors=raise_errors)
            if n == 0:
                return served
            served += n

    # --------------------------- background loop ---------------------------

    def start(self) -> "ServeLoop":
        """Run the loop on a daemon thread: batches form as lanes fill or
        age out, without any caller driving :meth:`tick`. Idempotent."""
        if self._thread is not None and self._thread.is_alive():
            return self
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._run, name=f"serve-loop[{self.service}]", daemon=True
        )
        self._thread.start()
        return self

    def stop(self, *, drain: bool = True, timeout: float = 5.0) -> None:
        """Stop the background thread; ``drain`` serves remaining work first."""
        self._stop.set()
        with self.queue.cond:
            self.queue.cond.notify_all()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None
        if drain:
            self.drain()

    def _run(self) -> None:
        while not self._stop.is_set():
            if self.tick():
                continue
            with self.queue.cond:
                if self._stop.is_set():
                    return
                oldest = self.queue.next_deadline()
                if oldest is None:
                    self.queue.cond.wait()  # idle until a submit arrives
                else:
                    # sleep only until the oldest lane ages past the
                    # coalescing window (a fill-triggered submit notifies)
                    remaining = self.batch.max_wait_s - (self.clock() - oldest)
                    self.queue.cond.wait(max(remaining, 0.0005))
