"""Serving layer: one continuous-batching loop behind every service.

``SpectrumService``/``ImagingService``/``ServeEngine`` share the
:class:`~repro.serve.loop.ServeLoop` scheduler (per-problem-key lanes,
coalescing, round-robin fairness, ``Overloaded`` backpressure);
:mod:`repro.serve.wisdom` ships pre-tuned plan caches as artifacts so a
fresh process serves with zero MEASURE cost.
"""

from repro.serve import wisdom
from repro.serve.engine import Request, ServeEngine, SpectrumRequest, SpectrumService
from repro.serve.imaging import (
    ConvolutionRequest,
    ImagingService,
    ReconRequest,
    RegistrationRequest,
)
from repro.serve.loop import ServeLoop
from repro.serve.queue import AdmissionQueue, BatchPolicy, LaneKey, Ticket

__all__ = [
    "AdmissionQueue",
    "BatchPolicy",
    "ConvolutionRequest",
    "ImagingService",
    "LaneKey",
    "ReconRequest",
    "RegistrationRequest",
    "Request",
    "ServeEngine",
    "ServeLoop",
    "SpectrumRequest",
    "SpectrumService",
    "Ticket",
    "wisdom",
]
