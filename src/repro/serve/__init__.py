from repro.serve.engine import ServeEngine, SpectrumRequest, SpectrumService
from repro.serve.imaging import (
    ConvolutionRequest,
    ImagingService,
    RegistrationRequest,
)

__all__ = [
    "ServeEngine",
    "SpectrumRequest",
    "SpectrumService",
    "ImagingService",
    "RegistrationRequest",
    "ConvolutionRequest",
]
