from repro.serve.engine import ServeEngine, SpectrumRequest, SpectrumService

__all__ = ["ServeEngine", "SpectrumRequest", "SpectrumService"]
