"""Batched serving engines over the shared continuous-batching loop.

* :class:`ServeEngine` — LM serving: prefill + jitted decode steps over the
  Model API. Supports every cache family (dense KV, SWA ring, MLA latent,
  SSM/xLSTM state) because it only ever touches the Model's cache pytree
  opaquely. Requests route through the same :class:`repro.serve.loop.
  ServeLoop` lane machinery as the FFT services — lanes are power-of-two
  prompt-length buckets, so a batch never pads a short prompt to an
  unrelated long one (the per-call slot manager this replaces had no
  problem-key grouping at all).
* :class:`SpectrumService` — the paper's 2D-FFT processor as a service:
  plan-aware batching groups frame requests by problem key (shape ×
  realness × direction), tunes ONE plan per group through ``repro.plan``,
  and runs each group as a single batched transform. Real frames (every
  workload the paper names: imaging, holography, correlation) take the
  two-for-one ``rfft2`` path — half the arithmetic and HBM traffic of the
  complex transform. Engine choice goes through the ``repro.engines``
  registry via ``resolve_call``: a scoped ``xfft.config(precision=
  "double")`` or ``config(backend=...)`` around ``serve()`` steers the
  whole service (and its wisdom keys) without any API change here.

Both services delegate admission, lane queues, coalescing and fairness
to their :class:`ServeLoop` (``svc.loop``): ``serve()`` stays the
call-scoped contract it always was, while ``svc.loop.submit()`` /
``svc.loop.start()`` expose the same service as a streaming,
continuously-batching endpoint.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.resilience.policies import ServicePolicy, execute_with_policy
from repro.serve.loop import LaneKey, ServeLoop, record_lane_key
from repro.serve.queue import BatchPolicy


@dataclasses.dataclass
class Request:
    prompt: np.ndarray            # (S,) int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    name = "lm"

    def __init__(self, model, params, *, batch: int, max_len: int, dtype=jnp.float32,
                 policy: ServicePolicy | None = None,
                 batch_policy: BatchPolicy | None = None):
        self.model = model
        self.params = params
        self.batch = batch
        self.max_len = max_len
        # Serving hardening (repro.resilience): per-batch deadline, bounded
        # retry with jittered backoff, queue-depth load shedding. The
        # default policy is maximally permissive — existing callers see no
        # behaviour change.
        self.policy = policy if policy is not None else ServicePolicy()
        self.caches = model.init_cache_fn(batch, max_len, dtype)
        self._decode = jax.jit(model.decode_fn)
        self._prefill = jax.jit(model.prefill_fn)
        self._extras: dict | None = None
        if batch_policy is None:
            batch_policy = BatchPolicy(max_batch=batch)
        elif batch_policy.max_batch is None or batch_policy.max_batch > batch:
            # the model was compiled for `batch` slots; a lane batch can
            # never exceed them
            batch_policy = dataclasses.replace(batch_policy, max_batch=batch)
        self.loop = ServeLoop(
            self._classify, self._execute_lane, service=self.name,
            policy=self.policy, batch=batch_policy,
            queue_fields=self._queue_fields,
        )

    def generate(self, prompts: list[np.ndarray], max_new: int = 16,
                 extras: dict | None = None) -> list[list[int]]:
        """Greedy generation for a single batch of equal-length prompts."""
        assert len(prompts) == self.batch
        s = len(prompts[0])
        batch = {"tokens": jnp.asarray(np.stack(prompts), jnp.int32)}
        if extras:
            batch.update(extras)
        logits, caches = self._prefill(self.params, batch, self.caches)
        outs: list[list[int]] = [[] for _ in prompts]
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        pos = s
        for _ in range(max_new):
            for i, t in enumerate(np.asarray(tok[:, 0])):
                outs[i].append(int(t))
            logits, caches = self._decode(
                self.params, tok, jnp.asarray(pos, jnp.int32), caches
            )
            tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            pos += 1
        return outs

    # --------------------------- lane machinery ---------------------------

    def _classify(self, r: Any) -> LaneKey:
        if not isinstance(r, Request):
            raise TypeError(f"expected Request, got {type(r)!r}")
        s = len(np.asarray(r.prompt))
        if not 0 < s <= self.max_len:
            raise ValueError(
                f"prompt length must be in 1..{self.max_len}, got {s}"
            )
        # pow2 length buckets: prompts in one lane pad to at most 2x the
        # shortest member, instead of to the longest prompt in the call
        bucket = min(1 << (s - 1).bit_length(), self.max_len)
        return LaneKey(self.name, (bucket,))

    def _queue_fields(self, requests, lanes) -> dict:
        return {"slots": self.batch, "lanes": len(set(lanes))}

    def _execute_lane(self, lane: LaneKey, members: list[Request]) -> None:
        s = max(len(a.prompt) for a in members)
        toks = np.zeros((self.batch, s), np.int32)
        for i, a in enumerate(members):
            toks[i, s - len(a.prompt):] = a.prompt
        with obs.span(
            "serve.batch",
            service=self.name,
            batch=len(members),
            slots=self.batch,
            queued=self.loop.queue.depth(),
            prompt_len=s,
        ):
            outs = execute_with_policy(
                self.policy,
                lambda: self.generate(
                    [toks[i] for i in range(self.batch)],
                    max_new=max(a.max_new for a in members),
                    extras=self._extras,
                ),
                service=self.name,
            )
        for i, a in enumerate(members):
            a.out = outs[i][: a.max_new]
            a.done = True

    def serve_queue(self, queue: list[Request], extras: dict | None = None) -> list[Request]:
        """Continuous batching: serve a request queue through the loop's
        prompt-length lanes, at most ``batch`` requests per execution.

        Under a bounding :class:`repro.resilience.ServicePolicy`, a queue
        deeper than ``max_queue`` is rejected whole with ``Overloaded``
        (shed at admission — no request is half-served), and each batch
        step runs with the policy's deadline/retry envelope.
        """
        requests = list(queue)
        self._extras = extras
        try:
            self.loop.serve(requests)
        finally:
            self._extras = None
        return requests


# ----------------------- plan-aware 2D-FFT serving ------------------------


@dataclasses.dataclass
class SpectrumRequest:
    """One frame to transform. Real frames are served via the two-for-one
    ``rfft2`` path (half spectrum out); complex frames via ``fft2``."""

    frame: np.ndarray                       # (H, W) real or complex
    spectrum: np.ndarray | None = None      # filled by SpectrumService.serve
    done: bool = False


class SpectrumService:
    """Serve batched 2D-FFT requests with plan-aware batching.

    Requests are grouped by problem key — frame shape and realness — so
    ONE tuned plan (``repro.plan``) serves a whole group as a single
    batched transform, instead of re-deciding the schedule per frame.
    Plans are cached across ``serve`` calls; with a MEASURE-mode,
    file-backed cache (or a :mod:`repro.serve.wisdom` warm start) a
    service tunes once per shape for its lifetime.

    Scheduling lives in ``self.loop`` (:class:`repro.serve.loop.
    ServeLoop`): ``serve()`` is the call-scoped entry, ``loop.submit()``
    the streaming one, and a ``batch`` :class:`BatchPolicy` bounds
    coalescing for both.
    """

    name = "spectrum"

    def __init__(self, plan_mode: str | None = None, cache=None,
                 policy: ServicePolicy | None = None,
                 batch: BatchPolicy | None = None):
        # None defers to the scoped repro.xfft.config mode, so an operator's
        # `xfft.config(mode="measure")` tunes the service exactly as it
        # tunes direct calls; an explicit plan_mode pins the policy.
        if plan_mode is not None and plan_mode not in ("estimate", "measure"):
            raise ValueError(f"plan_mode must be 'estimate' or 'measure', got {plan_mode!r}")
        self.plan_mode = plan_mode
        self.cache = cache
        self.policy = policy if policy is not None else ServicePolicy()
        self.plans: dict = {}               # (config, cache_key) -> FFTPlan memo
        self.loop = ServeLoop(
            self._classify, self._execute_lane, service=self.name,
            policy=self.policy, batch=batch, queue_fields=self._queue_fields,
        )

    # --------------------------- lane machinery ---------------------------

    def _classify(self, r: Any) -> LaneKey:
        if not isinstance(r, SpectrumRequest):
            raise TypeError(f"expected SpectrumRequest, got {type(r)!r}")
        frame = np.asarray(r.frame)
        if frame.ndim != 2:
            raise ValueError(f"expected a (H, W) frame, got {frame.shape}")
        real = not np.iscomplexobj(frame)
        return LaneKey("spectrum", (frame.shape, real))

    def _queue_fields(self, requests, lanes) -> dict:
        return {"groups": len(set(lanes))}

    def _execute_lane(self, lane: LaneKey, members: list) -> None:
        self._execute_spectra(lane, members)

    def _execute_spectra(self, lane: LaneKey, members: list) -> None:
        from repro.plan import execute

        shape, real = lane.signature
        batch = np.stack([np.asarray(r.frame) for r in members])
        kind = "rfft2d" if real else "fft2d"
        dtype = "float32" if real else "complex64"
        # Plan under the per-frame shape: the schedule depends on the
        # frame geometry, not on how many requests happened to arrive,
        # so varying batch sizes never trigger a re-tune.
        plan = self._plan_for(kind, shape, dtype)
        with obs.span(
            "serve.batch", service="spectrum", kind=kind, shape=shape,
            batch=len(members), variant=plan.variant,
        ):
            out = np.asarray(execute_with_policy(
                self.policy,
                lambda: execute(plan, jnp.asarray(batch)),
                service="spectrum", kind=kind,
            ))
        for j, r in enumerate(members):
            r.spectrum = out[j]
            r.done = True

    # ------------------------------ planning ------------------------------

    def _plan_for(self, kind: str, shape, dtype: str):
        from repro.plan import problem_key, resolve_call
        from repro.resilience import quarantine
        from repro.xfft import get_config

        # resolve_call (not plan_fft): the service honours scoped
        # repro.xfft.config overrides — a forced variant, mode or wisdom
        # directory applies to serving exactly as to direct calls (unless
        # the constructor pinned plan_mode). The session memo keys on the
        # active config too, so a scoped override neither reads nor
        # leaves stale memo entries.
        pk = problem_key(kind, shape, dtype)
        record_lane_key(self.name, pk.cache_key())
        memo_key = (get_config(), pk.cache_key())
        plan = self.plans.get(memo_key)
        breaker = quarantine()
        if plan is not None and breaker.excluded(plan.variant, pk):
            # memoized engine is benched: re-resolve around it — the lane
            # keeps serving instead of stalling on the quarantined engine
            obs.emit(
                "serve.lane.replan", service=self.name,
                key=pk.cache_key(), engine=plan.variant,
            )
            obs.count(f"serve.replan.{self.name}")
            plan = None
        if plan is None:
            plan = resolve_call(kind, shape, dtype=dtype, mode=self.plan_mode,
                                cache=self.cache)
            # Plans resolved under an active quarantine are workarounds:
            # don't memoize them, or the service would keep serving the
            # fallback after the benched engine recovers.
            if not breaker.affects(pk):
                self.plans[memo_key] = plan
        return plan

    # ------------------------------- entry -------------------------------

    def serve(self, requests: list[SpectrumRequest]) -> list[SpectrumRequest]:
        """Transform every request in-place; returns the same list.

        Admission first: a queue deeper than the policy's ``max_queue``
        sheds with ``Overloaded`` before any group executes. Each group
        then runs under the policy's deadline/retry envelope.
        """
        return self.loop.serve(requests)
