"""Batched serving engine: prefill + jitted decode steps over the Model API.

Supports every cache family (dense KV, SWA ring, MLA latent, SSM/xLSTM
state) because it only ever touches the Model's cache pytree opaquely.
Includes a minimal continuous-batching slot manager: finished sequences'
slots are refilled with queued requests between decode steps.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    prompt: np.ndarray            # (S,) int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model, params, *, batch: int, max_len: int, dtype=jnp.float32):
        self.model = model
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.caches = model.init_cache_fn(batch, max_len, dtype)
        self._decode = jax.jit(model.decode_fn)
        self._prefill = jax.jit(model.prefill_fn)

    def generate(self, prompts: list[np.ndarray], max_new: int = 16,
                 extras: dict | None = None) -> list[list[int]]:
        """Greedy generation for a single batch of equal-length prompts."""
        assert len(prompts) == self.batch
        s = len(prompts[0])
        batch = {"tokens": jnp.asarray(np.stack(prompts), jnp.int32)}
        if extras:
            batch.update(extras)
        logits, caches = self._prefill(self.params, batch, self.caches)
        outs: list[list[int]] = [[] for _ in prompts]
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        pos = s
        for _ in range(max_new):
            for i, t in enumerate(np.asarray(tok[:, 0])):
                outs[i].append(int(t))
            logits, caches = self._decode(
                self.params, tok, jnp.asarray(pos, jnp.int32), caches
            )
            tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            pos += 1
        return outs

    def serve_queue(self, queue: list[Request], extras: dict | None = None) -> list[Request]:
        """Continuous batching: process a request queue with ``batch`` slots,
        refilling finished slots from the queue (prompts padded to equal S)."""
        pending = list(queue)
        active: list[Request | None] = [None] * self.batch
        results: list[Request] = []
        while pending or any(a is not None for a in active):
            for i in range(self.batch):
                if active[i] is None and pending:
                    active[i] = pending.pop(0)
            # all-slot prefill is the simple (and restartable) policy:
            live = [a for a in active if a is not None]
            if not live:
                break
            s = max(len(a.prompt) for a in live)
            toks = np.zeros((self.batch, s), np.int32)
            for i, a in enumerate(active):
                if a is not None:
                    toks[i, s - len(a.prompt):] = a.prompt
            outs = self.generate(
                [toks[i] for i in range(self.batch)],
                max_new=max(a.max_new for a in live),
                extras=extras,
            )
            for i, a in enumerate(active):
                if a is not None:
                    a.out = outs[i][: a.max_new]
                    a.done = True
                    results.append(a)
                    active[i] = None
        return results
