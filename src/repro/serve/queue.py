"""Admission queue with per-problem-key lanes for the serve loop.

The paper's RAM controller sits between the functional blocks and decides
which buffered samples feed which engine next; this module is that
controller for serving. Incoming requests are classified into **lanes**
— one lane per problem key (frame shape × realness, registration
geometry × upsample, convolution geometry, LM length bucket) — so the
scheduler can coalesce *compatible* work into one batched execution
while unrelated traffic queues independently.

Pieces:

* :class:`LaneKey` — the lane identity: a request family plus the
  family-specific problem signature. Requests in one lane share a plan.
* :class:`Ticket` — one admitted request: completion event, error slot,
  submit timestamp (the tail-latency clock starts at admission).
* :class:`BatchPolicy` — when a lane's backlog becomes a batch: at
  ``max_batch`` requests, or when the oldest ticket has waited
  ``max_wait_s`` (the coalescing window), whichever comes first.
* :class:`AdmissionQueue` — thread-safe lanes + round-robin rotation.
  Backpressure is the existing :func:`repro.resilience.admit` shedding:
  a submit that would push the total depth past the policy's
  ``max_queue`` raises the typed ``Overloaded`` — the request is
  *rejected to its submitter*, never silently dropped.

Fairness is structural: :meth:`AdmissionQueue.take` walks the lane
rotation and moves a dispatched lane to the back, so a lane under
sustained load cannot starve a lane with a single waiting request.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict, deque
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

from repro import obs
from repro.resilience.policies import ServicePolicy, admit

__all__ = ["AdmissionQueue", "BatchPolicy", "LaneKey", "Ticket"]


@dataclasses.dataclass(frozen=True)
class LaneKey:
    """Identity of one serve lane: request family + problem signature.

    ``family`` names the request kind (``"spectrum"``, ``"registration"``,
    ``"convolution"``, ``"lm"``, ...); ``signature`` is the
    family-specific problem key material (hashable), e.g. ``((H, W),
    real)`` for spectrum frames. Two requests with equal lane keys may
    legally ride one batched execution under one plan.
    """

    family: str
    signature: Tuple

    def label(self) -> str:
        """Compact human form for events and report rows."""
        sig = ",".join(str(s) for s in self.signature)
        return f"{self.family}[{sig}]"


class Ticket:
    """One admitted request: completion state + the latency clock.

    The ticket is what a streaming submitter holds while the loop works:
    :meth:`wait` blocks until the batch containing the request executed,
    :meth:`result` returns the request (results are filled in-place, as
    everywhere in the serve layer) or re-raises the batch's error.
    """

    __slots__ = ("request", "lane", "submitted_at", "error", "_done")

    def __init__(self, request: Any, lane: LaneKey, submitted_at: float):
        self.request = request
        self.lane = lane
        self.submitted_at = submitted_at
        self.error: Optional[BaseException] = None
        self._done = threading.Event()

    @property
    def done(self) -> bool:
        return self._done.is_set()

    def mark_done(self, error: Optional[BaseException] = None) -> None:
        self.error = error
        self._done.set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block until the request's batch ran; False on timeout."""
        return self._done.wait(timeout)

    def result(self, timeout: Optional[float] = None):
        """The served request, or the batch's exception re-raised."""
        if not self.wait(timeout):
            raise TimeoutError(f"ticket for lane {self.lane.label()} still pending")
        if self.error is not None:
            raise self.error
        return self.request


@dataclasses.dataclass(frozen=True)
class BatchPolicy:
    """When a lane's backlog is dispatched as one batch.

    ``max_batch`` — coalesce at most this many requests per execution
    (``None`` = the whole lane). A full lane is always ready.
    ``max_wait_s`` — the coalescing window: a non-full lane is ready once
    its oldest ticket has waited this long. The default ``0.0`` keeps
    call-scoped serving eager (every tick dispatches), while a streaming
    loop sets a small window to trade first-request latency for batch
    occupancy.
    """

    max_batch: Optional[int] = None
    max_wait_s: float = 0.0

    def __post_init__(self):
        if self.max_batch is not None and self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1 or None, got {self.max_batch}")
        if self.max_wait_s < 0:
            raise ValueError(f"max_wait_s must be >= 0, got {self.max_wait_s}")


class AdmissionQueue:
    """Thread-safe per-lane FIFO queues with round-robin dispatch order.

    ``policy.max_queue`` is enforced at :meth:`submit` over the *total*
    pending depth — per-request backpressure via the typed ``Overloaded``
    (:func:`repro.resilience.admit`), so a producer learns immediately
    that it must back off. ``clock`` is injectable so tests drive
    coalescing windows without wall time.
    """

    def __init__(
        self,
        policy: Optional[ServicePolicy] = None,
        service: str = "serve",
        clock: Callable[[], float] = time.monotonic,
    ):
        self.policy = policy if policy is not None else ServicePolicy()
        self.service = service
        self.clock = clock
        self._lanes: "OrderedDict[LaneKey, Deque[Ticket]]" = OrderedDict()
        self._lock = threading.RLock()
        self.cond = threading.Condition(self._lock)

    def depth(self) -> int:
        """Total pending requests across all lanes."""
        with self._lock:
            return sum(len(q) for q in self._lanes.values())

    def lane_depths(self) -> Dict[LaneKey, int]:
        """Pending depth per lane — the queue-depth gauge the loop emits."""
        with self._lock:
            return {lane: len(q) for lane, q in self._lanes.items()}

    def submit(self, request: Any, lane: LaneKey, shed: bool = True) -> Ticket:
        """Admit one request into its lane; returns its :class:`Ticket`.

        ``shed=True`` (streaming submits) applies the policy's
        ``max_queue`` backpressure; a call-scoped ``serve()`` admits its
        whole queue up front and enqueues with ``shed=False`` so a
        half-admitted call can never happen.
        """
        with self._lock:
            if shed:
                admit(
                    self.policy,
                    self.depth() + 1,
                    service=self.service,
                    lane=lane.label(),
                )
            ticket = Ticket(request, lane, self.clock())
            self._lanes.setdefault(lane, deque()).append(ticket)
            obs.emit("serve.loop.enqueue", service=self.service, lane=lane.label())
            self.cond.notify_all()
            return ticket

    def _ready(self, q: Deque[Ticket], batch: BatchPolicy, now: float) -> bool:
        if batch.max_batch is not None and len(q) >= batch.max_batch:
            return True
        return now - q[0].submitted_at >= batch.max_wait_s

    def take(
        self, batch: BatchPolicy, drain: bool = False
    ) -> Optional[Tuple[LaneKey, List[Ticket]]]:
        """Pop the next ready batch in round-robin lane order, or None.

        The first *ready* lane (full, or past its coalescing window;
        ``drain`` makes every non-empty lane ready) yields up to
        ``batch.max_batch`` tickets. A lane with leftover backlog moves
        to the back of the rotation — one hot lane cannot monopolise the
        scheduler while another lane waits.
        """
        now = self.clock()
        with self._lock:
            for _ in range(len(self._lanes)):
                lane, q = next(iter(self._lanes.items()))
                if not (drain or self._ready(q, batch, now)):
                    self._lanes.move_to_end(lane)  # not ready: check the next lane
                    continue
                n = len(q) if batch.max_batch is None else min(len(q), batch.max_batch)
                tickets = [q.popleft() for _ in range(n)]
                if q:
                    self._lanes.move_to_end(lane)  # backlog left: to the back
                else:
                    del self._lanes[lane]
                return lane, tickets
            return None

    def next_deadline(self) -> Optional[float]:
        """Earliest clock() value at which a waiting lane becomes ready
        by age alone (None when empty) — what a background loop sleeps to."""
        with self._lock:
            oldest = [q[0].submitted_at for q in self._lanes.values() if q]
        return min(oldest) if oldest else None
