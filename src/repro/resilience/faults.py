"""Deterministic fault injection: seeded chaos for every seam that matters.

The source paper's control unit exists to keep a resource-starved
pipeline correct under pressure — recirculating N/2 butterflies while a
RAM controller sequences the functional blocks. The software counterpart
has to *prove* it degrades the same way, and the only honest proof is
injecting the failures on purpose. A :class:`FaultPlan` is a frozen,
seeded schedule of faults aimed at named seams; scope it with
``repro.xfft.config(faults=FaultPlan(...))`` (contextvars-based, exactly
like ``observe=``) and every chaos run replays identically.

Seams (the places the rest of the repo consults this module):

* ``engine.apply``     — the degradation ladder's engine dispatch
                         (``repro.resilience.ladder.run_plan``): error /
                         latency / vmem faults raise or stall before the
                         engine runs; nan/inf faults poison its output.
* ``plan.measure``     — each MEASURE candidate (``repro.plan.autotune``):
                         latency faults trip the per-candidate wall-clock
                         budget, error faults crash the candidate.
* ``plan.cache.load``  — wisdom-file reads (``PlanCache.load``): error
                         faults are accounted as ``file_error`` loads.
* ``plan.cache.save``  — wisdom-file writes (``PlanCache.save``): error
                         faults drive the read-only degrade path.
* ``kernel.fused``     — the fused Pallas kernels' VMEM fit decision
                         (``repro.kernels.ops``): vmem faults force the
                         unfused row/turn/column failover.
* ``serve.batch``      — one batched group execution in the serve layer:
                         error faults drive the bounded-retry path,
                         latency faults eat the request deadline.

Every fired fault emits a ``resilience.fault`` obs event and bumps the
``resilience.fault.<mode>`` counter, so a chaos run's injection schedule
is itself observable. With no plan in scope every hook is a single
contextvar read — the hot path stays clean.

This module imports only :mod:`repro.obs` and the standard library;
plan, engines, kernels and serve all consult it without cycles.
"""

from __future__ import annotations

import contextvars
import dataclasses
import random
import threading
import time
from typing import Any, Dict, Optional, Tuple, Union

from repro import obs

__all__ = [
    "FAULT_MODES",
    "FAULT_SEAMS",
    "FaultPlan",
    "FaultSpec",
    "FaultState",
    "InjectedFault",
    "active_faults",
    "maybe_corrupt",
    "maybe_fail",
    "push_faults",
    "pop_faults",
    "vmem_exhausted",
]

#: Seams a FaultSpec may target (validated at construction so a typo'd
#: seam fails when the plan is built, not by silently never firing).
FAULT_SEAMS = (
    "engine.apply",
    "plan.measure",
    "plan.cache.load",
    "plan.cache.save",
    "kernel.fused",
    "serve.batch",
)

#: What a fired fault does: raise (error), stall (latency), poison the
#: output payload (nan/inf), or report VMEM exhaustion (vmem).
FAULT_MODES = ("error", "latency", "nan", "inf", "vmem")


class InjectedFault(RuntimeError):
    """The exception a fired ``error``/``vmem`` fault raises at its seam.

    Deliberately a distinct type: resilience tests assert the *recovery*
    machinery (ladder, retry, readonly degrade) handled exactly the fault
    that was scheduled, not some unrelated failure.
    """

    def __init__(self, seam: str, mode: str, message: str):
        super().__init__(message)
        self.seam = seam
        self.mode = mode


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault: where it fires, what it does, how often.

    seam      — one of :data:`FAULT_SEAMS`.
    mode      — one of :data:`FAULT_MODES`.
    p         — firing probability per consultation (1.0 = always); draws
                come from the plan's seeded RNG, so a chaos run replays.
    times     — total fire budget (``None`` = unlimited): ``times=1``
                injects exactly one failure, the shape the acceptance
                test uses to watch a breaker open and then close.
    match     — context filter: only fire when every (field, value) pair
                matches the seam's call context (e.g. ``{"engine":
                "fused_r4"}`` aims at one engine). Dicts are normalized
                to a sorted tuple so specs stay hashable.
    latency_s — stall duration for ``latency`` faults.
    message   — override for the injected exception text.
    """

    seam: str
    mode: str = "error"
    p: float = 1.0
    times: Optional[int] = None
    match: Union[dict, Tuple[Tuple[str, Any], ...]] = ()
    latency_s: float = 0.05
    message: Optional[str] = None

    def __post_init__(self):
        if self.seam not in FAULT_SEAMS:
            raise ValueError(
                f"unknown fault seam {self.seam!r}; want one of {FAULT_SEAMS}"
            )
        if self.mode not in FAULT_MODES:
            raise ValueError(
                f"unknown fault mode {self.mode!r}; want one of {FAULT_MODES}"
            )
        if not 0.0 < self.p <= 1.0:
            raise ValueError(f"fault probability must be in (0, 1], got {self.p}")
        if self.times is not None and self.times < 1:
            raise ValueError(f"times must be >= 1 or None, got {self.times}")
        if isinstance(self.match, dict):
            object.__setattr__(
                self, "match", tuple(sorted(self.match.items()))
            )
        else:
            object.__setattr__(self, "match", tuple(self.match))


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A frozen, seeded schedule of :class:`FaultSpec` faults.

    Hashable by construction (it rides on the frozen
    ``repro.xfft.XFFTConfig``); all mutable firing state lives on the
    :class:`FaultState` created when the plan enters scope, so the same
    plan object can be reused across scopes and each scope replays from
    the seed.
    """

    specs: Tuple[FaultSpec, ...] = ()
    seed: int = 0

    def __post_init__(self):
        if isinstance(self.specs, FaultSpec):
            object.__setattr__(self, "specs", (self.specs,))
        else:
            object.__setattr__(self, "specs", tuple(self.specs))
        for spec in self.specs:
            if not isinstance(spec, FaultSpec):
                raise TypeError(
                    f"FaultPlan.specs wants FaultSpec entries, got {spec!r}"
                )


class FaultState:
    """Runtime firing state for one in-scope :class:`FaultPlan`.

    Holds the seeded RNG and per-spec fire counts. Thread-safe: a chaos
    run over the threaded serve layer must not double-spend a ``times``
    budget.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._rng = random.Random(plan.seed)
        self._fired: Dict[int, int] = {}
        self._lock = threading.Lock()

    def fire(
        self, seam: str, modes: Tuple[str, ...], ctx: Dict[str, Any]
    ) -> Optional[FaultSpec]:
        """The first armed spec matching (seam, modes, ctx), else None.

        A returned spec has been *spent*: its fire count is bumped, its
        probability draw consumed, and a ``resilience.fault`` event
        emitted — the consultation itself is the schedule.
        """
        with self._lock:
            for i, spec in enumerate(self.plan.specs):
                if spec.seam != seam or spec.mode not in modes:
                    continue
                if spec.times is not None and self._fired.get(i, 0) >= spec.times:
                    continue
                if any(ctx.get(k) != v for k, v in spec.match):
                    continue
                if spec.p < 1.0 and self._rng.random() >= spec.p:
                    continue
                self._fired[i] = self._fired.get(i, 0) + 1
                obs.emit(
                    "resilience.fault", seam=seam, mode=spec.mode,
                    fired=self._fired[i], **ctx,
                )
                obs.count(f"resilience.fault.{spec.mode}")
                return spec
        return None


_ACTIVE: contextvars.ContextVar[Optional[FaultState]] = contextvars.ContextVar(
    "repro_resilience_faults", default=None
)


def active_faults() -> Optional[FaultState]:
    """The in-scope fault state, or None when chaos is off (the default)."""
    return _ACTIVE.get()


def push_faults(plan: Optional[FaultPlan]):
    """Enter a fault scope (``repro.xfft.config(faults=...)`` calls this).

    ``plan=None`` pushes a cleared scope — an inner ``faults=False``
    turns chaos off without disturbing the enclosing scope's state.
    Returns a token for :func:`pop_faults`.
    """
    state = FaultState(plan) if isinstance(plan, FaultPlan) else None
    return _ACTIVE.set(state)


def pop_faults(token) -> None:
    """Undo one :func:`push_faults` (LIFO)."""
    _ACTIVE.reset(token)


def maybe_fail(seam: str, **ctx: Any) -> None:
    """Consult the seam for error/latency/vmem faults: raise or stall.

    The no-plan cost is one contextvar read. ``error`` and ``vmem``
    faults raise :class:`InjectedFault` (vmem with a RESOURCE_EXHAUSTED-
    flavoured message, mimicking what XLA reports when VMEM really runs
    out); ``latency`` faults sleep ``latency_s`` and return.
    """
    state = _ACTIVE.get()
    if state is None:
        return
    spec = state.fire(seam, ("error", "latency", "vmem"), ctx)
    if spec is None:
        return
    if spec.mode == "latency":
        time.sleep(spec.latency_s)
        return
    if spec.mode == "vmem":
        raise InjectedFault(
            seam, "vmem",
            spec.message
            or f"RESOURCE_EXHAUSTED: injected VMEM exhaustion at {seam} ({ctx})",
        )
    raise InjectedFault(
        seam, "error", spec.message or f"injected fault at {seam} ({ctx})"
    )


def maybe_corrupt(seam: str, value, **ctx: Any):
    """Consult the seam for nan/inf faults: poison one output element.

    Returns ``value`` unchanged when nothing fires. The poison is a
    single non-finite element at the origin — exactly the escape the
    opt-in ``check_health="nan"`` guard exists to catch.
    """
    state = _ACTIVE.get()
    if state is None:
        return value
    spec = state.fire(seam, ("nan", "inf"), ctx)
    if spec is None:
        return value
    poison = float("nan") if spec.mode == "nan" else float("inf")
    try:
        idx = (0,) * value.ndim
        return value.at[idx].set(poison)
    except AttributeError:  # plain numpy (or scalar) payloads
        import numpy as np

        out = np.array(value)
        out[(0,) * out.ndim] = poison
        return out


def vmem_exhausted(seam: str, **ctx: Any) -> bool:
    """True when a ``vmem`` fault fires at this seam (non-raising form).

    The fused kernels consult this alongside their real VMEM census, so
    an injected exhaustion exercises the genuine unfused failover path
    without needing a frame that actually busts the budget.
    """
    state = _ACTIVE.get()
    if state is None:
        return False
    return state.fire(seam, ("vmem",), ctx) is not None
