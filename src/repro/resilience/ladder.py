"""The degradation ladder: every transform call lands somewhere.

``run_plan`` wraps the engine dispatch of every ``repro.xfft`` transform
and of ``repro.plan.execute``. When the planned engine raises, the
failure is recorded in the quarantine breaker (:mod:`.breaker`), a
``resilience.failover`` obs event names the benched engine, and the call
retries on the next-best healthy rung — ranked by the same analytic
ESTIMATE model the planner uses — bottoming out at the always-works jnp
engines (``stockham``/``reference_x64``). One bad Pallas lowering costs
one failover, not an outage.

The opt-in output-health guard (``xfft.config(check_health="nan")``)
treats a non-finite output the same way: the producing engine takes a
failure, the call retries one rung down. If every rung yields non-finite
values the last output is returned as-is — at that point the *input* is
poisoned and no engine can do better.

Forced plans (``xfft.config(variant=...)``) bypass the ladder entirely:
a pin is an explicit opinion, and tests that pin an engine must observe
exactly that engine, faults and all.
"""

from __future__ import annotations

from typing import Any, Callable, Optional, Set

from repro import obs
from repro.resilience import faults
from repro.resilience.breaker import quarantine

__all__ = ["run_plan"]


def _check_health_enabled() -> bool:
    from repro.xfft._config import get_config  # lazy: xfft sits above plan

    return get_config().check_health == "nan"


def _is_finite(out: Any) -> bool:
    """False only when ``out`` is concretely non-finite.

    Tracers (inside jit) and non-array payloads can't be inspected;
    they count as healthy — the guard is a serving-path feature, not a
    trace-time one.
    """
    try:
        import jax.numpy as jnp

        return bool(jnp.isfinite(out).all())
    except Exception:
        return True


def _engine_meta(variant: str):
    """(backend, requires_x64) for a registered engine, (None, None) for
    builtin-only names the registry does not know."""
    from repro.engines import get_engine

    try:
        spec = get_engine(variant)
    except Exception:
        return None, None
    return spec.backend, spec.requires_x64


def _next_rung(key, attempted: Set[str]) -> Optional[str]:
    """Best untried healthy engine for ``key``, or None at the bottom.

    Candidates come from the planner's own quarantine-filtered
    enumeration, ranked by the analytic ESTIMATE model — the failover
    plan is exactly the plan the planner would have made without the
    benched engine.
    """
    from repro.plan.autotune import estimate_variant_time, variant_candidates

    try:
        names = [v for v in variant_candidates(key) if v not in attempted]
    except ValueError:
        return None
    if not names:
        return None
    return min(names, key=lambda v: estimate_variant_time(key, v))


def run_plan(plan, runner: Callable[[str], Any]):
    """Run ``runner(variant)`` with failover down the engine ladder.

    ``runner`` executes the transform under a named engine (a closure
    over the input array and kwargs). Success records into the breaker —
    closing any half-open probe for (engine, key) — and returns.
    Failure quarantines the engine for this problem key and retries the
    next-best rung; when no rung remains the last error propagates.
    """
    if plan.mode == "forced":
        # Pinned engines are exempt from injection and failover alike:
        # the scope asked for this engine, so this engine is the answer.
        # The dispatch span still fires — forced calls belong in the
        # flight recorder and the calibration ledger like any other.
        backend, x64 = _engine_meta(plan.variant)
        with obs.span(
            "engine.apply", engine=plan.variant, backend=backend,
            kind=plan.key.kind, direction=plan.key.direction,
            shape=plan.key.shape, precision=plan.key.precision, x64=x64,
        ) as sp:
            out = runner(plan.variant)
            sp["ok"] = True
        return out
    key = plan.key
    breaker = quarantine()
    variant = plan.variant
    attempted: Set[str] = set()
    check_health = _check_health_enabled()
    unhealthy_out = None
    while True:
        reason = "error"
        err: Optional[BaseException] = None
        try:
            # Injected pre-dispatch failures (error/latency/vmem) fire
            # OUTSIDE the span: a fault that prevented the engine from
            # running must not pollute its observed-duration population.
            faults.maybe_fail(
                "engine.apply", engine=variant, kind=key.kind,
                direction=key.direction,
            )
            backend, x64 = _engine_meta(variant)
            with obs.span(
                "engine.apply", engine=variant, backend=backend,
                kind=key.kind, direction=key.direction, shape=key.shape,
                precision=key.precision, x64=x64,
            ) as sp:
                out = faults.maybe_corrupt(
                    "engine.apply", runner(variant), engine=variant,
                    kind=key.kind, direction=key.direction,
                )
                sp["ok"] = True
            if not check_health or _is_finite(out):
                breaker.record_success(variant, key)
                return out
            reason = "nonfinite"
            unhealthy_out = out
        except Exception as e:  # noqa: BLE001 — the ladder exists to catch
            err = e
        attempted.add(variant)
        opened = breaker.record_failure(variant, key, error=repr(err or reason))
        nxt = _next_rung(key, attempted)
        obs.emit(
            "resilience.failover",
            engine=variant,
            kind=key.kind,
            shape=key.shape,
            direction=key.direction,
            reason=reason,
            error=repr(err) if err is not None else None,
            next=nxt,
            quarantined=opened,
        )
        obs.count("resilience.failover")
        if nxt is None:
            if err is not None:
                raise err
            # Non-finite on the bottom rung: the input itself is poisoned;
            # returning the output beats raising for a health *guard*.
            return unhealthy_out
        variant = nxt
