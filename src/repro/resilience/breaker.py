"""Per-problem engine quarantine: a circuit breaker over the registry.

When an engine raises mid-transform, retrying it on the very next call
is how one bad Pallas lowering melts a serving fleet. Instead the
degradation ladder records the failure here, and the planner excludes
the (engine, problem) pair from ``variant_candidates()`` until a
cooldown passes — the classic circuit breaker, keyed per
:class:`~repro.plan.ProblemKey` because an engine that dies on 2048²
frames may be perfectly healthy on 128².

States per (engine, problem-key) entry:

* **closed** — healthy; failures below threshold just count.
* **open** — quarantined: ``excluded()`` is True, the planner routes
  around the engine. Entered when failures reach ``threshold`` (default
  1 — a crashed transform is expensive enough to route around
  immediately).
* **half_open** — after ``cooldown_s`` the next ``excluded()`` check
  flips open → half_open and starts admitting calls again. A success
  closes the breaker; a failure reopens it and restarts the cooldown.
  Half-open is deliberately *non-consuming*: every caller is admitted
  until one resolves the probe, so no probe-token bookkeeping leaks
  between the planner and the ladder.

Transitions emit ``resilience.breaker`` obs events, so the acceptance
flow (open → cooldown → half-open probe → close) is assertable straight
from the event stream, and :meth:`QuarantineRegistry.table` feeds the
quarantine table in ``xfft.report()``.

A module-level singleton (:func:`quarantine`) holds process state, like
the engine registry it filters; tests swap the clock and call
:func:`reset` between cases.
"""

from __future__ import annotations

import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

from repro import obs

__all__ = [
    "BreakerEntry",
    "QuarantineRegistry",
    "configure",
    "quarantine",
    "reset",
]


class BreakerEntry:
    """Mutable breaker state for one (engine, problem-key) pair."""

    __slots__ = ("state", "failures", "opened_at", "last_error")

    def __init__(self):
        self.state = "closed"
        self.failures = 0
        self.opened_at: Optional[float] = None
        self.last_error: Optional[str] = None


class QuarantineRegistry:
    """Circuit breakers keyed by (engine_name, ProblemKey.cache_key()).

    ``threshold`` failures open a breaker; after ``cooldown_s`` the next
    exclusion check admits a half-open probe. ``clock`` is injectable so
    tests drive cooldown without sleeping.
    """

    def __init__(
        self,
        threshold: int = 1,
        cooldown_s: float = 30.0,
        clock: Callable[[], float] = time.monotonic,
    ):
        if threshold < 1:
            raise ValueError(f"threshold must be >= 1, got {threshold}")
        if cooldown_s <= 0:
            raise ValueError(f"cooldown_s must be > 0, got {cooldown_s}")
        self.threshold = threshold
        self.cooldown_s = cooldown_s
        self.clock = clock
        self._entries: Dict[Tuple[str, str], BreakerEntry] = {}
        self._lock = threading.Lock()

    # -- queries ----------------------------------------------------------

    def excluded(self, engine: str, key) -> bool:
        """Should the planner route around ``engine`` for this problem?

        Open breakers past their cooldown transition to half_open here
        (and stop excluding): exclusion checks are the only place the
        planner consults the breaker, so they double as the probe gate.
        """
        if not self._entries:  # fast path: nothing ever failed
            return False
        with self._lock:
            entry = self._entries.get((engine, key.cache_key()))
            if entry is None or entry.state == "closed":
                return False
            if entry.state == "open":
                if self.clock() - entry.opened_at >= self.cooldown_s:
                    entry.state = "half_open"
                    obs.emit(
                        "resilience.breaker", state="half_open",
                        engine=engine, key=key.cache_key(),
                    )
                    return False
                return True
            return False  # half_open admits every caller until resolved

    def affects(self, key) -> bool:
        """True when any engine is quarantined (open/half-open) for ``key``.

        The planner uses this to keep quarantine-shaped fallback plans
        out of the wisdom cache: a plan chosen while an engine was
        benched must not outlive the bench.
        """
        if not self._entries:
            return False
        kstr = key.cache_key()
        with self._lock:
            return any(
                k == kstr and e.state != "closed"
                for (_, k), e in self._entries.items()
            )

    # -- transitions ------------------------------------------------------

    def record_failure(self, engine: str, key, error: str = "") -> bool:
        """Count a failure; open the breaker at threshold. True if opened.

        A failure during a half-open probe reopens immediately — the
        probe answered.
        """
        with self._lock:
            k = (engine, key.cache_key())
            entry = self._entries.setdefault(k, BreakerEntry())
            entry.failures += 1
            entry.last_error = error or entry.last_error
            should_open = (
                entry.state == "half_open" or entry.failures >= self.threshold
            )
            if should_open and entry.state != "open":
                entry.state = "open"
                entry.opened_at = self.clock()
                obs.emit(
                    "resilience.breaker", state="open", engine=engine,
                    key=key.cache_key(), failures=entry.failures,
                    cooldown_s=self.cooldown_s,
                )
                obs.count("resilience.breaker.open")
                return True
            return False

    def record_success(self, engine: str, key) -> None:
        """A call through ``engine`` succeeded: close or reset its breaker."""
        if not self._entries:  # fast path: every healthy call lands here
            return
        with self._lock:
            entry = self._entries.get((engine, key.cache_key()))
            if entry is None:
                return
            if entry.state in ("half_open", "open"):
                entry.state = "closed"
                entry.failures = 0
                entry.opened_at = None
                obs.emit(
                    "resilience.breaker", state="closed", engine=engine,
                    key=key.cache_key(),
                )
                obs.count("resilience.breaker.close")
            else:
                entry.failures = 0

    # -- introspection ----------------------------------------------------

    def table(self) -> List[dict]:
        """Quarantine rows for ``xfft.report()`` (non-closed entries only)."""
        now = self.clock()
        with self._lock:
            rows = []
            for (engine, kstr), e in sorted(self._entries.items()):
                if e.state == "closed":
                    continue
                rows.append({
                    "engine": engine,
                    "key": kstr,
                    "state": e.state,
                    "failures": e.failures,
                    "cooldown_remaining_s": (
                        max(0.0, self.cooldown_s - (now - e.opened_at))
                        if e.state == "open" and e.opened_at is not None
                        else 0.0
                    ),
                    "last_error": e.last_error,
                })
            return rows

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()


_REGISTRY = QuarantineRegistry()


def quarantine() -> QuarantineRegistry:
    """The process-wide quarantine registry."""
    return _REGISTRY


def reset() -> None:
    """Drop all breaker state (tests; a deliberate ops 'unbench all')."""
    _REGISTRY.clear()


def configure(
    threshold: Optional[int] = None,
    cooldown_s: Optional[float] = None,
    clock: Optional[Callable[[], float]] = None,
) -> QuarantineRegistry:
    """Adjust the process-wide breaker policy in place (None = keep).

    In-place rather than replacing the singleton so modules that
    imported ``quarantine()`` results early never see a stale registry.
    """
    if threshold is not None:
        if threshold < 1:
            raise ValueError(f"threshold must be >= 1, got {threshold}")
        _REGISTRY.threshold = threshold
    if cooldown_s is not None:
        if cooldown_s <= 0:
            raise ValueError(f"cooldown_s must be > 0, got {cooldown_s}")
        _REGISTRY.cooldown_s = cooldown_s
    if clock is not None:
        _REGISTRY.clock = clock
    return _REGISTRY
