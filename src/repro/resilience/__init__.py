"""repro.resilience — fault injection, engine quarantine, degradation.

The robustness counterpart to :mod:`repro.obs`: where PR 6 made every
decision observable, this package makes every failure survivable — and
deliberately injectable, so survival is tested instead of hoped for.

* :mod:`.faults`   — ``FaultPlan``/``FaultSpec``: a seeded, frozen chaos
  schedule scoped via ``repro.xfft.config(faults=...)``; named seams
  across planner, cache, kernels, engines and serving.
* :mod:`.breaker`  — per-(engine, problem-key) circuit breakers
  (closed → open → cooldown → half-open probe → closed); the planner
  excludes quarantined engines from its candidate sweep.
* :mod:`.ladder`   — ``run_plan``: engine dispatch with failover down
  the ESTIMATE-ranked rungs to the always-works jnp engines, plus the
  opt-in ``check_health="nan"`` output guard.
* :mod:`.policies` — ``ServicePolicy``: per-request deadlines, bounded
  jittered retry, and queue-depth load shedding (typed ``Overloaded``)
  for the serve layer.

Layering: this package imports only ``repro.obs`` and the standard
library at module scope (the ladder reaches into the planner lazily),
so plan, engines, kernels, xfft and serve can all depend on it without
cycles.
"""

from repro.resilience.breaker import (
    QuarantineRegistry,
    configure,
    quarantine,
    reset,
)
from repro.resilience.faults import (
    FAULT_MODES,
    FAULT_SEAMS,
    FaultPlan,
    FaultSpec,
    InjectedFault,
    active_faults,
    pop_faults,
    push_faults,
)
from repro.resilience.ladder import run_plan
from repro.resilience.policies import (
    DeadlineExceeded,
    Overloaded,
    ServicePolicy,
    admit,
    execute_with_policy,
)

__all__ = [
    "FAULT_MODES",
    "FAULT_SEAMS",
    "DeadlineExceeded",
    "FaultPlan",
    "FaultSpec",
    "InjectedFault",
    "Overloaded",
    "QuarantineRegistry",
    "ServicePolicy",
    "active_faults",
    "admit",
    "configure",
    "execute_with_policy",
    "pop_faults",
    "push_faults",
    "quarantine",
    "reset",
    "run_plan",
]
