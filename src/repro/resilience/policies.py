"""Serving hardening policy: deadlines, bounded retry, load shedding.

A :class:`ServicePolicy` is the frozen knob-set the serve layer
(`ServeEngine`/`SpectrumService`/`ImagingService`) executes under:

* **deadline_s** — per-request wall-clock budget. A request that can't
  start (or retry) inside it fails fast with :class:`DeadlineExceeded`
  instead of occupying a batch slot forever.
* **max_retries / backoff_s / backoff_jitter** — bounded retry with
  exponential backoff and seeded jitter, so a transient engine failure
  costs one delayed batch, and a fleet of retrying servers doesn't
  thundering-herd in lockstep.
* **max_queue** — load shedding: past this queue depth, new work is
  rejected with the typed :class:`Overloaded` error (callers can back
  off) instead of growing the queue unboundedly.

:func:`execute_with_policy` is the single enforcement point; it consults
the ``serve.batch`` fault seam (:mod:`.faults`) on every attempt, so a
chaos plan targeting serving exercises the exact retry/deadline code
paths production failures would take. Retries emit ``resilience.retry``
events; sheds emit ``serve.shed``.
"""

from __future__ import annotations

import dataclasses
import random
import time
from typing import Any, Callable, Optional

from repro import obs
from repro.resilience import faults

__all__ = [
    "DeadlineExceeded",
    "Overloaded",
    "ServicePolicy",
    "admit",
    "execute_with_policy",
]


class Overloaded(RuntimeError):
    """Queue depth exceeded ``max_queue``: the service sheds this request.

    Typed (with ``depth``/``limit``) so callers can distinguish
    backpressure from failure and retry elsewhere/later.
    """

    def __init__(self, depth: int, limit: int):
        super().__init__(
            f"service overloaded: queue depth {depth} exceeds limit {limit}"
        )
        self.depth = depth
        self.limit = limit


class DeadlineExceeded(RuntimeError):
    """The request's ``deadline_s`` budget ran out before it completed."""

    def __init__(self, deadline_s: float, elapsed_s: float):
        super().__init__(
            f"deadline of {deadline_s:.3f}s exceeded after {elapsed_s:.3f}s"
        )
        self.deadline_s = deadline_s
        self.elapsed_s = elapsed_s


@dataclasses.dataclass(frozen=True)
class ServicePolicy:
    """Frozen serving policy; the default is maximally permissive (no
    deadline, no retry, no shedding) so existing callers see no change."""

    deadline_s: Optional[float] = None
    max_retries: int = 0
    backoff_s: float = 0.05
    backoff_jitter: float = 0.25
    max_queue: Optional[int] = None
    seed: int = 0

    def __post_init__(self):
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError(f"deadline_s must be > 0, got {self.deadline_s}")
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {self.max_retries}")
        if self.backoff_s < 0:
            raise ValueError(f"backoff_s must be >= 0, got {self.backoff_s}")
        if self.backoff_jitter < 0:
            raise ValueError(
                f"backoff_jitter must be >= 0, got {self.backoff_jitter}"
            )
        if self.max_queue is not None and self.max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {self.max_queue}")


def admit(policy: ServicePolicy, depth: int, **ctx: Any) -> None:
    """Shed (raise :class:`Overloaded`) when ``depth`` exceeds the policy.

    Call at enqueue/serve time with the *incoming* queue depth; emits a
    ``serve.shed`` event so dropped load is visible in ``xfft.report()``
    counters, not silent.
    """
    if policy.max_queue is not None and depth > policy.max_queue:
        obs.emit("serve.shed", depth=depth, limit=policy.max_queue, **ctx)
        obs.count("serve.shed")
        raise Overloaded(depth, policy.max_queue)


def execute_with_policy(
    policy: ServicePolicy,
    fn: Callable[[], Any],
    clock: Callable[[], float] = time.monotonic,
    sleep: Callable[[float], None] = time.sleep,
    **ctx: Any,
):
    """Run ``fn`` under the policy: deadline-checked, retried with backoff.

    ``fn`` is one batched execution attempt. The ``serve.batch`` fault
    seam fires inside each attempt (before ``fn``), so injected serve
    faults are retried exactly like real ones. :class:`Overloaded` and
    :class:`DeadlineExceeded` are never retried — backpressure and
    budget exhaustion are answers, not transients. ``clock``/``sleep``
    are injectable so tests drive deadlines without wall time.
    """
    rng = random.Random(policy.seed)
    start = clock()
    attempt = 0
    while True:
        if policy.deadline_s is not None:
            elapsed = clock() - start
            if elapsed >= policy.deadline_s:
                raise DeadlineExceeded(policy.deadline_s, elapsed)
        try:
            faults.maybe_fail("serve.batch", attempt=attempt, **ctx)
            return fn()
        except (Overloaded, DeadlineExceeded):
            raise
        except Exception as e:  # noqa: BLE001 — retry is the whole point
            attempt += 1
            if attempt > policy.max_retries:
                raise
            delay = policy.backoff_s * (2.0 ** (attempt - 1))
            delay *= 1.0 + policy.backoff_jitter * rng.random()
            if policy.deadline_s is not None:
                remaining = policy.deadline_s - (clock() - start)
                if remaining <= 0:
                    raise DeadlineExceeded(
                        policy.deadline_s, clock() - start
                    ) from e
                delay = min(delay, remaining)
            obs.emit(
                "resilience.retry", attempt=attempt, delay_s=delay,
                error=repr(e), **ctx,
            )
            obs.count("resilience.retry")
            sleep(delay)
