from repro.checkpoint.store import (
    latest_step,
    restore,
    restore_resharded,
    save,
    AsyncCheckpointer,
)

__all__ = ["save", "restore", "restore_resharded", "latest_step", "AsyncCheckpointer"]
