"""Fault-tolerant checkpointing: sharded npz + manifest, atomic rename,
async background writes, and elastic restore (re-shard onto a different mesh).

Layout:
  <dir>/step_<N>.tmp/ ... -> atomic rename -> <dir>/step_<N>/
      manifest.json       {step, leaf paths, shapes, dtypes, config_hash}
      arrays.npz          flat {path_i: array}
A partially-written checkpoint can never be picked up: ``latest_step`` only
sees fully-renamed directories.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _paths(tree):
    return [
        "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]
    ]


def config_hash(cfg) -> str:
    return hashlib.sha256(repr(cfg).encode()).hexdigest()[:16]


def save(ckpt_dir: str, step: int, tree: Any, extra: dict | None = None) -> str:
    """Atomic checkpoint write. Returns the final directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"step_{step}.tmp")
    final = os.path.join(ckpt_dir, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, _ = _flatten(tree)
    paths = _paths(tree)
    arrays = {f"a{i}": np.asarray(jax.device_get(l)) for i, l in enumerate(leaves)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "paths": paths,
        "shapes": [list(a.shape) for a in arrays.values()],
        "dtypes": [str(a.dtype) for a in arrays.values()],
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_", 1)[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
        and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json"))
    ]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Any) -> Any:
    """Restore into the structure of ``like`` (values replaced)."""
    path = os.path.join(ckpt_dir, f"step_{step}")
    with np.load(os.path.join(path, "arrays.npz")) as data:
        leaves = [data[f"a{i}"] for i in range(len(data.files))]
    like_leaves, treedef = _flatten(like)
    if len(leaves) != len(like_leaves):
        raise ValueError(
            f"checkpoint has {len(leaves)} leaves, expected {len(like_leaves)}"
        )
    out = [
        jnp.asarray(a, dtype=l.dtype) if hasattr(l, "dtype") else jnp.asarray(a)
        for a, l in zip(leaves, like_leaves)
    ]
    return jax.tree.unflatten(treedef, out)


def restore_resharded(ckpt_dir: str, step: int, like: Any, shardings: Any) -> Any:
    """Elastic restore: place restored host arrays with NEW shardings — this
    is how a run resumes on a different mesh (grown/shrunk data axis)."""
    tree = restore(ckpt_dir, step, like)
    return jax.tree.map(
        lambda a, s: jax.device_put(a, s) if s is not None else a, tree, shardings
    )


class AsyncCheckpointer:
    """Background-thread writer so the train loop never blocks on disk."""

    def __init__(self, ckpt_dir: str):
        self.ckpt_dir = ckpt_dir
        self._thread: threading.Thread | None = None
        self.last_saved: int | None = None

    def save_async(self, step: int, tree: Any, extra: dict | None = None):
        self.wait()
        host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), tree)

        def _work():
            save(self.ckpt_dir, step, host_tree, extra)
            self.last_saved = step

        self._thread = threading.Thread(target=_work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
