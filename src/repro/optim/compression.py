"""int8 gradient compression with error feedback (distributed-optimization
trick for the DP all-reduce; off by default, validated to converge in tests).

The DP mean is computed on int8-quantised tensors (per-tensor absmax scale);
the quantisation residual is fed back into the next step's gradient so the
bias vanishes over time (error-feedback SGD, Seide et al. / Karimireddy et al.).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_int8(g: jax.Array):
    scale = jnp.max(jnp.abs(g)).astype(jnp.float32) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array):
    return q.astype(jnp.float32) * scale


def compressed_mean(grads, error_state, axis_name=None):
    """Quantise (grad + error), average (optionally over ``axis_name``),
    return (mean_grads, new_error_state)."""

    def one(g, e):
        g_fb = g.astype(jnp.float32) + e
        q, scale = compress_int8(g_fb)
        deq = decompress_int8(q, scale)
        new_e = g_fb - deq
        if axis_name is not None:
            deq = jax.lax.pmean(deq, axis_name)
        return deq.astype(g.dtype), new_e

    out = jax.tree.map(one, grads, error_state)
    mean = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return mean, err


def init_error_state(grads):
    return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)
