from repro.optim.adamw import adamw_init, adamw_update, clip_by_global_norm, cosine_schedule
from repro.optim.compression import compress_int8, decompress_int8, compressed_mean

__all__ = [
    "adamw_init",
    "adamw_update",
    "clip_by_global_norm",
    "cosine_schedule",
    "compress_int8",
    "decompress_int8",
    "compressed_mean",
]
