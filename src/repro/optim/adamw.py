"""AdamW + global-norm clipping + cosine schedule (pure pytree functions)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params, dtype=jnp.float32):
    """``dtype=bf16`` halves optimizer memory for the XXL archs (the dry-run
    memory notes in EXPERIMENTS.md record when this is required to fit)."""
    zeros = lambda p: jnp.zeros_like(p, dtype=dtype)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def cosine_schedule(step, *, peak_lr=3e-4, warmup=100, total=10_000, floor=0.1):
    step = step.astype(jnp.float32)
    warm = peak_lr * jnp.minimum(step / max(warmup, 1), 1.0)
    t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < warmup, warm, peak_lr * cos)


def clip_by_global_norm(grads, max_norm=1.0):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def adamw_update(
    params,
    grads,
    state,
    *,
    lr=None,
    b1=0.9,
    b2=0.95,
    eps=1e-8,
    weight_decay=0.1,
    peak_lr=3e-4,
    warmup=100,
    total=10_000,
):
    step = state["step"] + 1
    lr_t = cosine_schedule(step, peak_lr=peak_lr, warmup=warmup, total=total) if lr is None else lr
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * g32
        nu = b2 * nu + (1 - b2) * jnp.square(g32)
        update = (mu / bc1) / (jnp.sqrt(nu / bc2) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr_t * update).astype(p.dtype), mu, nu

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"mu": new_mu, "nu": new_nu, "step": step}
