"""xLSTM blocks: mLSTM (matrix memory, parallelisable) and sLSTM (scalar
memory, strictly recurrent), after Beck et al. 2024 (arXiv:2405.04517).

mLSTM has two mathematically-equivalent forms (tested against each other):
  * parallel  — stabilised quadratic form for train/prefill;
  * recurrent — O(1) (C, n, m) state update for decode (long_500k eligible).
sLSTM is a lax.scan over time in both modes (exponential gating with the
m-stabiliser), with block-diagonal recurrent weights (4 heads).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.param import ParamDef
from repro.sharding.ctx import shard

NEG_INF = -1.0e30


# ------------------------------- mLSTM -------------------------------

def mlstm_skel(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_in = 2 * d                      # up-projection factor 2
    h = cfg.n_heads
    dh = d_in // h
    return {
        "up": ParamDef((d, 2 * d_in), ("embed", "mlp")),       # x_in, z gate
        "wq": ParamDef((d_in, h, dh), ("mlp", "heads", "head_dim")),
        "wk": ParamDef((d_in, h, dh), ("mlp", "heads", "head_dim")),
        "wv": ParamDef((d_in, h, dh), ("mlp", "heads", "head_dim")),
        "wi": ParamDef((d_in, h), ("mlp", "heads"), scale=0.1),
        "wf": ParamDef((d_in, h), ("mlp", "heads"), scale=0.1),
        "fb": ParamDef((h,), ("heads",), init="ones", scale=3.0),
        "norm": ParamDef((d_in,), ("mlp",), init="ones"),
        "down": ParamDef((d_in, d), ("mlp", "embed")),
    }


def mlstm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    d_in = 2 * cfg.d_model
    h = cfg.n_heads
    dh = d_in // h
    return {
        "c": jnp.zeros((batch, h, dh, dh), dtype),   # matrix memory (k ⊗ v)
        "n": jnp.zeros((batch, h, dh), dtype),
        "m": jnp.full((batch, h), -jnp.inf, dtype),
    }


def _mlstm_parallel(q, k, v, log_i, log_f):
    """Stabilised parallel mLSTM. q,k,v: (B,L,H,Dh); gates: (B,L,H) logs."""
    b, l, h, dh = q.shape
    lf_cum = jnp.cumsum(log_f, axis=1)                       # (B,L,H)
    # log D[t,s] = lfcum[t] − lfcum[s] + log_i[s]  for s ≤ t
    ld = lf_cum[:, :, None, :] - lf_cum[:, None, :, :] + log_i[:, None, :, :]
    mask = jnp.tril(jnp.ones((l, l), bool))
    ld = jnp.where(mask[None, :, :, None], ld, NEG_INF)
    m = jnp.max(ld, axis=2)                                  # (B,L,H) row-stabiliser
    d_mat = jnp.exp(ld - m[:, :, None, :])
    qk = jnp.einsum("blhd,bshd->blsh", q, k) / math.sqrt(dh)
    c = qk * d_mat
    n = jnp.maximum(jnp.abs(jnp.sum(c, axis=2)), jnp.exp(-m))  # (B,L,H)
    return jnp.einsum("blsh,bshd->blhd", c, v) / n[..., None]


def _mlstm_chunked(q, k, v, log_i, log_f, chunk: int, state0: dict):
    """Chunkwise mLSTM: intra-chunk quadratic + inter-chunk (C, n, m) carry.

    Peak score memory is (B, Q, Q, H) per chunk instead of (B, L, L, H) —
    the same decomposition SSD uses, applied to the mLSTM decay kernel.
    q,k,v: (B, L, H, Dh) f32; gates (B, L, H) log-space. Returns (y, state).
    """
    b, l, h, dh = q.shape
    nc = l // chunk
    q = (q / math.sqrt(dh)).reshape(b, nc, chunk, h, dh)
    k = k.reshape(b, nc, chunk, h, dh)
    v = v.reshape(b, nc, chunk, h, dh)
    li = log_i.reshape(b, nc, chunk, h)
    lf = log_f.reshape(b, nc, chunk, h)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))

    def step(carry, inp):
        c_prev, n_prev, m_prev = carry
        qc, kc, vc, lic, lfc = inp
        lf_cum = jnp.cumsum(lfc, axis=1)                     # (B,Q,H)
        ld = lf_cum[:, :, None, :] - lf_cum[:, None, :, :] + lic[:, None, :, :]
        ld = jnp.where(mask[None, :, :, None], ld, NEG_INF)
        local_max = jnp.max(ld, axis=2)                      # (B,Q,H)
        m_t = jnp.maximum(local_max, lf_cum + m_prev[:, None, :])
        inter = jnp.exp(lf_cum + m_prev[:, None, :] - m_t)   # (B,Q,H)
        num = jnp.einsum("bqhd,bhdv->bqhv", qc, c_prev) * inter[..., None]
        den = jnp.einsum("bqhd,bhd->bqh", qc, n_prev) * inter
        d_mat = jnp.exp(ld - m_t[:, :, None, :])
        cm = jnp.einsum("bqhd,bshd->bqsh", qc, kc) * d_mat
        num = num + jnp.einsum("bqsh,bshv->bqhv", cm, vc)
        den = jnp.maximum(jnp.abs(den + cm.sum(axis=2)), jnp.exp(-m_t))
        y = num / den[..., None]
        # end-of-chunk state
        lf_tot = lf_cum[:, -1]                               # (B,H)
        tail = lf_tot[:, None, :] - lf_cum + lic             # (B,Q,H)
        m_next = jnp.maximum(m_prev + lf_tot, jnp.max(tail, axis=1))
        b_scale = jnp.exp(tail - m_next[:, None, :])
        c_carry = jnp.exp(m_prev + lf_tot - m_next)
        c_new = c_prev * c_carry[..., None, None] + jnp.einsum(
            "bshd,bsh,bshv->bhdv", kc, b_scale, vc
        )
        n_new = n_prev * c_carry[..., None] + jnp.einsum("bshd,bsh->bhd", kc, b_scale)
        return (c_new, n_new, m_next), y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (q, k, v, li, lf))
    (c, n, m), ys = jax.lax.scan(
        step, (state0["c"], state0["n"], state0["m"]), xs
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(b, l, h, dh)
    return y, {"c": c, "n": n, "m": m}


def _mlstm_recurrent_step(state, q, k, v, log_i, log_f):
    """One decode step. q,k,v: (B,H,Dh); gates (B,H) logs. Returns (h, state)."""
    dh = q.shape[-1]
    m_new = jnp.maximum(log_f + state["m"], log_i)
    i_sc = jnp.exp(log_i - m_new)
    f_sc = jnp.exp(log_f + state["m"] - m_new)
    c = state["c"] * f_sc[..., None, None] + i_sc[..., None, None] * (
        k[..., :, None] * v[..., None, :]
    )
    n = state["n"] * f_sc[..., None] + i_sc[..., None] * k
    qs = q / math.sqrt(dh)
    num = jnp.einsum("bhd,bhdv->bhv", qs, c)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qs, n)), jnp.exp(-m_new))
    return num / den[..., None], {"c": c, "n": n, "m": m_new}


def mlstm_apply(p, x, cfg: ModelConfig, *, state=None, decode=False):
    """Returns (y, new_state). x: (B, L, D)."""
    d_in = 2 * cfg.d_model
    h = cfg.n_heads
    dt = x.dtype
    up = shard(jnp.einsum("bld,dk->blk", x, p["up"].astype(dt)), "dp", None, "tp")
    x_in, z = up[..., :d_in], up[..., d_in:]
    # 4 heads can't TP-shard: run the recurrence 2-D batch-parallel instead.
    bt = "dp" if decode else "dp+tp"
    q = shard(
        jnp.einsum("blk,khd->blhd", x_in, p["wq"].astype(dt)).astype(jnp.float32),
        bt, None, None, None,
    )
    k = shard(
        jnp.einsum("blk,khd->blhd", x_in, p["wk"].astype(dt)).astype(jnp.float32),
        bt, None, None, None,
    )
    v = shard(
        jnp.einsum("blk,khd->blhd", x_in, p["wv"].astype(dt)).astype(jnp.float32),
        bt, None, None, None,
    )
    log_i = jnp.einsum("blk,kh->blh", x_in.astype(jnp.float32), p["wi"])
    log_f = jax.nn.log_sigmoid(
        jnp.einsum("blk,kh->blh", x_in.astype(jnp.float32), p["wf"]) + p["fb"]
    )

    if decode:
        assert state is not None
        y1, new_state = _mlstm_recurrent_step(
            state, q[:, 0], k[:, 0], v[:, 0], log_i[:, 0], log_f[:, 0]
        )
        y = y1[:, None]  # (B,1,H,Dh)
    else:
        l0 = q.shape[1]
        chunk = min(256, l0)
        pad = (-l0) % chunk
        if pad:
            # state-neutral padding: log_f=0 (decay 1), log_i=-inf (no write)
            zpad = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
            q, k, v = zpad(q), zpad(k), zpad(v)
            log_f = zpad(log_f)
            log_i = jnp.pad(
                log_i, [(0, 0), (0, pad), (0, 0)], constant_values=NEG_INF
            )
        s0 = state if state is not None else mlstm_state(cfg, x.shape[0])
        y, new_state = _mlstm_chunked(q, k, v, log_i, log_f, chunk, s0)
        y = y[:, :l0]
        if state is None:
            new_state = None

    y = y.reshape(x.shape[0], -1, d_in).astype(dt)
    # gated output norm + down-projection
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y * jax.lax.rsqrt(var + cfg.rms_eps).astype(dt)) * p["norm"].astype(dt)
    out = jnp.einsum("blk,kd->bld", y, p["down"].astype(dt))
    return shard(out, "dp", None, None), new_state


# ------------------------------- sLSTM -------------------------------

_SLSTM_HEADS = 4


def _round128(n: int) -> int:
    return (n + 127) // 128 * 128


def slstm_skel(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    hd = d // _SLSTM_HEADS
    # xLSTM's 4/3 FF factor, rounded up to a lane-aligned (and TP-shardable)
    # multiple of 128 — hardware adaptation noted in DESIGN.md.
    ff = _round128((4 * d) // 3) if d >= 96 else (4 * d) // 3
    return {
        # The strictly-sequential recurrence distributes over BATCH only:
        # TP-sharding wx/wr forced a reshard every timestep (pathological
        # "involuntary full rematerialization" in the SPMD partitioner), so
        # the in-loop weights stay replicated and small.
        "wx": ParamDef((d, 4 * d), ("embed", None)),           # i,f,z,o from input
        "wr": ParamDef((_SLSTM_HEADS, hd, 4 * hd), (None, None, None), scale=0.5),
        "bias": ParamDef((4 * d,), (None,), init="zeros"),
        "norm": ParamDef((d,), ("embed",), init="ones"),
        "ff_up": ParamDef((d, ff), ("embed", "mlp")),
        "ff_down": ParamDef((ff, d), ("mlp", "embed")),
    }


def slstm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), dtype),
        "n": jnp.zeros((batch, d), dtype),
        "h": jnp.zeros((batch, d), dtype),
        "m": jnp.full((batch, d), -jnp.inf, dtype),
    }


def _slstm_step(p, s, x_t, d: int):
    """One sLSTM time step (exponential gating, m-stabilised)."""
    hd = d // _SLSTM_HEADS
    hprev = s["h"].reshape(-1, _SLSTM_HEADS, hd)
    rec = jnp.einsum("bhk,hkj->bhj", hprev, p["wr"]).reshape(-1, 4 * d)
    gates = x_t + rec + p["bias"]
    it, ft, zt, ot = jnp.split(gates, 4, axis=-1)
    log_i = it
    log_f = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(log_f + s["m"], log_i)
    i_sc = jnp.exp(log_i - m_new)
    f_sc = jnp.exp(log_f + s["m"] - m_new)
    c = f_sc * s["c"] + i_sc * jnp.tanh(zt)
    n = f_sc * s["n"] + i_sc
    h = jax.nn.sigmoid(ot) * c / jnp.maximum(n, 1e-6)
    return {"c": c, "n": n, "h": h, "m": m_new}


def slstm_apply(p, x, cfg: ModelConfig, *, state=None, decode=False):
    """Returns (y, new_state). Sequential over L in both modes."""
    d = cfg.d_model
    dt = x.dtype
    b = x.shape[0]
    xg = jnp.einsum("bld,dk->blk", x.astype(jnp.float32), p["wx"])
    s0 = state if state is not None else slstm_state(cfg, b)

    if decode:
        s_new = _slstm_step(p, s0, xg[:, 0], d)
        hs = s_new["h"][:, None]
    else:
        def step(s, x_t):
            s2 = _slstm_step(p, s, x_t, d)
            return s2, s2["h"]

        s_new, hs = jax.lax.scan(step, s0, jnp.moveaxis(xg, 0, 1))
        hs = jnp.moveaxis(hs, 0, 1)  # (B, L, D)

    y = hs.astype(dt)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y * jax.lax.rsqrt(var + cfg.rms_eps).astype(dt)) * p["norm"].astype(dt)
    h = jax.nn.gelu(
        shard(jnp.einsum("bld,df->blf", y, p["ff_up"].astype(dt)), "dp", None, "tp")
    )
    out = shard(jnp.einsum("blf,fd->bld", h, p["ff_down"].astype(dt)), "dp", None, None)
    return out, (s_new if (state is not None or decode) else None)
