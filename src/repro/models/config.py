"""ModelConfig — every assigned architecture is an instance of this."""

from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "hybrid", "ssm", "audio", "vlm", "spectral"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    d_ff_expert: int = 2048
    n_shared_experts: int = 0
    n_dense_layers: int = 0          # leading layers that stay dense
    router_norm: Literal["softmax", "sigmoid"] = "softmax"
    capacity_factor: float = 1.25
    impl: Literal["grouped_local", "ep_a2a", "dense_small"] = "grouped_local"
    ep_axes: tuple = ()                  # mesh axes for expert parallelism
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None          # default d_model // n_heads
    seq_pad_to_pow2: bool = False        # spectral archs need pow-2 seq
    rope_theta: float = 10000.0
    rms_eps: float = 1e-5
    attention: Literal["gqa", "mla", "none"] = "gqa"
    sliding_window: int | None = None    # SWA (mixtral)
    tie_embeddings: bool = False
    act: Literal["swiglu", "gelu"] = "swiglu"
    # encoder-decoder (audio family)
    n_enc_layers: int = 0
    enc_frames: int = 1500               # whisper 30 s encoder length
    # hybrid (zamba2): shared attention block every k SSM layers
    shared_attn_every: int = 6
    # xLSTM: alternate mLSTM/sLSTM
    slstm_every: int = 2                 # every k-th block is sLSTM
    # vlm: number of patch-embedding positions provided by the stub frontend
    n_patches: int = 256
    # spectral (fourier_lm): use the paper's engine as the mixing layer
    # ("auto" = the repro.plan-backed unified default; see repro.xfft)
    fft_variant: str = "auto"
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    # training-time knobs
    remat: bool = True
    remat_policy: Literal["full", "dots"] = "full"  # "dots": save matmul outputs
    scan_layers: bool = True
    attn_block_q: int = 512
    attn_block_k: int = 1024
    compute_dtype: str = "bfloat16"
    # long_500k eligibility (sub-quadratic sequence mixing)
    subquadratic: bool = False
    # deepseek-v3 multi-token prediction head
    mtp: bool = False
    mtp_weight: float = 0.3

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def scaled(self, **overrides) -> "ModelConfig":
        """A reduced copy for smoke tests (same family/topology, tiny dims)."""
        return dataclasses.replace(self, **overrides)
