"""Mixture-of-Experts: token-choice top-k routing with three dispatch paths.

  * ``dense_small``   — every expert on every token (tiny E, smoke tests).
  * ``grouped_local`` — capacity-grouped batched matmul per batch row; no
    cross-device dispatch (experts replicated/FSDP over data, hidden TP over
    model). The paper-faithful-baseline path for the MoE archs.
  * ``ep_a2a``        — expert parallelism: experts sharded over the data
    axis, tokens exchanged with all_to_all (beyond-paper optimization for
    the collective-bound cells; see EXPERIMENTS.md §Perf).

All paths share the router and the (E, D, F) expert weight layout, drop
over-capacity tokens (standard dropped-token semantics), and return an
auxiliary load-balance loss.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
from repro import compat
import jax.numpy as jnp

from repro.models.config import ModelConfig, MoEConfig
from repro.models.param import ParamDef
from repro.sharding.ctx import shard


def moe_skel(cfg: ModelConfig) -> dict:
    m: MoEConfig = cfg.moe
    d, f, e = cfg.d_model, m.d_ff_expert, m.n_experts
    skel = {
        "router": ParamDef((d, e), ("embed", "experts")),
        "wg": ParamDef((e, d, f), ("experts", "embed", "mlp")),
        "wu": ParamDef((e, d, f), ("experts", "embed", "mlp")),
        "wd": ParamDef((e, f, d), ("experts", "mlp", "embed")),
    }
    if m.n_shared_experts:
        fs = f * m.n_shared_experts
        skel["shared"] = {
            "wg": ParamDef((d, fs), ("embed", "mlp")),
            "wu": ParamDef((d, fs), ("embed", "mlp")),
            "wd": ParamDef((fs, d), ("mlp", "embed")),
        }
    return skel


def _router(p, x, m: MoEConfig):
    """Returns (gates (..., k), expert_ids (..., k) int32, aux_loss scalar)."""
    logits = jnp.einsum(
        "...d,de->...e", x.astype(jnp.float32), p["router"].astype(jnp.float32)
    )
    if m.router_norm == "sigmoid":  # deepseek-v3 style
        scores = jax.nn.sigmoid(logits)
        gates, ids = jax.lax.top_k(scores, m.top_k)
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    else:  # mixtral style: softmax over the selected logits
        top_logits, ids = jax.lax.top_k(logits, m.top_k)
        gates = jax.nn.softmax(top_logits, axis=-1)
    # Switch-style load-balance aux: E * sum_e f_e * p_e
    probs = jax.nn.softmax(logits, axis=-1)
    e = logits.shape[-1]
    frac = jnp.mean(
        jnp.sum(jax.nn.one_hot(ids, e, dtype=jnp.float32), axis=-2),
        axis=tuple(range(ids.ndim - 1)),
    )  # fraction routed per expert (×k)
    mean_prob = jnp.mean(probs, axis=tuple(range(probs.ndim - 1)))
    aux = e * jnp.sum(frac / m.top_k * mean_prob)
    return gates.astype(x.dtype), ids.astype(jnp.int32), aux


def _expert_ffn(wg, wu, wd, h, act: str = "swiglu"):
    """h: (E, C, D) grouped tokens; per-expert FFN (ep_a2a path, shard_map)."""
    dt = h.dtype
    g = jnp.einsum("ecd,edf->ecf", h, wg.astype(dt))
    u = jnp.einsum("ecd,edf->ecf", h, wu.astype(dt))
    a = jax.nn.silu(g) * u if act == "swiglu" else jax.nn.gelu(g) * u
    return jnp.einsum("ecf,efd->ecd", a, wd.astype(dt))


def _expert_ffn_batched(wg, wu, wd, h, act: str = "swiglu"):
    """h: (B, E, C, D); batch stays dp-sharded, expert hidden is TP'd."""
    dt = h.dtype
    g = shard(jnp.einsum("becd,edf->becf", h, wg.astype(dt)), "dp", None, None, "tp")
    u = shard(jnp.einsum("becd,edf->becf", h, wu.astype(dt)), "dp", None, None, "tp")
    a = jax.nn.silu(g) * u if act == "swiglu" else jax.nn.gelu(g) * u
    y = jnp.einsum("becf,efd->becd", a, wd.astype(dt))
    return shard(y, "dp", None, None, None)


def _group_by_expert(ids_flat: jax.Array, n_experts: int, capacity: int):
    """Sort assignment slots by expert; compute each slot's position in its
    expert group (without materialising an (A, E) cumsum).

    Returns (order, slot, keep): ``order`` sorts assignments by expert,
    ``slot`` is the flat (e*C + pos) destination (clipped), ``keep`` masks
    assignments that fit under capacity.
    """
    a = ids_flat.shape[0]
    order = jnp.argsort(ids_flat, stable=True)
    sorted_ids = ids_flat[order]
    idx = jnp.arange(a, dtype=jnp.int32)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_ids[1:] != sorted_ids[:-1]]
    )
    seg_start = jax.lax.associative_scan(jnp.maximum, jnp.where(is_start, idx, 0))
    pos = idx - seg_start
    keep = pos < capacity
    slot = sorted_ids * capacity + jnp.minimum(pos, capacity - 1)
    return order, slot, keep


def _moe_grouped_rows(p, x, m: MoEConfig, act: str):
    """Per-batch-row capacity grouping, explicitly batched (vmap-free so the
    sharding constraints apply). x: (B, S, D) -> (B, S, D)."""
    b, s, d = x.shape
    gates, ids, aux = _router(p, x, m)
    k = m.top_k
    e = m.n_experts
    capacity = max(1, int(s * k / e * m.capacity_factor))
    a = s * k

    ids_flat = ids.reshape(b, a)
    gate_flat = gates.reshape(b, a)
    tok_of_a = jnp.broadcast_to(
        jnp.repeat(jnp.arange(s, dtype=jnp.int32), k)[None], (b, a)
    )
    order = jnp.argsort(ids_flat, axis=-1, stable=True)
    sorted_ids = jnp.take_along_axis(ids_flat, order, axis=-1)
    idx = jnp.broadcast_to(jnp.arange(a, dtype=jnp.int32)[None], (b, a))
    is_start = jnp.concatenate(
        [jnp.ones((b, 1), bool), sorted_ids[:, 1:] != sorted_ids[:, :-1]], axis=1
    )
    seg_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(is_start, idx, 0), axis=1
    )
    pos = idx - seg_start
    keep = pos < capacity
    slot = sorted_ids * capacity + jnp.minimum(pos, capacity - 1)      # (B, A)
    tok_sorted = jnp.take_along_axis(tok_of_a, order, axis=-1)
    gate_sorted = jnp.where(keep, jnp.take_along_axis(gate_flat, order, -1), 0.0)

    x_sorted = jnp.take_along_axis(x, tok_sorted[..., None], axis=1)   # (B, A, D)
    x_sorted = jnp.where(keep[..., None], x_sorted, 0)
    grouped = jnp.zeros((b, e * capacity, d), x.dtype)
    rows = jnp.arange(b, dtype=jnp.int32)[:, None]
    grouped = grouped.at[rows, slot].add(x_sorted)
    grouped = shard(grouped.reshape(b, e, capacity, d), "dp", None, None, None)

    h = _expert_ffn_batched(p["wg"], p["wu"], p["wd"], grouped, act)
    h = h.reshape(b, e * capacity, d)

    y_sorted = jnp.take_along_axis(h, slot[..., None], axis=1) * gate_sorted[..., None]
    y = jnp.zeros_like(x)
    y = y.at[rows, tok_sorted].add(jnp.where(keep[..., None], y_sorted, 0.0))
    return shard(y, "dp", None, None), aux


def _moe_dense_small(p, x, m: MoEConfig, act: str):
    """All experts on all tokens, combined by gate weights (tiny E only)."""
    gates, ids, aux = _router(p, x, m)
    combine = jnp.sum(
        jax.nn.one_hot(ids, m.n_experts, dtype=x.dtype) * gates[..., None], axis=-2
    )  # (..., E)
    dt = x.dtype
    g = jnp.einsum("bsd,edf->besf", x, p["wg"].astype(dt))
    u = jnp.einsum("bsd,edf->besf", x, p["wu"].astype(dt))
    a = jax.nn.silu(g) * u if act == "swiglu" else jax.nn.gelu(g) * u
    h = jnp.einsum("besf,efd->besd", a, p["wd"].astype(dt))
    y = jnp.einsum("besd,bse->bsd", h, combine)
    return y, aux


def _moe_ep_a2a(p, x, m: MoEConfig, act: str, ep_axis):
    """Expert-parallel dispatch: experts sharded over ``ep_axis`` (shard_map).

    Per EP rank: route local tokens, bucket them by destination rank
    (fixed send capacity), all_to_all, run local experts, all_to_all back,
    combine. Two activation-sized collectives instead of per-layer weight
    gathering — the collective-term optimization for the MoE cells.
    """
    axis_size = compat.axis_size(ep_axis)
    e_loc = m.n_experts // axis_size
    b, s, d = x.shape  # local shapes inside shard_map
    gates, ids, aux = _router(p, x, m)
    k = m.top_k
    t = b * s
    x_flat = x.reshape(t, d)
    ids_flat = ids.reshape(t * k)
    gates_flat = gates.reshape(t * k)
    tok_of_a = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)

    # Bucket assignments by destination EP rank, fixed capacity per rank.
    cap_send = max(1, int(t * k / axis_size * m.capacity_factor))
    dest = ids_flat // e_loc
    order, slot, keep = _group_by_expert(dest, axis_size, cap_send)
    send_x = jnp.zeros((axis_size * cap_send, d), x.dtype)
    send_x = send_x.at[slot].add(
        jnp.where(keep[:, None], x_flat[tok_of_a[order]], 0.0)
    )
    send_eid = jnp.full((axis_size * cap_send,), -1, jnp.int32)
    send_eid = send_eid.at[slot].set(
        jnp.where(keep, ids_flat[order] % e_loc, -1)
    )
    # Exchange tokens.
    recv_x = jax.lax.all_to_all(
        send_x.reshape(axis_size, cap_send, d), ep_axis, 0, 0, tiled=False
    ).reshape(axis_size * cap_send, d)
    recv_eid = jax.lax.all_to_all(
        send_eid.reshape(axis_size, cap_send), ep_axis, 0, 0, tiled=False
    ).reshape(axis_size * cap_send)

    # Group received tokens by local expert and run the FFN.
    cap_e = max(1, int(recv_x.shape[0] * m.capacity_factor / e_loc))
    r_order, r_slot, r_keep = _group_by_expert(
        jnp.where(recv_eid >= 0, recv_eid, e_loc), e_loc + 1, cap_e
    )
    grouped = jnp.zeros(((e_loc + 1) * cap_e, d), x.dtype)
    grouped = grouped.at[r_slot].add(
        jnp.where(r_keep[:, None], recv_x[r_order], 0.0)
    )
    h = _expert_ffn(
        p["wg"], p["wu"], p["wd"], grouped.reshape(e_loc + 1, cap_e, d)[:e_loc], act
    )
    h_flat = jnp.concatenate(
        [h.reshape(e_loc * cap_e, d), jnp.zeros((cap_e, d), h.dtype)], axis=0
    )
    y_recv = jnp.zeros_like(recv_x).at[r_order].add(
        jnp.where(r_keep[:, None], h_flat[r_slot], 0.0)
    )
    # Send results home.
    back = jax.lax.all_to_all(
        y_recv.reshape(axis_size, cap_send, d), ep_axis, 0, 0, tiled=False
    ).reshape(axis_size * cap_send, d)
    y_assign = back[slot] * jnp.where(keep, gates_flat[order], 0.0)[:, None]
    y_flat = jnp.zeros_like(x_flat).at[tok_of_a[order]].add(y_assign)
    return y_flat.reshape(b, s, d), aux


def _moe_ep_shard_map(p, x, m: MoEConfig, act: str, ep_axes: tuple):
    """Run the EP dispatch under shard_map: tokens + experts sharded over
    ``ep_axes``; the model ("TP") axis stays GSPMD-automatic.

    Collective profile per layer: 2 activation-sized all_to_alls instead of
    gathering every expert's weights (the §Perf cell-A optimization) — and
    expert-weight gradients become rank-local (no DP all-reduce for them).
    """
    from jax.sharding import PartitionSpec as P

    mesh = compat.get_abstract_mesh()
    axis_name = ep_axes if len(ep_axes) > 1 else ep_axes[0]
    manual = set(ep_axes)
    auto = frozenset(a for a in mesh.axis_names if a not in manual)

    @functools.partial(
        compat.shard_map,
        mesh=mesh,
        in_specs=(
            {
                "router": P(),
                "wg": P(ep_axes, None, None),
                "wu": P(ep_axes, None, None),
                "wd": P(ep_axes, None, None),
            },
            P(ep_axes, None, None),
        ),
        out_specs=(P(ep_axes, None, None), P()),
        axis_names=manual,
    )
    def inner(p_loc, x_loc):
        y, aux = _moe_ep_a2a(p_loc, x_loc, m, act, axis_name)
        return y, jax.lax.pmean(aux, axis_name)

    routed = {k: p[k] for k in ("router", "wg", "wu", "wd")}
    return inner(routed, x)


def moe_apply(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    ep_axis: Any = None,
):
    """Returns (y, aux_loss). Adds shared experts if configured."""
    m: MoEConfig = cfg.moe
    impl = m.impl
    ep_axes = tuple(ep_axis) if ep_axis else tuple(m.ep_axes)
    if impl == "ep_a2a":
        mesh = compat.get_abstract_mesh()
        if not ep_axes or mesh.empty or any(a not in mesh.axis_names for a in ep_axes):
            impl = "grouped_local"  # no mesh context (CPU smoke tests)
    if impl == "dense_small":
        y, aux = _moe_dense_small(p, x, m, cfg.act)
    elif impl == "ep_a2a":
        y, aux = _moe_ep_shard_map(p, x, m, cfg.act, ep_axes)
    else:
        y, aux = _moe_grouped_rows(p, x, m, cfg.act)
    if m.n_shared_experts:
        sp = p["shared"]
        dt = x.dtype
        x = shard(x, "dp", None, None)  # pins the bwd cotangent (see layers.mlp)
        g = shard(jnp.einsum("bsd,df->bsf", x, sp["wg"].astype(dt)), "dp", None, "tp")
        u = shard(jnp.einsum("bsd,df->bsf", x, sp["wu"].astype(dt)), "dp", None, "tp")
        a = jax.nn.silu(g) * u if cfg.act == "swiglu" else jax.nn.gelu(g) * u
        y = y + jnp.einsum("bsf,fd->bsd", a, sp["wd"].astype(dt))
    return shard(y, "dp", None, None), aux
