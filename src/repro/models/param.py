"""Module-less parameter system: models are (skeleton, pure functions).

A *skeleton* is a pytree of ``ParamDef`` describing every weight: shape,
dtype, init, and **logical axes** (names like "embed", "heads", "mlp").
From a skeleton we derive, without ever allocating:

  * ``init_params``      — concrete arrays (CPU smoke tests, real training)
  * ``abstract_params``  — ShapeDtypeStructs (the multi-pod dry-run)
  * ``partition_specs``  — PartitionSpec per leaf, via per-config sharding
                           rules (``repro.sharding.rules``)

This is what lets the 671B-parameter configs lower+compile on one CPU.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    logical_axes: tuple[str | None, ...]
    dtype: Any = jnp.float32
    init: str = "normal"  # "normal" | "zeros" | "ones" | "scaled"
    scale: float = 1.0

    def __post_init__(self):
        if len(self.shape) != len(self.logical_axes):
            raise ValueError(
                f"rank mismatch: shape {self.shape} vs axes {self.logical_axes}"
            )


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def init_params(skeleton, key: jax.Array, dtype=None):
    """Materialise a skeleton into concrete arrays."""
    leaves, treedef = jax.tree.flatten(skeleton, is_leaf=_is_def)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, d in zip(keys, leaves):
        dt = dtype or d.dtype
        if d.init == "zeros":
            out.append(jnp.zeros(d.shape, dt))
        elif d.init == "ones":
            out.append(jnp.ones(d.shape, dt))
        else:
            fan_in = d.shape[0] if d.shape else 1
            std = d.scale * (1.0 / math.sqrt(max(fan_in, 1)))
            out.append((jax.random.normal(k, d.shape, jnp.float32) * std).astype(dt))
    return jax.tree.unflatten(treedef, out)


def abstract_params(skeleton, dtype=None):
    """ShapeDtypeStruct tree — zero allocation (dry-run path)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype or d.dtype),
        skeleton,
        is_leaf=_is_def,
    )


def partition_specs(skeleton, rules: dict[str, Any]):
    """logical axes -> PartitionSpec using a {logical_name: mesh_axes} map.

    Unknown logical names are replicated. ``rules`` values may be None, a
    mesh-axis name, or a tuple of mesh-axis names.
    """
    from jax.sharding import PartitionSpec as P

    def one(d: ParamDef):
        spec = []
        used: set[str] = set()
        for a in d.logical_axes:
            r = rules.get(a) if a is not None else None
            axes = (r,) if isinstance(r, str) else tuple(r or ())
            # a mesh axis may appear at most once per spec (first wins)
            axes = tuple(ax for ax in axes if ax not in used)
            used.update(axes)
            if not axes:
                spec.append(None)
            elif len(axes) == 1:
                spec.append(axes[0])
            else:
                spec.append(axes)
        return P(*spec)

    return jax.tree.map(one, skeleton, is_leaf=_is_def)


def param_count(skeleton) -> int:
    leaves = jax.tree.leaves(skeleton, is_leaf=_is_def)
    return int(sum(int(np.prod(d.shape)) for d in leaves))


def param_bytes(skeleton) -> int:
    leaves = jax.tree.leaves(skeleton, is_leaf=_is_def)
    return int(
        sum(int(np.prod(d.shape)) * jnp.dtype(d.dtype).itemsize for d in leaves)
    )


def stack_defs(d: ParamDef, n: int, axis_name: str = "layers") -> ParamDef:
    """Add a leading stacked-layer dimension (for scan-over-layers)."""
    return dataclasses.replace(
        d,
        shape=(n, *d.shape),
        logical_axes=(axis_name, *d.logical_axes),
    )


def stack_skeleton(skel, n: int, axis_name: str = "layers"):
    return jax.tree.map(lambda d: stack_defs(d, n, axis_name), skel, is_leaf=_is_def)
