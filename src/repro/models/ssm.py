"""Mamba2 (State-Space Duality) block: chunked parallel scan + recurrent decode.

Parallel (train/prefill) path is the standard SSD chunk decomposition:
intra-chunk quadratic attention-like term + inter-chunk state recurrence.
Decode path is the O(1) recurrent update on a (H, P, N) state — this is what
makes the hybrid/ssm archs eligible for the long_500k shape.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, SSMConfig
from repro.models.param import ParamDef
from repro.sharding.ctx import shard


def mamba2_skel(cfg: ModelConfig) -> dict:
    s: SSMConfig = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    nh = d_in // s.head_dim
    conv_dim = d_in + 2 * s.d_state
    return {
        "in_proj": ParamDef(
            (d, 2 * d_in + 2 * s.d_state + nh), ("embed", "ssm_in")
        ),
        "conv_w": ParamDef((s.d_conv, conv_dim), (None, "ssm_in"), scale=0.5),
        "conv_b": ParamDef((conv_dim,), ("ssm_in",), init="zeros"),
        "a_log": ParamDef((nh,), ("heads",), init="zeros"),
        "dt_bias": ParamDef((nh,), ("heads",), init="zeros"),
        "d_skip": ParamDef((nh,), ("heads",), init="ones"),
        "norm": ParamDef((d_in,), ("mlp",), init="ones"),
        "out_proj": ParamDef((d_in, d), ("mlp", "embed")),
    }


def mamba2_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    s: SSMConfig = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    conv_dim = d_in + 2 * s.d_state
    return {
        "ssd": jnp.zeros((batch, nh, s.head_dim, s.d_state), dtype),
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
    }


def _split_proj(z, d_in, d_state, nh):
    zx = z[..., :d_in]
    xbc = z[..., d_in : 2 * d_in + 2 * d_state]
    dt = z[..., 2 * d_in + 2 * d_state :]
    return zx, xbc, dt


def _causal_conv(xbc, w, b, conv_state=None):
    """Depthwise causal conv over time. xbc: (B, L, C); w: (K, C)."""
    k = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[-1]), xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(
        xp[:, i : i + xbc.shape[1]] * w[i].astype(xbc.dtype) for i in range(k)
    )
    new_state = xp[:, xp.shape[1] - (k - 1) :]
    return jax.nn.silu(out + b.astype(xbc.dtype)), new_state


def _gated_rmsnorm(y, z, w, eps=1e-5):
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    return (y * jax.lax.rsqrt(var + eps).astype(y.dtype)) * w.astype(y.dtype)


def _ssd_chunked(x, dt, a, b_mat, c_mat, d_skip, chunk, init_state=None):
    """SSD parallel form.

    x: (B, L, H, P); dt: (B, L, H) (post-softplus); a: (H,) negative;
    b_mat/c_mat: (B, L, N); returns (y (B,L,H,P), final_state (B,H,P,N)).
    """
    bsz, l, h, p = x.shape
    n = b_mat.shape[-1]
    nc = l // chunk
    xc = x.reshape(bsz, nc, chunk, h, p)
    dtc = dt.reshape(bsz, nc, chunk, h)
    bc = b_mat.reshape(bsz, nc, chunk, n)
    cc = c_mat.reshape(bsz, nc, chunk, n)

    da = dtc * a[None, None, None, :]              # (B,nc,cs,H) negative increments
    da_cum = jnp.cumsum(da, axis=2)
    x_dt = xc * dtc[..., None]

    # Intra-chunk (masked decay kernel): y[i] += sum_{j<=i} C_i·B_j e^{cum_i-cum_j} x_dt[j]
    seg = da_cum[:, :, :, None, :] - da_cum[:, :, None, :, :]   # (B,nc,i,j,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    # mask BEFORE exp: exp of the (positive) upper triangle would overflow and
    # poison the where-gradient with 0·inf.
    seg = jnp.where(mask[None, None, :, :, None], seg, -1e9)
    decay = jnp.exp(seg)
    cb = jnp.einsum("bcin,bcjn->bcij", cc, bc)                  # (B,nc,i,j)
    y_diag = jnp.einsum("bcij,bcijh,bcjhp->bcihp", cb, decay.astype(x.dtype), x_dt)

    # Chunk summary states: S_c = sum_j e^{cum_last - cum_j} B_j x_dt[j]
    decay_to_end = jnp.exp(da_cum[:, :, -1:, :] - da_cum)        # (B,nc,cs,H)
    states = jnp.einsum(
        "bcjn,bcjh,bcjhp->bchpn", bc, decay_to_end.astype(x.dtype), x_dt
    )

    # Inter-chunk recurrence (sequential over nc chunks).
    chunk_decay = jnp.exp(da_cum[:, :, -1, :])                   # (B,nc,H)
    s0 = (
        jnp.zeros((bsz, h, p, n), x.dtype)
        if init_state is None
        else init_state.astype(x.dtype)
    )

    def step(s_prev, inp):
        st, dec = inp
        s_new = s_prev * dec[..., None, None] + st
        return s_new, s_prev

    (s_final, s_prevs) = jax.lax.scan(
        step,
        s0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay.astype(x.dtype), 1, 0)),
    )
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)                        # (B,nc,H,P,N)

    # Off-diagonal: y[i] += C_i e^{cum_i} S_{c-1}
    decay_from_start = jnp.exp(da_cum).astype(x.dtype)           # (B,nc,cs,H)
    y_off = jnp.einsum(
        "bcin,bchpn,bcih->bcihp", cc, s_prevs, decay_from_start
    )
    y = (y_diag + y_off).reshape(bsz, l, h, p) + x * d_skip[None, None, :, None]
    return y, s_final


def mamba2_apply(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    state: dict | None = None,
    decode: bool = False,
):
    """Returns (y, new_state). x: (B, L, D) (L == 1 when decode)."""
    s: SSMConfig = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    dt_ = x.dtype
    z = jnp.einsum("bld,dk->blk", x, p["in_proj"].astype(dt_))
    # channels TP-sharded: heads (H=d_in/head_dim) stay sharded through the
    # SSD einsums; the small shared B/C projections get gathered per layer.
    z = shard(z, "dp", None, "tp")
    zx, xbc_raw, dt_raw = _split_proj(z, d_in, s.d_state, nh)

    conv_state = state["conv"] if state is not None else None
    xbc, new_conv = _causal_conv(xbc_raw, p["conv_w"], p["conv_b"], conv_state)
    xi = xbc[..., :d_in]
    b_mat = xbc[..., d_in : d_in + s.d_state]
    c_mat = xbc[..., d_in + s.d_state :]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,L,H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))                     # (H,) negative
    xh = xi.reshape(*xi.shape[:-1], nh, s.head_dim)

    if decode:
        assert state is not None
        # h' = h·exp(dt·a) + dt·B⊗x ; y = C·h' + D·x   (single step)
        dtb = dt[:, 0]                                   # (B,H)
        dec = jnp.exp(dtb * a[None, :])                  # (B,H)
        xb = jnp.einsum(
            "bhp,bn->bhpn", xh[:, 0].astype(jnp.float32), b_mat[:, 0].astype(jnp.float32)
        )
        h_new = state["ssd"] * dec[..., None, None] + xb * dtb[..., None, None]
        y = jnp.einsum("bhpn,bn->bhp", h_new, c_mat[:, 0].astype(jnp.float32))
        y = y + xh[:, 0].astype(jnp.float32) * p["d_skip"][None, :, None]
        y = y.reshape(x.shape[0], 1, d_in).astype(dt_)
        new_state = {"ssd": h_new, "conv": new_conv.astype(state["conv"].dtype)}
    else:
        l0 = xh.shape[1]
        chunk = min(s.chunk, l0)
        pad = (-l0) % chunk
        xh_p, b_p, c_p, dt_p = xh, b_mat, c_mat, dt
        if pad:
            # state-neutral padding: dt=0 ⇒ decay=1 and zero state injection
            zpad = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
            xh_p, b_p, c_p, dt_p = zpad(xh), zpad(b_mat), zpad(c_mat), zpad(dt)
        init = state["ssd"] if state is not None else None
        y4, s_final = _ssd_chunked(
            xh_p.astype(jnp.float32), dt_p, a, b_p.astype(jnp.float32),
            c_p.astype(jnp.float32), p["d_skip"].astype(jnp.float32),
            chunk, init_state=init,
        )
        y4 = y4[:, :l0].astype(dt_)
        y = y4.reshape(x.shape[0], -1, d_in)
        new_state = {
            "ssd": s_final.astype(jnp.float32),
            "conv": new_conv.astype(jnp.float32)
            if state is None
            else new_conv.astype(state["conv"].dtype),
        }

    y = _gated_rmsnorm(y, zx, p["norm"], cfg.rms_eps)
    out = jnp.einsum("blk,kd->bld", y, p["out_proj"].astype(dt_))
    return shard(out, "dp", None, None), new_state
