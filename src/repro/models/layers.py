"""Shared neural layers (pure functions over ParamDef skeletons)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.param import ParamDef
from repro.sharding.ctx import shard


# ----------------------------- norms -----------------------------

def rmsnorm_skel(d: int) -> dict:
    return {"scale": ParamDef((d,), ("embed",), init="ones")}


def rmsnorm(p, x, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps).astype(x.dtype)
    return y * p["scale"].astype(x.dtype)


# --------------------------- embeddings ---------------------------

def embedding_skel(vocab: int, d: int) -> dict:
    return {"table": ParamDef((vocab, d), ("vocab", "embed"), scale=1.0)}


def embed(p, tokens, compute_dtype):
    x = jnp.take(p["table"], tokens, axis=0).astype(compute_dtype)
    return shard(x, "dp", None, None)


def unembed_skel(vocab: int, d: int) -> dict:
    return {"kernel": ParamDef((d, vocab), ("embed", "vocab"))}


def unembed(p, x):
    # logits in f32 for a stable softmax/loss
    return jnp.einsum("...d,dv->...v", x, p["kernel"].astype(x.dtype)).astype(
        jnp.float32
    )


# ------------------------------ MLP ------------------------------

def mlp_skel(d: int, d_ff: int, act: str = "swiglu") -> dict:
    skel = {
        "up": ParamDef((d, d_ff), ("embed", "mlp")),
        "down": ParamDef((d_ff, d), ("mlp", "embed")),
    }
    if act == "swiglu":
        skel["gate"] = ParamDef((d, d_ff), ("embed", "mlp"))
    return skel


def mlp(p, x, act: str = "swiglu"):
    dt = x.dtype
    # constrain the INPUT as well: the transpose of this constraint pins the
    # backward cotangent dx to batch-sharded — without it the partitioner
    # materialises full-batch partial sums (30 GB AR/layer on deepseek;
    # EXPERIMENTS.md §Perf cell A iteration 3).
    x = shard(x, "dp", None, None)
    up = jnp.einsum("bsd,df->bsf", x, p["up"].astype(dt))
    up = shard(up, "dp", None, "tp")
    if act == "swiglu":
        gate = jnp.einsum("bsd,df->bsf", x, p["gate"].astype(dt))
        gate = shard(gate, "dp", None, "tp")
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    y = jnp.einsum("bsf,fd->bsd", h, p["down"].astype(dt))
    return shard(y, "dp", None, None)


# ------------------------------ RoPE ------------------------------

def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, Dh) with rotary over Dh; positions: (..., S) int32."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)  # (Dh/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------- loss utils ---------------------------

def softmax_xent(logits, labels, mask=None):
    """Mean next-token cross entropy. logits (..., V) f32, labels int32."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
