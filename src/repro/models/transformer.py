"""Model stacks for every assigned family, assembled from the component layers.

All homogeneous stacks scan over stacked per-layer parameters
(``scan_layers=True``) with optional full per-layer remat — this keeps the
compiled HLO one-layer-sized, which is what makes 61-80 layer × 512-device
dry-runs compile quickly.

Families:
  dense / vlm      — pre-norm GQA + SwiGLU decoder (vlm prepends patch embeds)
  moe              — GQA or MLA attention + MoE FFN (leading dense layers opt.)
  hybrid (zamba2)  — Mamba2 stacks with ONE shared attention block re-invoked
                     every k layers (weight reuse; cf. the paper's BU reuse)
  ssm (xlstm)      — alternating mLSTM / sLSTM blocks
  audio (whisper)  — encoder (stub frame embeddings) + causal decoder w/ cross-attn
  spectral         — FNet-style: the paper's 2D FFT engine as the mixing layer
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.spectral import fourier_mixing
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.config import ModelConfig
from repro.models.layers import (
    embed,
    embedding_skel,
    mlp,
    mlp_skel,
    rmsnorm,
    rmsnorm_skel,
    unembed,
    unembed_skel,
)
from repro.models.param import ParamDef, stack_skeleton


def _maybe_remat(fn, cfg: ModelConfig):
    if not cfg.remat:
        return fn
    if cfg.remat_policy == "dots":
        # selective checkpointing: keep matmul outputs, recompute elementwise
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)


# ------------------------- decoder block (dense/moe) -------------------------

def decoder_block_skel(cfg: ModelConfig, use_moe: bool) -> dict:
    skel = {
        "ln1": rmsnorm_skel(cfg.d_model),
        "ln2": rmsnorm_skel(cfg.d_model),
    }
    if cfg.attention == "mla":
        skel["attn"] = attn.mla_skel(cfg)
    else:
        skel["attn"] = attn.gqa_skel(cfg)
    if use_moe:
        skel["moe"] = moe_mod.moe_skel(cfg)
    else:
        skel["mlp"] = mlp_skel(cfg.d_model, cfg.d_ff, cfg.act)
    return skel


def decoder_block_apply(
    p, x, cfg: ModelConfig, *, positions, cache=None, decode=False, ep_axis=None
):
    """Returns (x, new_cache, aux)."""
    h = rmsnorm(p["ln1"], x, cfg.rms_eps)
    if cfg.attention == "mla":
        a, new_cache = attn.mla_apply(
            p["attn"], h, cfg, positions=positions, cache=cache, decode=decode
        )
    else:
        a, new_cache = attn.gqa_apply(
            p["attn"], h, cfg, positions=positions, cache=cache, decode=decode
        )
    x = x + a
    h = rmsnorm(p["ln2"], x, cfg.rms_eps)
    if "moe" in p:
        f, aux = moe_mod.moe_apply(p["moe"], h, cfg, ep_axis=ep_axis)
    else:
        f, aux = mlp(p["mlp"], h, cfg.act), jnp.zeros((), jnp.float32)
    return x + f, new_cache, aux


def _scan_stack(block_fn, params_stacked, x, caches, n_layers: int, cfg: ModelConfig):
    """Scan a block over stacked params (+ optional stacked caches)."""

    def body(carry, layer_in):
        x, aux_sum = carry
        p_l, c_l = layer_in
        x, c_new, aux = block_fn(p_l, x, c_l)
        return (x, aux_sum + aux), c_new

    body = _maybe_remat(body, cfg)
    if caches is None:
        caches = jnp.zeros((n_layers,), jnp.float32)  # dummy xs
    (x, aux), new_caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), (params_stacked, caches))
    return x, new_caches, aux


# ----------------------------- decoder-only LM -----------------------------

def lm_skel(cfg: ModelConfig) -> dict:
    n_dense = cfg.moe.n_dense_layers if cfg.moe else cfg.n_layers
    n_dense = min(n_dense, cfg.n_layers)
    n_moe = cfg.n_layers - n_dense if cfg.moe else 0
    skel: dict[str, Any] = {
        "embed": embedding_skel(cfg.vocab, cfg.d_model),
        "final_norm": rmsnorm_skel(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        skel["unembed"] = unembed_skel(cfg.vocab, cfg.d_model)
    if n_dense:
        skel["dense_layers"] = stack_skeleton(
            decoder_block_skel(cfg, use_moe=False), n_dense
        )
    if n_moe:
        skel["moe_layers"] = stack_skeleton(
            decoder_block_skel(cfg, use_moe=True), n_moe
        )
    if cfg.mtp:
        skel["mtp"] = {
            "norm_h": rmsnorm_skel(cfg.d_model),
            "norm_e": rmsnorm_skel(cfg.d_model),
            "proj": {
                "down": ParamDef((2 * cfg.d_model, cfg.d_model), ("mlp", "embed"))
            },
            "block": decoder_block_skel(cfg, use_moe=False),
        }
    return skel


def _logits(params, x, cfg):
    from repro.sharding.ctx import shard

    if cfg.tie_embeddings:
        logits = jnp.einsum(
            "bsd,vd->bsv", x, params["embed"]["table"].astype(x.dtype)
        ).astype(jnp.float32)
    else:
        logits = unembed(params["unembed"], x)
    return shard(logits, "dp", None, "tp")


def lm_forward(
    params,
    tokens,
    cfg: ModelConfig,
    *,
    pos0=0,
    caches=None,
    decode=False,
    prefill=False,
    ep_axis=None,
    prefix_embeds=None,
    return_hidden=False,
):
    """Shared forward for dense/moe/vlm LMs.

    Returns (logits, new_caches, aux[, hidden]). ``prefix_embeds`` (B, P, D)
    is the vlm stub frontend's patch embeddings, prepended to the tokens.
    """
    dt = jnp.dtype(cfg.compute_dtype)
    x = embed(params["embed"], tokens, dt)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(dt), x], axis=1)
    b, s, _ = x.shape
    positions = pos0 + jnp.arange(s, dtype=jnp.int32)[None, :]
    positions = jnp.broadcast_to(positions, (b, s))

    n_dense = cfg.moe.n_dense_layers if cfg.moe else cfg.n_layers
    n_dense = min(n_dense, cfg.n_layers)
    n_moe = cfg.n_layers - n_dense if cfg.moe else 0
    new_caches = {} if caches is not None else None
    aux_total = jnp.zeros((), jnp.float32)

    for name, n, use_moe in (
        ("dense_layers", n_dense, False),
        ("moe_layers", n_moe, True),
    ):
        if n == 0:
            continue

        def blk(p_l, x, c_l, use_moe=use_moe):
            c_in = c_l if caches is not None else None
            x, c_new, aux = decoder_block_apply(
                p_l, x, cfg,
                positions=positions, cache=c_in, decode=decode, ep_axis=ep_axis,
            )
            return x, (c_new if c_new is not None else jnp.zeros((), jnp.float32)), aux

        c_stack = caches[name] if caches is not None else None
        x, c_new, aux = _scan_stack(blk, params[name], x, c_stack, n, cfg)
        if caches is not None:
            new_caches[name] = c_new
        aux_total = aux_total + aux

    hidden = x
    x = rmsnorm(params["final_norm"], x, cfg.rms_eps)
    logits = _logits(params, x, cfg)
    if return_hidden:
        return logits, new_caches, aux_total, hidden
    return logits, new_caches, aux_total


def mtp_logits(params, hidden, tokens, cfg: ModelConfig):
    """DeepSeek-V3 multi-token prediction: predict t+2 from (h_t, emb_{t+1}).

    hidden: (B, S, D) pre-final-norm states. Returns logits (B, S-1, V)
    aligned so position t predicts tokens[t+2].
    """
    dt = jnp.dtype(cfg.compute_dtype)
    p = params["mtp"]
    h = rmsnorm(p["norm_h"], hidden[:, :-1], cfg.rms_eps)
    e = embed(params["embed"], tokens[:, 1:], dt)
    e = rmsnorm(p["norm_e"], e, cfg.rms_eps)
    x = jnp.einsum(
        "bsk,kd->bsd", jnp.concatenate([h, e], axis=-1), p["proj"]["down"].astype(dt)
    )
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x, _, _ = decoder_block_apply(p["block"], x, cfg, positions=positions)
    x = rmsnorm(params["final_norm"], x, cfg.rms_eps)
    return _logits(params, x, cfg)


def lm_init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    n_dense = cfg.moe.n_dense_layers if cfg.moe else cfg.n_layers
    n_dense = min(n_dense, cfg.n_layers)
    n_moe = cfg.n_layers - n_dense if cfg.moe else 0

    def one(n):
        if cfg.attention == "mla":
            c = attn.make_mla_cache(cfg, batch, max_len, dtype)
        else:
            c = attn.make_cache(cfg, batch, max_len, dtype)
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (n, *a.shape)), c)

    caches = {}
    if n_dense:
        caches["dense_layers"] = one(n_dense)
    if n_moe:
        caches["moe_layers"] = one(n_moe)
    return caches


# ------------------------------ hybrid (zamba2) ------------------------------

def hybrid_skel(cfg: ModelConfig) -> dict:
    """Mamba2 stack + ONE shared attention/MLP block over concat(x, x0)."""
    shared_cfg = _shared_block_cfg(cfg)
    return {
        "embed": embedding_skel(cfg.vocab, cfg.d_model),
        "final_norm": rmsnorm_skel(cfg.d_model),
        "unembed": unembed_skel(cfg.vocab, cfg.d_model),
        "mamba_layers": stack_skeleton(ssm_mod.mamba2_skel(cfg), cfg.n_layers),
        "shared": {
            "ln1": rmsnorm_skel(shared_cfg.d_model),
            "attn": attn.gqa_skel(shared_cfg),
            "ln2": rmsnorm_skel(shared_cfg.d_model),
            "mlp": mlp_skel(shared_cfg.d_model, cfg.d_ff, cfg.act),
            "proj": {
                "down": ParamDef(
                    (shared_cfg.d_model, cfg.d_model), ("mlp", "embed")
                )
            },
        },
    }


def _shared_block_cfg(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(
        cfg,
        d_model=2 * cfg.d_model,
        head_dim=2 * cfg.d_model // cfg.n_heads,
        attention="gqa",
    )


def _n_shared_invocations(cfg: ModelConfig) -> int:
    return max(1, cfg.n_layers // cfg.shared_attn_every)


def hybrid_forward(
    params, tokens, cfg: ModelConfig, *, pos0=0, caches=None, decode=False, **_
):
    """Returns (logits, new_caches, aux)."""
    dt = jnp.dtype(cfg.compute_dtype)
    x = embed(params["embed"], tokens, dt)
    x0 = x  # original embedding, re-fed to every shared-block invocation
    b, s, _ = x.shape
    positions = pos0 + jnp.arange(s, dtype=jnp.int32)[None, :]
    positions = jnp.broadcast_to(positions, (b, s))
    shared_cfg = _shared_block_cfg(cfg)

    n_inv = _n_shared_invocations(cfg)
    group = cfg.n_layers // n_inv
    new_caches: dict[str, Any] = {"mamba": [], "shared": []} if caches is not None else None
    mamba_stack = params["mamba_layers"]

    for gi in range(n_inv):
        sl = lambda a, gi=gi: jax.lax.slice_in_dim(a, gi * group, (gi + 1) * group, axis=0)
        p_group = jax.tree.map(sl, mamba_stack)

        def blk(p_l, x, c_l):
            st = c_l if caches is not None else None
            x_new, st_new = ssm_mod.mamba2_apply(p_l, x, cfg, state=st, decode=decode)
            return x_new, (st_new if st_new is not None else jnp.zeros((), jnp.float32)), jnp.zeros((), jnp.float32)

        c_group = (
            jax.tree.map(sl, caches["mamba"]) if caches is not None else None
        )
        x, c_new, _ = _scan_stack(blk, p_group, x, c_group, group, cfg)
        if caches is not None:
            new_caches["mamba"].append(c_new)

        # shared attention block (weights reused every invocation)
        xa = jnp.concatenate([x, x0], axis=-1)
        h = rmsnorm(params["shared"]["ln1"], xa, cfg.rms_eps)
        c_sh = (
            jax.tree.map(lambda a, gi=gi: a[gi], caches["shared"])
            if caches is not None
            else None
        )
        a_out, c_sh_new = attn.gqa_apply(
            params["shared"]["attn"], h, shared_cfg,
            positions=positions, cache=c_sh, decode=decode,
        )
        xa = xa + a_out
        h2 = rmsnorm(params["shared"]["ln2"], xa, cfg.rms_eps)
        xa = xa + mlp(params["shared"]["mlp"], h2, cfg.act)
        x = x + jnp.einsum(
            "bsk,kd->bsd", xa, params["shared"]["proj"]["down"].astype(dt)
        )
        if caches is not None:
            new_caches["shared"].append(c_sh_new)

    if caches is not None:
        new_caches["mamba"] = jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=0), *new_caches["mamba"]
        )
        new_caches["shared"] = jax.tree.map(
            lambda *xs: jnp.stack(xs, axis=0), *new_caches["shared"]
        )

    x = rmsnorm(params["final_norm"], x, cfg.rms_eps)
    return unembed(params["unembed"], x), new_caches, jnp.zeros((), jnp.float32)


def hybrid_init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    n_inv = _n_shared_invocations(cfg)
    shared_cfg = _shared_block_cfg(cfg)
    st = ssm_mod.mamba2_state(cfg, batch)
    kv = attn.make_cache(shared_cfg, batch, max_len, dtype)
    return {
        "mamba": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers, *a.shape)), st
        ),
        "shared": jax.tree.map(lambda a: jnp.broadcast_to(a, (n_inv, *a.shape)), kv),
    }


# -------------------------------- ssm (xlstm) --------------------------------

def xlstm_skel(cfg: ModelConfig) -> dict:
    n_pairs = cfg.n_layers // 2
    return {
        "embed": embedding_skel(cfg.vocab, cfg.d_model),
        "final_norm": rmsnorm_skel(cfg.d_model),
        "unembed": unembed_skel(cfg.vocab, cfg.d_model),
        "mlstm_layers": stack_skeleton(xlstm_mod.mlstm_skel(cfg), n_pairs),
        "slstm_layers": stack_skeleton(xlstm_mod.slstm_skel(cfg), n_pairs),
    }


def xlstm_forward(
    params, tokens, cfg: ModelConfig, *, pos0=0, caches=None, decode=False, **_
):
    dt = jnp.dtype(cfg.compute_dtype)
    x = embed(params["embed"], tokens, dt)
    n_pairs = cfg.n_layers // 2

    def blk(p_pair, x, c_pair):
        pm, ps = p_pair
        cm = c_pair[0] if caches is not None else None
        cs = c_pair[1] if caches is not None else None
        dm, sm = xlstm_mod.mlstm_apply(pm, rmsnorm_like(x, cfg), cfg, state=cm, decode=decode)
        x = x + dm
        ds, ss = xlstm_mod.slstm_apply(ps, rmsnorm_like(x, cfg), cfg, state=cs, decode=decode)
        x = x + ds
        zero = jnp.zeros((), jnp.float32)
        return x, ((sm if sm is not None else zero), (ss if ss is not None else zero)), zero

    c_stack = (
        (caches["mlstm"], caches["slstm"]) if caches is not None else None
    )
    x, c_new, _ = _scan_stack(
        blk, (params["mlstm_layers"], params["slstm_layers"]), x, c_stack, n_pairs, cfg
    )
    new_caches = (
        {"mlstm": c_new[0], "slstm": c_new[1]} if caches is not None else None
    )
    x = rmsnorm(params["final_norm"], x, cfg.rms_eps)
    return unembed(params["unembed"], x), new_caches, jnp.zeros((), jnp.float32)


def rmsnorm_like(x, cfg):
    """Parameter-free pre-norm used inside the xLSTM residual blocks
    (the blocks carry their own learned norms internally)."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + cfg.rms_eps).astype(x.dtype))


def xlstm_init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.float32):
    n_pairs = cfg.n_layers // 2
    sm = xlstm_mod.mlstm_state(cfg, batch)
    ss = xlstm_mod.slstm_state(cfg, batch)
    return {
        "mlstm": jax.tree.map(lambda a: jnp.broadcast_to(a, (n_pairs, *a.shape)), sm),
        "slstm": jax.tree.map(lambda a: jnp.broadcast_to(a, (n_pairs, *a.shape)), ss),
    }


# ------------------------------ audio (whisper) ------------------------------

def encdec_skel(cfg: ModelConfig) -> dict:
    enc_block = {
        "ln1": rmsnorm_skel(cfg.d_model),
        "attn": attn.gqa_skel(cfg),
        "ln2": rmsnorm_skel(cfg.d_model),
        "mlp": mlp_skel(cfg.d_model, cfg.d_ff, "gelu"),
    }
    dec_block = {
        "ln1": rmsnorm_skel(cfg.d_model),
        "attn": attn.gqa_skel(cfg),
        "lnx": rmsnorm_skel(cfg.d_model),
        "xattn": attn.cross_attn_skel(cfg),
        "ln2": rmsnorm_skel(cfg.d_model),
        "mlp": mlp_skel(cfg.d_model, cfg.d_ff, "gelu"),
    }
    return {
        "embed": embedding_skel(cfg.vocab, cfg.d_model),
        "enc_norm": rmsnorm_skel(cfg.d_model),
        "final_norm": rmsnorm_skel(cfg.d_model),
        "unembed": unembed_skel(cfg.vocab, cfg.d_model),
        "enc_layers": stack_skeleton(enc_block, cfg.n_enc_layers or cfg.n_layers),
        "dec_layers": stack_skeleton(dec_block, cfg.n_layers),
    }


def encoder_forward(params, frames, cfg: ModelConfig):
    """frames: (B, T, D) precomputed stub embeddings (assignment-mandated)."""
    dt = jnp.dtype(cfg.compute_dtype)
    x = frames.astype(dt)
    b, t, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))

    def blk(p_l, x, _c):
        h = rmsnorm(p_l["ln1"], x, cfg.rms_eps)
        a, _ = attn.gqa_apply(p_l["attn"], h, cfg, positions=positions, causal=False)
        x = x + a
        h = rmsnorm(p_l["ln2"], x, cfg.rms_eps)
        return x + mlp(p_l["mlp"], h, "gelu"), jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)

    n_enc = cfg.n_enc_layers or cfg.n_layers
    x, _, _ = _scan_stack(blk, params["enc_layers"], x, None, n_enc, cfg)
    return rmsnorm(params["enc_norm"], x, cfg.rms_eps)


def encdec_forward(
    params, tokens, cfg: ModelConfig, *,
    frames=None, enc_out=None, pos0=0, caches=None, decode=False, **_,
):
    """Decoder forward. Returns (logits, new_caches, aux). During decode the
    per-layer cross-attention K/V live in the cache (computed at prefill)."""
    dt = jnp.dtype(cfg.compute_dtype)
    if enc_out is None and frames is not None:
        enc_out = encoder_forward(params, frames, cfg)
    x = embed(params["embed"], tokens, dt)
    b, s, _ = x.shape
    positions = pos0 + jnp.arange(s, dtype=jnp.int32)[None, :]
    positions = jnp.broadcast_to(positions, (b, s))

    def blk(p_l, x, c_l):
        c_self = c_l["self"] if caches is not None else None
        h = rmsnorm(p_l["ln1"], x, cfg.rms_eps)
        a, c_self_new = attn.gqa_apply(
            p_l["attn"], h, cfg, positions=positions, cache=c_self, decode=decode
        )
        x = x + a
        h = rmsnorm(p_l["lnx"], x, cfg.rms_eps)
        if caches is not None:
            if decode:
                kx, vx = c_l["cross_k"], c_l["cross_v"]
            else:
                kx, vx = attn.cross_kv(p_l["xattn"], enc_out, dt)
        else:
            kx, vx = attn.cross_kv(p_l["xattn"], enc_out, dt)
        x = x + attn.cross_attn_apply(p_l["xattn"], h, (kx, vx), cfg)
        h = rmsnorm(p_l["ln2"], x, cfg.rms_eps)
        x = x + mlp(p_l["mlp"], h, "gelu")
        zero = jnp.zeros((), jnp.float32)
        if caches is not None:
            return x, {"self": c_self_new, "cross_k": kx, "cross_v": vx}, zero
        return x, zero, zero

    c_stack = caches["dec"] if caches is not None else None
    x, c_new, _ = _scan_stack(blk, params["dec_layers"], x, c_stack, cfg.n_layers, cfg)
    new_caches = {"dec": c_new} if caches is not None else None
    x = rmsnorm(params["final_norm"], x, cfg.rms_eps)
    return unembed(params["unembed"], x), new_caches, jnp.zeros((), jnp.float32)


def encdec_init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    kv = attn.make_cache(cfg, batch, max_len, dtype)
    h, dh = cfg.n_heads, cfg.resolved_head_dim
    cross = {
        "cross_k": jnp.zeros((batch, cfg.enc_frames, h, dh), dtype),
        "cross_v": jnp.zeros((batch, cfg.enc_frames, h, dh), dtype),
    }
    per_layer = {"self": kv, **cross}
    return {
        "dec": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers, *a.shape)), per_layer
        )
    }


# ----------------------------- spectral (fourier) -----------------------------

def spectral_skel(cfg: ModelConfig) -> dict:
    block = {
        "ln1": rmsnorm_skel(cfg.d_model),
        "ln2": rmsnorm_skel(cfg.d_model),
        "mlp": mlp_skel(cfg.d_model, cfg.d_ff, "gelu"),
    }
    return {
        "embed": embedding_skel(cfg.vocab, cfg.d_model),
        "final_norm": rmsnorm_skel(cfg.d_model),
        "unembed": unembed_skel(cfg.vocab, cfg.d_model),
        "layers": stack_skeleton(block, cfg.n_layers),
    }


def spectral_forward(params, tokens, cfg: ModelConfig, **_):
    """FNet-style encoder LM: mixing = Re(FFT2) — the paper's engine."""
    dt = jnp.dtype(cfg.compute_dtype)
    x = embed(params["embed"], tokens, dt)

    def blk(p_l, x, _c):
        h = rmsnorm(p_l["ln1"], x, cfg.rms_eps)
        x = x + fourier_mixing(h, variant=cfg.fft_variant)
        h = rmsnorm(p_l["ln2"], x, cfg.rms_eps)
        return x + mlp(p_l["mlp"], h, "gelu"), jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)

    x, _, _ = _scan_stack(blk, params["layers"], x, None, cfg.n_layers, cfg)
    x = rmsnorm(params["final_norm"], x, cfg.rms_eps)
    return unembed(params["unembed"], x), None, jnp.zeros((), jnp.float32)
