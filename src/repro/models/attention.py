"""Attention: chunked online-softmax (flash-style), GQA, MLA, SWA + caches.

Pure JAX with static block sizes — the memory-safe formulation the dry-run
needs (never materialises an (S, S) score matrix). Decode paths score one
query against a cache: dense buffer for full attention, ring buffer (size =
window) for sliding-window attention, compressed-latent buffer for MLA
(absorbed decode — the (B, S, r) latent is never expanded per head).
"""

from __future__ import annotations

import math
from typing import Any

import jax
from repro import compat
import jax.numpy as jnp

from repro.models.config import MLAConfig, ModelConfig
from repro.models.layers import apply_rope
from repro.models.param import ParamDef
from repro.sharding.ctx import cp_axis_for, shard, tp_size

NEG_INF = -1.0e30


# ------------------------- flash attention -------------------------

def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    block_q: int = 512,
    block_k: int = 1024,
    vma_axes: tuple = (),
) -> jax.Array:
    """q: (B, Sq, H, Dk); k: (B, Sk, KV, Dk); v: (B, Sk, KV, Dv). GQA via H=KV·g.

    Online-softmax over KV blocks inside a map over Q blocks — peak score
    memory is (B, bq, H, bk) regardless of sequence length.
    """
    b, sq0, h, dk = q.shape
    _, sk0, kv, _ = k.shape
    dv = v.shape[-1]
    g = h // kv
    block_q = min(block_q, sq0)
    block_k = min(block_k, sk0)
    # Pad ragged tails; padded k positions are masked out, padded q rows dropped.
    pad_q = (-sq0) % block_q
    pad_k = (-sk0) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    sq, sk = sq0 + pad_q, sk0 + pad_k
    nq, nk = sq // block_q, sk // block_k
    scale = 1.0 / math.sqrt(dk)

    qb = q.reshape(b, nq, block_q, kv, g, dk)
    kb = k.reshape(b, nk, block_k, kv, dk)
    vb = v.reshape(b, nk, block_k, kv, dv)

    def q_block(i):
        qi = qb[:, i] * scale  # (b, bq, kv, g, dk)
        qpos = q_offset + i * block_q + jnp.arange(block_q)

        def kv_step(carry, j):
            acc, m, l = carry
            kj = kb[:, j]
            vj = vb[:, j]
            s = jnp.einsum(
                "bqhgd,bkhd->bqhgk", qi, kj, preferred_element_type=jnp.float32
            )
            kpos = j * block_k + jnp.arange(block_k)
            mask = jnp.broadcast_to(kpos[None, :] < sk0, (block_q, block_k))
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                mask &= kpos[None, :] > qpos[:, None] - window
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bqhgk,bkhd->bqhgd", p.astype(vj.dtype), vj,
                preferred_element_type=jnp.float32,
            )
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((b, block_q, kv, g, dv), jnp.float32)
        m0 = jnp.full((b, block_q, kv, g), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, block_q, kv, g), jnp.float32)
        if vma_axes:  # inside shard_map: mark carries as manual-varying
            acc0, m0, l0 = (
                compat.pcast(t, vma_axes, to="varying") for t in (acc0, m0, l0)
            )
        (acc, _, l), _ = jax.lax.scan(
            jax.checkpoint(kv_step), (acc0, m0, l0), jnp.arange(nk)
        )
        return (acc / jnp.maximum(l[..., None], 1e-20)).astype(q.dtype)

    out = jax.lax.map(q_block, jnp.arange(nq))  # (nq, b, bq, kv, g, dv)
    out = jnp.moveaxis(out, 0, 1)  # (b, nq, bq, kv, g, dv)
    return out.reshape(b, sq, h, dv)[:, :sq0]


def flash_attention_cp(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    axis: str,
    **kw,
) -> jax.Array:
    """Context-parallel flash attention: Q sequence-sharded over ``axis``,
    K/V replicated across it (each rank attends its query slice against the
    full keys). Used when an arch can neither head-TP nor 2-D-batch its
    attention for the given batch (§Perf cell B) — e.g. llama/starcoder
    prefill_32k, whose batch of 32 leaves the model axis idle."""
    import functools as _ft

    from jax.sharding import PartitionSpec as P

    mesh = compat.get_abstract_mesh()

    @_ft.partial(
        compat.shard_map,
        mesh=mesh,
        in_specs=(P(None, axis, None, None), P(), P()),
        out_specs=P(None, axis, None, None),
        axis_names={axis},
    )
    def run(q_loc, k_full, v_full):
        rank = jax.lax.axis_index(axis)
        off = rank * q_loc.shape[1]
        return flash_attention(
            q_loc, k_full, v_full, q_offset=off, vma_axes=(axis,), **kw
        )

    return run(q, k, v)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    slot_pos: jax.Array,
    cur_pos: jax.Array,
) -> jax.Array:
    """One-token attention over a cache buffer.

    q: (B, 1, H, Dk); caches (B, S, KV, D*); slot_pos (S,) giving the global
    position stored in each slot (−1 = empty) — valid for both dense caches
    (slot_pos = arange) and SWA ring caches (rotating slots).
    """
    b, _, h, dk = q.shape
    kv = k_cache.shape[2]
    g = h // kv
    scale = 1.0 / math.sqrt(dk)
    qh = q.reshape(b, kv, g, dk) * scale
    s = jnp.einsum(
        "bhgd,bshd->bhgs", qh, k_cache, preferred_element_type=jnp.float32
    )
    valid = (slot_pos >= 0) & (slot_pos <= cur_pos)
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bhgs,bshd->bhgd", w.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, 1, h, v_cache.shape[-1]).astype(q.dtype)


# ----------------------------- GQA layer -----------------------------

def gqa_skel(cfg: ModelConfig) -> dict:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "wq": ParamDef((d, h, dh), ("embed", "heads", "head_dim")),
        "wk": ParamDef((d, kv, dh), ("embed", "kv_heads", "head_dim")),
        "wv": ParamDef((d, kv, dh), ("embed", "kv_heads", "head_dim")),
        "wo": ParamDef((h, dh, d), ("heads", "head_dim", "embed")),
    }


def make_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Dense or ring (SWA) KV cache for one layer."""
    size = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    kv, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, size, kv, dh), dtype),
        "v": jnp.zeros((batch, size, kv, dh), dtype),
        "slot_pos": jnp.full((size,), -1, jnp.int32),
    }


def _cache_insert(cache: dict, k_new: jax.Array, v_new: jax.Array, pos: jax.Array):
    """Insert (B, S_new, KV, Dh) at global position ``pos`` (ring-aware)."""
    size = cache["k"].shape[1]
    s_new = k_new.shape[1]
    if s_new == 1:
        slot = (pos % size).astype(jnp.int32)
        k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype), (0, slot, 0, 0))
        v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype), (0, slot, 0, 0))
        sp = jax.lax.dynamic_update_slice(cache["slot_pos"], pos[None].astype(jnp.int32), (slot,))
    else:
        # prefill: keep the last ``size`` entries (ring) or all (dense)
        take = min(s_new, size)
        k_tail = k_new[:, s_new - take :]
        v_tail = v_new[:, s_new - take :]
        k = jax.lax.dynamic_update_slice(cache["k"], k_tail.astype(cache["k"].dtype), (0, 0, 0, 0))
        v = jax.lax.dynamic_update_slice(cache["v"], v_tail.astype(cache["v"].dtype), (0, 0, 0, 0))
        sp = jnp.where(
            jnp.arange(size) < take,
            jnp.arange(size, dtype=jnp.int32) + (s_new - take),
            cache["slot_pos"],
        )
    return {"k": k, "v": v, "slot_pos": sp}


def gqa_apply(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    causal: bool = True,
    cache: dict | None = None,
    decode: bool = False,
):
    """Returns (out, new_cache). x: (B, S, D)."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    # TP over heads when the head count divides the model axis; otherwise
    # 2-D batch parallelism (batch over data×model) keeps attention
    # collective-free for the 24/48/4-head archs.
    if not decode:
        heads_tp = q.shape[2] % tp_size() == 0
        bt = "dp" if heads_tp else "dp+tp"
        ht = "tp" if heads_tp else None
        q = shard(q, bt, None, ht, None)
        k = shard(k, bt, None, ht, None)
        v = shard(v, bt, None, ht, None)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if decode:
        assert cache is not None
        pos = positions[0, 0] if positions.ndim == 2 else positions[0]
        new_cache = _cache_insert(cache, k, v, pos)
        out = decode_attention(q, new_cache["k"], new_cache["v"], new_cache["slot_pos"], pos)
    else:
        cp = cp_axis_for(q.shape[0], q.shape[1])
        if cp is not None and q.shape[1] == k.shape[1]:
            out = flash_attention_cp(
                q, k, v, cp,
                causal=causal,
                window=cfg.sliding_window,
                block_q=cfg.attn_block_q,
                block_k=cfg.attn_block_k,
            )
        else:
            out = flash_attention(
                q, k, v,
                causal=causal,
                window=cfg.sliding_window,
                block_q=cfg.attn_block_q,
                block_k=cfg.attn_block_k,
            )
        if cache is not None:
            pos = positions[0, 0] if positions.ndim == 2 else positions[0]
            new_cache = _cache_insert(cache, k, v, pos)
        heads_tp = out.shape[2] % tp_size() == 0
        out = shard(out, "dp" if heads_tp else "dp+tp", None,
                    "tp" if heads_tp else None, None)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))
    return shard(y, "dp", None, None), new_cache


# ------------------------- cross attention -------------------------

def cross_attn_skel(cfg: ModelConfig) -> dict:
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.resolved_head_dim
    return {
        "wq": ParamDef((d, h, dh), ("embed", "heads", "head_dim")),
        "wk": ParamDef((d, h, dh), ("embed", "heads", "head_dim")),
        "wv": ParamDef((d, h, dh), ("embed", "heads", "head_dim")),
        "wo": ParamDef((h, dh, d), ("heads", "head_dim", "embed")),
    }


def cross_attn_apply(p, x, enc_kv: tuple[jax.Array, jax.Array] | jax.Array, cfg):
    """x: (B, S, D); enc_kv: precomputed (k, v) or encoder output (B, T, D)."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    if isinstance(enc_kv, tuple):
        k, v = enc_kv
    else:
        k = jnp.einsum("btd,dhk->bthk", enc_kv, p["wk"].astype(dt))
        v = jnp.einsum("btd,dhk->bthk", enc_kv, p["wv"].astype(dt))
    out = flash_attention(
        q, k, v, causal=False,
        block_q=cfg.attn_block_q, block_k=min(cfg.attn_block_k, k.shape[1]),
    )
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))


def cross_kv(p, enc_out, dtype):
    k = jnp.einsum("btd,dhk->bthk", enc_out.astype(dtype), p["wk"].astype(dtype))
    v = jnp.einsum("btd,dhk->bthk", enc_out.astype(dtype), p["wv"].astype(dtype))
    return k, v


# ------------------------------- MLA -------------------------------

def mla_skel(cfg: ModelConfig) -> dict:
    m: MLAConfig = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    dq = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": ParamDef((d, m.q_lora_rank), ("embed", "q_lora")),
        "q_norm": ParamDef((m.q_lora_rank,), ("q_lora",), init="ones"),
        "wq_b": ParamDef((m.q_lora_rank, h, dq), ("q_lora", "heads", "head_dim")),
        "wkv_a": ParamDef(
            (d, m.kv_lora_rank + m.qk_rope_head_dim), ("embed", "kv_lora")
        ),
        "kv_norm": ParamDef((m.kv_lora_rank,), ("kv_lora",), init="ones"),
        "wk_b": ParamDef(
            (m.kv_lora_rank, h, m.qk_nope_head_dim), ("kv_lora", "heads", "head_dim")
        ),
        "wv_b": ParamDef(
            (m.kv_lora_rank, h, m.v_head_dim), ("kv_lora", "heads", "head_dim")
        ),
        "wo": ParamDef((h, m.v_head_dim, d), ("heads", "head_dim", "embed")),
    }


def make_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype),
        "slot_pos": jnp.full((max_len,), -1, jnp.int32),
    }


def _rms(x, w, eps=1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * w.astype(x.dtype)


def mla_apply(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    cache: dict | None = None,
    decode: bool = False,
):
    """DeepSeek Multi-head Latent Attention. Returns (out, new_cache)."""
    m: MLAConfig = cfg.mla
    dt = x.dtype
    b, s, _ = x.shape
    nope, drope = m.qk_nope_head_dim, m.qk_rope_head_dim

    q = jnp.einsum(
        "bsr,rhk->bshk", _rms(jnp.einsum("bsd,dr->bsr", x, p["wq_a"].astype(dt)), p["q_norm"]),
        p["wq_b"].astype(dt),
    )
    if not decode:
        q = shard(q, "dp", None, "tp", None)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckv_full = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"].astype(dt))
    c_kv = _rms(ckv_full[..., : m.kv_lora_rank], p["kv_norm"])
    k_rope = ckv_full[..., m.kv_lora_rank :]
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]

    scale = 1.0 / math.sqrt(nope + drope)
    new_cache = None

    if decode:
        assert cache is not None
        pos = positions[0, 0] if positions.ndim == 2 else positions[0]
        size = cache["c_kv"].shape[1]
        slot = (pos % size).astype(jnp.int32)
        c_buf = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, slot, 0)
        )
        r_buf = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), (0, slot, 0)
        )
        sp = jax.lax.dynamic_update_slice(
            cache["slot_pos"], pos[None].astype(jnp.int32), (slot,)
        )
        new_cache = {"c_kv": c_buf, "k_rope": r_buf, "slot_pos": sp}
        # Absorbed decode: never expand per-head K/V from the latent.
        q_abs = jnp.einsum("bshk,rhk->bshr", q_nope, p["wk_b"].astype(dt))
        s_lat = jnp.einsum("bshr,btr->bhst", q_abs, c_buf.astype(dt))
        s_rope = jnp.einsum("bshk,btk->bhst", q_rope, r_buf.astype(dt))
        logits = (s_lat + s_rope).astype(jnp.float32) * scale
        valid = (sp >= 0) & (sp <= pos)
        logits = jnp.where(valid[None, None, None, :], logits, NEG_INF)
        w = jax.nn.softmax(logits, axis=-1).astype(dt)
        o_lat = jnp.einsum("bhst,btr->bshr", w, c_buf.astype(dt))
        out = jnp.einsum("bshr,rhv->bshv", o_lat, p["wv_b"].astype(dt))
    else:
        k_nope = shard(
            jnp.einsum("bsr,rhk->bshk", c_kv, p["wk_b"].astype(dt)),
            "dp", None, "tp", None,
        )
        v = shard(
            jnp.einsum("bsr,rhv->bshv", c_kv, p["wv_b"].astype(dt)),
            "dp", None, "tp", None,
        )
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, cfg.n_heads, drope))],
            axis=-1,
        )
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = flash_attention(
            q_full, k_full, v,
            causal=True,
            block_q=cfg.attn_block_q, block_k=cfg.attn_block_k,
        )
        if cache is not None:
            size = cache["c_kv"].shape[1]
            take = min(s, size)
            c_buf = jax.lax.dynamic_update_slice(
                cache["c_kv"], c_kv[:, -take:].astype(cache["c_kv"].dtype), (0, 0, 0)
            )
            r_buf = jax.lax.dynamic_update_slice(
                cache["k_rope"], k_rope[:, -take:].astype(cache["k_rope"].dtype), (0, 0, 0)
            )
            sp = jnp.where(
                jnp.arange(size) < take,
                jnp.arange(size, dtype=jnp.int32) + (s - take),
                cache["slot_pos"],
            )
            new_cache = {"c_kv": c_buf, "k_rope": r_buf, "slot_pos": sp}
        out = shard(out, "dp", None, "tp", None)

    y = jnp.einsum("bshv,hvd->bsd", out, p["wo"].astype(dt))
    return shard(y, "dp", None, None), new_cache
