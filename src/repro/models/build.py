"""config → Model bundle: init/abstract/loss/prefill/decode/input_specs.

Every assigned architecture flows through here; the launch layer (train,
serve, dryrun) only ever talks to a ``Model``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.models.layers import softmax_xent
from repro.models.param import abstract_params, init_params, param_count, partition_specs


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    skeleton: Any
    loss_fn: Callable          # (params, batch) -> (loss, metrics)
    prefill_fn: Callable       # (params, batch, caches) -> (logits, caches)
    decode_fn: Callable | None # (params, token, pos, caches, extras) -> (logits, caches)
    init_cache_fn: Callable | None  # (batch, max_len, dtype) -> caches

    def init(self, key, dtype=None):
        return init_params(self.skeleton, key, dtype)

    def abstract(self, dtype=None):
        return abstract_params(self.skeleton, dtype)

    def specs(self, rules: dict):
        return partition_specs(self.skeleton, rules)

    @property
    def n_params(self) -> int:
        return param_count(self.skeleton)


def _lm_like(cfg: ModelConfig, forward, skel, init_cache):
    """Bundle for decoder-style LMs (dense/moe/vlm/hybrid/ssm)."""

    def loss_fn(params, batch):
        extras = {}
        if "patches" in batch:
            extras["prefix_embeds"] = batch["patches"]
        if cfg.mtp:
            logits, _, aux, hidden = forward(
                params, batch["tokens"], cfg, return_hidden=True, **extras
            )
        else:
            logits, _, aux = forward(params, batch["tokens"], cfg, **extras)
        n_prefix = logits.shape[1] - batch["tokens"].shape[1]
        logits_tok = logits[:, n_prefix:]
        loss = softmax_xent(logits_tok[:, :-1], batch["tokens"][:, 1:])
        metrics = {"xent": loss}
        if cfg.moe is not None:
            loss = loss + cfg.moe.aux_loss_weight * aux
            metrics["aux"] = aux
        if cfg.mtp:
            ml = T.mtp_logits(params, hidden, batch["tokens"], cfg)
            mtp_loss = softmax_xent(ml[:, :-1], batch["tokens"][:, 2:])
            loss = loss + cfg.mtp_weight * mtp_loss
            metrics["mtp"] = mtp_loss
        metrics["loss"] = loss
        return loss, metrics

    def prefill_fn(params, batch, caches):
        extras = {}
        if "patches" in batch:
            extras["prefix_embeds"] = batch["patches"]
        logits, caches, _ = forward(
            params, batch["tokens"], cfg, caches=caches, **extras
        )
        return logits[:, -1], caches

    def decode_fn(params, token, pos, caches, extras=None):
        logits, caches, _ = forward(
            params, token, cfg, pos0=pos, caches=caches, decode=True
        )
        return logits[:, -1], caches

    return Model(cfg, skel, loss_fn, prefill_fn, decode_fn, init_cache)


def _encdec(cfg: ModelConfig):
    skel = T.encdec_skel(cfg)

    def loss_fn(params, batch):
        logits, _, _ = T.encdec_forward(
            params, batch["tokens"], cfg, frames=batch["frames"]
        )
        loss = softmax_xent(logits[:, :-1], batch["tokens"][:, 1:])
        return loss, {"xent": loss, "loss": loss}

    def prefill_fn(params, batch, caches):
        enc_out = T.encoder_forward(params, batch["frames"], cfg)
        logits, caches, _ = T.encdec_forward(
            params, batch["tokens"], cfg, enc_out=enc_out, caches=caches
        )
        return logits[:, -1], caches

    def decode_fn(params, token, pos, caches, extras=None):
        logits, caches, _ = T.encdec_forward(
            params, token, cfg, pos0=pos, caches=caches, decode=True
        )
        return logits[:, -1], caches

    def init_cache(batch, max_len, dtype=jnp.bfloat16):
        return T.encdec_init_cache(cfg, batch, max_len, dtype)

    return Model(cfg, skel, loss_fn, prefill_fn, decode_fn, init_cache)


def _spectral(cfg: ModelConfig):
    """FNet-style masked-LM (bidirectional mixing ⇒ no causal decode)."""
    skel = T.spectral_skel(cfg)

    def loss_fn(params, batch):
        logits, _, _ = T.spectral_forward(params, batch["tokens"], cfg)
        mask = batch.get("mlm_mask")
        targets = batch.get("targets", batch["tokens"])
        loss = softmax_xent(logits, targets, mask)
        return loss, {"xent": loss, "loss": loss}

    def prefill_fn(params, batch, caches):
        logits, _, _ = T.spectral_forward(params, batch["tokens"], cfg)
        return logits[:, -1], caches

    return Model(cfg, skel, loss_fn, prefill_fn, None, None)


def build(cfg: ModelConfig) -> Model:
    if cfg.family in ("dense", "moe", "vlm"):
        return _lm_like(
            cfg, T.lm_forward, T.lm_skel(cfg),
            lambda b, s, dtype=jnp.bfloat16: T.lm_init_cache(cfg, b, s, dtype),
        )
    if cfg.family == "hybrid":
        return _lm_like(
            cfg, T.hybrid_forward, T.hybrid_skel(cfg),
            lambda b, s, dtype=jnp.bfloat16: T.hybrid_init_cache(cfg, b, s, dtype),
        )
    if cfg.family == "ssm":
        return _lm_like(
            cfg, T.xlstm_forward, T.xlstm_skel(cfg),
            lambda b, s, dtype=jnp.float32: T.xlstm_init_cache(cfg, b, s, dtype),
        )
    if cfg.family == "audio":
        return _encdec(cfg)
    if cfg.family == "spectral":
        return _spectral(cfg)
    raise ValueError(f"unknown family {cfg.family}")
