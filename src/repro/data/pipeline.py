"""Deterministic, restart-safe synthetic data pipeline.

Every batch is a pure function of (seed, step) — after a crash/restore the
pipeline resumes bit-identically from the checkpointed step (fault-tolerance
invariant tested in tests/train). The token stream is a learnable-structure
Markov-ish sequence so tiny LMs show a decreasing loss (not pure noise).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab: int
    seq: int
    batch: int
    seed: int = 0

    def batch_at(self, step: int) -> dict:
        """Batch for global step ``step`` (deterministic, O(1) state)."""
        rng = np.random.default_rng((self.seed << 20) ^ step)
        # structured stream: tok_{t+1} = (a·tok_t + b + noise) % V
        a = 31
        b = rng.integers(0, self.vocab, (self.batch, 1))
        t0 = rng.integers(0, self.vocab, (self.batch, 1))
        noise = (rng.random((self.batch, self.seq)) < 0.05) * rng.integers(
            0, self.vocab, (self.batch, self.seq)
        )
        toks = np.zeros((self.batch, self.seq), np.int64)
        toks[:, :1] = t0
        for t in range(1, self.seq):
            toks[:, t] = (a * toks[:, t - 1] + b[:, 0]) % self.vocab
        toks = (toks + noise) % self.vocab
        return {"tokens": jnp.asarray(toks, jnp.int32)}

    def mlm_batch_at(self, step: int, mask_rate: float = 0.15) -> dict:
        """Masked-LM variant (spectral/fourier_lm arch)."""
        base = self.batch_at(step)
        rng = np.random.default_rng((self.seed << 21) ^ step)
        mask = rng.random((self.batch, self.seq)) < mask_rate
        corrupted = np.asarray(base["tokens"]).copy()
        corrupted[mask] = 0  # [MASK] id
        return {
            "tokens": jnp.asarray(corrupted, jnp.int32),
            "targets": base["tokens"],
            "mlm_mask": jnp.asarray(mask, jnp.float32),
        }


def frames_for(cfg, batch: int, step: int, seed: int = 0):
    rng = np.random.default_rng((seed << 22) ^ step)
    return jnp.asarray(
        rng.standard_normal((batch, cfg.enc_frames, cfg.d_model)) * 0.02, jnp.float32
    )


def patches_for(cfg, batch: int, step: int, seed: int = 0):
    rng = np.random.default_rng((seed << 23) ^ step)
    return jnp.asarray(
        rng.standard_normal((batch, cfg.n_patches, cfg.d_model)) * 0.02, jnp.float32
    )


def make_batch(cfg, batch: int, seq: int, step: int, seed: int = 0) -> dict:
    """Family-aware batch builder used by the train loop and examples."""
    pipe = SyntheticLM(cfg.vocab, seq, batch, seed)
    if cfg.family == "spectral":
        return pipe.mlm_batch_at(step)
    out = pipe.batch_at(step)
    if cfg.family == "audio":
        out["frames"] = frames_for(cfg, batch, step, seed)
    if cfg.family == "vlm":
        out = SyntheticLM(cfg.vocab, seq - cfg.n_patches, batch, seed).batch_at(step)
        out["patches"] = patches_for(cfg, batch, step, seed)
    return out
