from repro.data.pipeline import SyntheticLM, frames_for, make_batch, patches_for

__all__ = ["SyntheticLM", "make_batch", "frames_for", "patches_for"]
