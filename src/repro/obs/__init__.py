"""repro.obs — structured tracing, metrics and plan introspection.

The software analog of the paper's control unit + RAM controller
*accounting*: every decision point in the stack — planner resolution
(``repro.plan``), MEASURE sweeps, engine dispatch (``repro.engines``),
fused-kernel VMEM failovers (``repro.kernels``), wisdom load/save, and
service batching (``repro.serve``: queue intake ``serve.queue``, scheduler
heartbeats ``serve.loop.tick`` with queue-depth gauges, per-lane batch
spans ``serve.batch``, quarantine-driven re-resolution
``serve.lane.replan``, wisdom warm starts ``serve.wisdom.warm_start``) —
emits structured events through this package.

    from repro import obs
    import repro.xfft as xfft

    with obs.capture() as trace:
        xfft.fft2(x)                       # cold: plan miss
        xfft.fft2(x)                       # warm: plan hit
    [e["outcome"] for e in trace.select("plan.resolve")]  # ['miss', 'hit']

Process-wide counters stay on even without a capture scope (one dict
increment per event — the ``benchmarks/obs_bench.py`` gate holds the
instrumented hot path within 3% of uninstrumented); ``xfft.report()``
renders them next to the live plan cache, FFTW ``export_wisdom``-style.
"""

from repro.obs.record import (
    Event,
    Trace,
    capture,
    count,
    counters,
    emit,
    enabled,
    pop_observe,
    profiling,
    push_observe,
    reset_counters,
    span,
)

__all__ = [
    "Event",
    "Trace",
    "capture",
    "count",
    "counters",
    "emit",
    "enabled",
    "pop_observe",
    "profiling",
    "push_observe",
    "reset_counters",
    "span",
]
