"""repro.obs — structured tracing, metrics and plan introspection.

The software analog of the paper's control unit + RAM controller
*accounting*: every decision point in the stack — planner resolution
(``repro.plan``), MEASURE sweeps, engine dispatch (``repro.engines``),
fused-kernel VMEM failovers (``repro.kernels``), wisdom load/save, and
service batching (``repro.serve``: queue intake ``serve.queue``, scheduler
heartbeats ``serve.loop.tick`` with queue-depth gauges, per-lane batch
spans ``serve.batch``, quarantine-driven re-resolution
``serve.lane.replan``, wisdom warm starts ``serve.wisdom.warm_start``) —
emits structured events through this package.

    from repro import obs
    import repro.xfft as xfft

    with obs.capture() as trace:
        xfft.fft2(x)                       # cold: plan miss
        xfft.fft2(x)                       # warm: plan hit
    [e["outcome"] for e in trace.select("plan.resolve")]  # ['miss', 'hit']

Process-wide counters stay on even without a capture scope (one dict
increment per event — the ``benchmarks/obs_bench.py`` gate holds the
instrumented hot path within 3% of uninstrumented); ``xfft.report()``
renders them next to the live plan cache, FFTW ``export_wisdom``-style.

Always-on telemetry rides the sink hook (:mod:`repro.obs.telemetry`,
installed at import): a bounded **flight recorder** keeps the most
recent events with no capture scope open and dumps a JSONL snapshot
when a failure trigger fires, and a **calibration ledger** joins planner
predictions against observed engine dispatch times. Latency histograms
(:mod:`repro.obs.hist`) and exporters — JSONL, Chrome trace, Prometheus
text (:mod:`repro.obs.export`) — make all of it consumable by standard
tooling.
"""

from repro.obs import export, hist, telemetry
from repro.obs.hist import (
    LatencyHistogram,
    histogram,
    histograms,
    reset_histograms,
)
from repro.obs.record import (
    Event,
    Trace,
    add_sink,
    capture,
    count,
    counters,
    emit,
    enabled,
    pop_observe,
    profiling,
    push_observe,
    remove_sink,
    reset_counters,
    span,
)
from repro.obs.telemetry import (
    CalibrationLedger,
    FlightRecorder,
    calibration_ledger,
    flight_recorder,
    set_flight_recorder,
)

__all__ = [
    "CalibrationLedger",
    "Event",
    "FlightRecorder",
    "LatencyHistogram",
    "Trace",
    "add_sink",
    "calibration_ledger",
    "capture",
    "count",
    "counters",
    "emit",
    "enabled",
    "export",
    "flight_recorder",
    "hist",
    "histogram",
    "histograms",
    "pop_observe",
    "profiling",
    "push_observe",
    "remove_sink",
    "reset_counters",
    "reset_histograms",
    "set_flight_recorder",
    "span",
    "telemetry",
]

# Always-on by default: the black box records from the first import.
telemetry.install_default()
