"""Event-stream exporters: JSONL, Chrome trace, Prometheus exposition.

The obs substrate records decisions; this module makes them *legible to
standard tooling* without taking a single dependency:

* :func:`write_jsonl` — one JSON object per event, the flight-recorder
  dump format (replayable, greppable, diffable);
* :func:`chrome_trace` / :func:`write_chrome_trace` — the Trace Event
  Format that ``chrome://tracing`` and Perfetto load: span events
  (anything carrying ``duration_us``) become complete ``"X"`` slices on
  per-thread lanes (the serve loop's background thread renders as its
  own track beside callers), instant events become ``"i"`` marks;
* :func:`prometheus_text` / :func:`write_prometheus` — text exposition
  of the process-wide counters, gauges, and latency-histogram quantiles
  in the format every metrics scraper already parses.

Everything here is pure formatting over snapshots — no locks held while
writing, no imports from plan/engines/serve.
"""

from __future__ import annotations

import json
import os
import threading
from typing import Any, Dict, Iterable, List, Mapping, Optional

from repro.obs.hist import LatencyHistogram
from repro.obs.record import Event

__all__ = [
    "chrome_trace",
    "event_dict",
    "prometheus_text",
    "write_chrome_trace",
    "write_jsonl",
    "write_prometheus",
]


def _jsonable(v: Any) -> Any:
    """Coerce a field value to something json.dump accepts (repr fallback:
    a dump must never fail because an event carried an exotic object)."""
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, Mapping):
        return {str(k): _jsonable(x) for k, x in v.items()}
    return repr(v)


def event_dict(event: Event) -> Dict[str, Any]:
    """One event as a JSON-safe dict (the JSONL line schema)."""
    return {
        "name": event.name,
        "t": event.t,
        "tid": event.tid,
        "fields": {str(k): _jsonable(v) for k, v in event.fields.items()},
    }


def write_jsonl(events: Iterable[Event], path: str) -> str:
    """Write ``events`` to ``path`` as JSON Lines; returns the path."""
    with open(path, "w") as fh:
        for event in events:
            fh.write(json.dumps(event_dict(event)) + "\n")
    return path


# ------------------------------ Chrome trace -------------------------------


def chrome_trace(
    events: Iterable[Event],
    thread_names: Optional[Mapping[int, str]] = None,
    pid: Optional[int] = None,
) -> Dict[str, Any]:
    """Build a Trace Event Format document from an event snapshot.

    Span events (``duration_us`` present) become complete ``"X"`` slices —
    ``ts`` is the span *start* (emission happens at exit, so the start is
    ``t - duration``); other events become instant ``"i"`` marks. Each
    emitting thread gets its own lane, labeled via ``thread_names`` (the
    flight recorder collects that map as events arrive).
    """
    pid = os.getpid() if pid is None else pid
    trace_events: List[Dict[str, Any]] = []
    seen_tids: Dict[int, bool] = {}
    names = dict(thread_names or {})
    for event in events:
        seen_tids[event.tid] = True
        args = {str(k): _jsonable(v) for k, v in event.fields.items()}
        dur = event.fields.get("duration_us")
        ts_us = event.t * 1e6
        if isinstance(dur, (int, float)):
            trace_events.append({
                "name": event.name, "ph": "X", "pid": pid, "tid": event.tid,
                "ts": ts_us - float(dur), "dur": float(dur), "args": args,
            })
        else:
            trace_events.append({
                "name": event.name, "ph": "i", "s": "t", "pid": pid,
                "tid": event.tid, "ts": ts_us, "args": args,
            })
    main_tid = threading.main_thread().ident
    for tid in seen_tids:
        label = names.get(tid) or (
            "caller (main)" if tid == main_tid else f"thread-{tid}"
        )
        trace_events.append({
            "name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
            "args": {"name": label},
        })
    return {"traceEvents": trace_events, "displayTimeUnit": "ms"}


def write_chrome_trace(
    events: Iterable[Event],
    path: str,
    thread_names: Optional[Mapping[int, str]] = None,
) -> str:
    """Write :func:`chrome_trace` of ``events`` to ``path``; returns it."""
    with open(path, "w") as fh:
        json.dump(chrome_trace(events, thread_names=thread_names), fh)
    return path


# ------------------------------ Prometheus ---------------------------------


def _label_value(value: Any) -> str:
    s = str(value)
    return s.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def prometheus_text(
    counters: Optional[Mapping[str, int]] = None,
    gauges: Optional[Mapping[str, float]] = None,
    histograms: Optional[Mapping[str, LatencyHistogram]] = None,
) -> str:
    """Render counters, gauges, and histogram quantiles as Prometheus
    text exposition (counters under one ``repro_events_total`` family,
    histograms as summary-style quantile series in microseconds)."""
    lines: List[str] = []
    if counters:
        lines.append("# TYPE repro_events_total counter")
        for name, value in sorted(counters.items()):
            lines.append(
                f'repro_events_total{{event="{_label_value(name)}"}} {int(value)}'
            )
    if gauges:
        lines.append("# TYPE repro_gauge gauge")
        for name, value in sorted(gauges.items()):
            lines.append(
                f'repro_gauge{{name="{_label_value(name)}"}} {float(value)}'
            )
    if histograms:
        lines.append("# TYPE repro_latency_us summary")
        for name, h in sorted(histograms.items()):
            label = _label_value(name)
            for q in (50, 95, 99):
                lines.append(
                    f'repro_latency_us{{hist="{label}",quantile="0.{q}"}} '
                    f"{h.percentile(q)}"
                )
            lines.append(f'repro_latency_us_count{{hist="{label}"}} {h.count}')
            lines.append(f'repro_latency_us_sum{{hist="{label}"}} {h.sum_us}')
    return "\n".join(lines) + ("\n" if lines else "")


def write_prometheus(
    path: str,
    counters: Optional[Mapping[str, int]] = None,
    gauges: Optional[Mapping[str, float]] = None,
    histograms: Optional[Mapping[str, LatencyHistogram]] = None,
) -> str:
    """Write :func:`prometheus_text` to ``path``; returns the path."""
    with open(path, "w") as fh:
        fh.write(prometheus_text(counters=counters, gauges=gauges,
                                 histograms=histograms))
    return path
