"""Always-on telemetry: the flight recorder and the calibration ledger.

Two process-wide sinks (:func:`repro.obs.record.add_sink`) that run with
*no* capture scope open — the black box a serving fleet member carries:

* :class:`FlightRecorder` — a bounded ring of the most recent events
  (``collections.deque(maxlen=...)``: one GIL-atomic append per event,
  no lock on the hot path). When an **armed trigger** fires — an engine
  failover, a circuit breaker opening, a shed request, a lane error —
  the ring is dumped to a JSONL snapshot *at that instant*, so the
  events leading up to the failure are preserved even though nobody had
  a ``capture()`` open when it happened. Scope or replace it with
  ``xfft.config(flight_recorder=...)``; read it back via
  ``xfft.report()``.
* :class:`CalibrationLedger` — joins the planner's *predictions*
  (``plan.resolve``'s ``est_time_s``/``measured_us``, per-candidate
  ``plan.measure.candidate`` timings) against *observed* ``engine.apply``
  span durations per (engine, kind, shape, precision). The resulting
  mispricing table (observed/predicted ratio, sample counts) is exactly
  the data the ESTIMATE-recalibration roadmap item needs, rendered in
  ``xfft.report()`` and gated in ``benchmarks/obs_bench.py``.

Both are installed at ``repro.obs`` import (:func:`install_default`) —
always-on is the default; ``xfft.config(flight_recorder=False)`` turns
the recorder off for a scope. Neither sink ever emits events of its own
(counters only), so a recorder can never recurse through itself.
"""

from __future__ import annotations

import collections
import os
import tempfile
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.obs import record as _record
from repro.obs.export import event_dict, write_jsonl
from repro.obs.hist import LatencyHistogram, histogram
from repro.obs.record import Event

__all__ = [
    "CalibrationLedger",
    "DEFAULT_TRIGGERS",
    "FlightRecorder",
    "calibration_ledger",
    "flight_recorder",
    "install_default",
    "set_calibration_ledger",
    "set_flight_recorder",
]

#: Event names that trigger an automatic flight dump. ``resilience.breaker``
#: is special-cased: only the ``state="open"`` transition dumps (half-open
#: probes and closes are recovery, not failure).
DEFAULT_TRIGGERS = frozenset({
    "resilience.failover",
    "resilience.breaker",
    "serve.shed",
    "serve.lane.error",
})


def _default_dump_dir() -> str:
    return os.environ.get(
        "REPRO_FLIGHT_DIR",
        os.path.join(tempfile.gettempdir(), f"repro-flight-{os.getpid()}"),
    )


class FlightRecorder:
    """Bounded always-on event ring with trigger-armed JSONL dumps.

    ``capacity`` — ring size in events (default 4096 ≈ a few thousand
    transform calls of context). ``triggers`` — event names that dump the
    ring; ``max_dumps`` caps files written per process so a flapping
    breaker cannot fill a disk (excess triggers are counted, not written).
    ``dump_dir`` defaults to ``$REPRO_FLIGHT_DIR`` or a pid-scoped tmpdir,
    created lazily on first dump.
    """

    def __init__(
        self,
        capacity: int = 4096,
        dump_dir: Optional[str] = None,
        triggers: frozenset = DEFAULT_TRIGGERS,
        max_dumps: int = 8,
    ):
        if capacity < 1:
            raise ValueError(f"flight recorder capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.dump_dir = dump_dir
        self.triggers = frozenset(triggers)
        self.max_dumps = int(max_dumps)
        self._ring: "collections.deque[Event]" = collections.deque(maxlen=capacity)
        self._thread_names: Dict[int, str] = {}
        self._dumps: List[Dict[str, Any]] = []
        self._dropped_dumps = 0
        self._recorded = 0
        self._dump_lock = threading.Lock()

    # -- the sink (hot path: one deque append, no lock) ---------------------

    def record(self, event: Event) -> None:
        self._ring.append(event)
        self._recorded += 1
        tid = event.tid
        if tid not in self._thread_names:
            self._thread_names[tid] = threading.current_thread().name
        if event.name in self.triggers:
            if event.name == "resilience.breaker" and \
                    event.fields.get("state") != "open":
                return
            self._auto_dump(event.name)

    # -- reading the box ----------------------------------------------------

    def events(self) -> List[Event]:
        """Snapshot of the ring, oldest first."""
        return list(self._ring)

    def thread_names(self) -> Dict[int, str]:
        """tid -> thread name for every thread seen by this recorder."""
        return dict(self._thread_names)

    def clear(self) -> None:
        self._ring.clear()

    def stats(self) -> Dict[str, Any]:
        """Report payload: capacity, retention, dump accounting."""
        return {
            "capacity": self.capacity,
            "retained": len(self._ring),
            "recorded_total": self._recorded,
            "dumps": list(self._dumps),
            "dropped_dumps": self._dropped_dumps,
        }

    # -- dumping ------------------------------------------------------------

    def _auto_dump(self, trigger: str) -> None:
        with self._dump_lock:
            if len(self._dumps) >= self.max_dumps:
                self._dropped_dumps += 1
                _record.count("obs.flight.dump_dropped")
                return
            seq = len(self._dumps) + 1
        try:
            self.dump(trigger=trigger, _seq=seq)
        except OSError:
            _record.count("obs.flight.dump_error")

    def dump(self, path: Optional[str] = None, trigger: str = "manual",
             _seq: Optional[int] = None) -> str:
        """Write the ring snapshot as JSONL; returns the path written.

        The snapshot is taken *before* any IO, so the triggering event —
        appended by :meth:`record` before the trigger check — is the last
        line of the file. Counts ``obs.flight.dump``; never emits (a dump
        inside event delivery must not re-enter event delivery).
        """
        snapshot = list(self._ring)
        if path is None:
            directory = self.dump_dir or _default_dump_dir()
            os.makedirs(directory, exist_ok=True)
            seq = _seq if _seq is not None else len(self._dumps) + 1
            slug = trigger.replace(".", "_")
            path = os.path.join(directory, f"flight-{seq:04d}-{slug}.jsonl")
        write_jsonl(snapshot, path)
        with self._dump_lock:
            self._dumps.append(
                {"path": path, "trigger": trigger, "events": len(snapshot)}
            )
        _record.count("obs.flight.dump")
        return path


# ---------------------------- calibration ----------------------------------

RowKey = Tuple[str, str, Tuple[int, ...], str]  # (engine, kind, shape, precision)


class CalibrationLedger:
    """Joins planner predictions against observed engine dispatch times.

    Predictions arrive from two event families: ``plan.resolve`` carries
    the chosen variant's analytic estimate (``est_time_s``) and, for
    MEASURE-grade plans, the swept ``measured_us``; per-candidate
    ``plan.measure.candidate`` events carry swept timings for the
    variants that *lost* (so mispricing is visible even for engines the
    planner never picks). Observations are ``engine.apply`` span
    durations with ``ok=True`` — dispatches that raised (injected faults,
    real failures) never pollute the timing population.
    """

    def __init__(self):
        self._rows: Dict[RowKey, Dict[str, Any]] = {}
        self._lock = threading.Lock()
        self._handlers: Dict[str, Callable[[Event], None]] = {
            "plan.resolve": self._on_resolve,
            "plan.measure.candidate": self._on_candidate,
            "engine.apply": self._on_apply,
        }

    # the sink: one dict lookup for every non-ledger event
    def record(self, event: Event) -> None:
        handler = self._handlers.get(event.name)
        if handler is not None:
            handler(event)

    @staticmethod
    def _row_key(f: Dict[str, Any], engine_field: str) -> Optional[RowKey]:
        engine = f.get(engine_field)
        kind = f.get("kind")
        shape = f.get("shape")
        if engine is None or kind is None or shape is None:
            return None
        return (
            str(engine), str(kind), tuple(shape), str(f.get("precision", "single"))
        )

    def _row(self, key: RowKey) -> Dict[str, Any]:
        row = self._rows.get(key)
        if row is None:
            row = {
                "estimate_us": None,   # analytic estimate_variant_time
                "measured_us": None,   # MEASURE sweep median
                "observed": LatencyHistogram(),
            }
            self._rows[key] = row
        return row

    def _on_resolve(self, event: Event) -> None:
        f = event.fields
        key = self._row_key(f, "variant")
        if key is None:
            return
        with self._lock:
            row = self._row(key)
            est = f.get("est_time_s")
            if isinstance(est, (int, float)):
                row["estimate_us"] = float(est) * 1e6
            measured = f.get("measured_us")
            if isinstance(measured, (int, float)):
                row["measured_us"] = float(measured)

    def _on_candidate(self, event: Event) -> None:
        f = event.fields
        key = self._row_key(f, "engine")
        if key is None:
            return
        us = f.get("median_us")
        if not isinstance(us, (int, float)):
            return
        with self._lock:
            self._row(key)["measured_us"] = float(us)

    def _on_apply(self, event: Event) -> None:
        f = event.fields
        if not f.get("ok"):
            return
        dur = f.get("duration_us")
        if not isinstance(dur, (int, float)):
            return
        key = self._row_key(f, "engine")
        if key is None:
            return
        with self._lock:
            self._row(key)["observed"].record(float(dur))
        # per-engine latency view, beside the per-lane serve histograms
        histogram(f"engine.{f['engine']}").record(float(dur))

    def reset(self) -> None:
        with self._lock:
            self._rows.clear()

    def table(self) -> List[Dict[str, Any]]:
        """The mispricing table: one row per (engine, kind, shape,
        precision) with a prediction, sorted worst mispricing first.

        ``predicted_us`` prefers the swept measurement over the analytic
        estimate (MEASURE *is* the planner's belief when present);
        ``ratio`` is observed-p50 / predicted — >1 means the planner is
        optimistic about that engine, <1 pessimistic.
        """
        with self._lock:
            items = [(k, dict(v, observed=v["observed"])) for k, v in
                     self._rows.items()]
        rows: List[Dict[str, Any]] = []
        for (engine, kind, shape, precision), row in items:
            hist: LatencyHistogram = row["observed"]
            predicted = row["measured_us"]
            source = "measure"
            if predicted is None:
                predicted = row["estimate_us"]
                source = "estimate"
            if predicted is None:
                continue
            observed_p50 = hist.percentile(50)
            ratio = (observed_p50 / predicted) if (hist.count and predicted) else None
            rows.append({
                "engine": engine,
                "kind": kind,
                "shape": list(shape),
                "precision": precision,
                "predicted_us": round(float(predicted), 2),
                "predicted_source": source,
                "observed_p50_us": round(observed_p50, 2) if hist.count else None,
                "observed_n": hist.count,
                "ratio": round(ratio, 3) if ratio is not None else None,
            })
        rows.sort(
            key=lambda r: abs((r["ratio"] or 1.0) - 1.0), reverse=True
        )
        return rows


# -------------------------- process-wide install ----------------------------

_RECORDER: Optional[FlightRecorder] = None
_LEDGER: Optional[CalibrationLedger] = None
_INSTALL_LOCK = threading.Lock()


def flight_recorder() -> Optional[FlightRecorder]:
    """The installed process-wide flight recorder (None when disabled)."""
    return _RECORDER


def set_flight_recorder(
    recorder: Optional[FlightRecorder],
) -> Optional[FlightRecorder]:
    """Install ``recorder`` as the process flight recorder (None turns the
    black box off); returns the previous recorder for restore."""
    global _RECORDER
    with _INSTALL_LOCK:
        previous = _RECORDER
        if previous is not None:
            _record.remove_sink(previous.record)
        _RECORDER = recorder
        if recorder is not None:
            _record.add_sink(recorder.record)
        return previous


def calibration_ledger() -> CalibrationLedger:
    """The process-wide calibration ledger (installed at obs import)."""
    with _INSTALL_LOCK:
        return _LEDGER if _LEDGER is not None else _install_ledger()


def set_calibration_ledger(
    ledger: Optional[CalibrationLedger],
) -> Optional[CalibrationLedger]:
    """Swap the process ledger (tests); returns the previous one."""
    global _LEDGER
    with _INSTALL_LOCK:
        previous = _LEDGER
        if previous is not None:
            _record.remove_sink(previous.record)
        _LEDGER = ledger
        if ledger is not None:
            _record.add_sink(ledger.record)
        return previous


def _install_ledger() -> CalibrationLedger:
    global _LEDGER
    _LEDGER = CalibrationLedger()
    _record.add_sink(_LEDGER.record)
    return _LEDGER


def install_default() -> None:
    """Install the default always-on recorder + ledger (idempotent); the
    capacity default can be overridden via ``$REPRO_FLIGHT_CAPACITY``
    and the whole recorder disabled via ``REPRO_FLIGHT_RECORDER=0``."""
    global _RECORDER
    with _INSTALL_LOCK:
        if _LEDGER is None:
            _install_ledger()
        if _RECORDER is None and os.environ.get(
            "REPRO_FLIGHT_RECORDER", "1"
        ) not in ("0", "off", "false"):
            capacity = int(os.environ.get("REPRO_FLIGHT_CAPACITY", "4096"))
            _RECORDER = FlightRecorder(capacity=capacity)
            _record.add_sink(_RECORDER.record)
