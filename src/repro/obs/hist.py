"""Fixed log-bucket latency histograms: mergeable, bounded, exact-ish tails.

The serve loop's product is its latency *tail*, and raw-sample
percentiles (``np.percentile`` over an unbounded list) are the wrong
tool for a long-running process: memory grows with traffic, merging two
processes' samples means shipping both lists, and the estimate jumps
around with every batch. A :class:`LatencyHistogram` fixes all three
with the standard HDR trick — fixed logarithmic buckets over the
microsecond domain:

* **bounded** — ``buckets`` integer cells, regardless of sample count;
* **mergeable** — two histograms with the same geometry add cell-wise,
  so per-lane, per-engine and per-process views compose;
* **exact within bucket resolution** — a reported percentile is the
  upper bound of the cell holding that rank, so it is within one
  ``growth`` factor (~19% at the default quarter-octave geometry) of
  the true order statistic, *by construction*, at any traffic volume.

The process-wide named registry (:func:`histogram`) is how the serve
loop and the engine dispatch spans attach their observations without
threading handles through every layer; ``xfft.report()`` and the
Prometheus exporter read :func:`histograms` back out.
"""

from __future__ import annotations

import math
import threading
from typing import Dict, List, Optional

__all__ = [
    "LatencyHistogram",
    "histogram",
    "histograms",
    "reset_histograms",
]


class LatencyHistogram:
    """Log-bucket histogram over microseconds: record / merge / percentile.

    Geometry: cell 0 holds everything ``<= min_us``; cell ``i`` holds
    ``(min_us * growth**(i-1), min_us * growth**i]``; the last cell is a
    catch-all for the far tail. The default quarter-octave growth
    (``2**0.25 ≈ 1.19``) over 128 cells spans 1 µs to ~66 minutes.
    """

    __slots__ = ("min_us", "growth", "buckets", "_log_growth", "_cells",
                 "count", "sum_us", "max_us", "_lock")

    def __init__(self, min_us: float = 1.0, growth: float = 2 ** 0.25,
                 buckets: int = 128):
        if min_us <= 0 or growth <= 1.0 or buckets < 2:
            raise ValueError(
                f"bad histogram geometry: min_us={min_us} growth={growth} "
                f"buckets={buckets}"
            )
        self.min_us = float(min_us)
        self.growth = float(growth)
        self.buckets = int(buckets)
        self._log_growth = math.log(self.growth)
        self._cells = [0] * self.buckets
        self.count = 0
        self.sum_us = 0.0
        self.max_us = 0.0
        self._lock = threading.Lock()

    def bucket_index(self, us: float) -> int:
        """The cell a latency of ``us`` microseconds falls into.

        Upper bounds are inclusive: the epsilon keeps a value sitting
        exactly on ``bucket_bound(i)`` (e.g. a reported percentile fed
        back in) in cell ``i`` despite floating-point log round-off.
        """
        if us <= self.min_us:
            return 0
        i = 1 + int(math.log(us / self.min_us) / self._log_growth - 1e-9)
        return min(i, self.buckets - 1)

    def bucket_bound(self, index: int) -> float:
        """Upper bound (µs) of cell ``index`` — what percentiles report."""
        return self.min_us * self.growth ** index

    def record(self, us: float) -> None:
        """Add one observation of ``us`` microseconds."""
        us = max(float(us), 0.0)
        i = self.bucket_index(us)
        with self._lock:
            self._cells[i] += 1
            self.count += 1
            self.sum_us += us
            if us > self.max_us:
                self.max_us = us

    def merge(self, other: "LatencyHistogram") -> None:
        """Add ``other``'s cells into this histogram (same geometry only)."""
        if (other.min_us, other.growth, other.buckets) != (
            self.min_us, self.growth, self.buckets
        ):
            raise ValueError("cannot merge histograms with different geometry")
        with other._lock:
            cells = list(other._cells)
            count, sum_us, max_us = other.count, other.sum_us, other.max_us
        with self._lock:
            for i, c in enumerate(cells):
                self._cells[i] += c
            self.count += count
            self.sum_us += sum_us
            if max_us > self.max_us:
                self.max_us = max_us

    def percentile(self, p: float) -> float:
        """The latency (µs) at percentile ``p`` — the upper bound of the
        cell where the cumulative count crosses rank ``ceil(p/100 * n)``.
        Returns 0.0 when empty."""
        with self._lock:
            n = self.count
            if n == 0:
                return 0.0
            target = max(1, math.ceil(n * p / 100.0))
            seen = 0
            for i, c in enumerate(self._cells):
                seen += c
                if seen >= target:
                    return self.bucket_bound(i)
        return self.bucket_bound(self.buckets - 1)  # pragma: no cover

    def mean_us(self) -> float:
        with self._lock:
            return self.sum_us / self.count if self.count else 0.0

    def cells(self) -> List[int]:
        """Snapshot of the raw cell counts (tests / exporters)."""
        with self._lock:
            return list(self._cells)

    def to_dict(self) -> Dict[str, float]:
        """Summary for benchmark JSON and the report: count + tail stats."""
        return {
            "count": self.count,
            "mean_us": round(self.mean_us(), 2),
            "p50_us": round(self.percentile(50), 2),
            "p95_us": round(self.percentile(95), 2),
            "p99_us": round(self.percentile(99), 2),
            "max_us": round(self.max_us, 2),
        }

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"LatencyHistogram(n={self.count}, p50={self.percentile(50):.1f}us, "
                f"p99={self.percentile(99):.1f}us)")


# ------------------------ process-wide registry ----------------------------

_HISTS: Dict[str, LatencyHistogram] = {}
_HISTS_LOCK = threading.Lock()


def histogram(name: str, *, min_us: float = 1.0, growth: float = 2 ** 0.25,
              buckets: int = 128) -> LatencyHistogram:
    """Get-or-create the process-wide histogram ``name``.

    Geometry arguments apply only on first creation; every later caller
    shares the same instance (that is what makes lane and engine views
    accumulate across the process lifetime).
    """
    with _HISTS_LOCK:
        h = _HISTS.get(name)
        if h is None:
            h = LatencyHistogram(min_us=min_us, growth=growth, buckets=buckets)
            _HISTS[name] = h
        return h


def histograms(prefix: Optional[str] = None) -> Dict[str, LatencyHistogram]:
    """Snapshot of the registry (optionally filtered by name prefix)."""
    with _HISTS_LOCK:
        items = sorted(_HISTS.items())
    if prefix is None:
        return dict(items)
    return {k: v for k, v in items if k.startswith(prefix)}


def reset_histograms() -> None:
    """Drop every registered histogram (tests / benchmark harnesses)."""
    with _HISTS_LOCK:
        _HISTS.clear()
