"""The observability substrate: events, spans, counters, capture scopes.

The paper's processor is legible because its control unit *accounts for*
every data movement it schedules: the RAM controller knows which buffer
each butterfly pass read and wrote. The software control unit
(``repro.plan`` over ``repro.engines``) makes the same class of decisions
— cache hit or MEASURE sweep, fused kernel or unfused failover, one
batched group or many — and this module is where those decisions become
*records* instead of vanishing into return values.

Three primitives, one cost rule:

* :func:`emit` — one structured :class:`Event` (name + fields). Delivered
  to every :func:`capture` scope on the contextvars stack; when no scope
  is active the only work done is one counter increment and one
  contextvar read (the "near-zero when disabled" contract the
  ``benchmarks/obs_bench.py`` gate enforces).
* :func:`span` — a timed region. Emits its event (with ``duration_us``)
  on exit and, when profiling is scoped on (``xfft.config(observe=True)``
  or ``capture(profile=True)``), also wraps the region in
  ``jax.profiler.TraceAnnotation`` so it lands in XLA profiles.
* :func:`count` / :func:`counters` — process-wide monotonic counters.
  Always on: they are how a process that never opens a capture scope
  (a serving fleet member) still answers "did my shipped wisdom load?"
  through :func:`repro.xfft.report`.

Scoping is :mod:`contextvars`-based: capture scopes nest (an inner scope
sees only its own window; every enclosing scope sees the inner events
too), compose across async tasks, and never observe another thread's
events. This module imports nothing from the rest of the repo — plan,
engines, kernels and serve all instrument through it.

Process-wide **sinks** (:func:`add_sink`) sit beside the capture stack:
a sink receives every event from every thread, scope or no scope — the
hook the always-on flight recorder and the planner calibration ledger
(:mod:`repro.obs.telemetry`) hang off. Sinks do not change :func:`emit`'s
return contract (still ``None`` with no capture scope), and a sink that
raises is counted (``obs.sink.error``) and skipped, never propagated
into the instrumented call.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import threading
import time
from typing import Any, Dict, Iterator, List, Optional, Tuple

__all__ = [
    "Event",
    "Trace",
    "add_sink",
    "capture",
    "count",
    "counters",
    "emit",
    "enabled",
    "profiling",
    "remove_sink",
    "reset_counters",
    "span",
]


@dataclasses.dataclass(frozen=True)
class Event:
    """One recorded decision: a dotted name, a timestamp, its fields."""

    name: str
    t: float                    # time.perf_counter() at emission
    fields: Dict[str, Any]
    tid: int = 0                # threading.get_ident() of the emitter

    def __getitem__(self, field: str) -> Any:
        return self.fields[field]

    def get(self, field: str, default: Any = None) -> Any:
        return self.fields.get(field, default)


class Trace:
    """Events recorded by one :func:`capture` scope, in emission order."""

    def __init__(self):
        self.events: List[Event] = []

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self) -> Iterator[Event]:
        return iter(self.events)

    def append(self, event: Event) -> None:
        self.events.append(event)

    def select(self, name: str) -> List[Event]:
        """Events with exactly ``name``, or under a ``"prefix.*"`` glob."""
        if name.endswith(".*"):
            prefix = name[:-1]  # keep the dot: "plan.*" -> "plan."
            return [e for e in self.events if e.name.startswith(prefix)]
        return [e for e in self.events if e.name == name]

    def first(self, name: str) -> Optional[Event]:
        hits = self.select(name)
        return hits[0] if hits else None

    def counts(self) -> Dict[str, int]:
        """Event-name histogram of this trace's window."""
        out: Dict[str, int] = {}
        for e in self.events:
            out[e.name] = out.get(e.name, 0) + 1
        return out

    def summary(self) -> str:
        """Human-readable one-line-per-event rendering."""
        lines = [f"trace: {len(self.events)} events"]
        for e in self.events:
            fields = " ".join(f"{k}={_short(v)}" for k, v in e.fields.items())
            lines.append(f"  {e.name}  {fields}")
        return "\n".join(lines)


def _short(v: Any) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    s = str(v)
    return s if len(s) <= 48 else s[:45] + "..."


# ------------------------------ collectors --------------------------------

_STACK: contextvars.ContextVar[Tuple[Trace, ...]] = contextvars.ContextVar(
    "repro_obs_stack", default=()
)
_PROFILE: contextvars.ContextVar[bool] = contextvars.ContextVar(
    "repro_obs_profile", default=False
)

_COUNTS: Dict[str, int] = {}
_COUNTS_LOCK = threading.Lock()

# Process-wide sinks: callables fed every Event from every thread. Stored
# as an immutable tuple so emit() reads one reference with no lock; the
# lock only serialises (un)installation.
_SINKS: Tuple[Any, ...] = ()
_SINKS_LOCK = threading.Lock()


def add_sink(sink) -> None:
    """Install ``sink(event)`` to receive every event process-wide."""
    global _SINKS
    with _SINKS_LOCK:
        if sink not in _SINKS:
            _SINKS = _SINKS + (sink,)


def remove_sink(sink) -> None:
    """Uninstall a sink previously passed to :func:`add_sink` (no-op if
    absent). Matches by equality, not identity: ``recorder.record`` is a
    fresh bound-method object at every attribute access, and bound
    methods compare equal when receiver and function match."""
    global _SINKS
    with _SINKS_LOCK:
        _SINKS = tuple(s for s in _SINKS if s != sink)


def enabled() -> bool:
    """True when at least one capture scope is collecting events here."""
    return bool(_STACK.get())


def profiling() -> bool:
    """True when spans should also become ``jax.profiler`` annotations."""
    return _PROFILE.get()


def count(name: str, n: int = 1) -> None:
    """Bump the process-wide counter ``name`` by ``n`` (thread-safe)."""
    with _COUNTS_LOCK:
        _COUNTS[name] = _COUNTS.get(name, 0) + n


def counters() -> Dict[str, int]:
    """Snapshot of every process-wide counter, sorted by name."""
    with _COUNTS_LOCK:
        return dict(sorted(_COUNTS.items()))


def reset_counters() -> None:
    """Zero the process-wide counters (tests / benchmark harnesses)."""
    with _COUNTS_LOCK:
        _COUNTS.clear()


def emit(name: str, **fields: Any) -> Optional[Event]:
    """Record one event; returns it when any capture scope received it.

    Always bumps the ``name`` counter. With no active scope and no
    installed sink that counter increment, one contextvar read and one
    global read are the entire cost — the fields dict the caller built
    is dropped without ever becoming an Event. Sinks receive the event
    regardless of scope, but the return value reflects only the capture
    stack (callers test it to know whether anyone in *their* context is
    listening).
    """
    count(name)
    stack = _STACK.get()
    sinks = _SINKS
    if not stack and not sinks:
        return None
    event = Event(
        name=name, t=time.perf_counter(), fields=fields,
        tid=threading.get_ident(),
    )
    for sink in sinks:
        try:
            sink(event)
        except Exception:
            count("obs.sink.error")
    for trace in stack:
        trace.append(event)
    return event if stack else None


def _annotation(name: str):
    """A ``jax.profiler.TraceAnnotation`` for ``name``, or None when jax
    (or the annotation API) is unavailable — profiling degrades silently
    rather than making obs depend on jax."""
    try:  # pragma: no cover - depends on installed jax
        from jax.profiler import TraceAnnotation

        return TraceAnnotation(name)
    except Exception:  # pragma: no cover
        return None


@contextlib.contextmanager
def span(name: str, **fields: Any):
    """Time a region; emit ``name`` with ``duration_us`` on exit.

    Yields a mutable dict merged into the final event's fields, so
    results computed inside the region can ride the span's event::

        with obs.span("plan.measure", kind=key.kind) as out:
            out["chosen"] = sweep()

    When profiling is scoped on, the region is also wrapped in a
    ``jax.profiler.TraceAnnotation`` so it shows up in XLA traces.
    """
    extra: Dict[str, Any] = {}
    stack = _STACK.get()
    prof = _PROFILE.get()
    if not stack and not prof and not _SINKS:
        # Disabled fast path: one counter bump, no timing, no Event.
        count(name)
        yield extra
        return
    annotation = _annotation(name) if prof else None
    if annotation is not None:
        annotation.__enter__()
    t0 = time.perf_counter()
    try:
        yield extra
    finally:
        duration_us = (time.perf_counter() - t0) * 1e6
        if annotation is not None:
            annotation.__exit__(None, None, None)
        emit(name, duration_us=duration_us, **{**fields, **extra})


@contextlib.contextmanager
def capture(profile: Optional[bool] = None):
    """Collect every event emitted in this scope into a fresh :class:`Trace`.

    Scopes nest: the inner trace holds only its own window, every
    enclosing trace receives the inner events too. ``profile=True`` also
    turns spans into ``jax.profiler`` annotations for the scope
    (``profile=False`` forces them off; ``None`` inherits).
    """
    trace = Trace()
    token = _STACK.set(_STACK.get() + (trace,))
    profile_token = (
        _PROFILE.set(bool(profile)) if profile is not None else None
    )
    try:
        yield trace
    finally:
        if profile_token is not None:
            _PROFILE.reset(profile_token)
        _STACK.reset(token)


# Scope hooks for repro.xfft.config(observe=...): push/pop without a with-
# block (config supports global-setter usage, so it holds tokens itself).


def push_observe(observe) -> Tuple[Any, Any]:
    """Apply an ``observe`` policy; returns tokens for :func:`pop_observe`.

    ``observe`` is a :class:`Trace` (collect the scope's events into it),
    ``True`` (profiler annotations on), or ``False`` (both off).
    """
    stack_token = None
    if isinstance(observe, Trace):
        stack_token = _STACK.set(_STACK.get() + (observe,))
        profile_token = _PROFILE.set(_PROFILE.get())
    else:
        profile_token = _PROFILE.set(bool(observe))
        if observe is False:
            stack_token = _STACK.set(())
    return stack_token, profile_token


def pop_observe(tokens: Tuple[Any, Any]) -> None:
    """Undo one :func:`push_observe` (LIFO)."""
    stack_token, profile_token = tokens
    _PROFILE.reset(profile_token)
    if stack_token is not None:
        _STACK.reset(stack_token)
