"""Correlation pattern recognition via the paper's 2D FFT engine — one of
the paper's motivating applications (abstract: "correlation pattern
recognition, digital holography"). A matched filter locates a template in
a noisy scene entirely in the Fourier domain:

  correlation = IFFT2( FFT2(scene) · conj(FFT2(template)) )

Scene and template are REAL, so the whole pipeline runs through the
two-for-one ``rfft2``/``irfft2`` path (``repro.core.correlate2``): the
conjugate-symmetric half spectrum carries all the information — half the
arithmetic and HBM traffic of the complex transform, same peak.

  PYTHONPATH=src python examples/correlator.py
"""

import jax.numpy as jnp
import numpy as np

import repro.xfft as xfft
from repro.core import correlate2


def make_scene(hw: int = 128, seed: int = 0):
    rng = np.random.default_rng(seed)
    scene = rng.standard_normal((hw, hw)).astype(np.float32) * 0.3
    # the template: a small cross
    t = np.zeros((16, 16), np.float32)
    t[7:9, :] = 1.0
    t[:, 7:9] = 1.0
    true_pos = (37, 81)
    scene[true_pos[0]:true_pos[0]+16, true_pos[1]:true_pos[1]+16] += t
    template = np.zeros((hw, hw), np.float32)
    template[:16, :16] = t
    return scene, template, true_pos


def main():
    scene, template, true_pos = make_scene()

    # Real-input matched filter: rfft2 → conj-multiply → irfft2 (plan-backed
    # by default — no variant kwarg needed anywhere anymore).
    corr = np.asarray(correlate2(jnp.asarray(scene), jnp.asarray(template)))
    peak = np.unravel_index(corr.argmax(), corr.shape)
    print(f"true position {true_pos}, detected {tuple(int(p) for p in peak)}")
    ok = abs(peak[0] - true_pos[0]) <= 1 and abs(peak[1] - true_pos[1]) <= 1
    print("matched-filter detection (real two-for-one path):", "OK" if ok else "FAILED")

    # Cross-check: the full complex pipeline finds the same peak (xfft
    # namespace, plan-backed — no variant kwargs anywhere).
    fs = xfft.fft2(jnp.asarray(scene).astype(np.complex64))
    ft = xfft.fft2(jnp.asarray(template).astype(np.complex64))
    corr_c = np.asarray(jnp.real(xfft.ifft2(fs * jnp.conj(ft))))
    peak_c = np.unravel_index(corr_c.argmax(), corr_c.shape)
    agree = tuple(int(p) for p in peak) == tuple(int(p) for p in peak_c)
    print(f"complex-path peak agrees: {agree} "
          f"(max |real - complex| = {np.max(np.abs(corr - corr_c)):.2e})")

    # Power spectrum (holography-style display, DC centred). The half
    # spectrum from rfft2 suffices for the display's left half; the full
    # surface comes from the complex transform for the centred view.
    half = np.asarray(jnp.abs(xfft.rfft2(jnp.asarray(scene))))
    print(f"rfft2 half-spectrum shape: {half.shape} (vs full {fs.shape})")
    ps = np.asarray(jnp.abs(xfft.fftshift2(fs)))
    print(f"scene power-spectrum peak at centre: "
          f"{bool(ps[64, 64] == ps.max() or ps.max() > 0)}")
    if not (ok and agree):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
