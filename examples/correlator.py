"""Correlation pattern recognition via the paper's 2D FFT engine — one of
the paper's motivating applications (abstract: "correlation pattern
recognition, digital holography"). A matched filter locates a template in
a noisy scene entirely in the Fourier domain:

  correlation = IFFT2( FFT2(scene) · conj(FFT2(template)) )

  PYTHONPATH=src python examples/correlator.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import fft2, fftshift2, ifft2


def make_scene(hw: int = 128, seed: int = 0):
    rng = np.random.default_rng(seed)
    scene = rng.standard_normal((hw, hw)).astype(np.float32) * 0.3
    # the template: a small cross
    t = np.zeros((16, 16), np.float32)
    t[7:9, :] = 1.0
    t[:, 7:9] = 1.0
    true_pos = (37, 81)
    scene[true_pos[0]:true_pos[0]+16, true_pos[1]:true_pos[1]+16] += t
    template = np.zeros((hw, hw), np.float32)
    template[:16, :16] = t
    return scene, template, true_pos


def main():
    scene, template, true_pos = make_scene()
    fs = fft2(jnp.asarray(scene))
    ft = fft2(jnp.asarray(template))
    corr = np.asarray(jnp.real(ifft2(fs * jnp.conj(ft))))
    peak = np.unravel_index(corr.argmax(), corr.shape)
    print(f"true position {true_pos}, detected {tuple(int(p) for p in peak)}")
    ok = abs(peak[0] - true_pos[0]) <= 1 and abs(peak[1] - true_pos[1]) <= 1
    print("matched-filter detection:", "OK" if ok else "FAILED")

    # power spectrum (holography-style display, DC centred)
    ps = np.asarray(jnp.abs(fftshift2(fs)))
    print(f"scene power-spectrum peak at centre: "
          f"{bool(ps[64, 64] == ps.max() or ps.max() > 0)}")
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
