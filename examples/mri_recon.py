"""End-to-end MRI reconstruction on the planned FFT stack.

The PR-10 workload, in ~60 lines:

1. **acquire** — undersample the Shepp-Logan phantom's multi-coil
   k-space with a seeded variable-density Cartesian mask (R≈2) and
   estimate coil sensitivities from the data's own calibration block
   (ESPIRiT-lite) — no ground-truth maps anywhere downstream;
2. **warm start** — load the packaged wisdom artifact so the service's
   CG transforms resolve MEASURE-grade plans with zero tuning cost;
3. **reconstruct** — submit :class:`repro.serve.ReconRequest`s to the
   ``ImagingService`` recon lane: the queue coalesces into ONE batched
   CG-SENSE solve (tens of planned centered transforms over two
   problem keys, all plan-cache hits after the first);
4. **introspect** — NRMSE vs the phantom for zero-filled and CG, then
   ``xfft.report()``: the plan table, counters and the recon lane's
   latency histogram, straight from the flight recorder.

  PYTHONPATH=src python examples/mri_recon.py --size 64 --requests 4
"""

import argparse

import numpy as np

import repro.xfft as xfft
from repro import mri
from repro.plan import PlanCache
from repro.serve import ImagingService, ReconRequest, wisdom


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", type=int, default=64, help="frame size (pow2)")
    ap.add_argument("--coils", type=int, default=4)
    ap.add_argument("--accel", type=int, default=2)
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--requests", type=int, default=4)
    args = ap.parse_args()

    # 1. the acquisition: phantom -> coil k-space -> undersample -> maps
    phantom = np.asarray(mri.shepp_logan(args.size))
    truth_maps = mri.birdcage_maps(args.coils, args.size)
    mask = mri.variable_density_mask(
        (args.size, args.size), args.accel, seed=1
    )
    kspace = np.asarray(mri.sense_forward(phantom, truth_maps, mask))
    smaps = np.asarray(mri.estimate_sensitivities(kspace, calib=16, mask=mask))
    print(f"acquired {args.coils}-coil k-space at "
          f"R={mri.acceleration(mask):.2f} "
          f"({args.size}x{args.size}, maps estimated from calibration)")

    # 2. warm-started serving: MEASURE-grade plans, zero tuning cost
    cache = PlanCache()
    report = wisdom.warm_start(cache=cache)
    svc = ImagingService(
        plan_mode="measure" if report.kept else None, cache=cache
    )

    # 3. the recon lane: N requests -> one batched CG-SENSE solve
    reqs = [
        ReconRequest(kspace=kspace, smaps=smaps, mask=mask,
                     iters=args.iters, lam=1e-3)
        for _ in range(args.requests)
    ]
    svc.serve(reqs)

    zf = mri.nrmse(mri.recon_zero_filled(kspace, smaps, mask), phantom)
    cg = mri.nrmse(reqs[0].image, phantom)
    print(f"zero-filled NRMSE = {zf:.4f}")
    print(f"CG-SENSE    NRMSE = {cg:.4f}  "
          f"({args.iters} iterations, batch of {args.requests})")
    assert cg < zf, "CG-SENSE must beat the zero-filled baseline"

    # 4. what the planner and the recon lane actually did
    print()
    print(xfft.report())


if __name__ == "__main__":
    main()
