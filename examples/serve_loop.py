"""End-to-end driver: the continuous-batching serve loop as a fleet server.

The production startup sequence for a serving process, in ~60 lines:

1. **warm start** — load the packaged wisdom artifact
   (``repro.serve.wisdom``) into a fresh plan cache, so a MEASURE-grade
   plan serves every covered shape with zero tuning cost;
2. **start the loop** — one background scheduler thread
   (``svc.loop.start()``) coalesces streaming submits into per-lane
   batches under a max-batch / max-wait policy, with ``Overloaded``
   backpressure past the queue limit;
3. **stream requests** — mixed real/complex frames from independent
   "clients" ride the same loop; each submitter holds a Ticket and
   blocks only on its own result;
4. **introspect** — ``xfft.report()`` shows the wisdom entries that
   served the traffic (and would show per-service quarantine rows if an
   engine had been benched mid-stream).

  PYTHONPATH=src python examples/serve_loop.py --requests 48 --hw 64
"""

import argparse
import time

import numpy as np

import repro.xfft as xfft
from repro import obs
from repro.plan import PlanCache
from repro.resilience import ServicePolicy
from repro.serve import BatchPolicy, SpectrumRequest, SpectrumService, wisdom


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--hw", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    args = ap.parse_args()

    # 1. warm start: the fleet never pays MEASURE cost per process
    cache = PlanCache()
    report = wisdom.warm_start(cache=cache)
    print(f"wisdom: kept={report.kept} dropped={report.dropped} "
          f"({report.file_error or 'packaged artifact'})")

    # 2. the service + its long-lived scheduler
    svc = SpectrumService(
        plan_mode="measure" if report.kept else None,
        cache=cache,
        policy=ServicePolicy(max_queue=4 * args.requests),
        batch=BatchPolicy(max_batch=args.max_batch,
                          max_wait_s=args.max_wait_ms / 1e3),
    )
    svc.loop.start()

    # 3. streaming clients: interleaved real/complex frames -> two lanes
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    tickets = []
    for i in range(args.requests):
        if i % 2 == 0:
            frame = rng.standard_normal((args.hw, args.hw)).astype(np.float32)
        else:
            frame = (rng.standard_normal((args.hw, args.hw))
                     + 1j * rng.standard_normal((args.hw, args.hw))
                     ).astype(np.complex64)
        tickets.append(svc.loop.submit(SpectrumRequest(frame=frame)))
    for t in tickets:
        t.result(timeout=60.0)  # blocks until this ticket's batch ran
    dt = time.perf_counter() - t0
    svc.loop.stop()

    ref = np.fft.rfft2(np.asarray(tickets[0].request.frame))
    np.testing.assert_allclose(tickets[0].request.spectrum, ref,
                               rtol=1e-4, atol=1e-4)
    print(f"served {args.requests} requests in {dt * 1e3:.1f} ms "
          f"({args.requests / dt:.0f} req/s), "
          f"lanes={len(svc.plans)}, ticks={obs.counters().get('serve.loop.tick')}")

    # 4. what the planner learned (FFTW export_wisdom-style)
    print(xfft.report(cache))


if __name__ == "__main__":
    main()
