"""Train the paper's own architecture: fourier_lm — an FNet-style masked LM
whose token-mixing layer IS the area-efficient 2D FFT engine.

Defaults train a small model for a quick CPU run; --full trains the ~100M
configuration for a few hundred steps (the assignment's end-to-end driver;
expect hours on this 1-core container — the small run demonstrates the
identical code path).

  PYTHONPATH=src python examples/train_spectral_lm.py --steps 120
  PYTHONPATH=src python examples/train_spectral_lm.py --full --steps 300
"""

import argparse
import time

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.data.pipeline import make_batch
from repro.models.build import build
from repro.train.loop import TrainLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="~100M-param config (12L x 512 x 32768 vocab)")
    ap.add_argument("--ckpt", default="/tmp/fourier_lm_ckpt")
    ap.add_argument("--peak-lr", type=float, default=3e-3)
    args = ap.parse_args()

    cfg = get_config("fourier_lm")
    if not args.full:
        cfg = cfg.scaled(n_layers=4, d_model=128, d_ff=512, vocab=2048,
                         remat=False, compute_dtype="float32")
    model = build(cfg)
    print(f"[spectral-lm] params={model.n_params/1e6:.1f}M "
          f"(mixing = Re(FFT2), variant={cfg.fft_variant})")

    loop = TrainLoop(
        model,
        ckpt_dir=args.ckpt,
        batch_fn=lambda s: make_batch(cfg, args.batch, args.seq, s),
        save_every=max(args.steps // 4, 10),
        peak_lr=args.peak_lr,
    )
    t0 = time.time()
    losses = loop.run(jax.random.PRNGKey(0), args.steps)
    dt = time.time() - t0
    steps = sorted(losses)
    k = max(len(steps) // 10, 1)
    first = float(np.mean([losses[s] for s in steps[:k]]))
    last = float(np.mean([losses[s] for s in steps[-k:]]))
    print(f"[spectral-lm] {len(steps)} steps in {dt:.1f}s; "
          f"masked-LM loss {first:.3f} -> {last:.3f}")
    if last >= first:
        raise SystemExit("loss did not decrease")
    print("[spectral-lm] OK — the paper's engine trains as an LM mixing layer")


if __name__ == "__main__":
    main()
