"""Quickstart: the paper's area-efficient FFT engine in five minutes.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import butterfly_counts, fft, fft2, fft2_stream, ifft2
from repro.kernels import fft2_kernel, fft_kernel, hbm_traffic_model


def main():
    rng = np.random.default_rng(0)

    # 1. The paper's looped 1D engine (N/2 butterflies reused log2 N times)
    x = rng.standard_normal((4, 1024)).astype(np.float32)
    y = fft(jnp.asarray(x), variant="looped")
    ref = np.fft.fft(x)
    print("1D looped engine max err:", float(np.max(np.abs(np.asarray(y) - ref))))
    c_prop, c_trad = butterfly_counts(1024, True), butterfly_counts(1024, False)
    print(f"   butterflies: {c_prop['butterfly_units']} (proposed) vs "
          f"{c_trad['butterfly_units']} (traditional) — paper Table 2")

    # 2. 2D FFT = two 1D passes (paper fig. 1) + inverse roundtrip
    img = rng.standard_normal((64, 64)).astype(np.float32)
    F = fft2(jnp.asarray(img))
    rt = np.asarray(ifft2(F)).real
    print("2D roundtrip err:", float(np.max(np.abs(rt - img))))

    # 3. Streaming frames through the ping-pong pipeline (paper fig. 3)
    frames = rng.standard_normal((6, 32, 32)).astype(np.float32)
    outs = fft2_stream(jnp.asarray(frames))
    print("stream matches per-frame:",
          bool(np.allclose(np.asarray(outs), np.fft.fft2(frames), atol=1e-3)))

    # 4. The TPU kernels (interpret mode on CPU): one HBM round trip
    yk = fft_kernel(jnp.asarray(x))
    print("fused kernel max err:", float(np.max(np.abs(np.asarray(yk) - ref))))
    print(f"   HBM traffic fused/staged = "
          f"{hbm_traffic_model(4, 1024, True) / hbm_traffic_model(4, 1024, False):.3f}"
          f" (paper alpha = {1/np.log2(1024):.3f})")
    Fk = fft2_kernel(jnp.asarray(img))
    print("fused 2D kernel max err:",
          float(np.max(np.abs(np.asarray(Fk) - np.fft.fft2(img)))))


if __name__ == "__main__":
    main()
