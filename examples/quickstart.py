"""Quickstart: the paper's area-efficient FFT engine in five minutes.

  PYTHONPATH=src python examples/quickstart.py

All transforms go through ``repro.xfft`` — the scipy.fft-style front door
whose dispatch is plan-backed (``repro.plan`` picks the engine schedule).
Pinning a specific engine is a *scope*, not a kwarg.
"""

import jax.numpy as jnp
import numpy as np

import repro.xfft as xfft
from repro.core import butterfly_counts
from repro.core.fft2d import fft2_stream
from repro.kernels import fft2_kernel, fft_kernel, hbm_traffic_model


def main():
    rng = np.random.default_rng(0)

    # 1. The paper's looped 1D engine (N/2 butterflies reused log2 N times),
    #    pinned via a config scope — the planner would pick a faster one.
    x = rng.standard_normal((4, 1024)).astype(np.float32)
    with xfft.config(variant="looped"):
        y = xfft.fft(jnp.asarray(x))
    ref = np.fft.fft(x)
    print("1D looped engine max err:", float(np.max(np.abs(np.asarray(y) - ref))))
    c_prop, c_trad = butterfly_counts(1024, True), butterfly_counts(1024, False)
    print(f"   butterflies: {c_prop['butterfly_units']} (proposed) vs "
          f"{c_trad['butterfly_units']} (traditional) — paper Table 2")

    # 2. 2D FFT = two 1D passes (paper fig. 1) + inverse roundtrip — no
    #    kwargs: repro.plan resolves the schedule per problem.
    img = rng.standard_normal((64, 64)).astype(np.float32)
    F = xfft.fft2(jnp.asarray(img))
    rt = np.asarray(xfft.ifft2(F)).real
    print("2D roundtrip err:", float(np.max(np.abs(rt - img))))

    # 2b. Real input gets the two-for-one path; norms are scipy-compatible.
    half = xfft.rfft2(jnp.asarray(img), norm="ortho")
    print("rfft2 ortho matches numpy:",
          bool(np.allclose(np.asarray(half), np.fft.rfft2(img, norm="ortho"),
                           atol=1e-3)))

    # 3. Streaming frames through the ping-pong pipeline (paper fig. 3)
    frames = rng.standard_normal((6, 32, 32)).astype(np.float32)
    outs = fft2_stream(jnp.asarray(frames))
    print("stream matches per-frame:",
          bool(np.allclose(np.asarray(outs), np.fft.fft2(frames), atol=1e-3)))

    # 4. The TPU kernels (interpret mode on CPU): one HBM round trip
    yk = fft_kernel(jnp.asarray(x))
    print("fused kernel max err:", float(np.max(np.abs(np.asarray(yk) - ref))))
    print(f"   HBM traffic fused/staged = "
          f"{hbm_traffic_model(4, 1024, True) / hbm_traffic_model(4, 1024, False):.3f}"
          f" (paper alpha = {1/np.log2(1024):.3f})")
    Fk = fft2_kernel(jnp.asarray(img))
    print("fused 2D kernel max err:",
          float(np.max(np.abs(np.asarray(Fk) - np.fft.fft2(img)))))

    # 5. The same kernels through the front door: force them by scope.
    with xfft.config(variant="fused_r4"):
        Fk2 = xfft.fft2(jnp.asarray(img))
    print("fused_r4 via config scope max err:",
          float(np.max(np.abs(np.asarray(Fk2) - np.fft.fft2(img)))))


if __name__ == "__main__":
    main()
