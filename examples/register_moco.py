"""Motion correction on k-space frames — the moco-workshop workflow on
the paper's planned 2D engine.

An MRI-style acquisition: the scanner records k-space (the centred 2D
spectrum) of the same anatomy over several frames, but the subject moves
between frames. The correction loop is exactly the operator set of
``repro.imaging``:

  1. ``kspace_to_image`` — centred inverse transform per frame;
  2. ``register_phase_correlation`` — subpixel shift of every frame
     against the reference, one batched planned transform pair;
  3. ``apply_shift`` — Fourier-domain correction of each frame;
  4. re-average: the corrected mean is sharp where the naive mean is
     smeared by motion.

  PYTHONPATH=src python examples/register_moco.py
"""

import numpy as np

from repro.imaging import (
    apply_shift,
    image_to_kspace,
    kspace_to_image,
    register_phase_correlation,
)


def make_phantom(n: int = 128) -> np.ndarray:
    """A Shepp-Logan-ish blob phantom (numpy-only, deterministic)."""
    y, x = np.mgrid[0:n, 0:n].astype(np.float32) / n - 0.5
    img = np.zeros((n, n), np.float32)
    for cy, cx, ry, rx, a in [
        (0.0, 0.0, 0.40, 0.30, 1.0),
        (-0.1, 0.05, 0.15, 0.10, -0.4),
        (0.15, -0.08, 0.08, 0.12, 0.6),
        (0.2, 0.15, 0.05, 0.05, 0.8),
    ]:
        img += a * (((y - cy) / ry) ** 2 + ((x - cx) / rx) ** 2 < 1.0)
    return img


def main():
    rng = np.random.default_rng(0)
    n, frames = 128, 6
    phantom = make_phantom(n)

    # Acquire: each frame is the phantom under a random inter-frame shift,
    # recorded in k-space with a little noise.
    true_shifts = np.round(rng.uniform(-6, 6, size=(frames, 2)) * 4) / 4
    true_shifts[0] = 0.0
    moved = np.stack(
        [np.asarray(apply_shift(phantom, s)) for s in true_shifts]
    )
    kspace = np.asarray(image_to_kspace(moved))
    kspace = kspace + 0.01 * (
        rng.standard_normal(kspace.shape) + 1j * rng.standard_normal(kspace.shape)
    ).astype(np.complex64)

    # Reconstruct and register every frame against frame 0 (one batched
    # call: the planner tunes ONE fft2d problem for the whole series).
    recon = np.asarray(kspace_to_image(kspace))
    magnitude = np.abs(recon).astype(np.float32)
    refs = np.broadcast_to(magnitude[0], magnitude.shape)
    shifts = np.asarray(
        register_phase_correlation(refs, magnitude, upsample_factor=8)
    )

    # Correct in the Fourier domain and re-average.
    corrected = np.asarray(apply_shift(magnitude, shifts))
    naive_err = np.abs(magnitude.mean(0) - phantom).mean()
    moco_err = np.abs(corrected.mean(0) - phantom).mean()

    print("frame   true shift        recovered (-shift)")
    for f in range(frames):
        print(
            f"  {f}   ({true_shifts[f][0]:+6.2f}, {true_shifts[f][1]:+6.2f})"
            f"   ({-shifts[f][0]:+6.2f}, {-shifts[f][1]:+6.2f})"
        )
    worst = np.abs(shifts + true_shifts).max()
    print(f"worst shift error : {worst:.3f} px (subpixel grid 1/8 px)")
    print(f"naive average err : {naive_err:.4f}")
    print(f"moco  average err : {moco_err:.4f}")
    assert worst <= 0.25, "registration drifted off the acquisition shifts"
    assert moco_err < 0.5 * naive_err, "motion correction did not help"
    print("OK: motion-corrected average is sharp; registration matched truth")


if __name__ == "__main__":
    main()
