"""End-to-end driver: a streaming 2D-FFT *service* — the paper's processor
as a deployable system. Batched frame requests flow through the ping-pong
pipeline continuously (RAM1/RAM2 never idle), with checkpointed stream
offsets so a killed worker resumes mid-stream.

  PYTHONPATH=src python examples/serve_fft2d.py --frames 64 --hw 128
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.xfft as xfft
from repro.core.fft2d import fft2_stream
from repro.plan import default_cache, plan_fft


def frame_source(step: int, batch: int, hw: int, seed: int = 0) -> np.ndarray:
    """Deterministic synthetic camera: frame t is a drifting 2-D chirp."""
    rng = np.random.default_rng(seed ^ step)
    yy, xx = np.mgrid[0:hw, 0:hw].astype(np.float32) / hw
    base = np.sin(2 * np.pi * (3 + step % 5) * xx) * np.cos(2 * np.pi * 2 * yy)
    noise = rng.standard_normal((batch, hw, hw)).astype(np.float32) * 0.1
    return base[None] + noise


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=64, help="total frames to serve")
    ap.add_argument("--batch", type=int, default=8, help="frames per request")
    ap.add_argument("--hw", type=int, default=128)
    ap.add_argument("--state", default="/tmp/fft2d_service_state.json")
    ap.add_argument("--reset", action="store_true")
    ap.add_argument(
        "--plan-mode",
        choices=["estimate", "measure"],
        default="measure",
        help="autotune mode used to warm the plan cache at startup",
    )
    args = ap.parse_args()

    # Warm the plan cache before serving: tune once for the request shape so
    # every variant="auto" resolution below is a cache hit, never a re-tune.
    t_plan = time.time()
    plan = plan_fft(
        "fft2d_stream", (args.batch, args.hw, args.hw), mode=args.plan_mode
    )
    print(
        f"[service] plan ({plan.mode}, {time.time() - t_plan:.2f}s): "
        f"variant={plan.variant} unroll={plan.unroll} "
        f"cache={default_cache().path or 'memory'}"
    )

    # resume support: the service remembers which frame it served last
    start = 0
    if not args.reset and os.path.exists(args.state):
        with open(args.state) as f:
            start = json.load(f)["next_frame"]
        print(f"[service] resuming at frame {start}")

    pipeline = jax.jit(lambda f: fft2_stream(f, variant="auto", unroll="auto"))
    served = 0
    t0 = time.time()
    checks = []
    for step in range(start, args.frames, args.batch):
        frames = frame_source(step, args.batch, args.hw)
        spectra = np.asarray(pipeline(jnp.asarray(frames)))
        # response: dominant spatial frequency per frame (the "detection")
        mags = np.abs(spectra.reshape(args.batch, -1))
        mags[:, 0] = 0  # ignore DC
        peaks = mags.argmax(axis=1)
        checks.append(int(peaks[0]))
        served += args.batch
        # checkpoint the stream offset (atomic rename)
        tmp = args.state + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"next_frame": step + args.batch}, f)
        os.replace(tmp, args.state)
    dt = time.time() - t0
    print(f"[service] served {served} frames of {args.hw}x{args.hw} in {dt:.2f}s "
          f"({served/max(dt,1e-9):.1f} frames/s)")
    print(f"[service] sample peak bins: {checks[:6]}")
    # verify one batch against numpy and against the xfft front door
    # (whose bare call resolves through the same warmed plan cache)
    frames = frame_source(start, args.batch, args.hw)
    ref = np.fft.fft2(frames)
    got = np.asarray(pipeline(jnp.asarray(frames)))
    err = np.max(np.abs(got - ref)) / np.max(np.abs(ref))
    print(f"[service] spectrum rel. error vs numpy: {err:.2e}")
    direct = np.asarray(xfft.fft2(jnp.asarray(frames)))
    agree = np.max(np.abs(got - direct)) / np.max(np.abs(ref))
    print(f"[service] stream vs xfft.fft2 rel. diff: {agree:.2e}")


if __name__ == "__main__":
    main()
