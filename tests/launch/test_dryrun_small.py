"""CI-scale dry-run: reduced configs on an 8-device test mesh (subprocess so
the main process keeps 1 device). Exercises the same build_cell path as the
production dry-run: lower + compile + memory/cost analysis + roofline."""

import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs.registry import smoke_config, SHAPES
from repro.launch.hlo_cost import loop_aware_cost
from repro.launch.mesh import make_test_mesh
from repro.models.build import build
from repro.optim import adamw_init
from repro.sharding import batch_specs, cache_specs, param_rules
from repro.sharding.ctx import activation_sharding
from repro.train.loop import TrainState, make_train_step

ARCHS = ["llama3.2-3b", "glm4-9b", "mixtral-8x22b", "zamba2-2.7b", "xlstm-350m",
         "whisper-medium", "deepseek-v3-671b", "internvl2-76b"]

mesh = make_test_mesh()  # (4, 2) data x model
ok = []
for arch in ARCHS:
    cfg = smoke_config(arch)
    model = build(cfg)
    rules = param_rules(cfg, multi_pod=False, model_size=2)
    pspecs = model.specs(rules)
    params_sds = model.abstract(jnp.float32)
    opt_sds = jax.eval_shape(adamw_init, params_sds)
    state_sds = TrainState(params_sds, opt_sds, None)
    state_specs = TrainState(pspecs, {"mu": pspecs, "nu": pspecs, "step": P()}, None)
    b, s = 8, 32
    batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    bspec = {"tokens": P(("data",), None)}
    if cfg.family == "audio":
        batch["frames"] = jax.ShapeDtypeStruct((b, cfg.enc_frames, cfg.d_model), jnp.float32)
        bspec["frames"] = P(("data",), None, None)
    if cfg.family == "vlm":
        batch["patches"] = jax.ShapeDtypeStruct((b, cfg.n_patches, cfg.d_model), jnp.float32)
        bspec["patches"] = P(("data",), None, None)
    step = make_train_step(model.loss_fn)
    named = lambda t: jax.tree.map(lambda sp: NamedSharding(mesh, sp), t,
                                   is_leaf=lambda x: isinstance(x, P))
    with compat.set_mesh(mesh), activation_sharding(
        dp=("data",), dp_sizes=(4,), tp="model", tp_size=2
    ):
        compiled = jax.jit(
            step, in_shardings=(named(state_specs), named(bspec))
        ).lower(state_sds, batch).compile()
    mem = compiled.memory_analysis()
    lac = loop_aware_cost(compiled.as_text())
    assert lac["flops"] > 0, arch
    assert mem.argument_size_in_bytes > 0, arch
    ok.append(arch)
print("DRYRUN_SMALL_OK", len(ok))
"""


@pytest.mark.slow
def test_dryrun_reduced_configs_compile_on_test_mesh():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True,
        cwd=os.path.join(os.path.dirname(__file__), "..", ".."),
        env=env, timeout=1800,
    )
    assert out.returncode == 0, out.stderr[-5000:]
    assert "DRYRUN_SMALL_OK 8" in out.stdout
