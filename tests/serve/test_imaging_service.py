"""ImagingService: mixed spectrum/registration/convolution queues served
with one plan per problem-key group."""

import numpy as np
import pytest

from repro.imaging import apply_shift
from repro.imaging.synthetic import band_limited_frame as _smooth
from repro.serve import (
    ConvolutionRequest,
    ImagingService,
    RegistrationRequest,
    SpectrumRequest,
)


def test_mixed_queue_all_served(rng):
    ref = _smooth(32, 1)
    reqs = [
        RegistrationRequest(ref=ref, mov=np.asarray(apply_shift(ref, (3.0, -2.0)))),
        RegistrationRequest(ref=ref, mov=np.asarray(apply_shift(ref, (-5.0, 7.0)))),
        ConvolutionRequest(
            image=rng.standard_normal((40, 40)).astype(np.float32),
            kernel=rng.standard_normal((5, 5)).astype(np.float32),
        ),
        SpectrumRequest(frame=rng.standard_normal((16, 16)).astype(np.float32)),
    ]
    out = ImagingService().serve(reqs)
    assert out is reqs and all(r.done for r in reqs)
    np.testing.assert_array_equal(reqs[0].shift, [-3.0, 2.0])
    np.testing.assert_array_equal(reqs[1].shift, [5.0, -7.0])
    conv = reqs[2]
    fh = np.fft.irfft2(
        np.fft.rfft2(conv.image, s=(44, 44)) * np.fft.rfft2(conv.kernel, s=(44, 44)),
        s=(44, 44),
    )
    np.testing.assert_allclose(conv.out, fh[2:42, 2:42], atol=1e-3)
    np.testing.assert_allclose(
        reqs[3].spectrum, np.fft.rfft2(reqs[3].frame), atol=1e-3
    )


def test_one_plan_per_group(rng):
    svc = ImagingService()
    ref = _smooth(16, 2)

    def queue():
        return [
            RegistrationRequest(ref=ref, mov=ref) for _ in range(4)
        ] + [
            ConvolutionRequest(
                image=rng.standard_normal((24, 24)).astype(np.float32),
                kernel=rng.standard_normal((3, 3)).astype(np.float32),
            )
            for _ in range(3)
        ]

    svc.serve(queue())
    # one rfft2d plan for the batched registration problem ((4, 16, 16) —
    # xfft keys on the full shape) + one oaconv2d plan for the conv
    # geometry (batch-independent: the tile depends on frame + kernel)
    assert len(svc.plans) == 2
    svc.serve(queue())
    assert len(svc.plans) == 2  # repeat groups re-decide nothing
    assert sorted(p.key.kind for p in svc.plans.values()) == [
        "oaconv2d", "rfft2d",
    ]
    reg_plan = next(p for p in svc.plans.values() if p.key.kind == "rfft2d")
    assert reg_plan.key.shape == (4, 16, 16)  # the batched problem


def test_convolution_group_uses_planned_tile(rng):
    svc = ImagingService()
    req = ConvolutionRequest(
        image=rng.standard_normal((64, 64)).astype(np.float32),
        kernel=rng.standard_normal((9, 9)).astype(np.float32),
        mode="full",
    )
    svc.serve([req])
    (plan,) = svc.plans.values()
    assert plan.key.kind == "oaconv2d" and plan.tile is not None
    fh = np.fft.irfft2(
        np.fft.rfft2(req.image, s=(72, 72)) * np.fft.rfft2(req.kernel, s=(72, 72)),
        s=(72, 72),
    )
    np.testing.assert_allclose(req.out, fh, atol=2e-3)


def test_upsample_groups_separately(rng):
    svc = ImagingService()
    ref = _smooth(32, 3)
    mov = np.asarray(apply_shift(ref, (1.5, -0.5)))
    coarse = RegistrationRequest(ref=ref, mov=mov)
    fine = RegistrationRequest(ref=ref, mov=mov, upsample=8)
    svc.serve([coarse, fine])
    np.testing.assert_allclose(fine.shift, [-1.5, 0.5], atol=0.13)
    assert np.abs(np.asarray(coarse.shift) - np.asarray(fine.shift)).max() <= 0.5


def test_unknown_request_type_rejected():
    with pytest.raises(TypeError, match="expected"):
        ImagingService().serve([object()])


def test_bad_frames_rejected():
    with pytest.raises(ValueError, match="matching"):
        ImagingService().serve(
            [RegistrationRequest(ref=np.zeros((8, 8)), mov=np.zeros((8, 4)))]
        )
    with pytest.raises(ValueError, match="2D"):
        ImagingService().serve(
            [ConvolutionRequest(image=np.zeros((2, 8, 8)), kernel=np.zeros((3, 3)))]
        )


def test_invalid_request_fails_before_any_work(rng):
    """Validation is all-or-nothing: a bad request anywhere in the queue
    means nothing in the queue is served."""
    good = SpectrumRequest(frame=rng.standard_normal((8, 8)).astype(np.float32))
    bad = RegistrationRequest(ref=np.zeros((2, 8, 8)), mov=np.zeros((2, 8, 8)))
    with pytest.raises(ValueError, match="matching"):
        ImagingService().serve([good, bad])
    assert not good.done and good.spectrum is None

    reg = RegistrationRequest(ref=np.zeros((8, 8)), mov=np.zeros((8, 8)))
    bad_mode = ConvolutionRequest(
        image=np.zeros((8, 8)), kernel=np.zeros((3, 3)), mode="reflect"
    )
    with pytest.raises(ValueError, match="mode"):
        ImagingService().serve([reg, bad_mode])
    assert not reg.done and reg.shift is None

    too_big = ConvolutionRequest(
        image=np.zeros((4, 4)), kernel=np.zeros((8, 8)), mode="valid"
    )
    with pytest.raises(ValueError, match="kernel <= image"):
        ImagingService().serve([too_big])
