"""Serve-suite fixtures: clean breaker + lane registry per test.

The quarantine registry and the serve-loop lane->key registry are both
process-wide (by design: ``xfft.report()`` groups the quarantine table
by service through them), so a test that opens a breaker or records
lanes must not leak into the next test.
"""

import time

import pytest

from repro.resilience import configure, reset
from repro.serve.loop import reset_lane_keys


@pytest.fixture(autouse=True)
def _clean_serve_state():
    reset()
    configure(threshold=1, cooldown_s=30.0, clock=time.monotonic)
    reset_lane_keys()
    yield
    reset()
    configure(threshold=1, cooldown_s=30.0, clock=time.monotonic)
    reset_lane_keys()


@pytest.fixture
def fake_clock():
    """A settable clock: ``clock.now += 31.0`` drives a cooldown."""

    class _Clock:
        now = 0.0

        def __call__(self):
            return self.now

    return _Clock()
