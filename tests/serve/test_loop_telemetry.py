"""Obs telemetry under the serve loop: thread isolation, bounded ring,
per-lane latency histograms on the tick stream.

The flight recorder is process-wide by design — a serving fleet member's
black box must see the background scheduler thread's events. The capture
stack is contextvars-scoped by design — a caller's ``capture()`` window
must NOT see them. These tests pin both properties at once, plus the
ring staying bounded under sustained emission and the lane histograms
feeding ``serve.loop.tick`` gauges.
"""

import threading

import pytest

from repro import obs
from repro.obs import telemetry
from repro.obs.hist import histogram, reset_histograms
from repro.obs.telemetry import FlightRecorder
from repro.serve import BatchPolicy, LaneKey, ServeLoop


@pytest.fixture
def recorder(tmp_path):
    rec = FlightRecorder(capacity=128, dump_dir=str(tmp_path / "flight"))
    prev = telemetry.set_flight_recorder(rec)
    yield rec
    telemetry.set_flight_recorder(prev)


@pytest.fixture(autouse=True)
def _clean_hists():
    reset_histograms()
    yield
    reset_histograms()


def _toy_loop(**kw):
    def classify(r):
        return LaneKey(r["lane"], ())

    def execute(lane, members):
        for m in members:
            m["served"] = True

    return ServeLoop(classify, execute, service="toy", **kw)


def test_background_thread_events_reach_recorder_not_caller_capture(recorder):
    loop = _toy_loop(batch=BatchPolicy(max_batch=4, max_wait_s=0.0))
    loop.start()
    try:
        with obs.capture() as trace:
            tickets = [loop.submit({"lane": "a", "i": i, "served": False})
                       for i in range(8)]
            for t in tickets:
                assert t.result(timeout=5.0)["served"]
    finally:
        loop.stop()
    # the caller's window saw its own submits...
    assert len(trace.select("serve.loop.enqueue")) == 8
    # ...but NOT the scheduler thread's dispatch events
    assert trace.select("serve.loop.tick") == []
    # which the process-wide flight recorder did record,
    ring = recorder.events()
    ticks = [e for e in ring if e.name == "serve.loop.tick"]
    assert ticks and all(e["service"] == "toy" for e in ticks)
    # on a distinct thread lane (Chrome-trace tracks come from this map)
    loop_tids = {e.tid for e in ticks}
    caller_tid = threading.get_ident()
    assert caller_tid not in loop_tids
    names = recorder.thread_names()
    assert any("serve-loop[toy]" in names[tid] for tid in loop_tids)


def test_ring_stays_bounded_under_sustained_loop_emission(recorder):
    loop = _toy_loop(batch=BatchPolicy(max_batch=2, max_wait_s=0.0))
    loop.start()
    try:
        for wave in range(20):
            tickets = [loop.submit({"lane": "a", "i": i, "served": False})
                       for i in range(16)]
            for t in tickets:
                t.result(timeout=5.0)
            assert len(recorder.events()) <= recorder.capacity
    finally:
        loop.stop()
    stats = recorder.stats()
    assert stats["retained"] <= stats["capacity"] == 128
    # sustained traffic really flowed through the bounded ring
    assert stats["recorded_total"] > 128


def test_lane_error_in_background_thread_dumps_flight_snapshot(recorder):
    def classify(r):
        return LaneKey(r["lane"], ())

    def execute(lane, members):
        raise RuntimeError("executor exploded")

    loop = ServeLoop(classify, execute, service="toy",
                     batch=BatchPolicy(max_batch=1, max_wait_s=0.0))
    loop.start()
    try:
        ticket = loop.submit({"lane": "a"})
        with pytest.raises(RuntimeError):
            ticket.result(timeout=5.0)
    finally:
        loop.stop()
    dumps = recorder.stats()["dumps"]
    assert any(d["trigger"] == "serve.lane.error" for d in dumps)


def test_tick_latency_lands_in_lane_histogram():
    class Clock:
        now = 0.0

        def __call__(self):
            return self.now

    clock = Clock()
    loop = _toy_loop(batch=BatchPolicy(max_batch=8), clock=clock)
    for i in range(4):
        loop.submit({"lane": "a", "i": i, "served": False})
    clock.now = 0.002  # tickets waited 2 ms admission -> completion
    assert loop.tick(drain=True) == 4
    h = histogram("serve.lane.toy.a[]")
    assert h.count == 4
    assert h.bucket_index(h.percentile(50)) == h.bucket_index(2000.0)


def test_tick_event_carries_lane_tail_gauges():
    loop = _toy_loop(batch=BatchPolicy(max_batch=4))
    with obs.capture() as trace:
        for i in range(4):
            loop.submit({"lane": "a", "i": i, "served": False})
        loop.tick(drain=True)
        for i in range(4):
            loop.submit({"lane": "a", "i": i, "served": False})
        loop.tick(drain=True)
    first, second = trace.select("serve.loop.tick")
    # gauges reflect the histogram as of the PREVIOUS batches
    assert first["lane_n"] == 0 and first["lane_p99_us"] is None
    assert second["lane_n"] == 4
    assert second["lane_p50_us"] > 0 and second["lane_p99_us"] > 0


def test_failed_batches_stay_out_of_latency_histograms():
    def classify(r):
        return LaneKey(r["lane"], ())

    calls = {"n": 0}

    def execute(lane, members):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("first batch fails")

    loop = ServeLoop(classify, execute, service="toy",
                     batch=BatchPolicy(max_batch=1))
    t1 = loop.submit({"lane": "a"})
    loop.tick(drain=True)
    with pytest.raises(RuntimeError):
        t1.result(timeout=1.0)
    t2 = loop.submit({"lane": "a"})
    loop.tick(drain=True)
    t2.result(timeout=1.0)
    assert histogram("serve.lane.toy.a[]").count == 1


def test_histograms_merge_across_lanes_for_service_view():
    loop = _toy_loop(batch=BatchPolicy(max_batch=4))
    for lane in ("a", "b"):
        for i in range(3):
            loop.submit({"lane": lane, "i": i, "served": False})
    loop.drain()
    merged = obs.LatencyHistogram()
    for name, h in obs.histograms(prefix="serve.lane.toy.").items():
        merged.merge(h)
    assert merged.count == 6
