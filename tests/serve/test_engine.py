"""Serving engine: generation consistency + continuous batching."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import smoke_config
from repro.models.build import build
from repro.serve.engine import Request, ServeEngine


@pytest.fixture(scope="module")
def engine():
    cfg = smoke_config("llama3.2-3b")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params, ServeEngine(model, params, batch=2, max_len=64)


def test_greedy_generation_shapes(engine):
    cfg, model, params, eng = engine
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, (8,)).astype(np.int32) for _ in range(2)]
    outs = eng.generate(prompts, max_new=6)
    assert len(outs) == 2 and all(len(o) == 6 for o in outs)
    assert all(0 <= t < cfg.vocab for o in outs for t in o)


def test_generation_matches_step_by_step_forward(engine):
    """Engine output == logits argmax of repeated full forwards (no cache)."""
    cfg, model, params, eng = engine
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab, (8,)).astype(np.int32)
    outs = eng.generate([prompt, prompt], max_new=4)

    seq = list(prompt)
    ref = []
    for _ in range(4):
        caches = model.init_cache_fn(1, 64, jnp.float32)
        logits, _ = model.prefill_fn(
            params, {"tokens": jnp.asarray([seq], jnp.int32)}, caches
        )
        t = int(jnp.argmax(logits[0]))
        ref.append(t)
        seq.append(t)
    assert outs[0] == ref, (outs[0], ref)


def test_continuous_batching_queue(engine):
    cfg, model, params, eng = engine
    rng = np.random.default_rng(2)
    queue = [
        Request(prompt=rng.integers(0, cfg.vocab, (6,)).astype(np.int32), max_new=3)
        for _ in range(5)  # 5 requests through 2 slots
    ]
    done = eng.serve_queue(list(queue))
    assert len(done) == 5
    assert all(r.done and len(r.out) == 3 for r in done)


def test_prompt_length_buckets_group_into_lanes(engine):
    """The LM path routes through the shared lane machinery: mixed prompt
    lengths split into pow2 buckets, so a short prompt is never padded to
    an unrelated long one in its batch (the pre-loop slot manager padded
    every batch to the longest live prompt)."""
    from repro import obs

    cfg, model, params, eng = engine
    rng = np.random.default_rng(3)
    short = [
        Request(prompt=rng.integers(0, cfg.vocab, (4,)).astype(np.int32), max_new=2)
        for _ in range(2)
    ]
    long = [
        Request(prompt=rng.integers(0, cfg.vocab, (30,)).astype(np.int32), max_new=2)
        for _ in range(2)
    ]
    with obs.capture() as trace:
        done = eng.serve_queue(short + long)
    assert len(done) == 4 and all(r.done for r in done)
    q = trace.first("serve.queue")
    assert q["service"] == "lm" and q["lanes"] == 2
    batches = sorted(e["prompt_len"] for e in trace.select("serve.batch"))
    assert batches == [4, 30]  # short batch padded to 4, not to 30


def test_oversized_prompt_rejected(engine):
    cfg, model, params, eng = engine
    too_long = Request(prompt=np.zeros((65,), np.int32))  # max_len is 64
    import pytest as _pytest

    with _pytest.raises(ValueError, match="request 0: prompt length"):
        eng.serve_queue([too_long])
