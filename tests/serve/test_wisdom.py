"""Wisdom artifacts: export/warm_start roundtrip + the packaged file.

Roundtrip tests hand-craft MEASURE plans (no sweeps — fast); the
packaged-artifact test doubles as a schema-staleness guard: if
``PLAN_SCHEMA_VERSION`` marches past the checked-in ``cpu.json``, its
``kept`` count drops to zero and this suite says so before a fleet
silently re-tunes.
"""

import os

import jax
import numpy as np
import pytest

from repro import obs
from repro.plan import PlanCache, plan_fft
from repro.plan.plan import FFTPlan, problem_key
from repro.serve import SpectrumRequest, SpectrumService, wisdom


def _measured_plan(shape=(8, 8), kind="rfft2d", dtype="float32"):
    key = problem_key(kind, shape, dtype)
    return FFTPlan(key=key, variant="stockham", mode="measure", measured_us=12.5)


def _estimate_plan(shape=(16, 16), kind="fft2d", dtype="complex64"):
    key = problem_key(kind, shape, dtype)
    return FFTPlan(key=key, variant="stockham", mode="estimate", est_time_s=1e-5)


def test_export_warm_start_roundtrip(tmp_path):
    src = PlanCache()
    src.put(_measured_plan())
    path = wisdom.export(str(tmp_path / "w.json"), src)
    assert os.path.exists(path)

    fresh = PlanCache()
    with obs.capture() as trace:
        report = wisdom.warm_start(path, cache=fresh)
    assert report.kept == 1 and report.dropped == 0
    assert len(fresh) == 1
    (ev,) = trace.select("serve.wisdom.warm_start")
    assert ev["kept"] == 1 and ev["file_error"] is None
    # the warmed entry is real wisdom: a lookup hits without planning
    got = fresh.get(_measured_plan().key)
    assert got is not None and got.mode == "measure"


def test_export_ships_measured_entries_only(tmp_path):
    src = PlanCache()
    src.put(_measured_plan())
    src.put(_estimate_plan())
    path = wisdom.export(str(tmp_path / "w.json"), src)
    fresh = PlanCache()
    assert fresh.load(path).kept == 1  # the ESTIMATE entry stayed home

    # measured_only=False ships everything (a debugging escape hatch)
    path_all = wisdom.export(str(tmp_path / "all.json"), src, measured_only=False)
    assert PlanCache().load(path_all).kept == 2


def test_export_to_unwritable_path_raises(tmp_path):
    # a regular file as the parent "directory" is unwritable for anyone,
    # root included (chmod-based denial doesn't bind root)
    blocker = tmp_path / "not-a-dir"
    blocker.write_text("")
    src = PlanCache()
    src.put(_measured_plan())
    with pytest.raises(RuntimeError, match="unwritable"):
        wisdom.export(str(blocker / "w.json"), src)


def test_warm_start_missing_artifact_reports_not_raises(tmp_path):
    report = wisdom.warm_start(str(tmp_path / "absent.json"), cache=PlanCache())
    assert report.kept == 0 and report.file_error is not None


def test_pretune_produces_measured_wisdom():
    cache = wisdom.pretune([8], kinds=("rfft2d",), measure_iters=1)
    assert len(cache) == 1
    ((_, plan),) = cache.entries()
    assert plan.key.kind == "rfft2d" and plan.key.shape == (8, 8)
    # MEASURE may legitimately degrade (trace state, budget) but the
    # entry must exist and carry the reason if it did
    assert plan.mode == "measure" or plan.degrade_reason is not None


@pytest.mark.skipif(
    jax.default_backend() != "cpu", reason="packaged artifact is cpu-tuned"
)
def test_packaged_cpu_artifact_loads_under_current_schema():
    """The checked-in wisdom file must stay loadable: kept > 0 guards
    against a schema bump orphaning the artifact silently."""
    path = wisdom.artifact_path("cpu")
    assert path is not None, "src/repro/serve/wisdom_files/cpu.json missing"
    cache = PlanCache()
    report = cache.load(path)
    assert report.kept > 0, f"packaged wisdom is stale: {report}"
    assert report.file_error is None
    assert all(p.mode == "measure" for _, p in cache.entries())


@pytest.mark.skipif(
    jax.default_backend() != "cpu", reason="packaged artifact is cpu-tuned"
)
def test_warm_started_service_serves_without_measure_sweeps(rng):
    """The fleet story end to end: warm_start + a measure-mode service =>
    zero plan.measure spans for an artifact-covered shape."""
    cache = PlanCache()
    report = wisdom.warm_start(cache=cache)  # packaged artifact
    assert report.kept > 0
    covered = next(
        p.key.shape for _, p in cache.entries() if p.key.kind == "rfft2d"
    )
    svc = SpectrumService(plan_mode="measure", cache=cache)
    reqs = [
        SpectrumRequest(frame=rng.standard_normal(covered).astype(np.float32))
        for _ in range(3)
    ]
    with obs.capture() as trace:
        svc.serve(reqs)
    assert all(r.done for r in reqs)
    assert trace.select("plan.measure") == []  # re-tuned nothing
    outcomes = [e["outcome"] for e in trace.select("plan.resolve")]
    assert outcomes == ["hit"]


def _warmed_cache(tmp_path, variant="stockham"):
    """A fresh cache warm-started from an artifact holding one MEASURE
    entry tuned to ``variant`` — the staleness-aging test substrate."""
    src = PlanCache()
    key = problem_key("rfft2d", (8, 8), "float32")
    src.put(FFTPlan(key=key, variant=variant, mode="measure", measured_us=12.5))
    path = wisdom.export(str(tmp_path / "seed.json"), src)
    fresh = PlanCache()
    wisdom.warm_start(path, cache=fresh)
    return fresh, key


def test_stale_losses_count_consecutive_retune_disagreements(tmp_path):
    cache, key = _warmed_cache(tmp_path, variant="stockham")
    ck = key.cache_key()
    retuned = FFTPlan(key=key, variant="radix4", mode="measure",
                      measured_us=9.0)
    with obs.capture() as trace:
        cache.put(retuned)
        cache.put(retuned)
    assert cache.stale_losses[ck] == 2
    losses = [e["losses"] for e in trace.select("serve.wisdom.stale")]
    assert losses == [1, 2]
    ev = trace.select("serve.wisdom.stale")[0]
    assert ev["artifact_variant"] == "stockham"
    assert ev["measured_variant"] == "radix4"


def test_stale_losses_reset_when_artifact_choice_reconfirmed(tmp_path):
    cache, key = _warmed_cache(tmp_path, variant="stockham")
    ck = key.cache_key()
    cache.put(FFTPlan(key=key, variant="radix4", mode="measure",
                      measured_us=9.0))
    assert cache.stale_losses[ck] == 1
    # a later sweep agrees with the artifact again: consecutive count resets
    cache.put(FFTPlan(key=key, variant="stockham", mode="measure",
                      measured_us=11.0))
    assert ck not in cache.stale_losses


def test_export_drops_entries_past_stale_loss_threshold(tmp_path):
    cache, key = _warmed_cache(tmp_path, variant="stockham")
    retuned = FFTPlan(key=key, variant="radix4", mode="measure",
                      measured_us=9.0)
    cache.put(retuned)
    cache.put(retuned)
    with obs.capture() as trace:
        aged = wisdom.export(str(tmp_path / "aged.json"), cache,
                             stale_loss_threshold=2)
    assert PlanCache().load(aged).kept == 0  # outvoted wisdom aged out
    (ev,) = trace.select("serve.wisdom.export")
    assert ev["dropped_stale"] == 1

    # below threshold (or aging disabled) the entry still ships
    kept = wisdom.export(str(tmp_path / "kept.json"), cache,
                         stale_loss_threshold=3)
    assert PlanCache().load(kept).kept == 1
    kept_all = wisdom.export(str(tmp_path / "all.json"), cache,
                             stale_loss_threshold=None)
    assert PlanCache().load(kept_all).kept == 1


def test_estimate_retunes_do_not_count_stale_losses(tmp_path):
    cache, key = _warmed_cache(tmp_path, variant="stockham")
    # ESTIMATE plans are heuristic guesses, not evidence against wisdom
    cache.put(FFTPlan(key=key, variant="radix4", mode="estimate",
                      est_time_s=1e-5))
    assert cache.stale_losses == {}


def test_pretune_wisdom_roundtrips_through_plan_fft(tmp_path, rng):
    """export -> warm_start -> plan_fft returns the shipped plan without
    re-tuning (cache hit, measure mode satisfied)."""
    src = PlanCache()
    src.put(_measured_plan(shape=(8, 8)))
    path = wisdom.export(str(tmp_path / "w.json"), src)
    fresh = PlanCache()
    wisdom.warm_start(path, cache=fresh)
    with obs.capture() as trace:
        plan = plan_fft("rfft2d", (8, 8), dtype="float32", mode="measure",
                        cache=fresh)
    assert plan.mode == "measure" and plan.measured_us == 12.5
    assert trace.select("plan.measure") == []
