"""ImagingService recon lane: CG-SENSE requests coalesce into ONE
batched solve under one plan per lane, and a repeat of a warm problem
key re-decides nothing (zero MEASURE sweeps, all cache hits)."""

import numpy as np
import pytest

from repro import mri, obs
from repro.plan import PlanCache
from repro.serve import ImagingService, ReconRequest, SpectrumRequest

N = 32


def _fixture(accel=2, n=N, coils=4, calib=8):
    x = np.asarray(mri.shepp_logan(n))
    smaps = np.asarray(mri.birdcage_maps(coils, n))
    mask = np.asarray(mri.uniform_mask((n, n), accel, calib=calib))
    k = np.asarray(mri.sense_forward(x, smaps, mask))
    return x, smaps, mask, k


def test_recon_lane_coalesces_into_one_batched_solve():
    x, smaps, mask, k = _fixture()
    svc = ImagingService()
    reqs = [ReconRequest(kspace=k, smaps=smaps, mask=mask) for _ in range(3)]
    with obs.capture() as trace:
        svc.serve(reqs)
    assert all(r.done for r in reqs)
    zf = mri.nrmse(np.asarray(mri.recon_zero_filled(k, smaps, mask)), x)
    for r in reqs:
        assert r.image.shape == (N, N)
        assert mri.nrmse(r.image, x) < 0.5 * zf
    # the whole queue ran as ONE batched CG execution
    batches = trace.select("serve.batch")
    assert [(e["service"], e["batch"]) for e in batches] == [("recon", 3)]
    # one plan, keyed on the batched coil-stack problem the CG transforms
    assert len(svc.plans) == 1
    (plan,) = svc.plans.values()
    assert plan.key.kind == "fft2d" and plan.key.shape == (3, 4, N, N)


def test_recon_result_matches_direct_call():
    x, smaps, mask, k = _fixture()
    req = ReconRequest(kspace=k, smaps=smaps, mask=mask, iters=6, lam=1e-3)
    ImagingService().serve([req])
    direct = np.asarray(
        mri.recon_cg_sense(k, smaps, mask, iters=6, lam=1e-3)
    )
    np.testing.assert_allclose(req.image, direct, atol=1e-5)


def test_recon_lanes_split_by_problem_geometry():
    # calib rows push the realised (rounded) acceleration of a nominal
    # R=4 mask down toward 2 — drop them so the lanes genuinely differ
    _, smaps, mask2, k2 = _fixture(accel=2)
    _, _, mask4, k4 = _fixture(accel=8, calib=0)
    svc = ImagingService()
    reqs = [
        ReconRequest(kspace=k2, smaps=smaps, mask=mask2),
        ReconRequest(kspace=k4, smaps=smaps, mask=mask4),   # different R
        ReconRequest(kspace=k2, smaps=smaps, mask=mask2, iters=5),  # diff budget
    ]
    with obs.capture() as trace:
        svc.serve(reqs)
    assert all(r.done for r in reqs)
    recon_batches = [
        e for e in trace.select("serve.batch") if e["service"] == "recon"
    ]
    assert sorted(e["batch"] for e in recon_batches) == [1, 1, 1]
    assert len({(e["accel"], e["iters"]) for e in recon_batches}) == 3


def test_mixed_queue_recon_plus_spectrum(rng):
    x, smaps, mask, k = _fixture()
    recon = ReconRequest(kspace=k, smaps=smaps, mask=mask)
    spec = SpectrumRequest(frame=rng.standard_normal((16, 16)).astype(np.float32))
    ImagingService().serve([recon, spec])
    assert recon.done and spec.done


def test_second_serve_of_warm_key_re_decides_nothing():
    """The acceptance gate: after a MEASURE warm-up, a repeat batch of
    the same problem key performs ZERO measured sweeps — every planner
    decision in the event stream is a cache hit."""
    x, smaps, mask, k = _fixture()
    svc = ImagingService(plan_mode="measure", cache=PlanCache())

    def queue():
        return [ReconRequest(kspace=k, smaps=smaps, mask=mask) for _ in range(2)]

    svc.serve(queue())                           # tunes the lane's key(s)
    with obs.capture() as trace:
        svc.serve(queue())
    assert trace.select("plan.measure") == []
    resolves = trace.select("plan.resolve")
    assert resolves and {e["outcome"] for e in resolves} == {"hit"}


def test_recon_request_validation_is_all_or_nothing(rng):
    _, smaps, mask, k = _fixture()
    good = SpectrumRequest(frame=rng.standard_normal((8, 8)).astype(np.float32))
    bad = ReconRequest(kspace=k, smaps=smaps[:2], mask=mask)
    with pytest.raises(ValueError, match="matching"):
        ImagingService().serve([good, bad])
    assert not good.done and good.spectrum is None
    with pytest.raises(ValueError, match="mask"):
        ImagingService().serve(
            [ReconRequest(kspace=k, smaps=smaps, mask=mask[:16])]
        )
    with pytest.raises(ValueError, match="iters"):
        ImagingService().serve(
            [ReconRequest(kspace=k, smaps=smaps, mask=mask, iters=0)]
        )
    with pytest.raises(ValueError, match="lam"):
        ImagingService().serve(
            [ReconRequest(kspace=k, smaps=smaps, mask=mask, lam=-0.1)]
        )
