"""SpectrumService: plan-aware batched 2D-FFT serving over mixed frames."""

import numpy as np
import pytest

from repro.serve import SpectrumRequest, SpectrumService


def test_serves_mixed_real_and_complex_groups(rng):
    reqs = [
        SpectrumRequest(frame=rng.standard_normal((16, 16)).astype(np.float32))
        for _ in range(3)
    ]
    reqs.append(
        SpectrumRequest(
            frame=(
                rng.standard_normal((8, 8)) + 1j * rng.standard_normal((8, 8))
            ).astype(np.complex64)
        )
    )
    svc = SpectrumService()
    out = svc.serve(reqs)
    assert out is reqs and all(r.done for r in reqs)
    # real frames -> two-for-one half spectrum
    for r in reqs[:3]:
        assert r.spectrum.shape == (16, 9)
        np.testing.assert_allclose(
            r.spectrum, np.fft.rfft2(np.asarray(r.frame)), atol=1e-3
        )
    # complex frame -> full spectrum
    assert reqs[3].spectrum.shape == (8, 8)
    np.testing.assert_allclose(
        reqs[3].spectrum, np.fft.fft2(np.asarray(reqs[3].frame)), atol=1e-3
    )


def test_one_plan_per_group_is_memoized(rng):
    svc = SpectrumService()
    reqs = [
        SpectrumRequest(frame=rng.standard_normal((8, 8)).astype(np.float32))
        for _ in range(4)
    ]
    svc.serve(reqs)
    assert len(svc.plans) == 1  # one shape group -> one plan
    svc.serve(
        [SpectrumRequest(frame=rng.standard_normal((8, 8)).astype(np.float32))
         for _ in range(7)]
    )
    # a different batch count of the same frame shape reuses the plan:
    # scheduling depends on frame geometry, not on arrival count
    assert len(svc.plans) == 1


def test_scoped_config_override_reaches_serving(rng):
    """A forced-variant scope applies to serving and neither reads nor
    leaves stale session-memo entries."""
    import repro.xfft as xfft

    svc = SpectrumService()
    frame = rng.standard_normal((8, 8)).astype(np.float32)
    svc.serve([SpectrumRequest(frame=frame)])
    (default_plan,) = svc.plans.values()
    with xfft.config(variant="looped"):
        svc.serve([SpectrumRequest(frame=frame)])
    assert len(svc.plans) == 2  # scoped call got its own memo entry
    forced = [p for p in svc.plans.values() if p is not default_plan]
    assert forced[0].variant == "looped"
    svc.serve([SpectrumRequest(frame=frame)])
    assert len(svc.plans) == 2  # back out of scope: default memo reused


def test_rejects_bad_inputs(rng):
    svc = SpectrumService()
    with pytest.raises(ValueError):
        svc.serve([SpectrumRequest(frame=rng.standard_normal((4, 4, 4)))])
    with pytest.raises(ValueError):
        SpectrumService(plan_mode="exhaustive")
