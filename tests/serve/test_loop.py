"""ServeLoop: continuous batching, fairness, backpressure, quarantine.

The toy-executor tests drive the scheduler itself (no jax, no planner);
the SpectrumService tests prove the integration — streaming submits
through the real planner/engine path, including the acceptance
criterion: benching a lane's engine mid-stream produces exactly one
``resilience.failover`` and the lane keeps serving with parity.
"""

import numpy as np
import pytest

import repro.xfft as xfft
from repro import obs
from repro.resilience import (
    FaultPlan,
    FaultSpec,
    Overloaded,
    ServicePolicy,
    configure,
    quarantine,
)
from repro.serve import (
    BatchPolicy,
    LaneKey,
    ServeLoop,
    SpectrumRequest,
    SpectrumService,
)
from repro.serve.loop import record_lane_key, services_for_key


def _toy_loop(batches, **kw):
    """A loop whose executor just records (lane, members) per batch."""

    def classify(r):
        return LaneKey(r["lane"], ())

    def execute(lane, members):
        batches.append((lane.family, list(members)))
        for m in members:
            m["served"] = True

    return ServeLoop(classify, execute, service="toy", **kw)


def _reqs(lane, n):
    return [{"lane": lane, "i": i, "served": False} for i in range(n)]


# ------------------------------ scheduling ------------------------------


def test_lane_coalescing_respects_max_batch():
    batches = []
    loop = _toy_loop(batches, batch=BatchPolicy(max_batch=4))
    for r in _reqs("a", 10):
        loop.submit(r)
    served = loop.drain()
    assert served == 10
    assert [len(m) for _, m in batches] == [4, 4, 2]
    assert all(m["served"] for _, ms in batches for m in ms)


def test_lanes_coalesce_across_interleaved_arrival_order():
    batches = []
    loop = _toy_loop(batches, batch=BatchPolicy(max_batch=8))
    reqs = [r for pair in zip(_reqs("a", 4), _reqs("b", 4)) for r in pair]
    loop.serve(reqs)
    # interleaved a/b/a/b arrivals still form ONE batch per lane
    assert sorted((fam, len(ms)) for fam, ms in batches) == [("a", 4), ("b", 4)]


def test_round_robin_prevents_lane_starvation():
    """Sustained load on a hot lane must not starve a quiet one: the
    quiet lane's single request is served within one rotation, not after
    the hot backlog empties."""
    batches = []
    loop = _toy_loop(batches, batch=BatchPolicy(max_batch=2))
    for r in _reqs("hot", 8):
        loop.submit(r)
    quiet = _reqs("quiet", 1)[0]
    loop.submit(quiet)
    loop.tick(drain=True)   # hot dispatches first (older lane)...
    loop.tick(drain=True)   # ...then the rotation reaches quiet
    assert quiet["served"], [fam for fam, _ in batches]
    assert [fam for fam, _ in batches] == ["hot", "quiet"]
    # the hot backlog is still pending — fairness, not preemption
    assert loop.queue.depth() == 6
    loop.drain()
    assert loop.queue.depth() == 0


def test_max_wait_window_holds_then_releases(fake_clock):
    """A non-full lane waits out the coalescing window, then dispatches."""
    batches = []
    loop = _toy_loop(
        batches, batch=BatchPolicy(max_batch=4, max_wait_s=1.0),
        clock=fake_clock,
    )
    loop.submit(_reqs("a", 1)[0])
    assert loop.tick() == 0          # inside the window: hold for more
    fake_clock.now += 0.5
    loop.submit(_reqs("a", 1)[0])
    assert loop.tick() == 0
    fake_clock.now += 0.6            # oldest ticket now past max_wait_s
    assert loop.tick() == 2          # both coalesced into one batch
    assert [len(ms) for _, ms in batches] == [2]


def test_full_lane_dispatches_inside_wait_window(fake_clock):
    batches = []
    loop = _toy_loop(
        batches, batch=BatchPolicy(max_batch=2, max_wait_s=60.0),
        clock=fake_clock,
    )
    for r in _reqs("a", 2):
        loop.submit(r)
    assert loop.tick() == 2          # full lane: no need to wait


# ---------------------------- backpressure ----------------------------


def test_streaming_shed_at_max_queue_never_drops_admitted():
    batches = []
    loop = _toy_loop(batches, policy=ServicePolicy(max_queue=2))
    t1 = loop.submit(_reqs("a", 1)[0])
    t2 = loop.submit(_reqs("b", 1)[0])
    with obs.capture() as trace:
        with pytest.raises(Overloaded) as ei:
            loop.submit(_reqs("a", 1)[0])
    assert ei.value.depth == 3 and ei.value.limit == 2
    (shed,) = trace.select("serve.shed")
    assert shed["service"] == "toy" and shed["lane"] == "a[]"
    # the two admitted requests still drain — shed rejects, never drops
    loop.drain()
    assert t1.done and t2.done
    assert t1.result()["served"] and t2.result()["served"]


def test_call_scoped_serve_sheds_whole_call():
    loop = _toy_loop([], policy=ServicePolicy(max_queue=2))
    reqs = _reqs("a", 3)
    with pytest.raises(Overloaded):
        loop.serve(reqs)
    assert not any(r["served"] for r in reqs)
    assert loop.queue.depth() == 0   # nothing half-admitted


def test_classify_error_prefixes_request_index():
    def classify(r):
        raise ValueError("boom")

    loop = ServeLoop(classify, lambda lane, ms: None, service="toy")
    with pytest.raises(ValueError, match="request 0: boom"):
        loop.serve([{"lane": "a"}])


# ------------------------------ tickets ------------------------------


def test_ticket_carries_batch_error_to_submitter():
    def execute(lane, members):
        raise RuntimeError("lane exploded")

    loop = ServeLoop(lambda r: LaneKey("a", ()), execute, service="toy")
    t = loop.submit({"x": 1})
    with obs.capture() as trace:
        served = loop.tick(drain=True)
    assert served == 1 and t.done
    with pytest.raises(RuntimeError, match="lane exploded"):
        t.result()
    (err,) = trace.select("serve.lane.error")
    assert err["service"] == "toy" and err["lane"] == "a[]"


def test_tick_emits_depth_gauge_and_lane_label():
    loop = _toy_loop([], batch=BatchPolicy(max_batch=2))
    for r in _reqs("a", 3):
        loop.submit(r)
    with obs.capture() as trace:
        loop.tick()
    (tick,) = trace.select("serve.loop.tick")
    assert tick["service"] == "toy" and tick["lane"] == "a[]"
    assert tick["batch"] == 2 and tick["depth"] == 1  # gauge: 1 left behind


# --------------------------- background thread ---------------------------


def test_background_loop_serves_streaming_submits():
    batches = []
    loop = _toy_loop(batches, batch=BatchPolicy(max_batch=4)).start()
    try:
        tickets = [loop.submit(r) for r in _reqs("a", 6)]
        for t in tickets:
            assert t.wait(timeout=5.0), "background loop never served ticket"
        assert all(t.result()["served"] for t in tickets)
    finally:
        loop.stop()
    assert loop.queue.depth() == 0


# ----------------------- lane -> key registry -----------------------


def test_lane_key_registry_groups_by_service():
    record_lane_key("spectrum", "v5|k1")
    record_lane_key("imaging", "v5|k1")
    record_lane_key("imaging", "v5|k2")
    assert services_for_key("v5|k1") == ("imaging", "spectrum")
    assert services_for_key("v5|k2") == ("imaging",)
    assert services_for_key("v5|unknown") == ()


# ------------------- SpectrumService over the loop -------------------


def test_streaming_submits_match_call_scoped_parity(rng):
    svc = SpectrumService(batch=BatchPolicy(max_batch=4))
    frames = [rng.standard_normal((8, 8)).astype(np.float32) for _ in range(6)]
    tickets = [svc.loop.submit(SpectrumRequest(frame=f)) for f in frames]
    svc.loop.drain()
    for t, f in zip(tickets, frames):
        np.testing.assert_allclose(
            t.result().spectrum, np.fft.rfft2(f), rtol=1e-4, atol=1e-4
        )
    assert len(svc.plans) == 1  # batches of 4 and 2 share one plan


def test_benched_engine_mid_stream_keeps_lane_serving(fake_clock, rng):
    """Acceptance criterion: bench a lane's engine mid-stream -> exactly
    one resilience.failover, the lane re-resolves (serve.lane.replan) and
    keeps serving with parity."""
    configure(cooldown_s=30.0, clock=fake_clock)
    svc = SpectrumService(batch=BatchPolicy(max_batch=2))
    frames = [rng.standard_normal((8, 8)).astype(np.float32) for _ in range(6)]

    # probe which engine serves this lane, then reset the bench
    svc.serve([SpectrumRequest(frame=frames[0])])
    ((_, plan),) = list(svc.plans.items())
    first = plan.variant
    from repro.resilience import reset

    reset()

    faults = FaultPlan(
        FaultSpec("engine.apply", mode="error", match={"engine": first}, times=1)
    )
    with obs.capture() as trace, xfft.config(faults=faults):
        tickets = [svc.loop.submit(SpectrumRequest(frame=f)) for f in frames]
        svc.loop.drain()
    for t, f in zip(tickets, frames):
        np.testing.assert_allclose(
            t.result().spectrum, np.fft.rfft2(f), rtol=1e-4, atol=1e-4
        )
    (failover,) = trace.select("resilience.failover")
    assert failover["engine"] == first
    # batches after the bench re-resolved around the benched memo entry
    assert len(trace.select("serve.lane.replan")) >= 1
    assert quarantine().table() != []  # breaker still open mid-cooldown
    # after cooldown the half-open probe restores the original engine
    fake_clock.now += 31.0
    svc.serve([SpectrumRequest(frame=frames[0])])
    assert quarantine().table() == []


def test_injected_serve_fault_retries_per_lane_policy(rng):
    svc = SpectrumService(
        policy=ServicePolicy(max_retries=1, backoff_s=0.0),
        batch=BatchPolicy(max_batch=4),
    )
    plan = FaultPlan(FaultSpec("serve.batch", mode="error", times=1))
    with obs.capture() as trace, xfft.config(faults=plan):
        t = svc.loop.submit(
            SpectrumRequest(frame=rng.standard_normal((8, 8)).astype(np.float32))
        )
        svc.loop.drain()
    assert t.result().done
    assert len(trace.select("resilience.retry")) == 1
