"""The ISSUE 10 acceptance gate: every repro.mri transform resolves
through repro.plan (spy on resolve_call; forced dispatch reroutes the
transforms INSIDE the operators), with zero private engine calls and a
DeprecationWarning-free surface."""

import warnings

import numpy as np
import pytest

import repro.xfft as xfft
import repro.xfft._transforms as _transforms
from repro import mri
from repro.plan.api import resolve_call as _real_resolve_call


@pytest.fixture
def plan_calls(monkeypatch):
    """Record every planner resolution made by the xfft front door;
    error on any DeprecationWarning (legacy shims would emit one)."""
    calls = []

    def spy(kind, shape, *args, **kwargs):
        calls.append(kind)
        return _real_resolve_call(kind, shape, *args, **kwargs)

    monkeypatch.setattr(_transforms, "resolve_call", spy)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        yield calls


def test_sense_operators_resolve_through_plan(plan_calls, phantom, smaps):
    mask = mri.uniform_mask((64, 64), 2)
    k = mri.sense_forward(phantom, smaps, mask)
    assert plan_calls == ["fft2d"]               # one batched coil transform
    mri.sense_adjoint(k, smaps, mask)
    assert plan_calls == ["fft2d", "fft2d"]


def test_cg_sense_transform_accounting(plan_calls, phantom, smaps):
    """A CG solve is EXACTLY 1 + 2·iters planned fft2d resolutions (the
    Aᴴy seed, then forward+adjoint per iteration) — nothing bypasses the
    planner, nothing transforms twice."""
    mask = mri.uniform_mask((64, 64), 2)
    k = mri.sense_forward(phantom, smaps, mask)
    plan_calls.clear()
    mri.recon_cg_sense(k, smaps, mask, iters=4)
    assert plan_calls == ["fft2d"] * (1 + 2 * 4)


def test_map_estimation_resolves_through_plan(plan_calls, kspace_full):
    plan_calls.clear()                           # kspace fixture transformed too
    mri.estimate_sensitivities(kspace_full, calib=16)
    assert plan_calls == ["fft2d"]               # one low-res inverse


def test_moco_resolves_through_plan(plan_calls, phantom, smaps):
    mask = np.asarray(mri.uniform_mask((64, 64), 2))
    masks = mri.shot_masks(mask, 2)
    shifts = np.array([[0.0, 0.0], [2.0, -1.0]], np.float32)
    k = mri.moco_forward(phantom, smaps, masks, shifts)
    # apply_shift (complex: fft2d pair) + the SENSE forward transform
    assert plan_calls == ["fft2d"] * 3 and "rfft2d" not in plan_calls
    plan_calls.clear()
    mri.estimate_shot_shifts(k, smaps, masks)
    # shot adjoint (fft2d), then phase correlation on REAL navigators:
    # the registration machinery keeps its two-for-one rfft2d path
    assert plan_calls[0] == "fft2d"
    assert plan_calls.count("rfft2d") == 3


def test_forced_dispatch_reaches_mri_operators(phantom, smaps, monkeypatch):
    """A scoped variant override must reroute the transforms INSIDE the
    MRI operators — proof their FFTs go through resolve_call, not around
    it (zero private engine calls)."""
    import repro.kernels.ops as ops

    kernel_calls = []
    real_kernel = ops.fft2_kernel

    def spy(x, **kw):
        kernel_calls.append(np.asarray(x).shape)
        return real_kernel(x, **kw)

    monkeypatch.setattr(ops, "fft2_kernel", spy)
    mask = mri.uniform_mask((64, 64), 2)
    mri.sense_forward(phantom, smaps, mask)
    assert kernel_calls == []                    # ESTIMATE on CPU: jnp engines
    with xfft.config(variant="fused"):
        mri.sense_forward(phantom, smaps, mask)
    assert len(kernel_calls) == 1                # forced, exactly once, in scope
    mri.sense_forward(phantom, smaps, mask)
    assert len(kernel_calls) == 1                # nothing leaked past the scope
