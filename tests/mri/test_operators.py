"""SENSE operator contracts: shapes, batching, and — the property every
iterative reconstruction leans on — exact adjointness of the
forward/adjoint pair under the ortho centered transform, in single AND
double precision."""

import numpy as np
import pytest
from jax.experimental import enable_x64

import repro.xfft as xfft
from repro import mri


def _complex_rand(rng, shape, dtype=np.complex64):
    return (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)).astype(
        dtype
    )


def test_forward_adjoint_shapes(phantom, smaps):
    k = mri.sense_forward(phantom, smaps)
    assert k.shape == smaps.shape                      # (C, H, W)
    img = mri.sense_adjoint(k, smaps)
    assert img.shape == phantom.shape                  # (H, W)


def test_leading_axes_batch(phantom, smaps):
    batch = np.stack([phantom, phantom[::-1].copy()])
    k = np.asarray(mri.sense_forward(batch, smaps))
    assert k.shape == (2, *smaps.shape)
    single = np.asarray(mri.sense_forward(batch[1], smaps))
    np.testing.assert_allclose(k[1], single, atol=1e-5)
    img = np.asarray(mri.sense_adjoint(k, smaps))
    assert img.shape == batch.shape


def test_unitarity_with_normalised_maps(phantom, smaps):
    """Birdcage maps are RSS-normalised, so AᴴA = Σ_c |S_c|² = I when
    fully sampled — the adjoint inverts the forward exactly."""
    x = phantom.astype(np.complex64)
    back = np.asarray(mri.sense_adjoint(mri.sense_forward(x, smaps), smaps))
    np.testing.assert_allclose(back, x, atol=1e-5)


def test_adjointness_single(rng, smaps):
    """<A u, v> == <u, Aᴴ v> — the defining identity, at the masked
    operator (the one CG actually inverts)."""
    h, w = smaps.shape[-2:]
    mask = np.asarray(mri.uniform_mask((h, w), 2))
    u = _complex_rand(rng, (h, w))
    v = _complex_rand(rng, smaps.shape)
    au = np.asarray(mri.sense_forward(u, smaps, mask))
    ahv = np.asarray(mri.sense_adjoint(v, smaps, mask))
    lhs = np.vdot(au, v)
    rhs = np.vdot(u, ahv)
    assert abs(lhs - rhs) <= 1e-4 * abs(lhs)


def test_adjointness_double(rng):
    """The same identity at double precision: the centered transforms
    must not silently downcast complex128 inside an x64 scope."""
    with enable_x64():
        with xfft.config(precision="double"):
            smaps = np.asarray(mri.birdcage_maps(4, 32)).astype(np.complex128)
            mask = np.asarray(mri.uniform_mask((32, 32), 2))
            u = _complex_rand(rng, (32, 32), np.complex128)
            v = _complex_rand(rng, smaps.shape, np.complex128)
            au = np.asarray(mri.sense_forward(u, smaps, mask))
            ahv = np.asarray(mri.sense_adjoint(v, smaps, mask))
    assert au.dtype == np.complex128 and ahv.dtype == np.complex128
    lhs = np.vdot(au, v)
    rhs = np.vdot(u, ahv)
    assert abs(lhs - rhs) <= 1e-12 * abs(lhs)


def test_apply_mask_bool_and_float(rng, smaps):
    k = _complex_rand(rng, smaps.shape)
    m = np.asarray(mri.uniform_mask(smaps.shape[-2:], 2))
    np.testing.assert_array_equal(
        np.asarray(mri.apply_mask(k, m.astype(bool))),
        np.asarray(mri.apply_mask(k, m)),
    )
    masked = np.asarray(mri.apply_mask(k, m))
    assert masked.dtype == k.dtype
    assert np.all(masked[:, m == 0] == 0)


def test_rss_of_normalised_maps_is_one(smaps):
    np.testing.assert_allclose(
        np.asarray(mri.rss_combine(smaps)), 1.0, atol=1e-5
    )


def test_shape_validation():
    with pytest.raises(ValueError, match="image"):
        mri.sense_forward(np.zeros(8), np.zeros((4, 8, 8)))
    with pytest.raises(ValueError, match="smaps"):
        mri.sense_forward(np.zeros((8, 8)), np.zeros((8, 8)))
    with pytest.raises(ValueError, match="does not match"):
        mri.sense_forward(np.zeros((8, 8)), np.zeros((4, 8, 16)))
    with pytest.raises(ValueError, match="kspace"):
        mri.sense_adjoint(np.zeros((8, 8)), np.zeros((4, 8, 8)))
    with pytest.raises(ValueError, match="does not match"):
        mri.sense_adjoint(np.zeros((4, 8, 8)), np.zeros((2, 8, 8)))
