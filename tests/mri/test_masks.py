"""Sampling-mask fixtures: reproducibility, calibration contract,
realised acceleration, and the ESPIRiT-lite map estimate."""

import numpy as np
import pytest

from repro import mri


def test_uniform_mask_pattern():
    m = np.asarray(mri.uniform_mask((64, 48), 4, calib=8))
    assert m.shape == (64, 48) and m.dtype == np.float32
    # rows are kept whole (Cartesian phase-encode undersampling)
    rows = (m != 0).any(axis=1)
    np.testing.assert_array_equal(m[rows], 1.0)
    assert rows[::4].all()                        # every 4th row kept
    assert rows[28:36].all()                      # centred calib block
    assert not rows[1] and not rows[2]


def test_variable_density_reproducible():
    a = mri.variable_density_mask((64, 64), 4, seed=7)
    b = mri.variable_density_mask((64, 64), 4, seed=7)
    np.testing.assert_array_equal(a, b)
    c = mri.variable_density_mask((64, 64), 4, seed=8)
    assert (a != c).any()


def test_variable_density_centre_heavy():
    m = np.asarray(mri.variable_density_mask((128, 64), 4, calib=0, seed=0))
    rows = (m != 0).any(axis=1)
    centre = rows[32:96].mean()
    edges = np.concatenate([rows[:32], rows[96:]]).mean()
    assert centre > edges


def test_calibration_block_always_sampled():
    m = np.asarray(mri.variable_density_mask((64, 64), 8, calib=12, seed=3))
    assert (m[26:38] == 1.0).all()


def test_acceleration_accounting():
    m = mri.uniform_mask((64, 64), 4, calib=0)
    assert mri.acceleration(m) == pytest.approx(4.0)
    with pytest.raises(ValueError, match="no samples"):
        mri.acceleration(np.zeros((8, 8)))


def test_mask_validation():
    with pytest.raises(ValueError, match="shape"):
        mri.uniform_mask((64,), 2)
    with pytest.raises(ValueError, match="acceleration"):
        mri.uniform_mask((64, 64), 0)
    with pytest.raises(ValueError, match="calibration"):
        mri.uniform_mask((64, 64), 2, calib=100)


def test_estimated_maps_close_to_truth(phantom, smaps, kspace_full):
    """On the smooth birdcage truth the windowed-calibration estimate is
    accurate wherever the object has signal."""
    est = np.asarray(mri.estimate_sensitivities(kspace_full, calib=24))
    assert est.shape == smaps.shape
    support = phantom > 0.1
    err = np.abs(est - smaps)[:, support]
    assert err.mean() < 0.06, err.mean()
    # RSS-normalised on the object, like the truth
    rss = np.asarray(mri.rss_combine(est))
    np.testing.assert_allclose(rss[support], 1.0, atol=0.05)


def test_estimate_rejects_unsampled_calibration(kspace_full):
    bad = np.asarray(mri.uniform_mask((64, 64), 4, calib=0))
    with pytest.raises(ValueError, match="calibration block"):
        mri.estimate_sensitivities(kspace_full, calib=16, mask=bad)
    ok = mri.uniform_mask((64, 64), 4, calib=16)
    mri.estimate_sensitivities(kspace_full, calib=16, mask=ok)
