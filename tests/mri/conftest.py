"""Shared MRI fixtures: one deterministic phantom + birdcage coil set.

Everything downstream (operators, masks, recon, moco, serving, the
benchmark and the example) is a function of these two arrays, so the
whole suite is bit-reproducible run to run.
"""

import numpy as np
import pytest

from repro import mri

N = 64
COILS = 4


@pytest.fixture(scope="session")
def phantom():
    return np.asarray(mri.shepp_logan(N))


@pytest.fixture(scope="session")
def smaps():
    return np.asarray(mri.birdcage_maps(COILS, N))


@pytest.fixture
def kspace_full(phantom, smaps):
    """Fully sampled multi-coil k-space of the phantom."""
    return np.asarray(mri.sense_forward(phantom, smaps))
