"""Reconstruction gates: CG-SENSE must beat the zero-filled baseline at
R=2 and R=4 by a real margin, converge (residual trace from the event
stream), batch correctly, and hold up with estimated maps."""

import numpy as np
import pytest

from repro import mri, obs


def _undersampled(phantom, smaps, mask):
    k = np.asarray(mri.sense_forward(phantom, smaps, mask))
    zf = mri.nrmse(mri.recon_zero_filled(k, smaps, mask), phantom)
    return k, zf


def test_cg_beats_zero_filled_r2(phantom, smaps):
    mask = mri.uniform_mask((64, 64), 2)
    k, zf = _undersampled(phantom, smaps, mask)
    cg = mri.nrmse(mri.recon_cg_sense(k, smaps, mask, iters=10), phantom)
    assert cg < 0.25 * zf, (cg, zf)


def test_cg_beats_zero_filled_r4(phantom, smaps):
    mask = mri.uniform_mask((64, 64), 4)
    k, zf = _undersampled(phantom, smaps, mask)
    cg = mri.nrmse(mri.recon_cg_sense(k, smaps, mask, iters=10), phantom)
    assert cg < 0.5 * zf, (cg, zf)


def test_cg_beats_zero_filled_variable_density(phantom, smaps):
    mask = mri.variable_density_mask((64, 64), 4, seed=0)
    k, zf = _undersampled(phantom, smaps, mask)
    cg = mri.nrmse(mri.recon_cg_sense(k, smaps, mask, iters=10), phantom)
    assert cg < 0.6 * zf, (cg, zf)


def test_convergence_trace_from_event_stream(phantom, smaps):
    """Every iteration emits mri.cg.iter; the residual trace decreases
    (CG minimises the A-norm error, so the residual norm may tick up a
    hair — bound the uptick, require a strong overall decrease)."""
    mask = mri.uniform_mask((64, 64), 4)
    k, _ = _undersampled(phantom, smaps, mask)
    with obs.capture() as trace:
        mri.recon_cg_sense(k, smaps, mask, iters=10)
    events = trace.select("mri.cg.iter")
    assert [e["iter"] for e in events] == list(range(10))
    assert all(e["model"] == "sense" for e in events)
    res = [e["residual"] for e in events]
    assert all(res[i + 1] <= 1.2 * res[i] for i in range(len(res) - 1)), res
    assert res[-1] < 0.1 * res[0], res


def test_tol_stops_early(phantom, smaps):
    mask = mri.uniform_mask((64, 64), 2)
    k, _ = _undersampled(phantom, smaps, mask)
    with obs.capture() as trace:
        mri.recon_cg_sense(k, smaps, mask, iters=20, tol=1e-2)
    assert len(trace.select("mri.cg.iter")) < 20


def test_batched_cg_matches_per_item(phantom, smaps):
    """A stacked (B, C, H, W) solve with per-item masks equals the two
    individual solves — the property the serve lane's coalescing rests
    on (per-item step sizes in cg_normal)."""
    m1 = np.asarray(mri.uniform_mask((64, 64), 2))
    m2 = np.asarray(mri.variable_density_mask((64, 64), 4, seed=5))
    k1 = np.asarray(mri.sense_forward(phantom, smaps, m1))
    k2 = np.asarray(mri.sense_forward(phantom[::-1].copy(), smaps, m2))
    ks = np.stack([k1, k2])
    masks = np.stack([m1, m2])[:, None]              # (B, 1, H, W)
    batched = np.asarray(
        mri.recon_cg_sense(ks, smaps, mask=masks, iters=6)
    )
    solo1 = np.asarray(mri.recon_cg_sense(k1, smaps, m1, iters=6))
    solo2 = np.asarray(mri.recon_cg_sense(k2, smaps, m2, iters=6))
    np.testing.assert_allclose(batched[0], solo1, atol=2e-4)
    np.testing.assert_allclose(batched[1], solo2, atol=2e-4)


def test_estimated_maps_close_the_loop(phantom, smaps):
    """End-to-end with NO ground-truth maps: estimate from the data's own
    calibration block, reconstruct, still beat zero-filled."""
    mask = mri.variable_density_mask((64, 64), 2, seed=1)
    k = np.asarray(mri.sense_forward(phantom, smaps, mask))
    est = mri.estimate_sensitivities(k, calib=16, mask=mask)
    zf = mri.nrmse(mri.recon_zero_filled(k, est, mask), phantom)
    cg = mri.nrmse(
        mri.recon_cg_sense(k, est, mask, iters=10, lam=1e-3), phantom
    )
    assert cg < 0.75 * zf, (cg, zf)


def test_tikhonov_and_iter_validation(phantom, smaps):
    mask = mri.uniform_mask((64, 64), 2)
    k, _ = _undersampled(phantom, smaps, mask)
    with pytest.raises(ValueError, match="lam"):
        mri.recon_cg_sense(k, smaps, mask, lam=-1.0)
    with pytest.raises(ValueError, match="iters"):
        mri.recon_cg_sense(k, smaps, mask, iters=0)


def test_nrmse_metric():
    ref = np.ones((8, 8), np.float32)
    assert mri.nrmse(ref, ref) == 0.0
    assert mri.nrmse(1.5 * ref, ref) == pytest.approx(0.5, abs=1e-6)
