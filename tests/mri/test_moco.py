"""Motion-compensated model: shot partitioning, adjointness, the
motion-beats-blind reconstruction gate, and registration-based shift
estimation closing the loop without ground truth."""

import numpy as np
import pytest

from repro import mri

SHIFTS = np.array([[0.0, 0.0], [3.0, -2.0]], np.float32)


def _corrupted(phantom, smaps, n_shots=2, accel=2):
    mask = np.asarray(mri.uniform_mask(phantom.shape, accel))
    masks = mri.shot_masks(mask, n_shots)
    k = np.asarray(mri.moco_forward(phantom, smaps, masks, SHIFTS[:n_shots]))
    return mask, masks, k


def test_shot_masks_partition():
    mask = np.asarray(mri.uniform_mask((64, 64), 2))
    shots = mri.shot_masks(mask, 3)
    assert shots.shape == (3, 64, 64)
    np.testing.assert_array_equal(shots.sum(axis=0), mask)   # complete
    assert (shots.astype(bool).sum(axis=0) <= 1).all()       # disjoint
    with pytest.raises(ValueError, match="n_shots"):
        mri.shot_masks(mask, 0)
    with pytest.raises(ValueError, match="too few"):
        mri.shot_masks(mask, 64)


def test_moco_adjointness(rng, phantom, smaps):
    mask, masks, _ = _corrupted(phantom, smaps)
    u = (rng.standard_normal((64, 64)) + 1j * rng.standard_normal((64, 64))).astype(
        np.complex64
    )
    v = (rng.standard_normal(smaps.shape) + 1j * rng.standard_normal(smaps.shape)).astype(
        np.complex64
    )
    au = np.asarray(mri.moco_forward(u, smaps, masks, SHIFTS))
    ahv = np.asarray(mri.moco_adjoint(v, smaps, masks, SHIFTS))
    lhs = np.vdot(au, v)
    rhs = np.vdot(u, ahv)
    assert abs(lhs - rhs) <= 1e-4 * abs(lhs)


def test_zero_motion_reduces_to_sense(phantom, smaps):
    """With all shifts zero the shot structure is invisible: the moco
    model must equal the plain SENSE model on the combined mask."""
    mask, masks, _ = _corrupted(phantom, smaps)
    zero = np.zeros((2, 2), np.float32)
    k_moco = np.asarray(mri.moco_forward(phantom, smaps, masks, zero))
    k_sense = np.asarray(mri.sense_forward(phantom, smaps, mask))
    np.testing.assert_allclose(k_moco, k_sense, atol=1e-5)


def test_moco_recon_beats_motion_blind(phantom, smaps):
    """The gate: modelling the inter-shot motion recovers what
    motion-blind CG-SENSE cannot."""
    mask, masks, k = _corrupted(phantom, smaps)
    blind = mri.nrmse(
        mri.recon_cg_sense(k, smaps, mask, iters=8), phantom
    )
    moco = mri.nrmse(
        mri.recon_cg_moco(k, smaps, masks, SHIFTS, iters=8), phantom
    )
    assert moco < 0.5 * blind, (moco, blind)


def test_estimated_shifts_close_the_loop(phantom, smaps):
    """Registration-based navigators estimate the per-shot motion from
    the corrupted data alone; reconstructing with the ESTIMATE must be
    about as good as with the truth."""
    mask, masks, k = _corrupted(phantom, smaps)
    est = np.asarray(mri.estimate_shot_shifts(k, smaps, masks))
    np.testing.assert_allclose(est[0], 0.0, atol=1e-6)       # ref shot pinned
    np.testing.assert_allclose(est, SHIFTS, atol=0.5)
    with_truth = mri.nrmse(
        mri.recon_cg_moco(k, smaps, masks, SHIFTS, iters=8), phantom
    )
    with_est = mri.nrmse(
        mri.recon_cg_moco(k, smaps, masks, est, iters=8), phantom
    )
    assert with_est < 1.25 * with_truth + 1e-3, (with_est, with_truth)


def test_moco_shape_validation(phantom, smaps):
    mask, masks, k = _corrupted(phantom, smaps)
    with pytest.raises(ValueError, match="shifts"):
        mri.moco_forward(phantom, smaps, masks, np.zeros((3, 2)))
    with pytest.raises(ValueError, match="shot masks"):
        mri.moco_adjoint(k, smaps, masks[0], SHIFTS)
    with pytest.raises(ValueError, match="ref_shot"):
        mri.estimate_shot_shifts(k, smaps, masks, ref_shot=5)
