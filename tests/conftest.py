"""Shared fixtures. NOTE: no XLA_FLAGS here — unit tests must see 1 device;
multi-device tests spawn subprocesses with their own flags."""

import os

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _scoped_test_precision():
    """Wrap tests in the precision scope named in $REPRO_TEST_PRECISION.

    CI's x64 step sets ``REPRO_TEST_PRECISION=double`` (with
    ``JAX_ENABLE_X64=1``) to re-run the *precision-agnostic* selections —
    the xfft norm matrix, rfftn, and the engine conformance/registry
    suites — through the double-precision engine path. It is NOT a
    whole-suite knob: tests that force single-only engines via
    ``xfft.config(variant=...)`` are correctly rejected inside a double
    scope (an incapable forced variant raises by design), so keep the
    selection to suites that plan through capability. Unset (the
    default), this fixture is a no-op.
    """
    precision = os.environ.get("REPRO_TEST_PRECISION")
    if not precision:
        yield
        return
    import repro.xfft as xfft

    with xfft.config(precision=precision):
        yield


@pytest.fixture(autouse=True)
def _chaos_faults():
    """Wrap tests in the seeded chaos plan named by $REPRO_CHAOS_SEED.

    CI's chaos step sets ``REPRO_CHAOS_SEED`` and re-runs the numerical
    parity suites with low-probability faults injected into the
    *self-healing* seams: engine failures the degradation ladder must
    absorb, and VMEM exhaustions the fused kernels must fail over from.
    Results must stay numerically identical — resilience means the
    answer doesn't change, only the route. Deterministic: the same seed
    replays the same injection schedule. Forced-variant scopes are
    exempt by design (a pin bypasses the ladder). Unset (the default),
    this fixture is a no-op.

    Not a whole-suite knob: suites that assert exact event streams or
    engine choices legitimately observe the injected detours — keep the
    chaos selection to parity tests.
    """
    seed = os.environ.get("REPRO_CHAOS_SEED")
    if not seed:
        yield
        return
    import repro.xfft as xfft
    from repro.resilience import FaultPlan, FaultSpec, reset

    plan = FaultPlan(
        specs=(
            FaultSpec("engine.apply", mode="error", p=0.01),
            FaultSpec("kernel.fused", mode="vmem", p=0.02),
            # latency (not error) on the serve seam: every batched serve
            # execution consults it, so the ServicePolicy deadline/retry
            # envelope is exercised in CI without failing any batch
            FaultSpec("serve.batch", mode="latency", p=0.1, latency_s=0.002),
        ),
        seed=int(seed),
    )
    with xfft.config(faults=plan):
        yield
    reset()  # quarantines must not leak into the next test's planning


def complex_rand(rng, shape):
    return (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)).astype(
        np.complex64
    )
