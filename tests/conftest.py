"""Shared fixtures. NOTE: no XLA_FLAGS here — unit tests must see 1 device;
multi-device tests spawn subprocesses with their own flags."""

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def complex_rand(rng, shape):
    return (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)).astype(
        np.complex64
    )
