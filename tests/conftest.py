"""Shared fixtures. NOTE: no XLA_FLAGS here — unit tests must see 1 device;
multi-device tests spawn subprocesses with their own flags."""

import os

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _scoped_test_precision():
    """Wrap tests in the precision scope named in $REPRO_TEST_PRECISION.

    CI's x64 step sets ``REPRO_TEST_PRECISION=double`` (with
    ``JAX_ENABLE_X64=1``) to re-run the *precision-agnostic* selections —
    the xfft norm matrix, rfftn, and the engine conformance/registry
    suites — through the double-precision engine path. It is NOT a
    whole-suite knob: tests that force single-only engines via
    ``xfft.config(variant=...)`` are correctly rejected inside a double
    scope (an incapable forced variant raises by design), so keep the
    selection to suites that plan through capability. Unset (the
    default), this fixture is a no-op.
    """
    precision = os.environ.get("REPRO_TEST_PRECISION")
    if not precision:
        yield
        return
    import repro.xfft as xfft

    with xfft.config(precision=precision):
        yield


def complex_rand(rng, shape):
    return (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)).astype(
        np.complex64
    )
