"""Elastic restore: a checkpoint written under one mesh restores onto a
different data-axis size (grown/shrunk cluster) with identical values and
the new shardings — subprocess with 8 fake devices."""

import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import tempfile
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.compat import make_mesh
from repro.checkpoint import restore_resharded, save
from repro.configs.registry import smoke_config
from repro.models.build import build
from repro.sharding.rules import param_rules

cfg = smoke_config("llama3.2-3b")
model = build(cfg)
params = model.init(jax.random.PRNGKey(0))

# "cluster A": 8-way data mesh
mesh_a = make_mesh((8, 1), ("data", "model"))
rules = param_rules(cfg, multi_pod=False, model_size=1)
specs = model.specs(rules)
named_a = jax.tree.map(lambda s: NamedSharding(mesh_a, s), specs,
                       is_leaf=lambda x: isinstance(x, P))
params_a = jax.tree.map(jax.device_put, params, named_a)

d = tempfile.mkdtemp()
save(d, 42, params_a)

# "cluster B": shrunk to 2-way data x 4 model
mesh_b = make_mesh((2, 4), ("data", "model"))
named_b = jax.tree.map(lambda s: NamedSharding(mesh_b, s), specs,
                       is_leaf=lambda x: isinstance(x, P))
restored = restore_resharded(d, 42, params, named_b)

same = jax.tree.map(lambda a, b: bool(jnp.all(a == b)), params_a, restored)
assert all(jax.tree.leaves(same)), "values changed across elastic restore"
# and the restored tree really lives on mesh B
leaf = jax.tree.leaves(restored)[0]
assert leaf.sharding.mesh.shape["data"] == 2
print("ELASTIC_OK")
"""


@pytest.mark.slow
def test_elastic_restore_across_meshes():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True,
        cwd=os.path.join(os.path.dirname(__file__), "..", ".."),
        env=env, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    assert "ELASTIC_OK" in out.stdout
