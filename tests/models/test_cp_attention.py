"""Context-parallel attention (§Perf cell B) parity — subprocess (8 devices)."""

import os
import subprocess
import sys

import jax
import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro import compat
from repro.models.attention import flash_attention, flash_attention_cp

mesh = compat.make_mesh((4, 2), ("data", "model"))
rng = np.random.default_rng(0)
B, S, H, KV, D = 4, 64, 6, 2, 16
q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
k = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
v = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
with compat.set_mesh(mesh):
    for kw in ({"causal": True}, {"causal": True, "window": 24},
               {"causal": False}):
        ref = flash_attention(q, k, v, block_q=16, block_k=16, **kw)
        got = jax.jit(lambda q, k, v: flash_attention_cp(
            q, k, v, "model", block_q=16, block_k=16, **kw))(q, k, v)
        err = float(jnp.max(jnp.abs(got - ref)))
        assert err < 1e-5, (kw, err)
    # gradient parity
    g_cp = jax.jit(jax.grad(lambda q: flash_attention_cp(
        q, k, v, "model", causal=True, block_q=16, block_k=16).sum()))(q)
    g_ref = jax.grad(lambda q: flash_attention(
        q, k, v, causal=True, block_q=16, block_k=16).sum())(q)
    assert float(jnp.max(jnp.abs(g_cp - g_ref))) < 1e-4
print("CP_OK")
"""


@pytest.mark.slow
@pytest.mark.skipif(
    not hasattr(jax, "shard_map"),
    reason="partial-manual shard_map (pcast/varying axes) needs jax>=0.5; 0.4.x XLA partitioner aborts",
)
def test_cp_attention_matches_plain():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True,
        cwd=os.path.join(os.path.dirname(__file__), "..", ".."),
        env=env, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    assert "CP_OK" in out.stdout
