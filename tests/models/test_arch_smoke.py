"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + finiteness (assignment deliverable f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest


from repro.configs.registry import ALL_IDS, get_config, smoke_config
from repro.models.build import build


def _smoke_batch(cfg, rng, b=2, s=16):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)}
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.enc_frames, cfg.d_model)), jnp.float32
        )
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_patches, cfg.d_model)), jnp.float32
        )
    if cfg.family == "spectral":
        batch["targets"] = batch["tokens"]
        batch["mlm_mask"] = jnp.ones((b, s), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ALL_IDS)
def test_one_train_step(arch):
    cfg = smoke_config(arch)
    model = build(cfg)
    rng = np.random.default_rng(0)
    params = model.init(jax.random.PRNGKey(0))
    batch = _smoke_batch(cfg, rng)

    loss, metrics = model.loss_fn(params, batch)
    assert np.isfinite(float(loss)), (arch, float(loss))

    grads = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat), f"{arch}: non-finite grads"
    # a second step with updated params still yields a finite loss
    params2 = jax.tree.map(lambda p, g: p - 1e-3 * g, params, grads)
    loss2, _ = model.loss_fn(params2, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", [a for a in ALL_IDS if a != "fourier_lm"])
def test_prefill_then_decode(arch):
    cfg = smoke_config(arch)
    model = build(cfg)
    rng = np.random.default_rng(1)
    params = model.init(jax.random.PRNGKey(1))
    b, s = 2, 8
    batch = _smoke_batch(cfg, rng, b, s)
    caches = model.init_cache_fn(b, 32, jnp.float32)
    logits, caches = model.prefill_fn(params, batch, caches)
    assert logits.shape == (b, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), arch
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, caches = model.decode_fn(params, tok, jnp.asarray(s, jnp.int32), caches)
    assert logits2.shape == (b, cfg.vocab)
    assert bool(jnp.isfinite(logits2).all()), arch


@pytest.mark.parametrize(
    "arch", ["llama3.2-3b", "mixtral-8x22b", "zamba2-2.7b", "xlstm-350m", "whisper-medium"]
)
def test_decode_matches_full_forward(arch):
    """Golden test: prefill+decode logits == full-sequence forward logits."""
    cfg = smoke_config(arch)
    model = build(cfg)
    rng = np.random.default_rng(2)
    params = model.init(jax.random.PRNGKey(2))
    b, s = 2, 8
    batch = _smoke_batch(cfg, rng, b, s + 1)
    full_batch = dict(batch)
    prefix = {k: v for k, v in batch.items() if k != "tokens"}

    # full forward logits at position s-? : loss path gives logits internally;
    # recompute via prefill on the full sequence (cache big enough).
    caches_full = model.init_cache_fn(b, 32, jnp.float32)
    logits_full, _ = model.prefill_fn(params, full_batch, caches_full)

    # prefill on s tokens, then decode token s
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :s]
    caches = model.init_cache_fn(b, 32, jnp.float32)
    _, caches = model.prefill_fn(params, pre, caches)
    logits_dec, _ = model.decode_fn(
        params, batch["tokens"][:, s : s + 1], jnp.asarray(s, jnp.int32), caches
    )
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_full), rtol=2e-3, atol=2e-3
    )


def test_full_configs_have_exact_assignment_numbers():
    cfg = get_config("deepseek-v3-671b")
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads) == (61, 7168, 128)
    assert cfg.moe.n_experts == 256 and cfg.moe.top_k == 8
    assert cfg.mla.kv_lora_rank == 512 and cfg.mtp
    cfg = get_config("mixtral-8x22b")
    assert cfg.moe.n_experts == 8 and cfg.moe.top_k == 2 and cfg.sliding_window == 4096
    cfg = get_config("glm4-9b")
    assert (cfg.n_layers, cfg.d_model, cfg.n_kv_heads, cfg.d_ff) == (40, 4096, 2, 13696)
    cfg = get_config("zamba2-2.7b")
    assert cfg.ssm.d_state == 64 and cfg.n_layers == 54
    cfg = get_config("internvl2-76b")
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads) == (80, 8192, 64, 8)
    cfg = get_config("whisper-medium")
    assert (cfg.d_model, cfg.vocab) == (1024, 51865)
