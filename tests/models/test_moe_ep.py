"""Expert-parallel MoE (shard_map + all_to_all) parity with grouped_local —
forward AND gradients (subprocess: needs 8 fake devices)."""

import os
import subprocess
import sys

import jax
import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro import compat
from repro.models.config import ModelConfig, MoEConfig
from repro.models.param import init_params
from repro.models.moe import moe_skel, moe_apply

mesh = compat.make_mesh((4, 2), ("data", "model"))
cfg_g = ModelConfig(name="t", family="moe", n_layers=1, d_model=32, n_heads=4,
                    n_kv_heads=4, d_ff=64, vocab=100,
                    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32,
                                  n_shared_experts=1,
                                  capacity_factor=8.0, impl="grouped_local"))

p = init_params(moe_skel(cfg_g), jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((8, 16, 32)), jnp.float32)

for ep_axes in (("data",), ("data", "model")):
    cfg_e = dataclasses.replace(cfg_g, moe=dataclasses.replace(
        cfg_g.moe, impl="ep_a2a", ep_axes=ep_axes))
    with compat.set_mesh(mesh):
        yg, _ = jax.jit(lambda p, x: moe_apply(p, x, cfg_g))(p, x)
        ye, _ = jax.jit(lambda p, x: moe_apply(p, x, cfg_e))(p, x)
        err = float(jnp.max(jnp.abs(yg - ye)))
        assert err < 1e-4, (ep_axes, err)

        def loss(p, cfg):
            y, _ = moe_apply(p, x, cfg)
            return jnp.sum(y ** 2)

        gg = jax.jit(jax.grad(lambda p: loss(p, cfg_g)))(p)
        ge = jax.jit(jax.grad(lambda p: loss(p, cfg_e)))(p)
        d = jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(a - b)) / (jnp.max(jnp.abs(a)) + 1e-9)),
            gg, ge)
        assert max(jax.tree.leaves(d)) < 1e-4, (ep_axes, d)
print("MOE_EP_OK")
"""


@pytest.mark.slow
@pytest.mark.skipif(
    not hasattr(jax, "shard_map"),
    reason="partial-manual shard_map (auto axes) needs jax>=0.5; 0.4.x XLA partitioner aborts",
)
def test_ep_a2a_matches_grouped_local():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True,
        cwd=os.path.join(os.path.dirname(__file__), "..", ".."),
        env=env, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    assert "MOE_EP_OK" in out.stdout
