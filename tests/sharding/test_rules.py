"""Sharding rules: divisibility safety + spec structure for every arch."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.registry import ALL_IDS, SHAPES, get_config
from repro.models.build import build
from repro.sharding.rules import batch_specs, cache_specs, dp_axes, param_rules, use_tp


MESH_SIZES = {"pod": 2, "data": 16, "model": 16}


def _axis_product(entry):
    if entry is None:
        return 1
    axes = (entry,) if isinstance(entry, str) else entry
    p = 1
    for a in axes:
        p *= MESH_SIZES[a]
    return p


@pytest.mark.parametrize("arch", ALL_IDS)
@pytest.mark.parametrize("multi_pod", [False, True])
def test_param_specs_divisible(arch, multi_pod):
    """Every sharded param dim must divide by its mesh-axis product."""
    cfg = get_config(arch)
    model = build(cfg)
    rules = param_rules(cfg, multi_pod=multi_pod)
    specs = model.specs(rules)
    sds = model.abstract()

    def check(s, spec):
        for dim, entry in zip(s.shape, tuple(spec)):
            prod = _axis_product(entry)
            assert dim % prod == 0, (arch, s.shape, tuple(spec))

    jax.tree.map(check, sds, specs, is_leaf=lambda x: isinstance(x, P))


@pytest.mark.parametrize("arch", ALL_IDS)
def test_param_specs_no_duplicate_axes(arch):
    cfg = get_config(arch)
    model = build(cfg)
    for multi_pod in (False, True):
        specs = model.specs(param_rules(cfg, multi_pod=multi_pod))

        def check(spec):
            flat = []
            for entry in tuple(spec):
                if entry is None:
                    continue
                flat.extend((entry,) if isinstance(entry, str) else entry)
            assert len(flat) == len(set(flat)), spec

        jax.tree.map(check, specs, is_leaf=lambda x: isinstance(x, P))


@pytest.mark.parametrize("arch", ["llama3.2-3b", "starcoder2-3b", "xlstm-350m"])
def test_non_divisible_heads_use_2d_batch(arch):
    assert not use_tp(get_config(arch))


@pytest.mark.parametrize(
    "arch", ["whisper-medium", "glm4-9b", "deepseek-v3-671b", "mixtral-8x22b",
             "zamba2-2.7b", "internvl2-76b", "stablelm-12b"]
)
def test_divisible_heads_use_tp(arch):
    assert use_tp(get_config(arch))


@pytest.mark.parametrize("arch", [a for a in ALL_IDS if a != "fourier_lm"])
@pytest.mark.parametrize("shape", ["decode_32k", "long_500k"])
def test_cache_specs_divisible(arch, shape):
    cfg = get_config(arch)
    from repro.configs.registry import shape_skips

    if shape_skips(cfg, shape):
        pytest.skip("shape skipped per policy")
    model = build(cfg)
    info = SHAPES[shape]
    caches = jax.eval_shape(
        lambda: model.init_cache_fn(info["batch"], info["seq"], jnp.bfloat16)
    )
    specs = cache_specs(cfg, caches, info["batch"], multi_pod=True)

    def check(s, spec):
        for dim, entry in zip(s.shape, tuple(spec)):
            prod = _axis_product(entry)
            assert dim % prod == 0, (arch, shape, s.shape, tuple(spec))

    jax.tree.map(check, caches, specs, is_leaf=lambda x: isinstance(x, P))


def test_batch_specs_batch1_replicated():
    cfg = get_config("xlstm-350m")
    s = batch_specs(cfg, "decode", multi_pod=True, batch=1)
    assert tuple(s["token"]) == (None, None)
    s128 = batch_specs(cfg, "decode", multi_pod=True, batch=128)
    assert s128["token"][0] == ("pod", "data")


def test_dp_axes():
    assert dp_axes(False) == ("data",)
    assert dp_axes(True) == ("pod", "data")
