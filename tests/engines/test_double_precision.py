"""The acceptance gate for the precision="double" path: all eight xfft
transforms, complex128 end to end, matching numpy's double transforms to
<= 1e-10 through the registered reference_x64 engine — with wisdom keyed
apart from the single-precision world."""

import numpy as np
import pytest

import repro.xfft as xfft
from repro.plan import default_cache, problem_key, reset_default_cache

TOL = 1e-10


@pytest.fixture(autouse=True)
def _fresh_default_cache():
    reset_default_cache()
    yield
    reset_default_cache()


def _close(got, ref):
    got, ref = np.asarray(got), np.asarray(ref)
    scale = max(1.0, np.max(np.abs(ref)))
    assert np.max(np.abs(got - ref)) / scale <= TOL


def _crand(rng, shape):
    return (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)).astype(
        np.complex64
    )


def test_all_eight_transforms_double_match_numpy(rng):
    """fft/ifft/fft2/ifft2/rfft/irfft/rfft2/irfft2 under one double scope."""
    z1 = _crand(rng, (3, 64))
    z2 = _crand(rng, (2, 16, 32))
    x1 = rng.standard_normal((3, 64)).astype(np.float32)
    x2 = rng.standard_normal((2, 16, 32)).astype(np.float32)
    h1 = np.fft.rfft(x1).astype(np.complex64)
    h2 = np.fft.rfft2(x2).astype(np.complex64)
    z1d = z1.astype(np.complex128)
    z2d = z2.astype(np.complex128)
    with xfft.config(precision="double"):
        cases = (
            (xfft.fft(z1), np.fft.fft(z1d), np.complex128),
            (xfft.ifft(z1), np.fft.ifft(z1d), np.complex128),
            (xfft.fft2(z2), np.fft.fft2(z2d), np.complex128),
            (xfft.ifft2(z2), np.fft.ifft2(z2d), np.complex128),
            (xfft.rfft(x1), np.fft.rfft(x1.astype(np.float64)), np.complex128),
            (xfft.irfft(h1), np.fft.irfft(h1.astype(np.complex128)), np.float64),
            (xfft.rfft2(x2), np.fft.rfft2(x2.astype(np.float64)), np.complex128),
            (xfft.irfft2(h2), np.fft.irfft2(h2.astype(np.complex128)), np.float64),
        )
        for got, ref, dtype in cases:
            assert np.asarray(got).dtype == dtype
            _close(got, ref)


@pytest.mark.parametrize("norm", ["backward", "ortho", "forward"])
def test_double_norm_conventions(rng, norm):
    z = _crand(rng, (4, 32))
    zd = z.astype(np.complex128)
    with xfft.config(precision="double"):
        _close(xfft.fft(z, norm=norm), np.fft.fft(zd, norm=norm))
        _close(xfft.ifft(z, norm=norm), np.fft.ifft(zd, norm=norm))


def test_double_resolves_to_reference_x64(rng):
    z = _crand(rng, (16, 16))
    with xfft.config(precision="double"):
        np.asarray(xfft.fft2(z))
    # runtime keys label the TRUE data width under double (complex128)
    plan = default_cache().get(
        problem_key("fft2d", (16, 16), dtype="complex128", precision="double")
    )
    assert plan is not None
    assert plan.variant == "reference_x64"
    assert plan.precision == "double"
    # the single-precision world is untouched: its key is different and
    # still unplanned
    assert default_cache().get(problem_key("fft2d", (16, 16))) is None


def test_double_wisdom_never_serves_single(rng):
    z = _crand(rng, (16, 16))
    with xfft.config(precision="double"):
        np.asarray(xfft.fft2(z))
    with xfft.config(precision="single"):
        got = np.asarray(xfft.fft2(z))  # back in a single scope
        assert got.dtype == np.complex64
        single = default_cache().get(problem_key("fft2d", (16, 16)))
    assert single is not None and single.variant != "reference_x64"


def test_double_scope_restores(rng):
    z = _crand(rng, (4, 16))
    with xfft.config(precision="single"):
        with xfft.config(precision="double"):
            assert np.asarray(xfft.fft(z)).dtype == np.complex128
        assert np.asarray(xfft.fft(z)).dtype == np.complex64
        assert xfft.get_config().precision == "single"


def test_fftn_ifftn_double(rng):
    z = _crand(rng, (4, 8, 16))
    zd = z.astype(np.complex128)
    with xfft.config(precision="double"):
        _close(xfft.fftn(z), np.fft.fftn(zd))
        _close(xfft.ifftn(z), np.fft.ifftn(zd))
    # rfftn in double: real N-D path stays off complex fftn and still doubles
    xr = rng.standard_normal((4, 8, 16)).astype(np.float32)
    with xfft.config(precision="double"):
        got = xfft.rfftn(xr)
        assert np.asarray(got).dtype == np.complex128
        _close(got, np.fft.rfftn(xr.astype(np.float64)))


def test_fftfreq_follows_precision_scope():
    np.testing.assert_allclose(np.asarray(xfft.fftfreq(8)), np.fft.fftfreq(8))
    np.testing.assert_allclose(np.asarray(xfft.rfftfreq(8)), np.fft.rfftfreq(8))
    with xfft.config(precision="single"):
        assert np.asarray(xfft.fftfreq(8)).dtype == np.float32
    with xfft.config(precision="double"):
        f = np.asarray(xfft.fftfreq(12, d=0.5))
        assert f.dtype == np.float64
        np.testing.assert_allclose(f, np.fft.fftfreq(12, d=0.5))
        r = np.asarray(xfft.rfftfreq(12, d=0.5))
        assert r.dtype == np.float64
        np.testing.assert_allclose(r, np.fft.rfftfreq(12, d=0.5))


def test_forced_variant_must_be_capable_of_scope():
    """config() rejects a forced engine that cannot serve the scope's
    precision or backend restriction — no silent complex64 fallback."""
    with pytest.raises(ValueError, match="cannot serve precision"):
        xfft.config(precision="double", variant="stockham")
    with pytest.raises(ValueError, match="cannot serve precision"):
        with xfft.config(precision="single"):
            xfft.config(variant="reference_x64")  # x64 engine is double-only
    with pytest.raises(ValueError, match="outside the scoped backend"):
        xfft.config(backend="jnp", precision="single", variant="fused_r4")
    # the capable combinations are accepted
    with xfft.config(precision="double", variant="reference_x64"):
        assert xfft.get_config().variant == "reference_x64"
    with xfft.config(backend="pallas", precision="single", variant="fused_r4"):
        assert xfft.get_config().backends == ("pallas",)


def test_explicit_double_wisdom_serves_scoped_calls(rng):
    """plan_fft(precision="double") and a scoped xfft call must land on ONE
    cache key — ProblemKey normalizes the dtype label to the true width,
    wherever the key is born (regression: pre-tuned double wisdom used to
    be keyed complex64 and never served)."""
    from repro.plan import PlanCache, plan_fft
    from repro.plan.api import resolve_call

    cache = PlanCache()
    tuned = plan_fft("fft2d", (16, 16), mode="measure", cache=cache,
                     measure_iters=1, precision="double")
    assert tuned.key.dtype == "complex128"  # label normalized at birth
    with xfft.config(precision="double"):
        hit = resolve_call("fft2d", (16, 16), cache=cache)
    assert hit is cache.get(tuned.key) and hit.mode == "measure"


def test_measure_sweep_respects_double_precision(rng):
    """MEASURE on a double key times real 64-bit inputs and yields a
    double plan (regression: sweeps used to feed complex64)."""
    from repro.plan import PlanCache, plan_fft

    timings = {}
    plan = plan_fft("fft1d", (2, 32), mode="measure", cache=PlanCache(),
                    measure_iters=1, timings_out=timings,
                    precision="double")
    assert set(timings) == {"reference_x64"}
    assert plan.variant == "reference_x64"
    assert plan.precision == "double" and plan.mode == "measure"


def test_backend_scope_restricts_planning(rng):
    x = rng.standard_normal((16, 16)).astype(np.float32)
    with xfft.config(backend="jnp", precision="single"):
        got = np.asarray(xfft.rfft2(x))
        key = problem_key("rfft2d", (16, 16), dtype="float32",
                          backends=("jnp",))
        plan = default_cache().get(key)
    np.testing.assert_allclose(got, np.fft.rfft2(x), atol=1e-3)
    assert plan is not None
    assert plan.variant in ("looped", "unrolled", "stockham", "radix4")
    with pytest.raises(ValueError, match="registered backends"):
        xfft.config(backend="cuda_graphs")
