"""Engine conformance: every registered engine, every kind it claims.

Parameterized over the LIVE registry — a third-party registration is
picked up automatically and gets forward/inverse/roundtrip/numpy-parity
coverage for free. Execution goes through ``repro.plan.execute`` on a
hand-built plan, so the conformance path is exactly the planner's
dispatch path.

Tolerances follow the engine's declared precision: single-precision
engines are held to the usual f32 budget, double-precision engines to
1e-10 against numpy's own double transforms.
"""

import numpy as np
import pytest

import jax.numpy as jnp

import repro.engines as engines
from repro.plan import FFTPlan, execute, problem_key

#: Kinds the suite can drive end-to-end. Pencil plans need a live mesh and
#: oaconv2d a (image, kernel) pair; both have dedicated suites elsewhere.
_SHAPES = {
    "fft1d": (3, 64),
    "rfft1d": (3, 64),
    "fft2d": (2, 16, 32),
    "rfft2d": (2, 16, 32),
    "fft2d_stream": (3, 8, 16),
}

_SKIP_KINDS = ("fft2d_pencil", "oaconv2d")


def _cases():
    out = []
    for spec in engines.iter_engines():
        for kind in spec.kinds:
            if kind in _SKIP_KINDS:
                continue
            for direction in ("fwd", "inv"):
                if kind == "fft2d_stream" and direction == "inv":
                    continue  # the streaming processor is forward-only
                out.append(pytest.param(
                    spec.name, kind, direction,
                    id=f"{spec.name}-{kind}-{direction}",
                ))
    return out


def _tolerance(spec) -> float:
    return 1e-10 if "double" in spec.precisions else 2e-3


def _precision_of(spec) -> str:
    return "double" if "double" in spec.precisions else "single"


def _plan_for(spec, kind, direction):
    key = problem_key(
        kind,
        _SHAPES[kind],
        dtype="float32" if kind.startswith("r") else "complex64",
        direction=direction,
        precision=_precision_of(spec),
    )
    return FFTPlan(key=key, variant=spec.name, precision=key.precision)


def _forward_input(kind, rng):
    shape = _SHAPES[kind]
    if kind.startswith("r"):
        return rng.standard_normal(shape).astype(np.float32)
    return (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)).astype(
        np.complex64
    )


def _numpy_reference(kind, direction, x):
    """numpy.fft in double precision (the x64 oracle for every engine)."""
    x = np.asarray(x)
    x64 = x.astype(np.complex128 if np.iscomplexobj(x) else np.float64)
    fwd = {
        "fft1d": np.fft.fft,
        "fft2d": np.fft.fft2,
        "fft2d_stream": np.fft.fft2,
        "rfft1d": np.fft.rfft,
        "rfft2d": np.fft.rfft2,
    }
    inv = {
        "fft1d": np.fft.ifft,
        "fft2d": np.fft.ifft2,
        "rfft1d": np.fft.irfft,
        "rfft2d": np.fft.irfft2,
    }
    return (inv if direction == "inv" else fwd)[kind](x64)


def _inverse_input(kind, rng):
    """What the inverse runner consumes: a half spectrum for real kinds."""
    x = _forward_input(kind, rng)
    if kind == "rfft1d":
        return np.fft.rfft(x).astype(np.complex64)
    if kind == "rfft2d":
        return np.fft.rfft2(x).astype(np.complex64)
    return x


def _assert_close(got, ref, tol):
    got, ref = np.asarray(got), np.asarray(ref)
    scale = max(1.0, np.max(np.abs(ref)))
    np.testing.assert_allclose(got / scale, ref / scale, atol=tol)


@pytest.mark.parametrize("name,kind,direction", _cases())
def test_engine_matches_numpy(name, kind, direction, rng):
    spec = engines.get_engine(name)
    plan = _plan_for(spec, kind, direction)
    x = _inverse_input(kind, rng) if direction == "inv" else _forward_input(kind, rng)
    got = execute(plan, jnp.asarray(x))
    _assert_close(got, _numpy_reference(kind, direction, x), _tolerance(spec))


@pytest.mark.parametrize(
    "name,kind",
    [p for p in [
        pytest.param(s.name, k, id=f"{s.name}-{k}")
        for s in engines.iter_engines()
        for k in s.kinds
        if k in ("fft1d", "fft2d", "rfft1d", "rfft2d")
    ]],
)
def test_engine_roundtrip(name, kind, rng):
    """inverse(forward(x)) == x under one engine — the conformance floor."""
    spec = engines.get_engine(name)
    x = _forward_input(kind, rng)
    fwd = execute(_plan_for(spec, kind, "fwd"), jnp.asarray(x))
    back = execute(_plan_for(spec, kind, "inv"), fwd)
    _assert_close(back, x, _tolerance(spec))


def test_double_engines_emit_double(rng):
    """Every double-capable engine must actually produce 64-bit output."""
    doubles = engines.iter_engines(precision="double")
    assert doubles, "registry lost its double-precision engine"
    for spec in doubles:
        if "fft1d" in spec.kinds:
            x = (rng.standard_normal((2, 32))
                 + 1j * rng.standard_normal((2, 32))).astype(np.complex64)
            y = execute(_plan_for(spec, "fft1d", "fwd"), jnp.asarray(x))
            assert np.asarray(y).dtype == np.complex128
        if "rfft2d" in spec.kinds:
            xr = rng.standard_normal((8, 16)).astype(np.float32)
            y = execute(_plan_for(spec, "rfft2d", "fwd"), jnp.asarray(xr))
            assert np.asarray(y).dtype == np.complex128
