"""The repro.engines registry: registration API, capability filtering,
the registry-derived PLAN_VARIANTS alias, and dynamic error messages."""

import dataclasses

import pytest

import repro.engines as engines
from repro.engines import CostHints, EngineSpec
from repro.plan import FFTPlan, problem_key, variant_candidates

SEED_SINGLE = ("looped", "unrolled", "stockham", "radix4", "fused", "fused_r4")


def test_builtin_engines_registered():
    names = engines.registered_variants()
    for name in SEED_SINGLE + ("reference_x64",):
        assert name in names
        assert engines.has_engine(name)
    assert engines.get_engine("radix4").radix == 4
    assert engines.get_engine("fused").fused
    assert engines.get_engine("fused_r4").radix == 4
    assert not engines.get_engine("stockham").fused
    assert engines.get_engine("looped").cost.entry_overhead_s > 0


def test_backend_families():
    assert engines.get_engine("looped").backend == "jnp"
    assert engines.get_engine("fused").backend == "pallas"
    assert engines.get_engine("reference_x64").backend == "x64"
    assert set(engines.registered_backends()) >= {"jnp", "pallas", "x64"}


def test_precision_capabilities():
    for name in SEED_SINGLE:
        assert engines.get_engine(name).precisions == ("single",)
    assert engines.get_engine("reference_x64").precisions == ("double",)


def test_register_rejects_duplicates_and_bad_specs():
    with pytest.raises(ValueError, match="unknown kind"):
        engines.register_engine(
            EngineSpec(name="toy_badkind", backend="jnp", kinds=("fft9d",))
        )
    with pytest.raises(ValueError, match="unknown precision"):
        engines.register_engine(
            EngineSpec(name="toy_badprec", backend="jnp", kinds=("fft1d",),
                       precisions=("half",))
        )
    assert not engines.has_engine("toy_badkind")
    toy = EngineSpec(name="toy_dup", backend="jnp", kinds=("fft1d",))
    engines.register_engine(toy)
    try:
        with pytest.raises(ValueError, match="already registered"):
            engines.register_engine(toy)
        # replace=True is the plugin-iteration escape hatch (non-builtins)
        engines.register_engine(
            EngineSpec(name="toy_dup", backend="jnp", kinds=("fft2d",)),
            replace=True,
        )
        assert engines.get_engine("toy_dup").kinds == ("fft2d",)
    finally:
        engines.unregister_engine("toy_dup")


def test_builtin_engines_cannot_be_replaced_or_removed():
    """The six seed bodies are fused into the core dispatch chains — a
    registry override would never execute, so registration refuses rather
    than lying (register under a new name instead)."""
    spec = EngineSpec(name="stockham", backend="jnp", kinds=("fft1d",))
    with pytest.raises(ValueError, match="cannot be replaced"):
        engines.register_engine(spec)
    with pytest.raises(ValueError, match="cannot be replaced"):
        engines.register_engine(spec, replace=True)
    with pytest.raises(ValueError, match="cannot be unregistered"):
        engines.unregister_engine("fused_r4")
    assert engines.has_engine("stockham") and engines.has_engine("fused_r4")


def test_decorator_registration_and_teardown():
    @engines.engine("toy_passthrough", backend="jnp", kinds=("fft1d",),
                    cost=CostHints(traffic_factor=1.0))
    def toy_ops(kind, direction):
        return lambda x: x

    try:
        assert isinstance(toy_ops, EngineSpec)  # decorator returns the spec
        assert engines.has_engine("toy_passthrough")
        # immediately a planner candidate for its kind...
        assert "toy_passthrough" in variant_candidates(problem_key("fft1d", (4, 16)))
        # ...and absent for kinds it did not declare
        assert "toy_passthrough" not in variant_candidates(
            problem_key("fft2d", (16, 16))
        )
        # its executor is reachable through the generic apply path
        assert engines.apply_engine("toy_passthrough", "fft1d", 7) == 7
    finally:
        engines.unregister_engine("toy_passthrough")
    assert not engines.has_engine("toy_passthrough")


def test_candidates_filter_by_precision():
    assert variant_candidates(problem_key("fft2d", (32, 32), precision="double")) \
        == ("reference_x64",)
    single = variant_candidates(problem_key("fft2d", (32, 32)))
    assert "reference_x64" not in single
    assert set(single) == set(SEED_SINGLE)


def test_candidates_filter_by_backend_scope():
    key = problem_key("fft2d", (32, 32), backends=("pallas",))
    assert set(variant_candidates(key)) == {"fused", "fused_r4"}
    key = problem_key("fft2d", (32, 32), backends=("jnp",))
    assert set(variant_candidates(key)) == {"looped", "unrolled", "stockham",
                                            "radix4"}


def test_unsatisfiable_capability_errors_name_registry():
    # no double-capable engine serves the pencil kind
    key = problem_key("fft2d_pencil", (64, 32), n_devices=8, precision="double")
    with pytest.raises(ValueError, match="reference_x64"):
        variant_candidates(key)


def test_vmem_working_set_gates_fused():
    spec = engines.get_engine("fused")
    small = problem_key("fft1d", (4, 128))
    huge = problem_key("fft1d", (4, 1 << 20))
    assert spec.supports(small)
    assert not spec.supports(huge)  # no row tile fits VMEM
    from repro.kernels.ops import vmem_budget_bytes

    assert spec.working_set(small) <= vmem_budget_bytes()
    assert spec.working_set(huge) > vmem_budget_bytes()


def test_plan_validation_error_is_dynamic():
    key = problem_key("fft2d", (16, 16))
    with pytest.raises(ValueError) as ei:
        FFTPlan(key=key, variant="definitely_not_an_engine")
    # the message names the live registry, not a stale tuple
    assert "reference_x64" in str(ei.value)
    assert "registered engines" in str(ei.value)


def test_plan_variants_alias_tracks_registry():
    from repro.plan import PLAN_VARIANTS

    assert PLAN_VARIANTS == engines.registered_variants(precision="single")
    assert "reference_x64" not in PLAN_VARIANTS

    @engines.engine("toy_alias_probe", backend="jnp", kinds=("fft1d",))
    def toy_ops(kind, direction):
        return lambda x: x

    try:
        from repro.plan import PLAN_VARIANTS as live

        assert "toy_alias_probe" in live  # the alias is derived, not frozen
    finally:
        engines.unregister_engine("toy_alias_probe")


def test_cache_keys_gain_precision_and_backend_scope():
    base = problem_key("fft2d", (64, 64))
    assert base.cache_key() != problem_key(
        "fft2d", (64, 64), precision="double"
    ).cache_key()
    assert base.cache_key() != problem_key(
        "fft2d", (64, 64), backends=("jnp",)
    ).cache_key()
    # backend scopes are canonicalized: order/duplicates never split keys
    assert problem_key("fft2d", (64, 64), backends=("pallas", "jnp")).cache_key() \
        == problem_key("fft2d", (64, 64), backends=("jnp", "pallas", "jnp")).cache_key()


def test_specs_are_frozen():
    spec = engines.get_engine("stockham")
    with pytest.raises(dataclasses.FrozenInstanceError):
        spec.name = "renamed"
