"""Latency histograms: geometry, percentile accuracy, merging, registry."""

import threading

import numpy as np
import pytest

from repro.obs.hist import (
    LatencyHistogram,
    histogram,
    histograms,
    reset_histograms,
)


@pytest.fixture(autouse=True)
def _clean_registry():
    reset_histograms()
    yield
    reset_histograms()


def test_bucket_index_monotone_and_bounded():
    h = LatencyHistogram()
    values = [0.0, 0.5, 1.0, 1.5, 10.0, 1e3, 1e6, 1e9, 1e15]
    indices = [h.bucket_index(v) for v in values]
    assert indices == sorted(indices)
    assert indices[0] == 0
    assert all(0 <= i < h.buckets for i in indices)
    # the far tail lands in the catch-all last cell, never out of range
    assert h.bucket_index(1e15) == h.buckets - 1


def test_bucket_bound_contains_its_values():
    h = LatencyHistogram()
    for v in [1.7, 23.0, 456.0, 9876.0]:
        i = h.bucket_index(v)
        assert v <= h.bucket_bound(i)
        if i > 0:
            assert v > h.bucket_bound(i - 1)


def test_percentile_within_bucket_resolution_of_raw():
    rng = np.random.default_rng(7)
    values = rng.lognormal(mean=5.0, sigma=1.2, size=4000)
    h = LatencyHistogram()
    for v in values:
        h.record(float(v))
    for p in (50, 95, 99):
        raw = float(np.percentile(values, p))
        est = h.percentile(p)
        # exact within bucket resolution: same cell or the neighbour
        # (numpy interpolates between the straddling order statistics)
        assert abs(h.bucket_index(est) - h.bucket_index(raw)) <= 1
        # ...which bounds the relative error by one growth factor
        assert est / raw <= h.growth * 1.0001
        assert raw / est <= h.growth * 1.0001


def test_empty_and_single_sample():
    h = LatencyHistogram()
    assert h.percentile(99) == 0.0
    assert h.count == 0 and h.mean_us() == 0.0
    h.record(42.0)
    assert h.count == 1
    assert h.bucket_index(h.percentile(50)) == h.bucket_index(42.0)


def test_merge_equals_recording_everything():
    rng = np.random.default_rng(3)
    a_vals = rng.lognormal(4.0, 1.0, size=500)
    b_vals = rng.lognormal(6.0, 0.5, size=700)
    a, b, both = LatencyHistogram(), LatencyHistogram(), LatencyHistogram()
    for v in a_vals:
        a.record(float(v))
        both.record(float(v))
    for v in b_vals:
        b.record(float(v))
        both.record(float(v))
    a.merge(b)
    assert a.count == both.count == 1200
    assert a.cells() == both.cells()
    for p in (50, 95, 99):
        assert a.percentile(p) == both.percentile(p)
    assert a.max_us == both.max_us


def test_merge_rejects_different_geometry():
    with pytest.raises(ValueError):
        LatencyHistogram(min_us=1.0).merge(LatencyHistogram(min_us=2.0))


def test_bad_geometry_rejected():
    with pytest.raises(ValueError):
        LatencyHistogram(min_us=0.0)
    with pytest.raises(ValueError):
        LatencyHistogram(growth=1.0)
    with pytest.raises(ValueError):
        LatencyHistogram(buckets=1)


def test_memory_is_bounded_by_geometry():
    h = LatencyHistogram(buckets=32)
    for i in range(10_000):
        h.record(float(i % 997))
    assert len(h.cells()) == 32
    assert h.count == 10_000


def test_concurrent_recording_loses_nothing():
    h = LatencyHistogram()
    per_thread, n_threads = 2000, 8

    def work():
        for i in range(per_thread):
            h.record(float(1 + i % 100))

    threads = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert h.count == per_thread * n_threads
    assert sum(h.cells()) == per_thread * n_threads


def test_registry_shares_instances_and_resets():
    a = histogram("serve.lane.test.x")
    b = histogram("serve.lane.test.x")
    assert a is b
    a.record(10.0)
    assert histograms()["serve.lane.test.x"].count == 1
    assert histograms(prefix="serve.lane.") == {"serve.lane.test.x": a}
    assert histograms(prefix="engine.") == {}
    reset_histograms()
    assert histogram("serve.lane.test.x") is not a


def test_to_dict_summary_fields():
    h = LatencyHistogram()
    for v in (10.0, 20.0, 30.0):
        h.record(v)
    d = h.to_dict()
    assert d["count"] == 3
    assert d["p50_us"] >= 10.0 and d["p99_us"] >= d["p50_us"]
    assert d["max_us"] == 30.0
