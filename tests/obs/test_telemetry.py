"""Flight recorder + calibration ledger: always-on black-box behaviour."""

import json
import threading
import time

import pytest

import repro.xfft as xfft
from repro import obs
from repro.obs import telemetry
from repro.obs.telemetry import CalibrationLedger, FlightRecorder


@pytest.fixture
def recorder(tmp_path):
    """A small fresh recorder installed for the test, previous restored."""
    rec = FlightRecorder(capacity=64, dump_dir=str(tmp_path / "flight"))
    prev = telemetry.set_flight_recorder(rec)
    yield rec
    telemetry.set_flight_recorder(prev)


def test_default_recorder_installed_and_always_on():
    rec = obs.flight_recorder()
    assert rec is not None
    before = rec.stats()["recorded_total"]
    # no capture scope open anywhere — the ring still records
    assert obs.emit("telemetry.unit.noscope", x=1) is None
    assert rec.stats()["recorded_total"] == before + 1
    assert any(e.name == "telemetry.unit.noscope" for e in rec.events())


def test_ring_is_bounded_and_keeps_most_recent(recorder):
    for i in range(200):
        obs.emit("telemetry.unit.flood", i=i)
    events = recorder.events()
    assert len(events) == 64
    assert [e["i"] for e in events] == list(range(136, 200))
    assert recorder.stats()["recorded_total"] == 200


def test_trigger_dumps_jsonl_with_trigger_event_last(recorder):
    for i in range(10):
        obs.emit("telemetry.unit.lead", i=i)
    obs.emit("serve.lane.error", service="spectrum", lane="x", error="boom")
    stats = recorder.stats()
    assert len(stats["dumps"]) == 1
    dump = stats["dumps"][0]
    assert dump["trigger"] == "serve.lane.error"
    lines = [json.loads(line) for line in open(dump["path"])]
    assert lines[-1]["name"] == "serve.lane.error"
    assert lines[-1]["fields"]["error"] == "boom"
    assert [ln["name"] for ln in lines[:-1]][-10:] == ["telemetry.unit.lead"] * 10
    # every line carries the emitting thread id
    assert all(isinstance(ln["tid"], int) for ln in lines)


def test_breaker_trigger_only_fires_on_open(recorder):
    obs.emit("resilience.breaker", state="half_open", engine="e")
    obs.emit("resilience.breaker", state="closed", engine="e")
    assert recorder.stats()["dumps"] == []
    obs.emit("resilience.breaker", state="open", engine="e")
    assert len(recorder.stats()["dumps"]) == 1


def test_shed_and_failover_triggers_fire(recorder):
    obs.emit("serve.shed", service="s", depth=9)
    obs.emit("resilience.failover", engine="e", next="f")
    triggers = [d["trigger"] for d in recorder.stats()["dumps"]]
    assert triggers == ["serve.shed", "resilience.failover"]


def test_max_dumps_caps_files_and_counts_drops(tmp_path):
    rec = FlightRecorder(capacity=16, dump_dir=str(tmp_path), max_dumps=2)
    prev = telemetry.set_flight_recorder(rec)
    try:
        for _ in range(5):
            obs.emit("serve.shed", service="s")
    finally:
        telemetry.set_flight_recorder(prev)
    stats = rec.stats()
    assert len(stats["dumps"]) == 2
    assert stats["dropped_dumps"] == 3


def test_manual_dump_to_explicit_path(recorder, tmp_path):
    obs.emit("telemetry.unit.manual", a=1)
    path = recorder.dump(str(tmp_path / "manual.jsonl"))
    lines = [json.loads(line) for line in open(path)]
    assert lines[-1]["name"] == "telemetry.unit.manual"
    assert recorder.stats()["dumps"][-1]["trigger"] == "manual"


def test_emit_return_contract_unchanged_with_recorder_on(recorder):
    # sinks must not make scope-less emit() look observed
    assert obs.emit("telemetry.unit.ret") is None
    with obs.capture():
        assert obs.emit("telemetry.unit.ret") is not None


def test_config_flight_recorder_scoping(tmp_path):
    outer = obs.flight_recorder()
    mine = FlightRecorder(capacity=8, dump_dir=str(tmp_path))
    with xfft.config(flight_recorder=mine):
        assert obs.flight_recorder() is mine
        obs.emit("telemetry.unit.scoped")
        assert any(e.name == "telemetry.unit.scoped" for e in mine.events())
        with xfft.config(flight_recorder=False):
            assert obs.flight_recorder() is None
            obs.emit("telemetry.unit.off")
        assert obs.flight_recorder() is mine
        assert not any(e.name == "telemetry.unit.off" for e in mine.events())
    assert obs.flight_recorder() is outer


def test_config_flight_recorder_capacity_and_validation():
    with xfft.config(flight_recorder=32):
        assert obs.flight_recorder().capacity == 32
    with xfft.config(flight_recorder=True):
        assert obs.flight_recorder().capacity == 4096
    with pytest.raises(ValueError):
        xfft.config(flight_recorder="yes")


def test_recorder_records_across_threads_capture_stays_isolated(recorder):
    done = threading.Event()

    def worker():
        obs.emit("telemetry.unit.worker", who="bg")
        done.set()

    with obs.capture() as trace:
        obs.emit("telemetry.unit.caller", who="main")
        threading.Thread(target=worker).start()
        assert done.wait(5.0)
    names = [e.name for e in trace]
    assert "telemetry.unit.caller" in names
    assert "telemetry.unit.worker" not in names  # thread isolation holds
    ring = [e.name for e in recorder.events()]
    assert "telemetry.unit.worker" in ring and "telemetry.unit.caller" in ring
    tids = {e.tid for e in recorder.events()}
    assert len(tids) >= 2
    assert set(recorder.thread_names()) >= tids


# ------------------------------ ledger ------------------------------


def _resolve_event(**over):
    fields = dict(
        variant="eng_a", kind="fft2d", shape=(64, 64), precision="single",
        est_time_s=100e-6, measured_us=None, outcome="miss",
    )
    fields.update(over)
    obs.emit("plan.resolve", **fields)


def test_ledger_joins_estimate_against_observed():
    ledger = obs.calibration_ledger()
    ledger.reset()
    _resolve_event()
    for _ in range(4):
        obs.emit(
            "engine.apply", engine="eng_a", kind="fft2d", shape=(64, 64),
            precision="single", ok=True, duration_us=200.0,
        )
    (row,) = [r for r in ledger.table() if r["engine"] == "eng_a"]
    assert row["predicted_us"] == 100.0
    assert row["predicted_source"] == "estimate"
    assert row["observed_n"] == 4
    # both sides are independently rounded for display, so compare loosely
    assert row["ratio"] == pytest.approx(
        row["observed_p50_us"] / 100.0, rel=1e-2
    )
    assert row["ratio"] > 1.0  # planner optimistic here, by construction


def test_ledger_prefers_measured_prediction():
    ledger = obs.calibration_ledger()
    ledger.reset()
    _resolve_event(measured_us=150.0, outcome="measured")
    obs.emit(
        "engine.apply", engine="eng_a", kind="fft2d", shape=(64, 64),
        precision="single", ok=True, duration_us=150.0,
    )
    (row,) = ledger.table()
    assert row["predicted_us"] == 150.0
    assert row["predicted_source"] == "measure"


def test_ledger_candidate_events_cover_losing_engines():
    ledger = obs.calibration_ledger()
    ledger.reset()
    obs.emit(
        "plan.measure.candidate", engine="eng_b", unroll=1, label="eng_b",
        kind="rfft2d", shape=(128, 128), precision="single", median_us=300.0,
    )
    (row,) = ledger.table()
    assert row["engine"] == "eng_b" and row["predicted_us"] == 300.0
    assert row["observed_n"] == 0 and row["ratio"] is None


def test_ledger_skips_failed_dispatches():
    ledger = obs.calibration_ledger()
    ledger.reset()
    _resolve_event()
    obs.emit(  # no ok=True: the engine raised mid-span
        "engine.apply", engine="eng_a", kind="fft2d", shape=(64, 64),
        precision="single", duration_us=5.0,
    )
    (row,) = ledger.table()
    assert row["observed_n"] == 0


def test_ledger_feeds_per_engine_histograms():
    obs.reset_histograms()
    ledger = obs.calibration_ledger()
    ledger.reset()
    obs.emit(
        "engine.apply", engine="eng_c", kind="fft2d", shape=(8, 8),
        precision="single", ok=True, duration_us=77.0,
    )
    assert obs.histograms()["engine.eng_c"].count == 1
    obs.reset_histograms()


def test_ledger_end_to_end_through_transforms(rng):
    import numpy as np

    ledger = obs.calibration_ledger()
    ledger.reset()
    x = (rng.standard_normal((16, 16))
         + 1j * rng.standard_normal((16, 16))).astype(np.complex64)
    for _ in range(3):
        np.asarray(xfft.fft2(x))
    rows = [r for r in ledger.table() if r["kind"] == "fft2d"
            and r["observed_n"] > 0]
    assert rows, "real transform dispatch must land observed samples"
    assert all(r["ratio"] is not None for r in rows)


def test_report_renders_telemetry_sections(rng):
    import numpy as np

    x = (rng.standard_normal((16, 16))
         + 1j * rng.standard_normal((16, 16))).astype(np.complex64)
    np.asarray(xfft.fft2(x))
    data = xfft.report_data()
    assert data["telemetry"]["flight_recorder"]["capacity"] >= 1
    assert isinstance(data["telemetry"]["calibration"], list)
    text = xfft.report()
    assert "flight recorder:" in text
    assert "planner calibration" in text


def test_sink_errors_never_break_emit():
    calls = []

    def bad_sink(event):
        calls.append(event.name)
        raise RuntimeError("sink exploded")

    obs.add_sink(bad_sink)
    try:
        before = obs.counters().get("obs.sink.error", 0)
        assert obs.emit("telemetry.unit.badsink") is None  # no raise
        assert obs.counters()["obs.sink.error"] == before + 1
        assert calls == ["telemetry.unit.badsink"]
    finally:
        obs.remove_sink(bad_sink)


def test_span_fires_sinks_without_capture_scope(recorder):
    with obs.span("telemetry.unit.region", tag=1):
        time.sleep(0.001)
    (ev,) = [e for e in recorder.events()
             if e.name == "telemetry.unit.region"]
    assert ev["duration_us"] > 0 and ev["tag"] == 1
