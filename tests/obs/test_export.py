"""Exporters: JSONL round-trip, Chrome trace structure, Prometheus text."""

import json

from repro.obs.export import (
    chrome_trace,
    event_dict,
    prometheus_text,
    write_chrome_trace,
    write_jsonl,
    write_prometheus,
)
from repro.obs.hist import LatencyHistogram
from repro.obs.record import Event


def _ev(name, t=1.0, tid=11, **fields):
    return Event(name=name, t=t, fields=fields, tid=tid)


def test_jsonl_round_trip(tmp_path):
    events = [
        _ev("plan.resolve", t=1.0, outcome="hit", shape=(64, 64)),
        _ev("engine.apply", t=2.0, duration_us=120.5, ok=True),
    ]
    path = write_jsonl(events, str(tmp_path / "events.jsonl"))
    lines = [json.loads(line) for line in open(path)]
    assert [ln["name"] for ln in lines] == ["plan.resolve", "engine.apply"]
    assert lines[0]["fields"]["shape"] == [64, 64]
    assert lines[1]["fields"]["duration_us"] == 120.5
    assert all(ln["tid"] == 11 for ln in lines)


def test_event_dict_survives_exotic_field_values():
    class Weird:
        def __repr__(self):
            return "<weird>"

    d = event_dict(_ev("x", obj=Weird(), nested={"a": (1, Weird())}, none=None))
    json.dumps(d)  # must be serialisable no matter what rode the event
    assert d["fields"]["obj"] == "<weird>"
    assert d["fields"]["nested"]["a"] == [1, "<weird>"]
    assert d["fields"]["none"] is None


def test_chrome_trace_spans_and_instants():
    events = [
        _ev("engine.apply", t=2.0, tid=5, duration_us=1000.0, engine="e"),
        _ev("plan.resolve", t=1.0, tid=5, outcome="hit"),
    ]
    doc = chrome_trace(events, thread_names={5: "serve-loop[spectrum]"})
    spans = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    instants = [e for e in doc["traceEvents"] if e.get("ph") == "i"]
    meta = [e for e in doc["traceEvents"] if e.get("ph") == "M"]
    (span,) = spans
    # emission happens at span EXIT: ts is start = t*1e6 - duration
    assert span["ts"] == 2.0 * 1e6 - 1000.0
    assert span["dur"] == 1000.0 and span["tid"] == 5
    (inst,) = instants
    assert inst["ts"] == 1.0 * 1e6
    (m,) = meta
    assert m["name"] == "thread_name"
    assert m["args"]["name"] == "serve-loop[spectrum]"


def test_chrome_trace_labels_unknown_threads(tmp_path):
    doc = chrome_trace([_ev("a", tid=999)])
    (m,) = [e for e in doc["traceEvents"] if e.get("ph") == "M"]
    assert m["args"]["name"] == "thread-999"
    path = write_chrome_trace([_ev("a", tid=999)], str(tmp_path / "t.json"))
    loaded = json.load(open(path))
    assert loaded["traceEvents"]


def test_prometheus_counters_gauges_and_quantiles(tmp_path):
    h = LatencyHistogram()
    for v in (10.0, 100.0, 1000.0):
        h.record(v)
    text = prometheus_text(
        counters={"plan.resolve.hit": 3},
        gauges={"flight_recorder_retained": 42},
        histograms={"serve.lane.spectrum.x": h},
    )
    assert '# TYPE repro_events_total counter' in text
    assert 'repro_events_total{event="plan.resolve.hit"} 3' in text
    assert 'repro_gauge{name="flight_recorder_retained"} 42.0' in text
    assert 'quantile="0.50"' in text and 'quantile="0.99"' in text
    assert 'repro_latency_us_count{hist="serve.lane.spectrum.x"} 3' in text
    assert text.endswith("\n")
    path = write_prometheus(
        str(tmp_path / "metrics.prom"), counters={"a": 1}
    )
    assert 'repro_events_total{event="a"} 1' in open(path).read()


def test_prometheus_escapes_label_values():
    text = prometheus_text(counters={'weird"name\\x': 1})
    assert 'event="weird\\"name\\\\x"' in text


def test_prometheus_empty_is_empty():
    assert prometheus_text() == ""
