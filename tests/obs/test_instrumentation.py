"""Every decision point actually emits: planner, cache, kernels, serving.

The acceptance contract of the obs layer: wrapping ``obs.capture()``
around a cold-then-warm pair of identical ``xfft.fft2`` calls yields an
event stream showing exactly one plan miss followed by one plan hit, with
zero MEASURE work on the second call.
"""

import json

import jax
import numpy as np
import pytest

import repro.xfft as xfft
from repro import obs
from repro.plan import (
    PlanCache,
    default_cache,
    estimate_plan,
    problem_key,
    reset_default_cache,
)
from repro.plan.api import resolve_call


@pytest.fixture(autouse=True)
def _fresh_state():
    reset_default_cache()
    obs.reset_counters()
    yield
    reset_default_cache()
    obs.reset_counters()


def _frame(rng, n=16, complex_=True):
    x = rng.standard_normal((n, n)).astype(np.float32)
    if complex_:
        x = (x + 1j * rng.standard_normal((n, n))).astype(np.complex64)
    return x


# ------------------------------ planner ------------------------------


def test_cold_then_warm_is_miss_then_hit_with_no_measure_work(rng):
    """The ISSUE acceptance criterion, verbatim."""
    x = _frame(rng)
    with obs.capture() as trace:
        np.asarray(xfft.fft2(x))
        np.asarray(xfft.fft2(x))
    resolves = trace.select("plan.resolve")
    assert [e["outcome"] for e in resolves] == ["miss", "hit"]
    assert trace.select("plan.measure") == []
    # both calls resolved the SAME problem to the SAME engine
    assert resolves[0]["key"] == resolves[1]["key"]
    assert resolves[0]["variant"] == resolves[1]["variant"]
    assert obs.counters()["plan.resolve.miss"] == 1
    assert obs.counters()["plan.resolve.hit"] == 1


def test_measure_sweep_emits_candidates_then_hits(tmp_path, rng):
    x = _frame(rng)
    with xfft.config(cache_dir=str(tmp_path), mode="measure"):
        with obs.capture() as cold:
            np.asarray(xfft.fft2(x))
        with obs.capture() as warm:
            np.asarray(xfft.fft2(x))
    assert cold.first("plan.resolve")["outcome"] == "measured"
    (sweep,) = cold.select("plan.measure")
    assert sweep["candidates"] >= 2
    assert sweep["chosen"] == cold.first("plan.resolve")["variant"]
    assert sweep["chosen_us"] > 0
    assert set(sweep["timings"]) >= {sweep["chosen"]}
    assert warm.first("plan.resolve")["outcome"] == "hit"
    assert warm.select("plan.measure") == []


def test_degrade_estimate_only_kind_recorded_on_event_and_plan():
    with obs.capture() as trace:
        plan = resolve_call("oaconv2d", (32, 32, 4, 4), dtype="float32",
                            cache=PlanCache(), mode="measure")
    (degrade,) = trace.select("plan.degrade")
    assert degrade["reason"] == "estimate_only_kind"
    assert plan.degrade_reason == "estimate_only_kind"
    assert plan.mode == "estimate"
    assert obs.counters()["plan.degrade.estimate_only_kind"] == 1
    # the reason survives the wisdom-file round trip
    rt = type(plan).from_dict(json.loads(json.dumps(plan.to_dict())))
    assert rt.degrade_reason == "estimate_only_kind"


def test_degrade_trace_not_clean_inside_jit(tmp_path, rng):
    x = _frame(rng, n=32)

    @jax.jit
    def f(v):
        return xfft.fft2(v)

    with xfft.config(cache_dir=str(tmp_path), mode="measure"):
        with obs.capture() as trace:
            jax.block_until_ready(f(x))
    (degrade,) = trace.select("plan.degrade")
    assert degrade["reason"] == "trace_not_clean"
    assert trace.first("plan.resolve")["degrade_reason"] == "trace_not_clean"
    assert trace.select("plan.measure") == []      # no jit inside the trace


def test_degrade_forced_variant_and_forced_outcome():
    # "looped" is the paper-faithful baseline no estimator would pick, so
    # the pin genuinely replaces the planned schedule.
    with xfft.config(variant="looped", mode="measure"):
        with obs.capture() as trace:
            plan = resolve_call("fft2d", (16, 16), cache=PlanCache())
    ev = trace.first("plan.resolve")
    assert ev["outcome"] == "forced"
    assert ev["variant"] == "looped"
    assert trace.first("plan.degrade")["reason"] == "forced_variant"
    assert plan.variant == "looped"
    assert plan.mode == "forced" and plan.degrade_reason == "forced_variant"
    assert obs.counters()["plan.resolve.forced"] == 1


# ------------------------------ cache ------------------------------


def _saved_wisdom(tmp_path):
    """A wisdom file holding one good entry; returns (path, good_key)."""
    cache = PlanCache(path=str(tmp_path / "xfft_plans.json"))
    cache.put(estimate_plan(problem_key("fft2d", (16, 16))))
    cache.save()
    (good_key, _plan) = cache.entries()[0]
    return cache.path, good_key


def test_load_report_accounts_for_every_dropped_entry(tmp_path):
    path, good_key = _saved_wisdom(tmp_path)
    payload = json.load(open(path))
    good = payload["plans"][good_key]
    payload["plans"]["v1|" + good_key.split("|", 1)[1]] = good  # stale schema
    payload["plans"][good_key + "|tampered"] = good             # key mismatch
    payload["plans"][good_key.replace("16x16", "8x8")] = {}     # malformed
    json.dump(payload, open(path, "w"))

    with obs.capture() as trace:
        loaded = PlanCache(path=path)
    report = loaded.load_report
    assert (report.kept, report.stale_schema, report.malformed,
            report.key_mismatch) == (1, 1, 1, 1)
    assert report.dropped == 3 and report.file_error is None
    assert len(loaded) == 1
    ev = trace.first("plan.cache.load")
    assert ev["kept"] == 1 and ev["stale_schema"] == 1
    counters = obs.counters()
    assert counters["plan.cache.load.kept"] == 1
    assert counters["plan.cache.load.malformed"] == 1
    assert counters["plan.cache.load.key_mismatch"] == 1
    assert counters["plan.cache.load.stale_schema"] == 1
    # ...and the human report renders the same accounting
    text = xfft.report(cache=loaded)
    assert "kept=1 stale_schema=1 malformed=1 key_mismatch=1" in text


def test_load_report_file_error(tmp_path):
    path = str(tmp_path / "xfft_plans.json")
    with open(path, "w") as f:
        f.write("not json{")
    loaded = PlanCache(path=path)
    assert loaded.load_report.file_error is not None
    assert loaded.load_report.kept == 0
    assert obs.counters()["plan.cache.load.file_error"] == 1


def test_default_cache_emits_attached_event(tmp_path, monkeypatch):
    path, _ = _saved_wisdom(tmp_path)
    monkeypatch.setenv("REPRO_PLAN_CACHE", path)
    reset_default_cache()
    with obs.capture() as trace:
        cache = default_cache()
        default_cache()                            # second touch: no re-emit
    (ev,) = trace.select("plan.cache.attached")
    assert ev["path"] == path and ev["entries"] == 1
    assert ev["source"] == "REPRO_PLAN_CACHE"
    assert len(cache) == 1


def test_default_cache_attached_memory_only(monkeypatch):
    monkeypatch.delenv("REPRO_PLAN_CACHE", raising=False)
    reset_default_cache()
    with obs.capture() as trace:
        default_cache()
    (ev,) = trace.select("plan.cache.attached")
    assert ev["path"] is None and ev["source"] == "memory"


def test_report_renders_live_entries_and_counters(rng):
    x = _frame(rng)
    np.asarray(xfft.fft2(x))
    np.asarray(xfft.fft2(x))
    text = xfft.report()
    assert "fft2d fwd 16x16 complex64" in text
    assert "hits=1" in text
    assert "plan.resolve.hit" in text              # counters section
    data = xfft.report_data()
    (entry,) = data["cache"]["entries"]
    assert entry["kind"] == "fft2d" and entry["hits"] == 1


# ------------------------------ kernels ------------------------------


def test_forced_fused_call_over_budget_emits_failover(rng, monkeypatch):
    """A forced fused call on a frame the VMEM census rejects silently
    takes the unfused row/turn/column path — the event is the only
    evidence the fused kernel did NOT run."""
    import repro.kernels.ops as ops

    monkeypatch.setattr(ops, "fft2_fits_vmem", lambda *a, **k: False)
    x = _frame(rng, n=8)
    with xfft.config(variant="fused"):
        with obs.capture() as trace:
            got = np.asarray(xfft.fft2(x))
    (ev,) = trace.select("kernel.failover")
    assert ev["kind"] == "fft2d"
    assert ev["shape"] == (8, 8)
    assert ev["budget"] > 0 and ev["working_set"] > 0
    # the unfused failover path still computes the right answer
    np.testing.assert_allclose(got, np.fft.fft2(x), rtol=2e-4, atol=2e-4)


# ------------------------------ serving ------------------------------


def test_spectrum_service_emits_queue_and_batch_events(rng):
    from repro.serve import SpectrumRequest, SpectrumService

    reqs = [
        SpectrumRequest(frame=rng.standard_normal((8, 8)).astype(np.float32))
        for _ in range(3)
    ] + [SpectrumRequest(frame=_frame(rng, n=8))]
    with obs.capture() as trace:
        SpectrumService().serve(reqs)
    q = trace.first("serve.queue")
    assert q["service"] == "spectrum" and q["depth"] == 4 and q["groups"] == 2
    batches = trace.select("serve.batch")
    assert sorted(e["batch"] for e in batches) == [1, 3]
    assert all(e["duration_us"] > 0 for e in batches)


def test_imaging_service_emits_per_family_batches(rng):
    from repro.serve import ConvolutionRequest, ImagingService, RegistrationRequest

    ref = rng.standard_normal((16, 16)).astype(np.float32)
    reqs = [
        RegistrationRequest(ref=ref, mov=np.roll(ref, 2, axis=0)),
        ConvolutionRequest(
            image=rng.standard_normal((16, 16)).astype(np.float32),
            kernel=np.ones((3, 3), np.float32) / 9.0,
        ),
    ]
    with obs.capture() as trace:
        ImagingService().serve(reqs)
    q = trace.first("serve.queue")
    assert q["service"] == "imaging"
    assert q["registrations"] == 1 and q["convolutions"] == 1
    services = {e["service"] for e in trace.select("serve.batch")}
    assert {"registration", "convolution"} <= services


# ------------------------------ engines ------------------------------


def test_engine_apply_span_wraps_registry_dispatch(rng):
    # Builtin variants run inside repro.core; the engine.apply span covers
    # registry dispatch — precision="double" routes through reference_x64.
    x = (rng.standard_normal((8, 8))
         + 1j * rng.standard_normal((8, 8))).astype(np.complex128)
    with xfft.config(precision="double"):
        with obs.capture() as trace:
            np.asarray(xfft.fft2(x))
    (ev,) = trace.select("engine.apply")
    assert ev["engine"] == "reference_x64"
    assert ev["backend"] == "x64" and ev["x64"] is True
    assert ev["kind"] == "fft2d" and ev["duration_us"] > 0
