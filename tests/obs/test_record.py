"""The obs substrate itself: events, spans, counters, capture scoping."""

import threading

import pytest

import repro.xfft as xfft
from repro import obs


@pytest.fixture(autouse=True)
def _fresh_counters():
    obs.reset_counters()
    yield
    obs.reset_counters()


def test_disabled_emit_is_a_noop_but_counts():
    assert not obs.enabled()
    assert obs.emit("unit.test", a=1) is None      # no scope -> no Event
    assert obs.counters()["unit.test"] == 1        # ...but always counted
    obs.emit("unit.test")
    assert obs.counters()["unit.test"] == 2


def test_capture_collects_and_restores():
    with obs.capture() as trace:
        assert obs.enabled()
        ev = obs.emit("unit.test", x=7)
        assert ev is not None and ev["x"] == 7
    assert not obs.enabled()
    assert [e.name for e in trace] == ["unit.test"]
    assert trace.first("unit.test").get("x") == 7
    assert trace.first("unit.test").get("missing", "d") == "d"


def test_nested_scopes_inner_window_outer_sees_all():
    with obs.capture() as outer:
        obs.emit("before.inner")
        with obs.capture() as inner:
            obs.emit("inside")
        obs.emit("after.inner")
    assert [e.name for e in inner] == ["inside"]
    assert [e.name for e in outer] == ["before.inner", "inside", "after.inner"]


def test_select_glob_first_counts():
    with obs.capture() as t:
        obs.emit("plan.resolve", outcome="miss")
        obs.emit("plan.resolve", outcome="hit")
        obs.emit("plan.measure")
        obs.emit("engine.apply")
    assert len(t.select("plan.resolve")) == 2
    assert len(t.select("plan.*")) == 3
    assert t.first("plan.resolve")["outcome"] == "miss"
    assert t.first("nope") is None
    assert t.counts() == {"plan.resolve": 2, "plan.measure": 1,
                          "engine.apply": 1}
    assert "plan.measure" in t.summary()


def test_span_times_and_merges_extra_fields():
    with obs.capture() as t:
        with obs.span("unit.region", fixed="f") as out:
            out["chosen"] = "radix4"
    (ev,) = t.select("unit.region")
    assert ev["fixed"] == "f"
    assert ev["chosen"] == "radix4"
    assert ev["duration_us"] >= 0.0


def test_span_disabled_fast_path_counts_only():
    with obs.span("unit.region") as out:
        out["ignored"] = 1                         # dict is yielded but dropped
    assert obs.counters()["unit.region"] == 1


def test_capture_profile_toggles_profiling_flag():
    assert not obs.profiling()
    with obs.capture(profile=True):
        assert obs.profiling()
        with obs.capture(profile=False):
            assert not obs.profiling()
        assert obs.profiling()
    assert not obs.profiling()


def test_threads_do_not_observe_each_other():
    """A thread spawned inside a capture scope starts with a fresh
    contextvars context: its events never land in this thread's trace,
    and its own scopes work independently."""
    seen_in_thread = {}

    def worker():
        seen_in_thread["enabled_on_entry"] = obs.enabled()
        with obs.capture() as t:
            obs.emit("thread.local")
        seen_in_thread["own_events"] = [e.name for e in t]

    with obs.capture() as trace:
        th = threading.Thread(target=worker)
        th.start()
        th.join()
        obs.emit("main.local")
    assert seen_in_thread["enabled_on_entry"] is False
    assert seen_in_thread["own_events"] == ["thread.local"]
    assert [e.name for e in trace] == ["main.local"]
    # counters ARE process-wide: both threads' emissions land there
    assert obs.counters()["thread.local"] == 1
    assert obs.counters()["main.local"] == 1


# --------------------- xfft.config(observe=...) hooks ---------------------


def test_config_observe_trace_streams_events():
    sink = obs.Trace()
    with xfft.config(observe=sink):
        obs.emit("scoped.event", k=1)
    obs.emit("outside.event")
    assert [e.name for e in sink] == ["scoped.event"]


def test_config_observe_false_silences_enclosing_capture():
    with obs.capture() as outer:
        obs.emit("kept")
        with xfft.config(observe=False):
            obs.emit("dropped")
        obs.emit("kept.again")
    assert [e.name for e in outer] == ["kept", "kept.again"]


def test_config_observe_inherits_without_double_recording():
    """An inner scope that does NOT set observe= must not re-push the
    inherited trace — every event would be recorded twice."""
    sink = obs.Trace()
    with xfft.config(observe=sink):
        with xfft.config(mode="estimate"):         # inherits observe
            obs.emit("once")
    assert len(sink.select("once")) == 1


def test_config_observe_true_scopes_profiling():
    with xfft.config(observe=True):
        assert obs.profiling()
    assert not obs.profiling()


def test_config_observe_rejects_junk():
    with pytest.raises(ValueError, match="observe"):
        xfft.config(observe="yes")
