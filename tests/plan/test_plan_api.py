"""Planner API: ESTIMATE/MEASURE plans, variant="auto" numerical equivalence
to the float64 DFT oracle, and execute() dispatch."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.fft1d import fft
from repro.core.fft2d import fft2, fft2_stream
from repro.plan import (
    PLAN_VARIANTS,
    PlanCache,
    chunk_candidates,
    execute,
    plan_fft,
    problem_key,
    resolve,
)


def _dft_oracle(x, axes):
    """Float64 DFT reference (np.fft over complex128)."""
    return np.fft.fftn(np.asarray(x, np.complex128), axes=axes)


@pytest.fixture
def crand(rng):
    def make(shape):
        return (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)).astype(
            np.complex64
        )

    return make


def test_estimate_plan_is_concrete_and_deterministic():
    cache = PlanCache()
    p1 = plan_fft("fft2d", (64, 64), cache=cache)
    p2 = plan_fft("fft2d", (64, 64), cache=cache)
    assert p1.variant in PLAN_VARIANTS
    assert p1 is p2  # second call is a cache hit, not a re-plan
    assert cache.hits >= 1


def test_estimate_crossover_small_vs_large():
    """The analytic model prefers low-overhead schedules at small N and
    bandwidth-lean schedules at large N (matches MEASURE on CPU). Within
    the radix-2 trio that is the seed's unrolled->stockham crossover; the
    radix-4 family (half the passes and overheads) may better either end
    but the losing schedules must stay losers."""
    from repro.plan.autotune import estimate_variant_time

    cache = PlanCache()
    small = plan_fft("fft1d", (4, 16), cache=cache)
    large = plan_fft("fft1d", (4, 4096), cache=cache)
    ks = problem_key("fft1d", (4, 16))
    kl = problem_key("fft1d", (4, 4096))
    # seed crossover, preserved within the radix-2 schedules
    assert estimate_variant_time(ks, "unrolled") < estimate_variant_time(ks, "stockham")
    assert estimate_variant_time(kl, "stockham") < estimate_variant_time(kl, "unrolled")
    # winners are overhead-lean (small) / bandwidth-lean (large)
    assert small.variant in ("unrolled", "radix4")
    assert large.variant in ("stockham", "radix4", "fused_r4")
    assert small.variant != "looped" and large.variant != "looped"


def test_fft1d_auto_matches_float64_oracle(crand):
    x = crand((3, 128))
    got = np.asarray(fft(jnp.asarray(x), variant="auto"))
    ref = _dft_oracle(x, axes=(-1,))
    scale = max(1.0, np.max(np.abs(ref)))
    np.testing.assert_allclose(got / scale, ref / scale, atol=5e-6)


def test_fft2_auto_matches_float64_oracle(crand):
    x = crand((2, 32, 64))
    got = np.asarray(fft2(jnp.asarray(x), variant="auto"))
    ref = _dft_oracle(x, axes=(-2, -1))
    scale = max(1.0, np.max(np.abs(ref)))
    np.testing.assert_allclose(got / scale, ref / scale, atol=1e-5)


def test_fft2_stream_auto_matches_float64_oracle(crand):
    frames = crand((5, 16, 32))
    got = np.asarray(fft2_stream(jnp.asarray(frames), variant="auto", unroll="auto"))
    ref = _dft_oracle(frames, axes=(-2, -1))
    scale = max(1.0, np.max(np.abs(ref)))
    np.testing.assert_allclose(got / scale, ref / scale, atol=1e-5)


def test_measure_plan_beats_nothing_but_is_concrete(crand):
    """MEASURE on a small problem: timings for every candidate, winner
    concrete, measured time recorded, and the plan replaces the ESTIMATE
    entry in the cache."""
    cache = PlanCache()
    est = plan_fft("fft1d", (2, 64), cache=cache)
    timings = {}
    plan = plan_fft(
        "fft1d", (2, 64), mode="measure", cache=cache, measure_iters=2,
        timings_out=timings,
    )
    assert set(timings) == set(PLAN_VARIANTS)
    assert plan.mode == "measure" and plan.measured_us is not None
    assert plan.measured_us == pytest.approx(min(timings.values()))
    assert cache.get(plan.key).mode == "measure"  # MEASURE displaced ESTIMATE
    assert est.key == plan.key
    # a later measure call hits the cache instead of re-timing
    again = plan_fft("fft1d", (2, 64), mode="measure", cache=cache)
    assert again is cache.get(plan.key)


def test_resolve_uses_cached_measure_plan():
    cache = PlanCache()
    measured = plan_fft("fft2d", (16, 16), mode="measure", cache=cache,
                        measure_iters=1)
    hit = resolve("fft2d", (16, 16), cache=cache)
    assert hit is cache.get(measured.key)
    assert hit.mode == "measure"


def test_execute_dispatch_matches_direct_calls(crand):
    cache = PlanCache()
    x2 = crand((32, 32))
    p2 = plan_fft("fft2d", (32, 32), cache=cache)
    np.testing.assert_array_equal(
        np.asarray(execute(p2, jnp.asarray(x2))),
        np.asarray(fft2(jnp.asarray(x2), variant=p2.variant)),
    )
    frames = crand((3, 16, 16))
    ps = plan_fft("fft2d_stream", (3, 16, 16), cache=cache)
    np.testing.assert_array_equal(
        np.asarray(execute(ps, jnp.asarray(frames))),
        np.asarray(
            fft2_stream(jnp.asarray(frames), variant=ps.variant, unroll=ps.unroll)
        ),
    )
    pp = plan_fft("fft2d_pencil", (64, 32), n_devices=8, cache=cache)
    with pytest.raises(ValueError):
        execute(pp, jnp.zeros((64, 32)))  # pencil plans need a mesh


def test_pencil_chunks_are_legal_divisors():
    for w, d in ((32, 8), (64, 4), (128, 8), (96, 4)):
        cands = chunk_candidates(w, d)
        assert cands, (w, d)
        for c in cands:
            assert w % c == 0 and (w // c) % d == 0
        plan = plan_fft("fft2d_pencil", (64, w), n_devices=d, cache=PlanCache())
        assert plan.chunks in cands


def test_measure_rejects_pencil_without_mesh():
    # MEASURE can't time a collective without devices; pencil falls back to
    # the analytic model rather than raising.
    plan = plan_fft("fft2d_pencil", (64, 32), n_devices=8, mode="measure",
                    cache=PlanCache())
    assert plan.mode == "estimate"


def test_plan_fft_autosaves_file_backed_cache(tmp_path):
    path = str(tmp_path / "wisdom.json")
    cache = PlanCache(path=path)
    plan_fft("fft2d", (32, 32), cache=cache)
    # a brand-new cache (fresh process analogue) re-tunes nothing
    fresh = PlanCache(path=path)
    assert fresh.get(problem_key("fft2d", (32, 32))) is not None
    assert fresh.hits == 1 and fresh.misses == 0


def test_bad_inputs():
    with pytest.raises(ValueError):
        plan_fft("fft3d", (8, 8, 8))
    with pytest.raises(ValueError):
        plan_fft("fft2d", (8, 8), mode="exhaustive")
