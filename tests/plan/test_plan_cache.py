"""Plan cache: round-trip, persistence, key versioning, hit/miss counters."""

import json

import pytest

from repro.plan import (
    PLAN_SCHEMA_VERSION,
    FFTPlan,
    PlanCache,
    ProblemKey,
)


def _key(shape=(64, 64), kind="fft2d", n_devices=1):
    return ProblemKey(
        kind=kind,
        backend="cpu",
        device_kind="cpu",
        shape=shape,
        dtype="complex64",
        n_devices=n_devices,
    )


def _plan(key=None, variant="stockham", **kw):
    return FFTPlan(key=key or _key(), variant=variant, **kw)


def test_put_get_roundtrip():
    cache = PlanCache()
    plan = _plan()
    assert cache.get(plan.key) is None
    cache.put(plan)
    assert cache.get(plan.key) == plan
    assert len(cache) == 1
    # distinct shape -> distinct key -> miss
    assert cache.get(_key(shape=(128, 128))) is None


def test_hit_miss_counters():
    cache = PlanCache()
    plan = _plan()
    cache.get(plan.key)
    cache.put(plan)
    cache.get(plan.key)
    cache.get(plan.key)
    assert cache.misses == 1 and cache.hits == 2
    cache.clear()
    assert len(cache) == 0 and cache.hits == 0 and cache.misses == 0


def test_persist_and_reload(tmp_path):
    path = str(tmp_path / "plans.json")
    cache = PlanCache(path=path)
    plans = [
        _plan(_key(shape=(64, 64))),
        _plan(_key(shape=(4, 256), kind="fft1d"), variant="unrolled"),
        _plan(_key(shape=(8, 32, 32), kind="fft2d_stream"), unroll=2,
              mode="measure", measured_us=123.4),
        _plan(_key(shape=(64, 32), kind="fft2d_pencil", n_devices=8), chunks=4),
    ]
    for p in plans:
        cache.put(p)
    cache.save()

    fresh = PlanCache(path=path)  # autoload
    assert len(fresh) == len(plans)
    for p in plans:
        got = fresh.get(p.key)
        assert got == p
    # full field fidelity through JSON for the measured plan
    m = fresh.get(plans[2].key)
    assert m.mode == "measure" and m.measured_us == pytest.approx(123.4)
    assert m.unroll == 2


def test_stale_schema_version_dropped(tmp_path):
    path = str(tmp_path / "plans.json")
    cache = PlanCache(path=path)
    cache.put(_plan())
    cache.save()

    # Rewrite the file as if produced by an older plan schema.
    with open(path) as f:
        payload = json.load(f)
    old = {}
    for key, plan in payload["plans"].items():
        assert key.startswith(f"v{PLAN_SCHEMA_VERSION}|")
        old_key = "v0|" + key.split("|", 1)[1]
        old[old_key] = plan
    payload["plans"] = old
    payload["plan_schema_version"] = 0
    with open(path, "w") as f:
        json.dump(payload, f)

    fresh = PlanCache(path=path)
    assert len(fresh) == 0  # stale entries orphaned, not mis-read


def test_corrupt_cache_file_ignored(tmp_path):
    path = str(tmp_path / "plans.json")
    with open(path, "w") as f:
        f.write("{not json")
    cache = PlanCache(path=path)  # must not raise
    assert len(cache) == 0
    # tampered key/value mismatch is dropped too
    good = PlanCache(path=str(tmp_path / "ok.json"))
    p = _plan()
    good.put(p)
    good.save()
    with open(good.path) as f:
        payload = json.load(f)
    (key,) = payload["plans"]
    payload["plans"][key]["key"]["shape"] = [128, 128]  # lies about its key
    with open(good.path, "w") as f:
        json.dump(payload, f)
    assert PlanCache(path=good.path)._plans == {}


def test_plan_rejects_auto_variant():
    with pytest.raises(ValueError):
        FFTPlan(key=_key(), variant="auto")


def test_cache_key_embeds_all_dimensions():
    base = _key().cache_key()
    assert base.startswith(f"v{PLAN_SCHEMA_VERSION}|")
    assert _key(shape=(32, 32)).cache_key() != base
    assert _key(kind="fft2d_stream", shape=(2, 64, 64)).cache_key() != base
    assert _key(n_devices=8).cache_key() != base
