"""PR-2 planner surface: radix-4/fused/real candidates, the transform
direction key, and the schema-version bump that forces stale wisdom to
re-tune."""

import json

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.fft2d import fft2, ifft2
from repro.core.rfft import rfft2
from repro.plan import (
    PLAN_SCHEMA_VERSION,
    PLAN_VARIANTS,
    PlanCache,
    plan_fft,
    problem_key,
    resolve,
    variant_candidates,
)


def test_new_variants_are_first_class():
    for v in ("radix4", "fused", "fused_r4"):
        assert v in PLAN_VARIANTS


def test_variant_candidates_gating():
    # pow2 single-device 1D/2D problems sweep everything, fused included
    assert set(variant_candidates(problem_key("fft2d", (64, 64)))) == set(PLAN_VARIANTS)
    assert set(variant_candidates(problem_key("rfft1d", (4, 128), dtype="float32"))) \
        == set(PLAN_VARIANTS)
    # stream/pencil kinds and multi-device problems keep the jnp engines only
    for key in (
        problem_key("fft2d_stream", (4, 32, 32)),
        problem_key("fft2d_pencil", (64, 32), n_devices=8),
        problem_key("fft2d", (64, 64), n_devices=4),
        # a single length-2^20 row cannot tile into VMEM: no fused candidate
        problem_key("fft1d", (4, 1 << 20)),
    ):
        cands = variant_candidates(key)
        assert "fused" not in cands and "fused_r4" not in cands
        assert "radix4" in cands


def test_measure_sweeps_new_variants(rng):
    """MEASURE times radix4 and both fused kernels alongside the seed trio."""
    timings = {}
    plan = plan_fft("fft1d", (2, 64), mode="measure", cache=PlanCache(),
                    measure_iters=1, timings_out=timings)
    assert set(timings) == set(PLAN_VARIANTS)
    assert plan.variant in PLAN_VARIANTS


def test_measure_real_kind_runs_real_candidates(rng):
    timings = {}
    plan = plan_fft("rfft2d", (16, 16), dtype="float32", mode="measure",
                    cache=PlanCache(), measure_iters=1, timings_out=timings)
    assert set(timings) == set(PLAN_VARIANTS)
    assert plan.mode == "measure"
    # the winning plan really runs the real transform
    from repro.plan import execute

    x = jnp.asarray(np.random.default_rng(0).standard_normal((16, 16)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(execute(plan, x)), np.fft.rfft2(np.asarray(x)), atol=1e-3
    )


def test_inverse_direction_plans_separately():
    cache = PlanCache()
    fwd = plan_fft("fft2d", (32, 32), cache=cache)
    inv = plan_fft("fft2d", (32, 32), cache=cache, direction="inv")
    assert fwd.key.direction == "fwd" and inv.key.direction == "inv"
    assert fwd.key.cache_key() != inv.key.cache_key()
    # both live in the cache side by side
    assert cache.get(fwd.key) is fwd and cache.get(inv.key) is inv


def test_ifft2_auto_resolves_inverse_key(rng):
    """ifft2 no longer reuses the forward "fft2d" plan entry."""
    from repro.plan import default_cache

    x = (rng.standard_normal((16, 16)) + 1j * rng.standard_normal((16, 16))).astype(
        np.complex64
    )
    got = np.asarray(ifft2(jnp.asarray(x), variant="auto"))
    np.testing.assert_allclose(got, np.fft.ifft2(x), atol=1e-4)
    inv_key = problem_key("fft2d", (16, 16), direction="inv")
    assert default_cache().get(inv_key) is not None


@pytest.mark.parametrize("variant", ["radix4", "fused", "fused_r4"])
def test_execute_variants_numerically_exact(rng, variant):
    cache = PlanCache()
    x = (rng.standard_normal((32, 32)) + 1j * rng.standard_normal((32, 32))).astype(
        np.complex64
    )
    got = np.asarray(fft2(jnp.asarray(x), variant=variant))
    ref = np.fft.fft2(x)
    scale = max(1.0, np.max(np.abs(ref)))
    np.testing.assert_allclose(got / scale, ref / scale, atol=1e-5)
    # planned rfft2 with an explicitly pinned variant matches numpy too
    xr = rng.standard_normal((32, 32)).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(rfft2(jnp.asarray(xr), variant=variant)),
        np.fft.rfft2(xr),
        atol=1e-3,
    )
    del cache


def test_schema_bump_orphans_preexisting_wisdom(tmp_path):
    """A wisdom file tuned under the previous schema version re-tunes: its
    keys carry the old version prefix, so load() drops every entry."""
    path = str(tmp_path / "wisdom.json")
    cache = PlanCache(path=path)
    plan = plan_fft("fft2d", (64, 64), mode="measure", cache=cache, measure_iters=1)
    assert plan.mode == "measure"

    # Rewrite the file as PR-1 code would have written it (schema v1 keys).
    with open(path) as f:
        payload = json.load(f)
    prev = PLAN_SCHEMA_VERSION - 1
    payload["plan_schema_version"] = prev
    payload["plans"] = {
        f"v{prev}|" + k.split("|", 1)[1]: v for k, v in payload["plans"].items()
    }
    with open(path, "w") as f:
        json.dump(payload, f)

    stale = PlanCache(path=path)
    assert len(stale) == 0  # nothing deserialises from the old schema
    replan = plan_fft("fft2d", (64, 64), cache=stale)
    assert stale.misses >= 1  # the lookup missed -> a fresh tune happened
    assert replan.key.cache_key().startswith(f"v{PLAN_SCHEMA_VERSION}|")


def test_estimate_prefers_fused_on_tpu_keys():
    """On a TPU problem key the one-round-trip fused kernels win ESTIMATE;
    on CPU (interpret mode) they don't get the HBM credit."""
    from repro.plan import ProblemKey, estimate_plan

    tpu = ProblemKey(kind="fft2d", backend="tpu", device_kind="TPU v5e",
                     shape=(1024, 1024), dtype="complex64")
    cpu = ProblemKey(kind="fft2d", backend="cpu", device_kind="cpu",
                     shape=(1024, 1024), dtype="complex64")
    assert estimate_plan(tpu).variant in ("fused", "fused_r4")
    assert estimate_plan(cpu).variant not in ("fused", "fused_r4")
