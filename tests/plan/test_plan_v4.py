"""Schema v4: norm-independent cache keys and the oaconv2d problem kind."""

import json

import numpy as np
import pytest

import repro.xfft as xfft
from repro.kernels.ops import fft2_fits_budget
from repro.plan import (
    PLAN_SCHEMA_VERSION,
    PlanCache,
    default_cache,
    estimate_plan,
    oaconv_tile_candidates,
    plan_fft,
    problem_key,
    reset_default_cache,
)


@pytest.fixture(autouse=True)
def _fresh_default_cache():
    reset_default_cache()
    yield
    reset_default_cache()


# ------------------------- norm-independent keys -------------------------


def test_norm_is_not_part_of_the_key(rng):
    """backward/ortho/forward resolve to ONE tuned entry: the scale is
    applied outside the engine, so the schedule optimum cannot differ."""
    x = rng.standard_normal((16, 16)).astype(np.float32)
    cache = default_cache()
    np.asarray(xfft.rfft2(x, norm="ortho"))
    assert len(cache) == 1
    hits_before = cache.hits
    np.asarray(xfft.rfft2(x, norm="forward"))
    np.asarray(xfft.rfft2(x))
    assert len(cache) == 1                  # still one entry
    assert cache.hits >= hits_before + 2    # other norms HIT that entry


def test_measure_wisdom_shared_across_norms(tmp_path, rng):
    cache = PlanCache(path=str(tmp_path / "wisdom.json"))
    tuned = plan_fft("fft2d", (16, 16), mode="measure", cache=cache,
                     measure_iters=1)
    x = (rng.standard_normal((16, 16)) + 1j * rng.standard_normal((16, 16))
         ).astype(np.complex64)
    with xfft.config(cache_dir=str(tmp_path)):
        for norm in ("backward", "ortho", "forward"):
            np.asarray(xfft.fft2(x, norm=norm))
    # every norm resolved to the tuned plan; nothing re-tuned or added
    assert len(PlanCache(path=str(tmp_path / "wisdom.json"))) == 1
    assert tuned.mode == "measure"


def test_v3_normful_wisdom_is_orphaned(tmp_path):
    """The satellite's orphan gate: v3 entries (norm in the key) carry the
    old version prefix, so a v4 load drops every one of them."""
    path = str(tmp_path / "wisdom.json")
    cache = PlanCache(path=path)
    plan_fft("fft2d", (32, 32), mode="measure", cache=cache, measure_iters=1)
    with open(path) as f:
        payload = json.load(f)
    # Rewrite the file as PR-3 code would have: v3 prefix, norm segment in
    # the key and a "norm" field in the serialized ProblemKey.
    payload["plan_schema_version"] = 3
    payload["plans"] = {
        k.replace(f"v{PLAN_SCHEMA_VERSION}|", "v3|").replace(
            "|ax", "|backward|ax"
        ): dict(v, key=dict(v["key"], norm="backward"))
        for k, v in payload["plans"].items()
    }
    with open(path, "w") as f:
        json.dump(payload, f)
    stale = PlanCache(path=path)
    assert len(stale) == 0                  # orphaned, not mis-deserialised


def test_problem_key_has_no_norm_field():
    key = problem_key("fft2d", (8, 8))
    assert not hasattr(key, "norm")
    assert "backward" not in key.cache_key()


# ------------------------------ oaconv2d ------------------------------


def _okey(shape=(256, 256, 16, 16), dtype="float32"):
    return problem_key("oaconv2d", shape, dtype=dtype)


def test_oaconv_plan_carries_a_legal_tile():
    plan = estimate_plan(_okey())
    th, tw = plan.tile
    assert th >= 16 and tw >= 16                      # step T-K+1 >= 1
    assert (th & (th - 1)) == 0 and (tw & (tw - 1)) == 0
    assert fft2_fits_budget(th, tw, real=True)        # kernels census holds
    assert plan.variant in ("looped", "unrolled", "stockham", "radix4",
                            "fused", "fused_r4")


def test_oaconv_tile_candidates_respect_kernel_and_budget():
    for th, tw in oaconv_tile_candidates(_okey()):
        assert th >= 16 and tw >= 16
        assert fft2_fits_budget(th, tw, real=True)
    with pytest.raises(ValueError, match="H, W, KH, KW"):
        oaconv_tile_candidates(problem_key("oaconv2d", (64, 64)))


def test_oaconv_complex_uses_complex_census():
    plan = estimate_plan(_okey(dtype="complex64"))
    th, tw = plan.tile
    assert fft2_fits_budget(th, tw, real=False)


def test_oaconv_plan_round_trips_through_the_cache(tmp_path):
    path = str(tmp_path / "wisdom.json")
    cache = PlanCache(path=path)
    plan = estimate_plan(_okey())
    cache.put(plan)
    cache.save()
    again = PlanCache(path=path).get(plan.key)
    assert again == plan and again.tile == plan.tile


def test_oaconv_measure_mode_degrades_to_estimate(tmp_path):
    cache = PlanCache()
    plan = plan_fft("oaconv2d", (128, 128, 8, 8), dtype="float32",
                    mode="measure", cache=cache)
    assert plan.mode == "estimate" and plan.tile is not None


def test_non_oaconv_plans_have_no_tile():
    assert estimate_plan(problem_key("fft2d", (64, 64))).tile is None


def test_execute_runs_an_oaconv_plan(rng):
    from repro.plan import execute

    image = rng.standard_normal((24, 24)).astype(np.float32)
    kernel = rng.standard_normal((3, 3)).astype(np.float32)
    plan = estimate_plan(_okey((24, 24, 3, 3)))
    got = np.asarray(execute(plan, (image, kernel)))
    want = np.fft.irfft2(
        np.fft.rfft2(image, s=(26, 26)) * np.fft.rfft2(kernel, s=(26, 26)),
        s=(26, 26),
    )[1:25, 1:25]
    np.testing.assert_allclose(got, want, atol=1e-3)
    with pytest.raises(ValueError, match="image, kernel"):
        execute(plan, image)
