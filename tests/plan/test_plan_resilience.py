"""Planner + cache resilience: quarantine filtering, readonly degrade,
atomic concurrent wisdom writes, and the MEASURE wall-clock budget."""

import json
import os
import threading
import time

import pytest

import repro.xfft as xfft
from repro import obs
from repro.plan import problem_key, resolve_call
from repro.plan.autotune import measure_plan, variant_candidates
from repro.plan.cache import PlanCache, default_cache, reset_default_cache
from repro.resilience import FaultPlan, FaultSpec, configure, quarantine, reset


KEY = problem_key("fft2d", (8, 8))


@pytest.fixture(autouse=True)
def _clean_breaker():
    reset()
    configure(threshold=1, cooldown_s=30.0, clock=time.monotonic)
    yield
    reset()
    configure(threshold=1, cooldown_s=30.0, clock=time.monotonic)


# --------------------- quarantine-aware candidate sets ---------------------


def test_variant_candidates_exclude_quarantined():
    baseline = variant_candidates(KEY)
    target = baseline[0]
    quarantine().record_failure(target, KEY)
    filtered = variant_candidates(KEY)
    assert target not in filtered
    assert set(filtered) == set(baseline) - {target}


def test_variant_candidates_bottom_out_at_reliable():
    """Quarantining everything still leaves the always-works jnp rung."""
    for name in variant_candidates(KEY):
        quarantine().record_failure(name, KEY)
    survivors = variant_candidates(KEY)
    assert survivors == ("stockham",)


def test_resolve_call_routes_around_quarantine_without_caching():
    first = resolve_call("fft2d", (8, 8)).variant
    quarantine().record_failure(first, KEY, error="boom")
    with obs.capture() as trace:
        fallback = resolve_call("fft2d", (8, 8))
    assert fallback.variant != first
    (e,) = trace.select("plan.resolve")
    assert e["outcome"] == "quarantined"
    # The workaround plan must not poison the wisdom cache: once the
    # breaker resets, the original first choice resolves again.
    reset()
    assert resolve_call("fft2d", (8, 8)).variant == first


def test_measure_under_quarantine_degrades_instead_of_sweeping():
    """Sweeping while an engine is benched would persist wisdom tuned over
    a temporarily reduced engine population — degrade instead."""
    first = resolve_call("fft2d", (8, 8)).variant
    quarantine().record_failure(first, KEY, error="boom")
    with obs.capture() as trace, xfft.config(mode="measure"):
        plan = resolve_call("fft2d", (8, 8))
    assert plan.mode == "estimate"
    assert plan.degrade_reason == "engine_quarantined"
    assert trace.select("plan.measure") == []  # no sweep ran
    (e,) = trace.select("plan.degrade")
    assert e["reason"] == "engine_quarantined"


# ------------------------- wisdom write resilience -------------------------


def _populated_cache(path=None):
    cache = PlanCache(path=path)
    with xfft.config(mode="estimate"):
        resolve_call("fft2d", (8, 8), cache=cache)
        resolve_call("fft1d", (64,), cache=cache)
    return cache


def test_save_is_atomic_under_concurrent_writers(tmp_path):
    path = str(tmp_path / "wisdom.json")
    cache = _populated_cache()
    errors = []

    def write():
        try:
            for _ in range(10):
                assert cache.save(path) == path
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=write) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert errors == []
    # Whatever interleaving happened, the surviving file is one complete
    # JSON document with every entry — never truncated or interleaved.
    with open(path) as f:
        payload = json.load(f)
    assert len(payload["plans"]) == len(cache)
    fresh = PlanCache(path=path)
    assert len(fresh) == len(cache)
    assert not [p for p in os.listdir(tmp_path) if p.endswith(".tmp")]


def test_unwritable_path_degrades_to_memory(tmp_path):
    blocker = tmp_path / "not_a_dir"
    blocker.write_text("a file where a directory must go")
    path = str(blocker / "sub" / "wisdom.json")  # makedirs must fail
    cache = _populated_cache()
    cache.path = path
    with obs.capture() as trace:
        assert cache.save() is None
    assert cache.path is None          # memory-only from here on
    assert cache.readonly_path == path
    (e,) = trace.select("plan.cache.readonly")
    assert e["path"] == path
    assert e["entries"] == len(cache)
    # Plans keep serving from memory; only persistence is lost.
    assert len(cache) > 0
    assert resolve_call("fft2d", (8, 8), cache=cache) is not None


def test_injected_save_fault_degrades_identically(tmp_path):
    path = str(tmp_path / "wisdom.json")
    cache = _populated_cache()
    cache.path = path
    plan = FaultPlan(FaultSpec("plan.cache.save", times=1))
    with obs.capture() as trace, xfft.config(faults=plan):
        assert cache.save() is None
    assert cache.readonly_path == path
    assert len(trace.select("plan.cache.readonly")) == 1
    assert not os.path.exists(path)  # degraded before any bytes landed


def test_injected_load_fault_accounts_as_file_error(tmp_path):
    path = str(tmp_path / "wisdom.json")
    _populated_cache(path=None).save(path)
    cache = PlanCache()
    plan = FaultPlan(FaultSpec("plan.cache.load", times=1))
    with xfft.config(faults=plan):
        report = cache.load(path)
    assert report.kept == 0
    assert "injected fault" in report.file_error
    assert cache.load(path).kept > 0  # budget spent: next load succeeds


def test_default_cache_degrade_via_env(tmp_path, monkeypatch):
    blocker = tmp_path / "not_a_dir"
    blocker.write_text("x")
    bad = str(blocker / "wisdom.json")
    monkeypatch.setenv("REPRO_PLAN_CACHE", bad)
    reset_default_cache()
    try:
        cache = default_cache()
        assert cache.path == bad
        with xfft.config(mode="estimate"):
            resolve_call("fft2d", (8, 8), cache=cache)
        with obs.capture() as trace:
            assert cache.save() is None
        assert cache.path is None
        assert len(trace.select("plan.cache.readonly")) == 1
        # report() surfaces the degrade for operators.
        assert "unwritable" in xfft.report(cache)
    finally:
        reset_default_cache()


# -------------------------- MEASURE budget guard --------------------------


def test_measure_budget_degrades_to_estimate():
    """A candidate stalled past its wall-clock budget degrades the sweep
    to ESTIMATE with reason measure_timeout instead of hanging."""
    stall = FaultPlan(
        FaultSpec("plan.measure", mode="latency", latency_s=0.2)
    )
    with obs.capture() as trace, xfft.config(faults=stall):
        plan = measure_plan(KEY, budget_s=0.05)
    assert plan.mode == "estimate"
    assert plan.degrade_reason == "measure_timeout"
    (e,) = trace.select("plan.degrade")
    assert e["reason"] == "measure_timeout"


def test_measure_candidate_errors_degrade():
    crash = FaultPlan(FaultSpec("plan.measure", mode="error"))
    with obs.capture() as trace, xfft.config(faults=crash):
        plan = measure_plan(KEY, budget_s=5.0)
    assert plan.mode == "estimate"
    assert plan.degrade_reason == "measure_failed"
    (e,) = trace.select("plan.degrade")
    assert e["reason"] == "measure_failed"


def test_measure_timeout_plans_do_not_resweep(monkeypatch):
    """A measure_timeout plan is remembered: resolve_call must not retry
    the whole sweep on every call (re-tune is explicit via force)."""
    monkeypatch.setattr(
        "repro.plan.autotune.MEASURE_CANDIDATE_BUDGET_S", 0.05
    )
    stall = FaultPlan(
        FaultSpec("plan.measure", mode="latency", latency_s=0.2)
    )
    cache = PlanCache()
    with xfft.config(faults=stall, mode="measure"):
        first = resolve_call("fft2d", (8, 8), cache=cache)
    assert first.degrade_reason == "measure_timeout"
    with obs.capture() as trace, xfft.config(mode="measure"):
        again = resolve_call("fft2d", (8, 8), cache=cache)
    assert again.degrade_reason == "measure_timeout"
    assert trace.select("plan.measure") == []  # no second sweep
