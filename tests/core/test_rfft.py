"""Real-input FFTs (two-for-one Hermitian packing) vs the numpy oracles."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.rfft import irfft, irfft2, rfft, rfft2

VARIANTS = ["looped", "unrolled", "stockham", "radix4"]


@pytest.mark.parametrize("shape", [(1, 2), (3, 8), (2, 64), (4, 128), (1, 1024)])
@pytest.mark.parametrize("variant", VARIANTS)
def test_rfft_matches_numpy(rng, shape, variant):
    x = rng.standard_normal(shape).astype(np.float32)
    got = np.asarray(rfft(jnp.asarray(x), variant=variant))
    ref = np.fft.rfft(x)
    assert got.shape == ref.shape
    scale = max(1.0, np.max(np.abs(ref)))
    np.testing.assert_allclose(got / scale, ref / scale, atol=1e-5)


@pytest.mark.parametrize("shape", [(3, 8), (2, 64), (1, 256)])
@pytest.mark.parametrize("variant", VARIANTS)
def test_irfft_roundtrip(rng, shape, variant):
    x = rng.standard_normal(shape).astype(np.float32)
    rt = np.asarray(irfft(rfft(jnp.asarray(x), variant=variant), variant=variant))
    np.testing.assert_allclose(rt, x, atol=1e-4)


def test_rfft_axis_argument(rng):
    x = rng.standard_normal((16, 5)).astype(np.float32)
    got = np.asarray(rfft(jnp.asarray(x), axis=0))
    np.testing.assert_allclose(got, np.fft.rfft(x, axis=0), atol=1e-4)
    rt = np.asarray(irfft(jnp.asarray(got), axis=0))
    np.testing.assert_allclose(rt, x, atol=1e-4)


@pytest.mark.parametrize("hw", [(8, 8), (16, 64), (64, 16), (32, 32)])
@pytest.mark.parametrize("variant", ["stockham", "radix4"])
def test_rfft2_matches_numpy(rng, hw, variant):
    x = rng.standard_normal((2, *hw)).astype(np.float32)
    got = np.asarray(rfft2(jnp.asarray(x), variant=variant))
    ref = np.fft.rfft2(x)
    assert got.shape == ref.shape
    scale = max(1.0, np.max(np.abs(ref)))
    np.testing.assert_allclose(got / scale, ref / scale, atol=1e-5)


@pytest.mark.parametrize("hw", [(8, 8), (16, 32)])
@pytest.mark.parametrize("variant", ["stockham", "radix4", "auto"])
def test_irfft2_roundtrip(rng, hw, variant):
    x = rng.standard_normal((2, *hw)).astype(np.float32)
    rt = np.asarray(irfft2(rfft2(jnp.asarray(x), variant=variant), variant=variant))
    np.testing.assert_allclose(rt, x, atol=1e-4)


def test_rfft_auto_plans_under_real_kind(rng):
    """variant="auto" resolves rfft through the rfft1d problem kind."""
    from repro.plan import default_cache, problem_key

    x = rng.standard_normal((4, 32)).astype(np.float32)
    got = np.asarray(rfft(jnp.asarray(x), variant="auto"))
    np.testing.assert_allclose(got, np.fft.rfft(x), atol=1e-4)
    key = problem_key("rfft1d", (4, 32), dtype="float32")
    assert default_cache().get(key) is not None


def test_rfft_rejects_complex_and_bad_lengths(rng):
    with pytest.raises(TypeError):
        rfft(jnp.ones((4, 8), jnp.complex64))
    with pytest.raises(ValueError):
        rfft(jnp.ones((4, 12), jnp.float32))  # not a power of two
    with pytest.raises(ValueError):
        irfft(jnp.ones((4, 8), jnp.complex64))  # width 8 is not N/2+1


def test_hermitian_half_spectrum_is_complete(rng):
    """The half spectrum reconstructs the full one by conjugate symmetry."""
    x = rng.standard_normal((2, 16)).astype(np.float32)
    half = np.asarray(rfft(jnp.asarray(x)))
    full = np.fft.fft(x)
    mirrored = np.conj(half[..., 1:-1][..., ::-1])
    np.testing.assert_allclose(
        np.concatenate([half, mirrored], axis=-1), full, atol=1e-4
    )
