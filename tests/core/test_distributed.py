"""Distributed pencil FFT — runs in a subprocess with 8 fake devices so the
rest of the suite keeps seeing exactly 1 device."""

import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core.distributed import fft2_pencil, fft2_pencil_overlapped, pencil_sharding

mesh = make_mesh((8,), ("data",))
rng = np.random.default_rng(7)

# sharded input, plain + overlapped variants, batched too
x = rng.standard_normal((64, 32)).astype(np.float32)
xs = jax.device_put(jnp.asarray(x), pencil_sharding(mesh, "data", "rows"))
ref = np.fft.fft2(x)
scale = np.max(np.abs(ref))
for fn, kw in ((fft2_pencil, {}), (fft2_pencil_overlapped, {"chunks": 4}),
               (fft2_pencil_overlapped, {"chunks": 2})):
    got = np.asarray(fn(xs, mesh, **kw))
    err = np.max(np.abs(got - ref)) / scale
    assert err < 1e-5, (fn.__name__, kw, err)

# planner integration: variant/chunks resolved through repro.plan
from repro.plan import default_cache, problem_key
got = np.asarray(fft2_pencil_overlapped(xs, mesh, variant="auto", chunks="auto"))
assert np.max(np.abs(got - ref)) / scale < 1e-5, "auto pencil mismatch"
plan = default_cache().get(problem_key("fft2d_pencil", (64, 32), n_devices=8))
assert plan is not None and plan.variant in ("looped", "unrolled", "stockham", "radix4")
assert 32 % plan.chunks == 0 and (32 // plan.chunks) % 8 == 0, plan.chunks

xb = rng.standard_normal((3, 64, 64)).astype(np.float32)
gb = np.asarray(fft2_pencil(jnp.asarray(xb), mesh))
assert np.max(np.abs(gb - np.fft.fft2(xb))) / np.max(np.abs(np.fft.fft2(xb))) < 1e-5

# output really lands column-sharded for the plain variant
y = fft2_pencil(xs, mesh)
spec = y.sharding.spec
assert tuple(spec) == (None, "data"), spec
print("DISTRIBUTED_OK")
"""


@pytest.mark.slow
def test_pencil_fft_multidevice():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True,
        text=True,
        cwd=os.path.join(os.path.dirname(__file__), "..", ".."),
        env=env,
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    assert "DISTRIBUTED_OK" in out.stdout
