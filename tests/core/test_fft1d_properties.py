"""Hypothesis property tests for the 1D engine (Parseval, roundtrip,
linearity, shift theorem, conjugate symmetry).

Guarded with importorskip: the whole module skips when hypothesis is not
installed (it is a test extra, not a runtime dependency)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

import jax.numpy as jnp  # noqa: E402
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core.fft1d import fft, ifft  # noqa: E402


def _crand(rng, shape):
    return (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)).astype(
        np.complex64
    )


array_strategy = st.tuples(
    st.integers(min_value=1, max_value=4),  # batch
    st.integers(min_value=1, max_value=7),  # log2 N
    st.integers(min_value=0, max_value=2**31 - 1),  # seed
)


@settings(max_examples=25, deadline=None)
@given(array_strategy)
def test_parseval(params):
    b, logn, seed = params
    n = 1 << logn
    rng = np.random.default_rng(seed)
    x = _crand(rng, (b, n))
    y = np.asarray(fft(jnp.asarray(x)))
    lhs = np.sum(np.abs(x) ** 2, axis=-1)
    rhs = np.sum(np.abs(y) ** 2, axis=-1) / n
    np.testing.assert_allclose(lhs, rhs, rtol=1e-3, atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(array_strategy)
def test_roundtrip(params):
    b, logn, seed = params
    n = 1 << logn
    rng = np.random.default_rng(seed)
    x = _crand(rng, (b, n))
    rt = np.asarray(ifft(fft(jnp.asarray(x))))
    np.testing.assert_allclose(rt, x, atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(array_strategy, st.integers(min_value=0, max_value=2**31 - 1))
def test_linearity(params, seed2):
    b, logn, seed = params
    n = 1 << logn
    r1, r2 = np.random.default_rng(seed), np.random.default_rng(seed2)
    x, y = _crand(r1, (b, n)), _crand(r2, (b, n))
    a = 0.7 - 0.3j
    lhs = np.asarray(fft(jnp.asarray(a * x + y)))
    rhs = a * np.asarray(fft(jnp.asarray(x))) + np.asarray(fft(jnp.asarray(y)))
    np.testing.assert_allclose(lhs, rhs, atol=2e-3)


@settings(max_examples=25, deadline=None)
@given(array_strategy)
def test_time_shift_theorem(params):
    b, logn, seed = params
    n = 1 << logn
    rng = np.random.default_rng(seed)
    x = _crand(rng, (b, n))
    shift = rng.integers(0, n)
    y_shifted = np.asarray(fft(jnp.asarray(np.roll(x, shift, axis=-1))))
    k = np.arange(n)
    phase = np.exp(-2j * np.pi * k * shift / n)
    y_expected = np.asarray(fft(jnp.asarray(x))) * phase
    np.testing.assert_allclose(y_shifted, y_expected, atol=5e-3)


@settings(max_examples=25, deadline=None)
@given(array_strategy)
def test_real_input_conjugate_symmetry(params):
    b, logn, seed = params
    n = 1 << logn
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((b, n)).astype(np.float32)
    y = np.asarray(fft(jnp.asarray(x)))
    # Y[k] == conj(Y[N-k])
    sym = np.conj(y[..., (-np.arange(n)) % n])
    np.testing.assert_allclose(y, sym, atol=2e-3)
    # DC bin is the plain sum.
    np.testing.assert_allclose(y[..., 0].real, x.sum(-1), rtol=1e-3, atol=1e-3)
