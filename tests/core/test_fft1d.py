"""Unit + property tests for the paper's 1D engine (all variants)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.fft1d import (
    bit_reversal_permutation,
    butterfly_counts,
    fft,
    fft_routing_tables,
    ifft,
)

VARIANTS = ("looped", "unrolled", "stockham")


def _crand(rng, shape):
    return (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)).astype(
        np.complex64
    )


@pytest.mark.parametrize("n", [2, 4, 8, 32, 128, 512, 2048])
@pytest.mark.parametrize("variant", VARIANTS)
def test_matches_numpy(rng, n, variant):
    x = _crand(rng, (3, n))
    ref = np.fft.fft(x.astype(np.complex128))
    got = np.asarray(fft(jnp.asarray(x), variant=variant))
    scale = max(1.0, np.max(np.abs(ref)))
    np.testing.assert_allclose(got / scale, ref / scale, atol=5e-6)


@pytest.mark.parametrize("variant", VARIANTS)
def test_variants_agree(rng, variant):
    x = _crand(rng, (2, 256))
    base = np.asarray(fft(jnp.asarray(x), variant="looped"))
    got = np.asarray(fft(jnp.asarray(x), variant=variant))
    np.testing.assert_allclose(got, base, atol=1e-3)


@pytest.mark.parametrize("axis", [0, 1, 2, -1, -2])
def test_axis_argument(rng, axis):
    x = _crand(rng, (8, 4, 16))
    got = np.asarray(fft(jnp.asarray(x), axis=axis))
    ref = np.fft.fft(x, axis=axis)
    np.testing.assert_allclose(got, ref, atol=1e-3)


def test_real_input_promoted(rng):
    x = rng.standard_normal((5, 64)).astype(np.float32)
    got = np.asarray(fft(jnp.asarray(x)))
    np.testing.assert_allclose(got, np.fft.fft(x), atol=1e-3)


def test_non_pow2_rejected():
    with pytest.raises(ValueError):
        fft(jnp.zeros((2, 12)))


def test_jit_and_grad():
    x = jnp.ones((2, 16), jnp.float32)

    @jax.jit
    def f(v):
        return jnp.sum(jnp.abs(fft(v)) ** 2)

    g = jax.grad(f)(x)
    assert g.shape == x.shape and bool(jnp.isfinite(g).all())


def test_bit_reversal_is_involution():
    for n in (2, 8, 64, 1024):
        p = bit_reversal_permutation(n)
        assert (p[p] == np.arange(n)).all()


def test_routing_tables_cover_all_positions():
    for n in (8, 64):
        idx_a, idx_b, tw, unperm = fft_routing_tables(n)
        for s in range(idx_a.shape[0]):
            union = np.sort(np.concatenate([idx_a[s], idx_b[s]]))
            assert (union == np.arange(n)).all()
            assert (idx_b[s] - idx_a[s] == (1 << s)).all()
            assert (np.sort(unperm[s]) == np.arange(n)).all()


def test_butterfly_counts_match_paper_tables():
    # Paper Table 2: proposed N/2 BUs vs traditional (N/2)·log2N.
    c_prop = butterfly_counts(1024, proposed=True)
    c_trad = butterfly_counts(1024, proposed=False)
    assert c_prop["butterfly_units"] == 512
    assert c_trad["butterfly_units"] == 512 * 10
    assert c_prop["adders_subtractors"] == 1024
    assert c_trad["adders_subtractors"] == 1024 * 10
    # eq. 5: area ratio = 1/log2 N
    assert c_prop["butterfly_units"] / c_trad["butterfly_units"] == 1 / 10


# ---------------- hypothesis property tests ----------------

array_strategy = st.tuples(
    st.integers(min_value=1, max_value=4),  # batch
    st.integers(min_value=1, max_value=7),  # log2 N
    st.integers(min_value=0, max_value=2**31 - 1),  # seed
)


@settings(max_examples=25, deadline=None)
@given(array_strategy)
def test_parseval(params):
    b, logn, seed = params
    n = 1 << logn
    rng = np.random.default_rng(seed)
    x = _crand(rng, (b, n))
    y = np.asarray(fft(jnp.asarray(x)))
    lhs = np.sum(np.abs(x) ** 2, axis=-1)
    rhs = np.sum(np.abs(y) ** 2, axis=-1) / n
    np.testing.assert_allclose(lhs, rhs, rtol=1e-3, atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(array_strategy)
def test_roundtrip(params):
    b, logn, seed = params
    n = 1 << logn
    rng = np.random.default_rng(seed)
    x = _crand(rng, (b, n))
    rt = np.asarray(ifft(fft(jnp.asarray(x))))
    np.testing.assert_allclose(rt, x, atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(array_strategy, st.integers(min_value=0, max_value=2**31 - 1))
def test_linearity(params, seed2):
    b, logn, seed = params
    n = 1 << logn
    r1, r2 = np.random.default_rng(seed), np.random.default_rng(seed2)
    x, y = _crand(r1, (b, n)), _crand(r2, (b, n))
    a = 0.7 - 0.3j
    lhs = np.asarray(fft(jnp.asarray(a * x + y)))
    rhs = a * np.asarray(fft(jnp.asarray(x))) + np.asarray(fft(jnp.asarray(y)))
    np.testing.assert_allclose(lhs, rhs, atol=2e-3)


@settings(max_examples=25, deadline=None)
@given(array_strategy)
def test_time_shift_theorem(params):
    b, logn, seed = params
    n = 1 << logn
    rng = np.random.default_rng(seed)
    x = _crand(rng, (b, n))
    shift = rng.integers(0, n)
    y_shifted = np.asarray(fft(jnp.asarray(np.roll(x, shift, axis=-1))))
    k = np.arange(n)
    phase = np.exp(-2j * np.pi * k * shift / n)
    y_expected = np.asarray(fft(jnp.asarray(x))) * phase
    np.testing.assert_allclose(y_shifted, y_expected, atol=5e-3)


@settings(max_examples=25, deadline=None)
@given(array_strategy)
def test_real_input_conjugate_symmetry(params):
    b, logn, seed = params
    n = 1 << logn
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((b, n)).astype(np.float32)
    y = np.asarray(fft(jnp.asarray(x)))
    # Y[k] == conj(Y[N-k])
    sym = np.conj(y[..., (-np.arange(n)) % n])
    np.testing.assert_allclose(y, sym, atol=2e-3)
    # DC bin is the plain sum.
    np.testing.assert_allclose(y[..., 0].real, x.sum(-1), rtol=1e-3, atol=1e-3)
