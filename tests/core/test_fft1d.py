"""Unit tests for the paper's 1D engine (all variants).

Hypothesis property tests live in test_fft1d_properties.py so this module
collects even when hypothesis is not installed."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fft1d import (
    bit_reversal_permutation,
    butterfly_counts,
    fft,
    fft_routing_tables,
    ifft,
)

VARIANTS = ("looped", "unrolled", "stockham")


def _crand(rng, shape):
    return (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)).astype(
        np.complex64
    )


@pytest.mark.parametrize("n", [2, 4, 8, 32, 128, 512, 2048])
@pytest.mark.parametrize("variant", VARIANTS)
def test_matches_numpy(rng, n, variant):
    x = _crand(rng, (3, n))
    ref = np.fft.fft(x.astype(np.complex128))
    got = np.asarray(fft(jnp.asarray(x), variant=variant))
    scale = max(1.0, np.max(np.abs(ref)))
    np.testing.assert_allclose(got / scale, ref / scale, atol=5e-6)


@pytest.mark.parametrize("variant", VARIANTS)
def test_variants_agree(rng, variant):
    x = _crand(rng, (2, 256))
    base = np.asarray(fft(jnp.asarray(x), variant="looped"))
    got = np.asarray(fft(jnp.asarray(x), variant=variant))
    np.testing.assert_allclose(got, base, atol=1e-3)


@pytest.mark.parametrize("axis", [0, 1, 2, -1, -2])
def test_axis_argument(rng, axis):
    x = _crand(rng, (8, 4, 16))
    got = np.asarray(fft(jnp.asarray(x), axis=axis))
    ref = np.fft.fft(x, axis=axis)
    np.testing.assert_allclose(got, ref, atol=1e-3)


def test_real_input_promoted(rng):
    x = rng.standard_normal((5, 64)).astype(np.float32)
    got = np.asarray(fft(jnp.asarray(x)))
    np.testing.assert_allclose(got, np.fft.fft(x), atol=1e-3)


def test_non_pow2_rejected():
    with pytest.raises(ValueError):
        fft(jnp.zeros((2, 12)))


def test_jit_and_grad():
    x = jnp.ones((2, 16), jnp.float32)

    @jax.jit
    def f(v):
        return jnp.sum(jnp.abs(fft(v)) ** 2)

    g = jax.grad(f)(x)
    assert g.shape == x.shape and bool(jnp.isfinite(g).all())


def test_bit_reversal_is_involution():
    for n in (2, 8, 64, 1024):
        p = bit_reversal_permutation(n)
        assert (p[p] == np.arange(n)).all()


def test_routing_tables_cover_all_positions():
    for n in (8, 64):
        idx_a, idx_b, tw, unperm = fft_routing_tables(n)
        for s in range(idx_a.shape[0]):
            union = np.sort(np.concatenate([idx_a[s], idx_b[s]]))
            assert (union == np.arange(n)).all()
            assert (idx_b[s] - idx_a[s] == (1 << s)).all()
            assert (np.sort(unperm[s]) == np.arange(n)).all()


def test_butterfly_counts_match_paper_tables():
    # Paper Table 2: proposed N/2 BUs vs traditional (N/2)·log2N.
    c_prop = butterfly_counts(1024, proposed=True)
    c_trad = butterfly_counts(1024, proposed=False)
    assert c_prop["butterfly_units"] == 512
    assert c_trad["butterfly_units"] == 512 * 10
    assert c_prop["adders_subtractors"] == 1024
    assert c_trad["adders_subtractors"] == 1024 * 10
    # eq. 5: area ratio = 1/log2 N
    assert c_prop["butterfly_units"] / c_trad["butterfly_units"] == 1 / 10
