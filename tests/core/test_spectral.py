"""Spectral applications: FNet mixing, fftconv, STFT/log-mel."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.spectral import (
    fftconv,
    fourier_mixing,
    fourier_mixing_rfft,
    log_mel,
    rfft_last_axis,
    stft,
)


def test_fourier_mixing_matches_fnet_definition(rng):
    x = rng.standard_normal((2, 16, 32)).astype(np.float32)
    got = np.asarray(fourier_mixing(jnp.asarray(x)))
    ref = np.fft.fft2(x).real.astype(np.float32)
    np.testing.assert_allclose(got, ref, atol=1e-3)


@pytest.mark.parametrize("shape", [(1, 8, 16), (2, 32, 64), (3, 16, 128)])
def test_rfft_matches_numpy(rng, shape):
    x = rng.standard_normal(shape).astype(np.float32)
    got = np.asarray(rfft_last_axis(jnp.asarray(x)))
    ref = np.fft.rfft(x)
    scale = max(1.0, np.max(np.abs(ref)))
    np.testing.assert_allclose(got / scale, ref / scale, atol=1e-5)


@pytest.mark.parametrize("shape", [(2, 16, 32), (1, 64, 64)])
def test_rfft_mixing_matches_full(rng, shape):
    """§Perf cell C2: the real-input specialisation is exact."""
    x = rng.standard_normal(shape).astype(np.float32)
    full = np.asarray(fourier_mixing(jnp.asarray(x), variant="stockham"))
    half = np.asarray(fourier_mixing_rfft(jnp.asarray(x)))
    scale = max(1.0, np.max(np.abs(full)))
    np.testing.assert_allclose(half / scale, full / scale, atol=1e-5)


def test_rfft_mixing_differentiable(rng):
    x = jnp.asarray(rng.standard_normal((1, 8, 16)).astype(np.float32))
    g = jax.grad(lambda v: jnp.sum(fourier_mixing_rfft(v) ** 2))(x)
    assert bool(jnp.isfinite(g).all())


def test_fourier_mixing_differentiable(rng):
    x = jnp.asarray(rng.standard_normal((1, 8, 8)).astype(np.float32))
    g = jax.grad(lambda v: jnp.sum(fourier_mixing(v) ** 2))(x)
    assert bool(jnp.isfinite(g).all())


def test_fftconv_matches_direct(rng):
    L, D = 64, 4
    x = rng.standard_normal((2, L, D)).astype(np.float32)
    k = rng.standard_normal((L, D)).astype(np.float32)
    got = np.asarray(fftconv(jnp.asarray(x), jnp.asarray(k)))
    ref = np.zeros_like(x)
    for t in range(L):
        for s in range(t + 1):
            ref[:, t] += k[s] * x[:, t - s]
    np.testing.assert_allclose(got, ref, atol=2e-3)


def test_fftconv_short_kernel(rng):
    x = rng.standard_normal((1, 32, 2)).astype(np.float32)
    k = rng.standard_normal((4, 2)).astype(np.float32)
    got = np.asarray(fftconv(jnp.asarray(x), jnp.asarray(k)))
    ref = np.zeros_like(x)
    for t in range(32):
        for s in range(min(t + 1, 4)):
            ref[:, t] += k[s] * x[:, t - s]
    np.testing.assert_allclose(got, ref, atol=1e-3)


def test_fftconv_is_causal(rng):
    """Changing the future must not change the past."""
    x1 = rng.standard_normal((1, 32, 2)).astype(np.float32)
    x2 = x1.copy()
    x2[:, 20:] += 1.0
    k = rng.standard_normal((32, 2)).astype(np.float32)
    y1 = np.asarray(fftconv(jnp.asarray(x1), jnp.asarray(k)))
    y2 = np.asarray(fftconv(jnp.asarray(x2), jnp.asarray(k)))
    np.testing.assert_allclose(y1[:, :20], y2[:, :20], atol=1e-4)


def test_stft_pure_tone_peak():
    sr, f0 = 16000.0, 1000.0
    t = np.arange(8192) / sr
    audio = np.sin(2 * np.pi * f0 * t).astype(np.float32)
    spec = np.abs(np.asarray(stft(jnp.asarray(audio), frame=512, hop=256)))
    peak_bin = spec.mean(axis=0).argmax()
    expected = round(f0 * 512 / sr)
    assert abs(int(peak_bin) - expected) <= 1


def test_log_mel_shape_and_finite(rng):
    a = rng.standard_normal((2, 4096)).astype(np.float32)
    lm = np.asarray(log_mel(jnp.asarray(a), n_mels=80))
    assert lm.shape == (2, 15, 80)
    assert np.isfinite(lm).all()
