"""2D engine + ping-pong streaming pipeline tests."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fft2d import fft2, fft2_stream, fftshift2, ifft2


@pytest.mark.parametrize("hw", [(8, 8), (16, 32), (64, 64)])
@pytest.mark.parametrize("variant", ["looped", "unrolled", "stockham"])
def test_fft2_matches_numpy(rng, hw, variant):
    x = rng.standard_normal((2, *hw)).astype(np.float32)
    got = np.asarray(fft2(jnp.asarray(x), variant=variant))
    ref = np.fft.fft2(x)
    scale = max(1.0, np.max(np.abs(ref)))
    np.testing.assert_allclose(got / scale, ref / scale, atol=1e-5)


def test_ifft2_roundtrip(rng):
    x = (rng.standard_normal((3, 16, 16)) + 1j * rng.standard_normal((3, 16, 16))).astype(
        np.complex64
    )
    rt = np.asarray(ifft2(fft2(jnp.asarray(x))))
    np.testing.assert_allclose(rt, x, atol=1e-4)


def test_stream_equals_per_frame(rng):
    """Ping-pong pipelined output == frame-at-a-time output (paper fig. 3/4)."""
    frames = rng.standard_normal((7, 16, 32)).astype(np.float32)
    got = np.asarray(fft2_stream(jnp.asarray(frames)))
    ref = np.fft.fft2(frames)
    scale = max(1.0, np.max(np.abs(ref)))
    np.testing.assert_allclose(got / scale, ref / scale, atol=1e-5)


def test_stream_single_frame(rng):
    frames = rng.standard_normal((1, 8, 8)).astype(np.float32)
    got = np.asarray(fft2_stream(jnp.asarray(frames)))
    np.testing.assert_allclose(got, np.fft.fft2(frames), atol=1e-4)


def test_stream_batched_frames(rng):
    frames = rng.standard_normal((4, 2, 8, 8)).astype(np.float32)
    got = np.asarray(fft2_stream(jnp.asarray(frames)))
    np.testing.assert_allclose(got, np.fft.fft2(frames), atol=1e-4)


@pytest.mark.parametrize("unroll", [2, 4])
def test_stream_unrolled_scan_matches(rng, unroll):
    """Unrolling the ping-pong scan must not change the pipeline semantics,
    including when T is not a multiple of the unroll factor."""
    frames = rng.standard_normal((7, 16, 16)).astype(np.float32)
    ref = np.fft.fft2(frames)
    scale = max(1.0, np.max(np.abs(ref)))
    got = np.asarray(fft2_stream(jnp.asarray(frames), unroll=unroll))
    np.testing.assert_allclose(got / scale, ref / scale, atol=1e-5)
    base = np.asarray(fft2_stream(jnp.asarray(frames), unroll=1))
    np.testing.assert_allclose(got, base, atol=1e-6)


def test_stream_auto_plan(rng):
    """variant="auto"/unroll="auto" resolve through repro.plan and stay exact."""
    frames = rng.standard_normal((4, 8, 8)).astype(np.float32)
    got = np.asarray(fft2_stream(jnp.asarray(frames), variant="auto", unroll="auto"))
    np.testing.assert_allclose(got, np.fft.fft2(frames), atol=1e-4)

    from repro.plan import default_cache, problem_key

    plan = default_cache().get(problem_key("fft2d_stream", (4, 8, 8)))
    assert plan is not None and plan.unroll >= 1


def test_fftshift2_centers_dc(rng):
    x = jnp.ones((8, 8), jnp.float32)  # all energy in DC bin
    y = np.asarray(fftshift2(fft2(x)))
    assert np.abs(y[4, 4]) == pytest.approx(64.0, rel=1e-4)
