"""Hypothesis property tests for the real-input (two-for-one) path:
rfft/rfft2 against the jnp.fft oracles, Hermitian round-trips, and the
radix-4 engine's parity with radix-2 and jnp.fft.

Guarded with importorskip: the whole module skips when hypothesis is not
installed (it is a test extra, not a runtime dependency)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

import jax.numpy as jnp  # noqa: E402
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core.fft1d import fft  # noqa: E402
from repro.core.rfft import irfft, irfft2, rfft, rfft2  # noqa: E402

array_strategy = st.tuples(
    st.integers(min_value=1, max_value=4),  # batch
    st.integers(min_value=1, max_value=7),  # log2 N
    st.integers(min_value=0, max_value=2**31 - 1),  # seed
)

frame_strategy = st.tuples(
    st.integers(min_value=2, max_value=5),  # log2 H
    st.integers(min_value=1, max_value=6),  # log2 W
    st.integers(min_value=0, max_value=2**31 - 1),  # seed
)


@settings(max_examples=25, deadline=None)
@given(array_strategy)
def test_rfft_matches_jnp(params):
    b, logn, seed = params
    n = 1 << logn
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((b, n)).astype(np.float32)
    got = np.asarray(rfft(jnp.asarray(x)))
    ref = np.asarray(jnp.fft.rfft(jnp.asarray(x)))
    scale = max(1.0, np.max(np.abs(ref)))
    np.testing.assert_allclose(got / scale, ref / scale, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(array_strategy)
def test_irfft_rfft_roundtrip(params):
    b, logn, seed = params
    n = 1 << logn
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((b, n)).astype(np.float32)
    rt = np.asarray(irfft(rfft(jnp.asarray(x))))
    np.testing.assert_allclose(rt, x, atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(frame_strategy)
def test_rfft2_matches_jnp(params):
    logh, logw, seed = params
    h, w = 1 << logh, 1 << logw
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((h, w)).astype(np.float32)
    got = np.asarray(rfft2(jnp.asarray(x)))
    ref = np.asarray(jnp.fft.rfft2(jnp.asarray(x)))
    scale = max(1.0, np.max(np.abs(ref)))
    np.testing.assert_allclose(got / scale, ref / scale, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(frame_strategy)
def test_irfft2_rfft2_roundtrip(params):
    """Hermitian-symmetry round trip: irfft2(rfft2(x)) recovers x."""
    logh, logw, seed = params
    h, w = 1 << logh, 1 << logw
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((h, w)).astype(np.float32)
    rt = np.asarray(irfft2(rfft2(jnp.asarray(x))))
    np.testing.assert_allclose(rt, x, atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(array_strategy)
def test_radix4_matches_radix2_and_jnp(params):
    b, logn, seed = params
    n = 1 << logn
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((b, n)) + 1j * rng.standard_normal((b, n))).astype(
        np.complex64
    )
    r4 = np.asarray(fft(jnp.asarray(x), variant="radix4"))
    r2 = np.asarray(fft(jnp.asarray(x), variant="stockham"))
    ref = np.asarray(jnp.fft.fft(jnp.asarray(x)))
    scale = max(1.0, np.max(np.abs(ref)))
    np.testing.assert_allclose(r4 / scale, r2 / scale, atol=1e-5)
    np.testing.assert_allclose(r4 / scale, ref / scale, atol=1e-5)
