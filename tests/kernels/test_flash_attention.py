"""Pallas flash-attention kernel vs the naive oracle (interpret mode).

Hypothesis property sweeps live in test_flash_attention_properties.py so
this module collects even when hypothesis is not installed."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_fwd, mha_reference


CASES = [
    (2, 64, 64, 32, True, None, 16, 16),
    (3, 128, 128, 16, True, 32, 32, 32),    # sliding window
    (1, 48, 96, 8, False, None, 16, 32),    # cross-attention, ragged
    (2, 100, 100, 16, True, None, 32, 32),  # non-divisible seq
    (1, 256, 256, 64, True, None, 64, 128),
]


@pytest.mark.parametrize("case", CASES)
def test_flash_matches_reference(rng, case):
    bh, sq, sk, d, causal, window, bq, bk = case
    q = jnp.asarray(rng.standard_normal((bh, sq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((bh, sk, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((bh, sk, d)), jnp.float32)
    got = flash_attention_fwd(
        q, k, v, causal=causal, window=window, block_q=bq, block_k=bk,
        interpret=True,
    )
    ref = mha_reference(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


def test_flash_agrees_with_model_flash(rng):
    """Kernel == the pure-XLA chunked attention used by the models."""
    from repro.models.attention import flash_attention as xla_flash

    b, s, h, d = 2, 64, 4, 16
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    xla = xla_flash(q, k, v, causal=True, block_q=16, block_k=16)
    qk = jnp.moveaxis(q, 2, 1).reshape(b * h, s, d)
    kk = jnp.moveaxis(k, 2, 1).reshape(b * h, s, d)
    vk = jnp.moveaxis(v, 2, 1).reshape(b * h, s, d)
    pal = flash_attention_fwd(qk, kk, vk, causal=True, block_q=16, block_k=16,
                              interpret=True)
    pal = jnp.moveaxis(pal.reshape(b, h, s, d), 1, 2)
    np.testing.assert_allclose(np.asarray(pal), np.asarray(xla), atol=2e-5)
