"""Injected VMEM exhaustion must drive the REAL unfused failover path.

The fused 2D kernels consult the ``kernel.fused`` fault seam alongside
their genuine VMEM census, so a chaos run exercises the row / corner-turn
/ column failover on frames that would normally fit — same code path a
too-big frame takes, no giant allocation needed.
"""

import numpy as np

import repro.xfft as xfft
from repro import obs
from repro.kernels.ops import fft2_kernel, rfft2_kernel
from repro.resilience import FaultPlan, FaultSpec


def _frame(rng, shape=(16, 16)):
    return (
        rng.standard_normal(shape) + 1j * rng.standard_normal(shape)
    ).astype(np.complex64)


def test_small_frame_stays_fused(rng):
    with obs.capture() as trace:
        y = fft2_kernel(_frame(rng))
    assert trace.select("kernel.failover") == []
    assert np.asarray(y).shape == (16, 16)


def test_injected_vmem_exhaustion_forces_unfused_failover(rng):
    x = _frame(rng)
    plan = FaultPlan(
        FaultSpec("kernel.fused", mode="vmem", match={"kind": "fft2d"}, times=1)
    )
    with obs.capture() as trace, xfft.config(faults=plan):
        y = fft2_kernel(x)
    np.testing.assert_allclose(
        np.asarray(y), np.fft.fft2(x), rtol=1e-3, atol=1e-3
    )
    (inj,) = trace.select("resilience.fault")
    assert inj["seam"] == "kernel.fused" and inj["mode"] == "vmem"
    (fo,) = trace.select("kernel.failover")
    assert fo["kind"] == "fft2d"
    assert tuple(fo["shape"]) == (16, 16)


def test_rfft2_vmem_injection_fails_over(rng):
    x = rng.standard_normal((16, 16)).astype(np.float32)
    plan = FaultPlan(
        FaultSpec("kernel.fused", mode="vmem", match={"kind": "rfft2d"}, times=1)
    )
    with obs.capture() as trace, xfft.config(faults=plan):
        y = rfft2_kernel(x)
    np.testing.assert_allclose(
        np.asarray(y), np.fft.rfft2(x), rtol=1e-3, atol=1e-3
    )
    (fo,) = trace.select("kernel.failover")
    assert fo["kind"] == "rfft2d"


def test_vmem_budget_spent_next_call_fuses(rng):
    """times=1: the second trace takes the fused path again."""
    x = _frame(rng)
    plan = FaultPlan(FaultSpec("kernel.fused", mode="vmem", times=1))
    with obs.capture() as trace, xfft.config(faults=plan):
        fft2_kernel(x)
        fft2_kernel(x)
    assert len(trace.select("kernel.failover")) == 1
