"""Hypothesis sweep of the fused Pallas FFT kernel (interpret mode).

Guarded with importorskip: skips when hypothesis is not installed."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

import jax.numpy as jnp  # noqa: E402
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.kernels.ops import fft_kernel  # noqa: E402


@settings(max_examples=20, deadline=None)
@given(
    st.integers(min_value=1, max_value=3),
    st.integers(min_value=3, max_value=9),
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_fused_kernel_property_sweep(b, logn, seed):
    n = 1 << logn
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((b, n)) + 1j * rng.standard_normal((b, n))).astype(
        np.complex64
    )
    got = np.asarray(fft_kernel(jnp.asarray(x), interpret=True))
    ref = np.fft.fft(x.astype(np.complex128))
    scale = max(1.0, np.max(np.abs(ref)))
    np.testing.assert_allclose(got / scale, ref / scale, atol=1e-5)
