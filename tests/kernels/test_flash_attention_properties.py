"""Hypothesis sweep of the Pallas flash-attention kernel (interpret mode).

Guarded with importorskip: skips when hypothesis is not installed."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

import jax.numpy as jnp  # noqa: E402
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.kernels.flash_attention import (  # noqa: E402
    flash_attention_fwd,
    mha_reference,
)


@settings(max_examples=15, deadline=None)
@given(
    st.integers(min_value=1, max_value=3),
    st.integers(min_value=4, max_value=7),
    st.integers(min_value=3, max_value=5),
    st.booleans(),
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_flash_property_sweep(bh, log_s, log_d, causal, seed):
    s, d = 1 << log_s, 1 << log_d
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((bh, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((bh, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((bh, s, d)), jnp.float32)
    got = flash_attention_fwd(q, k, v, causal=causal, block_q=16, block_k=16,
                              interpret=True)
    ref = mha_reference(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=3e-5)
