"""Radix-4 fused panels + real-input kernels (interpret mode) vs oracles,
and the VMEM working-set accounting that gates the fused 2D path."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fft_radix2 import (
    fft2_fits_vmem,
    fft2_fused,
    fft_fused,
    pick_row_tile,
)
from repro.kernels.ops import (
    fft2_kernel,
    fft_kernel,
    hbm_traffic_model,
    irfft2_kernel,
    irfft_kernel,
    rfft2_kernel,
    rfft_kernel,
)

# ISSUE 2 acceptance sizes: radix-4 vs radix-2 vs the reference at these N.
PARITY_N = [8, 64, 1024]


@pytest.mark.parametrize("n", PARITY_N)
def test_radix4_fused_matches_jnp_fft(rng, n):
    """Radix-4 fused kernel ≤ 1e-4 max abs error vs jnp.fft.fft (scaled)."""
    x = (rng.standard_normal((4, n)) + 1j * rng.standard_normal((4, n))).astype(
        np.complex64
    )
    ref = np.asarray(jnp.fft.fft(jnp.asarray(x)))
    r4 = np.asarray(fft_kernel(jnp.asarray(x), radix=4, interpret=True))
    r2 = np.asarray(fft_kernel(jnp.asarray(x), radix=2, interpret=True))
    scale = max(1.0, np.max(np.abs(ref)))
    assert np.max(np.abs(r4 - ref)) / scale <= 1e-4
    assert np.max(np.abs(r4 - r2)) / scale <= 1e-4


@pytest.mark.parametrize("n", [2, 4, 16, 32, 128, 512])
def test_radix4_fused_all_parities(rng, n):
    """Odd log2(N) falls back to one radix-2 stage; every size stays exact."""
    x = (rng.standard_normal((2, n)) + 1j * rng.standard_normal((2, n))).astype(
        np.complex64
    )
    got = np.asarray(fft_kernel(jnp.asarray(x), radix=4, interpret=True))
    ref = np.fft.fft(np.asarray(x, np.complex128))
    scale = max(1.0, np.max(np.abs(ref)))
    np.testing.assert_allclose(got / scale, ref / scale, atol=1e-5)


@pytest.mark.parametrize("hw", [(8, 8), (16, 64), (128, 128)])
@pytest.mark.parametrize("radix", [2, 4])
def test_fused_2d_kernel_radix(rng, hw, radix):
    x = rng.standard_normal((2, *hw)).astype(np.float32)
    got = np.asarray(fft2_kernel(jnp.asarray(x), radix=radix, interpret=True))
    ref = np.fft.fft2(x)
    scale = max(1.0, np.max(np.abs(ref)))
    np.testing.assert_allclose(got / scale, ref / scale, atol=1e-5)


@pytest.mark.parametrize("n", [2, 8, 64, 1024])
@pytest.mark.parametrize("radix", [2, 4])
def test_rfft_kernel_matches_numpy(rng, n, radix):
    x = rng.standard_normal((3, n)).astype(np.float32)
    got = np.asarray(rfft_kernel(jnp.asarray(x), radix=radix, interpret=True))
    ref = np.fft.rfft(x)
    assert got.shape == ref.shape
    scale = max(1.0, np.max(np.abs(ref)))
    np.testing.assert_allclose(got / scale, ref / scale, atol=1e-5)
    rt = np.asarray(irfft_kernel(jnp.asarray(got), radix=radix, interpret=True))
    np.testing.assert_allclose(rt, x, atol=1e-4)


@pytest.mark.parametrize("hw", [(8, 8), (16, 64), (64, 16)])
@pytest.mark.parametrize("radix", [2, 4])
def test_rfft2_kernel_matches_numpy(rng, hw, radix):
    x = rng.standard_normal((2, *hw)).astype(np.float32)
    got = np.asarray(rfft2_kernel(jnp.asarray(x), radix=radix, interpret=True))
    ref = np.fft.rfft2(x)
    assert got.shape == ref.shape
    scale = max(1.0, np.max(np.abs(ref)))
    np.testing.assert_allclose(got / scale, ref / scale, atol=1e-5)
    rt = np.asarray(irfft2_kernel(jnp.asarray(got), radix=radix, interpret=True))
    np.testing.assert_allclose(rt, x, atol=1e-4)


# ------------------------- VMEM working-set accounting ---------------------


def test_fft2_fused_guard_counts_corner_turn_temporaries():
    """The budget census includes the transposed temporaries (8 frame-sized
    arrays), not just the 4 I/O panes the old guard assumed."""
    # 1024x512: 4 arrays fit the 8 MiB budget exactly, the true working set
    # (16 MiB) does not — exactly the silent-overflow regime the fix targets.
    assert 1024 * 512 * 4 * 4 <= 8 * 1024 * 1024
    assert not fft2_fits_vmem(1024, 512)
    with pytest.raises(ValueError, match="VMEM budget"):
        fft2_fused(jnp.zeros((1, 1024, 512)), jnp.zeros((1, 1024, 512)),
                   interpret=True)


def test_fft2_kernel_fails_over_to_unfused(rng):
    """Frames over budget route through the unfused row/turn/column path
    and stay correct instead of overflowing VMEM."""
    x = rng.standard_normal((1, 1024, 512)).astype(np.float32)
    assert not fft2_fits_vmem(1024, 512)
    got = np.asarray(fft2_kernel(jnp.asarray(x), interpret=True))
    ref = np.fft.fft2(x)
    scale = max(1.0, np.max(np.abs(ref)))
    np.testing.assert_allclose(got / scale, ref / scale, atol=1e-5)


def test_rfft2_kernel_fails_over_to_unfused(rng):
    x = rng.standard_normal((1, 512, 1024)).astype(np.float32)
    assert not fft2_fits_vmem(512, 1024, arrays=6)
    got = np.asarray(rfft2_kernel(jnp.asarray(x), interpret=True))
    ref = np.fft.rfft2(x)
    scale = max(1.0, np.max(np.abs(ref)))
    np.testing.assert_allclose(got / scale, ref / scale, atol=1e-5)
    rt = np.asarray(irfft2_kernel(jnp.asarray(got), interpret=True))
    np.testing.assert_allclose(rt, x, atol=1e-4)


def test_fft_fused_rejects_untileable_rows():
    """A row too long for even a 1-row VMEM tile raises instead of
    silently overflowing (the 1D kernels have no unfused failover)."""
    from repro.kernels.fft_radix2 import fft_fits_vmem

    n = 1 << 20
    assert not fft_fits_vmem(n)
    with pytest.raises(ValueError, match="VMEM budget"):
        fft_fused(jnp.zeros((1, n)), jnp.zeros((1, n)), interpret=True)


def test_fft2_kernel_failover_handles_untileable_rows(rng):
    """Rows too long for even a 1-row VMEM tile: the 2D failover composes
    the row pass with the jnp engine — a result, never an overflow."""
    from repro.kernels.fft_radix2 import fft_fits_vmem

    w = 1 << 19
    assert not fft_fits_vmem(w)
    x = rng.standard_normal((1, 2, w)).astype(np.float32)
    got = np.asarray(fft2_kernel(jnp.asarray(x), interpret=True))
    ref = np.fft.fft2(x)
    scale = max(1.0, np.max(np.abs(ref)))
    np.testing.assert_allclose(got / scale, ref / scale, atol=1e-4)
    gotr = np.asarray(rfft2_kernel(jnp.asarray(x), interpret=True))
    refr = np.fft.rfft2(x)
    np.testing.assert_allclose(gotr / scale, refr / scale, atol=1e-4)
    rt = np.asarray(irfft2_kernel(jnp.asarray(gotr), interpret=True))
    np.testing.assert_allclose(rt, x, atol=1e-3)


def test_irfft_discards_dc_and_nyquist_imag(rng):
    """np.fft.irfft parity: Im(Y[0]) and Im(Y[N/2]) are ignored."""
    from repro.core.rfft import irfft

    n = 16
    y = (rng.standard_normal((2, n // 2 + 1))
         + 1j * rng.standard_normal((2, n // 2 + 1))).astype(np.complex64)
    ref = np.fft.irfft(y, n=n)
    got = np.asarray(irfft(jnp.asarray(y)))
    np.testing.assert_allclose(got, ref, atol=1e-5)
    got_k = np.asarray(irfft_kernel(jnp.asarray(y), interpret=True))
    np.testing.assert_allclose(got_k, ref, atol=1e-5)


def test_pick_row_tile_counts_working_set():
    """Default census is 6 row-sized arrays (in+out+working), not 4."""
    t = pick_row_tile(1 << 20, 4096)
    assert t * 4096 * 4 * 6 <= 8 * 1024 * 1024
    # a caller declaring a smaller working set may tile larger
    assert pick_row_tile(1 << 20, 4096, arrays=4) >= t


def test_traffic_model_radix_and_realness():
    for n in (64, 1024, 4096):
        full = hbm_traffic_model(32, n, False)
        assert hbm_traffic_model(32, n, True) / full == 1 / np.log2(n)
        # radix-4 halves the staged pass count (ceil for odd log2 N)
        r4 = hbm_traffic_model(32, n, False, radix=4)
        assert r4 == full * np.ceil(np.log2(n) / 2) / np.log2(n)
        # the two-for-one real pack halves every pass
        assert hbm_traffic_model(32, n, False, real=True) == full // 2
