"""Pallas sLSTM scan kernel vs the model's per-step reference."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.slstm_scan import hbm_traffic_estimate, slstm_scan
from repro.models import xlstm as X
from repro.models.config import ModelConfig
from repro.models.param import init_params


def _cfg(d):
    return ModelConfig(name="t", family="ssm", n_layers=1, d_model=d, n_heads=4,
                       n_kv_heads=4, d_ff=0, vocab=10)


@pytest.mark.parametrize("b,l,d,chunk", [(1, 8, 32, 4), (2, 32, 64, 8), (3, 64, 128, 16)])
def test_kernel_matches_reference(rng, b, l, d, chunk):
    cfg = _cfg(d)
    p = init_params(X.slstm_skel(cfg), jax.random.PRNGKey(0))
    x = jnp.asarray(rng.standard_normal((b, l, d)) * 0.5, jnp.float32)
    xg = jnp.einsum("bld,dk->blk", x, p["wx"])

    # reference: step-by-step recurrence (bias added inside the step)
    st = X.slstm_state(cfg, b)
    hs_ref = []
    for t in range(l):
        st = X._slstm_step(p, st, xg[:, t], d)
        hs_ref.append(st["h"])
    hs_ref = jnp.stack(hs_ref, 1)

    z = jnp.zeros((b, d), jnp.float32)
    hs, (c, n, h, m) = slstm_scan(
        xg, p["wr"], p["bias"], z, z, z,
        jnp.full((b, d), -1e30, jnp.float32),  # finite surrogate for -inf
        chunk=chunk, interpret=True,
    )
    np.testing.assert_allclose(np.asarray(hs), np.asarray(hs_ref), atol=2e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(st["h"]), atol=2e-5)
    np.testing.assert_allclose(np.asarray(c), np.asarray(st["c"]), atol=2e-5)


def test_traffic_model_improves():
    assert (
        hbm_traffic_estimate(32, 32768, 1024, True)
        < 0.5 * hbm_traffic_estimate(32, 32768, 1024, False)
    )
