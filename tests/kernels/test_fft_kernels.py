"""Pallas kernel sweeps (interpret mode) against the ref.py oracles.

Hypothesis property sweeps live in test_fft_kernels_properties.py so this
module collects even when hypothesis is not installed."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.butterfly import butterfly_stage
from repro.kernels.fft_radix2 import fft2_fused, fft_fused, pick_row_tile
from repro.kernels.ops import fft2_kernel, fft_kernel, fft_staged, hbm_traffic_model
from repro.kernels.ref import dft_matmul, fft2_jnp, fft_jnp

SHAPES_1D = [(1, 8), (4, 64), (16, 128), (8, 1024), (2, 4096)]
DTYPES = [np.float32, np.float64, np.complex64]


def _mk(rng, shape, dtype):
    if np.issubdtype(dtype, np.complexfloating):
        return (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)).astype(
            dtype
        )
    return rng.standard_normal(shape).astype(dtype)


@pytest.mark.parametrize("shape", SHAPES_1D)
@pytest.mark.parametrize("dtype", DTYPES)
def test_fused_kernel_matches_oracle(rng, shape, dtype):
    x = _mk(rng, shape, dtype)
    got = np.asarray(fft_kernel(jnp.asarray(x), interpret=True))
    ref = np.fft.fft(np.asarray(x, np.complex128))
    scale = max(1.0, np.max(np.abs(ref)))
    np.testing.assert_allclose(got / scale, ref / scale, atol=1e-5)


@pytest.mark.parametrize("shape", SHAPES_1D[:4])
@pytest.mark.parametrize("dtype", [np.float32, np.complex64])
def test_staged_kernel_matches_oracle(rng, shape, dtype):
    x = _mk(rng, shape, dtype)
    got = np.asarray(fft_staged(jnp.asarray(x), interpret=True))
    ref = np.fft.fft(np.asarray(x, np.complex128))
    scale = max(1.0, np.max(np.abs(ref)))
    np.testing.assert_allclose(got / scale, ref / scale, atol=1e-5)


@pytest.mark.parametrize("hw", [(8, 8), (16, 64), (64, 16), (128, 128)])
def test_fused_2d_kernel(rng, hw):
    x = rng.standard_normal((3, *hw)).astype(np.float32)
    got = np.asarray(fft2_kernel(jnp.asarray(x), interpret=True))
    ref = np.fft.fft2(x)
    scale = max(1.0, np.max(np.abs(ref)))
    np.testing.assert_allclose(got / scale, ref / scale, atol=1e-5)


def test_fused_vs_dft_matmul_oracle(rng):
    re = rng.standard_normal((4, 256)).astype(np.float32)
    im = rng.standard_normal((4, 256)).astype(np.float32)
    yr, yi = fft_fused(jnp.asarray(re), jnp.asarray(im), interpret=True)
    rr, ri = dft_matmul(jnp.asarray(re), jnp.asarray(im))
    np.testing.assert_allclose(np.asarray(yr), np.asarray(rr), atol=2e-3)
    np.testing.assert_allclose(np.asarray(yi), np.asarray(ri), atol=2e-3)


def test_oracles_agree(rng):
    re = rng.standard_normal((2, 128)).astype(np.float32)
    im = rng.standard_normal((2, 128)).astype(np.float32)
    a = fft_jnp(jnp.asarray(re), jnp.asarray(im))
    b = dft_matmul(jnp.asarray(re), jnp.asarray(im))
    np.testing.assert_allclose(np.asarray(a[0]), np.asarray(b[0]), atol=1e-3)
    r2 = rng.standard_normal((2, 16, 16)).astype(np.float32)
    c = fft2_jnp(jnp.asarray(r2), jnp.zeros_like(jnp.asarray(r2)))
    ref = np.fft.fft2(r2)
    np.testing.assert_allclose(np.asarray(c[0]), ref.real, atol=1e-3)


@pytest.mark.parametrize("stage", [0, 1, 3, 5])
def test_single_stage_butterfly_vs_tables(rng, stage):
    """One kernel stage == one pass of the reference routing-table stage."""
    from repro.core.fft1d import fft_routing_tables

    n = 64
    idx_a, idx_b, tw, unperm = fft_routing_tables(n)
    x = (rng.standard_normal((2, n)) + 1j * rng.standard_normal((2, n))).astype(
        np.complex64
    )
    a = x[:, idx_a[stage]]
    b = x[:, idx_b[stage]] * tw[stage]
    ref = np.concatenate([a + b, a - b], axis=-1)[:, unperm[stage]]
    got_re, got_im = butterfly_stage(
        jnp.asarray(x.real), jnp.asarray(x.imag), stage=stage, interpret=True
    )
    got = np.asarray(got_re) + 1j * np.asarray(got_im)
    np.testing.assert_allclose(got, ref, atol=1e-4)


def test_row_tile_picker():
    assert pick_row_tile(1024, 128) >= 1
    t = pick_row_tile(64, 4096)
    assert 64 % t == 0
    # VMEM budget respected
    assert t * 4096 * 4 * 4 <= 8 * 1024 * 1024


def test_traffic_ratio_is_paper_alpha():
    for n in (64, 1024, 4096):
        ratio = hbm_traffic_model(32, n, True) / hbm_traffic_model(32, n, False)
        assert ratio == 1 / np.log2(n)
