"""Unit tests for the deterministic fault-injection harness."""

import numpy as np
import pytest

import repro.xfft as xfft
from repro import obs
from repro.resilience import (
    FAULT_MODES,
    FAULT_SEAMS,
    FaultPlan,
    FaultSpec,
    InjectedFault,
    active_faults,
    pop_faults,
    push_faults,
)
from repro.resilience.faults import FaultState, maybe_corrupt, maybe_fail, vmem_exhausted


# ------------------------------ construction ------------------------------


def test_spec_rejects_unknown_seam():
    with pytest.raises(ValueError, match="unknown fault seam"):
        FaultSpec("engine.appply")


def test_spec_rejects_unknown_mode():
    with pytest.raises(ValueError, match="unknown fault mode"):
        FaultSpec("engine.apply", mode="segfault")


@pytest.mark.parametrize("p", [0.0, -0.1, 1.5])
def test_spec_rejects_bad_probability(p):
    with pytest.raises(ValueError, match="probability"):
        FaultSpec("engine.apply", p=p)


def test_spec_rejects_bad_times():
    with pytest.raises(ValueError, match="times"):
        FaultSpec("engine.apply", times=0)


def test_spec_match_dict_normalized_and_hashable():
    spec = FaultSpec("engine.apply", match={"engine": "radix4", "kind": "fft2d"})
    assert spec.match == (("engine", "radix4"), ("kind", "fft2d"))
    hash(spec)  # must ride on the frozen XFFTConfig


def test_plan_normalizes_single_spec_and_is_hashable():
    plan = FaultPlan(FaultSpec("serve.batch"))
    assert plan.specs == (FaultSpec("serve.batch"),)
    hash(plan)


def test_plan_rejects_non_spec_entries():
    with pytest.raises(TypeError, match="FaultSpec"):
        FaultPlan(specs=("engine.apply",))


def test_vocabulary_is_closed():
    assert "engine.apply" in FAULT_SEAMS
    assert set(FAULT_MODES) == {"error", "latency", "nan", "inf", "vmem"}


# ------------------------------ firing rules ------------------------------


def _fire_pattern(state, n=20):
    return [
        state.fire("engine.apply", ("error",), {}) is not None for _ in range(n)
    ]


def test_times_budget_is_exact():
    state = FaultState(FaultPlan(FaultSpec("engine.apply", times=2)))
    assert sum(_fire_pattern(state)) == 2


def test_unlimited_times_fires_every_consultation():
    state = FaultState(FaultPlan(FaultSpec("engine.apply")))
    assert all(_fire_pattern(state))


def test_match_filter_gates_on_context():
    plan = FaultPlan(FaultSpec("engine.apply", match={"engine": "radix4"}))
    state = FaultState(plan)
    assert state.fire("engine.apply", ("error",), {"engine": "stockham"}) is None
    assert state.fire("engine.apply", ("error",), {}) is None  # missing field
    assert state.fire("engine.apply", ("error",), {"engine": "radix4"})


def test_seeded_probability_replays_exactly():
    plan = FaultPlan(FaultSpec("engine.apply", p=0.3), seed=7)
    a = _fire_pattern(FaultState(plan), n=100)
    b = _fire_pattern(FaultState(plan), n=100)
    assert a == b
    assert 0 < sum(a) < 100  # actually probabilistic, not all-or-nothing


def test_different_seeds_differ():
    a = _fire_pattern(FaultState(FaultPlan(FaultSpec("engine.apply", p=0.5), seed=1)), 100)
    b = _fire_pattern(FaultState(FaultPlan(FaultSpec("engine.apply", p=0.5), seed=2)), 100)
    assert a != b


def test_fired_fault_emits_event_and_counter():
    token = push_faults(FaultPlan(FaultSpec("serve.batch", times=1)))
    try:
        with obs.capture() as trace:
            with pytest.raises(InjectedFault):
                maybe_fail("serve.batch", service="lm")
        (e,) = trace.select("resilience.fault")
        assert e["seam"] == "serve.batch"
        assert e["mode"] == "error"
        assert e["service"] == "lm"
    finally:
        pop_faults(token)


# ------------------------------ seam hooks --------------------------------


def test_maybe_fail_noop_without_plan():
    assert active_faults() is None
    maybe_fail("engine.apply")  # must not raise


def test_error_fault_raises_injected_fault():
    token = push_faults(FaultPlan(FaultSpec("plan.cache.load", message="boom")))
    try:
        with pytest.raises(InjectedFault, match="boom") as ei:
            maybe_fail("plan.cache.load", path="/x")
        assert ei.value.seam == "plan.cache.load"
        assert ei.value.mode == "error"
    finally:
        pop_faults(token)


def test_vmem_fault_message_mimics_xla():
    token = push_faults(FaultPlan(FaultSpec("engine.apply", mode="vmem")))
    try:
        with pytest.raises(InjectedFault, match="RESOURCE_EXHAUSTED"):
            maybe_fail("engine.apply")
    finally:
        pop_faults(token)


def test_latency_fault_stalls_then_returns():
    import time

    token = push_faults(
        FaultPlan(FaultSpec("plan.measure", mode="latency", latency_s=0.02))
    )
    try:
        t0 = time.perf_counter()
        maybe_fail("plan.measure")  # returns, does not raise
        assert time.perf_counter() - t0 >= 0.015
    finally:
        pop_faults(token)


@pytest.mark.parametrize("mode,bad", [("nan", np.isnan), ("inf", np.isinf)])
def test_maybe_corrupt_poisons_origin(mode, bad):
    token = push_faults(FaultPlan(FaultSpec("engine.apply", mode=mode)))
    try:
        out = np.asarray(maybe_corrupt("engine.apply", np.ones((3, 4))))
        assert bad(out[0, 0])
        assert np.isfinite(out).sum() == out.size - 1  # exactly one element
    finally:
        pop_faults(token)


def test_maybe_corrupt_passthrough_without_plan():
    x = np.ones(4)
    assert maybe_corrupt("engine.apply", x) is x


def test_vmem_exhausted_is_non_raising():
    assert vmem_exhausted("kernel.fused") is False
    token = push_faults(FaultPlan(FaultSpec("kernel.fused", mode="vmem", times=1)))
    try:
        assert vmem_exhausted("kernel.fused") is True
        assert vmem_exhausted("kernel.fused") is False  # budget spent
    finally:
        pop_faults(token)


# --------------------------- xfft.config scoping ---------------------------


def test_config_scopes_faults_like_observe():
    plan = FaultPlan(FaultSpec("engine.apply"))
    assert active_faults() is None
    with xfft.config(faults=plan):
        assert active_faults() is not None
        assert active_faults().plan is plan
        with xfft.config(faults=False):  # inner scope turns chaos off
            assert active_faults() is None
        assert active_faults() is not None
    assert active_faults() is None


def test_config_rejects_non_plan_faults():
    with pytest.raises((TypeError, ValueError)):
        with xfft.config(faults="chaos"):
            pass


def test_config_rejects_unknown_check_health():
    with pytest.raises(ValueError):
        with xfft.config(check_health="inf"):
            pass


def test_config_check_health_scoped():
    from repro.xfft import get_config

    assert get_config().check_health == "off"
    with xfft.config(check_health="nan"):
        assert get_config().check_health == "nan"
    assert get_config().check_health == "off"
