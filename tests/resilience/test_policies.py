"""Serving-policy tests: deadlines, bounded retry, load shedding."""

import pytest

import repro.xfft as xfft
from repro import obs
from repro.resilience import (
    DeadlineExceeded,
    FaultPlan,
    FaultSpec,
    InjectedFault,
    Overloaded,
    ServicePolicy,
    admit,
    execute_with_policy,
)


def test_policy_validation():
    with pytest.raises(ValueError):
        ServicePolicy(deadline_s=0)
    with pytest.raises(ValueError):
        ServicePolicy(max_retries=-1)
    with pytest.raises(ValueError):
        ServicePolicy(backoff_s=-0.1)
    with pytest.raises(ValueError):
        ServicePolicy(backoff_jitter=-1)
    with pytest.raises(ValueError):
        ServicePolicy(max_queue=0)


def test_default_policy_is_permissive():
    p = ServicePolicy()
    admit(p, 10_000)                       # no shedding
    assert execute_with_policy(p, lambda: 42) == 42


def test_admit_sheds_past_max_queue():
    p = ServicePolicy(max_queue=4)
    admit(p, 4)  # at the limit: admitted
    with obs.capture() as trace:
        with pytest.raises(Overloaded) as ei:
            admit(p, 5, service="spectrum")
    assert ei.value.depth == 5
    assert ei.value.limit == 4
    (e,) = trace.select("serve.shed")
    assert e["depth"] == 5 and e["limit"] == 4 and e["service"] == "spectrum"


def test_retry_recovers_from_transient_failure():
    p = ServicePolicy(max_retries=2, backoff_s=0.01)
    calls = []
    slept = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise RuntimeError("transient")
        return "ok"

    with obs.capture() as trace:
        out = execute_with_policy(p, flaky, sleep=slept.append, service="lm")
    assert out == "ok"
    assert len(calls) == 3
    assert len(slept) == 2
    assert slept[1] > slept[0]  # exponential backoff
    retries = trace.select("resilience.retry")
    assert [e["attempt"] for e in retries] == [1, 2]
    assert all(e["service"] == "lm" for e in retries)


def test_retry_budget_exhaustion_propagates_error():
    p = ServicePolicy(max_retries=1, backoff_s=0.0)

    def always():
        raise RuntimeError("permanent")

    with pytest.raises(RuntimeError, match="permanent"):
        execute_with_policy(p, always, sleep=lambda _: None)


def test_backoff_jitter_is_seeded():
    def delays(policy):
        slept = []

        def flaky():
            if len(slept) < 3:
                raise RuntimeError("x")
            return None

        execute_with_policy(policy, flaky, sleep=slept.append)
        return slept

    a = delays(ServicePolicy(max_retries=3, backoff_s=0.01, seed=5))
    b = delays(ServicePolicy(max_retries=3, backoff_s=0.01, seed=5))
    c = delays(ServicePolicy(max_retries=3, backoff_s=0.01, seed=6))
    assert a == b
    assert a != c


def test_deadline_bounds_retries():
    clock = [0.0]

    def tick():
        return clock[0]

    def failing():
        clock[0] += 0.6  # each attempt eats over half the budget
        raise RuntimeError("slow failure")

    p = ServicePolicy(deadline_s=1.0, max_retries=5, backoff_s=0.0)
    with pytest.raises(DeadlineExceeded) as ei:
        execute_with_policy(p, failing, clock=tick, sleep=lambda _: None)
    assert ei.value.deadline_s == 1.0
    assert ei.value.elapsed_s >= 1.0


def test_overloaded_and_deadline_are_never_retried():
    p = ServicePolicy(max_retries=5, backoff_s=0.0)
    calls = []

    def shed():
        calls.append(1)
        raise Overloaded(10, 1)

    with pytest.raises(Overloaded):
        execute_with_policy(p, shed, sleep=lambda _: None)
    assert len(calls) == 1  # backpressure is an answer, not a transient

    calls.clear()

    def over():
        calls.append(1)
        raise DeadlineExceeded(1.0, 2.0)

    with pytest.raises(DeadlineExceeded):
        execute_with_policy(p, over, sleep=lambda _: None)
    assert len(calls) == 1


def test_serve_batch_fault_seam_is_retried():
    """An injected serve fault takes the same retry path a real one would."""
    plan = FaultPlan(FaultSpec("serve.batch", mode="error", times=1))
    p = ServicePolicy(max_retries=1, backoff_s=0.0)
    with obs.capture() as trace, xfft.config(faults=plan):
        out = execute_with_policy(p, lambda: "served", sleep=lambda _: None)
    assert out == "served"
    (retry,) = trace.select("resilience.retry")
    assert "InjectedFault" in retry["error"]


def test_serve_batch_fault_without_retry_budget_raises():
    plan = FaultPlan(FaultSpec("serve.batch", mode="error", times=1))
    with xfft.config(faults=plan):
        with pytest.raises(InjectedFault):
            execute_with_policy(ServicePolicy(), lambda: "served")
